"""Paper Fig. 4: the safe-guard buffer heat maps — K1 x K2 for ARIMA and
GP resource shaping (turnaround ratio vs baseline, memory slack,
application failures).

The paper's key result reproduced here: the GP's *uncertainty* makes K2
useful (failures fall as K2 grows, with modest slack cost), while
ARIMA's over-confident intervals leave all metrics roughly flat in K2;
K1=100% degenerates to the baseline; K1=0 without uncertainty is
failure-prone.

A thin call into ``repro.sim.sweep``: forecaster x K1 x K2 are sweep
axes plus one explicit baseline cell; all ARIMA/GP cells share the
process-wide jitted forecast cache and the cross-sim window batcher.
Writes ``BENCH_fig4.json``.
"""
from __future__ import annotations

from repro.sim import ClusterConfig, SimConfig, WorkloadConfig
from repro.sim.sweep import run_grid

K1S = (0.0, 0.05, 0.25, 1.0)
K2S = (0.0, 1.0, 3.0)
ARTIFACT = "BENCH_fig4.json"


def make_configs(scale: str = "quick"):
    if scale == "quick":
        wl = WorkloadConfig(n_apps=160, max_components=10,
                            max_runtime=4500.0, mean_burst_gap=1.0,
                            mean_long_gap=40.0, jumpy_frac=0.35, seed=5)
        cl = ClusterConfig(n_hosts=6, max_running_apps=96)
    else:
        wl = WorkloadConfig(n_apps=800, max_components=14,
                            max_runtime=4 * 3600.0, mean_burst_gap=0.5,
                            mean_long_gap=30.0, jumpy_frac=0.35, seed=5)
        cl = ClusterConfig(n_hosts=16, max_running_apps=256)
    return wl, cl


def run(scale: str = "quick", models=("arima", "gp"),
        out_path: str | None = ARTIFACT) -> list[dict]:
    wl, cl = make_configs(scale)
    base = SimConfig(cluster=cl, workload=wl, policy="pessimistic",
                     max_ticks=30_000)
    res = run_grid(
        base,
        axes={"forecaster": list(models),
              "safeguard.k1": list(K1S),
              "safeguard.k2": list(K2S)},
        cells=[{"policy": "baseline", "forecaster": "persist"}],
        seeds=None,                 # single run on the base workload seed
        out_path=out_path)

    by_name = {a["name"]: a for a in res.aggregates}
    b = next(a for a in res.aggregates
             if a["overrides"].get("policy") == "baseline")
    rows = [dict(model="baseline", k1=1.0, k2=0.0, turnaround_ratio=1.0,
                 slack_mem=b["slack_mem_mean"], failed_frac=0.0,
                 wall_s=b["wall_s"])]
    for model in models:
        for k1 in K1S:
            for k2 in K2S:
                name = (f"forecaster={model},safeguard.k1={k1},"
                        f"safeguard.k2={k2}")
                a = by_name[name]
                rows.append(dict(
                    model=model, k1=k1, k2=k2,
                    turnaround_ratio=a["turnaround_speedup"],
                    slack_mem=a["slack_mem_mean"],
                    failed_frac=a["failed_frac"],
                    wall_s=a["wall_s"]))
    return rows


def main(quick: bool = True) -> None:
    rows = run("quick" if quick else "full")
    print("model,K1,K2,turnaround_ratio,slack_mem,failed_frac,wall_s")
    for r in rows:
        print(f"{r['model']},{r['k1']},{r['k2']},"
              f"{r['turnaround_ratio']:.2f},{r['slack_mem']:.3f},"
              f"{r['failed_frac']:.3f},{r['wall_s']}")
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
