"""Calibration study: coverage / turnaround / failure trade-offs.

The paper's safeguard (Eq. 9) buys failure avoidance with K2 sigma-bands
whose *nominal* coverage assumes Gaussian residuals; ADARES and Flex
both argue the confidence feeding such decisions must be adaptive.  This
study quantifies the gap and what conformal calibration
(:mod:`repro.core.uncertainty`) does about it, across every scenario
family:

  1. **Coverage diagnostics** (per family): Gaussian vs split-conformal
     band coverage at several nominal levels, pinball loss, CRPS, and
     the empirical coverage of the paper's K2 = 3 band vs its 0.99865
     Gaussian nominal — the trustworthiness deficit.
  2. **Simulation sweep**: baseline vs pessimistic shaping under the
     ``sigma`` / ``conformal`` / ``adaptive`` safeguard modes; reports
     turnaround (vs the same scenario's baseline), failure rate (vs the
     configured budget), utilization, and the engine's online
     calibration telemetry.
  3. **Criteria block**: the acceptance checks in machine-readable form
     (conformal coverage within +-3 points of nominal on `heavytail`,
     failure rate at or below the budget, turnaround on `google` no
     worse than the K2 = 3 sigma baseline).

Writes ``BENCH_calibration.json``.
"""
from __future__ import annotations

import dataclasses
import json
import time

from repro.sim import ClusterConfig, SimConfig, WorkloadConfig
from repro.sim.scenarios import build_trace, make_config
from repro.sim.scenarios.diagnostics import coverage_report
from repro.sim.sweep import run_grid

SCENARIOS = ("google", "diurnal", "flashcrowd", "heavytail", "colocated")
ARTIFACT = "BENCH_calibration.json"
TARGET_Q = 0.9
BUDGET = 0.1


def _coverage_block(scale: str, forecaster: str) -> list[dict]:
    n_series, n_eval = (64, 16) if scale == "quick" else (256, 24)
    out = []
    for fam in SCENARIOS:
        tr = build_trace(make_config(fam, n_apps=64, seed=0))
        rep = coverage_report(tr, forecaster, n_series=n_series,
                              n_eval=n_eval, q_levels=(0.8, TARGET_Q, 0.95))
        out.append({"scenario": fam, **rep})
    return out


def _sim_block(scale: str, forecaster: str, out_scenarios) -> dict:
    if scale == "quick":
        wl = WorkloadConfig(n_apps=48, max_components=8,
                            max_runtime=2700.0, mean_burst_gap=2.0,
                            mean_long_gap=40.0)
        cl = ClusterConfig(n_hosts=4, max_running_apps=48)
        seeds = [0]
    else:
        wl = WorkloadConfig(n_apps=400, max_components=12)
        cl = ClusterConfig(n_hosts=16, max_running_apps=256)
        seeds = [0, 1, 2]
    base = SimConfig(cluster=cl, workload=wl, forecaster=forecaster,
                     max_ticks=60_000)
    base = dataclasses.replace(
        base, calibration=dataclasses.replace(
            base.calibration, q=TARGET_Q, budget=BUDGET))
    cells = []
    for scen in out_scenarios:
        cells.append({"scenario": scen, "policy": "baseline"})
        for mode in ("sigma", "conformal", "adaptive"):
            cells.append({"scenario": scen, "policy": "pessimistic",
                          "calibration": mode})
    res = run_grid(base, cells=cells, seeds=seeds, forecast_diag=False)
    return {"cells": res.cells, "aggregates": res.aggregates,
            "wall_s": res.wall_s}


def _criteria(coverage: list[dict], sims: dict) -> dict:
    ht = next(c for c in coverage if c["scenario"] == "heavytail")
    lv = next(r for r in ht["levels"] if abs(r["q"] - TARGET_Q) < 1e-9)
    gap = abs(lv["conformal_coverage"] - TARGET_Q)

    def agg(scen, policy, mode=None):
        for a in sims["aggregates"]:
            o = a["overrides"]
            if (a["scenario"] == scen and o.get("policy") == policy
                    and o.get("calibration", None) == mode):
                return a
        return None

    cal_fail = [a["failed_frac"] for a in sims["aggregates"]
                if a["overrides"].get("calibration") in ("conformal",
                                                         "adaptive")]
    g_sigma = agg("google", "pessimistic", "sigma")
    g_conf = agg("google", "pessimistic", "conformal")
    ratio = (g_conf["turnaround_mean"] / g_sigma["turnaround_mean"]
             if g_sigma and g_conf else None)
    return {
        "target_q": TARGET_Q,
        "failure_budget": BUDGET,
        "heavytail_conformal_coverage": lv["conformal_coverage"],
        "heavytail_gaussian_coverage": lv["gaussian_coverage"],
        "heavytail_conformal_abs_gap": round(gap, 4),
        "heavytail_within_3pts": bool(gap <= 0.03),
        "heavytail_k2_coverage": ht["k2_coverage"],
        "heavytail_k2_nominal": ht["k2_nominal"],
        "heavytail_k2_undercovers": bool(
            ht["k2_coverage"] < ht["k2_nominal"]),
        "max_failed_frac_calibrated": max(cal_fail) if cal_fail else None,
        "failure_within_budget": bool(
            cal_fail and max(cal_fail) <= BUDGET),
        "google_turnaround_ratio_conformal_vs_sigma":
            round(ratio, 4) if ratio is not None else None,
        "google_no_worse": bool(ratio is not None and ratio <= 1.0 + 1e-6),
    }


def run(scale: str = "quick", out_path: str | None = ARTIFACT) -> dict:
    t0 = time.time()
    forecaster = "persist" if scale == "quick" else "gp"
    sim_scens = (("google", "heavytail") if scale == "quick"
                 else SCENARIOS)
    coverage = _coverage_block(scale, forecaster)
    sims = _sim_block(scale, forecaster, sim_scens)
    data = {
        "schema": 1,
        "scale": scale,
        "forecaster": forecaster,
        "coverage": coverage,
        "sweep": sims,
        "criteria": _criteria(coverage, sims),
        "wall_s": round(time.time() - t0, 2),
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
    return data


def main(quick: bool = True) -> None:
    data = run("quick" if quick else "full")
    print("scenario,k2_cov(nom 0.99865),gauss_cov@q90,conf_cov@q90")
    for c in data["coverage"]:
        lv = next(r for r in c["levels"] if abs(r["q"] - TARGET_Q) < 1e-9)
        print(f"{c['scenario']},{c['k2_coverage']:.4f},"
              f"{lv['gaussian_coverage']:.4f},"
              f"{lv['conformal_coverage']:.4f}")
    print("scenario,policy,mode,turnaround,speedup,failed_frac,"
          "online_coverage")
    for a in data["sweep"]["aggregates"]:
        mode = a["overrides"].get("calibration", "-")
        cov = None
        for c in data["sweep"]["cells"]:
            if c["name"] == a["name"]:
                cov = (c["summary"].get("calibration") or {}).get("coverage")
                break
        print(f"{a['scenario']},{a['overrides']['policy']},{mode},"
              f"{a['turnaround_mean']:.0f},"
              f"{a.get('turnaround_speedup', float('nan')):.2f},"
              f"{a['failed_frac']:.3f},{cov}")
    print("# criteria:", json.dumps(data["criteria"]))
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
