"""Bench regression ratchet: diff fresh BENCH_*.json against baselines.

``python -m benchmarks.run --compare <baseline_dir>`` (or ``python -m
benchmarks.compare <baseline_dir> [fresh_dir]``) walks every
``BENCH_*.json`` present in BOTH directories and flags regressions:

  * any ``criteria`` key that is true in the baseline but false in the
    fresh artifact — a contract the repo used to meet and no longer
    does — is always a regression;
  * selected numeric keys (:data:`TOLERANCES`) may not degrade by more
    than their tolerance ratio.  Tolerances are deliberately generous:
    CI runners are shared and noisy, and the perf benches already do
    best-of + escalating re-measurement, so the ratchet exists to
    catch step-function regressions (a 2x slowdown, a broken
    safeguard), not 3% jitter.

Baselines live in ``benchmarks/baselines/`` (committed — the
``BENCH_*.json`` gitignore carries an exception for that directory) and
are refreshed deliberately by committing new artifacts, which is what
makes this a ratchet: improvements are free, degradations need a
human to re-baseline.

Exit status: nonzero when any regression is found (CI fails the job).
Artifacts present only on one side are reported but never fail — new
benches have no baseline yet, and sections can be skipped locally.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Sequence

__all__ = ["TOLERANCES", "compare_artifact", "compare_dirs", "main"]

#: artifact basename -> (dotted key path, direction, tolerance ratio).
#: direction "higher" = fresh may not drop below baseline * (1 - tol);
#: "lower" = fresh may not rise above baseline * (1 + tol).
TOLERANCES = {
    "BENCH_engine.json": (
        ("cohort_ticks_per_s", "higher", 0.5),
        ("scan_ticks_per_s", "higher", 0.5),
        ("leap.leap_ticks_per_s", "higher", 0.5),
        ("gp.bucketed_row_overhead", "lower", 0.25),
        # compile-time ratchet: one scan program's jit wall (schema 2).
        # Generous — compile time is allocator/OS sensitive — but a
        # tracing blow-up (accidental unroll, bucket key explosion)
        # lands far above 1.5x.
        ("scan_compile_s", "lower", 1.5),
    ),
    "BENCH_obs.json": (
        ("overhead.on_ticks_per_s", "higher", 0.5),
        ("overhead.on_overhead", "higher", 0.15),
    ),
    "BENCH_tenancy.json": (
        ("perf.on_ticks_per_s", "higher", 0.5),
    ),
    "BENCH_shard.json": (
        ("fleet.speedup", "higher", 0.5),
    ),
    "BENCH_replay.json": (
        ("stream.ticks_per_s", "higher", 0.5),
        # residency ratchet: peak device rows per trace task — a
        # compaction regression (rows not reclaimed) lands orders of
        # magnitude above any noise band
        ("stream.residency", "lower", 1.0),
    ),
}


def _dig(doc: dict, path: str):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def compare_artifact(name: str, base: dict, fresh: dict) -> list[str]:
    """Regressions for one artifact (empty list = clean)."""
    problems = []
    base_crit = base.get("criteria", {})
    fresh_crit = fresh.get("criteria", {})
    for key, ok in sorted(base_crit.items()):
        if ok is True and fresh_crit.get(key) is False:
            problems.append(f"{name}: criterion {key!r} regressed "
                            f"true -> false")
    for path, direction, tol in TOLERANCES.get(name, ()):
        b, f = _dig(base, path), _dig(fresh, path)
        if not isinstance(b, (int, float)) or not isinstance(f, (int, float)):
            continue
        if direction == "higher" and f < b * (1.0 - tol):
            problems.append(
                f"{name}: {path} fell {b:.4g} -> {f:.4g} "
                f"(> {tol:.0%} below baseline)")
        elif direction == "lower" and f > b * (1.0 + tol):
            problems.append(
                f"{name}: {path} rose {b:.4g} -> {f:.4g} "
                f"(> {tol:.0%} above baseline)")
    return problems


def compare_dirs(baseline_dir: str, fresh_dir: str = ".") -> list[str]:
    """Regressions across every artifact present in both directories."""

    def _artifacts(d):
        try:
            return {f for f in os.listdir(d)
                    if f.startswith("BENCH_") and f.endswith(".json")
                    and not any(s in f for s in
                                (".manifest", ".sweep", ".trace"))}
        except OSError:
            return set()

    base_names = _artifacts(baseline_dir)
    fresh_names = _artifacts(fresh_dir)
    problems: list[str] = []
    compared = 0
    for name in sorted(base_names & fresh_names):
        with open(os.path.join(baseline_dir, name)) as f:
            base = json.load(f)
        with open(os.path.join(fresh_dir, name)) as f:
            fresh = json.load(f)
        found = compare_artifact(name, base, fresh)
        compared += 1
        status = "REGRESSED" if found else "ok"
        print(f"# compare {name}: {status}")
        problems.extend(found)
    for name in sorted(base_names - fresh_names):
        print(f"# compare {name}: no fresh artifact (section skipped?)")
    for name in sorted(fresh_names - base_names):
        print(f"# compare {name}: no baseline yet")
    if not compared:
        print("# compare: no artifact present in both directories")
    return problems


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.compare",
        description="Diff fresh BENCH_*.json against committed "
                    "baselines; nonzero exit on regression.")
    ap.add_argument("baseline_dir",
                    help="directory of committed baseline artifacts "
                         "(e.g. benchmarks/baselines)")
    ap.add_argument("fresh_dir", nargs="?", default=".",
                    help="directory of freshly produced artifacts "
                         "(default: cwd)")
    ns = ap.parse_args(argv)
    problems = compare_dirs(ns.baseline_dir, ns.fresh_dir)
    for p in problems:
        print(f"REGRESSION: {p}")
    if problems:
        return 1
    print("# compare: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
