"""Engine throughput benchmark: host loop vs device-resident scan engine.

Measures ticks/second on the sweep's quick-grid configuration for

  * ``host``    — the pre-refactor vectorized host-loop engine
                  (``repro.sim.engine.run_sim``): NumPy state, one
                  device round-trip per tick;
  * ``scan``    — the fused scan engine (``repro.sim.step``):
                  device-resident state, ``lax.scan`` over tick chunks;
  * ``cohort``  — a whole seed cohort vmapped into ONE device program
                  (``run_cohort_scan``), the sweep's cohort fast path.

Writes ``BENCH_engine.json`` and asserts the PR's acceptance criteria:
scan >= 3x host on a single sim, cohort >= 8x host aggregate
ticks/second.  Timings are best-of-N wall clock after a compile warm-up
(CI boxes are noisy; best-of is the stable estimator of the no-
interference run).  Equivalence of the engines' results is asserted
here too — a throughput win that changes results would be meaningless.

The ``gp`` block measures the ROADMAP's masked-forecast concern on a
tiny GP cell.  The scan engine used to forecast the FULL padded monitor
batch whenever any row was ready — ``rows_batch / rows_ready`` extra
model compute on forecasting ticks (the padded formula is still
reported as ``masked_row_overhead`` for reference).  Ragged bucketed
batching (``SimConfig.forecast_bucket``, default on) compacts the ready
rows into power-of-2 passes instead, so the EFFECTIVE overhead the
model now pays is ``bucketed_row_overhead`` (rows actually computed /
rows ready) — asserted ``<= 2x`` by the ``bucket_overhead_2x``
criterion.  ``bucket_cache_entries`` counts the distinct per-bucket jit
programs the run compiled (one cache entry per bucket size).

The ``leap`` block measures event-driven leap ticks
(``SimConfig.leap``) on a bursty flashcrowd trace with long idle gaps:
the uniform scan engine pays one fused tick per minute of simulated
time; the leap engine skips provably-idle tick runs in a scalar
while_loop and pays ~one fused tick per NON-idle tick.  Results are
bit-identical (asserted: ``leap_identical``); the throughput win is
asserted ``>= 3x`` on this trace (``leap_3x``).

Usage::

    python -m benchmarks.engine [--full] [--out BENCH_engine.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

SPEEDUP_SINGLE = 3.0      # acceptance: scan vs host, one sim
SPEEDUP_COHORT = 8.0      # acceptance: vmapped cohort vs host, aggregate
SPEEDUP_LEAP = 3.0        # acceptance: leap vs uniform scan, bursty trace
BUCKET_OVERHEAD = 2.0     # acceptance: effective gp row overhead ceiling
COHORT_SEEDS = 8


# the shared best-of-N timer (repro.obs.timing) — one implementation
# across every benchmark instead of a copy per file
from repro.obs.timing import best_of as _best_of  # noqa: E402

GP_COHORT_SEEDS = 4


def _gp_overhead(reps: int) -> dict:
    """Masked-forecast overhead on a tiny GP cell (see module doc)."""
    import dataclasses as dc

    from repro.sim import (ClusterConfig, SimConfig, WorkloadConfig,
                           generate, run_sim)
    from repro.sim.step import run_cohort_scan, run_sim_scan

    cfg = SimConfig(
        cluster=ClusterConfig(n_hosts=2, max_running_apps=8),
        workload=WorkloadConfig(n_apps=16, max_components=4,
                                max_runtime=1200.0, mean_burst_gap=4.0,
                                mean_long_gap=60.0, seed=0),
        policy="pessimistic", forecaster="gp", max_ticks=4000)
    wl = generate(cfg.workload)
    seeds = list(range(GP_COHORT_SEEDS))
    wls = [generate(dc.replace(cfg.workload, seed=s)) for s in seeds]
    chunk = 32

    host_res = run_sim(cfg, wl)                      # warm-up + anchor
    scan_res = run_sim_scan(cfg, wl, chunk=chunk)
    cohort_res = run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls)
    assert scan_res.turnaround == host_res.turnaround, \
        "gp scan diverged from gp host run"
    n_ticks = len(host_res.util_cpu)
    cohort_ticks = sum(len(r.util_cpu) for r in cohort_res)

    reps = max(reps // 2, 2)
    host_s = _best_of(lambda: run_sim(cfg, wl), reps)
    scan_s = _best_of(lambda: run_sim_scan(cfg, wl, chunk=chunk), reps)
    cohort_s = _best_of(
        lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls), reps)

    from repro.obs.metrics import REGISTRY

    rows = scan_res.forecast_rows
    # reference: what the padded batch WOULD cost without bucketing
    # (the pre-bucketing engine's cost, kept for cross-schema comparison)
    masked = (rows["rows_batch"] * rows["ticks_forecasting"]
              / max(rows["rows_ready"], 1))
    # effective: rows the model actually computed under ragged bucketing
    bucketed = rows["rows_bucketed"] / max(rows["rows_ready"], 1)
    return {
        "config": {"n_apps": cfg.workload.n_apps,
                   "max_running_apps": cfg.cluster.max_running_apps,
                   "cohort_seeds": GP_COHORT_SEEDS},
        "n_ticks": n_ticks,
        "host_ticks_per_s": round(n_ticks / host_s, 1),
        "scan_ticks_per_s": round(n_ticks / scan_s, 1),
        "cohort_ticks_per_s": round(cohort_ticks / cohort_s, 1),
        "forecast_rows": rows,
        "masked_row_overhead": round(masked, 2),
        "bucketed_row_overhead": round(bucketed, 2),
        "bucket_cache_entries":
            int(REGISTRY.gauge("scan.bucket_cache_entries").value),
    }


def _leap_speedup(reps: int) -> dict:
    """Leap vs uniform scan on a bursty flashcrowd cell (see module doc).

    The trace is deliberately gap-dominated: a handful of background
    apps 1h apart plus three flash events with minute-scale runtimes —
    most simulated ticks have an empty cluster AND an empty queue, which
    is exactly the regime the leap while_loop collapses."""
    from repro.sim import ClusterConfig, SimConfig
    from repro.sim.scenarios import make_config
    from repro.sim.step import run_sim_scan

    cfg = SimConfig(
        cluster=ClusterConfig(n_hosts=2, max_running_apps=16),
        workload=make_config(
            "flashcrowd", n_apps=24, max_components=4, seed=0,
            burst_frac=0.75, n_events=3, event_gap_s=2.0,
            mean_gap=10_800.0, min_runtime=120.0, max_runtime=600.0,
            bg_max_runtime=900.0),
        policy="pessimistic", forecaster="persist", max_ticks=20_000)
    leap_cfg = dataclasses.replace(cfg, leap=True)
    chunk = 32

    uni_res = run_sim_scan(cfg, chunk=chunk)         # warm-up + anchor
    leap_res = run_sim_scan(leap_cfg, chunk=chunk)
    identical = (uni_res.summary() == leap_res.summary()
                 and uni_res.turnaround == leap_res.turnaround
                 and uni_res.util_cpu == leap_res.util_cpu
                 and uni_res.n_running == leap_res.n_running)
    n_ticks = len(uni_res.util_cpu)
    busy = sum(1 for n in uni_res.n_running if n > 0)

    reps = max(reps // 2, 2)
    uni_s = _best_of(lambda: run_sim_scan(cfg, chunk=chunk), reps)
    leap_s = _best_of(lambda: run_sim_scan(leap_cfg, chunk=chunk), reps)
    if n_ticks / leap_s < SPEEDUP_LEAP * (n_ticks / uni_s):
        # noisy-runner re-measurement, same policy as the main blocks
        uni_s = min(uni_s, _best_of(
            lambda: run_sim_scan(cfg, chunk=chunk), 2 * reps))
        leap_s = min(leap_s, _best_of(
            lambda: run_sim_scan(leap_cfg, chunk=chunk), 2 * reps))
    speedup = (n_ticks / leap_s) / (n_ticks / uni_s)
    return {
        "config": {"scenario": "flashcrowd",
                   "n_apps": cfg.workload.n_apps,
                   "mean_gap_s": cfg.workload.mean_gap,
                   "max_running_apps": cfg.cluster.max_running_apps,
                   "chunk": chunk},
        "n_ticks": n_ticks,
        "busy_ticks": busy,
        "uniform_ticks_per_s": round(n_ticks / uni_s, 1),
        "leap_ticks_per_s": round(n_ticks / leap_s, 1),
        "speedup": round(speedup, 2),
        "identical": identical,
    }


def run(quick: bool = True, out: str = "BENCH_engine.json",
        reps: int = 5) -> dict:
    from repro.sim import generate, run_sim
    from repro.sim.step import run_cohort_scan, run_sim_scan
    from repro.sim.sweep import quick_base_config

    # quick: the small-A regime the refactor targets (ROADMAP: the
    # host engine's per-tick ShapeProblem device_puts dominate at small
    # A); --full: the sweep's standard quick-grid scale
    if quick:
        cfg = quick_base_config(n_apps=32, n_hosts=2, max_components=6)
        cfg = dataclasses.replace(
            cfg, cluster=dataclasses.replace(cfg.cluster,
                                             max_running_apps=16))
    else:
        cfg = quick_base_config(n_apps=64)
    cfg = dataclasses.replace(cfg, policy="pessimistic",
                              forecaster="persist")
    wl = generate(cfg.workload)
    wls = [generate(dataclasses.replace(cfg.workload, seed=s))
           for s in range(COHORT_SEEDS)]
    chunk = 32
    seeds = list(range(COHORT_SEEDS))

    # -- warm-up (jit compile) + result equivalence ---------------------
    host_res = run_sim(cfg, wl)
    t0 = time.perf_counter()
    scan_res = run_sim_scan(cfg, wl, chunk=chunk)
    compile_s = time.perf_counter() - t0
    cohort_res = run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls)
    n_ticks = len(host_res.util_cpu)
    assert len(scan_res.util_cpu) == n_ticks
    assert scan_res.turnaround == host_res.turnaround, \
        "scan engine diverged from host engine on the quick grid"

    # -- timed runs -----------------------------------------------------
    # best-of wall clock; if a criterion misses (noisy shared CI
    # runners), fold in ONE re-measurement with more reps before
    # declaring failure — the thresholds gate the code, not the tenant
    # the runner happened to share a core with
    host_s = _best_of(lambda: run_sim(cfg, wl), reps)
    scan_s = _best_of(lambda: run_sim_scan(cfg, wl, chunk=chunk), reps)
    cohort_s = _best_of(
        lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls),
        max(reps // 2, 2))
    cohort_ticks = sum(len(r.util_cpu) for r in cohort_res)
    if (n_ticks / scan_s < SPEEDUP_SINGLE * (n_ticks / host_s)
            or cohort_ticks / cohort_s
            < SPEEDUP_COHORT * (n_ticks / host_s)):
        scan_s = min(scan_s, _best_of(
            lambda: run_sim_scan(cfg, wl, chunk=chunk), 2 * reps))
        cohort_s = min(cohort_s, _best_of(
            lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls),
            reps))

    host_tps = n_ticks / host_s
    scan_tps = n_ticks / scan_s
    cohort_tps = cohort_ticks / cohort_s
    gp = _gp_overhead(reps)
    leap = _leap_speedup(reps)
    result = {
        "schema": 2,
        "quick": quick,
        "config": {"n_apps": cfg.workload.n_apps,
                   "n_hosts": cfg.cluster.n_hosts,
                   "max_running_apps": cfg.cluster.max_running_apps,
                   "policy": cfg.policy, "forecaster": cfg.forecaster,
                   "chunk": chunk, "cohort_seeds": COHORT_SEEDS},
        "n_ticks": n_ticks,
        "cohort_ticks": cohort_ticks,
        "host_ticks_per_s": round(host_tps, 1),
        "scan_ticks_per_s": round(scan_tps, 1),
        "cohort_ticks_per_s": round(cohort_tps, 1),
        "scan_compile_s": round(compile_s, 2),
        "speedup_single": round(scan_tps / host_tps, 2),
        "speedup_cohort": round(cohort_tps / host_tps, 2),
        "criteria": {
            "single_3x": scan_tps / host_tps >= SPEEDUP_SINGLE,
            "cohort_8x": cohort_tps / host_tps >= SPEEDUP_COHORT,
            "results_identical": True,   # asserted above
            "leap_3x": leap["speedup"] >= SPEEDUP_LEAP,
            "leap_identical": leap["identical"],
            "bucket_overhead_2x":
                gp["bucketed_row_overhead"] <= BUCKET_OVERHEAD,
        },
        "gp": gp,
        "leap": leap,
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"host   {host_tps:8.0f} ticks/s")
    print(f"scan   {scan_tps:8.0f} ticks/s  ({result['speedup_single']}x)")
    print(f"cohort {cohort_tps:8.0f} ticks/s  ({result['speedup_cohort']}x "
          f"aggregate, {COHORT_SEEDS} seeds)")
    print(f"gp     host {gp['host_ticks_per_s']:.0f} / scan "
          f"{gp['scan_ticks_per_s']:.0f} / cohort "
          f"{gp['cohort_ticks_per_s']:.0f} ticks/s; row overhead "
          f"{gp['bucketed_row_overhead']}x bucketed (was "
          f"{gp['masked_row_overhead']}x padded) on "
          f"{gp['forecast_rows']['ticks_forecasting']} forecasting ticks")
    print(f"leap   {leap['leap_ticks_per_s']:.0f} vs uniform "
          f"{leap['uniform_ticks_per_s']:.0f} ticks/s "
          f"({leap['speedup']}x, {leap['busy_ticks']}/{leap['n_ticks']} "
          f"busy ticks, identical={leap['identical']})")
    print(f"-> {out}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.engine")
    ap.add_argument("--full", action="store_true",
                    help="larger workload (slower, steadier estimates)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args()
    run(quick=not args.full, out=args.out, reps=args.reps)


if __name__ == "__main__":
    main()
