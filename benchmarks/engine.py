"""Engine throughput benchmark: host loop vs device-resident scan engine.

Measures ticks/second on the sweep's quick-grid configuration for

  * ``host``    — the pre-refactor vectorized host-loop engine
                  (``repro.sim.engine.run_sim``): NumPy state, one
                  device round-trip per tick;
  * ``scan``    — the fused scan engine (``repro.sim.step``):
                  device-resident state, ``lax.scan`` over tick chunks;
  * ``cohort``  — a whole seed cohort vmapped into ONE device program
                  (``run_cohort_scan``), the sweep's cohort fast path.

Writes ``BENCH_engine.json`` and asserts the PR's acceptance criteria:
scan >= 3x host on a single sim, cohort >= 8x host aggregate
ticks/second.  Timings are best-of-N wall clock after a compile warm-up
(CI boxes are noisy; best-of is the stable estimator of the no-
interference run).  Equivalence of the engines' results is asserted
here too — a throughput win that changes results would be meaningless.

The ``gp`` block measures the ROADMAP's masked-forecast concern on a
tiny GP cell: the scan engine forecasts the FULL padded monitor batch
whenever any row is ready (per-row compaction needs dynamic shapes),
so GP cohorts pay ``rows_batch / rows_ready`` extra model compute on
forecasting ticks.  Solo scan programs gate the model on ``ready.any()``
(skipping warm-up/grace and post-completion ticks outright); under a
cohort vmap that gate lowers to a select, which is exactly the overhead
reported here (``forecast_rows`` telemetry + host/scan/cohort
ticks-per-second on the same GP cell).

Usage::

    python -m benchmarks.engine [--full] [--out BENCH_engine.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

SPEEDUP_SINGLE = 3.0      # acceptance: scan vs host, one sim
SPEEDUP_COHORT = 8.0      # acceptance: vmapped cohort vs host, aggregate
COHORT_SEEDS = 8


# the shared best-of-N timer (repro.obs.timing) — one implementation
# across every benchmark instead of a copy per file
from repro.obs.timing import best_of as _best_of  # noqa: E402

GP_COHORT_SEEDS = 4


def _gp_overhead(reps: int) -> dict:
    """Masked-forecast overhead on a tiny GP cell (see module doc)."""
    import dataclasses as dc

    from repro.sim import (ClusterConfig, SimConfig, WorkloadConfig,
                           generate, run_sim)
    from repro.sim.step import run_cohort_scan, run_sim_scan

    cfg = SimConfig(
        cluster=ClusterConfig(n_hosts=2, max_running_apps=8),
        workload=WorkloadConfig(n_apps=16, max_components=4,
                                max_runtime=1200.0, mean_burst_gap=4.0,
                                mean_long_gap=60.0, seed=0),
        policy="pessimistic", forecaster="gp", max_ticks=4000)
    wl = generate(cfg.workload)
    seeds = list(range(GP_COHORT_SEEDS))
    wls = [generate(dc.replace(cfg.workload, seed=s)) for s in seeds]
    chunk = 32

    host_res = run_sim(cfg, wl)                      # warm-up + anchor
    scan_res = run_sim_scan(cfg, wl, chunk=chunk)
    cohort_res = run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls)
    assert scan_res.turnaround == host_res.turnaround, \
        "gp scan diverged from gp host run"
    n_ticks = len(host_res.util_cpu)
    cohort_ticks = sum(len(r.util_cpu) for r in cohort_res)

    reps = max(reps // 2, 2)
    host_s = _best_of(lambda: run_sim(cfg, wl), reps)
    scan_s = _best_of(lambda: run_sim_scan(cfg, wl, chunk=chunk), reps)
    cohort_s = _best_of(
        lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls), reps)

    rows = scan_res.forecast_rows
    # the compute a compacting forecaster would need vs what the padded
    # batch costs across the ticks that actually invoked the model
    masked = (rows["rows_batch"] * rows["ticks_forecasting"]
              / max(rows["rows_ready"], 1))
    return {
        "config": {"n_apps": cfg.workload.n_apps,
                   "max_running_apps": cfg.cluster.max_running_apps,
                   "cohort_seeds": GP_COHORT_SEEDS},
        "n_ticks": n_ticks,
        "host_ticks_per_s": round(n_ticks / host_s, 1),
        "scan_ticks_per_s": round(n_ticks / scan_s, 1),
        "cohort_ticks_per_s": round(cohort_ticks / cohort_s, 1),
        "forecast_rows": rows,
        "masked_row_overhead": round(masked, 2),
    }


def run(quick: bool = True, out: str = "BENCH_engine.json",
        reps: int = 5) -> dict:
    from repro.sim import generate, run_sim
    from repro.sim.step import run_cohort_scan, run_sim_scan
    from repro.sim.sweep import quick_base_config

    # quick: the small-A regime the refactor targets (ROADMAP: the
    # host engine's per-tick ShapeProblem device_puts dominate at small
    # A); --full: the sweep's standard quick-grid scale
    if quick:
        cfg = quick_base_config(n_apps=32, n_hosts=2, max_components=6)
        cfg = dataclasses.replace(
            cfg, cluster=dataclasses.replace(cfg.cluster,
                                             max_running_apps=16))
    else:
        cfg = quick_base_config(n_apps=64)
    cfg = dataclasses.replace(cfg, policy="pessimistic",
                              forecaster="persist")
    wl = generate(cfg.workload)
    wls = [generate(dataclasses.replace(cfg.workload, seed=s))
           for s in range(COHORT_SEEDS)]
    chunk = 32
    seeds = list(range(COHORT_SEEDS))

    # -- warm-up (jit compile) + result equivalence ---------------------
    host_res = run_sim(cfg, wl)
    t0 = time.perf_counter()
    scan_res = run_sim_scan(cfg, wl, chunk=chunk)
    compile_s = time.perf_counter() - t0
    cohort_res = run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls)
    n_ticks = len(host_res.util_cpu)
    assert len(scan_res.util_cpu) == n_ticks
    assert scan_res.turnaround == host_res.turnaround, \
        "scan engine diverged from host engine on the quick grid"

    # -- timed runs -----------------------------------------------------
    # best-of wall clock; if a criterion misses (noisy shared CI
    # runners), fold in ONE re-measurement with more reps before
    # declaring failure — the thresholds gate the code, not the tenant
    # the runner happened to share a core with
    host_s = _best_of(lambda: run_sim(cfg, wl), reps)
    scan_s = _best_of(lambda: run_sim_scan(cfg, wl, chunk=chunk), reps)
    cohort_s = _best_of(
        lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls),
        max(reps // 2, 2))
    cohort_ticks = sum(len(r.util_cpu) for r in cohort_res)
    if (n_ticks / scan_s < SPEEDUP_SINGLE * (n_ticks / host_s)
            or cohort_ticks / cohort_s
            < SPEEDUP_COHORT * (n_ticks / host_s)):
        scan_s = min(scan_s, _best_of(
            lambda: run_sim_scan(cfg, wl, chunk=chunk), 2 * reps))
        cohort_s = min(cohort_s, _best_of(
            lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls),
            reps))

    host_tps = n_ticks / host_s
    scan_tps = n_ticks / scan_s
    cohort_tps = cohort_ticks / cohort_s
    result = {
        "schema": 1,
        "quick": quick,
        "config": {"n_apps": cfg.workload.n_apps,
                   "n_hosts": cfg.cluster.n_hosts,
                   "max_running_apps": cfg.cluster.max_running_apps,
                   "policy": cfg.policy, "forecaster": cfg.forecaster,
                   "chunk": chunk, "cohort_seeds": COHORT_SEEDS},
        "n_ticks": n_ticks,
        "cohort_ticks": cohort_ticks,
        "host_ticks_per_s": round(host_tps, 1),
        "scan_ticks_per_s": round(scan_tps, 1),
        "cohort_ticks_per_s": round(cohort_tps, 1),
        "scan_compile_s": round(compile_s, 2),
        "speedup_single": round(scan_tps / host_tps, 2),
        "speedup_cohort": round(cohort_tps / host_tps, 2),
        "criteria": {
            "single_3x": scan_tps / host_tps >= SPEEDUP_SINGLE,
            "cohort_8x": cohort_tps / host_tps >= SPEEDUP_COHORT,
            "results_identical": True,   # asserted above
        },
        "gp": _gp_overhead(reps),
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"host   {host_tps:8.0f} ticks/s")
    print(f"scan   {scan_tps:8.0f} ticks/s  ({result['speedup_single']}x)")
    print(f"cohort {cohort_tps:8.0f} ticks/s  ({result['speedup_cohort']}x "
          f"aggregate, {COHORT_SEEDS} seeds)")
    gp = result["gp"]
    print(f"gp     host {gp['host_ticks_per_s']:.0f} / scan "
          f"{gp['scan_ticks_per_s']:.0f} / cohort "
          f"{gp['cohort_ticks_per_s']:.0f} ticks/s; masked-row overhead "
          f"{gp['masked_row_overhead']}x on "
          f"{gp['forecast_rows']['ticks_forecasting']} forecasting ticks")
    print(f"-> {out}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.engine")
    ap.add_argument("--full", action="store_true",
                    help="larger workload (slower, steadier estimates)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args()
    run(quick=not args.full, out=args.out, reps=args.reps)


if __name__ == "__main__":
    main()
