"""Paper Fig. 2: forecast error distributions — ARIMA vs GP-Exp vs
GP-RBF, history h in {10, 20, 40}.

The paper evaluates on ~6000 memory-usage series from their academic
cluster; we evaluate on utilization series sampled from a scenario's
ground-truth profiles (default: the Google-trace-shaped family, §4.1),
one-step-ahead rolling forecasts.  Reported: error quartiles per
(model, h) — the paper's boxplot as numbers — plus mean |z| calibration
(error in predictive sigmas; >> 1 = over-confidence).

Series come from ``repro.sim.scenarios.diagnostics`` — the same sampler
the sweep uses for its per-scenario forecast-error records — so pass
``scenario="flashcrowd"`` (etc.) to redo Fig. 2 on any registered
workload family.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forecast import ARIMAForecaster, GPConfig, GPForecaster
from repro.sim.scenarios import build_trace, make_config
from repro.sim.scenarios.diagnostics import sample_usage_series


def utilization_series(n_series: int, length: int, seed: int,
                       scenario: str = "google") -> np.ndarray:
    """Memory-usage series sampled from a scenario's app profiles."""
    cfg = make_config(scenario, n_apps=max(n_series // 3, 8), seed=seed)
    return sample_usage_series(build_trace(cfg), n_series, length, seed)


def rolling_errors(model, series: np.ndarray, window: int,
                   n_eval: int) -> tuple[np.ndarray, np.ndarray]:
    """Batched one-step-ahead rolling forecasts -> (rel_errors, zs)."""
    wins, tgts = [], []
    T = series.shape[1]
    starts = np.linspace(0, T - window - 1, n_eval).astype(int)
    for s in starts:
        wins.append(series[:, s:s + window])
        tgts.append(series[:, s + window])
    wins = np.concatenate(wins)           # (n_series*n_eval, window)
    tgts = np.concatenate(tgts)
    fc = jax.jit(lambda w: model.forecast_batch(w, 1))(jnp.asarray(wins))
    mean = np.asarray(fc.mean)[:, 0]
    sd = np.sqrt(np.maximum(np.asarray(fc.var)[:, 0], 1e-12))
    scale = np.maximum(np.abs(tgts), 1e-3)
    rel = (mean - tgts) / scale
    z = np.abs(mean - tgts) / sd
    return rel, z


def run(n_series: int = 60, length: int = 120, n_eval: int = 4,
        seed: int = 0, scenario: str = "google") -> list[dict]:
    series = utilization_series(n_series, length, seed, scenario)
    rows = []
    models = []
    for h in (10, 20, 40):
        models.append((f"GP-Exp(h={h})", GPForecaster(
            GPConfig(history=h, max_patterns=h, kernel="exp",
                     opt_steps=12))))
        models.append((f"GP-RBF(h={h})", GPForecaster(
            GPConfig(history=h, max_patterns=h, kernel="rbf",
                     opt_steps=12))))
    models.append(("ARIMA", ARIMAForecaster()))
    for name, model in models:
        window = max(getattr(getattr(model, "cfg", None), "history", 10)
                     + getattr(getattr(model, "cfg", None),
                               "max_patterns", 10), 20) + 2
        t0 = time.time()
        rel, z = rolling_errors(model, series, window, n_eval)
        q25, q50, q75 = np.percentile(np.abs(rel), [25, 50, 75])
        rows.append(dict(model=name, abs_rel_err_q25=float(q25),
                         median=float(q50), q75=float(q75),
                         mean=float(np.abs(rel).mean()),
                         mean_abs_z=float(np.median(z)),
                         wall_s=round(time.time() - t0, 1)))
    return rows


def main(quick: bool = True) -> None:
    rows = run() if quick else run(n_series=300, length=200, n_eval=8)
    print("model,err_q25,err_median,err_q75,err_mean,median_|z|,wall_s")
    for r in rows:
        print(f"{r['model']},{r['abs_rel_err_q25']:.4f},{r['median']:.4f},"
              f"{r['q75']:.4f},{r['mean']:.4f},{r['mean_abs_z']:.2f},"
              f"{r['wall_s']}")


if __name__ == "__main__":
    main()
