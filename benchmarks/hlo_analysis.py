"""Loop-aware cost analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each while-loop
BODY once, ignoring the trip count.  Every model here scans over layers
(and the recurrent archs scan over sequence), so raw numbers undercount
flops by ~L (and sequence-scans by ~S).  This module parses the
optimized HLO, builds the computation call graph, extracts loop trip
counts from the loop-condition constants, and accumulates:

  * flops           — dot instructions: 2 x |result| x K (contracting
                      dims from the operand symbol table);  convolutions
                      are approximated the same way via the kernel size;
  * bytes           — per call-site bytes accessed (operands + result),
                      an HBM-traffic proxy in the XLA convention —
                      weights re-streamed per loop iteration are counted
                      per iteration, as the hardware would;
  * collective bytes— all-gather / all-reduce / reduce-scatter /
                      all-to-all / collective-permute result bytes, by op;

each scaled by the product of enclosing loop trip counts.  All values
are PER DEVICE (the HLO is the single partitioned SPMD program).

Validated against hand-counted matmul/scan programs in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}
COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_WHILE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    return [(dt, tuple(int(d) for d in dims.split(",") if d))
            for dt, dims in _SHAPE_RE.findall(type_str)]


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str           # everything after the opening paren


_GLUE_OPS = frozenset((
    "convert", "copy", "bitcast", "reshape", "transpose", "broadcast",
    "parameter", "tuple", "get-tuple-element", "dynamic-update-slice",
    "dynamic-slice", "slice", "pad", "concatenate", "select", "compare",
    "iota", "constant",
))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    glue_bytes: float = 0.0   # XLA:CPU dtype/layout glue (absent on TPU)
    coll: dict | None = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVE_OPS}

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.glue_bytes += other.glue_bytes * mult
        for k in COLLECTIVE_OPS:
            self.coll[k] += other.coll[k] * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._cost_memo: dict[str, Cost] = {}
        self._trip_memo: dict[str, int] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: list[Instr] | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR.match(line)
            if hdr and line.lstrip().endswith("{"):
                name = hdr.group(1)
                cur = []
                self.comps[name] = cur
                if line.startswith("ENTRY"):
                    self.entry = name
                continue
            m = _INSTR.match(line)
            if m and cur is not None:
                cur.append(Instr(name=m.group(1), type_str=m.group(2),
                                 op=m.group(3), rest=m.group(4)))

    # ------------------------------------------------------------------
    def _symbols(self, comp: str) -> dict[str, str]:
        return {i.name: i.type_str for i in self.comps.get(comp, [])}

    def trip_count(self, cond_comp: str) -> int:
        """Max integer constant in the loop condition = trip count for
        counted loops (induction var starts at 0, compare direction LT)."""
        if cond_comp in self._trip_memo:
            return self._trip_memo[cond_comp]
        best = 1
        for i in self.comps.get(cond_comp, []):
            for m in _CONST.finditer(f"{i.type_str} {i.op}({i.rest}"):
                best = max(best, int(m.group(1)))
            # constants may live in fused compare computations
            c = _CALLS.search(i.rest)
            if c and c.group(1) in self.comps:
                for j in self.comps[c.group(1)]:
                    for m in _CONST.finditer(f"{j.type_str} {j.op}({j.rest}"):
                        best = max(best, int(m.group(1)))
        self._trip_memo[cond_comp] = best
        return best

    # ------------------------------------------------------------------
    def _dot_flops(self, instr: Instr, syms: dict[str, str]) -> float:
        out_elems = 0
        for dt, dims in _shapes(instr.type_str):
            n = 1
            for d in dims:
                n *= d
            out_elems += n
        ops = _OPERAND.findall(instr.rest.split("), ")[0])
        k = 1
        cd = _CDIMS.search(instr.rest)
        if cd and ops:
            lhs_type = syms.get(ops[0], "")
            shp = _shapes(lhs_type)
            if shp:
                dims = shp[0][1]
                for ax in cd.group(1).split(","):
                    if ax and int(ax) < len(dims):
                        k *= dims[int(ax)]
        return 2.0 * out_elems * k

    def _operand_names(self, instr: Instr) -> list[str]:
        return _OPERAND.findall(instr.rest.split("), ")[0])

    def _slice_read_bytes(self, comp: str) -> tuple[dict[int, float], float]:
        """For a called computation: effective traffic adjustments.

        * a parameter consumed ONLY by dynamic-slice reads just the
          slice, not the full operand;
        * a parameter consumed ONLY as the TARGET (operand 0) of
          dynamic-update-slice is updated IN PLACE (XLA aliases loop
          buffers): its read traffic is ~0 and the fusion's RESULT
          should be charged at the update size, not the buffer size.

        Returns ({param_index: effective_read_bytes}, result_override)
        where result_override < 0 means "no override"."""
        instrs = self.comps.get(comp, [])
        syms = {i.name: i.type_str for i in instrs}
        params: dict[str, int] = {}
        for i in instrs:
            if i.op == "parameter":
                m = re.search(r"^(\d+)", i.rest)
                if m:
                    params[i.name] = int(m.group(1))
        uses: dict[str, list[tuple[Instr, int]]] = {p: [] for p in params}
        for i in instrs:
            for pos, op_name in enumerate(self._operand_names(i)):
                if op_name in uses:
                    uses[op_name].append((i, pos))
        out: dict[int, float] = {}
        dus_update_bytes = 0.0
        has_dus_target = False
        for pname, idx in params.items():
            us = uses[pname]
            if not us:
                continue
            if all(u.op == "dynamic-slice" for u, _ in us):
                out[idx] = float(sum(_nbytes(u.type_str) for u, _ in us))
            elif all(u.op == "dynamic-update-slice" and pos == 0
                     for u, pos in us):
                out[idx] = 0.0            # aliased in-place target
                has_dus_target = True
                for u, _ in us:
                    ops = self._operand_names(u)
                    if len(ops) > 1:
                        dus_update_bytes += 2.0 * _nbytes(
                            syms.get(ops[1], ""))
        override = dus_update_bytes if has_dus_target else -1.0
        return out, override

    def _site_bytes(self, instr: Instr, syms: dict[str, str]) -> float:
        """Operands + result bytes at this call site (XLA bytes-accessed
        convention), with slicing awareness: dynamic-slice reads only the
        slice; dynamic-update-slice moves only the update; fusions whose
        parameter is consumed solely by an internal dynamic-slice read
        only the slice (the scan-over-layers weight indexing pattern)."""
        if instr.op == "dynamic-slice":
            return 2.0 * _nbytes(instr.type_str)
        if instr.op == "dynamic-update-slice":
            ops = self._operand_names(instr)
            upd = _nbytes(syms.get(ops[1], "")) if len(ops) > 1 else 0
            return 2.0 * upd
        ops = self._operand_names(instr)
        slice_reads: dict[int, float] = {}
        result_override = -1.0
        if instr.op in ("fusion", "call"):
            c = _CALLS.search(instr.rest)
            if c and c.group(1) in self.comps:
                slice_reads, result_override = self._slice_read_bytes(
                    c.group(1))
        total = (result_override if result_override >= 0
                 else float(_nbytes(instr.type_str)))
        for k, op_name in enumerate(ops):
            if k in slice_reads:
                total += slice_reads[k]
            elif op_name in syms:
                total += _nbytes(syms[op_name])
        return total

    def _is_glue(self, instr: Instr) -> bool:
        """A fusion is glue iff its computation only moves/retypes data."""
        c = _CALLS.search(instr.rest)
        if not c or c.group(1) not in self.comps:
            return False
        return all(i.op in _GLUE_OPS for i in self.comps[c.group(1)])

    def _glue_real_bytes(self, instr: Instr, syms: dict[str, str]) -> float:
        """Traffic a TPU would still pay for a glue fusion: the in-place
        update slices (2x each DUS update operand); a pure convert/copy
        fusion costs nothing extra (it folds into its consumer)."""
        c = _CALLS.search(instr.rest)
        if not c or c.group(1) not in self.comps:
            return 0.0
        inner = self.comps[c.group(1)]
        isyms = {i.name: i.type_str for i in inner}
        total = 0.0
        for i in inner:
            if i.op == "dynamic-update-slice":
                ops = self._operand_names(i)
                if len(ops) > 1:
                    total += 2.0 * _nbytes(isyms.get(ops[1], ""))
        return total

    def cost_of(self, comp: str) -> Cost:
        if comp in self._cost_memo:
            return self._cost_memo[comp]
        self._cost_memo[comp] = Cost()       # cycle guard
        total = Cost()
        syms = self._symbols(comp)
        for instr in self.comps.get(comp, []):
            if instr.op == "while":
                wm = _WHILE.search(instr.rest)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = self.trip_count(cond)
                    total.add(self.cost_of(body), trips)
                    total.add(self.cost_of(cond), trips + 1)
                continue
            if instr.op in ("parameter", "constant", "tuple",
                            "get-tuple-element", "bitcast", "copy"):
                continue
            if instr.op in COLLECTIVE_OPS:
                total.coll[instr.op] += _nbytes(instr.type_str)
                total.bytes += self._site_bytes(instr, syms)
                continue
            if instr.op == "dot":
                total.flops += self._dot_flops(instr, syms)
                total.bytes += self._site_bytes(instr, syms)
                continue
            if instr.op in ("fusion", "call", "conditional",
                            "custom-call", "map", "reduce", "sort",
                            "reduce-window", "scatter", "select-and-scatter"):
                site = self._site_bytes(instr, syms)
                if instr.op == "fusion" and self._is_glue(instr):
                    # dtype/layout glue XLA:CPU wraps around loop
                    # carries (e.g. converting a bf16 KV cache to f32
                    # for the dot every iteration).  XLA:TPU consumes
                    # bf16 natively and aliases the carry: count the
                    # in-place update traffic, book the rest as glue.
                    real = self._glue_real_bytes(instr, syms)
                    total.bytes += real
                    total.glue_bytes += max(site - real, 0.0)
                else:
                    total.bytes += site
                for cname in _CALLS.findall(instr.rest):
                    if cname in self.comps:
                        inner = self.cost_of(cname)
                        # only flops/collectives propagate from inside a
                        # fusion — its intermediates never touch HBM
                        total.flops += inner.flops
                        for k in COLLECTIVE_OPS:
                            total.coll[k] += inner.coll[k]
                continue
            total.bytes += self._site_bytes(instr, syms)
        self._cost_memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> dict:
    c = HloAnalysis(hlo_text).entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "glue_bytes": c.glue_bytes,
        "collective_bytes": c.collective_bytes,
        "collectives": dict(c.coll),
    }


def breakdown(hlo_text: str, top: int = 25) -> list[tuple[str, float]]:
    """Top traffic contributors: (instr-name@computation x mult, bytes)."""
    h = HloAnalysis(hlo_text)
    rows: list[tuple[str, float]] = []

    def walk(comp: str, mult: float, seen: tuple):
        if comp in seen:
            return
        syms = h._symbols(comp)
        for instr in h.comps.get(comp, []):
            if instr.op == "while":
                wm = _WHILE.search(instr.rest)
                if wm:
                    walk(wm.group(2), mult * h.trip_count(wm.group(1)),
                         seen + (comp,))
                continue
            if instr.op in ("parameter", "constant", "tuple",
                            "get-tuple-element", "bitcast", "copy"):
                continue
            b = h._site_bytes(instr, syms) * mult
            if b > 0:
                rows.append((f"{instr.op}:{instr.name}@{comp}x{mult:.0f}",
                             b))

    assert h.entry
    walk(h.entry, 1.0, ())
    rows.sort(key=lambda r: -r[1])
    return rows[:top]
