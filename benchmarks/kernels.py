"""Pallas kernel microbenchmarks (CPU: correctness-scale timings of the
interpret path + XLA reference; the BlockSpec/VMEM reasoning for the TPU
target is in EXPERIMENTS.md SS-Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
# the shared warmup + avg-of-N kernel timer (repro.obs.timing)
from repro.obs.timing import time_us as _time


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []

    # gram: fleet-scale batch of pattern sets (B series x N patterns)
    for B, N, D in ((64, 10, 11), (256, 20, 21), (64, 40, 41)):
        xa = jax.random.normal(key, (B, N, D), jnp.float32)
        jit_ref = jax.jit(jax.vmap(
            lambda x: ref.gram(x, x, 1.0, 1.0, kind="exp")))
        us = _time(jit_ref, xa)
        gf = 2 * B * N * N * D / (us * 1e-6) / 1e9
        rows.append(dict(name=f"gram_ref_B{B}_N{N}", us_per_call=us,
                         derived=f"{gf:.2f}GFLOP/s"))

    # attention: XLA ref at serving-ish sizes
    for B, H, S, Dh in ((1, 8, 512, 64), (2, 16, 1024, 64)):
        q = jax.random.normal(key, (B, H, S, Dh), jnp.float32)
        jit_attn = jax.jit(lambda q: ref.attention(q, q, q, causal=True))
        us = _time(jit_attn, q, iters=3)
        fl = 4 * B * H * S * S * Dh
        rows.append(dict(name=f"attn_ref_B{B}H{H}S{S}", us_per_call=us,
                         derived=f"{fl / (us * 1e-6) / 1e9:.1f}GFLOP/s"))

    # pallas interpret path (correctness-scale; Python interpreter speed,
    # NOT representative of TPU throughput)
    xa = jax.random.normal(key, (40, 41), jnp.float32)
    us = _time(lambda x: ops.gram(x, x, 1.0, 1.0, kind="exp",
                                  impl="pallas"), xa, iters=2)
    rows.append(dict(name="gram_pallas_interp_N40", us_per_call=us,
                     derived="interpret-mode"))
    return rows


def main(quick: bool = True) -> None:
    print("name,us_per_call,derived")
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")


if __name__ == "__main__":
    main()
