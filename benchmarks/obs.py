"""Observability-plane benchmark (BENCH_obs.json, schema 2).

Five cells guard the obs plane's contract (docs/OBSERVABILITY.md):

  * **overhead** — cohort ticks/sec with telemetry rings ON vs the
    BENCH_engine.json reference (same quick cell: 8-seed vmapped
    cohort, chunk=32).  Rings ride inside the fused tick, so their cost
    must stay under 5% (``OVERHEAD_RATIO``); measured with the shared
    best-of timer and tenancy-style escalating re-measurement so the
    gate trips on code, not on a noisy runner.
  * **disabled identity** — obs-off results are bit-identical with the
    rings compiled out entirely (``SimResults.obs is None``), and
    obs-ON summaries equal obs-off ones (telemetry never perturbs
    dynamics).
  * **ring chunk invariance** — drained histories for chunk=1 and
    chunk=32 are equal, field by field.
  * **trace + manifest** — a tiny obs-enabled ``run_grid`` writes a
    Chrome trace-event JSON that passes ``validate_trace`` and a run
    manifest whose config hashes round-trip (``load_manifest``
    re-derives and checks them); a smoke alert rule fires on every
    cell and must round-trip through the verified manifest AND show up
    in the rendered dashboard HTML together with all 13 ring channels.
    The files are CI artifacts.
  * **watchdog** — the default alert rules fire nothing on the quiet
    google/conformal baseline cell, while an injected flashcrowd OOM
    burst and a forced coverage drift are each detected within their
    rule windows (known onset tick -> bounded detection latency).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.obs.timing import best_of as _best_of

OVERHEAD_RATIO = 0.95     # acceptance: obs-on >= 95% of reference tps
COHORT_SEEDS = 8          # matches benchmarks.engine's quick cohort


def _quick_cfg():
    """The engine benchmark's quick cell — BENCH_engine.json's
    ``cohort_ticks_per_s`` is this exact configuration."""
    from repro.sim.sweep import quick_base_config
    cfg = quick_base_config(n_apps=32, n_hosts=2, max_components=6)
    return dataclasses.replace(
        cfg,
        cluster=dataclasses.replace(cfg.cluster, max_running_apps=16),
        policy="pessimistic", forecaster="persist")


def _overhead_cell(reps: int, engine_json: str) -> dict:
    from repro.obs import ObsConfig
    from repro.sim import generate
    from repro.sim.step import run_cohort_scan

    cfg = _quick_cfg()
    chunk = 32
    seeds = list(range(COHORT_SEEDS))
    wls = [generate(dataclasses.replace(cfg.workload, seed=s))
           for s in seeds]
    on = dataclasses.replace(cfg, obs=ObsConfig(enabled=True))

    # warm-up (compile both programs) + the identity criteria
    res_off = run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls)
    res_on = run_cohort_scan(on, seeds, chunk=chunk, wls=wls)
    assert all(r.obs is None for r in res_off), \
        "obs-off results must not carry rings"
    identity = all(a.summary() == b.summary()
                   for a, b in zip(res_off, res_on))
    n_ticks = sum(len(r.util_cpu) for r in res_off)

    off_s = _best_of(
        lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls), reps)
    on_s = _best_of(
        lambda: run_cohort_scan(on, seeds, chunk=chunk, wls=wls), reps)
    off_tps, on_tps = n_ticks / off_s, n_ticks / on_s

    ref_tps = None
    if os.path.exists(engine_json):
        with open(engine_json) as f:
            ref_tps = json.load(f).get("cohort_ticks_per_s")
    denom = ref_tps or off_tps
    ratio = on_tps / denom
    # noisy shared runners: escalate re-measurement (the best-of floor
    # only improves) before declaring a miss — same policy as the
    # tenancy bench's perf gate
    extra = reps
    while ratio < OVERHEAD_RATIO and extra <= 8 * reps:
        on_s = min(on_s, _best_of(
            lambda: run_cohort_scan(on, seeds, chunk=chunk, wls=wls),
            extra))
        on_tps = n_ticks / on_s
        ratio = on_tps / denom
        extra *= 2
    return {
        "config": {"n_apps": 32, "cohort_seeds": COHORT_SEEDS,
                   "chunk": chunk, "reps": reps},
        "n_ticks": n_ticks,
        "off_ticks_per_s": round(off_tps, 1),
        "on_ticks_per_s": round(on_tps, 1),
        "on_overhead": round(off_s / on_s, 3),
        "engine_ref_ticks_per_s": ref_tps,
        "on_vs_ref_ratio": round(ratio, 3),
        "disabled_identity": identity,
    }


def _ring_invariance_cell() -> dict:
    from repro.obs import ObsConfig
    from repro.sim import generate
    from repro.sim.step import run_sim_scan

    cfg = dataclasses.replace(_quick_cfg(), max_ticks=2000,
                              obs=ObsConfig(enabled=True))
    wl = generate(cfg.workload)
    h32 = run_sim_scan(cfg, wl, chunk=32).obs
    h1 = run_sim_scan(cfg, wl, chunk=1).obs
    mismatch = [k for k in h32 if not np.array_equal(h32[k], h1[k])]
    return {
        "ticks": int(h32["queue"].shape[0]),
        "fields": len(h32),
        "mismatched_fields": mismatch,
        "chunk_invariant": not mismatch,
    }


def _trace_manifest_cell(out_prefix: str) -> dict:
    from repro.obs import AlertRule, load_manifest, validate_trace
    from repro.obs.rings import RING_FIELDS
    from repro.sim.sweep import quick_base_config, run_grid

    sweep_json = f"{out_prefix}.sweep.json"
    trace_json = f"{out_prefix}.trace.json"
    manifest_json = f"{out_prefix}.manifest.json"
    report_html = f"{out_prefix}.report.html"
    base = quick_base_config(n_apps=24, n_hosts=2, max_components=4)
    # a trivially-firing smoke rule (every run admits apps) so the
    # alert -> manifest -> dashboard round trip always has a record
    smoke_rule = AlertRule("smoke-admitted", "admitted", "burst",
                           threshold=1.0, severity="info", window=8)
    res = run_grid(base, {"policy": ["baseline", "pessimistic"],
                          "forecaster": ["persist"]},
                   seeds=range(2), engine="scan", obs=True,
                   out_path=sweep_json, trace_path=trace_json,
                   manifest_path=manifest_json, forecast_diag=False,
                   alert_rules=(smoke_rule,),
                   dashboard_path=report_html)
    with open(trace_json) as f:
        problems = validate_trace(json.load(f))
    try:
        man = load_manifest(manifest_json, verify=True)
        roundtrip, man_err = True, None
    except (ValueError, KeyError) as e:
        man, roundtrip, man_err = None, False, str(e)
    obs_cells = sum(1 for c in res.cells if "obs" in c)
    man_alerts = (man or {}).get("alerts", [])
    alerts_roundtrip = (roundtrip and len(man_alerts) == len(res.cells)
                        and all(a["rule"] == "smoke-admitted"
                                for a in man_alerts))
    with open(report_html) as f:
        html = f.read()
    channels = [f[0] if isinstance(f, tuple) else f for f in RING_FIELDS]
    alerts_in_dashboard = ("smoke-admitted" in html
                           and "fired alerts" in html
                           and all(f">{c}<" in html for c in channels))
    return {
        "cells": len(res.cells),
        "cells_with_obs": obs_cells,
        "trace_problems": problems,
        "trace_valid": not problems,
        "manifest_roundtrip": roundtrip,
        "manifest_error": man_err,
        "manifest_cells": len(man["cells"]) if man else 0,
        "manifest_alerts": len(man_alerts),
        "alerts_roundtrip": alerts_roundtrip,
        "dashboard_channels": len(channels),
        "alerts_in_dashboard": alerts_in_dashboard,
        "artifacts": {"sweep": sweep_json, "trace": trace_json,
                      "manifest": manifest_json, "report": report_html},
    }


def _watchdog_cell() -> dict:
    """Alert-watchdog validation on real scan-engine histories.

    The baseline google/conformal cell must fire ZERO default rules; a
    deterministic OOM burst injected into the flashcrowd history must
    trip ``oom-burst`` within its 16-tick window; forcing half the
    resolved forecasts in the google tail to miscover must trip
    ``coverage-drift`` within its (run-clamped) window.  Injection is
    post-drain — real dynamics, synthetic anomaly — so detection
    latency is measured against a known ground-truth onset tick.
    """
    from repro.obs import ObsConfig, evaluate_rules
    from repro.sim.step import run_sim_scan
    from repro.sim.sweep import _apply_overrides, quick_base_config

    def cell(overrides):
        cfg = _apply_overrides(quick_base_config(), overrides)
        cfg = dataclasses.replace(cfg, obs=ObsConfig(enabled=True))
        return run_sim_scan(cfg)

    base = cell({"scenario": "google", "policy": "pessimistic",
                 "calibration": "conformal"})
    quiet = evaluate_rules(base.obs, nominal_q=0.9, tenancy=base.tenancy,
                           registry=None)

    flash = cell({"scenario": "flashcrowd", "policy": "optimistic"})
    h = dict(flash.obs)
    t0, burst_win = 150, 16
    oom = h["oom"].astype(np.float64).copy()
    oom[t0:t0 + 20] += np.tile([2.0, 3.0], 10)
    h["oom"] = oom
    fired = evaluate_rules(h, registry=None)
    oom_hits = [a for a in fired if a["rule"] == "oom-burst"]
    oom_first = oom_hits[0]["first_tick"] if oom_hits else None
    oom_ok = bool(oom_hits) and t0 <= oom_first <= t0 + burst_win

    h = dict(base.obs)
    t = int(h["cov_resolved"].shape[0])
    onset, cov_win = t // 2, 128
    err = h["cov_errors"].astype(np.float64).copy()
    err[onset:] = np.maximum(err[onset:],
                             0.5 * h["cov_resolved"][onset:])
    h["cov_errors"] = err
    fired = evaluate_rules(h, nominal_q=0.9, registry=None)
    cov_hits = [a for a in fired if a["rule"] == "coverage-drift"]
    cov_first = cov_hits[0]["first_tick"] if cov_hits else None
    cov_ok = bool(cov_hits) and onset <= cov_first <= onset + cov_win

    return {
        "baseline_ticks": int(base.obs["queue"].shape[0]),
        "baseline_fired": [a["rule"] for a in quiet],
        "baseline_quiet": not quiet,
        "oom_burst": {"onset": t0, "window": burst_win,
                      "first_tick": oom_first, "detected": oom_ok},
        "coverage_drift": {"onset": onset, "window": cov_win,
                           "first_tick": cov_first, "detected": cov_ok},
    }


def run(out: str = "BENCH_obs.json", reps: int = 20,
        engine_json: str = "BENCH_engine.json") -> dict:
    # perf first (same reasoning as the tenancy bench: the timed
    # programs are small, keep them ahead of the big grid compilations)
    overhead = _overhead_cell(reps, engine_json)
    invariance = _ring_invariance_cell()
    prefix = out[:-5] if out.endswith(".json") else out
    tm = _trace_manifest_cell(prefix)
    wd = _watchdog_cell()
    result = {
        "schema": 2,
        "overhead": overhead,
        "ring_invariance": invariance,
        "trace_manifest": tm,
        "watchdog": wd,
        "criteria": {
            "disabled_identity": overhead["disabled_identity"],
            "ring_chunk_invariant": invariance["chunk_invariant"],
            "enabled_overhead_lt_5pct":
                overhead["on_vs_ref_ratio"] >= OVERHEAD_RATIO,
            "trace_valid": tm["trace_valid"],
            "manifest_roundtrip": tm["manifest_roundtrip"],
            "watchdog_baseline_quiet": wd["baseline_quiet"],
            "watchdog_oom_burst_detected": wd["oom_burst"]["detected"],
            "watchdog_coverage_drift_detected":
                wd["coverage_drift"]["detected"],
            "alerts_manifest_roundtrip": tm["alerts_roundtrip"],
            "alerts_in_dashboard": tm["alerts_in_dashboard"],
        },
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"overhead: on {overhead['on_ticks_per_s']:.0f} ticks/s vs "
          f"ref {overhead['engine_ref_ticks_per_s'] or overhead['off_ticks_per_s']:.0f} "
          f"(x{overhead['on_vs_ref_ratio']}, overhead "
          f"{overhead['on_overhead']}x)")
    print(f"rings: {invariance['ticks']} ticks x "
          f"{invariance['fields']} fields, chunk-invariant="
          f"{invariance['chunk_invariant']}")
    print(f"trace/manifest: {tm['cells']} cells, trace_valid="
          f"{tm['trace_valid']}, roundtrip={tm['manifest_roundtrip']}, "
          f"alerts={tm['manifest_alerts']}, "
          f"dashboard={tm['alerts_in_dashboard']}")
    print(f"watchdog: baseline_fired={wd['baseline_fired']}, "
          f"oom first_tick={wd['oom_burst']['first_tick']} "
          f"(onset {wd['oom_burst']['onset']}), cov first_tick="
          f"{wd['coverage_drift']['first_tick']} "
          f"(onset {wd['coverage_drift']['onset']})")
    print(f"criteria: {result['criteria']}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.obs",
        description="Observability-plane benchmark: ring overhead + "
                    "identity, trace/manifest validity.")
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--engine-json", default="BENCH_engine.json",
                    help="engine benchmark artifact for the cohort "
                         "ticks/sec reference (absent = fresh obs-off "
                         "baseline)")
    args = ap.parse_args(argv)
    return run(out=args.out, reps=args.reps, engine_json=args.engine_json)


if __name__ == "__main__":
    main()
