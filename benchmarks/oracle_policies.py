"""Paper Fig. 3: baseline vs optimistic vs pessimistic with an ORACLE
predictor — slack, turnaround and failure distributions.

Scaled-down default (the paper: 150k apps x 250 hosts x 10 runs x ~3
simulated months); same generator family, saturated regime.  --full
raises the scale.
"""
from __future__ import annotations

import time

import numpy as np

from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, run_sim


def make_configs(scale: str = "quick"):
    if scale == "quick":
        wl = WorkloadConfig(n_apps=250, max_components=10,
                            max_runtime=5400.0, mean_burst_gap=1.0,
                            mean_long_gap=40.0)
        cl = ClusterConfig(n_hosts=8, max_running_apps=128)
        runs = 2
    else:
        wl = WorkloadConfig(n_apps=1500, max_components=16,
                            max_runtime=6 * 3600.0, mean_burst_gap=0.5,
                            mean_long_gap=30.0)
        cl = ClusterConfig(n_hosts=25, max_running_apps=512)
        runs = 3
    return wl, cl, runs


def run(scale: str = "quick") -> list[dict]:
    wl, cl, runs = make_configs(scale)
    rows = []
    for policy, fc in (("baseline", "persist"), ("optimistic", "oracle"),
                       ("pessimistic", "oracle")):
        tas, slacks, fails = [], [], []
        t0 = time.time()
        for seed in range(runs):
            import dataclasses
            wls = dataclasses.replace(wl, seed=seed + 1)
            s = run_sim(SimConfig(cluster=cl, workload=wls, policy=policy,
                                  forecaster=fc, max_ticks=30_000)).summary()
            assert s["completed"] == wls.n_apps
            tas.append(s["turnaround_mean"])
            slacks.append(s["slack_mem_mean"])
            fails.append(s["failed_frac"])
        rows.append(dict(policy=policy, forecaster=fc,
                         turnaround_mean=float(np.mean(tas)),
                         slack_mem=float(np.mean(slacks)),
                         failed_frac=float(np.mean(fails)),
                         wall_s=round(time.time() - t0, 1)))
    base = rows[0]["turnaround_mean"]
    for r in rows:
        r["turnaround_ratio"] = base / r["turnaround_mean"]
    return rows


def main(quick: bool = True) -> None:
    rows = run("quick" if quick else "full")
    print("policy,turnaround_mean_s,ratio_vs_baseline,slack_mem,"
          "failed_frac,wall_s")
    for r in rows:
        print(f"{r['policy']},{r['turnaround_mean']:.0f},"
              f"{r['turnaround_ratio']:.2f},{r['slack_mem']:.3f},"
              f"{r['failed_frac']:.3f},{r['wall_s']}")


if __name__ == "__main__":
    main()
