"""Paper Fig. 3: baseline vs optimistic vs pessimistic with an ORACLE
predictor — slack, turnaround and failure distributions.

Scaled-down default (the paper: 150k apps x 250 hosts x 10 runs x ~3
simulated months); same generator family, saturated regime.  --full
raises the scale.

A thin call into ``repro.sim.sweep``: the (policy, forecaster) pairs are
one zipped sweep axis, seeds another, and the grid runs thread-pooled
through the shared jitted forecast cache.  Writes the per-cell metrics to
``BENCH_fig3.json`` (one ``BENCH_<name>.json`` per benchmark section —
all gitignored, uploaded from CI).
"""
from __future__ import annotations

from repro.sim import ClusterConfig, SimConfig, WorkloadConfig
from repro.sim.sweep import run_grid

ARTIFACT = "BENCH_fig3.json"


def make_configs(scale: str = "quick"):
    if scale == "quick":
        wl = WorkloadConfig(n_apps=250, max_components=10,
                            max_runtime=5400.0, mean_burst_gap=1.0,
                            mean_long_gap=40.0)
        cl = ClusterConfig(n_hosts=8, max_running_apps=128)
        runs = 2
    else:
        wl = WorkloadConfig(n_apps=1500, max_components=16,
                            max_runtime=6 * 3600.0, mean_burst_gap=0.5,
                            mean_long_gap=30.0)
        cl = ClusterConfig(n_hosts=25, max_running_apps=512)
        runs = 3
    return wl, cl, runs


def run(scale: str = "quick", out_path: str | None = ARTIFACT) -> list[dict]:
    wl, cl, runs = make_configs(scale)
    base = SimConfig(cluster=cl, workload=wl, max_ticks=30_000)
    res = run_grid(
        base,
        axes={("policy", "forecaster"): [("baseline", "persist"),
                                         ("optimistic", "oracle"),
                                         ("pessimistic", "oracle")]},
        seeds=range(1, runs + 1),
        expect_completed=True,
        out_path=out_path)
    rows = []
    for a in res.aggregates:
        rows.append(dict(policy=a["overrides"]["policy"],
                         forecaster=a["overrides"]["forecaster"],
                         turnaround_mean=a["turnaround_mean"],
                         slack_mem=a["slack_mem_mean"],
                         failed_frac=a["failed_frac"],
                         turnaround_ratio=a["turnaround_speedup"],
                         wall_s=a["wall_s"]))
    return rows


def main(quick: bool = True) -> None:
    rows = run("quick" if quick else "full")
    print("policy,turnaround_mean_s,ratio_vs_baseline,slack_mem,"
          "failed_frac,wall_s")
    for r in rows:
        print(f"{r['policy']},{r['turnaround_mean']:.0f},"
              f"{r['turnaround_ratio']:.2f},{r['slack_mem']:.3f},"
              f"{r['failed_frac']:.3f},{r['wall_s']}")
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
