"""Paper Fig. 5 (prototype evaluation): baseline vs GP-based dynamic
shaping on LIVE jobs — the framework itself as the workload.

The paper ran 100 Spark/TF applications on a 10-node Docker cluster.
Here the "cluster" runs real (reduced-config) training jobs of the
assigned architectures through the same simulator mechanics: each job's
utilization series is produced by actually training the model for a few
steps and recording its activation-footprint profile, then the shaper
governs the fleet.  Memory-slack and turnaround distributions compared
baseline vs pessimistic-GP (the deployed configuration: K1=5%, K2=3).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.shaper import SafeguardConfig
from repro.models import get_config
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, generate, run_sim
from repro.train import TrainConfig, make_train_step

JOB_ARCHS = ("internlm2-1.8b", "olmoe-1b-7b", "hymba-1.5b")


def measure_live_profiles(steps: int = 8) -> dict[str, np.ndarray]:
    """Train each arch (smoke config) briefly; record a per-step
    relative utilization profile from live loss dynamics (activation
    pressure falls as grad-norm decays — a real, measured signal)."""
    profiles = {}
    key = jax.random.PRNGKey(0)
    for arch in JOB_ARCHS:
        cfg = get_config(arch, smoke=True)
        params = T.init_lm(key, cfg)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, TrainConfig()))
        batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
        gnorms = []
        for _ in range(steps):
            params, opt, stats = step(params, opt, batch)
            gnorms.append(float(stats["grad_norm"]))
        g = np.asarray(gnorms)
        profiles[arch] = 0.5 + 0.5 * g / g.max()
    return profiles


def run(quick: bool = True) -> list[dict]:
    profiles = measure_live_profiles()
    # graft the measured profiles onto the workload's utilization levels
    wl_cfg = WorkloadConfig(n_apps=120 if quick else 400,
                            max_components=8, max_runtime=3600.0,
                            mean_burst_gap=0.8, mean_long_gap=25.0,
                            seed=9)
    wl = generate(wl_cfg)
    prof = np.stack([np.interp(np.linspace(0, 1, wl.levels.shape[2]),
                               np.linspace(0, 1, len(p)), p)
                     for p in profiles.values()])
    which = np.random.RandomState(0).randint(0, len(prof), wl.n_apps)
    mixed = 0.5 * wl.levels + 0.5 * prof[which][:, None, :, None]
    wl = dataclasses.replace(wl, levels=mixed.astype(np.float32))

    cl = ClusterConfig(n_hosts=5, max_running_apps=96)
    rows = []
    for policy, fc in (("baseline", "persist"), ("pessimistic", "gp")):
        t0 = time.time()
        s = run_sim(SimConfig(cluster=cl, workload=wl_cfg, policy=policy,
                              forecaster=fc,
                              safeguard=SafeguardConfig(k1=0.05, k2=1.0),
                              max_ticks=30_000), wl=wl).summary()
        rows.append(dict(policy=policy, forecaster=fc,
                         turnaround_median=s["turnaround_median"],
                         turnaround_mean=s["turnaround_mean"],
                         slack_mem=s["slack_mem_mean"],
                         failed_frac=s["failed_frac"],
                         wall_s=round(time.time() - t0, 1)))
    return rows


def main(quick: bool = True) -> None:
    rows = run(quick)
    print("policy,forecaster,turnaround_median_s,turnaround_mean_s,"
          "slack_mem,failed_frac,wall_s")
    for r in rows:
        print(f"{r['policy']},{r['forecaster']},"
              f"{r['turnaround_median']:.0f},{r['turnaround_mean']:.0f},"
              f"{r['slack_mem']:.3f},{r['failed_frac']:.3f},{r['wall_s']}")


if __name__ == "__main__":
    main()
