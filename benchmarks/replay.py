"""Replay-at-scale benchmark: streamed trace ingestion at ~10^5 tasks.

The streaming loader (``repro.sim.scenarios.stream``) feeds arrival
chunks into a bounded device window and re-keys completed slot rows at
chunk boundaries, so device residency scales with *concurrent* apps —
not trace length.  This benchmark drives it with a synthetic
Alibaba-shaped trace (rigid single-component containers, lognormal
sizes and lifetimes, ~55%-utilized CPU reservations — the shape the
``alibaba`` replay preset produces from real ``container_usage``
files) long enough that materializing the full slot table would be the
bottleneck: 100k tasks through a ~hundred-row window.

Writes ``BENCH_replay.json`` and asserts the acceptance criteria:

  * ``stream_identical`` — on an identity slice of the same trace
    shape, streamed ingestion is bit-identical to the materialized
    scan run, uniform AND leap;
  * ``window_bounded``   — peak loaded rows over the full run stay
    under :data:`WINDOW_BOUND` (a small multiple of the cluster's
    admission cap, orders of magnitude below the task count);
  * ``stream_floor``     — streamed trace-ticks/second stays above
    :data:`TICKS_PER_S_FLOOR` (best-of timing with the escalating
    re-measurement policy the other benches use).

Usage::

    python -m benchmarks.replay [--full] [--out BENCH_replay.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math

TICKS_PER_S_FLOOR = 300.0   # CI CPU floor, ~10x below a healthy run
WINDOW_BOUND = 256          # peak loaded rows, vs ~1e5 tasks
SLICE_APPS = 1_500          # identity-slice length (materialized anchor)

from repro.obs.timing import best_of as _best_of  # noqa: E402


def synthetic_alibaba(n_apps: int, seed: int = 0):
    """Alibaba-container-shaped :class:`FittedConfig`.

    Little's-law sizing: arrival rate x mean lifetime ~= 24 concurrent
    containers, inside the cluster's ``max_running_apps=32`` cap — so
    the streamed window stays bounded while the cluster runs saturated
    enough for the shaper to matter.
    """
    from repro.sim.scenarios import FittedConfig
    mean_life = 480.0 * math.exp(0.4 ** 2 / 2)     # lognormal mean, s
    return FittedConfig(
        n_apps=n_apps, max_components=1, seed=seed,
        rate=24.0 / mean_life,
        runtime_mu=math.log(480.0), runtime_sigma=0.4,
        cpu_mu=math.log(2.0), cpu_sigma=0.5,        # ~2-core requests
        mem_mu=math.log(4.0), mem_sigma=0.7,        # ~4 GB requests
        comp_weights=(1.0,),
        cpu_level_mu=0.55, cpu_level_sigma=0.22,
        mem_level_mu=0.60, mem_level_sigma=0.10)


def _sim_config(workload):
    from repro.sim import ClusterConfig, SimConfig
    return SimConfig(
        cluster=ClusterConfig(n_hosts=8, max_running_apps=32),
        workload=workload, policy="pessimistic", forecaster="persist",
        max_ticks=200_000)


def _identity_slice(chunk: int) -> dict:
    """Streamed == materialized, bit for bit, uniform and leap."""
    from repro.sim.scenarios import build_trace
    from repro.sim.scenarios.stream import run_sim_stream
    from repro.sim.step import run_sim_scan

    fit = synthetic_alibaba(SLICE_APPS)
    wl = build_trace(fit)
    cfg = _sim_config(fit)

    def same(a, b):
        return (a.summary() == b.summary() and a.turnaround == b.turnaround
                and a.util_cpu == b.util_cpu and a.n_running == b.n_running
                and a.failed_apps == b.failed_apps)

    mat = run_sim_scan(cfg, wl, chunk=chunk)
    uni_ok = same(mat, run_sim_stream(cfg, wl, chunk=chunk, window=64))
    leap_cfg = dataclasses.replace(cfg, leap=True)
    leap_mat = run_sim_scan(leap_cfg, wl, chunk=chunk)
    leap_ok = (same(mat, leap_mat)
               and same(leap_mat, run_sim_stream(leap_cfg, wl, chunk=chunk,
                                                 window=64)))
    return {"n_apps": SLICE_APPS, "uniform_identical": uni_ok,
            "leap_identical": leap_ok,
            "identical": bool(uni_ok and leap_ok)}


def run(quick: bool = True, out: str = "BENCH_replay.json",
        reps: int = 3) -> dict:
    from repro.sim.scenarios import build_trace
    from repro.sim.scenarios.stream import run_sim_stream

    chunk = 32
    identity = _identity_slice(chunk)

    n_apps = 20_000 if quick else 100_000
    fit = synthetic_alibaba(n_apps)
    wl = build_trace(fit)
    cfg = _sim_config(fit)

    stats: dict = {}

    def streamed():
        stats.clear()
        return run_sim_stream(cfg, wl, chunk=chunk, window=64, stats=stats)

    res = streamed()                                 # warm-up + anchor
    n_ticks = len(res.util_cpu)
    completed = res.summary()["completed"]

    stream_s = _best_of(streamed, reps)
    if n_ticks / stream_s < TICKS_PER_S_FLOOR:
        # noisy-runner re-measurement, same policy as the other benches
        stream_s = min(stream_s, _best_of(streamed, 2 * reps))
    ticks_per_s = n_ticks / stream_s

    result = {
        "schema": 1,
        "quick": quick,
        "config": {
            "n_apps": n_apps, "chunk": chunk, "window": 64,
            "rate_per_s": round(fit.rate, 5),
            "max_running_apps": cfg.cluster.max_running_apps,
            "n_hosts": cfg.cluster.n_hosts,
        },
        "identity": identity,
        "stream": {
            "n_ticks": n_ticks,
            "completed": completed,
            "ticks_per_s": round(ticks_per_s, 1),
            "tasks_per_s": round(n_apps / stream_s, 1),
            "window_rows": stats["window_rows"],
            "peak_rows": stats["peak_rows"],
            "window_grows": stats["grows"],
            # residency ratio: device rows actually held vs trace length
            "residency": round(stats["peak_rows"] / n_apps, 6),
        },
        "criteria": {
            "stream_identical": identity["identical"],
            "window_bounded": stats["peak_rows"] <= WINDOW_BOUND,
            "stream_floor": ticks_per_s >= TICKS_PER_S_FLOOR,
        },
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    print(json.dumps(result["criteria"], indent=1, sort_keys=True))
    assert all(result["criteria"].values()), result["criteria"]
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.replay")
    ap.add_argument("--full", action="store_true",
                    help="100k-task trace (default: 20k quick run)")
    ap.add_argument("--out", default="BENCH_replay.json")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)
    run(quick=not args.full, out=args.out, reps=args.reps)


if __name__ == "__main__":
    main()
