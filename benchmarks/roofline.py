"""Roofline analysis from the dry-run artifacts (TPU v5e targets).

Per (arch x shape x mesh) cell, from the loop-corrected per-device HLO
costs (benchmarks/hlo_analysis.py via dryrun_results.json):

    compute_s    = HLO_flops   / PEAK_FLOPS          (197 TF/s bf16)
    memory_s     = HLO_bytes   / HBM_BW              (819 GB/s)
    collective_s = coll_bytes  / LINK_BW             (~50 GB/s/link ICI)

plus MODEL_FLOPS (6*N*D train / 2*N*D forward; N_active for MoE), the
useful-compute ratio MODEL_FLOPS/HLO_flops, the dominant term, and the
ROOFLINE FRACTION = useful_compute_time / max(term) — the fraction of
the best-achievable step time spent doing model math.  This is the
number §Perf hillclimbs.

Usage: python -m benchmarks.roofline [--json dryrun_results.json]
       [--mesh single] [--markdown]
"""
from __future__ import annotations

import argparse
import json

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

CHIPS = {"single": 256, "multi": 512}


def model_flops(rec: dict) -> float:
    """Useful model flops for the whole step, GLOBAL (all chips)."""
    n_active = rec.get("n_active") or rec["n_params"]
    seq, batch = rec["seq"], rec["batch"]
    kind = rec["kind"]
    if kind == "train":
        return 6.0 * n_active * seq * batch
    if kind == "prefill":
        return 2.0 * n_active * seq * batch
    # decode: one token per sequence + attention over the cache
    return 2.0 * n_active * batch


def analyze_cell(rec: dict) -> dict | None:
    if not rec.get("ok") or "hlo" not in rec or "flops" not in rec.get(
            "hlo", {}):
        return None
    chips = CHIPS[rec["mesh"]]
    h = rec["hlo"]
    compute_s = h["flops"] / PEAK_FLOPS
    memory_s = h["bytes"] / HBM_BW
    coll_s = h["collective_bytes"] / LINK_BW
    bound = max((compute_s, "compute"), (memory_s, "memory"),
                (coll_s, "collective"))[1]
    mf = model_flops(rec) / chips          # per device
    # the IDEAL step time is the larger of the useful-compute roofline
    # and the useful-traffic roofline.  Useful traffic = the program's
    # live inputs per device (params [+ opt state, + KV cache]) read
    # once — taken from the dry-run's own memory analysis, so decode
    # (inherently memory-bound) is scored against the memory roof, not
    # an unreachable flops-only ideal.
    arg_bytes = rec.get("memory", {}).get("argument_size_in_bytes", 0)
    ideal_s = max(mf / PEAK_FLOPS, arg_bytes / HBM_BW)
    step_s = max(compute_s, memory_s, coll_s)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "bound": bound,
        "model_flops_dev": mf, "hlo_flops_dev": h["flops"],
        "useful_ratio": mf / h["flops"] if h["flops"] else 0.0,
        "ideal_s": ideal_s,
        "roofline_frac": ideal_s / step_s if step_s else 0.0,
        "step_s": step_s,
        "arg_bytes_dev": arg_bytes,
        "temp_bytes_dev": rec.get("memory", {}).get("temp_size_in_bytes"),
    }


def load(path: str, mesh: str = "single",
         variant: str = "default") -> list[dict]:
    with open(path) as f:
        results = json.load(f)
    rows = []
    for rec in results.values():
        if rec.get("mesh") != mesh:
            continue
        if rec.get("variant", "default") != variant:
            continue
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "skipped": True,
                         "reason": rec["reason"]})
            continue
        row = analyze_cell(rec)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def print_table(rows: list[dict], markdown: bool = False) -> None:
    hdr = ("arch", "shape", "compute", "memory", "collective", "bound",
           "useful", "roofline")
    if markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for r in rows:
        if r.get("skipped"):
            cells = (r["arch"], r["shape"], "SKIP", "-", "-", "-", "-", "-")
        else:
            cells = (r["arch"], r["shape"], fmt_s(r["compute_s"]),
                     fmt_s(r["memory_s"]), fmt_s(r["collective_s"]),
                     r["bound"], f"{r['useful_ratio']:.2f}",
                     f"{r['roofline_frac']:.3f}")
        if markdown:
            print("| " + " | ".join(str(c) for c in cells) + " |")
        else:
            print(",".join(str(c) for c in cells))


def pick_hillclimb(rows: list[dict]) -> dict:
    live = [r for r in rows if not r.get("skipped")]
    picks: dict[str, dict] = {}

    def taken(r):
        return any(p["arch"] == r["arch"] and p["shape"] == r["shape"]
                   for p in picks.values())

    picks["worst_roofline"] = min(live, key=lambda r: r["roofline_frac"])
    picks["most_collective_bound"] = max(
        (r for r in live if not taken(r)),
        key=lambda r: r["collective_s"] / max(r["step_s"], 1e-30))
    # most representative of the paper: the serving cell whose elastic
    # resource (the KV cache in HBM) the shaper governs — the biggest
    # decode cell not already picked
    decodes = [r for r in live if r["kind"] == "decode" and not taken(r)]
    picks["paper_representative"] = (
        max(decodes, key=lambda r: r["step_s"]) if decodes
        else picks["worst_roofline"])
    return picks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    ap.add_argument("--variant", default="default",
                    choices=["default", "opt"])
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = load(args.json, args.mesh, args.variant)
    print_table(rows, markdown=args.markdown)
    picks = pick_hillclimb(rows)
    print()
    for why, r in picks.items():
        print(f"# hillclimb[{why}]: {r['arch']} x {r['shape']} "
              f"(bound={r['bound']}, roofline={r['roofline_frac']:.3f})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "picks": {
                k: {kk: v[kk] for kk in ("arch", "shape", "bound",
                                         "roofline_frac")}
                for k, v in picks.items()}}, f, indent=1)


if __name__ == "__main__":
    main()
