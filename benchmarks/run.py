"""Benchmark harness entry point — one section per paper table/figure.

  fig2   forecast-error distributions (ARIMA vs GP-Exp vs GP-RBF)
  fig3   oracle-based policy comparison (baseline/optimistic/pessimistic)
         — a thin repro.sim.sweep grid; writes BENCH_fig3.json
  fig4   K1 x K2 safeguard heat maps (ARIMA + GP)
         — a thin repro.sim.sweep grid; writes BENCH_fig4.json
  fig5   prototype: baseline vs dynamic on live training jobs
  scenarios  cross-scenario robustness grid (every workload family x
         policy); writes BENCH_scenarios.json
  calibration  Gaussian-vs-conformal safeguard study (coverage /
         turnaround / failure trade-offs); writes BENCH_calibration.json
  engine  host-loop vs device-resident scan engine vs vmapped seed
         cohort throughput (+ GP forecast-row overhead); writes
         BENCH_engine.json
  shard  scan cohort vs shard_map device-mesh fleets; writes
         BENCH_shard.json (run it standalone or first: forced host
         devices must be configured before jax initializes)
  kernels  Pallas kernel microbenches
  obs    observability plane: telemetry-ring overhead + identity, trace
         and manifest validity; writes BENCH_obs.json (+ .trace.json /
         .manifest.json artifacts)
  roofline dry-run-derived roofline table (if dryrun_results.json exists)

``python -m benchmarks.run [--only SECTION] [--full] [--compare DIR]``

Every section writes at most one ``BENCH_<name>.json`` artifact (all
gitignored; CI uploads them).  Arbitrary ad-hoc grids — any policy x
forecaster x safeguard x scenario x seed cross product — run through
``python -m repro.sim.sweep`` directly.

``--compare DIR`` diffs the artifacts in the cwd against the committed
baselines in DIR (``benchmarks/baselines`` in CI) and exits nonzero on
regression — see ``benchmarks.compare`` for the tolerance policy.
Without ``--only``, ``--compare`` runs the diff alone (compare-only
mode: CI produces artifacts via the per-section smokes first).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

SECTIONS = ("fig2", "fig3", "fig4", "fig5", "scenarios", "calibration",
            "engine", "shard", "replay", "kernels", "obs", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=SECTIONS)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (hours); default is CI scale")
    ap.add_argument("--compare", default=None, metavar="DIR",
                    help="diff cwd BENCH_*.json against the baselines "
                         "in DIR; nonzero exit on regression.  Without "
                         "--only, runs the diff alone")
    args = ap.parse_args()
    if args.compare is not None and args.only is None:
        from benchmarks import compare
        sys.exit(compare.main([args.compare]))
    quick = not args.full
    sections = [args.only] if args.only else list(SECTIONS)
    failures = 0

    for sec in sections:
        print(f"\n===== {sec} " + "=" * (60 - len(sec)), flush=True)
        t0 = time.time()
        try:
            if sec == "fig2":
                from benchmarks import forecast_error
                forecast_error.main(quick)
            elif sec == "fig3":
                from benchmarks import oracle_policies
                oracle_policies.main(quick)
            elif sec == "fig4":
                from benchmarks import beta_heatmap
                beta_heatmap.main(quick)
            elif sec == "fig5":
                from benchmarks import prototype
                prototype.main(quick)
            elif sec == "scenarios":
                from benchmarks import scenario_sweep
                scenario_sweep.main(quick)
            elif sec == "calibration":
                from benchmarks import calibration
                calibration.main(quick)
            elif sec == "engine":
                from benchmarks import engine
                engine.run(quick)
            elif sec == "shard":
                # importing benchmarks.shard forces host devices; if jax
                # is already initialized (an earlier section ran) the
                # bench still runs but may see a single device and then
                # skips the throughput criterion
                from benchmarks import shard
                shard.run()
            elif sec == "replay":
                from benchmarks import replay
                replay.run(quick)
            elif sec == "kernels":
                from benchmarks import kernels
                kernels.main(quick)
            elif sec == "obs":
                from benchmarks import obs
                obs.run()
            elif sec == "roofline":
                if os.path.exists("dryrun_results.json"):
                    from benchmarks import roofline
                    rows = roofline.load("dryrun_results.json", "single")
                    roofline.print_table(rows)
                    for why, r in roofline.pick_hillclimb(rows).items():
                        print(f"# hillclimb[{why}]: {r['arch']} x "
                              f"{r['shape']} bound={r['bound']}")
                else:
                    print("dryrun_results.json not found — run "
                          "`python -m repro.launch.dryrun` first")
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"----- {sec} done in {time.time() - t0:.0f}s", flush=True)

    if args.compare is not None:
        from benchmarks import compare
        failures += compare.main([args.compare])
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
