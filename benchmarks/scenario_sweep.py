"""Cross-scenario robustness grid: every workload family x policy.

The paper's Figs. 3-4 hold one workload fixed; this section asks the
question its related work (Flex, ADARES) treats as table stakes — does
uncertainty-modulated shaping keep its turnaround/failure/utilization
profile across workload regimes?  One ``repro.sim.sweep`` grid:

    scenario in {google, diurnal, flashcrowd, heavytail, colocated}
    x policy in {baseline, pessimistic}  ( + optimistic with --full)
    x seed

Per-scenario speedups use each scenario's own baseline as denominator;
the artifact (``BENCH_scenarios.json``) also carries per-scenario trace
statistics and rolling forecast-error diagnostics, so a regression in
any regime is attributable from the JSON alone.
"""
from __future__ import annotations

from repro.sim import ClusterConfig, SimConfig, WorkloadConfig
from repro.sim.sweep import run_grid

SCENARIOS = ("google", "diurnal", "flashcrowd", "heavytail", "colocated")
ARTIFACT = "BENCH_scenarios.json"


def run(scale: str = "quick", out_path: str | None = ARTIFACT):
    if scale == "quick":
        wl = WorkloadConfig(n_apps=48, max_components=8,
                            max_runtime=2700.0, mean_burst_gap=2.0,
                            mean_long_gap=40.0)
        cl = ClusterConfig(n_hosts=4, max_running_apps=48)
        policies = ["baseline", "pessimistic"]
        forecaster, seeds = "persist", [0]
    else:
        wl = WorkloadConfig(n_apps=400, max_components=12)
        cl = ClusterConfig(n_hosts=16, max_running_apps=256)
        policies = ["baseline", "optimistic", "pessimistic"]
        forecaster, seeds = "gp", [0, 1, 2]
    base = SimConfig(cluster=cl, workload=wl, forecaster=forecaster,
                     max_ticks=60_000)
    return run_grid(base,
                    axes={"scenario": list(SCENARIOS),
                          "policy": policies},
                    seeds=seeds, out_path=out_path)


def main(quick: bool = True) -> None:
    res = run("quick" if quick else "full")
    print("scenario,policy,speedup,failed_frac,util_mem,slack_mem")
    for a in res.aggregates:
        print(f"{a['scenario']},{a['overrides']['policy']},"
              f"{a.get('turnaround_speedup', float('nan')):.2f},"
              f"{a['failed_frac']:.3f},{a['util_mem_mean']:.3f},"
              f"{a['slack_mem_mean']:.3f}")
    for d in res.forecast_error:
        print(f"# forecast_error {d['scenario']}/{d['forecaster']}: "
              f"median_abs_rel={d['abs_rel_err_median']:.3f} "
              f"median_|z|={d['median_abs_z']:.2f}")
    print(f"# wrote {ARTIFACT}")


if __name__ == "__main__":
    main()
