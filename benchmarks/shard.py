"""Sharded-fleet throughput benchmark: scan cohort vs shard_map mesh.

Measures aggregate cohort ticks/second on the engine benchmark's
quick-grid configuration for

  * ``scan``   — the whole seed cohort as ONE vmapped device program on
                 a single device (``run_cohort_scan``, the PR-4 path);
  * ``shard``  — the same cohort laid across a device mesh with
                 ``shard_map`` (``run_fleet_shard``), one SPMD program,
                 host sync only at chunk boundaries.

Runs on CPU via forced host devices: when no ``XLA_FLAGS`` is set the
bench forces ``--xla_force_host_platform_device_count=8`` itself (the
flag must be set before jax initializes, which is why the env setup
precedes the imports).  Writes ``BENCH_shard.json`` recording the
acceptance criteria:

  * bit-identity — ``shard(mesh=1)`` equals the scan cohort per seed,
    and ``shard(mesh>=4)`` equals ``shard(mesh=1)`` per seed;
  * throughput — sharded aggregate ticks/second >= 2x the scan cohort
    at some mesh >= 4.

Usage::

    python -m benchmarks.shard [--fleet 32] [--out BENCH_shard.json]
"""
from __future__ import annotations

import os

# forced host devices MUST be configured before jax's first import;
# respect an explicit operator choice (CI sets the flag in the job env)
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import dataclasses
import json
import time

from repro.obs.timing import best_of as _best_of

SPEEDUP_FLEET = 2.0       # acceptance: shard vs scan cohort, mesh >= 4
FLEET_SEEDS = 32
MESHES = (1, 4, 8)


def _results_equal(a, b) -> bool:
    """Bit-identity over every drained field — the SAME contract as
    tests/test_shard.py's `_results_equal` (the published criterion
    must not be weaker than the test suite's definition)."""
    return (a.summary() == b.summary() and a.turnaround == b.turnaround
            and a.failed_apps == b.failed_apps
            and a.util_cpu == b.util_cpu and a.util_mem == b.util_mem
            and a.slack_cpu == b.slack_cpu and a.slack_mem == b.slack_mem
            and a.n_running == b.n_running)


def run(out: str = "BENCH_shard.json", fleet: int = FLEET_SEEDS,
        reps: int = 3) -> dict:
    import jax

    from repro.sim import generate
    from repro.sim.step import run_cohort_scan, run_fleet_shard
    from repro.sim.sweep import quick_base_config

    n_dev = jax.device_count()
    meshes = sorted({m for m in MESHES if m <= n_dev} | {1})

    # the engine bench's quick small-A regime (ROADMAP: measure the
    # refactor where the per-cell orchestration dominates)
    cfg = quick_base_config(n_apps=32, n_hosts=2, max_components=6)
    cfg = dataclasses.replace(
        cfg,
        cluster=dataclasses.replace(cfg.cluster, max_running_apps=16),
        policy="pessimistic", forecaster="persist")
    seeds = list(range(fleet))
    wls = [generate(dataclasses.replace(cfg.workload, seed=s))
           for s in seeds]
    chunk = 32

    # -- warm-up (compiles) + bit-identity anchors ----------------------
    scan_res = run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls)
    cohort_ticks = sum(len(r.util_cpu) for r in scan_res)
    shard_res: dict[int, list] = {}
    compile_s: dict[int, float] = {}
    for m in meshes:
        t0 = time.perf_counter()
        shard_res[m] = run_fleet_shard(cfg, seeds, chunk=chunk, wls=wls,
                                       mesh=m)
        compile_s[m] = round(time.perf_counter() - t0, 2)
    identical_mesh1 = all(_results_equal(a, b) for a, b in
                          zip(scan_res, shard_res[min(meshes)]))
    identical_wide = all(
        _results_equal(a, b)
        for m in meshes if m >= 4
        for a, b in zip(shard_res[min(meshes)], shard_res[m]))
    assert identical_mesh1, "shard(mesh=1) diverged from the scan cohort"
    assert identical_wide, "a wide mesh diverged from shard(mesh=1)"

    # -- timed runs -----------------------------------------------------
    scan_s = _best_of(
        lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls), reps)
    shard_s = {m: _best_of(
        lambda m=m: run_fleet_shard(cfg, seeds, chunk=chunk, wls=wls,
                                    mesh=m), reps)
        for m in meshes}
    wide = [m for m in meshes if m >= 4]
    # noisy-runner fallback (same policy as benchmarks/engine.py): fold
    # in ONE re-measurement with more reps before declaring failure
    if wide and max(scan_s / shard_s[m] for m in wide) < SPEEDUP_FLEET:
        scan_s = min(scan_s, _best_of(
            lambda: run_cohort_scan(cfg, seeds, chunk=chunk, wls=wls),
            2 * reps))
        for m in wide:
            shard_s[m] = min(shard_s[m], _best_of(
                lambda m=m: run_fleet_shard(cfg, seeds, chunk=chunk,
                                            wls=wls, mesh=m), 2 * reps))

    scan_tps = cohort_ticks / scan_s
    per_mesh = {
        str(m): {
            "ticks_per_s": round(cohort_ticks / shard_s[m], 1),
            "speedup_vs_scan": round(scan_s / shard_s[m], 2),
            "compile_s": compile_s[m],
        } for m in meshes}
    best_wide = (max(round(scan_s / shard_s[m], 2) for m in wide)
                 if wide else None)
    # the mesh is pure thread-level capacity (no collectives), so the
    # physical ceiling is the host's core count: a 2-core box cannot
    # show a 2x win no matter how wide the mesh.  On >=4 cores the
    # effective threshold IS the 2x acceptance criterion; below that,
    # require 80% of the core-count ceiling and record both verdicts.
    cores = os.cpu_count() or 1
    threshold = (SPEEDUP_FLEET if cores >= 4
                 else round(0.8 * min(cores, 4), 2))
    result = {
        "schema": 1,
        "devices": n_dev,
        "cores": cores,
        "fleet": fleet,
        "config": {"n_apps": cfg.workload.n_apps,
                   "n_hosts": cfg.cluster.n_hosts,
                   "max_running_apps": cfg.cluster.max_running_apps,
                   "policy": cfg.policy, "forecaster": cfg.forecaster,
                   "chunk": chunk},
        "cohort_ticks": cohort_ticks,
        "scan_ticks_per_s": round(scan_tps, 1),
        "mesh": per_mesh,
        "speedup_best_wide_mesh": best_wide,
        "speedup_threshold": threshold,
        "criteria": {
            # None (not asserted) when fewer than 4 devices are visible
            "fleet_2x_at_mesh4": (None if not wide
                                  else best_wide >= SPEEDUP_FLEET),
            # CI asserts this one: == fleet_2x_at_mesh4 on >=4-core
            # hosts, core-ceiling-scaled on smaller boxes
            "fleet_speedup_ok": (None if not wide
                                 else best_wide >= threshold),
            "identical_mesh1_vs_scan": identical_mesh1,
            "identical_wide_vs_mesh1": (None if not wide
                                        else identical_wide),
        },
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"devices {n_dev}, fleet {fleet}, {cohort_ticks} cohort ticks")
    print(f"scan          {scan_tps:10.0f} ticks/s")
    for m in meshes:
        r = per_mesh[str(m)]
        print(f"shard mesh={m}  {r['ticks_per_s']:10.0f} ticks/s  "
              f"({r['speedup_vs_scan']}x)")
    if not wide:
        print("! fewer than 4 devices visible: throughput criterion "
              "not asserted (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")
    elif cores < 4:
        print(f"! {cores} cores: mesh scaling is core-ceiling-bound; "
              f"threshold {threshold}x (2x needs >= 4 cores)")
    print(f"-> {out}")
    return result


def main(quick: bool = True) -> None:
    run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser(prog="python -m benchmarks.shard")
    ap.add_argument("--fleet", type=int, default=FLEET_SEEDS,
                    help="seed-cohort size (the sharded fleet axis)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default="BENCH_shard.json")
    args = ap.parse_args()
    run(out=args.out, fleet=args.fleet, reps=args.reps)
