"""Multi-tenant control-plane benchmark: fairness, per-tenant coverage,
and the zero-cost-when-off contract.

Three cells, each asserting one acceptance criterion of the control
plane PR:

  * ``fairness``  — a Zipf-skewed 4-tenant ``colocated`` population on a
                    saturated cluster, ``ungated`` (accounting only) vs
                    ``wdrf`` (admission gate).  Criterion: the gate
                    lifts the Jain index of mean dominant shares to
                    >= 0.9 from an ungated < 0.8;
  * ``coverage``  — a 2-tenant ``heavytail`` cell under the adaptive
                    conformal safeguard with per-tenant score pools.
                    Criterion: every tenant's online conformal coverage
                    lands within +-3 points of the nominal target
                    (1 - budget);
  * ``perf``      — the engine benchmark's quick cell with tenancy
                    DISABLED: the control plane is structurally absent
                    from the traced program (``SimState.tenancy is
                    None``), so scan throughput must stay within 10% of
                    ``BENCH_engine.json``'s (when that artifact exists;
                    else the fresh measurement is recorded as the new
                    reference).  The tenancy-ON overhead is measured and
                    reported alongside.  Bit-identity of the tenancy-off
                    path against the host engine is asserted in-process.

Writes ``BENCH_tenancy.json``.  Usage::

    python -m benchmarks.tenancy [--out BENCH_tenancy.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

JAIN_WDRF = 0.9           # acceptance: gated fairness floor
JAIN_UNGATED = 0.8        # acceptance: ungated stays visibly unfair
COVERAGE_TOL = 0.03       # acceptance: per-tenant coverage band
PERF_RATIO = 0.9          # acceptance: tenancy-off tps vs BENCH_engine


# the shared best-of-N timer (repro.obs.timing)
from repro.obs.timing import best_of as _best_of  # noqa: E402


def _fairness_cell(chunk: int = 64) -> dict:
    """Ungated vs wDRF-gated Jain index on the skewed colocated cell."""
    from repro.control import TenancyConfig
    from repro.sim import ClusterConfig, SimConfig
    from repro.sim.scenarios import build_trace, make_config
    from repro.sim.step import run_sim_scan

    wl_cfg = make_config("colocated", n_apps=128, max_components=4,
                         n_tenants=4, tenant_skew=1.0, seed=1,
                         mean_gap=5.0,
                         svc_min_runtime=1800.0, svc_max_runtime=7200.0,
                         batch_min_runtime=900.0, batch_max_runtime=3600.0)
    wl = build_trace(wl_cfg)
    base = SimConfig(cluster=ClusterConfig(n_hosts=3, max_running_apps=24),
                     workload=wl_cfg, policy="baseline", max_ticks=20000)
    modes = {
        "ungated": TenancyConfig(enabled=True, gate=False, credit=False),
        "wdrf": TenancyConfig(enabled=True, gate=True, credit=False,
                              slack=0.02),
        "credit": TenancyConfig(enabled=True, gate=True, credit=True,
                                slack=0.02),
    }
    out: dict = {"config": {"scenario": "colocated", "n_apps": 128,
                            "n_tenants": 4, "tenant_skew": 1.0,
                            "slack": 0.02}}
    for name, ctl in modes.items():
        res = run_sim_scan(dataclasses.replace(base, control=ctl), wl,
                           chunk=chunk)
        ten = res.tenancy
        out[name] = {
            "jain_mean_share": ten["jain_mean_share"],
            "mean_share": ten["mean_share"],
            "throttled": ten["throttled"],
            "completed": sum(ten["completed"]),
            "turnaround_mean": ten["turnaround_mean"],
        }
        assert sum(ten["completed"]) == wl.n_apps, \
            f"{name}: the gate must defer work, not lose it"
    return out


def _coverage_cell(chunk: int = 64) -> dict:
    """Per-tenant online conformal coverage on a 2-tenant heavytail."""
    from repro.control import TenancyConfig
    from repro.core.uncertainty import CalibrationConfig
    from repro.sim import ClusterConfig, SimConfig
    from repro.sim.scenarios import build_trace, make_config
    from repro.sim.step import run_sim_scan

    cal = CalibrationConfig(enabled=True, adaptive=True)
    wl_cfg = make_config("heavytail", n_apps=128, max_components=6,
                         n_tenants=2, tenant_skew=0.0, seed=0,
                         mean_gap=20.0, max_runtime=14400.0)
    cfg = SimConfig(cluster=ClusterConfig(n_hosts=4, max_running_apps=32),
                    workload=wl_cfg, policy="pessimistic",
                    forecaster="persist", max_ticks=40000,
                    calibration=cal,
                    control=TenancyConfig(enabled=True))
    res = run_sim_scan(cfg, build_trace(wl_cfg), chunk=chunk)
    groups = res.calibration["groups"]
    nominal = 1.0 - cal.budget
    covs = [c for c in groups["coverage"] if c is not None]
    return {
        "config": {"scenario": "heavytail", "n_apps": 128,
                   "n_tenants": 2, "nominal": nominal},
        "q_target": res.calibration["q_target"],
        "resolved": groups["resolved"][:2],
        "coverage": covs,
        "max_abs_dev": round(max(abs(c - nominal) for c in covs), 4),
    }


def _perf_cell(reps: int, engine_json: str, chunk: int = 32) -> dict:
    """Tenancy-off scan throughput vs the engine benchmark's reference,
    tenancy-on overhead, and the off-path bit-identity assert."""
    from repro.control import TenancyConfig
    from repro.sim import generate, run_sim
    from repro.sim.step import run_sim_scan
    from repro.sim.sweep import quick_base_config

    cfg = quick_base_config(n_apps=32, n_hosts=2, max_components=6)
    cfg = dataclasses.replace(
        cfg, cluster=dataclasses.replace(cfg.cluster, max_running_apps=16),
        policy="pessimistic", forecaster="persist")
    wl = generate(cfg.workload)

    # tenancy-off bit-identity: the host loop and the fused scan agree
    # exactly, as they did before the control plane existed
    host_res = run_sim(cfg, wl)
    scan_res = run_sim_scan(cfg, wl, chunk=chunk)
    assert scan_res.turnaround == host_res.turnaround, \
        "tenancy-off scan diverged from the host engine"
    assert "tenancy" not in scan_res.summary()
    n_ticks = len(host_res.util_cpu)

    on = dataclasses.replace(
        cfg, workload=dataclasses.replace(cfg.workload, n_tenants=4),
        control=TenancyConfig(enabled=True))
    wl_on = generate(on.workload)
    run_sim_scan(on, wl_on, chunk=chunk)        # warm-up (compile)

    off_s = _best_of(lambda: run_sim_scan(cfg, wl, chunk=chunk), reps)
    on_s = _best_of(lambda: run_sim_scan(on, wl_on, chunk=chunk), reps)
    off_tps = n_ticks / off_s

    ref_tps = None
    if os.path.exists(engine_json):
        with open(engine_json) as f:
            ref_tps = json.load(f).get("scan_ticks_per_s")
    if ref_tps:
        ratio = off_tps / ref_tps
        # noisy shared runners: the timed program is ~10 ms, so a few
        # seconds of background load can sink a whole best-of window.
        # Escalate re-measurement (the best-of floor only improves)
        # before declaring a miss — the ratio gates code, not noise.
        extra = reps
        while ratio < PERF_RATIO and extra <= 8 * reps:
            off_s = min(off_s, _best_of(
                lambda: run_sim_scan(cfg, wl, chunk=chunk), extra))
            off_tps = n_ticks / off_s
            ratio = off_tps / ref_tps
            extra *= 2
    else:
        ratio = 1.0        # no reference artifact: nothing to gate on
    return {
        "config": {"n_apps": 32, "chunk": chunk, "reps": reps},
        "n_ticks": n_ticks,
        "off_ticks_per_s": round(off_tps, 1),
        "on_ticks_per_s": round(n_ticks / on_s, 1),
        "on_overhead": round(off_s / on_s, 3),
        "engine_ref_ticks_per_s": ref_tps,
        "off_vs_engine_ratio": round(ratio, 3),
    }


def run(out: str = "BENCH_tenancy.json", reps: int = 20,
        engine_json: str = "BENCH_engine.json") -> dict:
    # perf first: the timed runs are ~10 ms each (the engine bench's
    # quick cell is 51 ticks), so they go before the big fairness /
    # coverage compilations can perturb the process
    perf = _perf_cell(reps, engine_json)
    fairness = _fairness_cell()
    coverage = _coverage_cell()
    result = {
        "schema": 1,
        "fairness": fairness,
        "coverage": coverage,
        "perf": perf,
        "criteria": {
            "jain_wdrf_ge_0p9":
                fairness["wdrf"]["jain_mean_share"] >= JAIN_WDRF,
            "jain_ungated_lt_0p8":
                fairness["ungated"]["jain_mean_share"] < JAIN_UNGATED,
            "coverage_within_3pts":
                coverage["max_abs_dev"] <= COVERAGE_TOL,
            "perf_off_within_10pct":
                perf["off_vs_engine_ratio"] >= PERF_RATIO,
            "off_path_bit_identical": True,     # asserted in _perf_cell
        },
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    print(f"fairness: ungated jain="
          f"{fairness['ungated']['jain_mean_share']:.3f} -> wdrf "
          f"{fairness['wdrf']['jain_mean_share']:.3f} (credit "
          f"{fairness['credit']['jain_mean_share']:.3f})")
    print(f"coverage: per-tenant {coverage['coverage']} vs nominal "
          f"{coverage['config']['nominal']} "
          f"(max dev {coverage['max_abs_dev']})")
    print(f"perf: off {perf['off_ticks_per_s']:.0f} ticks/s "
          f"(x{perf['off_vs_engine_ratio']} of engine ref), on-overhead "
          f"{perf['on_overhead']}x")
    print(f"criteria: {result['criteria']}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.tenancy",
        description="Control-plane fairness / coverage / perf benchmark.")
    ap.add_argument("--out", default="BENCH_tenancy.json")
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--engine-json", default="BENCH_engine.json",
                    help="engine benchmark artifact for the perf "
                         "reference (absent = record fresh baseline)")
    args = ap.parse_args(argv)
    return run(out=args.out, reps=args.reps, engine_json=args.engine_json)


if __name__ == "__main__":
    main()
