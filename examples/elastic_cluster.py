"""Elastic cluster demo — the paper's mechanism end to end.

Simulates a saturated cluster three ways (reservation baseline,
optimistic reclamation, pessimistic Algorithm 1 with a GP forecaster)
and prints the turnaround / slack / failure comparison — the Fig. 3/5
story in one command.

    PYTHONPATH=src python examples/elastic_cluster.py
"""
from repro.core.shaper import SafeguardConfig
from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, run_sim

WL = WorkloadConfig(n_apps=150, max_components=10, max_runtime=3600.0,
                    mean_burst_gap=1.0, mean_long_gap=30.0, seed=1)
CL = ClusterConfig(n_hosts=6, max_running_apps=96)

if __name__ == "__main__":
    rows = []
    for policy, fc in (("baseline", "persist"), ("optimistic", "oracle"),
                       ("pessimistic", "gp")):
        s = run_sim(SimConfig(
            cluster=CL, workload=WL, policy=policy, forecaster=fc,
            safeguard=SafeguardConfig(k1=0.05, k2=1.0),
            max_ticks=20_000)).summary()
        rows.append((policy, fc, s))
        print(f"{policy:12s}/{fc:8s}: turnaround {s['turnaround_mean']:6.0f}s "
              f"(median {s['turnaround_median']:6.0f}s)  "
              f"mem slack {s['slack_mem_mean']:.2f}  "
              f"failures {s['failed_frac']:.1%}  "
              f"(partial preemptions: {s['partial_preemptions']})")
    base = rows[0][2]["turnaround_mean"]
    best = rows[2][2]["turnaround_mean"]
    print(f"\npessimistic shaping: {base / best:.2f}x faster turnaround "
          f"than the reservation baseline, zero uncontrolled failures")
