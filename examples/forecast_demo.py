"""Forecasting demo: GP-with-history-kernel vs ARIMA on a utilization
series, showing the uncertainty quantification the shaper consumes.

    PYTHONPATH=src python examples/forecast_demo.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.forecast import ARIMAForecaster, GPConfig, GPForecaster
from repro.core.shaper import SafeguardConfig, shaped_demand

if __name__ == "__main__":
    rng = np.random.RandomState(0)
    t = np.arange(96, dtype=np.float32)
    # a component ramping toward its 24 GB reservation with a spike
    usage = 6 + 8 * (1 - np.exp(-t / 40)) + 2 * np.sin(t / 5)
    usage += rng.normal(0, 0.4, t.shape)
    usage[70:74] += 6.0                      # transient peak
    usage = np.clip(usage, 0, 24).astype(np.float32)
    reservation = 24.0

    window = jnp.asarray(usage[:-3])
    truth = usage[-3:]

    for name, model in (("GP-Exp", GPForecaster(GPConfig(history=10,
                                                         max_patterns=20))),
                        ("ARIMA", ARIMAForecaster())):
        fc = model.forecast(window, 3)
        mean = np.asarray(fc.mean)
        sd = np.sqrt(np.asarray(fc.var))
        grant = shaped_demand(fc.mean.max(), reservation, fc.var.max(),
                              SafeguardConfig(k1=0.05, k2=3.0))
        print(f"{name:7s} forecast: " +
              " ".join(f"{m:5.1f}+/-{s:4.1f}" for m, s in zip(mean, sd)))
        print(f"        truth:    " +
              " ".join(f"{x:5.1f}" for x in truth))
        print(f"        shaper grant (K1=5%, K2=3): {float(grant):5.1f} GB "
              f"of {reservation:.0f} GB reserved "
              f"(slack redeemed: {reservation - float(grant):4.1f} GB)\n")
