"""Quickstart: train a ~100M-class LM end-to-end with the full driver.

Runs the real training loop — data pipeline, AdamW, checkpointing,
restart ledger, live utilization monitoring with the paper's GP
forecaster + safeguard buffer reporting grants every few steps.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    out = main(["--arch", "internlm2-1.8b", "--smoke",
                "--steps", "120", "--batch", "8", "--seq", "128",
                "--ckpt-every", "40", "--ckpt-dir", "/tmp/repro_quickstart"]
               + sys.argv[1:])
    assert out["final_loss"] < out["first_loss"], "loss must decrease"
    print("quickstart OK:", out)
