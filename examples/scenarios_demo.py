"""Scenario subsystem demo: registry, trace replay, and a scenario sweep.

1. build each registered generator family and print its shape statistics;
2. export one trace to CSV and replay it through the engine (identical
   results — the replayed file IS the workload);
3. run a scenario x policy sweep grid and print per-scenario speedups
   (each scenario's baseline is its own denominator).

    PYTHONPATH=src python examples/scenarios_demo.py
"""
from repro.sim import (ClusterConfig, SimConfig, WorkloadConfig, run_sim,
                       trace_stats)
from repro.sim.scenarios import (build_trace, make_config, save_trace,
                                 scenario_names)
from repro.sim.scenarios.replay import ReplayConfig
from repro.sim.sweep import run_grid

FAMILIES = ("google", "diurnal", "flashcrowd", "heavytail", "colocated")


def main() -> None:
    # 1. the registry ----------------------------------------------------
    print(f"registered scenarios: {', '.join(scenario_names())}\n")
    print(f"{'family':11s} {'elastic':>7s} {'comps':>6s} {'runtime_p95':>12s} "
          f"{'mem_p95':>8s}")
    for name in FAMILIES:
        st = trace_stats(build_trace(make_config(name, n_apps=120, seed=0)))
        print(f"{name:11s} {st['elastic_frac']:7.2f} "
              f"{st['mean_components']:6.1f} "
              f"{st['runtime_p95_s'] / 3600:10.1f} h "
              f"{st['mem_req_p95_gb']:6.1f}G")

    # 2. trace replay ----------------------------------------------------
    src = make_config("flashcrowd", n_apps=24, seed=1)
    tr = build_trace(src)
    save_trace(tr, "/tmp/flashcrowd.csv")
    cl = ClusterConfig(n_hosts=4, max_running_apps=32)
    a = run_sim(SimConfig(cluster=cl, workload=src, policy="baseline",
                          forecaster="persist", max_ticks=20_000)).summary()
    b = run_sim(SimConfig(
        cluster=cl,
        workload=ReplayConfig(path="/tmp/flashcrowd.csv",
                              max_components=tr.max_components),
        policy="baseline", forecaster="persist",
        max_ticks=20_000)).summary()
    assert a == b, "replayed trace must reproduce the source run"
    print(f"\nreplay: {a['completed']} apps, turnaround "
          f"{a['turnaround_mean']:.0f}s — generated == replayed ✓")

    # 3. scenario-axis sweep --------------------------------------------
    base = SimConfig(cluster=cl,
                     workload=WorkloadConfig(n_apps=32, max_components=8,
                                             max_runtime=1800.0,
                                             mean_burst_gap=2.0,
                                             mean_long_gap=40.0),
                     forecaster="persist", max_ticks=40_000)
    res = run_grid(base, axes={"scenario": ["google", "flashcrowd",
                                            "heavytail"],
                               "policy": ["baseline", "pessimistic"]},
                   seeds=[0])
    print(f"\n{len(res.cells)} cells in {res.wall_s:.1f}s")
    print(f"{'scenario':11s} {'policy':12s} {'speedup':>7s} {'failed':>7s} "
          f"{'util_mem':>8s}")
    for g in res.aggregates:
        print(f"{g['scenario']:11s} {g['overrides']['policy']:12s} "
              f"{g.get('turnaround_speedup', 1.0):7.2f} "
              f"{g['failed_frac']:7.3f} {g['util_mem_mean']:8.3f}")
    for d in res.forecast_error:
        print(f"forecast_error[{d['scenario']}]: "
              f"median_abs_rel={d['abs_rel_err_median']:.3f}")


if __name__ == "__main__":
    main()
