"""Serving demo: batched decode with a shaper-governed batch cap.

The KV cache is the finite resource; the forecaster + safeguard buffer
set how many request slots the scheduler may fill (see
repro/launch/serve.py for the full driver).

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    stats = main(["--arch", "internlm2-1.8b", "--smoke",
                  "--requests", "24", "--max-batch", "6",
                  "--prompt-len", "24", "--gen-len", "8"] + sys.argv[1:])
    print("serve_demo OK:", stats)
