"""Sweep demo: a policy x forecaster x safeguard grid in one process.

Runs a small saturated cluster through every combination of shaping
policy and forecaster (plus a safeguard sub-grid for the GP), with the
vectorized engine, one shared jitted forecast cache, and cross-sim
window batching — then prints the paper-style comparison and shows the
vectorized engine agreeing bit-for-bit with the seed loop engine (the
vectorized win grows with the slot-table size; at this demo scale the
two are close).

    PYTHONPATH=src python examples/sweep_demo.py
"""
import time

from repro.sim import run_sim, run_sim_reference
from repro.sim.sweep import expand_grid, quick_base_config, run_grid


def main() -> None:
    base = quick_base_config(n_apps=48, n_hosts=4)

    # 1. the grid: 3 policies x 2 forecasters x 2 seeds = 12 cells ------
    # no out_path: demos do not leave artifacts behind (BENCH_<name>.json
    # files are written by benchmarks/run.py sections / the sweep CLI)
    res = run_grid(base,
                   axes={"policy": ["baseline", "optimistic", "pessimistic"],
                         "forecaster": ["persist", "oracle"]},
                   seeds=[0, 1])
    print(f"{len(res.cells)} cells in {res.wall_s:.1f}s wall "
          f"({res.forecast_requests} forecasts in {res.forecast_batches} "
          f"stacked batches)\n")
    print(f"{'combo':44s} speedup failed util_mem")
    for a in res.aggregates:
        print(f"{a['name']:44s} {a.get('turnaround_speedup', 1.0):6.2f} "
              f"{a['failed_frac']:6.3f} {a['util_mem_mean']:8.3f}")

    # 2. a nested-field axis: GP safeguard K2 sub-grid ------------------
    res2 = run_grid(base,
                    axes={"safeguard.k2": [0.0, 1.0, 3.0]},
                    cells=[{"policy": "baseline", "forecaster": "persist"}],
                    seeds=[0])
    print("\nGP safeguard K2 sweep (pessimistic):")
    for a in res2.aggregates:
        print(f"  {a['name']:36s} speedup={a.get('turnaround_speedup', 1):.2f} "
              f"failed={a['failed_frac']:.3f}")

    # 3. vectorized engine == seed engine, bit for bit ------------------
    cell = expand_grid(base, {"policy": ["pessimistic"],
                              "forecaster": ["oracle"]}, seeds=[0])[0]
    run_sim(cell.cfg)                       # warm the jit caches
    t0 = time.time()
    vec = run_sim(cell.cfg)
    t1 = time.time()
    ref = run_sim_reference(cell.cfg)
    t2 = time.time()
    assert vec.summary() == ref.summary(), "engines must agree bit-for-bit"
    print(f"\nvectorized engine: {t1 - t0:.2f}s vs seed loop engine "
          f"{t2 - t1:.2f}s (identical results)")


if __name__ == "__main__":
    main()
