"""Checkpointing: atomic, async, elastic-reshard-on-load."""
from repro.checkpoint.manager import CheckpointManager, load_pytree, save_pytree

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]
