"""Step-atomic, async checkpointing with elastic re-shard on restore.

Layout:  <dir>/step_<n>/  arrays.npz + manifest.json ;  commit is a
rename of a ``.tmp`` directory, so a checkpoint either exists completely
or not at all (a killed writer can never leave a half checkpoint that a
restart would load).  ``save_async`` snapshots device arrays to host
(blocking only for the device->host copy) and writes in a background
thread — the training loop overlaps the serialization with subsequent
steps, which is the paper's preempt-to-checkpoint primitive made cheap.

Restore is mesh-agnostic: arrays land on host first, then ``device_put``
against the CURRENT mesh/sharding — the elastic re-mesh path (grow or
shrink DP width after the resource shaper resizes the job).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name.startswith(("bfloat", "float8", "float4")):
            # ml_dtypes (bfloat16, fp8) are not npz-serializable; store
            # as f32 (lossless upcast) — restore casts back to the
            # target leaf dtype
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_pytree(tree, directory: str) -> None:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"treedef": str(treedef),
                   "keys": sorted(flat.keys())}, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)      # atomic commit


def load_pytree(tree_like, directory: str, shardings=None):
    """Restore into the structure of ``tree_like``; optionally place
    each leaf with the given sharding (elastic re-mesh)."""
    with np.load(os.path.join(directory, "arrays.npz")) as z:
        flat = jax.tree_util.tree_flatten_with_path(tree_like)[0]
        leaves = []
        for path, leaf in flat:
            key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = z[key]
            leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                          else arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree) -> None:
        save_pytree(tree, self._step_dir(step))
        self._gc()

    def save_async(self, step: int, tree) -> None:
        """Device->host snapshot now; disk write in the background."""
        self.wait()
        host = jax.tree.map(np.asarray, tree)   # snapshot (blocks on d2h)

        def work():
            save_pytree(host, self._step_dir(step))
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, tree_like, step: int | None = None, shardings=None):
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return load_pytree(tree_like, self._step_dir(step), shardings), step

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
