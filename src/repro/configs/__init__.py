"""One config module per assigned architecture (+ the paper's own sim
config).  Exact dimensions from the assignment table; source tags in
each module docstring."""
