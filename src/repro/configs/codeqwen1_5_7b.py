"""codeqwen1.5-7b [dense] — qwen1.5 arch. [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_ff=13440,
    vocab=92416,
    rope_theta=1_000_000.0,
)
