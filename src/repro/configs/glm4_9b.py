"""glm4-9b [dense] — RoPE, aggressive GQA (kv=2). [hf:THUDM/glm-4-9b; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=2,
    d_ff=13696,
    vocab=151552,
    rope_theta=10_000.0,
)
