"""granite-3-8b [dense] — GQA kv=8. [hf:ibm-granite/granite-3.0; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=12800,
    vocab=49155,
    rope_theta=10_000.0,
)
