"""hymba-1.5b [hybrid] — parallel attention + mamba heads, sliding
windows with periodic global layers, SSM state 16. [arXiv:2411.13676; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    conv_kernel=4,
    window=1024,             # sliding-window attention
    global_every=16,         # layers 0 and 16 attend globally
    rope_theta=10_000.0,
)
