"""olmoe-1b-7b [moe] — 64 experts, top-8, no dense FFN.
[arXiv:2409.02060; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=0,                  # MoE replaces the dense FFN entirely
    vocab=50304,
    n_experts=64,
    top_k=8,
    expert_ff=1024,
    rope_theta=10_000.0,
)
