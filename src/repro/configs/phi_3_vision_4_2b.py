"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32064,
    n_img_tokens=256,        # stub CLIP frontend: precomputed patch embeds
    rope_theta=10_000.0,
)
