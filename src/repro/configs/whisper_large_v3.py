"""whisper-large-v3 [audio] — enc-dec, conv/mel frontend stubbed.
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,             # encoder layers
    dec_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv=20,
    d_ff=5120,
    vocab=51866,
    encdec=True,
    dec_len=448,
)
