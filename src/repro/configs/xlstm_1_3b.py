"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, recurrent O(1) decode state.
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,               # mLSTM heads
    n_kv=4,
    d_ff=0,                  # blocks carry their own up/down projections
    vocab=50304,
    slstm_every=8,           # 6 groups of 7 mLSTM + 1 sLSTM
)
