"""Multi-tenant control plane layered over the simulation engines.

The paper shapes resources per *application*; a production cluster
contends per *tenant* (ROADMAP open item 1).  This package adds the
control-plane shape that Flex (Le & Liu, 2020) and the two-stage Mesos
work (Rattihalli et al., 2019) put in front of a shaper:

  * :mod:`repro.control.config`   — ``TenancyConfig`` (the ``SimConfig.
    control`` field) + SLO-class constants;
  * :mod:`repro.control.fairness` — weighted dominant-resource shares,
    Jain's fairness index, the admission gate mask.  Every function
    works on NumPy *and* JAX arrays (the host engine and the fused
    tick share one implementation);
  * :mod:`repro.control.credit`   — the online tenant credit score
    (EMA of good vs bad outcomes) and the credit->quantile mapping
    that modulates the conformal safeguard per tenant;
  * :mod:`repro.control.device`   — ``TenantState``, the tenant-indexed
    accounting pytree carried through the fused tick (scan/shard);
  * :mod:`repro.control.host`     — ``HostControl``, the NumPy mirror
    the vectorized host engine drives tick by tick;
  * :mod:`repro.control.summary`  — the shared per-tenant results
    block (fairness / SLO / turnaround / credit) both engine families
    drain into ``SimResults.tenancy``.

See ``docs/CONTROL_PLANE.md`` for the subsystem reference.
"""
from repro.control.config import (SLO_CLASSES, SLO_STRETCH, TenancyConfig,
                                  resolve_weights)
from repro.control.credit import credit_quantile, credit_step
from repro.control.device import TenantState, control_init
from repro.control.fairness import dominant_shares, gate_mask, jain_index
from repro.control.host import HostControl
from repro.control.summary import tenancy_summary

__all__ = [
    "SLO_CLASSES", "SLO_STRETCH", "TenancyConfig", "resolve_weights",
    "credit_quantile", "credit_step", "TenantState", "control_init",
    "dominant_shares", "gate_mask", "jain_index", "HostControl",
    "tenancy_summary",
]
