"""Tenancy configuration + SLO-class constants.

This module is a leaf: it imports nothing from ``repro`` so both the
trace schema (``repro.sim.scenarios.schema``) and the engines can use
it without cycles.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: SLO classes a trace may tag apps with, ordered weakest-first.  The
#: integer code stored in ``Trace.slo`` indexes this tuple.
SLO_CLASSES = ("best-effort", "standard", "premium")

#: Turnaround stretch budget per SLO class: an app meets its SLO when
#: ``turnaround <= stretch * runtime`` (queue wait + shaping slowdown
#: bounded as a multiple of the ideal runtime).  Premium tenants buy a
#: tight stretch; best-effort tolerates a long queue.
SLO_STRETCH = (8.0, 4.0, 2.0)

#: Error budget per SLO class: the fraction of a tenant's apps allowed
#: to MISS their turnaround SLO before the class's budget is spent.
#: This is the denominator of the obs plane's SLO burn-rate alerts
#: (``repro.obs.alerts``): burn 1.0 = exactly on budget, burn >= the
#: rule threshold = paging.  Best-effort buys a wide budget, premium a
#: tight one — same ordering as ``SLO_STRETCH``.
SLO_BUDGET = (0.25, 0.10, 0.02)


@dataclasses.dataclass(frozen=True)
class TenancyConfig:
    """``SimConfig.control`` — the multi-tenant control plane.

    Disabled by default: ``enabled=False`` is bit-identical to the
    pre-control-plane engines (no tenant state is allocated, no gate
    runs — the equivalence anchors in ``tests/test_scan_engine.py`` /
    ``tests/test_shard.py`` hold unchanged).
    """

    enabled: bool = False
    #: static tenant-axis width for the device accounting arrays (the
    #: fused tick needs a fixed shape); traces must satisfy
    #: ``tenant < max_tenants``.
    max_tenants: int = 8
    #: per-tenant wDRF weights, padded with 1.0 up to ``max_tenants``
    #: (empty = unweighted DRF).  A tenant's accounted share is its
    #: dominant share divided by its weight.
    weights: tuple = ()
    #: admission/throttling gate at enqueue time: a tenant whose wDRF
    #: share exceeds the active-tenant mean by more than ``slack`` is
    #: held back this tick (its queued apps stay queued).
    gate: bool = True
    slack: float = 0.10
    #: online credit score: EMA of good (completions, covered conformal
    #: resolutions) vs bad (failures, conflicts, miscoverage) outcomes.
    #: Modulates BOTH the gate headroom (``slack * credit``) and the
    #: per-tenant conformal target quantile (see ``credit_quantile``).
    credit: bool = True
    credit_gamma: float = 0.10
    credit_floor: float = 0.05
    credit_init: float = 0.5
    #: half-width of the credit->quantile band: a zero-credit tenant
    #: targets ``q + q_spread`` (conservative band), a full-credit one
    #: ``q - q_spread`` (aggressive shaping).
    q_spread: float = 0.05


def resolve_weights(cfg: TenancyConfig) -> np.ndarray:
    """``(max_tenants,)`` float32 wDRF weights, 1.0-padded."""
    w = np.ones(cfg.max_tenants, np.float32)
    given = np.asarray(cfg.weights, np.float32)
    if given.size > cfg.max_tenants:
        raise ValueError(f"{given.size} weights for "
                         f"max_tenants={cfg.max_tenants}")
    if np.any(given <= 0):
        raise ValueError("tenant weights must be positive")
    w[:given.size] = given
    return w
