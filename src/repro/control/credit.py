"""Online tenant credit score.

An EMA of each tenant's good-vs-bad outcome ratio: completions and
covered conformal resolutions raise credit, failures (OOM kills,
optimistic conflicts) and conformal miscoverage lower it.  The score
feeds back into the control plane twice:

  * the admission gate's headroom shrinks for low-credit tenants
    (``slack * credit`` instead of ``slack``);
  * the conformal safeguard's target quantile widens for low-credit
    tenants (:func:`credit_quantile`) — risky tenants get conservative
    bands, reliable ones aggressive shaping.

Like :mod:`repro.control.fairness`, everything is NumPy/JAX agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _xp(*arrays):
    return jnp if any(isinstance(a, jax.Array) for a in arrays) else np


def credit_step(credit, good, bad, gamma, floor):
    """One EMA update: ``credit += gamma * (good_ratio - credit)``.

    ``good`` / ``bad`` are per-tenant event counts for the tick; a
    tenant with no events this tick keeps its credit.  The result is
    clipped to ``[floor, 1]`` — the floor keeps a misbehaving tenant's
    gate headroom and conformal band finite (it can always earn its
    way back).
    """
    xp = _xp(credit, good, bad)
    g = good.astype(xp.float32)
    b = bad.astype(xp.float32)
    tot = g + b
    ratio = g / xp.maximum(tot, 1.0)
    target = xp.where(tot > 0, ratio, credit)
    new = credit + xp.float32(gamma) * (target - credit)
    return xp.clip(new, xp.float32(floor), xp.float32(1.0)).astype(xp.float32)


def credit_quantile(credit, q, spread, q_min, q_max):
    """Per-tenant conformal target quantile from the credit score.

    Linear in credit around the configured target: a neutral tenant
    (credit 0.5) keeps ``q``, a zero-credit tenant targets
    ``q + spread`` and a full-credit one ``q - spread``; the result is
    clipped into the calibrator's admissible ``[q_min, q_max]`` band.
    """
    xp = _xp(credit, q)
    # q may be a traced device scalar (st.calib.q inside the fused
    # tick), so no xp.float32(q) cast — promotion keeps float32 anyway
    qs = q + xp.float32(spread) * (1.0 - 2.0 * credit)
    return xp.clip(qs, xp.float32(q_min), xp.float32(q_max)).astype(xp.float32)
