"""Tenant accounting state for the fused device tick (scan/shard).

``TenantState`` is the control plane's twin of
``repro.core.uncertainty.online.CalibState``: a frozen pytree of
device arrays carried through ``lax.scan`` tick chunks, vmapped seed
cohorts and ``shard_map`` fleets.  The host engine mirrors it with
:class:`repro.control.host.HostControl`; both drain into the same
:func:`repro.control.summary.tenancy_summary` block.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.control.config import TenancyConfig, resolve_weights

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TenantState:
    """Per-tenant accounting arrays, shape ``(T,)`` (``(B, T)`` under a
    cohort vmap).  ``T = TenancyConfig.max_tenants`` is static."""

    credit: Array         # f32 - online credit score in [floor, 1]
    admitted: Array       # i32 - apps admitted through the gate
    throttled: Array      # i32 - queued app-ticks held back by the gate
    completed: Array      # i32 - apps completed
    failed: Array         # i32 - failure events (conflicts + OOM kills)
    share_sum: Array      # f32 - sum of wDRF share over active ticks
    active_ticks: Array   # i32 - ticks the tenant was running or queued


def control_init(cfg: TenancyConfig, batch: int | None = None) -> TenantState:
    """Fresh tenant state (optionally with a leading cohort axis)."""
    B = () if batch is None else (batch,)
    T = cfg.max_tenants
    zi = lambda: jnp.zeros(B + (T,), jnp.int32)        # noqa: E731
    return TenantState(
        credit=jnp.full(B + (T,), cfg.credit_init, jnp.float32),
        admitted=zi(), throttled=zi(), completed=zi(), failed=zi(),
        share_sum=jnp.zeros(B + (T,), jnp.float32), active_ticks=zi())


def device_weights(cfg: TenancyConfig) -> Array:
    """The resolved wDRF weights as a device constant."""
    return jnp.asarray(resolve_weights(cfg))


def credit_mean(credit: Array, active: Array) -> Array:
    """Mean credit over ACTIVE tenants (the telemetry ring's ``credit``
    series — ``repro.obs.rings``): inactive tenants sit at the init
    value forever and would wash the signal out of a plain mean."""
    n = active.sum()
    return jnp.where(n > 0,
                     (credit * active).sum() / jnp.maximum(n, 1), 0.0)
