"""Weighted dominant-resource fairness (wDRF) accounting.

Every function here runs on NumPy *and* JAX arrays — the vectorized
host engine and the fused device tick share one implementation, so the
two paths cannot drift apart formula-wise (float accumulation order
may still differ by an ulp; the cross-engine tests compare counters
exactly and shares with tolerance).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _xp(*arrays):
    """numpy-or-jax dispatch on the argument types."""
    return jnp if any(isinstance(a, jax.Array) for a in arrays) else np


def dominant_shares(alloc, cap, weights):
    """Per-tenant weighted dominant share.

    ``alloc`` is ``(T, R)`` allocated resources per tenant, ``cap``
    the ``(R,)`` cluster capacity, ``weights`` the ``(T,)`` wDRF
    weights.  A tenant's dominant share is its largest
    capacity-normalized allocation across resources (DRF [Ghodsi'11]);
    dividing by the weight makes heavier tenants entitled to more.
    """
    xp = _xp(alloc, cap, weights)
    norm = alloc / xp.maximum(cap, 1e-9)[None, :]
    return (xp.max(norm, axis=-1) / weights).astype(xp.float32)


def jain_index(shares, active=None):
    """Jain's fairness index over the active tenants' shares.

    ``(sum x)^2 / (n * sum x^2)`` — 1.0 when all active shares are
    equal, ``1/n`` when one tenant holds everything.  ``active`` masks
    which tenants count (default: all); with no active tenant or all
    zero shares the index is defined as 1.0 (nothing to be unfair
    about).
    """
    xp = _xp(shares, active)
    x = shares if active is None else shares * active
    n = x.size if active is None else active.sum()
    num = xp.sum(x) ** 2
    den = n * xp.sum(x * x)
    return xp.where(den > 0, num / xp.maximum(den, 1e-30), 1.0)


def gate_mask(shares, active, slack):
    """Admission-gate eligibility per tenant.

    A tenant may admit new work this tick unless its wDRF share
    exceeds the mean share of the *active* tenants (running or
    queued) by more than ``slack`` (scalar, or per-tenant — the
    credit-modulated headroom ``slack * credit``).  Inactive tenants
    are trivially eligible.
    """
    xp = _xp(shares, active)
    n = active.sum()
    mean = xp.where(n > 0,
                    xp.sum(shares * active) / xp.maximum(n, 1), 0.0)
    return (~active) | (shares <= mean + slack)
