"""Host-engine mirror of the device tenant state.

``HostControl`` is to :class:`repro.control.device.TenantState` what
``OnlineCalibrator`` is to ``CalibState``: the same accounting,
updated imperatively in NumPy by ``repro.sim.engine.run_sim``.  The
shared formula layer (:mod:`repro.control.fairness`,
:mod:`repro.control.credit`) keeps the two in lockstep; event counters
match the device engines exactly, float accumulations to within
reduction-order ulps.

Per-tick protocol (mirrors ``step._control_tick``):

1. phases 2-5 call :meth:`note_completed` / :meth:`note_failed` /
   :meth:`note_calib` as events land (good/bad accumulate);
2. at admission time :meth:`gate` folds the tick's events into the
   credit EMA, accrues share/active accounting and returns the
   per-tenant eligibility mask;
3. the admission loop calls :meth:`note_admitted` per placed app.

Shaping (phase 4) reads :meth:`q_groups` *before* step 2 runs, so the
safeguard quantile always uses the previous tick's credit — exactly
like the fused tick, where ``calib_scales`` precedes the control
update.
"""
from __future__ import annotations

import numpy as np

from repro.control.config import TenancyConfig, resolve_weights
from repro.control.credit import credit_quantile, credit_step
from repro.control.fairness import dominant_shares, gate_mask


class HostControl:
    def __init__(self, cfg: TenancyConfig):
        T = cfg.max_tenants
        self.cfg = cfg
        self.weights = resolve_weights(cfg)
        self.credit = np.full(T, cfg.credit_init, np.float32)
        self.admitted = np.zeros(T, np.int64)
        self.throttled = np.zeros(T, np.int64)
        self.completed = np.zeros(T, np.int64)
        self.failed = np.zeros(T, np.int64)
        self.share_sum = np.zeros(T, np.float32)
        self.active_ticks = np.zeros(T, np.int64)
        self._good = np.zeros(T, np.int64)
        self._bad = np.zeros(T, np.int64)

    # -- per-event notes (phases 2-5) ----------------------------------
    def note_completed(self, tenants) -> None:
        np.add.at(self.completed, tenants, 1)
        np.add.at(self._good, tenants, 1)

    def note_failed(self, tenants) -> None:
        """A failure event (optimistic conflict or OOM full kill)."""
        np.add.at(self.failed, tenants, 1)
        np.add.at(self._bad, tenants, 1)

    def note_calib(self, covered, miscovered) -> None:
        """Per-tenant conformal resolution counts for this tick."""
        self._good += np.asarray(covered, np.int64)
        self._bad += np.asarray(miscovered, np.int64)

    def note_admitted(self, tenant: int) -> None:
        self.admitted[tenant] += 1

    # -- shaping hook (phase 4, pre-update credit) ---------------------
    def q_groups(self, q: float, q_min: float, q_max: float) -> np.ndarray:
        """Per-tenant conformal target quantile from current credit."""
        if not self.cfg.credit:
            return np.full(self.cfg.max_tenants, q, np.float32)
        return credit_quantile(self.credit, q, self.cfg.q_spread,
                               q_min, q_max)

    # -- admission gate (phase 6 entry) --------------------------------
    def gate(self, alloc_t: np.ndarray, cap: np.ndarray,
             queued_t: np.ndarray) -> np.ndarray:
        """Fold the tick's events into credit, accrue share accounting,
        return the per-tenant admission-eligibility mask.

        ``alloc_t`` is ``(T, R)`` allocated resources per tenant,
        ``cap`` the ``(R,)`` cluster capacity, ``queued_t`` the
        ``(T,)`` queued-app counts."""
        cfg = self.cfg
        if cfg.credit:
            self.credit = credit_step(self.credit, self._good, self._bad,
                                      cfg.credit_gamma, cfg.credit_floor)
        self._good[:] = 0
        self._bad[:] = 0
        share = dominant_shares(np.asarray(alloc_t, np.float32),
                                np.asarray(cap, np.float32), self.weights)
        active = (share > 0) | (queued_t > 0)
        self.share_sum += np.float32(share * active)
        self.active_ticks += active
        if cfg.gate:
            slack = (np.float32(cfg.slack) * self.credit
                     if cfg.credit else np.float32(cfg.slack))
            elig = gate_mask(share, active, slack)
        else:
            elig = np.ones(cfg.max_tenants, bool)
        self.throttled += np.where(elig, 0, queued_t).astype(np.int64)
        return elig

    # -- drain ---------------------------------------------------------
    def arrays(self) -> dict:
        return dict(credit=self.credit, admitted=self.admitted,
                    throttled=self.throttled, completed=self.completed,
                    failed=self.failed, share_sum=self.share_sum,
                    active_ticks=self.active_ticks)
