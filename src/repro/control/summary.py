"""The shared per-tenant results block (``SimResults.tenancy``).

One function builds it for every engine: the host engine passes its
``HostControl.arrays()``, the scan/shard drain passes the
``TenantState`` arrays pulled back to NumPy.  The turnaround / SLO
columns are derived purely from host-side values (the trace and the
completed-turnaround dict), so they are identical across engines by
construction.
"""
from __future__ import annotations

import numpy as np

from repro.control.config import SLO_STRETCH, TenancyConfig
from repro.control.fairness import jain_index


def tenancy_summary(cfg: TenancyConfig, trace, turnaround: dict,
                    failed_apps: set, arrays: dict) -> dict:
    """Per-tenant fairness / SLO / turnaround / credit block.

    ``arrays`` carries the accounting counters (see
    ``HostControl.arrays`` for the keys); ``trace`` the workload
    (``tenant`` / ``slo`` / ``runtime`` columns); ``turnaround`` the
    gid -> seconds dict of completed apps.
    """
    tenant = np.asarray(trace.tenant, np.int64)
    slo = np.asarray(trace.slo, np.int64)
    Tn = int(tenant.max()) + 1 if tenant.size else 1

    ticks = np.asarray(arrays["active_ticks"], np.int64)[:Tn]
    share_sum = np.asarray(arrays["share_sum"], np.float64)[:Tn]
    mean_share = share_sum / np.maximum(ticks, 1)
    jain = float(jain_index(mean_share, ticks > 0))

    ta_mean = np.full(Tn, np.nan)
    ta_p95 = np.full(Tn, np.nan)
    slo_met = np.full(Tn, np.nan)
    done_t = np.zeros(Tn, np.int64)
    fail_t = np.zeros(Tn, np.int64)
    # Majority SLO class per tenant: the alerting plane keys its
    # per-tenant error budget (SLO_BUDGET) off this class code.
    slo_class = np.zeros(Tn, np.int64)
    for t in range(Tn):
        codes = slo[tenant == t]
        if codes.size:
            slo_class[t] = int(np.bincount(codes).argmax())
    stretch = np.asarray(SLO_STRETCH)[slo]
    for t in range(Tn):
        gids = [g for g in turnaround if tenant[g] == t]
        done_t[t] = len(gids)
        fail_t[t] = sum(1 for g in failed_apps if tenant[g] == t)
        if gids:
            ta = np.asarray([turnaround[g] for g in gids], np.float64)
            ta_mean[t] = ta.mean()
            ta_p95[t] = np.percentile(ta, 95)
            budget = stretch[gids] * np.asarray(trace.runtime, np.float64)[gids]
            slo_met[t] = float(np.mean(ta <= budget))

    def _fl(a):
        return [round(float(v), 6) for v in a]

    return {
        "n_tenants": Tn,
        "jain_mean_share": round(jain, 6),
        "mean_share": _fl(mean_share),
        "active_ticks": [int(v) for v in ticks],
        "credit": _fl(np.asarray(arrays["credit"], np.float64)[:Tn]),
        "admitted": [int(v) for v in np.asarray(arrays["admitted"])[:Tn]],
        "throttled": [int(v) for v in np.asarray(arrays["throttled"])[:Tn]],
        "completed": [int(v) for v in done_t],
        "failed_apps": [int(v) for v in fail_t],
        "failure_events": [int(v) for v in np.asarray(arrays["failed"])[:Tn]],
        "turnaround_mean": _fl(ta_mean),
        "turnaround_p95": _fl(ta_p95),
        "slo_met_frac": _fl(slo_met),
        "slo_class": [int(v) for v in slo_class],
    }
