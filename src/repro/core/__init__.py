# The paper's primary contribution: data-driven dynamic resource
# allocation = utilization forecasting (core.forecast) + resource
# shaping with pessimistic preemption (core.shaper) + monitoring
# (core.monitor).  All decision math is pure JAX and jit/vmap-batched.
from repro.core import forecast, shaper
from repro.core.monitor import Monitor

__all__ = ["forecast", "shaper", "Monitor"]
