"""Utilization forecasting (paper §3.1): predictive mean + *variance*."""
from repro.core.forecast.arima import ARIMAConfig, ARIMAForecaster
from repro.core.forecast.base import Forecast, Forecaster, batched
from repro.core.forecast.gp import GPConfig, GPForecaster, build_patterns
from repro.core.forecast.oracle import OracleForecaster

__all__ = [
    "Forecast", "Forecaster", "batched",
    "ARIMAConfig", "ARIMAForecaster",
    "GPConfig", "GPForecaster", "build_patterns",
    "OracleForecaster",
]
