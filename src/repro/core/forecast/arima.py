"""ARIMA(p,d,q) forecasting (paper §3.1.1) — pure JAX, batchable.

The paper uses auto-ARIMA (AIC order selection, |p| <= 3 in practice,
d = 1 "enough in most cases").  We reproduce that pipeline with fixed
shapes so it jits and vmaps over a fleet of series:

  1. difference the window d times (d in {0, 1});
  2. fit ARMA(p, q) by the Hannan-Rissanen two-stage method — a long
     AR(m) OLS fit supplies innovation estimates, then a second OLS
     regresses on p lags of the series and q lags of the innovations.
     Both stages are closed-form masked least squares (no iterative
     MLE), which is what makes a 24-candidate grid x fleet-size batch
     feasible every monitoring tick;
  3. AIC = n log(sigma^2) + 2 (p + q + 2) selects the order (the +2
     counts the intercept and the variance);
  4. k-step forecasts via the ARMA recursion with future innovations
     zeroed; the forecast VARIANCE comes from the psi-weight recursion
       psi_0 = 1,  psi_j = theta_j + sum_i phi_i psi_{j-i}
     integrated d times, Var[e(k)] = sigma^2 * sum_{j<k} psi_j^2
     (the paper's MSE identity for the unbiased forecast).

Note the paper's §3.1.1 caveat: these are *in-sample* innovation
variances — they ignore parameter uncertainty, and the resulting bands
are systematically narrow ("over-confidence").  This is the property
that makes ARIMA's K2 term ineffective in Fig. 4a, and we deliberately
do not correct it: it is the phenomenon under study.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.forecast.base import Forecast, batched

Array = jax.Array

MAX_P = 3
MAX_Q = 2
LONG_AR = 6          # stage-1 long-AR order m
RIDGE = 1e-4


@dataclasses.dataclass(frozen=True)
class ARIMAConfig:
    max_p: int = MAX_P
    max_q: int = MAX_Q
    max_d: int = 1
    long_ar: int = LONG_AR


def _masked_lstsq(A: Array, z: Array, row_mask: Array, col_mask: Array) -> Array:
    """Ridge-regularized masked OLS.  Excluded columns get beta = 0."""
    W = row_mask.astype(jnp.float32)
    Aw = A * W[:, None] * col_mask[None, :]
    G = Aw.T @ Aw + RIDGE * jnp.eye(A.shape[1], dtype=A.dtype)
    # pin excluded columns: identity row forces beta_j = 0
    G = jnp.where(col_mask[:, None] * col_mask[None, :] > 0, G,
                  jnp.eye(A.shape[1], dtype=A.dtype))
    b = Aw.T @ (z * W)
    beta = jnp.linalg.solve(G, b)
    return beta * col_mask


def _lags(z: Array, k: int) -> Array:
    """(T, k) matrix whose column j is z lagged by j+1 (zeros pre-sample)."""
    T = z.shape[0]
    idx = jnp.arange(T)[:, None] - (jnp.arange(k)[None, :] + 1)
    ok = idx >= 0
    return jnp.where(ok, z[jnp.clip(idx, 0)], 0.0), ok


def _fit_arma(z: Array, zmask: Array, p_mask: Array, q_mask: Array,
              cfg: ARIMAConfig):
    """Hannan-Rissanen ARMA fit with static MAX_P/MAX_Q shapes.

    p_mask: (MAX_P,) 1/0 — which AR coefficients are active.
    q_mask: (MAX_Q,) — which MA coefficients are active.
    Returns (delta, phi, theta, sigma2, resid, n_eff)."""
    T = z.shape[0]
    m = cfg.long_ar
    # stage 1: long AR(m) for innovation estimates
    L1, ok1 = _lags(z, m)
    rows1 = zmask & jnp.all(ok1, axis=1)
    A1 = jnp.concatenate([jnp.ones((T, 1), z.dtype), L1], axis=1)
    beta1 = _masked_lstsq(A1, z, rows1, jnp.ones((m + 1,), z.dtype))
    e = jnp.where(rows1, z - A1 @ beta1, 0.0)

    # stage 2: regress z_t on [1, z lags (P), e lags (Q)]
    Lz, okz = _lags(z, cfg.max_p)
    Le, oke = _lags(e, cfg.max_q)
    need = jnp.concatenate([
        jnp.ones((T, 1), bool),
        okz & (p_mask[None, :] > 0),
        oke & (q_mask[None, :] > 0)], axis=1)
    # rows valid where every *active* regressor is in-sample; also require
    # stage-1 residuals valid over the MA lags actually used
    e_rows = jnp.roll(rows1, 1)  # e_{t-1} needs row t-1 valid; approx for q>=1
    rows2 = zmask & jnp.all(need, axis=1) & jnp.where(q_mask.sum() > 0,
                                                      e_rows, True)
    A2 = jnp.concatenate([jnp.ones((T, 1), z.dtype), Lz, Le], axis=1)
    cmask = jnp.concatenate([jnp.ones((1,), z.dtype), p_mask, q_mask])
    beta2 = _masked_lstsq(A2, z, rows2, cmask)
    resid = jnp.where(rows2, z - A2 @ beta2, 0.0)
    n_eff = jnp.maximum(rows2.sum(), 1).astype(z.dtype)
    sigma2 = jnp.maximum((resid ** 2).sum() / n_eff, 1e-10)
    delta = beta2[0]
    phi = beta2[1:1 + cfg.max_p]
    theta = beta2[1 + cfg.max_p:]
    return delta, phi, theta, sigma2, resid, n_eff


def _psi_weights(phi: Array, theta: Array, horizon: int, d: Array) -> Array:
    """psi_j for j in [0, horizon), integrated d times (d traced 0/1)."""
    P, Q = phi.shape[0], theta.shape[0]
    psi = jnp.zeros((horizon,), phi.dtype).at[0].set(1.0)

    def body(j, psi):
        th = jnp.where(j <= Q, theta[jnp.clip(j - 1, 0, Q - 1)], 0.0)
        idx = j - 1 - jnp.arange(P)
        prev = jnp.where(idx >= 0, psi[jnp.clip(idx, 0)], 0.0)
        val = th + jnp.sum(phi * prev)
        return psi.at[j].set(val)

    psi = jax.lax.fori_loop(1, horizon, body, psi)
    # d=1 integration: psi~_j = cumsum(psi)_j
    psi_int = jnp.cumsum(psi)
    return jnp.where(d > 0, psi_int, psi)


@dataclasses.dataclass(frozen=True)
class ARIMAForecaster:
    """Auto-ARIMA forecaster (paper's parametric model)."""

    cfg: ARIMAConfig = ARIMAConfig()

    def forecast(self, window: Array, horizon: int, *,
                 valid: Array | None = None) -> Forecast:
        cfg = self.cfg
        window = window.astype(jnp.float32)
        T = window.shape[0]
        if valid is None:
            valid = jnp.ones((T,), dtype=bool)
        # scale-normalize for conditioning
        w = valid.astype(jnp.float32)
        mu = (window * w).sum() / jnp.maximum(w.sum(), 1.0)
        sd = jnp.sqrt(jnp.maximum(
            ((window - mu) ** 2 * w).sum() / jnp.maximum(w.sum(), 1.0), 1e-8))
        y = (window - mu) / sd

        # candidate grid (static): (p, d, q)
        cands = [(p, d, q)
                 for d in range(cfg.max_d + 1)
                 for p in range(cfg.max_p + 1)
                 for q in range(cfg.max_q + 1)
                 if p + q > 0]

        def eval_cand(p, d, q):
            if d == 0:
                z, zm = y, valid
            else:
                z = jnp.diff(y, prepend=y[:1])
                zm = valid & jnp.roll(valid, 1)
                zm = zm.at[0].set(False)
            p_mask = (jnp.arange(cfg.max_p) < p).astype(jnp.float32)
            q_mask = (jnp.arange(cfg.max_q) < q).astype(jnp.float32)
            delta, phi, theta, sig2, resid, n = _fit_arma(
                z, zm, p_mask, q_mask, cfg)
            aic = n * jnp.log(sig2) + 2.0 * (p + q + 2)
            # k-step recursion on z, future innovations = 0
            zbuf = jnp.concatenate([z, jnp.zeros((horizon,), z.dtype)])
            ebuf = jnp.concatenate([resid, jnp.zeros((horizon,), z.dtype)])

            def step(carry, j):
                zb, eb = carry
                t = T + j
                zl = jax.lax.dynamic_slice(zb, (t - cfg.max_p,), (cfg.max_p,))[::-1]
                el = jax.lax.dynamic_slice(eb, (t - cfg.max_q,), (cfg.max_q,))[::-1]
                zt = delta + jnp.sum(phi * p_mask * zl) + jnp.sum(theta * q_mask * el)
                zb = jax.lax.dynamic_update_index_in_dim(zb, zt, t, 0)
                return (zb, eb), zt

            (_, _), zf = jax.lax.scan(step, (zbuf, ebuf), jnp.arange(horizon))
            if d == 0:
                mean = zf
            else:
                mean = y[-1] + jnp.cumsum(zf)
            psi = _psi_weights(phi * p_mask, theta * q_mask, horizon,
                               jnp.asarray(d))
            var = sig2 * jnp.cumsum(psi ** 2)
            return aic, mean, var

        aics, means, vars_ = [], [], []
        for (p, d, q) in cands:
            a, mn, vr = eval_cand(p, d, q)
            aics.append(a)
            means.append(mn)
            vars_.append(vr)
        aics = jnp.stack(aics)
        means = jnp.stack(means)
        vars_ = jnp.stack(vars_)
        aics = jnp.where(jnp.isfinite(aics), aics, jnp.inf)
        best = jnp.argmin(aics)
        mean = means[best] * sd + mu
        var = vars_[best] * sd ** 2

        enough = valid.sum() >= (cfg.long_ar + cfg.max_p + 2)
        last = window[-1]
        mean = jnp.where(enough, mean, last)
        var = jnp.where(enough, var, (0.5 * jnp.abs(last) + 1.0) ** 2)
        return Forecast(mean=mean, var=jnp.maximum(var, 1e-9))

    def forecast_batch(self, windows: Array, horizon: int, *,
                       valid: Array | None = None) -> Forecast:
        # shared vmap wrapper (repro.core.forecast.base.batched): per-row
        # independence is the contract the engines' bucketed/padded
        # batch paths rely on, so there is exactly one batching idiom
        return batched(self.forecast, windows, horizon, valid)
