"""Forecaster protocol — the paper's §3.1 utilization-forecasting module.

Every forecaster consumes a fixed-length window of past observations of a
single resource time series (CPU or memory of one application component,
sampled once per monitoring tick) and produces a ``Forecast``: the k-step
ahead predictive mean together with a *variance* that quantifies the
uncertainty of the prediction.  The variance is what the resource shaper's
safe-guard buffer (Eq. 9) consumes — it is a first-class output, not a
diagnostic.

All forecasters are pure-JAX and batchable with ``vmap`` over thousands of
component series, which is how the fleet-scale deployment runs them.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax
import jax.numpy as jnp

from repro.core.uncertainty.scoring import (gaussian_quantile_scale,
                                            sigma_from_var)

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Forecast:
    """k-step-ahead predictive distribution for one series.

    mean, var have shape ``(horizon,)``; ``var`` is the *predictive*
    variance (not a parameter confidence interval — see paper §3.1.1 for
    why the distinction matters).
    """

    mean: Array
    var: Array

    @property
    def sigma(self) -> Array:
        """Predictive standard deviation (shared clamp, see Eq. 9)."""
        return sigma_from_var(self.var)

    def quantile(self, q, *, scale: Array | None = None) -> Array:
        """Upper q-quantile of the predictive distribution.

        Default is the Gaussian form ``mean + z(q) * sigma`` (the
        paper's §3.1 distributional assumption, which Eq. 9's K2 bands
        instantiate).  Pass ``scale`` — e.g. a calibrated score
        quantile from :mod:`repro.core.uncertainty.conformal` — to get
        a *distribution-free* quantile ``mean + scale * sigma`` instead;
        ``q`` is then only the nominal level the scale was built for.
        """
        z = gaussian_quantile_scale(q) if scale is None else scale
        return self.mean + z * self.sigma

    def interval(self, q_lo, q_hi, *,
                 scale_lo: Array | None = None,
                 scale_hi: Array | None = None) -> tuple[Array, Array]:
        """(lower, upper) predictive interval at the given levels."""
        return (self.quantile(q_lo, scale=scale_lo),
                self.quantile(q_hi, scale=scale_hi))


class Forecaster(Protocol):
    """A forecaster maps an observation window to a Forecast.

    ``window`` is shape ``(T,)`` float32 — the most recent T observations,
    oldest first.  ``valid`` is an optional same-shape boolean mask for
    series younger than the window (the grace period of §5 means shapers
    only act once enough points exist, but forecasters must not NaN on
    short histories).
    """

    def forecast(self, window: Array, horizon: int, *,
                 valid: Array | None = None) -> Forecast:
        ...


def batched(forecast_fn, windows: Array, horizon: int,
            valid: Array | None = None) -> Forecast:
    """vmap a single-series forecast fn over (B, T) windows."""
    if valid is None:
        valid = jnp.ones(windows.shape, dtype=bool)
    def fn(w, v):
        return forecast_fn(w, horizon, valid=v)
    return jax.vmap(fn)(windows, valid)


def peak_over_horizon(fc: Forecast) -> tuple[Array, Array]:
    """(peak mean, its variance) from a batched ``(B, horizon)`` Forecast.

    The paper's predictor outputs a *future peak* utilization (§4.2): we
    take the max of the predictive path and carry that step's variance.
    Shared by the host engines' jitted peak path and the fused scan
    engine, so the two can never drift on this reduction.
    """
    k = jnp.argmax(fc.mean, axis=1)
    peak = jnp.take_along_axis(fc.mean, k[:, None], 1)[:, 0]
    pvar = jnp.take_along_axis(fc.var, k[:, None], 1)[:, 0]
    return peak, pvar


def persistence_peak(windows: Array, valid: Array) -> tuple[Array, Array]:
    """The ``persist`` forecaster's (mean, var) over ``(B, W)`` windows.

    Mean = last observation, var = masked window variance + 1e-6 —
    jnp mirror of the host engines' NumPy path (same masked-moment
    formula, so solo/batched/scan paths agree)."""
    w = valid.astype(windows.dtype)
    cnt = jnp.maximum(w.sum(axis=1), 1.0)
    mu = (windows * w).sum(axis=1) / cnt
    var = (((windows - mu[:, None]) ** 2) * w).sum(axis=1) / cnt
    return windows[:, -1], var + 1e-6
