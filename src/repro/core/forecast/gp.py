"""GP regression with a history-dependent kernel (paper §3.1.2).

Time series are modeled as the state-space form of Eq. (4):

    y_t = f(y_{t-1}, ..., y_{t-h}) + eps_t

and f is learned by standard GP regression over *pattern* inputs (Eq. 5):

    x~_t = [t, y_{t-h}, ..., y_{t-1}]

so the kernel compares observation histories, not just time stamps
(Eq. 6).  Two stationary kernels are supported, matching the paper's
Fig. 2 comparison:

  * ``exp``  — exponential  k(r) = sf^2 * exp(-r / ell)      (paper's pick)
  * ``rbf``  — squared-exp  k(r) = sf^2 * exp(-r^2 / 2 ell^2)

The posterior mean/variance are the closed forms of Eqs. (7)-(8); hyper-
parameters (ell, sf, sn) are tuned by evidence maximization (a fixed
number of Adam steps on the log marginal likelihood — no cross
validation, per the paper's argument).  The dataset is windowed to the
latest N patterns to keep the O(N^3) solve tractable (paper end of
§3.1.2); N and h are static so everything jits and vmaps.

The Gram-matrix construction — the arithmetic hot spot when batching
over a fleet's worth of series — is delegated to ``repro.kernels.ops``
which dispatches to the Pallas TPU kernel (``kernels/gp_gram.py``) on
TPU and to the pure-jnp reference elsewhere.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.forecast.base import Forecast, batched
from repro.kernels import ops as kops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GPConfig:
    history: int = 10          # h — pattern length (paper uses 10/20/40)
    max_patterns: int = 10     # N — latest patterns kept (paper: N = h)
    kernel: str = "exp"        # "exp" (paper's choice) or "rbf"
    opt_steps: int = 25        # evidence-maximization Adam steps
    opt_lr: float = 0.08
    jitter: float = 1e-5
    impl: str = "auto"         # gram impl: "auto" | "pallas" | "jnp"


def build_patterns(window: Array, h: int, n: int) -> tuple[Array, Array, Array]:
    """Build (X, y, row_valid) from the last ``n`` patterns of a window.

    X[i] = [t_i, y_{t_i-h}, ..., y_{t_i-1}],  y[i] = y_{t_i}   (Eq. 5)

    The time feature is normalized to [0, 1] over the window so that its
    scale is commensurate with standardized observations.
    """
    T = window.shape[0]
    n_avail = T - h
    assert n_avail >= 1, "window must be longer than history"
    n = min(n, n_avail)
    # pattern i predicts target index  T - n + i  (the n most recent)
    tgt = jnp.arange(T - n, T)
    t_feat = tgt.astype(jnp.float32) / jnp.float32(max(T - 1, 1))
    # history rows: indices tgt-h .. tgt-1
    offs = jnp.arange(-h, 0)
    hist = window[tgt[:, None] + offs[None, :]]          # (n, h)
    X = jnp.concatenate([t_feat[:, None], hist], axis=1)  # (n, h+1)
    y = window[tgt]
    valid = jnp.ones((n,), dtype=bool)
    return X, y, valid


def _standardize(y: Array, valid: Array) -> tuple[Array, Array, Array]:
    w = valid.astype(y.dtype)
    cnt = jnp.maximum(w.sum(), 1.0)
    mu = (y * w).sum() / cnt
    var = ((y - mu) ** 2 * w).sum() / cnt
    sd = jnp.sqrt(jnp.maximum(var, 1e-10))
    return (y - mu) / sd, mu, sd


def _neg_log_marginal(log_params: Array, X: Array, y: Array,
                      row_valid: Array, kernel: str, jitter: float,
                      impl: str) -> Array:
    ell, sf, sn = jnp.exp(log_params)
    K = kops.gram(X, X, ell, sf, kind=kernel, impl=impl)
    # invalid rows: decouple them with enormous noise so they carry no info
    noise = jnp.where(row_valid, sn ** 2 + jitter, 1e6)
    K = K + jnp.diag(noise)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    n_eff = row_valid.sum().astype(y.dtype)
    return (0.5 * y @ alpha
            + jnp.sum(jnp.where(row_valid, jnp.log(jnp.diagonal(L)), 0.0))
            + 0.5 * n_eff * jnp.log(2.0 * jnp.pi))


def _optimize_evidence(X, y, row_valid, cfg: GPConfig) -> Array:
    """A fixed Adam loop on the log marginal likelihood (no line search —
    deterministic cost, which matters when vmapping over a fleet)."""
    loss = partial(_neg_log_marginal, X=X, y=y, row_valid=row_valid,
                   kernel=cfg.kernel, jitter=cfg.jitter, impl=cfg.impl)
    grad = jax.grad(loss)
    init = jnp.log(jnp.asarray([1.0, 1.0, 0.3], dtype=jnp.float32))
    b1, b2, eps = 0.9, 0.999, 1e-8

    def step(state, i):
        p, m, v = state
        g = grad(p)
        g = jnp.where(jnp.isfinite(g), g, 0.0)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** (i + 1.0))
        vh = v / (1 - b2 ** (i + 1.0))
        p = p - cfg.opt_lr * mh / (jnp.sqrt(vh) + eps)
        p = jnp.clip(p, -6.0, 6.0)
        return (p, m, v), None

    (p, _, _), _ = jax.lax.scan(
        step, (init, jnp.zeros_like(init), jnp.zeros_like(init)),
        jnp.arange(cfg.opt_steps, dtype=jnp.float32))
    return p


@dataclasses.dataclass(frozen=True)
class GPForecaster:
    """History-kernel GP forecaster (paper's non-parametric model)."""

    cfg: GPConfig = GPConfig()

    def forecast(self, window: Array, horizon: int, *,
                 valid: Array | None = None) -> Forecast:
        cfg = self.cfg
        T = window.shape[0]
        h = cfg.history
        if valid is None:
            valid = jnp.ones((T,), dtype=bool)
        window = window.astype(jnp.float32)
        z, mu, sd = _standardize(window, valid)
        X, y, _ = build_patterns(z, h, cfg.max_patterns)
        n = X.shape[0]
        # a pattern row is valid iff its whole history + target are observed
        tgt = jnp.arange(T - n, T)
        offs = jnp.arange(-h, 1)  # history + target
        row_valid = jnp.all(valid[tgt[:, None] + offs[None, :]], axis=1)

        log_params = _optimize_evidence(X, y, row_valid, cfg)
        ell, sf, sn = jnp.exp(log_params)

        K = kops.gram(X, X, ell, sf, kind=cfg.kernel, impl=cfg.impl)
        noise = jnp.where(row_valid, sn ** 2 + cfg.jitter, 1e6)
        L = jnp.linalg.cholesky(K + jnp.diag(noise))
        alpha = jax.scipy.linalg.cho_solve((L, True), y)

        # iterated k-step-ahead: feed the predictive mean back into the
        # history (standard for NARX-style GP forecasting); the predictive
        # variance at each step quantifies uncertainty (Eq. 8).
        hist = z[-h:]
        means, variances = [], []
        for k in range(horizon):
            t_next = (T + k) / max(T - 1, 1)
            xs = jnp.concatenate([jnp.asarray([t_next], jnp.float32), hist])[None, :]
            ks = kops.gram(xs, X, ell, sf, kind=cfg.kernel, impl=cfg.impl)[0]
            mean_k = ks @ alpha
            v = jax.scipy.linalg.cho_solve((L, True), ks)
            var_k = sf ** 2 + sn ** 2 - ks @ v
            var_k = jnp.maximum(var_k, 1e-9)
            means.append(mean_k)
            variances.append(var_k)
            hist = jnp.concatenate([hist[1:], mean_k[None]])

        mean = jnp.stack(means) * sd + mu
        var = jnp.stack(variances) * sd ** 2
        # degenerate window (fewer than h+1 valid points): fall back to
        # persistence with inflated variance rather than NaN.
        enough = valid.sum() >= (h + 1)
        last = window[-1]
        mean = jnp.where(enough, mean, last)
        var = jnp.where(enough, var, (0.5 * jnp.abs(last) + 1.0) ** 2)
        return Forecast(mean=mean, var=var)

    def forecast_batch(self, windows: Array, horizon: int, *,
                       valid: Array | None = None) -> Forecast:
        # shared vmap wrapper (repro.core.forecast.base.batched): per-row
        # independence is the contract the engines' bucketed/padded
        # batch paths rely on, so there is exactly one batching idiom
        return batched(self.forecast, windows, horizon, valid)
