"""Oracle forecaster — perfect information about future utilization.

The paper's Fig. 3 isolates the value of the *shaping mechanism* from
the quality of the *predictor* by plugging in an oracle.  The simulator
hands the oracle the true future slice of each component's utilization
series; the oracle returns it with zero variance, so the safeguard
buffer collapses to its static term K1*R.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.forecast.base import Forecast

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OracleForecaster:
    """Returns the supplied future truth, variance = 0."""

    def forecast_from_future(self, future: Array) -> Forecast:
        future = jnp.asarray(future, jnp.float32)
        return Forecast(mean=future, var=jnp.zeros_like(future))

    # Forecaster-protocol shim: with no future supplied, degrade to
    # persistence (used only by API-compat tests).
    def forecast(self, window: Array, horizon: int, *,
                 valid: Array | None = None) -> Forecast:
        last = jnp.asarray(window)[-1]
        mean = jnp.full((horizon,), last, jnp.float32)
        return Forecast(mean=mean, var=jnp.zeros_like(mean))

    def forecast_batch(self, windows: Array, horizon: int, *,
                       valid: Array | None = None) -> Forecast:
        def fn(w):
            return self.forecast(w, horizon)
        return jax.vmap(fn)(windows)
