"""Resource monitor — fixed-window utilization time series per component.

The paper's monitor samples standard OS metrics (CPU, memory) for every
component of every running application once per interval, with no
application instrumentation.  This class is the host-side ring buffer
both the simulator and the live framework feed; ``windows()`` hands the
forecasters a dense (slots, W) array plus validity masks, oldest-first.

Host-side numpy by design: sampling is I/O, not compute — only the
forecast/shape math goes through JAX.
"""
from __future__ import annotations

import numpy as np

N_RES = 2          # 0 = cpu, 1 = mem
CPU, MEM = 0, 1


class Monitor:
    def __init__(self, slots: int, window: int):
        self.window = window
        self.buf = np.zeros((slots, window, N_RES), np.float32)
        self.count = np.zeros((slots,), np.int64)   # samples seen per slot

    def reset_slot(self, slot) -> None:
        self.buf[slot] = 0.0
        self.count[slot] = 0

    def record(self, slots: np.ndarray, cpu: np.ndarray,
               mem: np.ndarray) -> None:
        """Append one sample for each slot in ``slots`` (vectorized)."""
        self.buf[slots] = np.roll(self.buf[slots], -1, axis=1)
        self.buf[slots, -1, CPU] = cpu
        self.buf[slots, -1, MEM] = mem
        self.count[slots] += 1

    def windows(self, slots: np.ndarray):
        """(windows, valid): (n, W, 2) float32 and (n, W) bool, oldest-first."""
        w = self.buf[slots]
        age = np.arange(self.window)[None, :]  # 0 = oldest cell
        valid = age >= (self.window - np.minimum(self.count[slots], self.window))[:, None]
        return w, valid

    def ready(self, slots: np.ndarray, grace: int) -> np.ndarray:
        """Grace period (paper §5): shape only after ``grace`` samples."""
        return self.count[slots] >= grace
