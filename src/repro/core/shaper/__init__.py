"""Resource shaper (paper §3.2): shaping policies + safe-guard buffer."""
from repro.core.shaper.baseline import baseline_shape
from repro.core.shaper.optimistic import optimistic_shape
from repro.core.shaper.pessimistic import (ShapeDecision, ShapeProblem,
                                           pessimistic_shape)
from repro.core.shaper.safeguard import (SafeguardConfig, beta,
                                         shaped_demand, shaped_demand_scaled)

POLICIES = {
    "baseline": baseline_shape,
    "optimistic": optimistic_shape,
    "pessimistic": pessimistic_shape,
}

__all__ = [
    "ShapeProblem", "ShapeDecision", "pessimistic_shape",
    "optimistic_shape", "baseline_shape", "POLICIES",
    "SafeguardConfig", "beta", "shaped_demand", "shaped_demand_scaled",
]
