"""Resource shaper (paper §3.2): shaping policies + safe-guard buffer."""
from repro.core.shaper.baseline import baseline_shape, baseline_shape_raw
from repro.core.shaper.optimistic import optimistic_shape, optimistic_shape_raw
from repro.core.shaper.pessimistic import (ShapeDecision, ShapeProblem,
                                           pessimistic_shape,
                                           pessimistic_shape_raw)
from repro.core.shaper.safeguard import (SafeguardConfig, beta,
                                         shaped_demand, shaped_demand_raw,
                                         shaped_demand_scaled,
                                         shaped_demand_scaled_raw)

POLICIES = {
    "baseline": baseline_shape,
    "optimistic": optimistic_shape,
    "pessimistic": pessimistic_shape,
}

#: unjitted bodies, for fusing a whole tick (forecast -> safeguard ->
#: policy -> OOM) into ONE jitted program (repro.sim.step)
RAW_POLICIES = {
    "baseline": baseline_shape_raw,
    "optimistic": optimistic_shape_raw,
    "pessimistic": pessimistic_shape_raw,
}

__all__ = [
    "ShapeProblem", "ShapeDecision", "pessimistic_shape",
    "optimistic_shape", "baseline_shape", "POLICIES", "RAW_POLICIES",
    "pessimistic_shape_raw", "optimistic_shape_raw", "baseline_shape_raw",
    "SafeguardConfig", "beta", "shaped_demand", "shaped_demand_scaled",
    "shaped_demand_raw", "shaped_demand_scaled_raw",
]
