"""Baseline policy: allocation == reservation, never adjusted (paper §4.2).

The reservation-centric approach of Mesos/YARN as implemented in the
Omega simulator: the only "shaping" is the identity.  The caller passes
reservations in the demand fields of the ShapeProblem.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.shaper.pessimistic import ShapeDecision, ShapeProblem


def baseline_shape_raw(p: ShapeProblem) -> ShapeDecision:
    """Unjitted body — fuseable inside larger jitted programs."""
    A, C = p.comp_exists.shape
    H = p.host_cpu.shape[0]
    live = p.comp_exists & p.app_exists[:, None]
    alloc_cpu = jnp.where(live, p.comp_cpu, 0.0)
    alloc_mem = jnp.where(live, p.comp_mem, 0.0)
    flat_host = p.comp_host.reshape(-1)
    used_cpu = jax.ops.segment_sum(alloc_cpu.reshape(-1), flat_host,
                                   num_segments=H)
    used_mem = jax.ops.segment_sum(alloc_mem.reshape(-1), flat_host,
                                   num_segments=H)
    return ShapeDecision(
        kill_app=jnp.zeros((A,), bool),
        kill_comp=jnp.zeros((A, C), bool),
        alloc_cpu=alloc_cpu,
        alloc_mem=alloc_mem,
        cpu_free=p.host_cpu - used_cpu,
        mem_free=p.host_mem - used_mem,
    )


#: jitted entry point (one dispatch per call — the host-loop engines)
baseline_shape = jax.jit(baseline_shape_raw)
