"""Optimistic reclamation policy (Borg/Omega-style — paper §3.2, §4.2).

Resources are redeemed "without taking explicit actions to manage the
consequences": every component is resized to its shaped demand with no
coordination.  Conflicts are resolved after the fact, in the manner of
optimistic concurrency control: "when two applications compete for
resources and there are none left, the system will let one of the two
fail" (paper §4.2).  Concretely, for every host whose total demand
exceeds capacity, whole applications are failed — largest resident
demand first, with no elastic-first ordering, no priority ordering and
no partial preemption — until the host fits.  These kills are the
*uncontrolled application failures* measured at 37.67% in Fig. 3.

Implemented as a bounded ``lax.while_loop`` so the policy stays a single
jitted call like its pessimistic counterpart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.shaper.pessimistic import ShapeDecision, ShapeProblem


def optimistic_shape_raw(p: ShapeProblem) -> ShapeDecision:
    """Unjitted body — fuseable inside larger jitted programs."""
    A, C = p.comp_exists.shape
    H = p.host_cpu.shape[0]
    live0 = p.comp_exists & p.app_exists[:, None]
    flat_host = p.comp_host.reshape(-1)

    def by_host(x):     # (A, C) -> (H,)
        return jax.ops.segment_sum(x.reshape(-1), flat_host, num_segments=H)

    # per-app, per-host demand footprint: (A, H)
    app_cpu_h = jax.vmap(lambda cpu, host, lv: jax.ops.segment_sum(
        jnp.where(lv, cpu, 0.0), host, num_segments=H))(
        p.comp_cpu, p.comp_host, live0)
    app_mem_h = jax.vmap(lambda mem, host, lv: jax.ops.segment_sum(
        jnp.where(lv, mem, 0.0), host, num_segments=H))(
        p.comp_mem, p.comp_host, live0)

    def cond(state):
        kill, cpu_h, mem_h = state
        return jnp.any((cpu_h > p.host_cpu + 1e-6)
                       | (mem_h > p.host_mem + 1e-6))

    # "unpredictable" OS-style victim choice: a fixed pseudo-random
    # priority per app (hash of its index), not size- or age-aware
    rand_prio = ((jnp.arange(A, dtype=jnp.uint32) * jnp.uint32(2654435761))
                 >> 8).astype(jnp.float32)

    def body(state):
        kill, cpu_h, mem_h = state
        # the most-overcommitted host (memory-first, the finite resource)
        over_mem = mem_h - p.host_mem
        over_cpu = cpu_h - p.host_cpu
        h = jnp.argmax(jnp.maximum(over_mem, over_cpu * 1e-3))
        # fail a pseudo-random app among those resident on that host
        resident = (app_mem_h[:, h] + app_cpu_h[:, h]) > 0
        score = jnp.where(kill | ~resident, -jnp.inf, rand_prio)
        victim = jnp.argmax(score)
        kill = kill.at[victim].set(True)
        cpu_h = cpu_h - app_cpu_h[victim]
        mem_h = mem_h - app_mem_h[victim]
        return kill, cpu_h, mem_h

    kill0 = ~p.app_exists
    state = (kill0, app_cpu_h.sum(0), app_mem_h.sum(0))
    kill, cpu_h, mem_h = jax.lax.while_loop(cond, body, state)
    kill_app = kill & p.app_exists

    live = live0 & ~kill_app[:, None]
    alloc_cpu = jnp.where(live, p.comp_cpu, 0.0)
    alloc_mem = jnp.where(live, p.comp_mem, 0.0)
    return ShapeDecision(
        kill_app=kill_app,
        kill_comp=jnp.zeros((A, C), bool),
        alloc_cpu=alloc_cpu,
        alloc_mem=alloc_mem,
        cpu_free=p.host_cpu - by_host(alloc_cpu),
        mem_free=p.host_mem - by_host(alloc_mem),
    )


#: jitted entry point (one dispatch per call — the host-loop engines)
optimistic_shape = jax.jit(optimistic_shape_raw)
