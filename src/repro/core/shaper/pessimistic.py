"""Pessimistic preemption policy — paper Algorithm 1, in JAX.

Greedy pass over running applications in scheduler-policy order:

  * an application's CORE components are fitted first, host by host; if
    any host would go negative the whole application is marked for FULL
    preemption (paper lines 11-21, 34-36);
  * surviving applications then fit their ELASTIC components one at a
    time, oldest-first (sorted by timeAlive, line 25) — a component that
    does not fit is PARTIALLY preempted on its own (lines 26-33, 37-38);
  * every surviving component is resized to its shaped demand
    (forecast peak + beta, lines 39-41).

Faithfulness notes: core checks use ``< 0`` and elastic checks ``<= 0``
exactly as in the listing; beta is already folded into the demands by
the caller (the listing subtracts ``futureX - beta`` — we precompute
``demand = clip(forecast + beta, 0, request)`` via safeguard.shaped_demand).

The whole policy is a ``lax.scan`` over the (padded, fixed-size) app
table with an inner scan over the component table, so one jitted call
shapes the entire cluster — this is what lets the live framework run the
policy every monitoring tick for thousands of nodes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShapeProblem:
    """Fixed-size cluster state handed to a shaping policy.

    A = max apps, C = max components per app, H = hosts.
    Demands are the shaped targets (forecast + beta) per component.
    """

    host_cpu: Array          # (H,) capacity
    host_mem: Array          # (H,)
    app_exists: Array        # (A,) bool
    app_order: Array         # (A,) int — processing order (policy-sorted),
                             #   entries are app indices; padded with -1
    comp_exists: Array       # (A, C) bool
    comp_core: Array         # (A, C) bool
    comp_host: Array         # (A, C) int32 host index (0 if absent)
    comp_cpu: Array          # (A, C) shaped cpu demand
    comp_mem: Array          # (A, C) shaped mem demand
    comp_alive: Array        # (A, C) seconds alive (elastic sort key)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShapeDecision:
    kill_app: Array          # (A,) bool — full preemption
    kill_comp: Array         # (A, C) bool — partial (elastic) preemption
    alloc_cpu: Array         # (A, C) granted allocation (0 for killed)
    alloc_mem: Array         # (A, C)
    cpu_free: Array          # (H,) remaining after allocation
    mem_free: Array          # (H,)


def pessimistic_shape_raw(p: ShapeProblem) -> ShapeDecision:
    """Unjitted Algorithm 1 — inline this inside larger jitted programs
    (the fused scan engine traces it once per tick chunk instead of
    paying a separate dispatch per tick)."""
    A, C = p.comp_exists.shape
    H = p.host_cpu.shape[0]

    # elastic processing order per app: oldest (largest timeAlive) first,
    # so the newest components are the ones that hit exhausted capacity.
    alive_key = jnp.where(p.comp_exists & ~p.comp_core,
                          p.comp_alive, -jnp.inf)
    elastic_order = jnp.argsort(-alive_key, axis=1)          # (A, C)

    # Everything the sequential pass needs is pre-gathered OUTSIDE the
    # scan, batched over all apps: rows permuted into processing order,
    # per-app core demand aggregated per host, elastic demands permuted
    # into eviction order, and host one-hots materialized.  The scan
    # body is then pure masked arithmetic — no dynamic gathers or
    # scatters, which XLA CPU serializes (and which stay serial under
    # the scan engine's vmap over seed cohorts).
    a_all = jnp.maximum(p.app_order, 0)
    valid_all = (p.app_order >= 0) & p.app_exists[a_all]
    exists = p.comp_exists[a_all]                            # (A, C)
    is_core = p.comp_core[a_all]
    host = p.comp_host[a_all]
    # cpu/mem fused on a trailing resource lane: halves the op count of
    # the sequential passes (tiny-tensor op overhead dominates there)
    row_dem = jnp.stack([p.comp_cpu[a_all], p.comp_mem[a_all]], -1)
    core = exists & is_core
    host_oh = host[:, :, None] == jnp.arange(H)[None, None, :]  # (A, C, H)
    core_dem_all = jnp.where((core[:, :, None] & host_oh)[..., None],
                             row_dem[:, :, None, :], 0.0).sum(1)  # (A, H, 2)
    order = elastic_order[a_all]                             # (A, C)
    ar = jnp.arange(A)[:, None]
    ord_dem = row_dem[ar, order]                             # (A, C, 2)
    ord_el = (exists & ~is_core)[ar, order]
    ord_oh = host_oh[ar, order]                              # (A, C, H)

    xs = (valid_all, core_dem_all, ord_dem, ord_el, ord_oh)

    def app_step(carry, x):
        free = carry                                         # (H, 2)
        valid, core_dem, o_dem, o_el, o_oh = x

        # ---- core components (lines 11-19): aggregate per-host demand ----
        trial = free - core_dem
        remove = valid & jnp.any(trial < 0.0)
        commit_core = valid & ~remove
        free = jnp.where(commit_core, trial, free)

        # ---- elastic components (lines 25-33): sequential oldest-first ----
        def comp_step(f, x2):
            dem, el_c, oh = x2                   # (2,), (), (H,)
            is_el = commit_core & el_c
            tcm = jnp.where(oh[:, None], f, 0.0).sum(0) - dem    # (2,)
            kill_c = is_el & jnp.any(tcm <= 0.0)
            commit = is_el & ~kill_c
            f = f - jnp.where((oh & commit)[:, None], dem, 0.0)
            return f, kill_c

        # fully unrolled: C is small and the body is a handful of scalar
        # ops — loop-carry overhead would dominate the work (the scan
        # engine runs this every tick inside a fused chunk)
        free, kill_pos = jax.lax.scan(
            comp_step, free, (o_dem, o_el, o_oh), unroll=True)

        return free, (remove, kill_pos)

    free0 = jnp.stack([p.host_cpu, p.host_mem], -1)
    free, (removes, kill_pos) = jax.lax.scan(
        app_step, free0, xs, unroll=8)
    cpu_free, mem_free = free[:, 0], free[:, 1]

    # scatter scan outputs back: kill positions -> component order, then
    # processing order -> app-index order
    kill_rows = jnp.zeros((A, C), bool).at[ar, order].set(kill_pos)
    kill_app = jnp.zeros((A,), bool).at[a_all].max(removes)
    kill_comp = jnp.zeros((A, C), bool).at[a_all].max(kill_rows)

    survive = (p.comp_exists & p.app_exists[:, None]
               & ~kill_app[:, None] & ~kill_comp)
    alloc_cpu = jnp.where(survive, p.comp_cpu, 0.0)
    alloc_mem = jnp.where(survive, p.comp_mem, 0.0)
    return ShapeDecision(kill_app=kill_app, kill_comp=kill_comp,
                         alloc_cpu=alloc_cpu, alloc_mem=alloc_mem,
                         cpu_free=cpu_free, mem_free=mem_free)


#: jitted entry point (one dispatch per call — the host-loop engines)
pessimistic_shape = jax.jit(pessimistic_shape_raw)
