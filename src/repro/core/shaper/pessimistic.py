"""Pessimistic preemption policy — paper Algorithm 1, in JAX.

Greedy pass over running applications in scheduler-policy order:

  * an application's CORE components are fitted first, host by host; if
    any host would go negative the whole application is marked for FULL
    preemption (paper lines 11-21, 34-36);
  * surviving applications then fit their ELASTIC components one at a
    time, oldest-first (sorted by timeAlive, line 25) — a component that
    does not fit is PARTIALLY preempted on its own (lines 26-33, 37-38);
  * every surviving component is resized to its shaped demand
    (forecast peak + beta, lines 39-41).

Faithfulness notes: core checks use ``< 0`` and elastic checks ``<= 0``
exactly as in the listing; beta is already folded into the demands by
the caller (the listing subtracts ``futureX - beta`` — we precompute
``demand = clip(forecast + beta, 0, request)`` via safeguard.shaped_demand).

The whole policy is a ``lax.scan`` over the (padded, fixed-size) app
table with an inner scan over the component table, so one jitted call
shapes the entire cluster — this is what lets the live framework run the
policy every monitoring tick for thousands of nodes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShapeProblem:
    """Fixed-size cluster state handed to a shaping policy.

    A = max apps, C = max components per app, H = hosts.
    Demands are the shaped targets (forecast + beta) per component.
    """

    host_cpu: Array          # (H,) capacity
    host_mem: Array          # (H,)
    app_exists: Array        # (A,) bool
    app_order: Array         # (A,) int — processing order (policy-sorted),
                             #   entries are app indices; padded with -1
    comp_exists: Array       # (A, C) bool
    comp_core: Array         # (A, C) bool
    comp_host: Array         # (A, C) int32 host index (0 if absent)
    comp_cpu: Array          # (A, C) shaped cpu demand
    comp_mem: Array          # (A, C) shaped mem demand
    comp_alive: Array        # (A, C) seconds alive (elastic sort key)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShapeDecision:
    kill_app: Array          # (A,) bool — full preemption
    kill_comp: Array         # (A, C) bool — partial (elastic) preemption
    alloc_cpu: Array         # (A, C) granted allocation (0 for killed)
    alloc_mem: Array         # (A, C)
    cpu_free: Array          # (H,) remaining after allocation
    mem_free: Array          # (H,)


def _seg_sum(vals: Array, seg: Array, num: int) -> Array:
    return jax.ops.segment_sum(vals, seg, num_segments=num)


@jax.jit
def pessimistic_shape(p: ShapeProblem) -> ShapeDecision:
    A, C = p.comp_exists.shape
    H = p.host_cpu.shape[0]

    # elastic processing order per app: oldest (largest timeAlive) first,
    # so the newest components are the ones that hit exhausted capacity.
    alive_key = jnp.where(p.comp_exists & ~p.comp_core,
                          p.comp_alive, -jnp.inf)
    elastic_order = jnp.argsort(-alive_key, axis=1)          # (A, C)

    def app_step(carry, a):
        cpu_free, mem_free = carry
        valid = (a >= 0) & p.app_exists[jnp.maximum(a, 0)]
        a_ = jnp.maximum(a, 0)
        exists = p.comp_exists[a_]
        core = exists & p.comp_core[a_]
        host = p.comp_host[a_]

        # ---- core components (lines 11-19): aggregate per-host demand ----
        core_cpu = _seg_sum(jnp.where(core, p.comp_cpu[a_], 0.0), host, H)
        core_mem = _seg_sum(jnp.where(core, p.comp_mem[a_], 0.0), host, H)
        trial_cpu = cpu_free - core_cpu
        trial_mem = mem_free - core_mem
        remove = valid & (jnp.any(trial_cpu < 0.0) | jnp.any(trial_mem < 0.0))
        commit_core = valid & ~remove
        cpu_free = jnp.where(commit_core, trial_cpu, cpu_free)
        mem_free = jnp.where(commit_core, trial_mem, mem_free)

        # ---- elastic components (lines 25-33): sequential oldest-first ----
        def comp_step(inner, c_pos):
            cf, mf, kill_row = inner
            c = elastic_order[a_, c_pos]
            is_el = commit_core & exists[c] & ~p.comp_core[a_, c]
            h = host[c]
            tc = cf[h] - p.comp_cpu[a_, c]
            tm = mf[h] - p.comp_mem[a_, c]
            kill_c = is_el & ((tc <= 0.0) | (tm <= 0.0))
            commit = is_el & ~kill_c
            cf = cf.at[h].add(jnp.where(commit, -p.comp_cpu[a_, c], 0.0))
            mf = mf.at[h].add(jnp.where(commit, -p.comp_mem[a_, c], 0.0))
            kill_row = kill_row.at[c].set(kill_c)
            return (cf, mf, kill_row), None

        (cpu_free, mem_free, kill_row), _ = jax.lax.scan(
            comp_step, (cpu_free, mem_free, jnp.zeros((C,), bool)),
            jnp.arange(C))

        out = (a_, remove, kill_row)
        return (cpu_free, mem_free), out

    (cpu_free, mem_free), (idxs, removes, kill_rows) = jax.lax.scan(
        app_step, (p.host_cpu, p.host_mem), p.app_order)

    # scatter scan outputs (ordered by app_order) back to app-index order
    kill_app = jnp.zeros((A,), bool).at[idxs].max(removes)
    kill_comp = jnp.zeros((A, C), bool).at[idxs].max(kill_rows)

    survive = (p.comp_exists & p.app_exists[:, None]
               & ~kill_app[:, None] & ~kill_comp)
    alloc_cpu = jnp.where(survive, p.comp_cpu, 0.0)
    alloc_mem = jnp.where(survive, p.comp_mem, 0.0)
    return ShapeDecision(kill_app=kill_app, kill_comp=kill_comp,
                         alloc_cpu=alloc_cpu, alloc_mem=alloc_mem,
                         cpu_free=cpu_free, mem_free=mem_free)
