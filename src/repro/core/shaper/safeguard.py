"""Safe-guard buffer (paper Eq. 9):  beta = K1 * R_A  +  K2 * V_A.

K1 scales the *static* term — a minimum allocation floor expressed as a
fraction of the original reservation R (K1 = 1.0 degenerates to the
baseline, K1 = 0 removes the floor).  K2 scales the *dynamic* term — the
predictive uncertainty reported by the forecaster.  The paper sweeps
K2 in {0, 1, 2, 3}, "bands around the mean of the predictive Gaussian
distribution, according to the three-sigma rule": i.e. the dynamic term
is K2 predictive *standard deviations* (V in Eq. 9 is the forecaster's
variance estimate; sigma bands are its actionable form).

``conformal`` mode (``SimConfig.calibration``) keeps Eq. 9's shape but
replaces the fixed Gaussian multiplier with a per-series *calibrated*
score quantile from :mod:`repro.core.uncertainty`:  the dynamic term
becomes ``q_hat(q) * sigma`` — a distribution-free upper band whose
coverage tracks the nominal level even where the Gaussian assumption
fails (heavy-tailed or regime-switching workloads).
``shaped_demand_scaled`` is that path: identical math, with the sigma
multiplier supplied per element instead of baked into the config.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.uncertainty.scoring import sigma_from_var

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SafeguardConfig:
    k1: float = 0.05   # paper's best: 5% static floor
    k2: float = 3.0    # paper's best: 3-sigma dynamic band


def beta(request: Array, var: Array, cfg: SafeguardConfig) -> Array:
    """Buffer added on top of the predicted peak utilization.

    request: original reservation (same units as the resource);
    var: forecaster predictive variance (same units squared).
    Broadcasts over any shape (per-component, per-resource).
    """
    return cfg.k1 * request + cfg.k2 * sigma_from_var(var)


def shaped_demand_raw(pred_peak: Array, request: Array, var: Array,
                      cfg: SafeguardConfig) -> Array:
    """Allocation target: forecast peak + beta, clamped into (0, request].

    The clamp to the reservation is the paper's implicit contract: the
    shaper only *redeems* slack, it never grants more than the tenant
    reserved; the floor keeps a crumb allocated so idle components stay
    alive (K1 = 0 with a confident predictor would allocate ~0).
    """
    b = beta(request, var, cfg)
    return jnp.clip(pred_peak + b, 0.0, request)


def shaped_demand_scaled_raw(pred_peak: Array, request: Array, var: Array,
                             k1: Array, scale: Array) -> Array:
    """Eq. 9 with a per-element sigma multiplier (conformal safeguard).

    ``scale`` is the calibrated upper-quantile multiplier ``q_hat`` for
    each series (broadcastable against ``pred_peak``); everything else
    matches :func:`shaped_demand`, including the (0, request] clamp.
    Monotone in ``scale``: a higher target quantile can only allocate
    more, which is what makes the adaptive controller's knob safe.
    """
    b = k1 * request + scale * sigma_from_var(var)
    return jnp.clip(pred_peak + b, 0.0, request)


#: jitted entry points (one dispatch per call — the host-loop engines);
#: the raw bodies above fuse into the scan engine's per-tick program
shaped_demand = partial(jax.jit, static_argnames="cfg")(shaped_demand_raw)
shaped_demand_scaled = jax.jit(shaped_demand_scaled_raw)
