"""Safe-guard buffer (paper Eq. 9):  beta = K1 * R_A  +  K2 * V_A.

K1 scales the *static* term — a minimum allocation floor expressed as a
fraction of the original reservation R (K1 = 1.0 degenerates to the
baseline, K1 = 0 removes the floor).  K2 scales the *dynamic* term — the
predictive uncertainty reported by the forecaster.  The paper sweeps
K2 in {0, 1, 2, 3}, "bands around the mean of the predictive Gaussian
distribution, according to the three-sigma rule": i.e. the dynamic term
is K2 predictive *standard deviations* (V in Eq. 9 is the forecaster's
variance estimate; sigma bands are its actionable form).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SafeguardConfig:
    k1: float = 0.05   # paper's best: 5% static floor
    k2: float = 3.0    # paper's best: 3-sigma dynamic band


def beta(request: Array, var: Array, cfg: SafeguardConfig) -> Array:
    """Buffer added on top of the predicted peak utilization.

    request: original reservation (same units as the resource);
    var: forecaster predictive variance (same units squared).
    Broadcasts over any shape (per-component, per-resource).
    """
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    return cfg.k1 * request + cfg.k2 * sigma


@partial(jax.jit, static_argnames="cfg")
def shaped_demand(pred_peak: Array, request: Array, var: Array,
                  cfg: SafeguardConfig) -> Array:
    """Allocation target: forecast peak + beta, clamped into (0, request].

    The clamp to the reservation is the paper's implicit contract: the
    shaper only *redeems* slack, it never grants more than the tenant
    reserved; the floor keeps a crumb allocated so idle components stay
    alive (K1 = 0 with a confident predictor would allocate ~0).
    """
    b = beta(request, var, cfg)
    return jnp.clip(pred_peak + b, 0.0, request)
