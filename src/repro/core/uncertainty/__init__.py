"""Uncertainty calibration & risk-aware safeguards (paper §3.1 + Eq. 9).

The paper's mechanism is forecasting *with quantified uncertainty* that
modulates allocations; this package makes that uncertainty trustworthy:

  * :mod:`~repro.core.uncertainty.scoring`   — batched, jittable proper
    scoring metrics (coverage vs nominal, pinball, CRPS) plus the one
    shared variance -> sigma clamp;
  * :mod:`~repro.core.uncertainty.conformal` — online split-conformal
    calibration: per-series residual-score ring buffers and the
    distribution-free ``q_hat`` quantile that replaces the Gaussian
    ``K2`` multiplier in Eq. 9;
  * :mod:`~repro.core.uncertainty.adaptive`  — ACI-style controller that
    turns a failure-rate budget into the target quantile set-point;
  * :mod:`~repro.core.uncertainty.online`    — the engine-facing tick
    loop tying forecasts, realized peaks, and calibrated scales together.
"""
from repro.core.uncertainty.adaptive import QuantileController
from repro.core.uncertainty.conformal import (CalibrationConfig,
                                              ConformalForecaster,
                                              ScoreBuffer, conformal_scale,
                                              conformal_scale_ring)
from repro.core.uncertainty.online import (CalibState, OnlineCalibrator,
                                           calib_begin, calib_init,
                                           calib_observe, calib_report,
                                           calib_scales)
from repro.core.uncertainty.scoring import (bucket_pow2, crps_empirical,
                                            crps_gaussian,
                                            empirical_coverage,
                                            gaussian_quantile_scale,
                                            pinball_loss, sigma_from_var,
                                            sigma_from_var_np)

__all__ = [
    "sigma_from_var", "sigma_from_var_np", "bucket_pow2",
    "gaussian_quantile_scale", "empirical_coverage",
    "pinball_loss", "crps_gaussian", "crps_empirical",
    "CalibrationConfig", "conformal_scale", "conformal_scale_ring",
    "ScoreBuffer", "ConformalForecaster", "QuantileController",
    "OnlineCalibrator", "CalibState", "calib_init", "calib_observe",
    "calib_begin", "calib_scales", "calib_report",
]
