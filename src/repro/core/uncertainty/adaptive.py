"""Adaptive target-quantile controller (risk budget as a set-point).

Split-conformal calibration makes the safeguard's coverage match its
*nominal* level q; this controller closes the remaining loop and picks
q itself.  Flex (arXiv:2006.01354) frames reclamation as an explicit
risk budget and ADARES (arXiv:1812.01837) adapts its confidence online;
following adaptive conformal inference (ACI), we servo the level on the
realized miscoverage stream:

    q_{t+1} = clip( q_t + gamma * (err_t - budget), q_min, q_max )

where ``err_t`` is the fraction of freshly resolved predictions whose
realized peak exceeded the deployed upper bound.  Above-budget
miscoverage widens the band (q up), below-budget miscoverage narrows it
(q down) — the failure axis of paper Fig. 3 becomes a configuration
input instead of an experimental outcome.

The controller is deliberately a *fleet-level* scalar: failures are
pooled across series exactly like the paper's failure-rate metric, and
a scalar q keeps the conformal quantile lookup one batched call.
"""
from __future__ import annotations

import numpy as np

from repro.core.uncertainty.conformal import CalibrationConfig

__all__ = ["QuantileController"]


class QuantileController:
    """ACI-style integrator from miscoverage events to the target q."""

    def __init__(self, cfg: CalibrationConfig):
        self.cfg = cfg
        self.q = float(np.clip(cfg.q, cfg.q_min, cfg.q_max))
        self.steps = 0
        self.errors = 0          # miscoverage events seen
        self.resolved = 0        # predictions resolved

    def update(self, errors: np.ndarray) -> float:
        """Fold one tick's resolved miscoverage indicators into q.

        ``errors`` is a boolean array (one entry per prediction resolved
        this tick); empty arrays leave q untouched — no observation, no
        control action.
        """
        n = int(errors.size)
        if n == 0:
            return self.q
        err_rate = float(np.mean(errors))
        self.resolved += n
        self.errors += int(errors.sum())
        self.steps += 1
        self.q = float(np.clip(
            self.q + self.cfg.gamma * (err_rate - self.cfg.budget),
            self.cfg.q_min, self.cfg.q_max))
        return self.q

    @property
    def miscoverage(self) -> float:
        """Lifetime realized miscoverage rate (the budget's read-back)."""
        return self.errors / max(self.resolved, 1)
