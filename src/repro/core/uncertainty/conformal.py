"""Online split-conformal calibration of forecast upper bounds.

The paper's safeguard (Eq. 9) adds ``K2`` predictive *standard
deviations* to the forecast peak — "bands around the mean of the
predictive Gaussian distribution".  That band carries its nominal
coverage only while the residuals really are Gaussian; on heavy-tailed
or regime-switching workloads it under-covers and the failure-rate knob
the paper advertises stops being trustworthy.

Split-conformal calibration fixes this without distributional
assumptions: keep a ring buffer of *nonconformity scores* — here the
sigma-normalized residuals

    s_t = (y_t - mean_t) / sigma_t

— and replace the Gaussian z-multiplier with the empirical
``ceil((n+1) q) / n`` quantile of the recorded scores.  The resulting
``mean + q_hat * sigma`` upper bound inherits the finite-sample
coverage guarantee of conformal prediction (>= q under exchangeability)
while staying *locally adaptive*: sigma still scales the band per
series, the calibration only corrects its overall level.

Layout mirrors the rest of the stack: ring-buffer state is host-side
NumPy (like :class:`repro.core.monitor.Monitor` — feeding it is I/O),
the quantile math is pure JAX, jitted and batched over every series of
a fleet in one padded call (like the forecasters).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.uncertainty.scoring import (bucket_pow2,
                                            gaussian_quantile_scale)

Array = jax.Array

__all__ = ["CalibrationConfig", "conformal_scale", "conformal_scale_ring",
           "ScoreBuffer", "ConformalForecaster"]


@dataclasses.dataclass(frozen=True)
class CalibrationConfig:
    """Conformal-safeguard configuration (``SimConfig.calibration``).

    ``enabled=False`` is the bit-identical legacy path: the safeguard
    stays ``K1*R + K2*sigma`` exactly as Eq. 9.  Enabled, the dynamic
    term becomes ``q_hat(q) * sigma`` with ``q_hat`` the calibrated
    score quantile; ``adaptive=True`` additionally servo-controls the
    target ``q`` so the realized miscoverage tracks ``budget`` (the
    failure axis of paper Fig. 3 becomes a set-point, not an outcome).
    """

    enabled: bool = False
    q: float = 0.9          # target upper-quantile (coverage set-point)
    capacity: int = 128     # per-series score-ring capacity
    min_scores: int = 16    # below this, fall back down the hierarchy
    # hierarchical fallback for young series: sigma-normalized scores are
    # comparable across series, so a fleet-wide pooled quantile (group
    # conformal) beats reverting to the uncalibrated K2 band while a
    # series' own ring warms up.  False = fall straight back to K2.
    pool: bool = True
    pool_capacity: int = 1024
    # per-GROUP score rings — the series -> group -> fleet-pool tier of
    # the fallback hierarchy.  Groups are tenants when the control plane
    # is enabled (``SimConfig.control``): a young series borrows its
    # tenant's pooled quantile before falling back to the fleet pool,
    # so coverage holds per tenant even when tenants' residual
    # distributions differ.  Only allocated when the engine passes
    # ``n_groups > 0``.
    group_capacity: int = 256
    adaptive: bool = False  # tune q online against the failure budget
    budget: float = 0.1     # target miscoverage (failure-rate budget)
    gamma: float = 0.05     # ACI step size for the adaptive controller
    q_min: float = 0.5      # adaptive controller clamp
    q_max: float = 0.995


@jax.jit
def conformal_scale(scores: Array, counts: Array, q: Array,
                    fallback: Array) -> Array:
    """Split-conformal quantile of per-series score rings.

    scores:  (B, capacity) ring contents, newest written last (only the
             trailing ``min(count, capacity)`` cells are live);
    counts:  (B,) total scores ever pushed per series;
    q:       scalar or (B,) target quantile level;
    fallback: scalar or (B,) value returned where a series has no
             scores yet (the K2 sigma-multiplier, in the safeguard).

    Returns (B,) ``q_hat`` — the ``ceil((n+1) q)``-th order statistic
    of the live scores (the finite-sample-corrected conformal quantile;
    when ``(n+1) q > n`` it saturates at the sample maximum, the
    standard bounded-support surrogate for the +inf bound).
    """
    B, cap = scores.shape
    n = jnp.minimum(counts, cap)                              # (B,)
    pos = jnp.arange(cap)[None, :]
    live = pos >= (cap - n)[:, None]
    masked = jnp.where(live, scores, jnp.inf)
    srt = jnp.sort(masked, axis=1)                            # live first
    q = jnp.broadcast_to(jnp.asarray(q, jnp.float32), (B,))
    k = jnp.ceil((n + 1.0) * q).astype(jnp.int32) - 1
    k = jnp.clip(k, 0, jnp.maximum(n - 1, 0))
    val = jnp.take_along_axis(srt, k[:, None], axis=1)[:, 0]
    fallback = jnp.broadcast_to(jnp.asarray(fallback, jnp.float32), (B,))
    return jnp.where(n > 0, val, fallback)


def conformal_scale_ring(scores: Array, counts: Array, q: Array,
                         fallback: Array) -> Array:
    """:func:`conformal_scale` for *circular* rings (scan-engine layout).

    The device-resident calibrator (:mod:`repro.core.uncertainty.online`,
    ``CalibState``) writes scores at ``count % capacity`` instead of
    rolling, and pre-fills unwritten cells with ``+inf`` — so the live
    window is position-independent and no mask is needed: the sort sends
    unwritten cells past every live score, and the order statistic is
    taken over ``n = min(count, capacity)`` exactly as in
    :func:`conformal_scale`.  The live window holds the same multiset of
    scores as a rolled :class:`ScoreBuffer`, hence identical quantiles.

    Unjitted on purpose: this fuses into the scan engine's per-tick
    program (jit at the call site for standalone use).
    """
    B, cap = scores.shape
    n = jnp.minimum(counts, cap)
    srt = jnp.sort(scores, axis=1)
    q = jnp.broadcast_to(jnp.asarray(q, jnp.float32), (B,))
    k = jnp.ceil((n + 1.0) * q).astype(jnp.int32) - 1
    k = jnp.clip(k, 0, jnp.maximum(n - 1, 0))
    val = jnp.take_along_axis(srt, k[:, None], axis=1)[:, 0]
    fallback = jnp.broadcast_to(jnp.asarray(fallback, jnp.float32), (B,))
    return jnp.where(n > 0, val, fallback)


class ScoreBuffer:
    """Per-series nonconformity-score ring buffers (host-side state).

    Same design as :class:`repro.core.monitor.Monitor`: a dense
    ``(series, capacity)`` float32 table rolled on push, so thousands of
    component series share one allocation and ``scales`` runs ONE
    padded jitted quantile over any subset of rows.
    """

    def __init__(self, n_series: int, capacity: int):
        self.capacity = capacity
        self.buf = np.zeros((n_series, capacity), np.float32)
        self.count = np.zeros((n_series,), np.int64)

    def push(self, rows: np.ndarray, scores: np.ndarray) -> None:
        """Append one score for each series in ``rows`` (vectorized).

        Rows must be unique — duplicate indices would collide in the
        fancy-indexed write; use :meth:`push_many` to append several
        scores to ONE series.
        """
        self.buf[rows] = np.roll(self.buf[rows], -1, axis=1)
        self.buf[rows, -1] = scores
        self.count[rows] += 1

    def push_many(self, row: int, scores: np.ndarray) -> None:
        """Append a batch of scores to a single series' ring."""
        k = min(scores.shape[0], self.capacity)
        if k == 0:
            return
        self.buf[row] = np.roll(self.buf[row], -k)
        self.buf[row, -k:] = scores[-k:]
        self.count[row] += scores.shape[0]

    def n(self, rows: np.ndarray) -> np.ndarray:
        return np.minimum(self.count[rows], self.capacity)

    def scales(self, rows: np.ndarray, q, fallback) -> np.ndarray:
        """Calibrated ``q_hat`` per row; ``fallback`` where empty.

        Rows are padded to a power-of-two bucket so the jitted quantile
        kernel compiles O(log n) times per capacity, not per batch size
        (same convention as the engine's forecast path).
        """
        m = rows.shape[0]
        b = bucket_pow2(m)
        spad = np.zeros((b, self.capacity), np.float32)
        cpad = np.zeros((b,), np.int64)
        spad[:m] = self.buf[rows]
        cpad[:m] = self.count[rows]
        qv = np.broadcast_to(np.asarray(q, np.float32), (m,))
        fv = np.broadcast_to(np.asarray(fallback, np.float32), (m,))
        qpad = np.zeros((b,), np.float32)
        fpad = np.zeros((b,), np.float32)
        qpad[:m], fpad[:m] = qv, fv
        out = conformal_scale(jnp.asarray(spad), jnp.asarray(cpad),
                              jnp.asarray(qpad), jnp.asarray(fpad))
        # np.array (not asarray): device output buffers are read-only
        # and callers overwrite the fallback rows in place
        return np.array(out)[:m]


class ConformalForecaster:
    """Wrap any :class:`~repro.core.forecast.base.Forecaster` with
    online split-conformal calibration.

    The wrapper is a streaming loop per series::

        fc = wrapper.forecast(window, horizon, series=i)   # predict
        up = wrapper.upper(fc, series=i)                   # calibrated bound
        ...one tick later...
        wrapper.observe(y_next, series=i)                  # score residual

    ``forecast`` passes through to the base model unchanged (the mean /
    variance stay the paper's §3.1 outputs); ``observe`` scores the
    1-step-ahead prediction against the realized value and feeds the
    ring; ``upper`` replaces the Gaussian ``z(q)`` multiplier with the
    calibrated score quantile once ``min_scores`` have accumulated.
    """

    def __init__(self, base, cfg: CalibrationConfig = CalibrationConfig(),
                 n_series: int = 1):
        self.base = base
        self.cfg = cfg
        self.scores = ScoreBuffer(n_series, cfg.capacity)
        self._pend_mean = np.zeros((n_series,), np.float32)
        self._pend_sigma = np.ones((n_series,), np.float32)
        self._has_pend = np.zeros((n_series,), bool)

    def forecast(self, window, horizon: int, *, series: int = 0,
                 valid=None):
        fc = self.base.forecast(window, horizon, valid=valid)
        self._pend_mean[series] = float(fc.mean[0])
        self._pend_sigma[series] = max(float(fc.sigma[0]), 1e-9)
        self._has_pend[series] = True
        return fc

    def observe(self, y: float, *, series: int = 0) -> float | None:
        """Score the outstanding 1-step prediction; returns the score."""
        if not self._has_pend[series]:
            return None
        s = (float(y) - self._pend_mean[series]) / self._pend_sigma[series]
        self.scores.push(np.asarray([series]), np.asarray([s], np.float32))
        self._has_pend[series] = False
        return s

    def scale(self, *, series: int = 0, q: float | None = None) -> float:
        """Calibrated sigma-multiplier (Gaussian z until ``min_scores``)."""
        q = self.cfg.q if q is None else q
        gauss = float(gaussian_quantile_scale(q))
        rows = np.asarray([series])
        if int(self.scores.n(rows)[0]) < self.cfg.min_scores:
            return gauss
        return float(self.scores.scales(rows, q, gauss)[0])

    def upper(self, fc, *, series: int = 0, q: float | None = None):
        """Distribution-free upper band: mean + q_hat(q) * sigma."""
        return fc.mean + self.scale(series=series, q=q) * fc.sigma
