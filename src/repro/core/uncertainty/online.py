"""Online calibration loop for the simulation engine / live shaper.

Bridges the engine's tick loop and the conformal machinery: every
monitored component series (one per (slot, resource), exactly the rows
of the engine's stacked forecast batch) gets

  * one *outstanding prediction* at a time — the safeguard's deployed
    upper bound ``mean + scale * sigma`` over the forecast horizon;
  * a nonconformity-score ring fed when that prediction resolves.

Because the safeguard protects against the *peak* over the horizon
(paper §4.2), the score compares the realized running maximum over the
next ``horizon`` ticks against the predicted peak:

    s = (max_{k<=h} y_{t+k} - mean_t) / sigma_t

resolved h ticks after the forecast.  Monitor resets (admission,
eviction, preemption) invalidate an outstanding prediction via the
monitor's own sample counter: a resolution is only scored when the
series aged exactly ``horizon`` samples since the forecast, which a
reset makes impossible (counts restart at zero and shaping waits out
the grace period — paper §5).

State is host-side NumPy ring buffers (the Monitor convention); the
quantile evaluation is one padded jitted JAX call per tick via
:class:`~repro.core.uncertainty.conformal.ScoreBuffer`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.uncertainty.adaptive import QuantileController
from repro.core.uncertainty.conformal import (CalibrationConfig, ScoreBuffer,
                                              conformal_scale_ring)

__all__ = ["OnlineCalibrator", "CalibState", "calib_init", "calib_observe",
           "calib_begin", "calib_scales", "calib_report",
           "calib_group_report"]


class OnlineCalibrator:
    """Per-series online split-conformal calibration for the engine.

    ``n_series`` rows follow the engine's forecast-batch layout: CPU
    rows ``0 .. M-1`` then MEM rows ``M .. 2M-1`` where ``M`` is the
    monitor slot count; ``observe`` takes the monitor's per-slot sample
    counts (length ``M``) and tiles them.
    """

    def __init__(self, n_series: int, horizon: int, fallback: float,
                 cfg: CalibrationConfig, *, n_groups: int = 0):
        self.cfg = cfg
        self.horizon = int(horizon)
        self.fallback = float(fallback)
        self.scores = ScoreBuffer(n_series, cfg.capacity)
        # fleet-wide pooled ring: the middle tier of the fallback
        # hierarchy (series ring -> pool -> K2) for young series
        self.pooled = (ScoreBuffer(1, cfg.pool_capacity)
                       if cfg.pool else None)
        # per-GROUP rings (series -> group -> pool -> K2): groups are
        # tenants when the control plane is on.  ``n_groups == 0`` (the
        # default, and every pre-control-plane caller) allocates nothing
        # and keeps behavior identical.
        self.groups = (ScoreBuffer(n_groups, cfg.group_capacity)
                       if n_groups > 0 else None)
        self._group = np.full((n_series,), -1, np.int64)
        self.group_resolved = np.zeros(max(n_groups, 0), np.int64)
        self.group_errors = np.zeros(max(n_groups, 0), np.int64)
        self.controller = QuantileController(cfg) if cfg.adaptive else None
        z = lambda dt: np.zeros((n_series,), dt)  # noqa: E731
        self._mean, self._sigma, self._scale = z(np.float32), z(np.float32), z(np.float32)
        self._peak = z(np.float32)      # running max of realized usage
        self._left = z(np.int64)        # ticks to resolution; 0 = idle
        self._due = z(np.int64)         # expected monitor count at resolution
        # telemetry
        self.resolved = 0
        self.errors = 0
        self.dropped = 0                # invalidated by a series reset
        self._scale_sum = 0.0
        self._scale_n = 0

    # -- target level --------------------------------------------------
    @property
    def q(self) -> float:
        return self.controller.q if self.controller is not None else self.cfg.q

    # -- tick loop ------------------------------------------------------
    def observe(self, usage: np.ndarray, mon_count: np.ndarray) -> None:
        """Advance outstanding predictions with this tick's usage.

        ``usage``: (n_series,) realized utilization (CPU rows then MEM
        rows); ``mon_count``: (M,) monitor sample counts, M = n_series/2.
        Call once per tick, after monitor sampling and before shaping.
        """
        act = self._left > 0
        if not act.any():
            return
        np.maximum(self._peak, usage, where=act, out=self._peak)
        self._left[act] -= 1
        fire = act & (self._left == 0)
        if not fire.any():
            return
        counts = np.concatenate([mon_count, mon_count])
        ok = fire & (counts == self._due)
        self.dropped += int(fire.sum() - ok.sum())
        rows = np.nonzero(ok)[0]
        if rows.size == 0:
            return
        sig = np.maximum(self._sigma[rows], 1e-6)
        s = (self._peak[rows] - self._mean[rows]) / sig
        self.scores.push(rows, s.astype(np.float32))
        if self.pooled is not None:
            self.pooled.push_many(0, s.astype(np.float32))
        err = self._peak[rows] > (self._mean[rows]
                                  + self._scale[rows] * self._sigma[rows])
        if self.groups is not None:
            g = self._group[rows]
            valid = g >= 0
            for gg in np.unique(g[valid]):
                self.groups.push_many(int(gg),
                                      s[g == gg].astype(np.float32))
            np.add.at(self.group_resolved, g[valid], 1)
            np.add.at(self.group_errors, g[valid], err[valid])
        self.resolved += rows.size
        self.errors += int(err.sum())
        if self.controller is not None:
            self.controller.update(err)

    def begin(self, rows: np.ndarray, mean: np.ndarray, sigma: np.ndarray,
              scale: np.ndarray, mon_count: np.ndarray,
              groups: np.ndarray | None = None) -> None:
        """Register deployed predictions for ``rows`` (batch layout).

        Rows with an outstanding prediction keep it — calibration
        samples the forecast stream at horizon stride instead of scoring
        overlapping horizons (which would double-count excursions).
        ``mon_count``: per-ROW monitor counts (already gathered);
        ``groups``: per-ROW group (tenant) ids, recorded at deploy time
        so the resolution credits the tenant that owned the slot when
        the bound shipped.
        """
        free = self._left[rows] == 0
        r = rows[free]
        if r.size == 0:
            return
        self._mean[r] = mean[free]
        self._sigma[r] = sigma[free]
        self._scale[r] = scale[free]
        self._peak[r] = -np.inf
        self._left[r] = self.horizon
        self._due[r] = mon_count[free] + self.horizon
        if self.groups is not None and groups is not None:
            self._group[r] = groups[free]

    def scales(self, rows: np.ndarray, groups: np.ndarray | None = None,
               q: np.ndarray | float | None = None) -> np.ndarray:
        """Calibrated sigma-multipliers for ``rows``.

        Hierarchy: the series' own score quantile once ``min_scores``
        accumulated; else the row's GROUP quantile (when group rings
        exist, ``groups`` maps rows to them, and that group is warm);
        else the fleet-wide pooled quantile (if enabled and itself
        warm); else the uncalibrated K2 fallback.  ``q`` overrides the
        target level per row (the control plane's credit-modulated
        quantile); default is the fleet set-point.
        """
        qv = self.q if q is None else q
        out = self.scores.scales(rows, qv, self.fallback)
        young = self.scores.n(rows) < self.cfg.min_scores
        if young.any():
            fb = self.fallback
            if (self.pooled is not None
                    and int(self.pooled.n(np.asarray([0]))[0])
                    >= self.cfg.min_scores):
                fb = float(self.pooled.scales(np.asarray([0]), self.q,
                                              self.fallback)[0])
            fbv = np.full(rows.shape[0], fb, np.float32)
            if self.groups is not None and groups is not None:
                gc = np.maximum(groups, 0)
                warm = ((groups >= 0)
                        & (self.groups.n(gc) >= self.cfg.min_scores))
                gq = self.groups.scales(gc, qv, fbv)
                fbv = np.where(warm, gq, fbv)
            out[young] = fbv[young]
        self._scale_sum += float(out.sum())
        self._scale_n += rows.size
        return out

    # -- telemetry ------------------------------------------------------
    def report(self) -> dict:
        """JSON-ready summary block (``SimResults.calibration``)."""
        live = np.minimum(self.scores.count, self.scores.capacity)
        return {
            "q_target": round(float(self.q), 4),
            "q_initial": self.cfg.q,
            "adaptive": bool(self.cfg.adaptive),
            "budget": self.cfg.budget,
            "resolved": int(self.resolved),
            "miscovered": int(self.errors),
            "coverage": (round(1.0 - self.errors / self.resolved, 4)
                         if self.resolved else None),
            "dropped": int(self.dropped),
            "scores_recorded": int(self.scores.count.sum()),
            "series_warm": int((live >= self.cfg.min_scores).sum()),
            "pool_warm": bool(
                self.pooled is not None
                and int(self.pooled.n(np.asarray([0]))[0])
                >= self.cfg.min_scores),
            "mean_scale": (round(self._scale_sum / self._scale_n, 4)
                           if self._scale_n else None),
        }

    def group_report(self) -> dict | None:
        """Per-group (tenant) resolution/coverage block, or None."""
        if self.groups is None:
            return None
        res = self.group_resolved
        err = self.group_errors
        live = np.minimum(self.groups.count, self.groups.capacity)
        cov = [(round(1.0 - e / r, 4) if r else None)
               for r, e in zip(res.tolist(), err.tolist())]
        return {
            "resolved": res.tolist(),
            "miscovered": err.tolist(),
            "coverage": cov,
            "warm": (live >= self.cfg.min_scores).astype(int).tolist(),
        }


# ----------------------------------------------------------------------
# device-resident calibrator (the scan engine's twin of OnlineCalibrator)
# ----------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CalibState:
    """Calibration state as a pytree of device arrays.

    Functional twin of :class:`OnlineCalibrator` for the fused scan
    engine (:mod:`repro.sim.step`): score rings live on device next to
    the slot table, and ``calib_observe`` / ``calib_begin`` /
    ``calib_scales`` fuse into the per-tick program instead of
    round-tripping through host NumPy.  Rings are *circular* (write at
    ``count % capacity``, unwritten cells ``+inf``) rather than rolled —
    the live window holds the same multiset of scores, so
    :func:`~repro.core.uncertainty.conformal.conformal_scale_ring`
    returns the same quantiles as the host path.

    Rows follow the engine's forecast-batch layout: CPU rows
    ``0 .. M-1`` then MEM rows ``M .. 2M-1`` (``M`` = monitor slots).
    """

    ring: jax.Array        # (S, capacity) f32, unwritten cells +inf
    ring_count: jax.Array  # (S,) i32 total scores ever pushed per series
    pool: jax.Array        # (pool_capacity,) f32 fleet-pooled ring
    pool_count: jax.Array  # () i32
    # one outstanding prediction per series (horizon-stride sampling)
    mean: jax.Array        # (S,) f32
    sigma: jax.Array       # (S,) f32
    scale: jax.Array       # (S,) f32 deployed sigma-multiplier
    peak: jax.Array        # (S,) f32 running max of realized usage
    left: jax.Array        # (S,) i32 ticks to resolution; 0 = idle
    due: jax.Array         # (S,) i32 expected monitor count at resolution
    # adaptive controller set-point + telemetry counters
    q: jax.Array           # () f32
    resolved: jax.Array    # () i32
    errors: jax.Array      # () i32
    dropped: jax.Array     # () i32 invalidated by a series reset
    scale_sum: jax.Array   # () f32
    scale_n: jax.Array     # () i32
    # per-GROUP (tenant) tier — ``None`` when the engine runs without
    # the control plane, so the pytree STRUCTURE (and hence every
    # compiled program) stays identical to the pre-tenancy layout
    group_ring: jax.Array | None = None      # (G, group_capacity) f32
    group_count: jax.Array | None = None     # (G,) i32
    group: jax.Array | None = None           # (S,) i32 deploy group, -1 idle
    group_resolved: jax.Array | None = None  # (G,) i32
    group_errors: jax.Array | None = None    # (G,) i32


def calib_init(n_series: int, cfg: CalibrationConfig,
               batch: int | None = None, n_groups: int = 0) -> CalibState:
    """Fresh device calibration state for ``n_series`` rows.

    ``batch`` prepends a seed-cohort axis (see ``state.init_state``);
    ``n_groups > 0`` allocates the per-group (tenant) score tier."""
    B = () if batch is None else (batch,)
    z = lambda dt: jnp.zeros(B + (n_series,), dt)  # noqa: E731
    s = lambda dt: jnp.zeros(B, dt)                # noqa: E731
    q0 = float(np.clip(cfg.q, cfg.q_min, cfg.q_max)
               if cfg.adaptive else cfg.q)
    kw = {}
    if n_groups > 0:
        kw = dict(
            group_ring=jnp.full(B + (n_groups, cfg.group_capacity),
                                jnp.inf, jnp.float32),
            group_count=jnp.zeros(B + (n_groups,), jnp.int32),
            group=jnp.full(B + (n_series,), -1, jnp.int32),
            group_resolved=jnp.zeros(B + (n_groups,), jnp.int32),
            group_errors=jnp.zeros(B + (n_groups,), jnp.int32))
    return CalibState(
        ring=jnp.full(B + (n_series, cfg.capacity), jnp.inf, jnp.float32),
        ring_count=z(jnp.int32),
        pool=jnp.full(B + (cfg.pool_capacity,), jnp.inf, jnp.float32),
        pool_count=s(jnp.int32),
        mean=z(jnp.float32), sigma=z(jnp.float32), scale=z(jnp.float32),
        peak=z(jnp.float32), left=z(jnp.int32), due=z(jnp.int32),
        q=jnp.full(B, q0, jnp.float32),
        resolved=s(jnp.int32), errors=s(jnp.int32), dropped=s(jnp.int32),
        scale_sum=s(jnp.float32), scale_n=s(jnp.int32), **kw)


def calib_observe(st: CalibState, usage: jax.Array, mon_count: jax.Array,
                  cfg: CalibrationConfig,
                  active: jax.Array | bool = True) -> CalibState:
    """Advance outstanding predictions with this tick's usage (pure).

    ``usage``: (S,) realized utilization (CPU rows then MEM rows);
    ``mon_count``: (S,) per-ROW monitor sample counts (already tiled).
    Mirrors :meth:`OnlineCalibrator.observe`: a resolution only scores
    when the series aged exactly ``horizon`` samples since the forecast
    (a monitor reset makes the count mismatch and the score drops).

    ``active`` gates the whole update: outstanding predictions may
    outlive the last app, so the scan engine's post-completion padding
    ticks must not age them (chunk invariance).
    """
    S, cap = st.ring.shape
    act = (st.left > 0) & active
    peak = jnp.where(act, jnp.maximum(st.peak, usage), st.peak)
    left = st.left - act.astype(st.left.dtype)
    fire = act & (left == 0)
    ok = fire & (mon_count.astype(st.due.dtype) == st.due)
    dropped = st.dropped + (fire & ~ok).sum().astype(st.dropped.dtype)

    sig = jnp.maximum(st.sigma, 1e-6)
    s = ((peak - st.mean) / sig).astype(jnp.float32)

    # per-series ring: circular write at count % capacity where resolved
    rows = jnp.arange(S)
    pos = st.ring_count % cap
    cur = st.ring[rows, pos]
    ring = st.ring.at[rows, pos].set(jnp.where(ok, s, cur))
    ring_count = st.ring_count + ok.astype(st.ring_count.dtype)

    # fleet pool: scatter this tick's resolved scores in row order (the
    # host path's push_many order); non-resolved rows write to a dummy
    # slot past the ring, which is sliced off.  When MORE than
    # pool_capacity scores resolve in one tick, only the LAST capacity
    # of them write (exactly ``push_many``'s ``scores[-k:]``) — without
    # the cut the wrapped positions would collide and XLA scatter makes
    # no ordering promise for duplicate indices, which would break the
    # scan engine's bit-identity contracts
    pool, pool_count = st.pool, st.pool_count
    if cfg.pool:
        pcap = st.pool.shape[0]
        k = jnp.cumsum(ok) - 1
        n_ok = ok.sum()
        write = ok & (k >= n_ok - pcap)
        ppos = jnp.where(write, (st.pool_count + k) % pcap, pcap)
        padded = jnp.concatenate([st.pool, jnp.full((1,), jnp.inf,
                                                    jnp.float32)])
        pool = padded.at[ppos].set(jnp.where(write, s, jnp.inf))[:pcap]
        pool_count = st.pool_count + n_ok.astype(st.pool_count.dtype)

    err = ok & (peak > st.mean + st.scale * st.sigma)
    n_ok = ok.sum()
    resolved = st.resolved + n_ok.astype(st.resolved.dtype)
    errors = st.errors + err.sum().astype(st.errors.dtype)

    # per-group rings: same circular scatter as the pool, but positions
    # are ranked WITHIN each group (row order, the host path's
    # per-group push_many order) and offset into a flattened (G, gcap)
    # table; the per-group keep-last-gcap cut prevents duplicate
    # scatter indices exactly as above
    gr, gcnt = st.group_ring, st.group_count
    g_res, g_err = st.group_resolved, st.group_errors
    if gr is not None:
        G, gcap = gr.shape
        g = st.group
        gok = ok & (g >= 0)
        gc = jnp.maximum(g, 0)
        oh = gok[:, None] & (g[:, None] == jnp.arange(G)[None, :])
        rank = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(S), gc]
        ng = oh.sum(axis=0)
        write = gok & (rank >= ng[gc] - gcap)
        pos = (gcnt[gc] + rank) % gcap
        idx = jnp.where(write, gc * gcap + pos, G * gcap)
        padded = jnp.concatenate([gr.reshape(-1),
                                  jnp.full((1,), jnp.inf, jnp.float32)])
        gr = padded.at[idx].set(
            jnp.where(write, s, jnp.inf))[:G * gcap].reshape(G, gcap)
        gcnt = gcnt + ng.astype(gcnt.dtype)
        g_res = g_res + ng.astype(g_res.dtype)
        g_err = g_err + (oh & err[:, None]).sum(axis=0).astype(g_err.dtype)

    q = st.q
    if cfg.adaptive:
        err_rate = err.sum() / jnp.maximum(n_ok, 1).astype(jnp.float32)
        q_new = jnp.clip(st.q + cfg.gamma * (err_rate - cfg.budget),
                         cfg.q_min, cfg.q_max)
        q = jnp.where(n_ok > 0, q_new, st.q)

    return dataclasses.replace(
        st, ring=ring, ring_count=ring_count, pool=pool,
        pool_count=pool_count, peak=peak, left=left, q=q,
        resolved=resolved, errors=errors, dropped=dropped,
        group_ring=gr, group_count=gcnt,
        group_resolved=g_res, group_errors=g_err)


def calib_begin(st: CalibState, deploy: jax.Array, mean: jax.Array,
                sigma: jax.Array, scale: jax.Array, mon_count: jax.Array,
                horizon: int,
                groups: jax.Array | None = None) -> CalibState:
    """Register deployed predictions where ``deploy`` (pure, all-rows).

    Rows with an outstanding prediction keep it (horizon-stride
    sampling, exactly :meth:`OnlineCalibrator.begin`); the mean-scale
    telemetry accumulates over every deployed row like the host path's
    ``scales()`` accounting.  ``groups``: per-row group (tenant) ids
    recorded at deploy time, mirroring ``OnlineCalibrator.begin``.
    """
    m = deploy & (st.left == 0)
    dt = st.left.dtype
    extra = {}
    if st.group is not None and groups is not None:
        extra["group"] = jnp.where(m, groups.astype(st.group.dtype),
                                   st.group)
    return dataclasses.replace(
        st,
        mean=jnp.where(m, mean, st.mean),
        sigma=jnp.where(m, sigma, st.sigma),
        scale=jnp.where(m, scale, st.scale),
        peak=jnp.where(m, -jnp.inf, st.peak),
        left=jnp.where(m, jnp.int32(horizon), st.left).astype(dt),
        due=jnp.where(m, mon_count.astype(dt) + horizon, st.due).astype(dt),
        scale_sum=st.scale_sum + jnp.where(deploy, scale, 0.0).sum(),
        scale_n=st.scale_n + deploy.sum().astype(st.scale_n.dtype),
        **extra)


def calib_scales(st: CalibState, cfg: CalibrationConfig,
                 fallback: float, groups: jax.Array | None = None,
                 q_rows: jax.Array | None = None,
                 q_groups: jax.Array | None = None) -> jax.Array:
    """(S,) calibrated sigma-multipliers.

    Hierarchy: series -> group -> pool -> K2, exactly
    :meth:`OnlineCalibrator.scales`.  ``groups`` maps rows to group
    rings (current slot occupant's tenant); ``q_rows`` overrides the
    per-row target level and ``q_groups`` the per-group one (the
    control plane's credit-modulated quantiles) — both default to the
    fleet set-point ``st.q``.
    """
    q = st.q if q_rows is None else q_rows
    out = conformal_scale_ring(st.ring, st.ring_count, q,
                               jnp.float32(fallback))
    young = jnp.minimum(st.ring_count, st.ring.shape[1]) < cfg.min_scores
    fb = jnp.float32(fallback)
    if cfg.pool:
        pool_n = jnp.minimum(st.pool_count, st.pool.shape[0])
        pool_q = conformal_scale_ring(st.pool[None, :],
                                      st.pool_count[None], st.q,
                                      jnp.float32(fallback))[0]
        fb = jnp.where(pool_n >= cfg.min_scores, pool_q, fb)
    fb_rows = jnp.broadcast_to(fb, out.shape)
    if st.group_ring is not None and groups is not None:
        gcap = st.group_ring.shape[1]
        qg = st.q if q_groups is None else q_groups
        gq = conformal_scale_ring(st.group_ring, st.group_count, qg, fb)
        gc = jnp.maximum(groups, 0)
        warm = ((groups >= 0)
                & (jnp.minimum(st.group_count, gcap)[gc]
                   >= cfg.min_scores))
        fb_rows = jnp.where(warm, gq[gc], fb_rows)
    return jnp.where(young, fb_rows, out)


def calib_report(st: CalibState, cfg: CalibrationConfig) -> dict:
    """Drain a device CalibState into the JSON telemetry block (host).

    Same schema as :meth:`OnlineCalibrator.report`.
    """
    ring_count = np.asarray(st.ring_count)
    live = np.minimum(ring_count, st.ring.shape[1])
    resolved = int(st.resolved)
    errors = int(st.errors)
    scale_n = int(st.scale_n)
    return {
        "q_target": round(float(st.q), 4),
        "q_initial": cfg.q,
        "adaptive": bool(cfg.adaptive),
        "budget": cfg.budget,
        "resolved": resolved,
        "miscovered": errors,
        "coverage": (round(1.0 - errors / resolved, 4) if resolved
                     else None),
        "dropped": int(st.dropped),
        "scores_recorded": int(ring_count.sum()),
        "series_warm": int((live >= cfg.min_scores).sum()),
        "pool_warm": bool(cfg.pool
                          and int(np.minimum(np.asarray(st.pool_count),
                                             st.pool.shape[0]))
                          >= cfg.min_scores),
        "mean_scale": (round(float(st.scale_sum) / scale_n, 4)
                       if scale_n else None),
    }


def calib_group_report(st: CalibState, cfg: CalibrationConfig) -> dict | None:
    """Per-group (tenant) block; same schema as
    :meth:`OnlineCalibrator.group_report`."""
    if st.group_ring is None:
        return None
    res = np.asarray(st.group_resolved)
    err = np.asarray(st.group_errors)
    live = np.minimum(np.asarray(st.group_count), st.group_ring.shape[1])
    cov = [(round(1.0 - e / r, 4) if r else None)
           for r, e in zip(res.tolist(), err.tolist())]
    return {
        "resolved": res.tolist(),
        "miscovered": err.tolist(),
        "coverage": cov,
        "warm": (live >= cfg.min_scores).astype(int).tolist(),
    }
