"""Online calibration loop for the simulation engine / live shaper.

Bridges the engine's tick loop and the conformal machinery: every
monitored component series (one per (slot, resource), exactly the rows
of the engine's stacked forecast batch) gets

  * one *outstanding prediction* at a time — the safeguard's deployed
    upper bound ``mean + scale * sigma`` over the forecast horizon;
  * a nonconformity-score ring fed when that prediction resolves.

Because the safeguard protects against the *peak* over the horizon
(paper §4.2), the score compares the realized running maximum over the
next ``horizon`` ticks against the predicted peak:

    s = (max_{k<=h} y_{t+k} - mean_t) / sigma_t

resolved h ticks after the forecast.  Monitor resets (admission,
eviction, preemption) invalidate an outstanding prediction via the
monitor's own sample counter: a resolution is only scored when the
series aged exactly ``horizon`` samples since the forecast, which a
reset makes impossible (counts restart at zero and shaping waits out
the grace period — paper §5).

State is host-side NumPy ring buffers (the Monitor convention); the
quantile evaluation is one padded jitted JAX call per tick via
:class:`~repro.core.uncertainty.conformal.ScoreBuffer`.
"""
from __future__ import annotations

import numpy as np

from repro.core.uncertainty.adaptive import QuantileController
from repro.core.uncertainty.conformal import CalibrationConfig, ScoreBuffer

__all__ = ["OnlineCalibrator"]


class OnlineCalibrator:
    """Per-series online split-conformal calibration for the engine.

    ``n_series`` rows follow the engine's forecast-batch layout: CPU
    rows ``0 .. M-1`` then MEM rows ``M .. 2M-1`` where ``M`` is the
    monitor slot count; ``observe`` takes the monitor's per-slot sample
    counts (length ``M``) and tiles them.
    """

    def __init__(self, n_series: int, horizon: int, fallback: float,
                 cfg: CalibrationConfig):
        self.cfg = cfg
        self.horizon = int(horizon)
        self.fallback = float(fallback)
        self.scores = ScoreBuffer(n_series, cfg.capacity)
        # fleet-wide pooled ring: the middle tier of the fallback
        # hierarchy (series ring -> pool -> K2) for young series
        self.pooled = (ScoreBuffer(1, cfg.pool_capacity)
                       if cfg.pool else None)
        self.controller = QuantileController(cfg) if cfg.adaptive else None
        z = lambda dt: np.zeros((n_series,), dt)  # noqa: E731
        self._mean, self._sigma, self._scale = z(np.float32), z(np.float32), z(np.float32)
        self._peak = z(np.float32)      # running max of realized usage
        self._left = z(np.int64)        # ticks to resolution; 0 = idle
        self._due = z(np.int64)         # expected monitor count at resolution
        # telemetry
        self.resolved = 0
        self.errors = 0
        self.dropped = 0                # invalidated by a series reset
        self._scale_sum = 0.0
        self._scale_n = 0

    # -- target level --------------------------------------------------
    @property
    def q(self) -> float:
        return self.controller.q if self.controller is not None else self.cfg.q

    # -- tick loop ------------------------------------------------------
    def observe(self, usage: np.ndarray, mon_count: np.ndarray) -> None:
        """Advance outstanding predictions with this tick's usage.

        ``usage``: (n_series,) realized utilization (CPU rows then MEM
        rows); ``mon_count``: (M,) monitor sample counts, M = n_series/2.
        Call once per tick, after monitor sampling and before shaping.
        """
        act = self._left > 0
        if not act.any():
            return
        np.maximum(self._peak, usage, where=act, out=self._peak)
        self._left[act] -= 1
        fire = act & (self._left == 0)
        if not fire.any():
            return
        counts = np.concatenate([mon_count, mon_count])
        ok = fire & (counts == self._due)
        self.dropped += int(fire.sum() - ok.sum())
        rows = np.nonzero(ok)[0]
        if rows.size == 0:
            return
        sig = np.maximum(self._sigma[rows], 1e-6)
        s = (self._peak[rows] - self._mean[rows]) / sig
        self.scores.push(rows, s.astype(np.float32))
        if self.pooled is not None:
            self.pooled.push_many(0, s.astype(np.float32))
        err = self._peak[rows] > (self._mean[rows]
                                  + self._scale[rows] * self._sigma[rows])
        self.resolved += rows.size
        self.errors += int(err.sum())
        if self.controller is not None:
            self.controller.update(err)

    def begin(self, rows: np.ndarray, mean: np.ndarray, sigma: np.ndarray,
              scale: np.ndarray, mon_count: np.ndarray) -> None:
        """Register deployed predictions for ``rows`` (batch layout).

        Rows with an outstanding prediction keep it — calibration
        samples the forecast stream at horizon stride instead of scoring
        overlapping horizons (which would double-count excursions).
        ``mon_count``: per-ROW monitor counts (already gathered).
        """
        free = self._left[rows] == 0
        r = rows[free]
        if r.size == 0:
            return
        self._mean[r] = mean[free]
        self._sigma[r] = sigma[free]
        self._scale[r] = scale[free]
        self._peak[r] = -np.inf
        self._left[r] = self.horizon
        self._due[r] = mon_count[free] + self.horizon

    def scales(self, rows: np.ndarray) -> np.ndarray:
        """Calibrated sigma-multipliers for ``rows``.

        Hierarchy: the series' own score quantile once ``min_scores``
        accumulated; else the fleet-wide pooled quantile (if enabled and
        itself warm); else the uncalibrated K2 fallback.
        """
        out = self.scores.scales(rows, self.q, self.fallback)
        young = self.scores.n(rows) < self.cfg.min_scores
        if young.any():
            fb = self.fallback
            if (self.pooled is not None
                    and int(self.pooled.n(np.asarray([0]))[0])
                    >= self.cfg.min_scores):
                fb = float(self.pooled.scales(np.asarray([0]), self.q,
                                              self.fallback)[0])
            out[young] = fb
        self._scale_sum += float(out.sum())
        self._scale_n += rows.size
        return out

    # -- telemetry ------------------------------------------------------
    def report(self) -> dict:
        """JSON-ready summary block (``SimResults.calibration``)."""
        live = np.minimum(self.scores.count, self.scores.capacity)
        return {
            "q_target": round(float(self.q), 4),
            "q_initial": self.cfg.q,
            "adaptive": bool(self.cfg.adaptive),
            "budget": self.cfg.budget,
            "resolved": int(self.resolved),
            "miscovered": int(self.errors),
            "coverage": (round(1.0 - self.errors / self.resolved, 4)
                         if self.resolved else None),
            "dropped": int(self.dropped),
            "scores_recorded": int(self.scores.count.sum()),
            "series_warm": int((live >= self.cfg.min_scores).sum()),
            "pool_warm": bool(
                self.pooled is not None
                and int(self.pooled.n(np.asarray([0]))[0])
                >= self.cfg.min_scores),
            "mean_scale": (round(self._scale_sum / self._scale_n, 4)
                           if self._scale_n else None),
        }
