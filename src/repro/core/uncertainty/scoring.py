"""Proper-scoring metrics for predictive distributions (paper §3.1).

The paper's forecasters emit a predictive mean + *variance* (Eq. 8 for
the GP, the psi-weight MSE identity for ARIMA) and the safeguard buffer
(Eq. 9) turns that variance into an actionable band.  Whether the band
is *trustworthy* is a calibration question, and these are the standard
instruments for answering it:

  * ``empirical_coverage``  — fraction of outcomes under a predicted
    upper bound (compare against the nominal quantile level);
  * ``pinball_loss``        — the proper scoring rule for a single
    quantile (minimized in expectation by the true quantile);
  * ``crps_gaussian``       — closed-form CRPS of a Gaussian predictive
    distribution (the paper's §3.1 distributional assumption);
  * ``crps_empirical``      — sample-based CRPS for distribution-free
    predictive ensembles (what conformal calibration produces).

Everything is pure ``jnp``, elementwise/reduction only — jittable and
``vmap``-batchable over fleets of series, like the forecasters.
``sigma_from_var`` is the ONE place predictive variance becomes a
standard deviation (the clamp used to be copy-pasted across
``forecast/base.py`` and ``shaper/safeguard.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import ndtri

Array = jax.Array

__all__ = ["sigma_from_var", "sigma_from_var_np", "bucket_pow2",
           "gaussian_quantile_scale", "empirical_coverage", "pinball_loss",
           "crps_gaussian", "crps_empirical"]


def sigma_from_var(var: Array) -> Array:
    """Predictive standard deviation from predictive variance.

    Forecaster variances can round to tiny negatives under float32
    accumulation; the clamp keeps sigma well-defined without inflating
    honest zero-variance (oracle) forecasts.
    """
    return jnp.sqrt(jnp.maximum(var, 0.0))


def sigma_from_var_np(var: np.ndarray) -> np.ndarray:
    """Host-side (NumPy) twin of :func:`sigma_from_var` — same clamp
    semantics, no device round-trip, for the engines' tick loops."""
    return np.sqrt(np.maximum(var, 0.0))


def bucket_pow2(n: int, base: int = 64) -> int:
    """Smallest power-of-two batch bucket >= n (never below ``base``).

    The shared padding convention of every jitted batch path (forecast
    peaks, shaped demand, conformal quantiles): padding to buckets keeps
    each kernel at O(log n) compilations per shape family instead of one
    per distinct tick batch size.
    """
    b = base
    while b < n:
        b *= 2
    return b


def gaussian_quantile_scale(q) -> Array:
    """z such that  mean + z * sigma  is the Gaussian q-quantile.

    This is the sigma-multiplier a *distributional* K2 corresponds to:
    K2 = gaussian_quantile_scale(q) assumes the predictive residuals
    are Gaussian — the assumption conformal calibration removes.
    """
    return ndtri(jnp.asarray(q, jnp.float32))


def empirical_coverage(y: Array, upper: Array,
                       where: Array | None = None) -> Array:
    """Fraction of outcomes ``y <= upper`` (scalar in [0, 1]).

    Compare against the nominal quantile level: a q = 0.9 upper bound
    is calibrated iff coverage ~= 0.9.  ``where`` masks invalid rows.
    """
    hit = (y <= upper).astype(jnp.float32)
    if where is None:
        return hit.mean()
    w = where.astype(jnp.float32)
    return (hit * w).sum() / jnp.maximum(w.sum(), 1.0)


def pinball_loss(y: Array, pred_q: Array, q) -> Array:
    """Mean pinball (quantile) loss of predicted q-quantiles ``pred_q``.

    rho_q(u) = u * (q - 1[u < 0]),  u = y - pred_q.  A proper scoring
    rule: the expected loss is minimized by the true q-quantile, so a
    lower value means a better-placed band at the SAME nominal level.
    """
    q = jnp.asarray(q, jnp.float32)
    u = y - pred_q
    return jnp.mean(jnp.maximum(q * u, (q - 1.0) * u))


def crps_gaussian(y: Array, mean: Array, var: Array) -> Array:
    """Closed-form CRPS of N(mean, var) predictions, averaged over y.

    CRPS(N(m, s^2), y) = s * (z (2 Phi(z) - 1) + 2 phi(z) - 1/sqrt(pi)),
    z = (y - m) / s.  Strictly proper: it rewards both sharpness and
    calibration, which is why the calibration bench reports it next to
    coverage (coverage alone can be gamed by arbitrarily wide bands).
    """
    sigma = jnp.maximum(sigma_from_var(var), 1e-9)
    z = (y - mean) / sigma
    phi = jnp.exp(-0.5 * z * z) / jnp.sqrt(2.0 * jnp.pi)
    cdf = 0.5 * (1.0 + jax.scipy.special.erf(z / jnp.sqrt(2.0)))
    return jnp.mean(sigma * (z * (2.0 * cdf - 1.0) + 2.0 * phi
                             - 1.0 / jnp.sqrt(jnp.pi)))


def crps_empirical(y: Array, samples: Array) -> Array:
    """Sample-based CRPS, averaged over y.

    ``samples`` is (n_samples,) or (batch, n_samples) — an ensemble
    representing the predictive distribution (e.g. mean + sigma *
    calibrated score quantiles).  Uses the energy form
    CRPS = E|X - y| - 0.5 E|X - X'|, exact for the empirical CDF.
    """
    if samples.ndim == 1:
        samples = jnp.broadcast_to(samples, (y.shape[0], samples.shape[0]))
    term1 = jnp.abs(samples - y[:, None]).mean(axis=1)
    term2 = jnp.abs(samples[:, :, None] - samples[:, None, :]).mean((1, 2))
    return jnp.mean(term1 - 0.5 * term2)
