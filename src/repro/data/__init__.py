"""Data pipeline: deterministic synthetic token streams, host-sharded."""
from repro.data.pipeline import DataConfig, SyntheticStream, make_batch_specs

__all__ = ["DataConfig", "SyntheticStream", "make_batch_specs"]
