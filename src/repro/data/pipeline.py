"""Deterministic synthetic token pipeline.

Offline-container substitute for a real corpus loader, engineered like
one: per-step batches are a pure function of (seed, step) so every data-
parallel host can materialize ITS OWN shard without coordination — the
property a 1000-node loader needs anyway (no central dispenser, restart
at step k reproduces the stream).  Tokens follow a Zipfian unigram draw
with short Markov repetitions so the LM loss actually decreases during
the example runs (pure uniform noise would pin loss at log V).

``prefetch`` wraps the stream with a background thread + device_put,
overlapping host generation with device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    repeat_p: float = 0.35     # Markov copy-previous prob (gives structure)


class SyntheticStream:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # truncated Zipf table
        ranks = np.arange(1, min(cfg.vocab, 65536) + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.p = (p / p.sum()).astype(np.float64)
        self.support = len(ranks)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % 2**31)
        B, S = cfg.global_batch, cfg.seq_len
        base = rng.choice(self.support, size=(B, S + 1), p=self.p)
        rep = rng.rand(B, S + 1) < cfg.repeat_p
        toks = base.copy()
        for j in range(1, S + 1):          # cheap Markov structure
            toks[:, j] = np.where(rep[:, j], toks[:, j - 1], toks[:, j])
        toks = toks.astype(np.int32) % cfg.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def prefetch(self, steps: int, put_fn=None, depth: int = 2):
        """Background-thread prefetch generator."""
        q: queue.Queue = queue.Queue(maxsize=depth)

        def worker():
            for s in range(steps):
                b = self.batch(s)
                q.put(put_fn(b) if put_fn else b)
            q.put(None)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is None:
                return
            yield item


def make_batch_specs(cfg: DataConfig) -> dict[str, jax.ShapeDtypeStruct]:
    B, S = cfg.global_batch, cfg.seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
