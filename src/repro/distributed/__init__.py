"""Distribution substrate: sharding rules, elastic re-mesh, fault
tolerance, gradient compression, pipeline parallelism."""
from repro.distributed import sharding

__all__ = ["sharding"]
