"""Gradient compression: int8 quantized all-reduce with error feedback.

For the inter-pod hop of the hierarchical DP reduction: DCI bandwidth is
the scarcest link in a multi-pod job, and gradients tolerate 8-bit
stochastic-rounding-free quantization when the residual is fed back
(error-feedback keeps the compression bias out of the optimizer's
long-run trajectory; cf. 1-bit SGD / EF-SGD lineage).

``compressed_psum`` is designed for use inside ``shard_map`` over the
``pod`` axis: quantize (per-tensor scale) -> psum in int32 -> dequant;
the residual state is returned for the next step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_int8(x: Array) -> tuple[Array, Array]:
    """Per-tensor symmetric int8 quantization -> (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ef_compress(x: Array, residual: Array) -> tuple[Array, Array, Array]:
    """Error-feedback compression: returns (q, scale, new_residual)."""
    target = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(target)
    new_residual = target - dequantize_int8(q, scale)
    return q, scale, new_residual


def compressed_psum(x: Array, residual: Array, axis_name: str
                    ) -> tuple[Array, Array]:
    """int8 EF all-reduce over ``axis_name`` (inside shard_map).

    The int8 payload is summed in int32 (no overflow for <= 2^23
    participants), scales are meaned; the result is the dequantized
    mean-of-quantized gradient."""
    q, scale, new_residual = ef_compress(x, residual)
    n = jax.lax.psum(1, axis_name)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scale, axis_name)
    out = qsum.astype(jnp.float32) * (ssum / n) / n
    return out.astype(x.dtype), new_residual


def init_residuals(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
