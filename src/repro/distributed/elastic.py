"""Elastic re-mesh: grow/shrink the data-parallel width of a running job.

This is the TPU materialization of the paper's *elastic components*: a
training job's DP replicas beyond the first are elastic — the resource
shaper can revoke them (shrink) or grant them back (grow) and the job
continues from its last checkpoint on a different mesh.

Mechanics: checkpoints are mesh-agnostic (host numpy); ``reshard`` takes
a host pytree + the NEW mesh and places every leaf with the param specs
recomputed against that mesh.  Shrinking DP only changes the batch
sharding; shrinking/growng the model axis re-partitions weights — both
are the same device_put.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed import sharding as Sh


def to_host(tree):
    return jax.tree.map(np.asarray, tree)


def reshard(host_tree, mesh: Mesh):
    """Place a host pytree onto ``mesh`` using the standard param rules."""
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             Sh.param_specs(host_tree, mesh))
    return jax.tree.map(jax.device_put, host_tree, shardings)


@dataclasses.dataclass
class ElasticDecision:
    """What the shaper decided for this job at the last tick."""
    dp_width: int                 # granted data-parallel replicas
    preempt: bool = False         # full preemption (checkpoint + vacate)


class ElasticController:
    """Bridges the resource shaper's per-job allocation to mesh geometry.

    The job's components: 1 core replica (model-parallel slice) + up to
    ``max_dp - 1`` elastic replicas.  The shaper's granted allocation is
    quantized to a DP width; on change the driver checkpoints, rebuilds
    the mesh and reshards (see launch/train.py)."""

    def __init__(self, min_dp: int = 1, max_dp: int = 16):
        self.min_dp = min_dp
        self.max_dp = max_dp
        self.current = max_dp

    def decide(self, granted_fraction: float) -> ElasticDecision:
        """granted_fraction: granted / reserved resources for the job."""
        if granted_fraction <= 0.0:
            return ElasticDecision(dp_width=0, preempt=True)
        width = max(self.min_dp,
                    min(self.max_dp, round(granted_fraction * self.max_dp)))
        return ElasticDecision(dp_width=width)

    def apply(self, decision: ElasticDecision) -> bool:
        """Returns True if the mesh geometry changed."""
        if decision.preempt:
            return True
        changed = decision.dp_width != self.current
        self.current = decision.dp_width
        return changed
