"""Fault tolerance: heartbeats, straggler detection, restart ledger.

These are the host-side mechanisms the paper's architecture needs on a
TPU cluster:

* ``HeartbeatTracker`` — per-host liveness with a deadline; a missed
  heartbeat marks the host failed, which the training driver maps to
  preempt-to-checkpoint + elastic re-mesh (DP width shrinks by the lost
  replica, exactly the paper's "elastic component" removal).
* ``StragglerDetector`` — per-host step-time EWMA; hosts slower than
  ``threshold x`` median are flagged.  Flags feed the utilization
  monitor (a straggling host shows up as an anomalous utilization
  series, which raises the GP's predictive variance, which widens the
  safeguard buffer — the paper's uncertainty channel doing double duty).
* ``RestartLedger`` — append-only JSONL of failure/preemption/restart
  events; on restart the driver replays it to decide the resume step and
  requeue position (the paper: resubmission keeps original priority).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time


@dataclasses.dataclass
class HeartbeatTracker:
    deadline_s: float = 30.0
    _last: dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, now: float | None = None) -> None:
        self._last[host] = now if now is not None else time.time()

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self._last.items()
                if now - t > self.deadline_s]

    def alive(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self._last.items()
                if now - t <= self.deadline_s]


class StragglerDetector:
    def __init__(self, alpha: float = 0.2, threshold: float = 1.5):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: dict[int, float] = {}

    def record(self, host: int, step_time: float) -> None:
        prev = self.ewma.get(host, step_time)
        self.ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time

    def stragglers(self) -> list[int]:
        if len(self.ewma) < 2:
            return []
        med = sorted(self.ewma.values())[len(self.ewma) // 2]
        return [h for h, t in self.ewma.items()
                if t > self.threshold * med]


class RestartLedger:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def record(self, kind: str, **fields) -> None:
        entry = dict(kind=kind, ts=time.time(), **fields)
        with open(self.path, "a") as f:
            f.write(json.dumps(entry) + "\n")

    def replay(self) -> list[dict]:
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out

    def last_committed_step(self) -> int | None:
        steps = [e["step"] for e in self.replay()
                 if e["kind"] == "checkpoint_committed"]
        return max(steps) if steps else None
