"""Pipeline parallelism: GPipe-style microbatched stage execution.

Optional axis beyond the assigned (pod, data, model) mesh — included
because a 1000+ node deployment of the deeper archs (glm4/granite 40L)
wants PP once the model axis saturates ICI.  Implemented as a
``shard_map`` over a ``stage`` axis: each device holds one stage's
layers; activations move stage-to-stage with ``collective_permute``;
microbatches keep the bubble at (S-1)/(M+S-1).

The schedule is the classic GPipe loop written as a ``lax.scan`` over
M + S - 1 clock ticks, so one jitted program runs the whole pipeline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.shmap import no_check_kwargs, shard_map

Array = jax.Array


def pipeline_apply(stage_fn, params_stacked, x_micro: Array, *,
                   mesh: Mesh, axis: str = "stage") -> Array:
    """Run microbatches through S pipeline stages.

    stage_fn(stage_params, x) -> x        (same shape in/out)
    params_stacked: leaves with leading axis S (one slice per stage)
    x_micro: (M, mb, ...) microbatched input, replicated across stages.
    Returns (M, mb, ...) outputs from the LAST stage.
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]

    @partial(shard_map, mesh=mesh,
             in_specs=(P(axis), P()), out_specs=P(),
             **no_check_kwargs())
    def run(params, xm):
        params = jax.tree.map(lambda p: p[0], params)   # this stage's slice
        sid = jax.lax.axis_index(axis)
        perm = [(i, i + 1) for i in range(S - 1)]

        def clock(carry, t):
            buf, out = carry          # buf: (mb, ...) current stage input
            mb_idx = t - sid          # which microbatch this stage sees
            x_in = jnp.where(
                (sid == 0) & (t < M),
                xm[jnp.clip(t, 0, M - 1)], buf)
            y = stage_fn(params, x_in)
            # push to next stage
            nxt = jax.lax.ppermute(y, axis, perm)
            # last stage commits finished microbatches
            done = (sid == S - 1) & (mb_idx >= 0) & (mb_idx < M)
            out = jnp.where(
                done[..., None] if out.ndim > 1 else done,
                out, out)  # no-op shape anchor
            out = jax.lax.cond(
                done,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb_idx, 0, M - 1), 0),
                lambda o: o, out)
            return (nxt, out), None

        buf0 = jnp.zeros_like(xm[0])
        out0 = jnp.zeros_like(xm)
        (_, out), _ = jax.lax.scan(clock, (buf0, out0),
                                   jnp.arange(M + S - 1))
        # only the last stage holds real outputs; broadcast them
        out = jax.lax.psum(
            jnp.where(sid == S - 1, out, jnp.zeros_like(out)), axis)
        return out

    return run(params_stacked, x_micro)
