"""Logical sharding rules: param/optimizer/batch/cache PartitionSpecs.

Megatron-style tensor parallelism over the ``model`` axis composed with
(hierarchical) data parallelism over ``("pod", "data")``:

  * column-parallel (output dim on ``model``): q/k/v projections, MLP
    gate/up, SSM in-projections, xLSTM up-projections;
  * row-parallel (input dim on ``model``): attention output, MLP down,
    SSM/xLSTM down-projections — GSPMD closes each block with one
    reduce-scatter/all-gather pair;
  * expert-parallel: MoE expert stacks shard their EXPERT dim over
    ``model`` (token exchange lowers to all-to-alls);
  * vocab-parallel embedding + lm_head;
  * optimizer moments inherit the param spec (ZeRO-3-like for the TP
    dims for free; DP-replicated otherwise).

Rules are path-name based so they survive arbitrary stacking: a leaf's
spec is (None,)*(ndim - len(rule)) + rule, which handles scan-stacked
blocks (L, ...) and xLSTM's (G, K, ...) nesting uniformly.

Divisibility is checked against the actual mesh: a dim that does not
divide falls back to replication (e.g. glm4's 2 KV heads on a 16-way
model axis — its decode cache shards the SEQUENCE dim instead, which is
exactly what makes that cell collective-bound; see EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

MODEL = "model"
# data axes present in the mesh are discovered at call time
CANDIDATE_DATA_AXES = ("pod", "data")

# name -> trailing-dims rule (applied to the last len(rule) dims)
_COLUMN = (None, MODEL)
_ROW = (MODEL, None)
_RULES: dict[str, tuple] = {
    # embeddings
    "embed": (MODEL, None), "tok_embed": (MODEL, None),
    "lm_head": _COLUMN,
    # attention
    "wq": _COLUMN, "wk": _COLUMN, "wv": _COLUMN, "wo": _ROW,
    # dense MLP
    "gate": _COLUMN, "up": _COLUMN, "down": _ROW,
    # ssm
    "in_proj": _COLUMN, "out_proj": _ROW, "conv": (None, MODEL),
    "w_dt": _COLUMN,
    # xlstm
    "w_up": _COLUMN, "w_z": _COLUMN, "w_in": _COLUMN,
    "w_down": _ROW, "w_out": _ROW,
}
# MoE expert tensors: (..., E, d, ff) / (..., E, ff, d)
_EXPERT_RULE = (MODEL, None, None)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in CANDIDATE_DATA_AXES if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _leaf_spec(path, leaf, mesh: Mesh) -> P:
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    in_moe = "moe" in names
    msize = mesh.shape[MODEL]

    rule: tuple | None = None
    if in_moe and name in ("gate", "up", "down") and leaf.ndim >= 3:
        rule = _EXPERT_RULE
    elif name in _RULES and leaf.ndim >= len(_RULES[name]):
        rule = _RULES[name]

    if rule is None:
        return P()
    # divisibility check on each sharded dim
    full = (None,) * (leaf.ndim - len(rule)) + rule
    ok = []
    for dim, ax in enumerate(full):
        if ax is None:
            ok.append(None)
        elif leaf.shape[dim] % msize == 0:
            ok.append(ax)
        else:
            ok.append(None)
    return P(*ok)


def param_specs(params, mesh: Mesh, overrides: dict | None = None):
    """Pytree of PartitionSpec mirroring ``params``.

    ``overrides``: {leaf_name: trailing-rule or P()} — per-arch perf
    variants (e.g. the ssm family replicates its block weights: TP
    all-reduces of mLSTM activations cost more than the weights save)."""
    flat = jax.tree_util.tree_flatten_with_path(params)

    def spec(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        if overrides and name in overrides:
            return P()
        return _leaf_spec(path, leaf, mesh)

    specs = [spec(path, leaf) for path, leaf in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def zero_shard(spec: P, leaf, mesh: Mesh) -> P:
    """ZeRO-style optimizer-state sharding: give an (otherwise
    replicated or partially sharded) moment tensor one extra data-axis
    shard on its first large divisible dim."""
    daxes = data_axes(mesh)
    dsize = axis_size(mesh, daxes)
    cur = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
    for dim in range(leaf.ndim):
        if cur[dim] is None and leaf.shape[dim] % dsize == 0 \
                and leaf.shape[dim] >= dsize:
            new = list(cur)
            new[dim] = daxes
            return P(*new)
    return P(*cur)


def opt_specs(opt_state, params, mesh: Mesh, *, zero: bool = False,
              overrides: dict | None = None):
    """Optimizer moments inherit the param spec; counters replicate.
    ``zero=True`` additionally shards moments over the data axis
    (ZeRO-1) — fp32 mu/nu dominate HBM for replicated-weight archs."""
    pspecs = param_specs(params, mesh, overrides)
    if zero:
        mspecs = jax.tree.map(
            lambda s, l: zero_shard(s, l, mesh), pspecs, params)
    else:
        mspecs = pspecs
    return {"mu": mspecs, "nu": mspecs, "step": P()}


def batch_spec(mesh: Mesh, ndim: int = 2) -> P:
    """(B, ...) host batch: batch dim over all data axes."""
    return P(data_axes(mesh), *([None] * (ndim - 1)))


def _maybe(axes, size: int, mesh: Mesh):
    return axes if axes and size % axis_size(mesh, axes) == 0 else None


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh,
                *, kv_fallback: str = "seq"):
    """PartitionSpecs for the stacked decode caches of ``init_caches``.

    Priority: shard batch over data; shard KV heads over model when they
    divide (pad_kv_heads replication makes this the common case).  When
    heads do not divide, ``kv_fallback`` picks the layout:
      * "seq"       — shard the cache SEQUENCE over model (ring-style;
                      minimizes HBM but pays attention-time collectives
                      every layer — the §Perf BASELINE for glm4/granite);
      * "replicate" — keep the cache whole per model shard (costs HBM,
                      zero attention collectives — §Perf optimized).
    """
    daxes = data_axes(mesh)
    b_ax = _maybe(daxes, batch, mesh)
    if cfg.family == "ssm":
        from repro.models import xlstm as X
        dh = X.PROJ * cfg.d_model // cfg.n_heads
        m_ok = dh % mesh.shape[MODEL] == 0
        return {
            "mlstm": {
                "C": P(None, None, b_ax, None, MODEL if m_ok else None, None),
                "n": P(None, None, b_ax, None, None),
                "m": P(None, None, b_ax, None),
            },
            **({"slstm": {
                "c": P(None, b_ax, MODEL if cfg.d_model % mesh.shape[MODEL] == 0 else None),
                "n": P(None, b_ax, None),
                "m": P(None, b_ax, None),
                "h": P(None, b_ax, None),
            }} if cfg.slstm_every > 0 else {}),
        }
    kv_on_model = cfg.kv_heads_eff % mesh.shape[MODEL] == 0
    seq_on_model = (not kv_on_model and kv_fallback == "seq"
                    and max_len % mesh.shape[MODEL] == 0)
    from repro.models.attention import KVCache
    from repro.models.transformer import LayerCache
    d_ok = cfg.d_model % mesh.shape[MODEL] == 0

    if not cfg.scan_layers:
        # per-layer (unstacked) serving caches: same dims minus the
        # leading layer axis, one spec per layer
        kv_spec = P(b_ax, MODEL if kv_on_model else None,
                    MODEL if seq_on_model else None, None)
        kv = KVCache(k=kv_spec, v=kv_spec, length=P())
        ssm = None
        if cfg.family == "hybrid":
            ssm = {"h": P(b_ax, MODEL if d_ok else None, None),
                   "conv": P(b_ax, None, MODEL if d_ok else None)}
        return [LayerCache(attn=kv, ssm=ssm)] * cfg.n_layers

    kv_spec = P(None, b_ax,
                MODEL if kv_on_model else None,
                MODEL if seq_on_model else None,
                None)
    kv = KVCache(k=kv_spec, v=kv_spec, length=P(None))
    ssm = None
    if cfg.family == "hybrid":
        ssm = {"h": P(None, b_ax, MODEL if d_ok else None, None),
               "conv": P(None, b_ax, None, MODEL if d_ok else None)}
    return LayerCache(attn=kv, ssm=ssm)


def logits_spec(mesh: Mesh) -> P:
    return P(data_axes(mesh), None, MODEL)
