"""Version-compatible ``shard_map``.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` (and the
replication-check kwarg was renamed ``check_rep`` -> ``check_vma``) in
newer JAX releases; the baked toolchain may sit on either side.  This
shim resolves the callable and kwarg name once at import.
"""
from __future__ import annotations

import inspect

import jax

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map  # noqa: F811

_params = inspect.signature(shard_map).parameters
CHECK_KW = "check_vma" if "check_vma" in _params else "check_rep"


def no_check_kwargs() -> dict:
    """{check_vma/check_rep: False} for the running JAX version."""
    return {CHECK_KW: False}
