"""Pallas TPU kernel: block-wise online-softmax attention (FlashAttention).

The framework's compute hot spot for the ``prefill_32k`` / ``train_4k``
shapes: at S = 32k the naive (S, T) logits tensor is 4 GiB/head and the
attention becomes HBM-bound; the block-wise formulation keeps every
intermediate in VMEM and turns attention into a stream of MXU matmuls.

TPU adaptation (vs the CUDA original):
  * no warp-level shuffles — the online-softmax carries (m, l, acc) live
    in VMEM scratch that persists across the innermost (sequential) grid
    dimension, the TPU-idiomatic replacement for shared-memory tiles;
  * tiles are (bq, d) x (d, bk) MXU matmuls with fp32 accumulation;
    m/l are kept lane-replicated at width 128 to stay VPU-aligned;
  * causal block skipping via ``pl.when`` on the kv-block index — skipped
    blocks cost zero MXU cycles (vs thread divergence on GPU);
  * GQA is folded into the K/V BlockSpec index map (head h reads kv-head
    h // group) so no repeated K/V ever materializes in HBM.

The pure-jnp oracle is ``ref.attention``; tests sweep shapes/dtypes/
causality and assert allclose in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEF_BQ = 256
DEF_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sm_scale: float, causal: bool, bq: int, bk: int,
                  q_offset: int):
    i = pl.program_id(2)          # query block
    j = pl.program_id(3)          # kv block (sequential, innermost)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: kv block j overlaps queries iff j*bk <= last qpos in block i
    run = (j * bk <= q_offset + (i + 1) * bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        if causal:
            qpos = q_offset + i * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                            # (bq, 128) lane-replicated
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)             # broadcast -> (bq, 128)
        p = jnp.exp(s - m_new[:, :1])                  # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 128)
        l_ref[...] = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq, d)
        acc_ref[...] = corr[:, :1] * acc_ref[...] + pv
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[..., :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "bq", "bk", "q_offset",
                     "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    sm_scale: float | None = None, bq: int = DEF_BQ,
                    bk: int = DEF_BK, q_offset: int = 0,
                    interpret: bool = False) -> Array:
    """q: (B, Hq, S, D), k/v: (B, Hkv, T, D), Hq % Hkv == 0.

    Requires S % bq == 0, T % bk == 0, D % 128 == 0 (ops.py pads).
    ``q_offset`` is the global position of q[...,0,:] for causal masking
    with a pre-existing KV prefix (T - S by default in ops.py).
    """
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0 and S % bq == 0 and T % bk == 0 and D % 128 == 0
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    grid = (B, Hq, S // bq, T // bk)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, bq=bq, bk=bk,
        q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # m (lane-replicated)
            pltpu.VMEM((bq, 128), jnp.float32),   # l (lane-replicated)
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
