"""Pallas TPU kernel: batched history-kernel Gram matrix (paper Eq. 6).

This is the arithmetic hot spot of fleet-scale GP forecasting: with B
component series, N patterns each of dimension D = h+1, every monitoring
tick rebuilds B Gram matrices (N x N) plus B cross-vectors — O(B N^2 D)
flops that are 100% MXU-friendly once phrased as a matmul via

    ||a - b||^2 = ||a||^2 + ||b||^2 - 2 a.b

The kernel fuses the distance computation with the kernel application
(exp / rbf) so the (M, N) distance intermediate never round-trips to HBM.

TPU adaptation notes (vs a CUDA pairwise-distance kernel):
  * tiles are MXU/VPU aligned — D is padded to a multiple of 128 (lane
    dim) by the wrapper in ops.py; M/N tiles are multiples of 8 (sublane);
  * the -2 a.b term is a (bm, D) x (D, bn) matmul hitting the MXU with
    fp32 accumulation via ``preferred_element_type``;
  * hyper-parameters (ell, sf) arrive as a small VMEM vector so the same
    compiled kernel serves every evidence-maximization step.

Zero-padding contract: padded D columns are zero in BOTH operands, so
they contribute nothing to any pairwise distance; padded M/N rows produce
garbage rows/cols that the wrapper slices off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# fallback tile sizes — ops.py may override per shape
DEF_BM = 128
DEF_BN = 128


def _gram_kernel(xa_ref, xb_ref, params_ref, out_ref, *, kind: str):
    """One (bm, bn) tile of the Gram matrix. Full D is resident."""
    xa = xa_ref[...].astype(jnp.float32)           # (bm, D)
    xb = xb_ref[...].astype(jnp.float32)           # (bn, D)
    ell = params_ref[0, 0]
    sf = params_ref[0, 1]
    na = jnp.sum(xa * xa, axis=1, keepdims=True)    # (bm, 1)
    nb = jnp.sum(xb * xb, axis=1, keepdims=True).T  # (1, bn)
    # MXU matmul with fp32 accumulate
    ab = jax.lax.dot_general(
        xa, xb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (bm, bn)
    d2 = jnp.maximum(na + nb - 2.0 * ab, 0.0)
    if kind == "exp":
        r = jnp.sqrt(d2 + 1e-12)
        k = jnp.exp(-r / ell)
    else:  # rbf
        k = jnp.exp(-0.5 * d2 / (ell * ell))
    out_ref[...] = (sf * sf) * k


@functools.partial(jax.jit, static_argnames=("kind", "bm", "bn", "interpret"))
def gp_gram(xa: Array, xb: Array, params: Array, *, kind: str = "exp",
            bm: int = DEF_BM, bn: int = DEF_BN,
            interpret: bool = False) -> Array:
    """Gram matrix between padded pattern sets.

    xa: (M, D), xb: (N, D) with M % bm == 0, N % bn == 0, D % 128 == 0
    (the ops.py wrapper pads).  params: (1, 128) vector, [0,0]=ell,
    [0,1]=sigma_f.  Returns (M, N) float32.
    """
    M, D = xa.shape
    N, _ = xb.shape
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_gram_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 128), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(xa, xb, params)
