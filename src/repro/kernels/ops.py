"""Public jit'd wrappers for the Pallas kernels, with shape padding and
backend dispatch.

``impl`` semantics (every op):
  * "auto"   — Pallas on TPU, pure-jnp reference elsewhere (CPU dry-run /
               tests compile the reference; TPU deployment gets the kernel);
  * "pallas" — force the kernel (interpret-mode off-TPU, used by tests);
  * "jnp"    — force the reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gp_gram as _gg
from repro.kernels import ref

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_axis(x: Array, axis: int, to: int) -> Array:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ----------------------------------------------------------------------
# gram — history-kernel Gram matrix
# ----------------------------------------------------------------------

def gram(xa: Array, xb: Array, lengthscale, sigma_f, *, kind: str = "exp",
         impl: str = "auto") -> Array:
    """Gram matrix k_h(xa, xb) (paper Eq. 6). xa: (M,D), xb: (N,D)."""
    if impl == "jnp" or (impl == "auto" and not _on_tpu()):
        return ref.gram(xa, xb, lengthscale, sigma_f, kind=kind)
    M, D = xa.shape
    N = xb.shape[0]
    # pick tiles: small problems use one tile, large problems 128x128
    bm = min(_round_up(M, 8), 128)
    bn = min(_round_up(N, 8), 128)
    Dp = _round_up(D, 128)
    Mp, Np = _round_up(M, bm), _round_up(N, bn)
    xa_p = _pad_axis(_pad_axis(xa.astype(jnp.float32), 1, Dp), 0, Mp)
    xb_p = _pad_axis(_pad_axis(xb.astype(jnp.float32), 1, Dp), 0, Np)
    params = jnp.zeros((1, 128), jnp.float32)
    params = params.at[0, 0].set(jnp.asarray(lengthscale, jnp.float32))
    params = params.at[0, 1].set(jnp.asarray(sigma_f, jnp.float32))
    out = _gg.gp_gram(xa_p, xb_p, params, kind=kind, bm=bm, bn=bn,
                      interpret=not _on_tpu())
    return out[:M, :N]


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------

def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              sm_scale: float | None = None, impl: str = "auto",
              bq: int | None = None, bk: int | None = None) -> Array:
    """Multi-head (GQA) attention. q: (B,Hq,S,D), k/v: (B,Hkv,T,D).

    Queries are aligned to the END of the key sequence (decode semantics:
    q_offset = T - S), which also covers self-attention (T == S).
    """
    B, Hq, S, D = q.shape
    T = k.shape[2]
    if impl == "jnp" or (impl == "auto" and not _on_tpu()):
        return ref.attention(q, k, v, causal=causal, sm_scale=sm_scale)
    if S < 8:  # decode-style tiny q: blockwise machinery not worth it
        return ref.attention(q, k, v, causal=causal, sm_scale=sm_scale)
    bq = bq or min(_round_up(S, 8), _fa.DEF_BQ)
    bk = bk or min(_round_up(T, 128), _fa.DEF_BK)
    Sp, Tp, Dp = _round_up(S, bq), _round_up(T, bk), _round_up(D, 128)
    q_p = _pad_axis(_pad_axis(q, 3, Dp), 2, Sp)
    k_p = _pad_axis(_pad_axis(k, 3, Dp), 2, Tp)
    v_p = _pad_axis(_pad_axis(v, 3, Dp), 2, Tp)
    if Tp != T and not causal:
        # padded keys must not receive mass: bias via causal offset trick
        # doesn't apply; mask by writing NEG_INF into padded K is wrong for
        # exp kernel — instead fall back to reference for non-causal pads.
        return ref.attention(q, k, v, causal=causal, sm_scale=sm_scale)
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)   # scale by TRUE head dim, not padded
    out = _fa.flash_attention(
        q_p, k_p, v_p, causal=causal, sm_scale=sm_scale, bq=bq, bk=bk,
        q_offset=T - S, interpret=not _on_tpu())
    return out[:, :, :S, :D]
