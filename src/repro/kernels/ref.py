"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: each Pallas kernel's test sweeps shapes
and dtypes and asserts allclose against the function here.  They are also
the runtime fallback on non-TPU backends (the dry-run and the CPU test
environment compile these; the Pallas path is the TPU deployment path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ----------------------------------------------------------------------
# gp_gram — history-kernel Gram matrix (paper Eq. 6)
# ----------------------------------------------------------------------

def sq_dists(xa: Array, xb: Array) -> Array:
    """Pairwise squared Euclidean distances, (M,D) x (N,D) -> (M,N)."""
    na = jnp.sum(xa * xa, axis=-1)
    nb = jnp.sum(xb * xb, axis=-1)
    d2 = na[:, None] + nb[None, :] - 2.0 * (xa @ xb.T)
    return jnp.maximum(d2, 0.0)


def gram(xa: Array, xb: Array, lengthscale: Array, sigma_f: Array,
         kind: str = "exp") -> Array:
    """k_h(x, x') of Eq. (6): a stationary kernel on pattern vectors.

    kind="exp": sf^2 * exp(-r / ell)        (paper's choice — Fig. 2)
    kind="rbf": sf^2 * exp(-r^2 / (2 ell^2))
    """
    d2 = sq_dists(xa.astype(jnp.float32), xb.astype(jnp.float32))
    if kind == "exp":
        r = jnp.sqrt(d2 + 1e-12)
        k = jnp.exp(-r / lengthscale)
    elif kind == "rbf":
        k = jnp.exp(-0.5 * d2 / (lengthscale ** 2))
    else:
        raise ValueError(f"unknown kernel kind: {kind}")
    return (sigma_f ** 2) * k


# ----------------------------------------------------------------------
# flash_attention — causal/full multi-head attention with GQA
# ----------------------------------------------------------------------

def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              sm_scale: float | None = None) -> Array:
    """Reference attention.  q: (B,Hq,S,D), k/v: (B,Hkv,T,D) with
    Hq % Hkv == 0 (GQA).  Returns (B,Hq,S,D) in q.dtype."""
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf) * sm_scale
    if causal:
        # query i (global position T-S+i) attends keys 0..T-S+i
        qpos = jnp.arange(S)[:, None] + (T - S)
        kpos = jnp.arange(T)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", w, vf)
    return out.astype(q.dtype)
