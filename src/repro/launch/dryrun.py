import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init.  The dry-run (and only the dry-run) runs with 512 placeholder
# host devices so the production meshes can be built.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:   with mesh:
                     lowered = jax.jit(step, in_shardings=...).lower(*specs)
                     compiled = lowered.compile()
                     memory_analysis / cost_analysis / collective bytes

Proves: the sharding config is coherent (no mismatched specs), the
program fits (memory analysis), and yields the roofline inputs
(HLO FLOPs + bytes from cost_analysis; collective bytes parsed from the
post-SPMD optimized HLO).  Results accumulate in dryrun_results.json —
re-runs skip completed cells, failures record the error.

Usage:
  python -m repro.launch.dryrun [--arch A] [--shape S] [--mesh single|multi|both]
                                [--out PATH] [--smoke] [--force]
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, eligible, input_specs
from repro.models import ARCHS, get_config

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum byte sizes of all typed shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-op byte totals from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            # match "= TYPE op(" — result type precedes the opcode
            idx = line.find(f" {op}(")
            if idx < 0 or "=" not in line[:idx]:
                continue
            lhs = line[line.index("=") + 1:idx]
            out[op] += _shape_bytes(lhs)
            out["count"] += 1
            break
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             smoke: bool = False, variant: str = "default") -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    cell = input_specs(arch, shape_name, mesh, smoke=smoke,
                       variant=variant)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rec: dict = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                     kind=cell.kind, ok=True, variant=variant,
                     t_lower=round(t_lower, 1),
                     t_compile=round(t_compile, 1), **cell.meta)
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover - backend dependent
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # jax<=0.4.x: one dict per program
            ca = ca[0] if ca else {}
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       ("flops" in k or "bytes" in k or k == "utilization")}
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        # loop-aware per-device cost (fixes the while-body-counted-once
        # convention of cost_analysis — see benchmarks/hlo_analysis.py)
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))
        from benchmarks.hlo_analysis import analyze
        rec["hlo"] = analyze(hlo)
    except Exception as e:  # pragma: no cover
        rec["collectives"] = rec.get("collectives", {"error": str(e)})
        rec["hlo"] = {"error": str(e)}
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="default",
                    choices=["default", "opt"])
    args = ap.parse_args()

    try:
        with open(args.out) as f:
            results = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        results = {}

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, why = eligible(cfg, shape_name)
            for mesh_kind in meshes:
                key = f"{arch}|{shape_name}|{mesh_kind}"
                if args.variant != "default":
                    key += f"|{args.variant}"
                if not ok:
                    results[key] = dict(arch=arch, shape=shape_name,
                                        mesh=mesh_kind, skipped=True,
                                        variant=args.variant, reason=why)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
                    print(f"SKIP {key}: {why}", flush=True)
                    n_skip += 1
                    continue
                if key in results and results[key].get("ok") and not args.force:
                    print(f"CACHED {key}", flush=True)
                    n_ok += 1
                    continue
                print(f"RUN  {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape_name, mesh_kind,
                                   smoke=args.smoke,
                                   variant=args.variant)
                    n_ok += 1
                    cb = rec.get("collectives", {})
                    print(f"  ok: compile {rec['t_compile']}s, "
                          f"flops={rec['cost'].get('flops', 0):.3e}, "
                          f"coll_ops={cb.get('count', '?')}", flush=True)
                except Exception as e:
                    rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                               ok=False, error=f"{type(e).__name__}: {e}",
                               trace=traceback.format_exc()[-2000:])
                    print(f"  FAIL: {rec['error']}", flush=True)
                    n_fail += 1
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed",
          flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
