"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Topology (TPU v5e pods): a pod is a 16x16 slice (256 chips) meshed as
(data=16, model=16); multi-pod prepends a ``pod`` axis (DCI-connected),
and data-parallel reduction becomes hierarchical (reduce-scatter intra-
pod over ICI, all-reduce across pods, all-gather intra-pod).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh for CPU tests/examples (1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
