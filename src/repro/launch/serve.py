"""Serving driver: batched request admission governed by the shaper.

A miniature continuous-batching server: requests queue up, the decode
batch is the elastic dimension (paper mapping: each batch slot's KV
cache is an elastic component claiming HBM), and the utilization
forecaster + safeguard buffer decide how many slots the scheduler may
fill — shrinking the batch BEFORE the KV cache would OOM instead of
letting the runtime die (the paper's finite-resource story, serving
edition).

Usage:
  python -m repro.launch.serve --arch internlm2-1.8b --smoke --requests 32
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forecast import GPConfig, GPForecaster
from repro.core.monitor import Monitor
from repro.core.shaper import SafeguardConfig, shaped_demand
from repro.models import get_config
from repro.models import transformer as T
from repro.serve.engine import decode_step_fn, prefill_fn


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--hbm-budget-gib", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    max_len = args.prompt_len + args.gen_len + 16

    B = args.max_batch
    prefill = jax.jit(lambda p, t: prefill_fn(p, cfg, t, max_len=max_len))
    decode = jax.jit(lambda p, t, c: decode_step_fn(p, cfg, t, c))

    # KV bytes per occupied slot (the "reservation" of a request)
    cache_t = jax.eval_shape(lambda: T.init_caches(cfg, B, max_len))
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache_t))
    slot_gib = cache_bytes / B / 2**30

    mon = Monitor(slots=1, window=16)
    forecaster = GPForecaster(GPConfig(history=6, max_patterns=6,
                                       opt_steps=6))
    guard = SafeguardConfig(k1=0.05, k2=3.0)

    rng = np.random.RandomState(0)
    pending = [rng.randint(0, cfg.vocab, size=(args.prompt_len,))
               for _ in range(args.requests)]
    done = 0
    batch_cap = B
    stats = {"batches": 0, "shrinks": 0, "tokens": 0}

    while pending:
        take = min(batch_cap, len(pending), B)
        reqs = [pending.pop(0) for _ in range(take)]
        prompts = np.stack(reqs + [reqs[-1]] * (B - take))  # pad batch
        caches, logits = prefill(params, jnp.asarray(prompts, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(args.gen_len):
            logits, caches = decode(params, tok, caches)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            stats["tokens"] += take
        done += take
        stats["batches"] += 1

        # utilization sample: occupied KV slots (GiB)
        used = take * slot_gib
        mon.record(np.asarray([0]), np.asarray([take], np.float32),
                   np.asarray([used], np.float32))
        if mon.ready(np.asarray([0]), grace=6)[0]:
            w, v = mon.windows(np.asarray([0]))
            fc = forecaster.forecast(jnp.asarray(w[0, :, 1]), 2,
                                     valid=jnp.asarray(v[0]))
            grant = float(shaped_demand(
                fc.mean.max(), args.hbm_budget_gib, fc.var.max(), guard))
            new_cap = max(1, min(B, int(grant / max(slot_gib, 1e-9))))
            if new_cap < batch_cap:
                stats["shrinks"] += 1
            batch_cap = new_cap
        print(f"served {done}/{args.requests} "
              f"(batch cap {batch_cap}, kv/slot {slot_gib:.3f} GiB)")

    print(f"done: {stats}")
    return stats


if __name__ == "__main__":
    main()
