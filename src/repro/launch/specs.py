"""input_specs(): ShapeDtypeStruct stand-ins for every dry-run cell.

No device allocation anywhere: parameters, optimizer state and caches
come from ``jax.eval_shape`` over the real init functions, so the specs
can never drift from the actual model; inputs are built directly.

A cell = (arch, shape_name, step kind):
  train_4k    -> train_step(params, opt_state, batch)
  prefill_32k -> prefill(params, tokens[, img/frames])
  decode_32k  -> decode_step(params, token, caches)   (cache len = seq)
  long_500k   -> decode_step at 524288 ctx, batch 1 (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as Sh
from repro.models import get_config
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.serve.engine import decode_step_fn, prefill_fn, whisper_decode_step_fn
from repro.train import TrainConfig, make_train_step

SHAPES: dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1),
}


def apply_variant(cfg: ModelConfig, variant: str, mesh):
    """Perf-variant config overrides (EXPERIMENTS.md §Perf).

    "default" — the paper-faithful / first-principles baseline;
    "opt"     — the hillclimbed configuration:
       * KV-head replication padding to the TP width (cache shards over
         model; no attention collectives) where n_kv | width | n_heads;
       * replicate-KV fallback (instead of sequence-sharding) when
         padding is impossible;
       * chunkwise-parallel mLSTM (chunk 64) for the ssm family.
    Returns (cfg, kv_fallback)."""
    if variant == "default":
        return cfg, "seq"
    msize = mesh.shape["model"]
    changes: dict = {}
    if (cfg.family != "ssm" and cfg.n_kv % msize != 0
            and msize % cfg.n_kv == 0 and cfg.n_heads % msize == 0):
        changes["pad_kv_heads"] = msize
    if cfg.family == "ssm":
        changes["mlstm_chunk"] = 64
        # replicate mLSTM block weights: the (di,di) projections would
        # contract a model-sharded dim, costing a (B,S,di) fp32
        # all-reduce per layer — far more than the 2.6GB of weights;
        # optimizer moments go ZeRO-1 over data to pay for it
    else:
        # unrolled serving layers: per-layer donated cache buffers with
        # static in-place updates (§Perf iteration 4)
        changes["scan_layers"] = False
    if changes:
        cfg = dataclasses.replace(cfg, **changes)
    return cfg, "replicate"


_SSM_OVERRIDES = {"wq": None, "wk": None, "wv": None, "w_up": None,
                  "w_z": None, "w_down": None, "w_in": None, "w_out": None}


def variant_overrides(cfg: ModelConfig, variant: str) -> dict | None:
    if variant == "opt" and cfg.family == "ssm":
        return _SSM_OVERRIDES
    return None


def eligible(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k-token decode is "
                       "quadratic-history + unshardable KV at batch 1 "
                       "(skip noted in DESIGN.md)")
    return True, ""


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    kind: str
    fn: Callable                  # the function to lower
    args: tuple                   # ShapeDtypeStruct pytrees
    in_shardings: tuple
    donate: tuple[int, ...]
    meta: dict[str, Any]


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _shardify(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def _batch_sharding(mesh, batch: int, ndim: int, *,
                    include_model: bool = False):
    daxes = Sh.data_axes(mesh)
    if include_model:
        # DP-only archs (replicated weights): the model axis would sit
        # idle — fold it into the batch shard
        daxes = daxes + (Sh.MODEL,)
        while daxes and batch % Sh.axis_size(mesh, daxes) != 0:
            daxes = daxes[1:]   # drop leading axes until divisible
    if not daxes or batch % Sh.axis_size(mesh, daxes) != 0:
        daxes = None
    return NamedSharding(mesh, P(daxes, *([None] * (ndim - 1))))


def input_specs(arch: str, shape_name: str, mesh, *,
                smoke: bool = False, variant: str = "default") -> Cell:
    cfg = get_config(arch, smoke=smoke)
    cfg, kv_fallback = apply_variant(cfg, variant, mesh)
    sh = SHAPES[shape_name]
    seq, batch = sh["seq"], sh["batch"]
    if smoke:
        seq, batch = 64, 4
    kind = sh["kind"]

    key = jax.random.PRNGKey(0)
    if cfg.encdec:
        params = jax.eval_shape(lambda: W.init_whisper(key, cfg))
    else:
        params = jax.eval_shape(lambda: T.init_lm(key, cfg))
    overrides = variant_overrides(cfg, variant)
    pspecs = Sh.param_specs(params, mesh, overrides)
    pshard = _shardify(mesh, pspecs)

    meta = dict(seq=seq, batch=batch,
                n_params=int(sum(x.size for x in jax.tree.leaves(params))),
                n_active=cfg.n_active_params())

    if kind == "train":
        opt = jax.eval_shape(lambda: adamw_init(params))
        ospecs = Sh.opt_specs(None, params, mesh,
                              zero=(variant == "opt"),
                              overrides=overrides)
        oshard = _shardify(mesh, ospecs)
        if cfg.encdec:
            dlen = cfg.dec_len
            batch_t = {
                "frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.bfloat16),
                "dec_tokens": jax.ShapeDtypeStruct((batch, dlen), jnp.int32),
                "dec_labels": jax.ShapeDtypeStruct((batch, dlen), jnp.int32),
            }
            bshard = {"frames": _batch_sharding(mesh, batch, 3),
                      "dec_tokens": _batch_sharding(mesh, batch, 2),
                      "dec_labels": _batch_sharding(mesh, batch, 2)}
        else:
            dp_only = variant == "opt" and cfg.family == "ssm"
            batch_t = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                       "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
            bshard = {"tokens": _batch_sharding(mesh, batch, 2,
                                                include_model=dp_only),
                      "labels": _batch_sharding(mesh, batch, 2,
                                                include_model=dp_only)}
            if cfg.family == "vlm":
                batch_t["img_embeds"] = jax.ShapeDtypeStruct(
                    (batch, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
                bshard["img_embeds"] = _batch_sharding(mesh, batch, 3)
        tc = TrainConfig(microbatches=1)
        fn = make_train_step(cfg, tc)
        return Cell(arch, shape_name, kind, fn,
                    (params, _sds(opt), batch_t),
                    (pshard, oshard, bshard), donate=(0, 1), meta=meta)

    if kind == "prefill":
        if cfg.encdec:
            frames = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                          jnp.bfloat16)

            def wfn(p, fr):
                enc = W.encode(p, fr, cfg)
                toks = jnp.zeros((fr.shape[0], cfg.dec_len), jnp.int32)
                logits, _ = W.decode(p, toks, enc, cfg)
                return logits[:, -1]

            return Cell(arch, shape_name, kind, wfn, (params, frames),
                        (pshard, _batch_sharding(mesh, batch, 3)),
                        donate=(), meta=meta)
        toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        extra = {}
        if cfg.family == "vlm":
            extra["img_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)

        def pfn(p, tokens, **kw):
            return prefill_fn(p, cfg, tokens, max_len=seq, **kw)

        shardings = [pshard, _batch_sharding(mesh, batch, 2)]
        args = [params, toks]
        if extra:
            args.append(extra["img_embeds"])
            shardings.append(_batch_sharding(mesh, batch, 3))

            def pfn(p, tokens, img):  # noqa: F811
                return prefill_fn(p, cfg, tokens, max_len=seq,
                                  img_embeds=img)

        return Cell(arch, shape_name, kind, pfn, tuple(args),
                    tuple(shardings), donate=(), meta=meta)

    # ---- decode -------------------------------------------------------
    if cfg.encdec:
        token = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        enc_out = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                       jnp.bfloat16)
        caches = jax.eval_shape(
            lambda: W.init_dec_caches(cfg, batch, cfg.dec_len))
        daxes = Sh.data_axes(mesh)
        b_ok = batch % Sh.axis_size(mesh, daxes) == 0

        def _cspec(x):
            if x.ndim < 2:
                return P()
            return P(None, daxes if b_ok else None,
                     *([None] * (x.ndim - 2)))

        cshard = _shardify(mesh, jax.tree.map(_cspec, caches))

        def dfn(p, tok, enc, ca):
            return whisper_decode_step_fn(p, cfg, tok, enc, ca)

        return Cell(arch, shape_name, kind, dfn,
                    (params, token, enc_out, caches),
                    (pshard, _batch_sharding(mesh, batch, 2),
                     _batch_sharding(mesh, batch, 3), cshard),
                    donate=(3,), meta=meta)

    token = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    # cache length seq+16 keeps the seq dim divisible by the model axis
    # (required by the sequence-sharded KV fallback, e.g. glm4's kv=2)
    caches = jax.eval_shape(lambda: T.init_caches(cfg, batch, seq + 16))
    cspecs = Sh.cache_specs(cfg, batch, seq + 16, mesh,
                            kv_fallback=kv_fallback)
    cshard = _shardify(mesh, cspecs)

    def dfn(p, tok, ca):
        return decode_step_fn(p, cfg, tok, ca)

    return Cell(arch, shape_name, kind, dfn, (params, token, caches),
                (pshard, _batch_sharding(mesh, batch, 2), cshard),
                donate=(2,), meta=meta)
