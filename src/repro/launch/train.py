"""End-to-end training driver — the paper's mechanism governing a live job.

Wires together every substrate: data pipeline -> jit train step (pjit
shardings) -> monitor (per-step utilization series) -> GP forecaster ->
safeguard buffer -> elastic controller -> checkpoint manager + restart
ledger.  On CPU this trains a genuinely small model end-to-end (the
quickstart example); on TPU the same driver scales by mesh geometry.

The shaper integration: each step reports a utilization sample (HBM
high-water proxy + step time).  Every ``shape_interval`` steps the
forecaster predicts the job's near-future utilization; the elastic
controller quantizes the granted allocation to a DP width; a width
change triggers checkpoint -> re-mesh -> reshard -> resume, which is
the paper's elastic-component resize executed as preempt-to-checkpoint.

Usage:
  python -m repro.launch.train --arch internlm2-1.8b --steps 200 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import CheckpointManager
from repro.core.forecast import GPConfig, GPForecaster
from repro.core.monitor import Monitor
from repro.core.shaper import SafeguardConfig, shaped_demand
from repro.data import DataConfig, SyntheticStream
from repro.distributed import sharding as Sh
from repro.distributed.fault import RestartLedger, StragglerDetector
from repro.launch.mesh import make_host_mesh
from repro.models import get_config
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train import TrainConfig, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model config (CPU-trainable ~100M-class)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--shape-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    tc = TrainConfig(optim=AdamWConfig(lr=1e-3, warmup_steps=20,
                                       total_steps=args.steps))

    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    opt = adamw_init(params)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          Sh.param_specs(params, mesh))
    params = jax.tree.map(jax.device_put, params, pshard)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    ledger = RestartLedger(args.ckpt_dir + "/ledger.jsonl")
    start_step = 0
    if args.resume and ckpt.latest() is not None:
        (params, opt), start_step = ckpt.restore((params, opt))
        ledger.record("resumed", step=start_step)
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
    data = SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))

    # --- the paper's mechanism, attached to a live job ------------------
    mon = Monitor(slots=1, window=24)
    forecaster = GPForecaster(GPConfig(history=8, max_patterns=8,
                                       opt_steps=8))
    guard = SafeguardConfig(k1=0.05, k2=3.0)
    stragglers = StragglerDetector()
    # utilization proxy: activation footprint varies with batch shape; on
    # a real cluster this is the HBM high-water + per-host step time
    n_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(params))

    losses = []
    t_last = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if cfg.family == "vlm":
            batch["img_embeds"] = jnp.zeros(
                (args.batch, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
        params, opt, stats = step_fn(params, opt, batch)
        loss = float(stats["loss"])
        losses.append(loss)

        dt = time.time() - t_last
        t_last = time.time()
        stragglers.record(0, dt)
        util = n_bytes * (0.6 + 0.4 * np.tanh(loss))  # demo signal
        mon.record(np.asarray([0]), np.asarray([dt], np.float32),
                   np.asarray([util / 2**30], np.float32))

        if step % args.shape_every == 0 and mon.ready(
                np.asarray([0]), grace=10)[0]:
            w, v = mon.windows(np.asarray([0]))
            fc = forecaster.forecast(jnp.asarray(w[0, :, 1]), 3,
                                     valid=jnp.asarray(v[0]))
            demand = shaped_demand(fc.mean.max(), n_bytes / 2**30,
                                   fc.var.max(), guard)
            print(f"[shaper] step {step}: mem forecast "
                  f"{float(fc.mean.max()):.2f}GiB "
                  f"+/- {float(jnp.sqrt(fc.var.max())):.2f} -> grant "
                  f"{float(demand):.2f}GiB")

        if step and step % args.ckpt_every == 0:
            ckpt.save_async(step, (params, opt))
            ledger.record("checkpoint_committed", step=step)

        if step % 20 == 0:
            print(f"step {step}: loss {loss:.4f} "
                  f"({dt * 1e3:.0f} ms/step)")

    ckpt.wait()
    ckpt.save(args.steps, (params, opt))
    ledger.record("checkpoint_committed", step=args.steps)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return {"first_loss": losses[0], "final_loss": losses[-1]}


if __name__ == "__main__":
    main()
