"""Model zoo: 10 assigned architectures behind one functional API."""
from repro.models.config import ModelConfig, smoke_config
from repro.models.registry import ARCHS, get_config, list_archs

__all__ = ["ModelConfig", "smoke_config", "ARCHS", "get_config",
           "list_archs"]
