"""GQA attention with RoPE, KV cache, sliding windows and kernel dispatch.

Three execution paths share one parameter layout:
  * training / prefill: full-sequence attention (optionally causal or
    windowed), dispatched to the Pallas flash kernel on TPU or the XLA
    reference elsewhere (``cfg.attn_impl``);
  * decode: single-token query against a mutable KV cache
    (functionally updated — caches are pytrees threaded by serve_step).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array


class KVCache(NamedTuple):
    k: Array        # (B, n_kv, T, dh)
    v: Array        # (B, n_kv, T, dh)
    length: Array   # () int32 — valid prefix length


def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": L.init_linear(kq, d, cfg.q_dim, cfg.dtype),
        "wk": L.init_linear(kk, d, cfg.kv_dim, cfg.dtype),
        "wv": L.init_linear(kv, d, cfg.kv_dim, cfg.dtype),
        "wo": L.init_linear(ko, cfg.q_dim, d, cfg.dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> KVCache:
    dtype = dtype or cfg.dtype
    shape = (batch, cfg.kv_heads_eff, max_len, cfg.dh)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def _split_heads(x: Array, n: int, dh: int) -> Array:
    B, S, _ = x.shape
    return x.reshape(B, S, n, dh).transpose(0, 2, 1, 3)


def _merge_heads(x: Array) -> Array:
    B, H, S, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * dh)


def _masked_ref_attention(q, k, v, *, causal, window, kv_len, sm_scale):
    """XLA attention with optional sliding window and cache-length mask.

    q: (B,Hq,S,D); k/v: (B,Hkv,T,D).  kv_len masks keys >= kv_len
    (decode with a partially filled cache).  Queries align to the END of
    the valid prefix: qpos = kv_len - S + i.

    GQA-native: q is reshaped to (B, Hkv, group, S, D) and contracted
    against K/V directly — no materialized jnp.repeat, no fp32 upcast of
    the (large) K/V tensors; accumulation is fp32 via the einsum's
    preferred_element_type.  (§Perf iteration 2: the repeat+upcast was
    ~100x the KV-cache bytes on the decode cells.)
    """
    B, Hq, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, S, D)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * sm_scale
    kpos = jnp.arange(T)[None, :]
    qpos = (kv_len - S) + jnp.arange(S)[:, None]
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    # window may be a traced per-layer scalar (hybrid archs mix windowed
    # and global layers inside one scan-over-layers); 0 = full attention
    window = jnp.asarray(window, jnp.int32)
    mask &= (window <= 0) | (kpos > qpos - window)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, S, D).astype(q.dtype)


def attend(q: Array, k: Array, v: Array, cfg: ModelConfig, *,
           causal: bool, window=0,
           kv_len: Array | None = None) -> Array:
    """Dispatch: Pallas flash kernel when eligible, XLA reference else."""
    sm_scale = cfg.dh ** -0.5
    full_len = kv_len is None
    static_no_window = isinstance(window, int) and window == 0
    if (cfg.attn_impl in ("flash", "auto") and static_no_window and full_len
            and causal and q.shape[2] >= 8):
        impl = "pallas" if cfg.attn_impl == "flash" else "auto"
        return kops.attention(q, k, v, causal=True, sm_scale=sm_scale,
                              impl=impl)
    if kv_len is None:
        kv_len = jnp.asarray(k.shape[2], jnp.int32)
    return _masked_ref_attention(q, k, v, causal=causal, window=window,
                                 kv_len=kv_len, sm_scale=sm_scale)


def project_qkv(p: dict, x: Array, cfg: ModelConfig, *,
                positions: Array, rope: bool = True):
    """q/k/v projections + KV-head padding + RoPE (shared by the
    teacher-forced block and the carry-cache decode path)."""
    B, S, _ = x.shape
    q = _split_heads(L.matmul(x, p["wq"]), cfg.n_heads, cfg.dh)
    k = _split_heads(L.matmul(x, p["wk"]), cfg.n_kv, cfg.dh)
    v = _split_heads(L.matmul(x, p["wv"]), cfg.n_kv, cfg.dh)
    if cfg.pad_kv_heads and cfg.pad_kv_heads > cfg.n_kv:
        rep = cfg.pad_kv_heads // cfg.n_kv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p: dict, x: Array, cfg: ModelConfig, *,
                    positions: Array, causal: bool = True,
                    window=0, rope: bool = True,
                    cache: KVCache | None = None,
                    kv_override: tuple[Array, Array] | None = None,
                    ) -> tuple[Array, KVCache | None]:
    """Full attention sub-block: projections + rope + attend + output.

    With ``cache``: appends this call's K/V at cache.length and attends
    against the valid prefix (decode or incremental prefill).
    ``kv_override`` supplies external K/V inputs (cross-attention).
    """
    B, S, _ = x.shape
    q = _split_heads(L.matmul(x, p["wq"]), cfg.n_heads, cfg.dh)
    if kv_override is None:
        xkv = x
    else:
        xkv = kv_override[0]
    k = _split_heads(L.matmul(xkv, p["wk"]), cfg.n_kv, cfg.dh)
    v = _split_heads(L.matmul(xkv, p["wv"]), cfg.n_kv, cfg.dh)
    if cfg.pad_kv_heads and cfg.pad_kv_heads > cfg.n_kv:
        # replicate KV heads so the cache's head dim divides the TP axis
        # (n_kv | pad | n_heads): pure layout change, attention-identical
        rep = cfg.pad_kv_heads // cfg.n_kv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    if rope and kv_override is None:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache.length, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache.length, axis=2)
        kv_len = cache.length + S
        new_cache = KVCache(k=kc, v=vc, length=kv_len)
        out = attend(q, kc, vc, cfg, causal=causal, window=window,
                     kv_len=kv_len)
    else:
        out = attend(q, k, v, cfg, causal=causal, window=window)

    return L.matmul(_merge_heads(out), p["wo"]), new_cache
