"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int                    # dense MLP width (0 = no dense MLP)
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_ff: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid (Hymba, xLSTM)
    ssm_state: int = 0
    conv_kernel: int = 4
    window: int = 0              # sliding-window attention (0 = full)
    global_every: int = 0        # hybrid: every k-th layer uses full attn
    slstm_every: int = 0         # xLSTM: every k-th layer is sLSTM

    # encoder-decoder (Whisper)
    encdec: bool = False
    dec_layers: int = 0
    dec_len: int = 448

    # VLM stub frontend
    n_img_tokens: int = 0

    # numerics / implementation
    dtype: Any = jnp.bfloat16
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    attn_impl: str = "ref"       # "ref" (XLA) | "flash" (Pallas) | "auto"
    remat: str = "dots"          # none | dots | full
    scan_layers: bool = True
    # perf variants (EXPERIMENTS.md §Perf)
    pad_kv_heads: int = 0        # replicate KV heads to this count so the
                                 # cache shards across a TP axis > n_kv
    mlstm_chunk: int = 0         # chunkwise-parallel mLSTM chunk (0 = scan)

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.dh

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.dh

    @property
    def kv_heads_eff(self) -> int:
        """KV heads materialized in the cache (after replication pad)."""
        return self.pad_kv_heads or self.n_kv

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def subquadratic(self) -> bool:
        """True iff decode state does not grow linearly in an unbounded
        attention window (the long_500k eligibility test)."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, dh = self.d_model, self.dh
        emb = self.vocab * d * 2  # in + lm_head (untied)
        per = 0
        per += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d  # attn
        if self.d_ff:
            per += 3 * d * self.d_ff                                  # swiglu
        if self.is_moe:
            per += d * self.n_experts
            per += self.n_experts * 3 * d * self.expert_ff
        if self.family == "hybrid":
            per += 2 * d * self.d_model + self.d_model * (2 * self.ssm_state)
        per += 2 * d                                                  # norms
        n = emb + self.n_layers * per
        if self.encdec:
            n += self.dec_layers * (per + d * self.q_dim * 2)         # cross
        return n

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        per_dense = (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                     + d * self.n_experts + 2 * d)
        per_active = self.top_k * 3 * d * self.expert_ff
        return (self.vocab * d * 2
                + self.n_layers * (per_dense + per_active))


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: 2 layers, narrow
    widths, tiny vocab — exercises the identical code paths."""
    return dataclasses.replace(
        cfg,
        n_layers=2,
        dec_layers=min(cfg.dec_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        expert_ff=64 if cfg.expert_ff else 0,
        ssm_state=min(cfg.ssm_state, 8),
        slstm_every=min(cfg.slstm_every, 2),
        window=min(cfg.window, 16) if cfg.window else 0,
        n_img_tokens=min(cfg.n_img_tokens, 8),
        dec_len=16,
        dtype=jnp.float32,
        remat="none",
    )
