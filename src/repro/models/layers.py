"""Shared neural building blocks: norms, MLPs, embeddings, RoPE.

Functional style: ``init_*`` returns a param pytree, ``apply`` functions
are pure.  All matmuls accumulate in fp32 via ``preferred_element_type``
(bf16 weights on TPU), and every parameter leaf gets a logical sharding
spec through ``repro.distributed.sharding`` at jit boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def init_linear(key, d_in: int, d_out: int, dtype) -> Array:
    return _dense_init(key, (d_in, d_out), dtype)


def matmul(x: Array, w: Array) -> Array:
    """fp32-accumulating matmul that keeps the activation dtype."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------

def init_rmsnorm(d: int) -> Array:
    return jnp.ones((d,), jnp.float32)


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def init_layernorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(x: Array, p: dict, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)
            * p["scale"] + p["bias"]).astype(x.dtype)


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------

def init_swiglu(key, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": init_linear(k1, d, ff, dtype),
            "up": init_linear(k2, d, ff, dtype),
            "down": init_linear(k3, ff, d, dtype)}


def swiglu(p: dict, x: Array) -> Array:
    g = matmul(x, p["gate"])
    u = matmul(x, p["up"])
    return matmul(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
                  p["down"])


def init_gelu_mlp(key, d: int, ff: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {"up": init_linear(k1, d, ff, dtype),
            "down": init_linear(k2, ff, d, dtype)}


def gelu_mlp(p: dict, x: Array) -> Array:
    h = matmul(x, p["up"])
    return matmul(jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype),
                  p["down"])


# ----------------------------------------------------------------------
# embeddings + RoPE
# ----------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * (d ** -0.5)).astype(dtype)


def rope_freqs(dh: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, H, S, Dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                         # (dh/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv                          # (..., S, dh/2)
    if ang.ndim == 2:                                   # (S, dh/2)
        ang = ang[None, None]
    else:                                               # (B, S, dh/2)
        ang = ang[:, None]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)
