"""Mixture-of-Experts block with sort-based dispatch (OLMoE / Granite-MoE).

Top-k softmax routing with capacity clamping, implemented as a
sort-scatter-gather pipeline rather than the one-hot einsum dispatch:
at train_4k scale (1M tokens, 64 experts, top-8) a (T, E, C) dispatch
tensor is ~10^17 elements — the sort-based form is O(T*K*D + E*C*D) and
shards cleanly: expert weights are laid out (E, d, ff) with E over the
``model`` mesh axis (expert parallelism), token buffers over ``data``;
GSPMD materializes the token exchange as all-to-alls.

Load-balancing auxiliary loss (Switch-style) is returned for the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array


def init_moe(key, cfg: ModelConfig) -> dict:
    kr, ke = jax.random.split(key)
    d, ff, E = cfg.d_model, cfg.expert_ff, cfg.n_experts
    kg, ku, kd = jax.random.split(ke, 3)
    return {
        "router": L.init_linear(kr, d, E, jnp.float32),
        "gate": L._dense_init(kg, (E, d, ff), cfg.dtype),
        "up": L._dense_init(ku, (E, d, ff), cfg.dtype),
        "down": L._dense_init(kd, (E, ff, d), cfg.dtype, in_axis=1),
    }


def moe_block(p: dict, x: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """Returns (output (B,S,D), aux_loss ())."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = L.matmul(xt.astype(jnp.float32), p["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    me = probs.mean(0)                                            # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch -----------------------------------------
    # serving-scale token counts get NO-DROP capacity (decode correctness:
    # incremental must equal teacher-forced); train-scale uses the usual
    # capacity-factor clamp
    if T * K <= 4096:
        cap = min(T * K, T)
    else:
        cap = int(cfg.capacity_factor * T * K / E + 0.999)
    flat_e = expert_idx.reshape(-1)                               # (T*K,)
    order = jnp.argsort(flat_e)                                   # stable
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * K) - starts[sorted_e]
    keep = pos < cap
    slot = jnp.where(keep, sorted_e * cap + pos, E * cap)         # overflow->sink
    token = order // K

    xe = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].set(
        xt[token], mode="drop")
    xe = xe[:-1].reshape(E, cap, D)

    # ---- expert FFN (swiglu), vmapped over experts --------------------
    def ffn(xb, wg, wu, wd):
        g = jax.lax.dot_general(xb, wg, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        u = jax.lax.dot_general(xb, wu, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xb.dtype)
        return jax.lax.dot_general(h, wd, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32
                                   ).astype(xb.dtype)

    he = jax.vmap(ffn)(xe, p["gate"], p["up"], p["down"])          # (E,cap,D)
    he = he.reshape(E * cap, D)

    # ---- combine -------------------------------------------------------
    gathered = jnp.where(keep[:, None],
                         he[jnp.minimum(slot, E * cap - 1)], 0.0)
    w = gate_vals.reshape(-1)[order][:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[token].add(gathered * w)
    return out.reshape(B, S, D), aux
