"""name -> (config, init, forward) resolution for every assigned arch."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, smoke_config

ARCHS = [
    "phi-3-vision-4.2b",
    "codeqwen1.5-7b",
    "glm4-9b",
    "granite-3-8b",
    "internlm2-1.8b",
    "olmoe-1b-7b",
    "granite-moe-1b-a400m",
    "hymba-1.5b",
    "xlstm-1.3b",
    "whisper-large-v3",
]


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    cfg = _module(name).CONFIG
    return smoke_config(cfg) if smoke else cfg


def list_archs() -> list[str]:
    return list(ARCHS)
