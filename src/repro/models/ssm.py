"""Selective state-space mixer (Mamba S6 style) — the SSM half of Hymba.

Training/prefill uses a *chunked* scan: an outer ``lax.scan`` over
sequence chunks carrying the (B, d, N) state, with an associative scan
inside each chunk.  The naive full-sequence associative scan would
materialize a (B, S, d, N) fp32 tensor — at train_4k scale that is
O(100 TB); chunking bounds the transient to (B, chunk, d, N), which is
the TPU-native equivalent of the CUDA fused-scan kernel's tiling
(DESIGN.md hardware-adaptation notes).

Decode is the O(1) recurrent step on the carried state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array

CHUNK = 32


def init_ssm(key, cfg: ModelConfig) -> dict:
    d, N = cfg.d_model, cfg.ssm_state
    kin, kdt, kb, kc, kout, kA = jax.random.split(key, 6)
    return {
        "in_proj": L.init_linear(kin, d, d, cfg.dtype),
        "conv": (jax.random.normal(kin, (cfg.conv_kernel, d), jnp.float32)
                 * 0.1).astype(cfg.dtype),
        "w_dt": L.init_linear(kdt, d, d, cfg.dtype),
        "dt_bias": jnp.zeros((d,), jnp.float32),
        "w_B": L.init_linear(kb, d, N, cfg.dtype),
        "w_C": L.init_linear(kc, d, N, cfg.dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                  (d, 1))),
        "D": jnp.ones((d,), jnp.float32),
        "out_proj": L.init_linear(kout, d, d, cfg.dtype),
    }


def init_ssm_state(cfg: ModelConfig, batch: int) -> dict:
    d, N = cfg.d_model, cfg.ssm_state
    return {"h": jnp.zeros((batch, d, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d), jnp.float32)}


def _causal_conv(x: Array, w: Array, prefix: Array | None) -> Array:
    """Depthwise causal conv1d.  x: (B, S, d), w: (K, d)."""
    K = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out, xp[:, -(K - 1):] if K > 1 else prefix


def _ssm_params(p: dict, u: Array):
    """u: (B, S, d) post-conv activations -> discretized dA, dBx, C."""
    A = -jnp.exp(p["A_log"])                                     # (d, N)
    dt = jax.nn.softplus(
        L.matmul(u, p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    Bm = L.matmul(u, p["w_B"]).astype(jnp.float32)               # (B,S,N)
    Cm = L.matmul(u, p["w_C"]).astype(jnp.float32)               # (B,S,N)
    dA = jnp.exp(dt[..., None] * A)                              # (B,S,d,N)
    dBx = (dt * u.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    return dA, dBx, Cm


def ssm_mixer(p: dict, x: Array, cfg: ModelConfig,
              state: dict | None = None) -> tuple[Array, dict | None]:
    """x: (B, S, d).  Returns (y, new_state)."""
    B, S, d = x.shape
    u = L.matmul(x, p["in_proj"])
    conv_prefix = state["conv"] if state is not None else None
    u, new_conv = _causal_conv(u, p["conv"], conv_prefix)
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)

    h0 = (state["h"] if state is not None
          else jnp.zeros((B, d, cfg.ssm_state), jnp.float32))

    if S == 1:   # decode: O(1) recurrence
        dA, dBx, Cm = _ssm_params(p, u)
        h = dA[:, 0] * h0 + dBx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None, :]
        new_state = {"h": h, "conv": new_conv.astype(jnp.float32)}
    else:
        chunk = min(CHUNK, S)
        assert S % chunk == 0, (S, chunk)
        uc = u.reshape(B, S // chunk, chunk, d).transpose(1, 0, 2, 3)

        def step(h, u_ch):
            dA, dBx, Cm = _ssm_params(p, u_ch)
            # prepend carry as a virtual step, associative-scan the chunk
            def op(a, b):
                return (b[0] * a[0], b[0] * a[1] + b[1])
            dA_all = jnp.concatenate(
                [jnp.ones((B, 1, d, cfg.ssm_state)), dA], axis=1)
            dBx_all = jnp.concatenate([h[:, None], dBx], axis=1)
            _, hs = jax.lax.associative_scan(op, (dA_all, dBx_all), axis=1)
            hs = hs[:, 1:]                                       # (B,c,d,N)
            y = jnp.einsum("bcdn,bcn->bcd", hs, Cm)
            return hs[:, -1], y

        h_last, ys = jax.lax.scan(step, h0, uc)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, d)
        new_state = ({"h": h_last, "conv": new_conv.astype(jnp.float32)}
                     if state is not None else None)

    y = y + p["D"] * u.astype(jnp.float32)
    y = y.astype(x.dtype)
    return L.matmul(y, p["out_proj"]), new_state
