"""Decoder-only LM covering the dense / vlm / moe / hybrid families.

One scanned block body serves every depth: per-layer parameters are
stacked on a leading axis and consumed by ``lax.scan`` (compact HLO,
fast compiles — essential for the 40-cell dry-run).  Per-layer
structural variation (Hymba's windowed-vs-global attention) rides along
as a scanned ``meta`` array rather than unrolled branches.

Families:
  dense  — pre-norm GQA attention + SwiGLU MLP (CodeQwen/GLM/Granite/
           InternLM and the Phi-3-vision backbone);
  vlm    — dense backbone; image patch embeddings (stub frontend)
           overlay the first ``n_img_tokens`` positions;
  moe    — attention + sort-dispatch MoE (OLMoE, Granite-MoE);
  hybrid — Hymba: attention and SSM mixer run in PARALLEL on the same
           normed input, each post-normed, averaged, then MLP;
  ssm    — delegated to models.xlstm (different block algebra).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S_mod
from repro.models import xlstm as X
from repro.models.config import ModelConfig

S = S_mod  # legacy alias used by the block path

Array = jax.Array


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig) -> dict:
    ka, km, ks = jax.random.split(key, 3)
    p: dict[str, Any] = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": A.init_attention(ka, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = M.init_moe(km, cfg)
    elif cfg.d_ff:
        p["mlp"] = L.init_swiglu(km, cfg.d_model, cfg.d_ff, cfg.dtype)
    if cfg.family == "hybrid":
        p["ssm"] = S.init_ssm(ks, cfg)
        p["norm_attn"] = L.init_rmsnorm(cfg.d_model)
        p["norm_ssm"] = L.init_rmsnorm(cfg.d_model)
    return p


def layer_meta(cfg: ModelConfig) -> dict:
    """Per-layer scanned metadata."""
    idx = jnp.arange(cfg.n_layers)
    if cfg.family == "hybrid" and cfg.window:
        ge = max(cfg.global_every, 1)
        window = jnp.where(idx % ge == 0, 0, cfg.window).astype(jnp.int32)
    else:
        window = jnp.zeros((cfg.n_layers,), jnp.int32)
    return {"window": window}


def init_lm(key, cfg: ModelConfig) -> dict:
    ke, kb, kh = jax.random.split(key, 3)
    if cfg.family == "ssm":
        return {
            "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, cfg.dtype),
            "xlstm": X.init_xlstm_stack(kb, cfg),
            "final_norm": L.init_rmsnorm(cfg.d_model),
            "lm_head": L.init_linear(kh, cfg.d_model, cfg.vocab, cfg.dtype),
        }
    bkeys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(bkeys)
    return {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": blocks,
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "lm_head": L.init_linear(kh, cfg.d_model, cfg.vocab, cfg.dtype),
    }


# ----------------------------------------------------------------------
# caches (decode / incremental prefill)
# ----------------------------------------------------------------------

class LayerCache(NamedTuple):
    attn: A.KVCache | None
    ssm: dict | None


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Per-layer decode caches.

    cfg.scan_layers=True  -> stacked (leading n_layers axis), consumed by
                             the scan path;
    cfg.scan_layers=False -> a LIST of per-layer caches (vLLM-style
                             layout): each layer's buffer is donated and
                             updated in place with a STATIC index — the
                             decode HBM floor (§Perf iteration 4).
    """
    if cfg.family == "ssm":
        return X.init_xlstm_states(cfg, batch)
    if not cfg.scan_layers:
        out = []
        for _ in range(cfg.n_layers):
            kv = A.init_cache(cfg, batch, max_len)
            ssm = (S_mod.init_ssm_state(cfg, batch)
                   if cfg.family == "hybrid" else None)
            out.append(LayerCache(attn=kv, ssm=ssm))
        return out
    Ln = cfg.n_layers
    # windowed layers only ever read the trailing ``window`` positions,
    # but we keep a uniform max_len cache for scan homogeneity; the
    # hymba window cache optimization is a documented perf lever.
    kv = A.KVCache(
        k=jnp.zeros((Ln, batch, cfg.kv_heads_eff, max_len, cfg.dh),
                    cfg.dtype),
        v=jnp.zeros((Ln, batch, cfg.kv_heads_eff, max_len, cfg.dh),
                    cfg.dtype),
        length=jnp.zeros((Ln,), jnp.int32))
    ssm = None
    if cfg.family == "hybrid":
        st = S.init_ssm_state(cfg, batch)
        ssm = jax.tree.map(lambda x: jnp.zeros((Ln,) + x.shape, x.dtype), st)
    return LayerCache(attn=kv, ssm=ssm)


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------

def _block(lp: dict, x: Array, cfg: ModelConfig, *, positions, meta,
           cache: LayerCache | None):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_cache = cache.attn if cache is not None else None
    out_a, new_attn = A.attention_block(
        lp["attn"], h, cfg, positions=positions, causal=True,
        window=meta["window"], cache=attn_cache)
    new_ssm = None
    if cfg.family == "hybrid":
        ssm_state = cache.ssm if cache is not None else None
        out_s, new_ssm = S.ssm_mixer(lp["ssm"], h, cfg, ssm_state)
        out_a = 0.5 * (L.rms_norm(out_a, lp["norm_attn"], cfg.norm_eps)
                       + L.rms_norm(out_s, lp["norm_ssm"], cfg.norm_eps))
    x = x + out_a
    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        out_m, aux = M.moe_block(lp["moe"], h2, cfg)
    elif cfg.d_ff:
        out_m = L.swiglu(lp["mlp"], h2)
    else:
        out_m = jnp.zeros_like(h2)
    x = x + out_m
    new_cache = (LayerCache(attn=new_attn, ssm=new_ssm)
                 if cache is not None else None)
    return x, new_cache, aux


def _forward_decode_carry(params: dict, cfg: ModelConfig, x: Array,
                          positions: Array, caches: LayerCache):
    """Decode/incremental path with the stacked KV cache as a scan CARRY.

    §Perf iteration 3: threading per-layer caches through scan xs->ys
    forces XLA to copy each layer's full cache every step (~2x cache
    bytes per token).  As a carry, the token-slice dynamic-update-slice
    aliases in place: per-layer traffic = one cache READ (the attention
    must read it) + a token-sized write — the HBM floor for decode.
    """
    Ln = cfg.n_layers
    kc, vc = caches.attn.k, caches.attn.v          # (L,B,Hkv,T,dh)
    length = caches.attn.length[0]
    S = x.shape[1]
    meta = layer_meta(cfg)
    idxs = jnp.arange(Ln)
    ssm_xs = caches.ssm if caches.ssm is not None else 0 * idxs

    def body(carry, per_layer):
        xc, kc, vc = carry
        lp, mt, idx, ssm_st = per_layer
        h = L.rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q, k, v = A.project_qkv(lp["attn"], h, cfg, positions=positions)
        kc = jax.lax.dynamic_update_slice(
            kc, k[None].astype(kc.dtype), (idx, 0, 0, length, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v[None].astype(vc.dtype), (idx, 0, 0, length, 0))
        k_all = jax.lax.dynamic_index_in_dim(kc, idx, 0, keepdims=False)
        v_all = jax.lax.dynamic_index_in_dim(vc, idx, 0, keepdims=False)
        out_a = A.attend(q, k_all, v_all, cfg, causal=True,
                         window=mt["window"], kv_len=length + S)
        out_a = L.matmul(A._merge_heads(out_a), lp["attn"]["wo"])
        new_ssm = None
        if cfg.family == "hybrid":
            out_s, new_ssm = S_mod.ssm_mixer(lp["ssm"], h, cfg, ssm_st)
            out_a = 0.5 * (L.rms_norm(out_a, lp["norm_attn"], cfg.norm_eps)
                           + L.rms_norm(out_s, lp["norm_ssm"], cfg.norm_eps))
        xc = xc + out_a
        h2 = L.rms_norm(xc, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            out_m, _ = M.moe_block(lp["moe"], h2, cfg)
        elif cfg.d_ff:
            out_m = L.swiglu(lp["mlp"], h2)
        else:
            out_m = jnp.zeros_like(h2)
        return (xc + out_m, kc, vc), new_ssm

    (x, kc, vc), new_ssm = jax.lax.scan(
        body, (x, kc, vc), (params["blocks"], meta, idxs, ssm_xs))
    new_caches = LayerCache(
        attn=A.KVCache(k=kc, v=vc, length=caches.attn.length + S),
        ssm=new_ssm if cfg.family == "hybrid" else None)
    return x, new_caches


def forward(params: dict, cfg: ModelConfig, *, tokens: Array | None = None,
            embeds: Array | None = None, img_embeds: Array | None = None,
            positions: Array | None = None, caches=None,
            want_logits: bool = True):
    """Returns (logits | hidden, new_caches, aux).

    tokens: (B, S) int32 — or ``embeds``: (B, S, D) pre-embedded (audio
    frames / serving with external embedding service).
    img_embeds: (B, n_img, D) VLM stub-frontend patch embeddings,
    overlaid on the first n_img positions.
    caches: stacked per-layer caches -> decode/incremental mode.
    """
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds.astype(cfg.dtype)
    if img_embeds is not None and cfg.n_img_tokens:
        n = cfg.n_img_tokens
        x = jnp.concatenate([img_embeds.astype(cfg.dtype)[:, :n],
                             x[:, n:]], axis=1)
    B, Sq, _ = x.shape
    if positions is None:
        if isinstance(caches, list):
            base = caches[0].attn.length
        elif caches is not None and cfg.family != "ssm":
            base = caches.attn.length[0]
        else:
            base = 0
        positions = base + jnp.arange(Sq)

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        x, new_caches = X.xlstm_stack(params["xlstm"], x, cfg, caches)
    elif isinstance(caches, list):
        # serving, unrolled: per-layer donated buffers, static in-place
        # updates (§Perf iteration 4 — the decode HBM floor)
        meta = layer_meta(cfg)
        new_caches = []
        for i, ca in enumerate(caches):
            lp = jax.tree.map(lambda p: p[i], params["blocks"])
            mt = {"window": meta["window"][i]}
            x, new_ca, _ = _block(lp, x, cfg, positions=positions,
                                  meta=mt, cache=ca)
            new_caches.append(new_ca)
    elif caches is not None:
        # serving: stacked-carry cache path (§Perf iteration 3)
        x, new_caches = _forward_decode_carry(params, cfg, x, positions,
                                              caches)
    else:
        meta = layer_meta(cfg)

        def body(xc, per_layer):
            lp, mt = per_layer
            y, _, aux = _block(lp, xc, cfg, positions=positions,
                               meta=mt, cache=None)
            return y, aux

        if cfg.remat == "full":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

        x, auxs = jax.lax.scan(body, x, (params["blocks"], meta))
        new_caches = None
        aux_total = auxs.sum()

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if not want_logits:
        return x, new_caches, aux_total
    logits = L.matmul(x, params["lm_head"])
    return logits, new_caches, aux_total
