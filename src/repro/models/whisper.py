"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, S_frames, d_model) directly.
The backbone is faithful: sinusoidal positions, pre-LN bidirectional
encoder; decoder with causal self-attention + cross-attention to the
encoder output + GELU MLPs (whisper-large-v3: 32 enc + 32 dec layers,
d=1280, 20 heads).

Shapes honored as assigned: ``train_4k``/``prefill_32k`` treat seq_len
as the encoder FRAME length with a ``dec_len`` teacher-forced target;
``decode_32k`` is one decoder step cross-attending a 32k-frame encoder
output (DESIGN.md notes the 448-token real-world decoder limit).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array


def sinusoids(length: int, d: int) -> Array:
    t = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    ang = t * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_block(key, cfg: ModelConfig) -> dict:
    ka, km = jax.random.split(key)
    return {
        "ln1": L.init_layernorm(cfg.d_model),
        "attn": A.init_attention(ka, cfg),
        "ln2": L.init_layernorm(cfg.d_model),
        "mlp": L.init_gelu_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def _init_dec_block(key, cfg: ModelConfig) -> dict:
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": L.init_layernorm(cfg.d_model),
        "self_attn": A.init_attention(ka, cfg),
        "ln_x": L.init_layernorm(cfg.d_model),
        "cross_attn": A.init_attention(kc, cfg),
        "ln2": L.init_layernorm(cfg.d_model),
        "mlp": L.init_gelu_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def init_whisper(key, cfg: ModelConfig) -> dict:
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.n_layers)
    dec_keys = jax.random.split(kd, cfg.dec_layers or cfg.n_layers)
    return {
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_ln": L.init_layernorm(cfg.d_model),
        "tok_embed": L.init_embedding(kt, cfg.vocab, cfg.d_model, cfg.dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "dec_ln": L.init_layernorm(cfg.d_model),
        "lm_head": L.init_linear(kh, cfg.d_model, cfg.vocab, cfg.dtype),
    }


def encode(params: dict, frames: Array, cfg: ModelConfig) -> Array:
    """frames: (B, S, d_model) stub frontend output -> encoder states."""
    x = frames.astype(cfg.dtype)
    x = x + sinusoids(x.shape[1], cfg.d_model).astype(cfg.dtype)
    pos = jnp.arange(x.shape[1])

    def body(xc, lp):
        h = L.layer_norm(xc, lp["ln1"], cfg.norm_eps)
        out, _ = A.attention_block(lp["attn"], h, cfg, positions=pos,
                                   causal=False, rope=False)
        xc = xc + out
        h = L.layer_norm(xc, lp["ln2"], cfg.norm_eps)
        return xc + L.gelu_mlp(lp["mlp"], h), None

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.layer_norm(x, params["enc_ln"], cfg.norm_eps)


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int) -> A.KVCache:
    Ln = cfg.dec_layers or cfg.n_layers
    return A.KVCache(
        k=jnp.zeros((Ln, batch, cfg.n_kv, max_len, cfg.dh), cfg.dtype),
        v=jnp.zeros((Ln, batch, cfg.n_kv, max_len, cfg.dh), cfg.dtype),
        length=jnp.zeros((Ln,), jnp.int32))


def decode(params: dict, tokens: Array, enc_out: Array, cfg: ModelConfig,
           caches: A.KVCache | None = None):
    """Teacher-forced (caches=None) or incremental decoder pass."""
    x = params["tok_embed"][tokens]
    base = caches.length[0] if caches is not None else 0
    S = x.shape[1]
    pos_emb = sinusoids(cfg.dec_len, cfg.d_model).astype(cfg.dtype)
    pos_idx = base + jnp.arange(S)
    x = x + pos_emb[jnp.clip(pos_idx, 0, cfg.dec_len - 1)]

    def body(xc, per_layer):
        lp, ca = per_layer
        h = L.layer_norm(xc, lp["ln1"], cfg.norm_eps)
        out, new_ca = A.attention_block(lp["self_attn"], h, cfg,
                                        positions=pos_idx, causal=True,
                                        rope=False, cache=ca)
        xc = xc + out
        h = L.layer_norm(xc, lp["ln_x"], cfg.norm_eps)
        out, _ = A.attention_block(lp["cross_attn"], h, cfg,
                                   positions=pos_idx, causal=False,
                                   rope=False, kv_override=(enc_out, enc_out))
        xc = xc + out
        h = L.layer_norm(xc, lp["ln2"], cfg.norm_eps)
        return xc + L.gelu_mlp(lp["mlp"], h), new_ca

    if cfg.remat != "none" and caches is None:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    if caches is None:
        x, _ = jax.lax.scan(lambda xc, lp: body(xc, (lp, None)),
                            x, params["dec_blocks"])
        new_caches = None
    else:
        x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = L.layer_norm(x, params["dec_ln"], cfg.norm_eps)
    return L.matmul(x, params["lm_head"]), new_caches
