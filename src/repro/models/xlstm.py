"""xLSTM blocks: mLSTM (matrix memory) + sLSTM (scalar memory).

Follows arXiv:2405.04517 with the stabilized exponential gating
(running log-max stabilizer m_t).  Simplifications recorded in
DESIGN.md: sLSTM uses diagonal recurrence vectors instead of full
block-diagonal recurrent matrices.

Layer layout for an ``slstm_every = k`` config: groups of (k-1) mLSTM
layers + 1 sLSTM layer, scanned at both levels so the HLO stays compact
(one mLSTM body + one sLSTM body regardless of depth).

The mLSTM recurrence is inherently sequential over time (the matrix
memory C_t is rank-1-updated with input-dependent decay); training uses
``lax.scan`` over the sequence — each step is still a batch of MXU
outer-products/matvecs.  Decode carries (C, n, m) per layer: O(1) state,
which is why this arch runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array

PROJ = 2  # mLSTM up-projection factor (paper's 1.3B setting)


# ----------------------------------------------------------------------
# mLSTM
# ----------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = PROJ * d
    ks = jax.random.split(key, 8)
    return {
        "ln": L.init_rmsnorm(d),
        "w_up": L.init_linear(ks[0], d, di, cfg.dtype),
        "w_z": L.init_linear(ks[1], d, di, cfg.dtype),
        "wq": L.init_linear(ks[2], di, di, cfg.dtype),
        "wk": L.init_linear(ks[3], di, di, cfg.dtype),
        "wv": L.init_linear(ks[4], di, di, cfg.dtype),
        "w_i": L.init_linear(ks[5], di, cfg.n_heads, jnp.float32),
        "w_f": L.init_linear(ks[6], di, cfg.n_heads, jnp.float32),
        "f_bias": jnp.full((cfg.n_heads,), 3.0, jnp.float32),
        "gn": L.init_rmsnorm(di),
        "w_down": L.init_linear(ks[7], di, d, cfg.dtype),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.n_heads
    dh = PROJ * cfg.d_model // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def _mlstm_cell(carry, qkvif):
    """One time step.  carry: (C, n, m); q/k/v: (B,H,dh), i/f: (B,H)."""
    C, n, m = carry
    q, k, v, log_i, log_f = qkvif
    m_new = jnp.maximum(log_f + m, log_i)
    i_ = jnp.exp(log_i - m_new)[..., None]                      # (B,H,1)
    f_ = jnp.exp(log_f + m - m_new)[..., None]
    n_new = f_ * n + i_ * k
    C_new = f_[..., None] * C + i_[..., None] * (v[..., None] * k[..., None, :])
    num = jnp.einsum("bhij,bhj->bhi", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q)), 1.0)
    h = num / den[..., None]                                     # (B,H,dh)
    return (C_new, n_new, m_new), h


def _mlstm_chunkwise(q, k, v, log_i, log_f, carry, chunk: int):
    """Chunkwise-parallel mLSTM (stabilized) — §Perf optimization.

    The sequential cell materializes the (dh x dh) matrix memory EVERY
    timestep: O(S * B * H * dh^2) HBM traffic, the dominant roofline
    term of the xlstm train cell.  The chunkwise form (cf. the xLSTM
    kernels / chunkwise linear-attention lineage) materializes C only at
    chunk boundaries and handles intra-chunk interactions as masked
    (Tc x Tc) matmuls — traffic / chunk, MXU-friendly.

    Derivation (per head; b_t = cumsum(log f) within the chunk,
    a_j = log i_j - b_j,  g_t = max(m_in, cummax_{j<=t} a_j),
    m_t = b_t + g_t — identical to the sequential recurrence by
    induction):

      h~_t  = e^{m_in - g_t} (C~_in q_t)
              + sum_{j<=t} e^{a_j - g_t} (k_j.q_t) v_j
      den_t = max(|e^{m_in - g_t} (n~_in.q_t)
              + sum_{j<=t} e^{a_j - g_t} (k_j.q_t)|, 1)

    Every exponent is <= 0 by construction of g_t, so nothing overflows
    (including the m_in = -1e30 cold-start sentinel).  Matches the
    sequential cell exactly (tests/test_models.py::test_mlstm_chunkwise).

    q/k/v: (B, S, H, dh); log_i/log_f: (B, S, H);
    carry: (C~ (B,H,dh,dh), n~ (B,H,dh), m (B,H)).
    """
    B, S, H, dh = q.shape
    nc = S // chunk
    def resh(x):
        return x.reshape(B, nc, chunk, *x.shape[2:]).transpose(
            1, 0, *range(2, x.ndim + 1))
    qc, kc, vc = resh(q), resh(k), resh(v)            # (nc,B,Tc,H,dh)
    lic, lfc = resh(log_i), resh(log_f)               # (nc,B,Tc,H)

    def chunk_step(carry, xs):
        C_in, n_in, m_in = carry                      # (B,H,dh,dh) ...
        qt, kt, vt, li, lf = xs
        b = jnp.cumsum(lf, axis=1)                    # (B,Tc,H)
        a = li - b                                    # (B,Tc,H)
        g = jnp.maximum(m_in[:, None, :], jax.lax.cummax(a, axis=1))
        w_inter = jnp.exp(m_in[:, None, :] - g)       # (B,Tc,H), <= 1

        # inter-chunk: contribution of the carried state
        inter = jnp.einsum("bthd,bhed->bthe", qt, C_in)      # (B,Tc,H,dh)
        den_in = jnp.einsum("bthd,bhd->bth", qt, n_in)       # (B,Tc,H)

        # intra-chunk: masked (Tc x Tc) attention-like matmuls with the
        # pairwise stable weights  w[t,j] = e^{a_j - g_t}  (j <= t)
        s = jnp.einsum("bthd,bjhd->bhtj", qt, kt)            # (B,H,Tc,Tc)
        diff = (a.transpose(0, 2, 1)[:, :, None, :]
                - g.transpose(0, 2, 1)[:, :, :, None])       # (B,H,t,j)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        wmat = jnp.where(mask, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        sw = s * wmat                                        # (B,H,Tc,Tc)
        intra = jnp.einsum("bhtj,bjhd->bthd", sw, vt)
        den_intra = jnp.sum(sw, axis=3).transpose(0, 2, 1)   # (B,Tc,H)

        num = w_inter[..., None] * inter + intra
        den = w_inter * den_in + den_intra
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # carry to next chunk (materialized ONCE per chunk)
        gT = g[:, -1, :]                                     # (B,H)
        wT = jnp.exp(a - gT[:, None, :])                     # (B,Tc,H)
        C_out = (jnp.exp(m_in - gT)[:, :, None, None] * C_in
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", wT, vt, kt))
        n_out = (jnp.exp(m_in - gT)[:, :, None] * n_in
                 + jnp.einsum("bjh,bjhd->bhd", wT, kt))
        m_out = b[:, -1, :] + gT
        return (C_out, n_out, m_out), h

    carry, hs = jax.lax.scan(chunk_step, carry, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
    return carry, h


def mlstm_layer(p: dict, x: Array, cfg: ModelConfig,
                state: dict | None = None) -> tuple[Array, dict | None]:
    B, S, d = x.shape
    H = cfg.n_heads
    di = PROJ * d
    dh = di // H
    xin = L.rms_norm(x, p["ln"], cfg.norm_eps)
    u = L.matmul(xin, p["w_up"])                                 # (B,S,di)
    z = L.matmul(xin, p["w_z"])

    def heads(w):
        return L.matmul(u, w).reshape(B, S, H, dh).astype(jnp.float32)

    q, k, v = heads(p["wq"]), heads(p["wk"]) * dh ** -0.5, heads(p["wv"])
    log_i = L.matmul(u, p["w_i"]).astype(jnp.float32)            # (B,S,H)
    log_f = jax.nn.log_sigmoid(
        L.matmul(u, p["w_f"]).astype(jnp.float32) + p["f_bias"])

    if state is None:
        carry = (jnp.zeros((B, H, dh, dh), jnp.float32),
                 jnp.zeros((B, H, dh), jnp.float32),
                 jnp.full((B, H), -1e30, jnp.float32))
    else:
        carry = (state["C"], state["n"], state["m"])

    chunk = cfg.mlstm_chunk
    if chunk and S > 1 and S % chunk == 0:
        (C, n, m), hs4 = _mlstm_chunkwise(q, k, v, log_i, log_f, carry,
                                          chunk)
        h = hs4.reshape(B, S, di).astype(x.dtype)
    else:
        xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
              v.transpose(1, 0, 2, 3), log_i.transpose(1, 0, 2),
              log_f.transpose(1, 0, 2))
        (C, n, m), hs = jax.lax.scan(_mlstm_cell, carry, xs)
        h = hs.transpose(1, 0, 2, 3).reshape(B, S, di).astype(x.dtype)
    h = L.rms_norm(h, p["gn"], cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = x + L.matmul(h, p["w_down"])
    new_state = ({"C": C, "n": n, "m": m} if state is not None else None)
    return out, new_state


# ----------------------------------------------------------------------
# sLSTM (diagonal recurrence)
# ----------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "ln": L.init_rmsnorm(d),
        "w_in": L.init_linear(ks[0], d, 4 * d, cfg.dtype),
        "r_diag": (jax.random.normal(ks[1], (4, d), jnp.float32) * 0.02),
        "f_bias": jnp.full((d,), 3.0, jnp.float32),
        "w_out": L.init_linear(ks[2], d, d, cfg.dtype),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    def z():
        return jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "m": jnp.full((batch, d), -1e30, jnp.float32),
            "h": z()}


def _slstm_cell(p, carry, g):
    c, n, m, h_prev = carry
    gz, gi, gf, go = jnp.split(g, 4, axis=-1)                    # (B,d) each
    rz, ri, rf, ro = p["r_diag"]
    z = jnp.tanh(gz + rz * h_prev)
    log_i = gi + ri * h_prev
    log_f = jax.nn.log_sigmoid(gf + rf * h_prev + p["f_bias"])
    o = jax.nn.sigmoid(go + ro * h_prev)
    m_new = jnp.maximum(log_f + m, log_i)
    i_ = jnp.exp(log_i - m_new)
    f_ = jnp.exp(log_f + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h), h


def slstm_layer(p: dict, x: Array, cfg: ModelConfig,
                state: dict | None = None) -> tuple[Array, dict | None]:
    B, S, d = x.shape
    xin = L.rms_norm(x, p["ln"], cfg.norm_eps)
    g = L.matmul(xin, p["w_in"]).astype(jnp.float32)             # (B,S,4d)
    if state is None:
        carry = (jnp.zeros((B, d), jnp.float32),) * 2 + (
            jnp.full((B, d), -1e30, jnp.float32),
            jnp.zeros((B, d), jnp.float32))
    else:
        carry = (state["c"], state["n"], state["m"], state["h"])
    def cell(cr, gg):
        return _slstm_cell(p, cr, gg)
    (c, n, m, h_last), hs = jax.lax.scan(cell, carry, g.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    out = x + L.matmul(h, p["w_out"])
    new_state = ({"c": c, "n": n, "m": m, "h": h_last}
                 if state is not None else None)
    return out, new_state


# ----------------------------------------------------------------------
# full xLSTM stack (grouped scan)
# ----------------------------------------------------------------------

def group_structure(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, mlstm_per_group).  slstm_every = 0 -> all mLSTM."""
    if cfg.slstm_every <= 0:
        return 1, cfg.n_layers
    assert cfg.n_layers % cfg.slstm_every == 0
    return cfg.n_layers // cfg.slstm_every, cfg.slstm_every - 1


def init_xlstm_stack(key, cfg: ModelConfig) -> dict:
    G, K = group_structure(cfg)
    km, ks = jax.random.split(key)

    def init_m(k):
        return init_mlstm(k, cfg)

    mkeys = jax.random.split(km, G * max(K, 1)).reshape(G, max(K, 1), 2)
    mlstm = jax.vmap(jax.vmap(init_m))(mkeys)
    out = {"mlstm": mlstm}
    if cfg.slstm_every > 0:
        skeys = jax.random.split(ks, G)
        out["slstm"] = jax.vmap(lambda k: init_slstm(k, cfg))(skeys)
    return out


def init_xlstm_states(cfg: ModelConfig, batch: int) -> dict:
    G, K = group_structure(cfg)
    H = cfg.n_heads
    dh = PROJ * cfg.d_model // H
    d = cfg.d_model
    out = {"mlstm": {
        "C": jnp.zeros((G, max(K, 1), batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((G, max(K, 1), batch, H, dh), jnp.float32),
        "m": jnp.full((G, max(K, 1), batch, H), -1e30, jnp.float32),
    }}
    if cfg.slstm_every > 0:
        out["slstm"] = {
            "c": jnp.zeros((G, batch, d), jnp.float32),
            "n": jnp.zeros((G, batch, d), jnp.float32),
            "m": jnp.full((G, batch, d), -1e30, jnp.float32),
            "h": jnp.zeros((G, batch, d), jnp.float32),
        }
    return out


def xlstm_stack(params: dict, x: Array, cfg: ModelConfig,
                states: dict | None = None) -> tuple[Array, dict | None]:
    """Grouped scan over (k-1) mLSTM + 1 sLSTM blocks per group."""
    has_slstm = cfg.slstm_every > 0

    if states is None:
        # training path: no state threading (avoids stacking dead final
        # states through the scans)
        def layer_body(xc, lp):
            y, _ = mlstm_layer(lp, xc, cfg, None)
            return y, None

        def group_body(xc, inp):
            xc, _ = jax.lax.scan(layer_body, xc, inp["mlstm"])
            if has_slstm:
                xc, _ = slstm_layer(inp["slstm"], xc, cfg, None)
            return xc, None

        if cfg.remat != "none":
            # remat each layer: without this, the backward pass saves the
            # per-chunk (B,H,dh,dh) matrix-memory residuals of every
            # chunk of every layer — the dominant HBM term of the train
            # cell (§Perf iteration 2)
            layer_body = jax.checkpoint(
                layer_body,
                policy=jax.checkpoint_policies.nothing_saveable)
            group_body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.nothing_saveable)

        x, _ = jax.lax.scan(group_body, x, params)
        return x, None

    def layer_body_st(xc, inp):
        lp, st = inp
        y, st2 = mlstm_layer(lp, xc, cfg, st)
        return y, st2

    def group_body_st(xc, inp):
        pg, sg = inp
        xc, mst2 = jax.lax.scan(layer_body_st, xc, (pg["mlstm"], sg["mlstm"]))
        out = {"mlstm": mst2}
        if has_slstm:
            xc, out["slstm"] = slstm_layer(pg["slstm"], xc, cfg, sg["slstm"])
        return xc, out

    x, new_states = jax.lax.scan(group_body_st, x, (params, states))
    return x, new_states
