"""repro.obs — the observability plane.

Spans device and host:

  * :mod:`repro.obs.rings` — device-side per-tick telemetry rings
    (``ObsState``, structurally absent when disabled) drained at chunk
    boundaries by the scan/shard engines;
  * :mod:`repro.obs.trace` — host span tracing to Chrome trace-event /
    Perfetto JSON for sweep-driver phases;
  * :mod:`repro.obs.metrics` — process metrics registry (counters /
    gauges / histograms) with JSONL + Prometheus-textfile export;
  * :mod:`repro.obs.timing` — the shared benchmark timers;
  * :mod:`repro.obs.manifest` — run manifests with round-trippable
    config hashes;
  * :mod:`repro.obs.report` — ring-history and forecast-rows summaries;
  * :mod:`repro.obs.analyze` — vectorized post-drain detectors (EWMA /
    CUSUM / burst / coverage-drift / SLO burn-rate) over ring
    histories;
  * :mod:`repro.obs.alerts` — the alert-rule watchdog the sweep driver
    evaluates per cell;
  * :mod:`repro.obs.dashboard` — stdlib-only static HTML report from
    run artifacts.

Import-light on purpose: nothing here imports ``repro.sim`` (the sim
imports us), and jax is only touched lazily where a device is involved.
"""
from repro.obs.alerts import (DEFAULT_RULES, AlertRule, evaluate_rules,
                              write_alert_log)
from repro.obs.analyze import (Detection, burn_rate_detect, burst_detect,
                               coverage_drift_detect, cusum_detect,
                               ewma_detect)
from repro.obs.config import ObsConfig
from repro.obs.dashboard import render_dashboard
from repro.obs.manifest import (build_manifest, cell_hash, config_hash,
                                load_manifest, write_manifest)
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.report import (bucketed_row_overhead, compact_history,
                              masked_row_overhead, obs_summary)
from repro.obs.timing import best_of, time_us
from repro.obs.trace import (Tracer, current_tracer, span, tracing,
                             validate_trace)

__all__ = [
    "ObsConfig",
    "REGISTRY", "MetricsRegistry",
    "Tracer", "span", "tracing", "current_tracer", "validate_trace",
    "best_of", "time_us",
    "config_hash", "cell_hash", "build_manifest", "write_manifest",
    "load_manifest",
    "masked_row_overhead", "bucketed_row_overhead",
    "obs_summary", "compact_history",
    "Detection", "ewma_detect", "cusum_detect", "burst_detect",
    "coverage_drift_detect", "burn_rate_detect",
    "AlertRule", "DEFAULT_RULES", "evaluate_rules", "write_alert_log",
    "render_dashboard",
]
