"""Alert rules over drained telemetry: the watchdog the sweep runs.

``repro.obs.analyze`` turns ring histories into detections; this module
decides which detections *matter*.  An :class:`AlertRule` binds one
detector to one channel with a threshold and severity; the sweep driver
evaluates the rule set per cell (post-drain — the fused tick never sees
any of this) and threads fired alerts into:

  * the per-cell ``obs`` summary block (``rec["obs"]["alerts"]``),
  * the run manifest (an un-hashed ``alerts`` extra, so PR 7 manifest
    verification is unaffected),
  * the global :data:`repro.obs.metrics.REGISTRY`
    (``alerts.fired{rule,severity}`` labeled counters),
  * a JSONL alert log next to the metrics export
    (:func:`write_alert_log`),
  * the rendered dashboard (``repro.obs.dashboard`` highlights each
    alert's tick window on the channel's sparkline).

Rule thresholds in :data:`DEFAULT_RULES` were tuned against measured
baselines (google / flashcrowd scenario cells at CI scale, 50-300
ticks): the quiet google cells fire nothing, an injected OOM burst or
forced coverage drift fires within its rule window — benchmarks/obs.py
asserts exactly that as BENCH_obs criteria.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from repro.control.config import SLO_BUDGET, SLO_CLASSES
from repro.obs.analyze import (Detection, burn_rate_detect, burst_detect,
                               coverage_drift_detect, cusum_detect,
                               ewma_detect)
from repro.obs.metrics import REGISTRY

__all__ = ["AlertRule", "DEFAULT_RULES", "SEVERITIES", "evaluate_rules",
           "run_rule", "write_alert_log"]

#: Severity ladder, weakest first.  ``page`` is the "wake a human"
#: tier; the dashboard renders it as critical.
SEVERITIES = ("info", "warn", "page")

_DETECTORS = ("ewma", "cusum", "burst", "coverage", "burn", "tenant_burn")


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One watchdog rule: a detector bound to a channel.

    ``channel`` names a ring field for ewma/cusum/burst; the derived
    channels are ``coverage`` (cov_resolved + cov_errors rings) and
    ``slo_burn`` (bad = fail + oom, exposure = admitted).  Zero-valued
    window fields mean "use the detector default".  Frozen + hashable,
    like every config object in this repo, so rule sets can live in
    frozen sweep configs.
    """

    name: str
    channel: str
    detector: str
    threshold: float
    severity: str = "warn"
    window: int = 0          # burst / coverage / short burn window
    long_window: int = 0     # burn only
    warmup: int = 0          # ewma / cusum
    budget: float = 0.0      # burn / tenant_burn (0 -> SLO_BUDGET default)

    def __post_init__(self):
        if self.detector not in _DETECTORS:
            raise ValueError(f"unknown detector {self.detector!r}; "
                             f"expected one of {_DETECTORS}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"expected one of {SEVERITIES}")


#: The stock rule set the sweep driver evaluates when none is given.
#: Thresholds carry margin over measured quiet-cell statistics (e.g.
#: flashcrowd's natural failure ramp peaks at 7 events / 16 ticks, so
#: the failure-burst threshold sits at 12; the google queue channel's
#: EWMA residual peaks at ~11 sigmas during its backlog drain, so the
#: queue rule sits at 20).  The shift rules use EWMA charts rather
#: than CUSUM: CI-scale runs ramp up and drain down by design, and a
#: CUSUM chart integrates that trend into a guaranteed false alarm —
#: the EWMA mean tracks slow ramps and alarms only on abrupt jumps.
#: CUSUM stays available for stationary channels via custom rules.
#: Warmups are sized for CI-scale runs (50+ ticks).
DEFAULT_RULES = (
    AlertRule("oom-burst", "oom", "burst", threshold=8.0,
              severity="page", window=16),
    AlertRule("failure-burst", "fail", "burst", threshold=12.0,
              severity="page", window=16),
    AlertRule("preempt-burst", "preempt", "burst", threshold=24.0,
              severity="warn", window=16),
    AlertRule("queue-shift", "queue", "ewma", threshold=20.0,
              severity="warn", warmup=24),
    AlertRule("gap-cpu-shift", "gap_cpu", "ewma", threshold=10.0,
              severity="warn", warmup=24),
    AlertRule("util-cpu-shift", "used_cpu", "ewma", threshold=12.0,
              severity="info", warmup=24),
    AlertRule("coverage-drift", "coverage", "coverage", threshold=4.0,
              severity="page", window=128),
    AlertRule("slo-burn", "slo_burn", "burn", threshold=4.0,
              severity="page", window=32, long_window=128, budget=0.05),
    AlertRule("tenant-slo-burn", "slo_burn", "tenant_burn",
              threshold=4.0, severity="warn"),
)


def run_rule(rule: AlertRule, history: dict, *,
             nominal_q: float = 0.9) -> Detection | None:
    """Evaluate one rule against a drained history.

    Returns ``None`` when the rule's channel is absent from the
    history (tenancy channels on a tenancy-off run still exist as
    zeros, so in practice only malformed histories skip).
    """
    if rule.detector == "coverage":
        if "cov_resolved" not in history:
            return None
        return coverage_drift_detect(
            history["cov_resolved"], history["cov_errors"],
            nominal=nominal_q, threshold=rule.threshold,
            window=rule.window or 256, min_resolved=32,
            channel="coverage")
    if rule.detector == "burn":
        if "fail" not in history or "admitted" not in history:
            return None
        bad = (np.asarray(history["fail"], np.float64)
               + np.asarray(history["oom"], np.float64))
        return burn_rate_detect(
            bad, history["admitted"],
            budget=rule.budget or SLO_BUDGET[0],
            threshold=rule.threshold, window=rule.window or 64,
            long_window=rule.long_window or 512, channel="slo_burn")
    x = history.get(rule.channel)
    if x is None:
        return None
    if rule.detector == "burst":
        return burst_detect(x, threshold=rule.threshold,
                            window=rule.window or 16,
                            channel=rule.channel)
    if rule.detector == "cusum":
        return cusum_detect(x, threshold=rule.threshold,
                            warmup=rule.warmup or 64,
                            channel=rule.channel)
    if rule.detector == "ewma":
        return ewma_detect(x, threshold=rule.threshold,
                           warmup=rule.warmup or 64,
                           channel=rule.channel)
    return None


def _tenant_burn_alerts(rule: AlertRule, tenancy: dict) -> list[dict]:
    """Per-tenant run-level SLO burn from the tenancy summary block.

    The rings are cluster-aggregate, so per-tenant attribution uses the
    run-level ``slo_met_frac`` per tenant: ``burn = (1 - met) /
    budget(class)``.  Tenants with no completions (NaN met-fraction)
    are skipped — no evidence, no page.
    """
    fired = []
    met = tenancy.get("slo_met_frac", [])
    classes = tenancy.get("slo_class", [0] * len(met))
    for t, m in enumerate(met):
        if m is None or (isinstance(m, float) and np.isnan(m)):
            continue
        cls = int(classes[t]) if t < len(classes) else 0
        budget = rule.budget or SLO_BUDGET[cls]
        burn = (1.0 - float(m)) / budget
        if burn > rule.threshold:
            fired.append({
                "rule": rule.name, "channel": "slo_burn",
                "detector": "tenant_burn", "severity": rule.severity,
                "threshold": round(rule.threshold, 4),
                "peak_stat": round(burn, 4),
                "tenant": t, "slo_class": SLO_CLASSES[cls],
                "n_alarms": 1, "first_tick": None, "last_tick": None,
            })
    return fired


def evaluate_rules(history: dict, rules=DEFAULT_RULES, *,
                   nominal_q: float = 0.9, tenancy: dict | None = None,
                   registry=REGISTRY) -> list[dict]:
    """Evaluate a rule set against one cell's drained history.

    Returns the FIRED alerts as typed records (rule / channel /
    detector / severity / threshold / peak_stat / tick window), ready
    for the manifest and the JSONL log.  Each fired alert increments
    the labeled ``alerts.fired{rule,severity}`` counter; the
    ``alerts.evaluated`` counter ticks per rule regardless, so "zero
    alerts" is distinguishable from "watchdog never ran".
    """
    fired: list[dict] = []
    for rule in rules:
        if rule.detector == "tenant_burn":
            if tenancy:
                hits = _tenant_burn_alerts(rule, tenancy)
                if registry is not None:
                    registry.counter("alerts.evaluated").inc()
                fired.extend(hits)
            continue
        det = run_rule(rule, history, nominal_q=nominal_q)
        if det is None:
            continue
        if registry is not None:
            registry.counter("alerts.evaluated").inc()
        if det.fired:
            rec = det.to_dict()
            rec["rule"] = rule.name
            rec["severity"] = rule.severity
            fired.append(rec)
    if registry is not None:
        for rec in fired:
            registry.counter("alerts.fired", rule=rec["rule"],
                             severity=rec["severity"]).inc()
    return fired


def write_alert_log(path: str, alerts: list[dict], *, cell: str = "",
                    run_id: str = "") -> None:
    """Append fired alerts as JSONL, one record per alert (the same
    append-only convention as ``MetricsRegistry.write_jsonl`` — sweep
    reruns accumulate, nothing is overwritten)."""
    if not alerts:
        return
    with open(path, "a") as f:
        for rec in alerts:
            line = {"ts": time.time(), "cell": cell, "run_id": run_id,
                    **rec}
            f.write(json.dumps(line, sort_keys=True) + "\n")
