"""Streaming telemetry analysis: detectors over drained ring histories.

PR 7's rings record 13 per-tick channels (``SimResults.obs``) but
nothing consumed them — a coverage drift or an OOM burst in a large
sweep was invisible unless a human grepped histories.  This module
turns histories into *detections*: every detector is vectorized NumPy
over the post-drain ``field -> (T,)`` arrays, so the fused tick is
untouched and obs-off / obs-on bit-identity holds unchanged.

Detectors (each returns a :class:`Detection`):

  * :func:`ewma_detect` — EWMA control chart: residuals of the series
    against its exponentially-weighted mean, scaled by a robust (MAD)
    sigma estimated on the warmup prefix.  Catches level shifts in
    utilization / queue-depth / demand-gap channels.
  * :func:`cusum_detect` — two-sided standardized CUSUM.  The
    recursion ``S[t] = max(0, S[t-1] + z[t] - k)`` is computed in
    closed form as a cumulative sum minus its running minimum, so the
    whole chart is two ``np.cumsum`` calls.  Catches slow drifts the
    EWMA chart's per-tick residual misses.
  * :func:`burst_detect` — rolling-window event-count burst on the
    oom / fail / preempt counter channels.
  * :func:`coverage_drift_detect` — rolling realized conformal
    coverage vs the nominal quantile with a binomial-sigma band
    (under-coverage is the alarm direction: the safeguard is supposed
    to *hold* nominal).
  * :func:`burn_rate_detect` — SRE-style multi-window SLO burn rate:
    the bad-event fraction of a short AND a long trailing window must
    both exceed ``threshold`` times the error budget (the short window
    makes the alert fast, the long window keeps it from flapping).

Alarm indices are tick coordinates into the drained history, so the
dashboard can highlight the exact windows on the sparklines.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["Detection", "ewma", "rolling_sum", "ewma_detect",
           "cusum_detect", "burst_detect", "coverage_drift_detect",
           "burn_rate_detect"]


@dataclasses.dataclass
class Detection:
    """One detector's verdict over one channel's history.

    ``fired`` iff any tick alarmed; ``first_tick`` / ``last_tick``
    bound the alarm region (tick coordinates into the drained
    history); ``peak_stat`` is the detector statistic's maximum —
    comparable against ``threshold`` in the same unit (sigmas for
    ewma/cusum/coverage, events for burst, budget multiples for burn).
    """

    detector: str
    channel: str
    fired: bool
    threshold: float
    peak_stat: float = 0.0
    n_ticks: int = 0          # ticks analyzed
    n_alarms: int = 0         # ticks past threshold
    first_tick: int | None = None
    last_tick: int | None = None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["peak_stat"] = round(float(d["peak_stat"]), 4)
        d["threshold"] = round(float(d["threshold"]), 4)
        return d


def _finish(det: Detection, stat: np.ndarray, ticks: np.ndarray,
            threshold: float) -> Detection:
    """Fill a Detection from per-tick statistic values and their tick
    coordinates (``stat`` and ``ticks`` are parallel arrays)."""
    det.n_alarms = int((stat > threshold).sum())
    det.peak_stat = float(stat.max()) if stat.size else 0.0
    if det.n_alarms:
        hit = ticks[stat > threshold]
        det.fired = True
        det.first_tick = int(hit[0])
        det.last_tick = int(hit[-1])
    return det


def ewma(x: np.ndarray, alpha: float = 0.2) -> np.ndarray:
    """Exponentially-weighted moving average, exact and loop-free.

    Within a block, ``y[i] = d^(i+1) y_prev + a d^i cumsum(d^-j x[j])``
    (``d = 1 - alpha``); the block length is capped so ``d^-j`` stays
    finite, which keeps the closed form numerically exact while doing
    per-block vector work instead of a per-tick Python loop.
    """
    if not 0.0 < alpha <= 1.0:
        raise ValueError(f"alpha must be in (0, 1], got {alpha}")
    x = np.asarray(x, np.float64)
    out = np.empty(x.size)
    if x.size == 0:
        return out
    d = 1.0 - alpha
    if d == 0.0:
        return x.copy()
    block = max(8, min(512, int(650.0 / max(-math.log(d), 1e-3))))
    out[0] = prev = x[0]
    i = 1
    while i < x.size:
        xs = x[i:i + block]
        n = xs.size
        j = np.arange(n)
        y = d ** (j + 1) * prev + alpha * d ** j * np.cumsum(d ** -j * xs)
        out[i:i + n] = y
        prev = y[-1]
        i += n
    return out


def rolling_sum(x: np.ndarray, window: int) -> np.ndarray:
    """Trailing-window sums: element ``i`` covers ticks
    ``[i, i + window)`` — length ``T - window + 1``."""
    if window < 1:
        raise ValueError("window must be >= 1")
    x = np.asarray(x, np.float64)
    c = np.concatenate([[0.0], np.cumsum(x)])
    return c[window:] - c[:-window]


def _robust_sigma(r: np.ndarray) -> float:
    """MAD-based sigma (1.4826 * median absolute deviation)."""
    if r.size == 0:
        return 0.0
    return 1.4826 * float(np.median(np.abs(r - np.median(r))))


def ewma_detect(x, *, threshold: float = 8.0, alpha: float = 0.2,
                warmup: int = 64, channel: str = "") -> Detection:
    """EWMA control chart: alarm where the one-step residual
    ``|x[t] - ewma(x)[t-1]|`` exceeds ``threshold`` robust sigmas.

    Sigma is the MAD of the warmup-prefix residuals, floored by a
    fraction of the whole series' residual MAD (so a dead-flat warmup
    on an integer channel does not turn single-count noise into
    alarms) and by an absolute epsilon scaled to the series magnitude.
    """
    det = Detection("ewma", channel, False, threshold)
    x = np.asarray(x, np.float64)
    det.n_ticks = x.size
    if x.size < 2 * warmup:
        return det
    resid = x[1:] - ewma(x, alpha)[:-1]
    eps = 1e-9 + 1e-3 * float(np.mean(np.abs(x)))
    sigma = max(_robust_sigma(resid[:warmup]),
                0.25 * _robust_sigma(resid), eps)
    z = np.abs(resid[warmup:]) / sigma
    ticks = np.arange(warmup + 1, x.size)
    return _finish(det, z, ticks, threshold)


def cusum_detect(x, *, threshold: float = 10.0, drift: float = 0.5,
                 warmup: int = 64, channel: str = "") -> Detection:
    """Two-sided standardized CUSUM changepoint chart.

    ``x`` is standardized against the warmup prefix (robust location /
    scale); the one-sided statistic ``S[t] = max(0, S[t-1] + z[t] -
    drift)`` equals ``cumsum(z - drift)`` minus its running minimum,
    so both sides are vectorized exactly.  ``threshold`` and ``drift``
    are in sigmas.
    """
    det = Detection("cusum", channel, False, threshold)
    x = np.asarray(x, np.float64)
    det.n_ticks = x.size
    if x.size < 2 * warmup:
        return det
    base = x[:warmup]
    eps = 1e-9 + 1e-3 * float(np.mean(np.abs(x)))
    sigma = max(_robust_sigma(base), 0.25 * _robust_sigma(x), eps)
    z = (x - float(np.median(base))) / sigma
    up = np.cumsum(z - drift)
    s_up = up - np.minimum.accumulate(np.concatenate([[0.0], up]))[1:]
    dn = np.cumsum(-z - drift)
    s_dn = dn - np.minimum.accumulate(np.concatenate([[0.0], dn]))[1:]
    stat = np.maximum(s_up, s_dn)[warmup:]
    ticks = np.arange(warmup, x.size)
    return _finish(det, stat, ticks, threshold)


def burst_detect(x, *, threshold: float = 8.0, window: int = 16,
                 channel: str = "") -> Detection:
    """Event burst: alarm where the trailing ``window``-tick event
    count exceeds ``threshold`` (strictly).  Alarm ticks are the
    window END, so a burst is reported no later than ``window - 1``
    ticks after its last contributing event."""
    det = Detection("burst", channel, False, threshold)
    x = np.asarray(x, np.float64)
    det.n_ticks = x.size
    if x.size < window:
        return det
    s = rolling_sum(x, window)
    ticks = np.arange(window - 1, x.size)
    return _finish(det, s, ticks, threshold)


def coverage_drift_detect(resolved, errors, *, nominal: float = 0.9,
                          threshold: float = 4.0, window: int = 256,
                          min_resolved: int = 64,
                          channel: str = "coverage") -> Detection:
    """Conformal coverage drift: rolling realized coverage vs the
    nominal quantile, standardized by the binomial sigma
    ``sqrt(q (1-q) / n)`` of the window's resolved count.

    Alarms on UNDER-coverage only (realized below nominal): the
    calibrated safeguard's contract is to hold nominal, and
    over-coverage merely means conservative shaping.  Windows with
    fewer than ``min_resolved`` resolutions are skipped — early ticks
    resolve nothing while forecasts are still outstanding.
    """
    det = Detection("coverage", channel, False, threshold)
    resolved = np.asarray(resolved, np.float64)
    errors = np.asarray(errors, np.float64)
    det.n_ticks = resolved.size
    if resolved.size < window:
        window = max(int(resolved.size), 1)
    if resolved.size == 0:
        return det
    rs = rolling_sum(resolved, window)
    es = rolling_sum(errors, window)
    n = np.maximum(rs, 1.0)
    cov = 1.0 - es / n
    z = (nominal - cov) / np.sqrt(nominal * (1.0 - nominal) / n)
    valid = rs >= min_resolved
    ticks = np.arange(window - 1, resolved.size)
    return _finish(det, z[valid], ticks[valid], threshold)


def burn_rate_detect(bad, exposure, *, budget: float = 0.05,
                     threshold: float = 4.0, window: int = 64,
                     long_window: int = 512,
                     channel: str = "slo_burn") -> Detection:
    """Multi-window SLO burn rate (SRE style).

    ``burn(w) = (bad events / exposure events in the trailing window)
    / budget``; a tick alarms when BOTH the short and the long window
    burn above ``threshold``.  The short window bounds detection
    latency; the long window stops a single bad tick from paging.
    Windows longer than the run are clamped to it (short runs still
    evaluate, over their whole length).
    """
    det = Detection("burn", channel, False, threshold)
    bad = np.asarray(bad, np.float64)
    exposure = np.asarray(exposure, np.float64)
    det.n_ticks = bad.size
    if budget <= 0:
        raise ValueError("budget must be positive")
    long_window = min(long_window, bad.size) or 1
    window = min(window, long_window)
    if bad.size < long_window or long_window < 1:
        return det
    bs = rolling_sum(bad, window)
    es = np.maximum(rolling_sum(exposure, window), 1.0)
    bl = rolling_sum(bad, long_window)
    el = np.maximum(rolling_sum(exposure, long_window), 1.0)
    # align both windows on their shared END tick
    off = long_window - window
    burn_s = (bs[off:] / es[off:]) / budget
    burn_l = (bl / el) / budget
    stat = np.minimum(burn_s, burn_l)    # both windows must burn
    ticks = np.arange(long_window - 1, bad.size)
    return _finish(det, stat, ticks, threshold)
