"""Observability configuration (the ``SimConfig.obs`` field).

Frozen and hashable like every other config block: the scan engine's
compile cache keys on it (``repro.sim.step._cfg_key``), and the sweep
dedups traces/diagnostics by config identity.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Device-side telemetry rings (``repro.obs.rings``).

    Disabled by default: the ``ObsState`` pytree is then structurally
    ABSENT from the traced program (exactly like ``TenantState`` /
    ``CalibState``), so obs-off runs are bit-identical to engines that
    predate the observability plane.
    """

    enabled: bool = False
    # ring capacity in ticks; the chunk drivers drain the rings at every
    # chunk boundary, so capacity must be >= the chunk size (enforced by
    # repro.sim.step._drive_chunks) or undrained entries would be
    # overwritten
    ring: int = 128
