"""Self-contained sweep dashboard: one static HTML file from run artifacts.

``python -m repro.obs.dashboard run.manifest.json -o report.html`` (or
``run_grid(dashboard_path=...)`` / the sweep CLI ``--dashboard``) renders
everything PR 7/8 write — the manifest, the per-cell results JSON, the
span trace, the metrics snapshot, fired alerts, and any BENCH_*.json
sitting next to the manifest — into a single offline-viewable report:

  * inline-SVG sparklines of every ring channel per cell (from the
    ``obs.history`` block :func:`repro.obs.report.compact_history`
    embeds), with fired-alert tick windows highlighted on the affected
    channel,
  * the span-trace phase waterfall (error-flagged spans marked),
  * the metrics snapshot and fired-alert tables,
  * a BENCH criteria table (pass/fail per artifact).

Stdlib only — no matplotlib, no JS frameworks, no network: the file
works on a CI artifact download with zero dependencies.  Light and dark
render from the same CSS custom properties (OS preference via
``prefers-color-scheme``, explicit override via ``data-theme``).
"""
from __future__ import annotations

import argparse
import html
import json
import os
from typing import Sequence

__all__ = ["render_dashboard", "main"]

# sparkline geometry (viewBox units)
_W, _H, _PAD = 240, 44, 3

# severity -> (status color, icon); status colors are fixed across
# light/dark per the palette (never themed), and always paired with
# the icon + text label so color never carries meaning alone
_SEVERITY = {"info": ("var(--ink-2)", "i"),
             "warn": ("#fab219", "⚠"),        # warning
             "page": ("#d03b3b", "●")}        # critical

_CSS = """
:root {
  color-scheme: light;
  --surface: #fcfcfb; --page: #f9f9f7;
  --ink: #0b0b0b; --ink-2: #52514e; --muted: #898781;
  --grid: #e1e0d9; --axis: #c3c2b7; --border: rgba(11,11,11,0.10);
  --series: #2a78d6; --band: rgba(208,59,59,0.14);
  --good: #0ca30c; --crit: #d03b3b;
}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) {
    color-scheme: dark;
    --surface: #1a1a19; --page: #0d0d0d;
    --ink: #ffffff; --ink-2: #c3c2b7; --muted: #898781;
    --grid: #2c2c2a; --axis: #383835; --border: rgba(255,255,255,0.10);
    --series: #3987e5; --band: rgba(208,59,59,0.22);
  }
}
:root[data-theme="dark"] {
  color-scheme: dark;
  --surface: #1a1a19; --page: #0d0d0d;
  --ink: #ffffff; --ink-2: #c3c2b7; --muted: #898781;
  --grid: #2c2c2a; --axis: #383835; --border: rgba(255,255,255,0.10);
  --series: #3987e5; --band: rgba(208,59,59,0.22);
}
* { box-sizing: border-box; }
body { margin: 0; padding: 24px; background: var(--page); color: var(--ink);
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  font-size: 14px; line-height: 1.45; }
h1 { font-size: 20px; margin: 0 0 4px; }
h2 { font-size: 16px; margin: 28px 0 8px; }
h3 { font-size: 14px; margin: 18px 0 6px; color: var(--ink-2); }
.sub { color: var(--ink-2); margin: 0 0 16px; }
section { background: var(--surface); border: 1px solid var(--border);
  border-radius: 8px; padding: 16px; margin: 16px 0; }
table { border-collapse: collapse; width: 100%; }
th { text-align: left; color: var(--ink-2); font-weight: 600;
  border-bottom: 1px solid var(--axis); padding: 4px 10px 4px 0; }
td { border-bottom: 1px solid var(--grid); padding: 4px 10px 4px 0;
  font-variant-numeric: tabular-nums; }
.sparks { display: grid; grid-template-columns: repeat(auto-fill, minmax(260px, 1fr));
  gap: 10px; }
.spark { border: 1px solid var(--grid); border-radius: 6px; padding: 6px 8px; }
.spark .name { color: var(--ink-2); font-size: 12px; }
.spark .val { float: right; color: var(--muted); font-size: 12px;
  font-variant-numeric: tabular-nums; }
svg { display: block; width: 100%; height: auto; }
.badge { display: inline-block; border: 1px solid var(--border);
  border-radius: 10px; padding: 0 8px; font-size: 12px; white-space: nowrap; }
.pass { color: var(--good); } .fail { color: var(--crit); }
.wf-label { font-size: 11px; fill: var(--ink-2); }
.wf-dur { font-size: 11px; fill: var(--muted); }
.cellhead { color: var(--muted); font-size: 12px; }
"""


def _esc(v) -> str:
    return html.escape(str(v), quote=True)


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, float):
        return f"{v:.4g}"
    return _esc(v)


def _sparkline(series: Sequence[float], bands: list[tuple[int, int]],
               n_buckets: int) -> str:
    """One inline-SVG sparkline: single series (no legend — the tile
    names it), thin line, no axes beyond a baseline, alert tick windows
    as translucent bands behind the line."""
    n = len(series)
    if n == 0:
        return "<svg viewBox='0 0 240 44'></svg>"
    xs = [float(v) if v is not None else 0.0 for v in series]
    lo, hi = min(xs), max(xs)
    span = (hi - lo) or 1.0
    w, h, pad = _W, _H, _PAD
    step = (w - 2 * pad) / max(n - 1, 1)

    def x(i):
        return pad + i * step

    def y(v):
        return h - pad - (v - lo) / span * (h - 2 * pad)

    parts = [f"<svg viewBox='0 0 {w} {h}' preserveAspectRatio='none' "
             f"role='img'>"]
    for b0, b1 in bands:
        b0 = max(0, min(b0, n_buckets - 1))
        b1 = max(b0, min(b1, n_buckets - 1))
        parts.append(f"<rect x='{x(b0):.1f}' y='0' "
                     f"width='{max(x(b1) - x(b0), 2.0):.1f}' height='{h}' "
                     f"fill='var(--band)'/>")
    parts.append(f"<line x1='{pad}' y1='{h - pad}' x2='{w - pad}' "
                 f"y2='{h - pad}' stroke='var(--axis)' stroke-width='1'/>")
    pts = " ".join(f"{x(i):.1f},{y(v):.1f}" for i, v in enumerate(xs))
    parts.append(f"<polyline points='{pts}' fill='none' "
                 f"stroke='var(--series)' stroke-width='1.5' "
                 f"stroke-linejoin='round'/>")
    parts.append("</svg>")
    return "".join(parts)


def _severity_badge(sev: str) -> str:
    color, icon = _SEVERITY.get(sev, _SEVERITY["info"])
    return (f"<span class='badge' style='color:{color}'>"
            f"{icon} {_esc(sev)}</span>")


def _alert_rows(alerts: list[dict]) -> str:
    rows = []
    for a in alerts:
        win = ("&#8212;" if a.get("first_tick") is None else
               f"{a['first_tick']}&#8211;{a['last_tick']}")
        tenant = f" tenant={a['tenant']}" if "tenant" in a else ""
        rows.append(
            "<tr>"
            f"<td>{_esc(a.get('cell', ''))}</td>"
            f"<td>{_esc(a.get('rule', ''))}{tenant}</td>"
            f"<td>{_esc(a.get('channel', ''))}</td>"
            f"<td>{_esc(a.get('detector', ''))}</td>"
            f"<td>{_severity_badge(a.get('severity', 'info'))}</td>"
            f"<td>{_fmt(a.get('peak_stat', ''))}</td>"
            f"<td>{_fmt(a.get('threshold', ''))}</td>"
            f"<td>{win}</td></tr>")
    return "".join(rows)


def _cell_section(rec: dict, alerts: list[dict]) -> str:
    obs = rec.get("obs") or {}
    hist = obs.get("history") or {}
    channels = hist.get("channels") or {}
    stride = int(hist.get("stride", 1)) or 1
    ticks = int(hist.get("ticks", 0))
    name = rec.get("name", "?")
    out = [f"<h3>cell <code>{_esc(name)}</code> "
           f"<span class='cellhead'>({ticks} ticks, stride {stride})"
           f"</span></h3>"]
    if not channels:
        out.append("<p class='sub'>no ring history embedded "
                   "(obs disabled for this cell)</p>")
        return "".join(out)
    by_channel: dict[str, list[tuple[int, int]]] = {}
    for a in alerts:
        if a.get("first_tick") is None:
            continue
        by_channel.setdefault(a.get("channel", ""), []).append(
            (int(a["first_tick"]) // stride, int(a["last_tick"]) // stride))
    n_buckets = max((len(v) for v in channels.values()), default=0)
    out.append("<div class='sparks'>")
    for ch, series in channels.items():
        last = series[-1] if series else 0
        out.append(
            "<div class='spark'>"
            f"<span class='name'>{_esc(ch)}</span>"
            f"<span class='val'>last {_fmt(last)}</span>"
            f"{_sparkline(series, by_channel.get(ch, []), n_buckets)}"
            "</div>")
    out.append("</div>")
    return "".join(out)


def _waterfall(trace: dict, max_spans: int = 48) -> str:
    evs = [e for e in trace.get("traceEvents", [])
           if e.get("ph") == "X" and isinstance(e.get("dur"), (int, float))]
    if not evs:
        return "<p class='sub'>no trace artifact found</p>"
    evs.sort(key=lambda e: e["ts"])
    if len(evs) > max_spans:
        keep = sorted(evs, key=lambda e: -e["dur"])[:max_spans]
        dropped = len(evs) - max_spans
        evs = sorted(keep, key=lambda e: e["ts"])
    else:
        dropped = 0
    t0 = min(e["ts"] for e in evs)
    t1 = max(e["ts"] + e["dur"] for e in evs)
    total = (t1 - t0) or 1.0
    row_h, label_w, w = 18, 190, 760
    h = row_h * len(evs) + 6
    parts = [f"<svg viewBox='0 0 {w} {h}'>"]
    for i, e in enumerate(evs):
        y = 3 + i * row_h
        bx = label_w + (e["ts"] - t0) / total * (w - label_w - 60)
        bw = max(e["dur"] / total * (w - label_w - 60), 1.5)
        err = isinstance(e.get("args"), dict) and e["args"].get("error")
        fill = "var(--crit)" if err else "var(--series)"
        label = e["name"] + (f" ⚠ {e['args']['error']}" if err else "")
        parts.append(f"<text x='0' y='{y + 12}' class='wf-label'>"
                     f"{_esc(label[:30])}</text>")
        parts.append(f"<rect x='{bx:.1f}' y='{y + 2}' width='{bw:.1f}' "
                     f"height='{row_h - 6}' rx='2' fill='{fill}'/>")
        parts.append(f"<text x='{bx + bw + 4:.1f}' y='{y + 12}' "
                     f"class='wf-dur'>{e['dur'] / 1e3:.1f}ms</text>")
    parts.append("</svg>")
    note = (f"<p class='sub'>showing the {max_spans} longest of "
            f"{len(evs) + dropped} spans</p>" if dropped else "")
    return note + "".join(parts)


def _metrics_table(metrics: dict) -> str:
    if not metrics:
        return "<p class='sub'>no metrics snapshot in manifest</p>"
    rows = []
    for name, snap in sorted(metrics.items()):
        if snap.get("type") == "histogram":
            val = (f"n={snap['count']} sum={_fmt(snap['sum'])} "
                   f"min={_fmt(snap.get('min'))} max={_fmt(snap.get('max'))}")
        else:
            val = _fmt(snap.get("value"))
        rows.append(f"<tr><td><code>{_esc(name)}</code></td>"
                    f"<td>{_esc(snap.get('type', ''))}</td>"
                    f"<td>{val}</td></tr>")
    return ("<table><tr><th>metric</th><th>type</th><th>value</th></tr>"
            + "".join(rows) + "</table>")


def _bench_table(bench_docs: dict) -> str:
    if not bench_docs:
        return "<p class='sub'>no BENCH_*.json artifacts found</p>"
    rows = []
    for fname, doc in sorted(bench_docs.items()):
        crit = doc.get("criteria", {})
        for key, ok in sorted(crit.items()):
            mark = ("<span class='pass'>✓ pass</span>" if ok
                    else "<span class='fail'>✗ FAIL</span>")
            rows.append(f"<tr><td>{_esc(fname)}</td>"
                        f"<td><code>{_esc(key)}</code></td>"
                        f"<td>{mark}</td></tr>")
    if not rows:
        return "<p class='sub'>bench artifacts carry no criteria</p>"
    return ("<table><tr><th>artifact</th><th>criterion</th><th>status</th>"
            "</tr>" + "".join(rows) + "</table>")


def _read_json(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def render_dashboard(manifest, out_path: str, *, results: dict | None = None,
                     trace: dict | None = None,
                     bench_docs: dict | None = None) -> str:
    """Render the report HTML to ``out_path`` and return the path.

    ``manifest`` is a manifest dict or a path to one; artifact paths in
    the manifest resolve relative to the manifest's directory (the
    layout a CI artifact download preserves).  ``results`` / ``trace``
    / ``bench_docs`` override artifact loading for in-process use.
    """
    base_dir = "."
    if isinstance(manifest, str):
        base_dir = os.path.dirname(os.path.abspath(manifest))
        with open(manifest) as f:
            manifest = json.load(f)

    def _artifact(key):
        p = (manifest.get("artifacts") or {}).get(key)
        if not p:
            return None
        cands = [p, os.path.join(base_dir, os.path.basename(p))]
        for c in cands:
            doc = _read_json(c)
            if doc is not None:
                return doc
        return None

    if results is None:
        results = _artifact("results")
    if trace is None:
        trace = _artifact("trace") or {}
    if bench_docs is None:
        bench_docs = {}
        try:
            names = sorted(os.listdir(base_dir))
        except OSError:
            names = []
        for fname in names:
            if fname.startswith("BENCH_") and fname.endswith(".json") \
                    and not any(s in fname for s in
                                (".manifest", ".sweep", ".trace")):
                doc = _read_json(os.path.join(base_dir, fname))
                if isinstance(doc, dict):
                    bench_docs[fname] = doc

    cells = (results or {}).get("cells") or manifest.get("cells") or []
    alerts = manifest.get("alerts") or []
    if not alerts:
        alerts = [a for rec in cells
                  for a in ((rec.get("obs") or {}).get("alerts") or [])]

    body = []
    run_id = manifest.get("run_id", manifest.get("created", ""))
    body.append(f"<h1>sweep report <code>{_esc(run_id)}</code></h1>")
    body.append(f"<p class='sub'>engine {_esc(manifest.get('engine', '?'))}"
                f" &middot; {len(cells)} cells &middot; wall "
                f"{_fmt(manifest.get('wall_s', 0))}s &middot; "
                f"{len(alerts)} fired alerts</p>")

    body.append("<section id='alerts'><h2>fired alerts</h2>")
    if alerts:
        body.append("<table><tr><th>cell</th><th>rule</th><th>channel</th>"
                    "<th>detector</th><th>severity</th><th>peak</th>"
                    "<th>threshold</th><th>tick window</th></tr>"
                    + _alert_rows(alerts) + "</table>")
    else:
        body.append("<p class='sub'>✓ no alerts fired</p>")
    body.append("</section>")

    body.append("<section id='cells'><h2>ring channels per cell</h2>")
    for rec in cells:
        cell_alerts = [a for a in alerts
                       if a.get("cell", "") in ("", rec.get("name"))]
        body.append(_cell_section(rec, cell_alerts))
    if not cells:
        body.append("<p class='sub'>no cell records found</p>")
    body.append("</section>")

    body.append("<section id='trace'><h2>span waterfall</h2>"
                + _waterfall(trace or {}) + "</section>")
    body.append("<section id='metrics'><h2>metrics snapshot</h2>"
                + _metrics_table(manifest.get("metrics") or {})
                + "</section>")
    body.append("<section id='bench'><h2>bench criteria</h2>"
                + _bench_table(bench_docs) + "</section>")

    doc = ("<!doctype html><html lang='en'><head><meta charset='utf-8'>"
           "<meta name='viewport' content='width=device-width,"
           "initial-scale=1'>"
           "<title>sweep report</title>"
           f"<style>{_CSS}</style></head><body>"
           + "".join(body) + "</body></html>")
    with open(out_path, "w") as f:
        f.write(doc)
    return out_path


def main(argv: Sequence[str] | None = None) -> str:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dashboard",
        description="Render a static HTML report from a sweep manifest.")
    ap.add_argument("manifest", help="path to a run manifest JSON")
    ap.add_argument("-o", "--out", default="report.html")
    ns = ap.parse_args(argv)
    path = render_dashboard(ns.manifest, ns.out)
    print(f"wrote {path}")
    return path


if __name__ == "__main__":
    main()
