"""Run manifests: make every BENCH_*.json reproducible from a sidecar.

A manifest records everything needed to re-run (and trust) a sweep:
canonical hashes of the base config and every expanded cell, the
jax/jaxlib versions and device topology that executed it, compile-time
and wall-clock metrics, and the artifact paths it produced.  Cell
hashes are RECOMPUTABLE from the manifest alone (base snapshot +
per-cell overrides + seed), so :func:`load_manifest` can verify a
manifest round-trips its own hashes — a tampered or stale manifest
fails loudly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import platform
import sys

__all__ = ["config_hash", "cell_hash", "build_manifest",
           "write_manifest", "load_manifest"]

MANIFEST_SCHEMA = 1


def _canon(obj) -> str:
    """Canonical JSON: sorted keys, no whitespace, str() fallback for
    exotic leaves (dtypes etc.) — stable across processes."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=str)


def config_hash(cfg) -> str:
    """sha256 of the canonical JSON form of a config (dataclass or
    plain dict)."""
    if dataclasses.is_dataclass(cfg) and not isinstance(cfg, type):
        cfg = dataclasses.asdict(cfg)
    return hashlib.sha256(_canon(cfg).encode()).hexdigest()


def cell_hash(base_hash: str, overrides: dict, seed: int) -> str:
    """Hash of one expanded sweep cell: the base identity plus exactly
    what the grid changed.  Recomputable from manifest contents."""
    payload = {"base": base_hash, "overrides": dict(overrides),
               "seed": int(seed)}
    return hashlib.sha256(_canon(payload).encode()).hexdigest()


def _environment() -> dict:
    env = {"python": sys.version.split()[0],
           "platform": platform.platform()}
    try:
        import jax
        import jaxlib
        env["jax"] = jax.__version__
        env["jaxlib"] = jaxlib.__version__
        devs = jax.devices()
        env["backend"] = devs[0].platform if devs else "none"
        env["device_count"] = len(devs)
        env["devices"] = [str(d) for d in devs[:16]]
    except Exception as e:  # no backend in a stripped environment
        env["jax"] = f"unavailable: {e}"
    return env


def build_manifest(*, base_config: dict, cells: list[dict],
                   engine: str, artifacts: dict,
                   wall_s: float | None = None,
                   metrics: dict | None = None,
                   extra: dict | None = None) -> dict:
    """Assemble a manifest document.

    ``base_config`` is the asdict snapshot of the sweep base config;
    ``cells`` are dicts with at least ``overrides`` and ``seed`` (a
    ``config_hash`` field is filled in for each).  Both are normalized
    through a JSON round trip BEFORE hashing, so the stored hashes are
    recomputable from the loaded manifest (tuples become lists, exotic
    leaves their str() form — identically on both sides).
    """
    base_config = json.loads(_canon(base_config))
    base_h = config_hash(base_config)
    out_cells = []
    for c in cells:
        c = json.loads(_canon(dict(c)))
        c["config_hash"] = cell_hash(base_h, c.get("overrides", {}),
                                     c.get("seed", 0))
        out_cells.append(c)
    man = {
        "schema": MANIFEST_SCHEMA,
        "engine": engine,
        "base_config": base_config,
        "base_config_hash": base_h,
        "cells": out_cells,
        "environment": _environment(),
        "artifacts": dict(artifacts),
    }
    if wall_s is not None:
        man["wall_s"] = float(wall_s)
    if metrics is not None:
        man["metrics"] = metrics
    if extra:
        man.update(extra)
    return man


def write_manifest(path: str, manifest: dict) -> None:
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True, default=str)
        f.write("\n")


def load_manifest(path: str, verify: bool = True) -> dict:
    """Load a manifest; with ``verify`` (default) recompute the base and
    cell hashes from the stored snapshot/overrides and raise
    ``ValueError`` on any mismatch."""
    with open(path) as f:
        man = json.load(f)
    if verify:
        base_h = config_hash(man["base_config"])
        if base_h != man["base_config_hash"]:
            raise ValueError(
                f"manifest base_config_hash mismatch: stored "
                f"{man['base_config_hash'][:12]}…, recomputed {base_h[:12]}…")
        for c in man.get("cells", []):
            h = cell_hash(base_h, c.get("overrides", {}), c.get("seed", 0))
            if h != c.get("config_hash"):
                raise ValueError(
                    f"manifest cell hash mismatch for "
                    f"{c.get('name', '?')!r}")
    return man
