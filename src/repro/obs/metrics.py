"""Process-wide metrics registry: counters / gauges / histograms with
JSONL and Prometheus-textfile export.

Deliberately tiny (stdlib only, no client-library dependency): the
point is ONE place where driver-level telemetry accumulates — compile
times, chunk walls, benchmark timer samples — so manifests and bench
artifacts can snapshot it instead of every module keeping ad-hoc
stopwatch variables.
"""
from __future__ import annotations

import json
import math
import re
import threading
import time

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "REGISTRY", "registry"]


class Counter:
    """Monotone event count."""

    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Histogram:
    """Streaming count / sum / min / max summary (no buckets: the
    exporters emit ``_count`` / ``_sum`` / ``_min`` / ``_max`` series,
    which is what the bench criteria and manifests actually consume)."""

    kind = "histogram"

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def snapshot(self) -> dict:
        return {"type": self.kind, "count": self.count, "sum": self.total,
                "min": (None if self.count == 0 else self.min),
                "max": (None if self.count == 0 else self.max)}


class MetricsRegistry:
    """Thread-safe name -> metric map (get-or-create per kind)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls()
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                                f"{cls.kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        with self._lock:
            return {name: m.snapshot()
                    for name, m in sorted(self._metrics.items())}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- exporters -----------------------------------------------------
    def write_jsonl(self, path: str, **extra) -> None:
        """Append one timestamped snapshot line (metrics-over-time logs:
        each sweep / bench run appends, nothing is overwritten)."""
        rec = {"ts": time.time(), "metrics": self.snapshot(), **extra}
        with open(path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")

    def write_textfile(self, path: str) -> None:
        """Prometheus textfile-collector exposition format (one flat
        sample per series; histograms expand to _count/_sum/_min/_max)."""
        lines = []
        for name, snap in self.snapshot().items():
            pname = _prom_name(name)
            if snap["type"] == "histogram":
                lines.append(f"# TYPE {pname} summary")
                lines.append(f"{pname}_count {snap['count']}")
                lines.append(f"{pname}_sum {_prom_val(snap['sum'])}")
                for k in ("min", "max"):
                    if snap[k] is not None:
                        lines.append(f"{pname}_{k} {_prom_val(snap[k])}")
            else:
                lines.append(f"# TYPE {pname} {snap['type']}")
                lines.append(f"{pname} {_prom_val(snap['value'])}")
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")


def _prom_name(name: str) -> str:
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    return out if re.match(r"^[a-zA-Z_:]", out) else "_" + out


def _prom_val(v: float) -> str:
    return repr(float(v))


# the process-wide default registry (what the engines / benches use)
REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return REGISTRY
