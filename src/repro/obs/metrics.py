"""Process-wide metrics registry: counters / gauges / histograms with
JSONL and Prometheus-textfile export.

Deliberately tiny (stdlib only, no client-library dependency): the
point is ONE place where driver-level telemetry accumulates — compile
times, chunk walls, benchmark timer samples — so manifests and bench
artifacts can snapshot it instead of every module keeping ad-hoc
stopwatch variables.
"""
from __future__ import annotations

import json
import math
import re
import threading
import time

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "REGISTRY", "registry", "series_key"]


class Counter:
    """Monotone event count."""

    kind = "counter"

    def __init__(self, family: str = "", labels: dict | None = None):
        self.value = 0.0
        self.family = family
        self.labels = dict(labels or {})

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v

    def snapshot(self) -> dict:
        d = {"type": self.kind, "value": self.value}
        if self.labels:
            d["labels"] = dict(self.labels)
        return d


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, family: str = "", labels: dict | None = None):
        self.value = 0.0
        self.family = family
        self.labels = dict(labels or {})

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        d = {"type": self.kind, "value": self.value}
        if self.labels:
            d["labels"] = dict(self.labels)
        return d


class Histogram:
    """Streaming count / sum / min / max summary (no buckets: the
    exporters emit ``_count`` / ``_sum`` / ``_min`` / ``_max`` series,
    which is what the bench criteria and manifests actually consume)."""

    kind = "histogram"

    def __init__(self, family: str = "", labels: dict | None = None):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.family = family
        self.labels = dict(labels or {})

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def snapshot(self) -> dict:
        d = {"type": self.kind, "count": self.count, "sum": self.total,
             "min": (None if self.count == 0 else self.min),
             "max": (None if self.count == 0 else self.max)}
        if self.labels:
            d["labels"] = dict(self.labels)
        return d


def series_key(name: str, labels: dict | None) -> str:
    """Canonical ``family{k="v",...}`` series identity (sorted label
    order, so kwargs order never creates duplicate series)."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Thread-safe series -> metric map (get-or-create per kind).

    A *family* is the bare metric name; a *series* is family + labels
    (``counter("alerts.fired", rule="oom-burst", severity="page")``).
    Unlabeled calls keep their historical single-series behavior.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._help: dict[str, str] = {}

    def _get(self, name: str, cls, labels: dict):
        labels = {k: str(v) for k, v in labels.items()}
        key = series_key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(family=name, labels=labels)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {key!r} is a {m.kind}, not a "
                                f"{cls.kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, Counter, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, Gauge, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(name, Histogram, labels)

    def set_help(self, name: str, text: str) -> None:
        """Register the ``# HELP`` line for a metric family."""
        with self._lock:
            self._help[name] = text

    def snapshot(self) -> dict:
        with self._lock:
            return {name: m.snapshot()
                    for name, m in sorted(self._metrics.items())}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- exporters -----------------------------------------------------
    def write_jsonl(self, path: str, **extra) -> None:
        """Append one timestamped snapshot line (metrics-over-time logs:
        each sweep / bench run appends, nothing is overwritten)."""
        rec = {"ts": time.time(), "metrics": self.snapshot(), **extra}
        with open(path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")

    def write_textfile(self, path: str) -> None:
        """Prometheus textfile-collector exposition format.

        ``# HELP`` / ``# TYPE`` are emitted ONCE per metric *family*
        (labeled series of one family share a single header block, as
        the exposition format requires — a repeated TYPE line is a
        parse error for promtool), label values are escaped per the
        format (backslash, double quote, newline), and histograms
        expand to ``_count`` / ``_sum`` / ``_min`` / ``_max`` samples.
        """
        with self._lock:
            items = sorted(self._metrics.items(),
                           key=lambda kv: (kv[1].family, kv[0]))
            helps = dict(self._help)
        lines: list[str] = []
        seen: set[str] = set()
        for key, m in items:
            pname = _prom_name(m.family or key)
            snap = m.snapshot()
            if m.family not in seen:
                seen.add(m.family)
                help_text = helps.get(m.family, m.family or key)
                lines.append(f"# HELP {pname} {_escape_help(help_text)}")
                ptype = "summary" if snap["type"] == "histogram" else snap["type"]
                lines.append(f"# TYPE {pname} {ptype}")
            lbl = _prom_labels(m.labels)
            if snap["type"] == "histogram":
                lines.append(f"{pname}_count{lbl} {snap['count']}")
                lines.append(f"{pname}_sum{lbl} {_prom_val(snap['sum'])}")
                for k in ("min", "max"):
                    if snap[k] is not None:
                        lines.append(f"{pname}_{k}{lbl} {_prom_val(snap[k])}")
            else:
                lines.append(f"{pname}{lbl} {_prom_val(snap['value'])}")
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")


def _prom_name(name: str) -> str:
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    return out if re.match(r"^[a-zA-Z_:]", out) else "_" + out


def _escape_label(v: str) -> str:
    """Label-value escaping per the exposition format."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(v: str) -> str:
    """HELP text escapes backslash and newline (but not quotes)."""
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{re.sub(r"[^a-zA-Z0-9_]", "_", k)}="{_escape_label(str(v))}"'
        for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _prom_val(v: float) -> str:
    return repr(float(v))


# the process-wide default registry (what the engines / benches use)
REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return REGISTRY
