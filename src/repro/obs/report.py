"""Telemetry reports: turn drained rings / collected counters into the
compact summaries that sweep cells, manifests, and BENCH artifacts carry.
"""
from __future__ import annotations

import numpy as np

__all__ = ["bucketed_row_overhead", "masked_row_overhead",
           "obs_summary", "compact_history"]


def masked_row_overhead(rows: dict) -> float:
    """Padded-vs-compact forecast cost ratio from ``forecast_rows``
    telemetry: the batch rows a padded forecaster evaluates across the
    ticks that actually invoked the model, over the rows that were
    genuinely ready.  >1 means masked rows are being paid for; the
    BENCH_engine ``gp`` block reports this as ``masked_row_overhead``
    (~6.7x on the tiny GP cell — ROADMAP item 3's ragged-batch target).
    """
    return (rows["rows_batch"] * rows["ticks_forecasting"]
            / max(rows["rows_ready"], 1))


def bucketed_row_overhead(rows: dict) -> float:
    """Computed-vs-ready forecast cost ratio under ragged bucketing:
    the rows the model ACTUALLY evaluated (``rows_bucketed`` — passes x
    bucket batch; equal to the full padded cost when un-bucketed) over
    the rows that were genuinely ready.  The bucketed scan path targets
    <= 2x where the padded batch pays ~6.7x (the BENCH_engine ``gp``
    block asserts this)."""
    return rows.get("rows_bucketed", 0) / max(rows["rows_ready"], 1)


def obs_summary(history: dict) -> dict:
    """Collapse one member's drained ring history (``SimResults.obs``)
    into scalar telemetry for sweep-cell records and manifests.

    Event rings (oom/fail/preempt/admitted/throttled/cov_*) are per-tick
    deltas, so their SUM is the run total; level rings (used/queue/gap/
    credit) report means and peaks.
    """
    t = int(history["queue"].shape[0]) if history else 0
    if t == 0:
        return {"ticks": 0}
    out = {"ticks": t}
    for name in ("oom", "fail", "preempt", "admitted", "throttled",
                 "cov_resolved", "cov_errors"):
        out[f"{name}_total"] = int(history[name].sum())
    for name in ("used_cpu", "used_mem", "gap_cpu", "gap_mem", "credit"):
        out[f"{name}_mean"] = float(history[name].mean())
    out["queue_mean"] = float(history["queue"].mean())
    out["queue_peak"] = int(history["queue"].max())
    out["gap_cpu_peak"] = float(history["gap_cpu"].max(initial=0.0))
    res = out["cov_resolved_total"]
    # guard the zero-resolved case explicitly: a short run that never
    # resolves a forecast must omit the key rather than divide by zero
    # and leak NaN into the cell summary / manifest
    if res > 0:
        out["coverage"] = round(1.0 - out["cov_errors_total"] / res, 4)
    return out


def compact_history(history: dict, max_points: int = 512) -> dict:
    """Downsample a drained history for artifact embedding (dashboard
    sparklines): every channel is bucketed to at most ``max_points``.

    Event channels (per-tick deltas) SUM within each bucket so run
    totals survive the downsampling exactly; level channels take the
    bucket MEAN.  The stride is recorded so alert tick coordinates map
    onto bucket indices (``tick // stride``).
    """
    if not history:
        return {"ticks": 0, "stride": 1, "channels": {}}
    t = int(next(iter(history.values())).shape[0])
    stride = max(1, -(-t // max_points))        # ceil div
    n = -(-t // stride)
    event = {"oom", "fail", "preempt", "admitted", "throttled",
             "cov_resolved", "cov_errors"}
    channels = {}
    for name, x in history.items():
        x = np.asarray(x, np.float64)
        pad = np.full(n * stride, np.nan)
        pad[:t] = x
        buckets = pad.reshape(n, stride)
        if name in event:
            y = np.nansum(buckets, axis=1)
        else:
            y = np.nanmean(buckets, axis=1)
        channels[name] = [round(float(v), 4) for v in y]
    return {"ticks": t, "stride": stride, "channels": channels}
