"""Device-side telemetry rings: per-tick time series inside the fused step.

The scan/shard engines used to emit END-OF-RUN scalars only (counters
plus the ``TickMetrics`` usage sums).  ``ObsState`` adds circular
per-tick rings — queue depth, shaped-vs-actual demand gap, OOM /
admission / gate / credit events, conformal coverage deltas — written
by ``repro.sim.step.fused_tick`` and drained by the host at chunk
boundaries (:class:`RingDrain`), so a run yields full histories.

Two invariants, inherited from ``TickMetrics``:

  * STRUCTURAL ABSENCE — ``SimState.obs`` is ``None`` when
    ``SimConfig.obs.enabled`` is off, so disabled programs are
    bit-identical to pre-observability engines (same convention as
    ``TenantState`` / ``CalibState``);
  * CHUNK INVARIANCE — rings record raw per-tick sums and event DELTAS,
    never ratios (XLA may rewrite loop-invariant divisions depending on
    unroll; the sums are chunk-stable), and writes are gated on the
    same ``active`` mask as ``TickMetrics.valid``, so drained histories
    are identical for chunk=1 and chunk=32.

Layout: the fields are PACKED into one f32 and one i32 matrix of shape
``(F, R)`` rather than one array per field — the tick then pays two
one-hot masked writes and two stacks instead of thirteen, and the
state adds three leaves instead of fourteen (leaf count is what eager
per-member slicing and init dispatch scale with).  The packing is an
implementation detail: :meth:`RingDrain.history` still returns a
``field name -> (T,)`` mapping.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.config import ObsConfig

Array = jax.Array

# ring fields: (name, dtype).  All raw sums / deltas — see module doc.
RING_FIELDS = (
    ("used_cpu", jnp.float32),      # cluster-total instantaneous usage
    ("used_mem", jnp.float32),
    ("queue", jnp.int32),           # apps waiting in the FIFO queue
    ("gap_cpu", jnp.float32),       # shaped-demand sum - usage sum
    ("gap_mem", jnp.float32),       # (0 under the baseline policy)
    ("oom", jnp.int32),             # OOM kills this tick
    ("fail", jnp.int32),            # uncontrolled failure events
    ("preempt", jnp.int32),         # full + partial preemptions
    ("admitted", jnp.int32),        # apps admitted from the queue
    ("throttled", jnp.int32),       # gate-held queued app-ticks (tenancy)
    ("credit", jnp.float32),        # mean credit of active tenants
    ("cov_resolved", jnp.int32),    # conformal predictions resolved
    ("cov_errors", jnp.int32),      # ... of which miscovered
)

F32_NAMES = tuple(n for n, dt in RING_FIELDS if dt == jnp.float32)
I32_NAMES = tuple(n for n, dt in RING_FIELDS if dt == jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ObsState:
    """Per-run telemetry rings (``(B, ...)``-leading under a cohort
    vmap).  ``cursor`` counts total ticks recorded (monotone); tick
    ``k`` lives at ring column ``k % R`` until drained."""

    cursor: Array   # () i32
    f32: Array      # (len(F32_NAMES), R) f32, rows in F32_NAMES order
    i32: Array      # (len(I32_NAMES), R) i32, rows in I32_NAMES order
    # leap engine only (None otherwise — structural absence, so uniform
    # programs are unchanged): idle ticks skipped immediately BEFORE the
    # tick recorded at each column.  Skipped ticks are provably all-zero
    # on every channel (empty cluster, empty queue, quiescent
    # calibration), so RingDrain re-expands them into zero history
    # columns and leap histories stay bit-identical to uniform ones.
    lead: Array | None = None


def obs_init(cfg: ObsConfig, batch: int | None = None,
             leap: bool = False) -> ObsState:
    """Fresh rings (optionally with a leading cohort axis)."""
    B = () if batch is None else (batch,)
    R = int(cfg.ring)
    return ObsState(
        cursor=jnp.zeros(B, jnp.int32),
        f32=jnp.zeros(B + (len(F32_NAMES), R), jnp.float32),
        i32=jnp.zeros(B + (len(I32_NAMES), R), jnp.int32),
        lead=jnp.zeros(B + (R,), jnp.int32) if leap else None)


def obs_record(obs: ObsState, active: Array, values: dict,
               lead: Array | None = None) -> ObsState:
    """Write one tick's values at ``cursor % R`` (one-hot masked update —
    no scatter: XLA CPU serializes scatters under vmap).  Gated on
    ``active`` exactly like ``TickMetrics.valid``, so padding ticks
    after global completion record nothing.  ``lead`` (leap engine) is
    stored alongside the column when the state carries a lead ring."""
    R = obs.f32.shape[-1]
    oh = (jnp.arange(R) == obs.cursor % R) & active
    vf = jnp.stack([jnp.asarray(values[n], jnp.float32)
                    for n in F32_NAMES])
    vi = jnp.stack([jnp.asarray(values[n], jnp.int32)
                    for n in I32_NAMES])
    lead_ring = obs.lead
    if lead_ring is not None:
        lead_val = (jnp.zeros((), jnp.int32) if lead is None
                    else jnp.asarray(lead, jnp.int32))
        lead_ring = jnp.where(oh, lead_val, obs.lead)
    return ObsState(
        cursor=obs.cursor + active.astype(jnp.int32),
        f32=jnp.where(oh, vf[:, None], obs.f32),
        i32=jnp.where(oh, vi[:, None], obs.i32),
        lead=lead_ring)


class RingDrain:
    """Host-side accumulator: chunk-boundary ``ObsState`` snapshots ->
    contiguous per-tick histories.

    Tracks a drained-count per cohort member (members finish at
    different ticks, so cursors diverge) and unrolls the modular ring
    indexing.  The chunk drivers guarantee ``chunk <= ring capacity``,
    so no undrained entry is ever overwritten; a violation raises."""

    def __init__(self):
        self._drained: np.ndarray | None = None
        self._parts: list[dict] | None = None

    def drain(self, obs: ObsState) -> None:
        h = jax.device_get(obs)      # sharded states gather here (small)
        cur = np.asarray(h.cursor, np.int64).reshape(-1)
        R = np.asarray(h.f32).shape[-1]
        f32 = np.asarray(h.f32).reshape(-1, len(F32_NAMES), R)
        i32 = np.asarray(h.i32).reshape(-1, len(I32_NAMES), R)
        lead = (None if h.lead is None
                else np.asarray(h.lead, np.int64).reshape(-1, R))
        if self._parts is None:
            self._drained = np.zeros_like(cur)
            self._parts = [{name: [] for name, _ in RING_FIELDS}
                           for _ in range(cur.size)]
        for m in range(cur.size):
            n = int(cur[m] - self._drained[m])
            if n == 0:
                continue
            if n > R:
                raise RuntimeError(
                    f"obs ring overflow: {n} ticks written since the "
                    f"last drain exceeds capacity {R} (keep chunk <= "
                    "SimConfig.obs.ring)")
            idx = (self._drained[m] + np.arange(n)) % R
            pos = None
            if lead is not None:
                # leap engine: expand each column into its `lead`
                # skipped (all-zero) ticks followed by the recorded tick
                reps = lead[m, idx] + 1
                pos = np.cumsum(reps) - 1
                n = int(reps.sum())
            for j, name in enumerate(F32_NAMES):
                col = f32[m, j, idx]
                if pos is not None:
                    out = np.zeros(n, col.dtype)
                    out[pos] = col
                    col = out
                self._parts[m][name].append(col)
            for j, name in enumerate(I32_NAMES):
                col = i32[m, j, idx]
                if pos is not None:
                    out = np.zeros(n, col.dtype)
                    out[pos] = col
                    col = out
                self._parts[m][name].append(col)
        self._drained = cur.copy()

    def history(self, member: int = 0) -> dict:
        """``field -> (T,) array`` of per-tick values for one member
        (T = the member's executed tick count)."""
        if self._parts is None:
            return {name: np.zeros((0,), np.dtype(dt))
                    for name, dt in RING_FIELDS}
        p = self._parts[member]
        return {name: (np.concatenate(p[name]) if p[name]
                       else np.zeros((0,), np.dtype(dt)))
                for name, dt in RING_FIELDS}
