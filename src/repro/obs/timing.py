"""Shared benchmark timers.

Every benchmark used to carry its own copy of a best-of-N
``time.perf_counter()`` loop (engine / shard / tenancy) or an
average-of-N blocking loop (kernels).  These are THE implementations
now; samples are mirrored into the process :data:`repro.obs.metrics.REGISTRY`
so manifests and bench artifacts can snapshot what was measured.
"""
from __future__ import annotations

import time

from repro.obs.metrics import REGISTRY

__all__ = ["best_of", "time_us"]


def best_of(fn, n: int, metric: str | None = None) -> float:
    """Min wall-clock seconds of ``fn()`` over ``n`` runs (the classic
    noise-robust estimator: min is the run with the least interference).

    ``metric`` names a :class:`~repro.obs.metrics.Histogram` that
    receives every individual sample (not just the min)."""
    hist = REGISTRY.histogram(metric) if metric else None
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        if hist is not None:
            hist.observe(dt)
        best = min(best, dt)
    return best


def time_us(fn, *args, iters: int = 5, metric: str | None = None) -> float:
    """Average microseconds per call of a jax computation: one warmup
    call (blocked), then ``iters`` back-to-back calls with a single
    trailing ``block_until_ready`` — the kernel-microbench convention."""
    import jax  # lazy: repro.obs stays importable without a backend

    out = fn(*args)
    out[0].block_until_ready() if isinstance(out, tuple) else \
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    if metric:
        REGISTRY.histogram(metric).observe(us)
    return us
