"""Host-side span tracing: Chrome trace-event / Perfetto JSON.

A :class:`Tracer` collects "X" (complete) events — name, category,
start timestamp, duration — from :func:`span` context managers placed
around sweep-driver phases (trace build, jit compile, chunk execute,
ring drain, per-combo cohorts).  :func:`tracing` installs a global
tracer for a ``with`` region and writes the JSON on exit; when no
tracer is installed every ``span`` is a shared no-op, so the
instrumentation costs one dict lookup on the disabled path.

Load the output in ``chrome://tracing`` or https://ui.perfetto.dev.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

__all__ = ["Tracer", "span", "tracing", "current_tracer",
           "validate_trace", "profiler_annotation"]

_NULL = contextlib.nullcontext()
_lock = threading.Lock()
_tracer: Tracer | None = None


class Tracer:
    """Accumulates Chrome trace events (``ts``/``dur`` in microseconds
    relative to the tracer's epoch, per the trace-event spec)."""

    def __init__(self):
        self._epoch = time.perf_counter_ns()
        self._lock = threading.Lock()
        self.events: list[dict] = []

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._epoch) / 1e3

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "sweep", args: dict | None = None):
        t0 = self._now_us()
        error: str | None = None
        try:
            yield
        except BaseException as e:
            # close the span with an error tag and re-raise: the phase
            # still shows up in the waterfall (flagged), and the tracer
            # state stays consistent for whatever spans come after
            error = type(e).__name__
            raise
        finally:
            ev = {"name": name, "cat": cat, "ph": "X", "ts": t0,
                  "dur": self._now_us() - t0, "pid": os.getpid(),
                  "tid": threading.get_ident()}
            if args or error:
                ev["args"] = dict(args or {})
                if error:
                    ev["args"]["error"] = error
            with self._lock:
                self.events.append(ev)

    def instant(self, name: str, cat: str = "sweep",
                args: dict | None = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "ts": self._now_us(),
              "s": "p", "pid": os.getpid(), "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)

    def to_json(self) -> dict:
        with self._lock:
            evs = sorted(self.events, key=lambda e: e["ts"])
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


def current_tracer() -> Tracer | None:
    return _tracer


def span(name: str, cat: str = "sweep", args: dict | None = None):
    """Span against the installed tracer, or a shared no-op context."""
    t = _tracer
    return t.span(name, cat, args) if t is not None else _NULL


@contextlib.contextmanager
def tracing(path: str | None = None):
    """Install a global :class:`Tracer` for the ``with`` body; write the
    trace JSON to ``path`` on exit (even on error).  Yields the tracer.
    Nested ``tracing`` regions are refused — spans are process-global."""
    global _tracer
    t = Tracer()
    with _lock:
        if _tracer is not None:
            raise RuntimeError("a tracer is already installed")
        _tracer = t
    try:
        yield t
    finally:
        with _lock:
            _tracer = None
        if path is not None:
            t.save(path)


def profiler_annotation(name: str):
    """Optional ``jax.profiler`` hook: returns a TraceAnnotation so obs
    spans also show up in XLA profiler dumps, or a no-op context when
    the profiler is unavailable (e.g. stripped minimal builds)."""
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


def validate_trace(doc) -> list[str]:
    """Schema check for a loaded (or stringified) trace document.

    Returns a list of problems (empty == valid):
      * top level is an object bearing a ``traceEvents`` list,
      * every event has name/ph/ts/pid/tid; ``X`` events have numeric
        ``dur >= 0``,
      * ``B``/``E`` events are properly nested per (pid, tid),
      * event ``ts`` are monotone non-decreasing in file order.
    """
    problems: list[str] = []
    if isinstance(doc, (str, bytes)):
        try:
            doc = json.loads(doc)
        except json.JSONDecodeError as e:
            return [f"not valid JSON: {e}"]
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["top level must be an object with a traceEvents list"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents must be a list"]
    stacks: dict[tuple, list[str]] = {}
    last_ts = None
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        missing = [k for k in ("name", "ph", "ts", "pid", "tid")
                   if k not in ev]
        if missing:
            problems.append(f"event {i}: missing {missing}")
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: non-numeric ts")
            continue
        if last_ts is not None and ts < last_ts:
            problems.append(f"event {i}: ts not monotone "
                            f"({ts} < {last_ts})")
        last_ts = ts
        ph = ev["ph"]
        key = (ev["pid"], ev["tid"])
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X event needs dur >= 0")
        elif ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.setdefault(key, [])
            if not stack:
                problems.append(f"event {i}: E without matching B")
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            problems.append(f"unclosed B events on {key}: {stack}")
    return problems
