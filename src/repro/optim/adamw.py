"""AdamW with decoupled weight decay, global-norm clipping and a cosine
schedule — functional, pytree-generic, and sharding-transparent (the
optimizer state inherits each parameter's sharding, so ZeRO-style
optimizer-state sharding falls out of the param specs for free).

Moments are kept in fp32 regardless of parameter dtype (bf16 params on
TPU): mixed-precision training without a separate master copy —
the fp32 ``mu`` buffer doubles as the high-precision signal.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params) -> dict:
    def zeros32(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
