"""Serving substrate: prefill + decode steps with KV/SSM caches."""
from repro.serve.engine import (decode_step_fn, greedy_generate, prefill_fn,
                                whisper_decode_step_fn)

__all__ = ["prefill_fn", "decode_step_fn", "greedy_generate",
           "whisper_decode_step_fn"]
