"""Serving steps — what the decode_32k / long_500k dry-run cells lower.

``decode_step_fn``: ONE new token per request against a pre-filled cache
(the assigned decode shapes: cache length = seq_len, batch = global
decode batch).  ``prefill_fn`` builds the cache from a prompt in a
single forward.  ``greedy_generate`` chains them for the examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.config import ModelConfig

Array = jax.Array


def prefill_fn(params, cfg: ModelConfig, tokens: Array, max_len: int,
               img_embeds: Array | None = None):
    """Returns (caches, last_token_logits)."""
    B = tokens.shape[0]
    caches = T.init_caches(cfg, B, max_len)
    logits, caches, _ = T.forward(params, cfg, tokens=tokens,
                                  img_embeds=img_embeds, caches=caches)
    return caches, logits[:, -1]


def decode_step_fn(params, cfg: ModelConfig, token: Array, caches):
    """token: (B, 1) -> (logits (B, vocab), new caches)."""
    logits, caches, _ = T.forward(params, cfg, tokens=token, caches=caches)
    return logits[:, -1], caches


def whisper_decode_step_fn(params, cfg: ModelConfig, token: Array,
                           enc_out: Array, caches):
    logits, caches = W.decode(params, token, enc_out, cfg, caches)
    return logits[:, -1], caches


def greedy_generate(params, cfg: ModelConfig, prompt: Array, steps: int,
                    max_len: int) -> Array:
    caches, logits = prefill_fn(params, cfg, prompt, max_len)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    def body(carry, _):
        tok, caches = carry
        logits, caches = decode_step_fn(params, cfg, tok, caches)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return (nxt, caches), nxt[:, 0]

    (_, _), out = jax.lax.scan(body, (tok, caches), None, length=steps)
    return jnp.concatenate([prompt, tok, out.T], axis=1)
