"""Trace-driven discrete-event cluster simulator (paper §4)."""
from repro.sim.cluster import Cluster, ClusterConfig
from repro.sim.engine import SimConfig, run_sim
from repro.sim.metrics import SimResults
from repro.sim.workload import Workload, WorkloadConfig, generate

__all__ = ["Cluster", "ClusterConfig", "SimConfig", "run_sim", "SimResults",
           "Workload", "WorkloadConfig", "generate"]
