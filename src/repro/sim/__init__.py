"""Trace-driven discrete-event cluster simulator (paper §4)."""
from repro.sim.cluster import Cluster, ClusterConfig
from repro.sim.engine import SimConfig, run_sim
from repro.sim.metrics import SimResults, aggregate_summaries, trace_stats
from repro.sim.workload import Trace, Workload, WorkloadConfig, generate

__all__ = ["Cluster", "ClusterConfig", "SimConfig", "run_sim",
           "run_sim_reference", "run_sim_scan", "run_cohort_scan",
           "run_fleet_shard", "fleet_mesh",
           "SimResults", "aggregate_summaries",
           "trace_stats",
           "Trace", "Workload", "WorkloadConfig", "generate",
           "build_trace", "make_config", "scenario_names", "scenario_of",
           "load_trace", "save_trace",
           "ForecastBatcher", "SweepCell", "SweepResult", "expand_grid",
           "run_grid"]

_LAZY = {
    "run_sim_reference": "repro.sim.engine_ref",
    "run_sim_scan": "repro.sim.step",
    "run_cohort_scan": "repro.sim.step",
    "run_fleet_shard": "repro.sim.step",
    "fleet_mesh": "repro.sim.shard",
    "build_trace": "repro.sim.scenarios",
    "make_config": "repro.sim.scenarios",
    "scenario_names": "repro.sim.scenarios",
    "scenario_of": "repro.sim.scenarios",
    "load_trace": "repro.sim.scenarios",
    "save_trace": "repro.sim.scenarios",
    "ForecastBatcher": "repro.sim.sweep",
    "SweepCell": "repro.sim.sweep",
    "SweepResult": "repro.sim.sweep",
    "expand_grid": "repro.sim.sweep",
    "run_grid": "repro.sim.sweep",
}


def __getattr__(name):
    # lazy so that `python -m repro.sim.sweep` does not re-import the
    # module it is executing (runpy's sys.modules warning)
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
