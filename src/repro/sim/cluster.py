"""Cluster state + mechanics for the discrete-event simulation (paper §4.1).

Default geometry matches the paper: 250 homogeneous machines, 32 cores,
128 GB each (scaled down by configs for CI-speed runs).  The cluster
holds a fixed slot table of running applications (A slots x C components)
— the same padded layout the JAX shaping policies consume — plus the
placement, preemption and OOM mechanics that the engine drives.

OOM semantics: Docker soft limits mean a component may use more than its
allocation while the host has headroom; only when a host's total usage
exceeds its capacity does the "OS" step in and kill — victim order is the
largest (usage - allocation) overage first, the closest analogue of the
kernel badness score, and exactly the "unpredictable, application
agnostic" behavior the paper's pessimistic policy is designed to avoid.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.workload import Workload

CPU, MEM = 0, 1


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    n_hosts: int = 50
    host_cpu: float = 32.0
    host_mem: float = 128.0
    max_running_apps: int = 128     # slot-table A (padded, JAX-fixed)
    tick: float = 60.0              # monitoring interval (paper: 1 min)


class Cluster:
    def __init__(self, cfg: ClusterConfig, max_components: int):
        self.cfg = cfg
        A, C, H = cfg.max_running_apps, max_components, cfg.n_hosts
        self.A, self.C, self.H = A, C, H
        self.host_cap = np.zeros((H, 2), np.float32)
        self.host_cap[:, CPU] = cfg.host_cpu
        self.host_cap[:, MEM] = cfg.host_mem
        self.slot_gid = np.full((A,), -1, np.int64)
        self.start_time = np.zeros((A,), np.float32)
        self.work_done = np.zeros((A,), np.float32)
        self.comp_running = np.zeros((A, C), bool)
        self.comp_host = np.zeros((A, C), np.int32)
        self.alloc = np.zeros((A, C, 2), np.float32)
        self.alive_since = np.zeros((A, C), np.float32)

    # ------------------------------------------------------------------
    # resource accounting
    # ------------------------------------------------------------------
    def running_slots(self) -> np.ndarray:
        return np.nonzero(self.slot_gid >= 0)[0]

    def free_resources(self) -> np.ndarray:
        """(H, 2) capacity minus committed allocations."""
        used = np.zeros((self.H, 2), np.float32)
        run = self.comp_running
        for r in (CPU, MEM):
            np.add.at(used[:, r], self.comp_host[run],
                      self.alloc[:, :, r][run])
        return self.host_cap - used

    def host_usage(self, usage: np.ndarray) -> np.ndarray:
        """usage: (A, C, 2) instantaneous -> (H, 2) per-host totals."""
        tot = np.zeros((self.H, 2), np.float32)
        run = self.comp_running
        for r in (CPU, MEM):
            np.add.at(tot[:, r], self.comp_host[run], usage[:, :, r][run])
        return tot

    # ------------------------------------------------------------------
    # placement (worst fit = most-free host, for load balance — the
    # paper's cited schedulers re-balance load across hosts [Mercury];
    # first-fit would cram host 0 and manufacture artificial contention)
    # ------------------------------------------------------------------
    def _fit_component(self, free: np.ndarray, cpu: float, mem: float) -> int:
        ok = (free[:, CPU] >= cpu) & (free[:, MEM] >= mem)
        if not ok.any():
            return -1
        score = np.where(ok, free[:, MEM], -np.inf)
        return int(np.argmax(score))

    def admit(self, gid: int, wl: Workload, t: float) -> int:
        """Place an app: all CORE components must fit (else reject);
        elastic components placed best-effort.  Returns slot or -1."""
        empty = np.nonzero(self.slot_gid < 0)[0]
        if empty.size == 0:
            return -1
        slot = int(empty[0])
        free = self.free_resources().copy()
        C = self.C
        placement = np.full((C,), -1, np.int32)
        for c in range(C):
            if wl.cpu_req[gid, c] == 0:
                continue
            if not wl.is_core[gid, c]:
                continue
            h = self._fit_component(free, wl.cpu_req[gid, c], wl.mem_req[gid, c])
            if h < 0:
                return -1  # core does not fit -> stays queued
            placement[c] = h
            free[h, CPU] -= wl.cpu_req[gid, c]
            free[h, MEM] -= wl.mem_req[gid, c]
        for c in range(C):
            if wl.cpu_req[gid, c] == 0 or wl.is_core[gid, c]:
                continue
            h = self._fit_component(free, wl.cpu_req[gid, c], wl.mem_req[gid, c])
            if h >= 0:
                placement[c] = h
                free[h, CPU] -= wl.cpu_req[gid, c]
                free[h, MEM] -= wl.mem_req[gid, c]
        # commit
        self.slot_gid[slot] = gid
        self.start_time[slot] = t
        self.work_done[slot] = 0.0
        placed = placement >= 0
        self.comp_running[slot] = placed
        self.comp_host[slot] = np.maximum(placement, 0)
        self.alloc[slot, :, CPU] = np.where(placed, wl.cpu_req[gid], 0.0)
        self.alloc[slot, :, MEM] = np.where(placed, wl.mem_req[gid], 0.0)
        self.alive_since[slot] = t
        return slot

    def place_missing_elastic(self, wl: Workload, t: float) -> int:
        """Best-effort (re)placement of elastic components at reservation.

        The (slot, component) candidates are found with one array scan
        over the slot table; only the usually-tiny set of actually-missing
        elastic components is walked sequentially (placement is order-
        dependent: each fit consumes free capacity).  Walk order is
        row-major (slot asc, component asc) — identical to the seed's
        nested loops."""
        gid_safe = np.maximum(self.slot_gid, 0)
        missing = ((self.slot_gid >= 0)[:, None]
                   & (wl.cpu_req[gid_safe] > 0)
                   & ~wl.is_core[gid_safe]
                   & ~self.comp_running)
        slots, comps = np.nonzero(missing)
        if slots.size == 0:
            return 0
        placed = 0
        free = self.free_resources().copy()
        for slot, c in zip(slots, comps):
            gid = self.slot_gid[slot]
            h = self._fit_component(free, wl.cpu_req[gid, c],
                                    wl.mem_req[gid, c])
            if h < 0:
                continue
            self.comp_running[slot, c] = True
            self.comp_host[slot, c] = h
            self.alloc[slot, c, CPU] = wl.cpu_req[gid, c]
            self.alloc[slot, c, MEM] = wl.mem_req[gid, c]
            self.alive_since[slot, c] = t
            free[h, CPU] -= wl.cpu_req[gid, c]
            free[h, MEM] -= wl.mem_req[gid, c]
            placed += 1
        return placed

    # ------------------------------------------------------------------
    # preemption primitives
    # ------------------------------------------------------------------
    def kill_component(self, slot: int, c: int) -> None:
        self.comp_running[slot, c] = False
        self.alloc[slot, c] = 0.0

    def kill_components(self, slots: np.ndarray, comps: np.ndarray) -> None:
        """Batched ``kill_component`` over parallel (slot, comp) arrays."""
        self.comp_running[slots, comps] = False
        self.alloc[slots, comps] = 0.0

    def evict_app(self, slot: int) -> int:
        gid = int(self.slot_gid[slot])
        self.slot_gid[slot] = -1
        self.comp_running[slot] = False
        self.alloc[slot] = 0.0
        self.work_done[slot] = 0.0
        return gid

    def evict_apps(self, slots: np.ndarray) -> np.ndarray:
        """Batched ``evict_app``: returns the evicted gids."""
        gids = self.slot_gid[slots].copy()
        self.slot_gid[slots] = -1
        self.comp_running[slots] = False
        self.alloc[slots] = 0.0
        self.work_done[slots] = 0.0
        return gids

    # ------------------------------------------------------------------
    # progress & OOM
    # ------------------------------------------------------------------
    def progress_rate(self, wl: Workload) -> np.ndarray:
        """(A,) work/second.  rate = (1 + running elastic)/(1 + n_elastic);
        a full component set progresses at 1.0 (base runtime)."""
        rate = np.zeros((self.A,), np.float32)
        run = self.running_slots()
        if run.size == 0:
            return rate
        gids = self.slot_gid[run]
        is_core = wl.is_core[gids]
        exists = wl.cpu_req[gids] > 0
        running = self.comp_running[run]
        core_ok = ((is_core & running).sum(1) == is_core.sum(1))
        n_el = (exists & ~is_core).sum(1)
        n_run_el = (running & ~is_core).sum(1)
        rate[run] = core_ok * (1.0 + n_run_el) / (1.0 + n_el)
        return rate

    def progress(self, wl: Workload) -> np.ndarray:
        """(A,) fraction of work completed, for pattern lookup."""
        p = np.zeros((self.A,), np.float32)
        run = self.running_slots()
        if run.size:
            gids = self.slot_gid[run]
            p[run] = np.clip(self.work_done[run] / wl.runtime[gids], 0.0, 1.0)
        return p

    def usage_now(self, wl: Workload) -> np.ndarray:
        """(A, C, 2) instantaneous usage of running components."""
        out = np.zeros((self.A, self.C, 2), np.float32)
        run = self.running_slots()
        if run.size:
            gids = self.slot_gid[run]
            u = wl.usage(gids, self.progress(wl)[run])
            out[run] = u * self.comp_running[run][:, :, None]
        return out

    def resolve_oom(self, wl: Workload, usage: np.ndarray):
        """OS OOM handler: for every over-capacity host, kill components by
        descending (usage - allocation) overage until the host fits.
        Returns (full_kill_slots, partial_kills [(slot, c)]).

        Each victim selection is one array scan over the slot table
        (candidate membership, totals and the argmax are NumPy ops); the
        outer loop runs once per actual kill, i.e. O(events) not
        O(slots x components) Python iterations.  Victim order matches the
        seed's ``sort(reverse=True)`` tuple ordering exactly: largest
        overage first, ties broken by largest slot then largest component
        (``np.nonzero`` is row-major, so the last tied index wins)."""
        full, partial = [], []
        host_tot = self.host_usage(usage)
        over_hosts = np.nonzero(host_tot[:, MEM] > self.host_cap[:, MEM] + 1e-6)[0]
        for h in over_hosts:
            while True:
                on_h = self.comp_running & (self.comp_host == h)
                mem = usage[:, :, MEM]
                vals = mem[on_h]
                # sequential float32 accumulation in row-major order —
                # bit-identical to the seed loop's `tot += usage[...]`
                # (NEP-50: 0.0 + float32 stays float32); a pairwise or
                # float64 sum can flip the near-capacity stop condition
                tot = vals.cumsum(dtype=np.float32)[-1] if vals.size else 0.0
                if tot <= self.host_cap[h, MEM] + 1e-6 or not vals.size:
                    break
                over = np.where(on_h, mem - self.alloc[:, :, MEM], -np.inf)
                cand_s, cand_c = np.nonzero(over == over.max())
                slot, c = int(cand_s[-1]), int(cand_c[-1])
                gid = int(self.slot_gid[slot])
                if wl.is_core[gid, c]:
                    usage[slot] = 0.0
                    self.evict_app(slot)
                    full.append(gid)
                else:
                    usage[slot, c] = 0.0
                    self.kill_component(slot, c)
                    partial.append((slot, c))
        return full, partial
