"""Simulation engine (paper §4): FIFO scheduler + monitor + forecast +
resource shaper, advanced in 60 s monitoring ticks.

Per tick:
  1. arrivals enter the FIFO queue (priority = ORIGINAL submit time, so a
     resubmitted-after-failure app re-enters "commensurate to its original
     priority" — paper §3.2);
  2. running apps progress (elastic rate model), completions recorded;
  3. the monitor samples per-component CPU/memory usage;
  4. past the grace period, the forecaster predicts each component's
     future utilization (mean + variance), the safeguard buffer (Eq. 9)
     turns it into a shaped demand, and the shaping policy (baseline /
     optimistic / pessimistic Algorithm 1) computes allocations +
     preemptions, which are applied through the preemption primitives;
  5. the OS OOM handler fires for any host whose true usage exceeds
     capacity (the uncontrolled-failure channel);
  6. the scheduler admits queued apps into freed capacity and re-places
     missing elastic components.

Forecast + shaping run as jitted, vmapped JAX on fixed-size padded
batches — identical code paths to the live framework's shaper service.
"""
from __future__ import annotations

import bisect
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forecast import (ARIMAConfig, ARIMAForecaster, GPConfig,
                                 GPForecaster)
from repro.core.monitor import Monitor
from repro.core.shaper import (POLICIES, SafeguardConfig, ShapeProblem,
                               shaped_demand)
from repro.sim.cluster import CPU, MEM, Cluster, ClusterConfig
from repro.sim.metrics import SimResults
from repro.sim.workload import Workload, WorkloadConfig, generate


@dataclasses.dataclass(frozen=True)
class SimConfig:
    cluster: ClusterConfig = ClusterConfig()
    workload: WorkloadConfig = WorkloadConfig()
    policy: str = "pessimistic"          # baseline | optimistic | pessimistic
    forecaster: str = "gp"               # oracle | gp | arima | persist
    safeguard: SafeguardConfig = SafeguardConfig()
    window: int = 24                     # monitor window (ticks)
    grace: int = 10                      # grace period (paper §5: 10 min)
    horizon: int = 3                     # forecast look-ahead (ticks)
    gp: GPConfig = GPConfig(history=10, max_patterns=10, opt_steps=10)
    arima: ARIMAConfig = ARIMAConfig()
    max_ticks: int = 100_000
    work_lost_on_kill: bool = True       # kill primitive loses all work


def _bucket(n: int) -> int:
    b = 64
    while b < n:
        b *= 2
    return b


class _BatchedForecaster:
    """Caches jitted batched forecast fns per (kind, bucket size)."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self._jitted = {}
        if cfg.forecaster == "gp":
            self._model = GPForecaster(cfg.gp)
        elif cfg.forecaster == "arima":
            self._model = ARIMAForecaster(cfg.arima)
        else:
            self._model = None

    def __call__(self, windows: np.ndarray, valid: np.ndarray):
        """windows: (n, W) -> (peak_mean, peak_var) each (n,)."""
        cfg = self.cfg
        n = windows.shape[0]
        if cfg.forecaster == "persist":
            mean = windows[:, -1]
            var = windows.var(axis=1, where=valid) + 1e-6
            return mean, var
        b = _bucket(n)
        if b not in self._jitted:
            model, horizon = self._model, cfg.horizon

            @jax.jit
            def fn(w, v):
                fc = model.forecast_batch(w, horizon, valid=v)
                # future PEAK utilization (paper §4.2: predictor outputs a
                # future peak; we take the max of the path + its variance)
                k = jnp.argmax(fc.mean, axis=1)
                peak = jnp.take_along_axis(fc.mean, k[:, None], 1)[:, 0]
                pvar = jnp.take_along_axis(fc.var, k[:, None], 1)[:, 0]
                return peak, pvar

            self._jitted[b] = fn
        wpad = np.zeros((b, windows.shape[1]), np.float32)
        vpad = np.zeros((b, windows.shape[1]), bool)
        wpad[:n], vpad[:n] = windows, valid
        peak, pvar = self._jitted[b](jnp.asarray(wpad), jnp.asarray(vpad))
        return np.asarray(peak)[:n], np.asarray(pvar)[:n]


def _oracle_peaks(cluster: Cluster, wl: Workload, horizon: int,
                  tick: float) -> np.ndarray:
    """(A, C, 2) true future peak usage over the horizon (variance 0)."""
    A, C = cluster.A, cluster.C
    out = np.zeros((A, C, 2), np.float32)
    run = cluster.running_slots()
    if run.size == 0:
        return out
    gids = cluster.slot_gid[run]
    rate = cluster.progress_rate(wl)[run]
    peaks = np.zeros((run.size, C, 2), np.float32)
    for k in range(1, horizon + 1):
        prog = np.clip((cluster.work_done[run] + rate * tick * k)
                       / wl.runtime[gids], 0.0, 1.0)
        u = wl.usage(gids, prog) * cluster.comp_running[run][:, :, None]
        peaks = np.maximum(peaks, u)
    out[run] = peaks
    return out


def run_sim(cfg: SimConfig, wl: Workload | None = None) -> SimResults:
    wl = wl if wl is not None else generate(cfg.workload)
    N, C = wl.n_apps, wl.max_components
    cl = Cluster(cfg.cluster, C)
    A = cl.A
    mon = Monitor(slots=A * C, window=cfg.window)
    fc = _BatchedForecaster(cfg)
    policy_fn = POLICIES[cfg.policy]
    res = SimResults(n_apps=N)
    tick = cfg.cluster.tick

    queue: list[tuple[float, int]] = []   # (original submit, gid) sorted
    arrived = 0
    done = np.zeros((N,), bool)
    submit0 = wl.submit.copy()            # original submit (priority key)
    # preempt-to-checkpoint mode (work_lost_on_kill=False): a preempted
    # app resumes from its last "checkpoint" (saved progress) instead of
    # restarting — the TPU adaptation's beyond-paper ablation
    saved_work: dict[int, float] = {}

    def requeue(gid: int):
        bisect.insort(queue, (float(submit0[gid]), gid))

    t = 0.0
    for step in range(cfg.max_ticks):
        if done.all():
            break
        t += tick

        # 1. arrivals ---------------------------------------------------
        while arrived < N and wl.submit[arrived] <= t:
            requeue(arrived)
            arrived += 1

        # 2. progress + completions --------------------------------------
        rate = cl.progress_rate(wl)
        cl.work_done += rate * tick
        for slot in cl.running_slots():
            gid = int(cl.slot_gid[slot])
            if cl.work_done[slot] >= wl.runtime[gid]:
                for c in range(C):
                    if cl.comp_running[slot, c]:
                        mon.reset_slot(slot * C + c)
                cl.evict_app(slot)
                done[gid] = True
                res.record_completion(gid, submit0[gid], t)

        # 3. monitor sampling --------------------------------------------
        usage = cl.usage_now(wl)
        run = cl.running_slots()
        if run.size:
            rc = np.nonzero(cl.comp_running[run])  # (slot_i, c)
            mslots = run[rc[0]] * C + rc[1]
            mon.record(mslots, usage[run][rc][:, CPU], usage[run][rc][:, MEM])

        # 4. shaping ------------------------------------------------------
        # two distinct kill channels (paper §4.2): controlled preemptions
        # (Algorithm 1, work lost but clean) vs uncontrolled OS OOM kills
        # (the "application failures" metric of Figs. 3-4)
        preempted_this_tick: list[int] = []
        oom_failed_this_tick: list[int] = []
        if cfg.policy != "baseline" and run.size:
            gids = cl.slot_gid[run]
            req = np.stack([wl.cpu_req[gids], wl.mem_req[gids]], -1)  # (n,C,2)
            running = cl.comp_running[run]
            demand = np.where(running[:, :, None], req, 0.0).astype(np.float32)

            if cfg.forecaster == "oracle":
                # perfect information needs no training history: the grace
                # period (paper §5) exists only for statistical models
                peaks = _oracle_peaks(cl, wl, cfg.horizon, tick)[run]
                var = np.zeros_like(peaks)
                ready = running
                shaped = np.asarray(shaped_demand(
                    jnp.asarray(peaks), jnp.asarray(req), jnp.asarray(var),
                    cfg.safeguard))
                demand = np.where(ready[:, :, None], shaped, demand)
            else:
                rc = np.nonzero(running)
                mslots = run[rc[0]] * C + rc[1]
                ready = mon.ready(mslots, cfg.grace)
                if ready.any():
                    sel = np.nonzero(ready)[0]
                    wins, vmask = mon.windows(mslots[sel])
                    n = sel.size
                    wflat = np.concatenate([wins[:, :, CPU], wins[:, :, MEM]])
                    vflat = np.concatenate([vmask, vmask])
                    mean, var = fc(wflat, vflat)
                    reqs = req[rc[0][sel], rc[1][sel]]     # (n, 2)
                    for r, off in ((CPU, 0), (MEM, n)):
                        sh = np.asarray(shaped_demand(
                            jnp.asarray(mean[off:off + n]),
                            jnp.asarray(reqs[:, r]),
                            jnp.asarray(var[off:off + n]),
                            cfg.safeguard))
                        demand[rc[0][sel], rc[1][sel], r] = sh

            # build the fixed-size ShapeProblem over ALL slots
            dem_full = np.zeros((A, C, 2), np.float32)
            dem_full[run] = demand
            app_exists = cl.slot_gid >= 0
            order = np.full((A,), -1, np.int64)
            fifo = np.argsort(submit0[np.maximum(cl.slot_gid, 0)]
                              + np.where(app_exists, 0, 1e18))
            order[:run.size] = fifo[:run.size]
            prob = ShapeProblem(
                host_cpu=jnp.asarray(cl.host_cap[:, CPU]),
                host_mem=jnp.asarray(cl.host_cap[:, MEM]),
                app_exists=jnp.asarray(app_exists),
                app_order=jnp.asarray(order),
                comp_exists=jnp.asarray(cl.comp_running),
                comp_core=jnp.asarray(
                    wl.is_core[np.maximum(cl.slot_gid, 0)]
                    & app_exists[:, None]),
                comp_host=jnp.asarray(cl.comp_host),
                comp_cpu=jnp.asarray(dem_full[:, :, CPU]),
                comp_mem=jnp.asarray(dem_full[:, :, MEM]),
                comp_alive=jnp.asarray(t - cl.alive_since),
            )
            dec = policy_fn(prob)
            kill_app = np.asarray(dec.kill_app)
            kill_comp = np.asarray(dec.kill_comp)
            alloc_cpu = np.asarray(dec.alloc_cpu)
            alloc_mem = np.asarray(dec.alloc_mem)

            for slot in np.nonzero(kill_app & app_exists)[0]:
                if not cfg.work_lost_on_kill:
                    gid0 = int(cl.slot_gid[slot])
                    saved_work[gid0] = float(cl.work_done[slot])
                gid = cl.evict_app(int(slot))
                usage[slot] = 0.0
                for c in range(C):
                    mon.reset_slot(int(slot) * C + c)
                if cfg.policy == "optimistic":
                    # optimistic-concurrency conflict: an UNCONTROLLED
                    # failure (paper: "the system will let one of the
                    # two fail")
                    oom_failed_this_tick.append(gid)
                else:
                    preempted_this_tick.append(gid)
                    res.full_preemptions += 1
            for slot, c in zip(*np.nonzero(kill_comp)):
                if cl.slot_gid[slot] >= 0 and cl.comp_running[slot, c]:
                    cl.kill_component(int(slot), int(c))
                    usage[slot, c] = 0.0
                    mon.reset_slot(int(slot) * C + int(c))
                    res.partial_preemptions += 1
            live = cl.comp_running
            cl.alloc[:, :, CPU] = np.where(live, alloc_cpu, 0.0)
            cl.alloc[:, :, MEM] = np.where(live, alloc_mem, 0.0)

        # 5. OOM (uncontrolled failures) -----------------------------------
        oom_gids, oom_partial = cl.resolve_oom(wl, usage)
        for gid in oom_gids:
            oom_failed_this_tick.append(gid)
            res.oom_kills += 1
        res.partial_preemptions += len(oom_partial)
        for slot, c in oom_partial:
            mon.reset_slot(slot * C + c)

        for gid in oom_failed_this_tick:
            res.record_failure(gid)
        for gid in oom_failed_this_tick + preempted_this_tick:
            requeue(gid)

        # 6. scheduler: FIFO admission + elastic re-placement --------------
        while queue:
            _, gid = queue[0]
            slot = cl.admit(gid, wl, t)
            if slot < 0:
                break
            queue.pop(0)
            if not cfg.work_lost_on_kill and gid in saved_work:
                cl.work_done[slot] = saved_work.pop(gid)  # resume from ckpt
            for c in range(C):
                mon.reset_slot(slot * C + c)
        cl.place_missing_elastic(wl, t)

        # 7. metrics -------------------------------------------------------
        res.record_tick(t, cl, usage)

    res.finalize(t)
    return res
