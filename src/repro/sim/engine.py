"""Simulation engine (paper §4): FIFO scheduler + monitor + forecast +
resource shaper, advanced in 60 s monitoring ticks.

Per tick:
  1. arrivals enter the FIFO queue (priority = ORIGINAL submit time, so a
     resubmitted-after-failure app re-enters "commensurate to its original
     priority" — paper §3.2);
  2. running apps progress (elastic rate model), completions recorded;
  3. the monitor samples per-component CPU/memory usage;
  4. past the grace period, the forecaster predicts each component's
     future utilization (mean + variance), the safeguard buffer (Eq. 9)
     turns it into a shaped demand, and the shaping policy (baseline /
     optimistic / pessimistic Algorithm 1) computes allocations +
     preemptions, which are applied through the preemption primitives.
     With ``SimConfig.calibration`` enabled, Eq. 9's dynamic term uses
     an online split-conformal quantile instead of the fixed K2
     sigma-multiplier (``repro.core.uncertainty``): realized peaks are
     scored against deployed bounds each tick and the calibrated scale
     tracks the target coverage.  Disabled (the default), the path is
     bit-identical to ``engine_ref``;
  5. the OS OOM handler fires for any host whose true usage exceeds
     capacity (the uncontrolled-failure channel);
  6. the scheduler admits queued apps into freed capacity and re-places
     missing elastic components.

Forecast + shaping run as jitted, vmapped JAX on fixed-size padded
batches — identical code paths to the live framework's shaper service.

This is the VECTORIZED engine: every per-tick scan (completion detection,
kill/evict application, monitor resets, OOM candidate selection) is a
NumPy array op over the padded slot table instead of a Python loop over
slots, so one tick costs O(array-op).  ``repro.sim.engine_ref`` keeps the
original loop-based implementation as a golden reference; the two are
bit-identical on any workload (``tests/test_sweep.py``).

The jitted forecast path is cached at module level keyed by
(model, horizon, batch bucket, window width), so every sim in a process —
in particular every cell of a ``repro.sim.sweep`` grid — shares one
compilation per shape instead of recompiling per ``run_sim`` call.
``run_sim(..., forecast_fn=...)`` lets the sweep driver swap in a
cross-sim batching client that stacks windows from all concurrently
running sims into one padded batch (row-deterministic, hence still
bit-identical to a solo run).
"""
from __future__ import annotations

import bisect
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forecast import (ARIMAConfig, ARIMAForecaster, GPConfig,
                                 GPForecaster)
from repro.core.forecast.base import peak_over_horizon
from repro.core.monitor import Monitor
from repro.core.shaper import (POLICIES, SafeguardConfig, ShapeProblem,
                               shaped_demand, shaped_demand_scaled)
from repro.core.uncertainty import (CalibrationConfig, OnlineCalibrator,
                                    bucket_pow2, sigma_from_var_np)
from repro.control import HostControl, TenancyConfig, tenancy_summary
from repro.obs import ObsConfig
from repro.sim.cluster import CPU, MEM, Cluster, ClusterConfig
from repro.sim.metrics import SimResults
from repro.sim.scenarios.registry import build_trace
from repro.sim.workload import Workload, WorkloadConfig


@dataclasses.dataclass(frozen=True)
class SimConfig:
    cluster: ClusterConfig = ClusterConfig()
    workload: WorkloadConfig = WorkloadConfig()
    policy: str = "pessimistic"          # baseline | optimistic | pessimistic
    forecaster: str = "gp"               # oracle | gp | arima | persist
    safeguard: SafeguardConfig = SafeguardConfig()
    # conformal calibration of the safeguard's dynamic term (disabled by
    # default — the legacy K2-sigma path stays bit-identical to
    # engine_ref; see repro.core.uncertainty)
    calibration: CalibrationConfig = CalibrationConfig()
    # multi-tenant control plane: admission gate (wDRF), credit-aware
    # shaping, per-tenant conformal pools (disabled by default — the
    # tenancy-off path is bit-identical to the pre-control-plane
    # engines; see repro.control)
    control: TenancyConfig = TenancyConfig()
    # device-side telemetry rings (disabled by default — SimState.obs is
    # then structurally absent and obs-off programs are bit-identical to
    # pre-observability engines; scan/shard only, the host engines
    # ignore it like forecast_rows; see repro.obs)
    obs: ObsConfig = ObsConfig()
    window: int = 24                     # monitor window (ticks)
    grace: int = 10                      # grace period (paper §5: 10 min)
    horizon: int = 3                     # forecast look-ahead (ticks)
    gp: GPConfig = GPConfig(history=10, max_patterns=10, opt_steps=10)
    arima: ARIMAConfig = ARIMAConfig()
    max_ticks: int = 100_000
    work_lost_on_kill: bool = True       # kill primitive loses all work
    # event-driven leap ticks (scan/shard engines only; the host engines
    # ignore it): each scan step first skips a run of provably-idle
    # ticks — empty cluster, empty queue, quiescent calibration — with a
    # cheap clock loop that replays the uniform engine's exact f32 time
    # accumulation, then executes one real tick.  Bit-identical to
    # leap=False (uniform stays the reference; tests/test_scan_engine.py
    # enforces the equivalence across all scenario families).
    leap: bool = False
    # ragged bucketed forecast batching (scan/shard engines, gp/arima):
    # compact forecast-ready monitor rows and run the model over
    # power-of-two buckets sized per chunk instead of the full padded
    # batch (the measured ~6.7x masked-row overhead).  One jit cache
    # entry per bucket, mirroring forecast_peaks' host-side padding;
    # per-row model independence makes it bit-identical, so it defaults
    # on.
    forecast_bucket: bool = True


# power-of-two padding for every jitted batch path (the shared
# convention lives in repro.core.uncertainty.scoring; engine_ref keeps
# its own frozen copy by design)
_bucket = bucket_pow2


def _make_model(cfg: SimConfig):
    if cfg.forecaster == "gp":
        return GPForecaster(cfg.gp)
    if cfg.forecaster == "arima":
        return ARIMAForecaster(cfg.arima)
    if cfg.forecaster in ("persist", "oracle"):
        return None
    raise ValueError(f"unknown forecaster {cfg.forecaster!r} "
                     "(expected oracle | gp | arima | persist)")


# process-wide jit cache: (model, horizon, bucket, window-width) -> fn.
# Models are frozen dataclasses, so two sweep cells with the same
# forecaster config hash to the same compiled function.
_JIT_CACHE: dict = {}
_JIT_LOCK = threading.Lock()


def _jitted_peak_forecast(model, horizon: int, b: int, width: int):
    key = (model, horizon, b, width)
    with _JIT_LOCK:
        fn = _JIT_CACHE.get(key)
        if fn is None:

            @jax.jit
            def fn(w, v):
                fc = model.forecast_batch(w, horizon, valid=v)
                # future PEAK utilization (paper §4.2: predictor outputs a
                # future peak) — shared reduction with the scan engine
                return peak_over_horizon(fc)

            _JIT_CACHE[key] = fn
    return fn


def forecast_peaks(model, horizon: int, windows: np.ndarray,
                   valid: np.ndarray):
    """Pad (n, W) windows to a power-of-two bucket and run the shared
    jitted peak forecast.  Row i's result depends only on row i (verified
    bit-identical across bucket sizes), so callers may freely stack
    windows from many sims into one call."""
    n, width = windows.shape
    b = _bucket(n)
    fn = _jitted_peak_forecast(model, horizon, b, width)
    wpad = np.zeros((b, width), np.float32)
    vpad = np.zeros((b, width), bool)
    wpad[:n], vpad[:n] = windows, valid
    peak, pvar = fn(jnp.asarray(wpad), jnp.asarray(vpad))
    return np.asarray(peak)[:n], np.asarray(pvar)[:n]


class _BatchedForecaster:
    """Per-sim forecast client over the process-wide jit cache."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self._model = _make_model(cfg)

    def __call__(self, windows: np.ndarray, valid: np.ndarray):
        """windows: (n, W) -> (peak_mean, peak_var) each (n,)."""
        if self.cfg.forecaster == "persist":
            mean = windows[:, -1]
            var = windows.var(axis=1, where=valid) + 1e-6
            return mean, var
        return forecast_peaks(self._model, self.cfg.horizon, windows, valid)


def _oracle_peaks(cluster: Cluster, wl: Workload, horizon: int,
                  tick: float) -> np.ndarray:
    """(A, C, 2) true future peak usage over the horizon (variance 0)."""
    A, C = cluster.A, cluster.C
    out = np.zeros((A, C, 2), np.float32)
    run = cluster.running_slots()
    if run.size == 0:
        return out
    gids = cluster.slot_gid[run]
    rate = cluster.progress_rate(wl)[run]
    peaks = np.zeros((run.size, C, 2), np.float32)
    for k in range(1, horizon + 1):
        prog = np.clip((cluster.work_done[run] + rate * tick * k)
                       / wl.runtime[gids], 0.0, 1.0)
        u = wl.usage(gids, prog) * cluster.comp_running[run][:, :, None]
        peaks = np.maximum(peaks, u)
    out[run] = peaks
    return out


def _shaped_demand_padded(peak: np.ndarray, req: np.ndarray,
                          var: np.ndarray, sg: SafeguardConfig) -> np.ndarray:
    """``shaped_demand`` over a leading axis padded to a power-of-two
    bucket, so the jitted elementwise kernel compiles O(log n) times per
    safeguard config instead of once per distinct tick batch size.  The
    op is row-independent, so padding cannot change the real rows."""
    n = peak.shape[0]
    b = _bucket(n)
    if b == n:
        return np.asarray(shaped_demand(peak, req, var, sg))

    def pad(a):
        z = np.zeros((b,) + a.shape[1:], a.dtype)
        z[:n] = a
        return z

    return np.asarray(shaped_demand(pad(peak), pad(req), pad(var), sg))[:n]


def _shaped_demand_scaled_padded(peak: np.ndarray, req: np.ndarray,
                                 var: np.ndarray, k1: float,
                                 scale: np.ndarray) -> np.ndarray:
    """Bucket-padded ``shaped_demand_scaled`` (conformal safeguard)."""
    n = peak.shape[0]
    b = _bucket(n)

    def pad(a):
        if b == n:
            return a
        z = np.zeros((b,) + a.shape[1:], a.dtype)
        z[:n] = a
        return z

    out = shaped_demand_scaled(pad(peak), pad(req), pad(var),
                               np.float32(k1), pad(scale.astype(np.float32)))
    return np.asarray(out)[:n]


def _shape_decisions(cfg: SimConfig, cl: Cluster, wl: Workload, mon: Monitor,
                     fc, policy_fn, submit0: np.ndarray, run: np.ndarray,
                     t: float, tick: float, calib=None, ctl=None):
    """Forecast -> safeguard -> Algorithm 1 for one tick (shared by the
    vectorized and reference engines).  Returns numpy
    (kill_app, kill_comp, alloc_cpu, alloc_mem)."""
    A, C = cl.A, cl.C
    gids = cl.slot_gid[run]
    req = np.stack([wl.cpu_req[gids], wl.mem_req[gids]], -1)  # (n,C,2)
    running = cl.comp_running[run]
    demand = np.where(running[:, :, None], req, 0.0).astype(np.float32)

    if cfg.forecaster == "oracle":
        # perfect information needs no training history: the grace
        # period (paper §5) exists only for statistical models
        peaks = _oracle_peaks(cl, wl, cfg.horizon, tick)[run]
        var = np.zeros_like(peaks)
        ready = running
        shaped = _shaped_demand_padded(peaks, req, var, cfg.safeguard)
        demand = np.where(ready[:, :, None], shaped, demand)
    else:
        rc = np.nonzero(running)
        mslots = run[rc[0]] * C + rc[1]
        ready = mon.ready(mslots, cfg.grace)
        if ready.any():
            sel = np.nonzero(ready)[0]
            wins, vmask = mon.windows(mslots[sel])
            n = sel.size
            wflat = np.concatenate([wins[:, :, CPU], wins[:, :, MEM]])
            vflat = np.concatenate([vmask, vmask])
            mean, var = fc(wflat, vflat)
            reqs = req[rc[0][sel], rc[1][sel]]     # (n, 2)
            if calib is None:
                for r, off in ((CPU, 0), (MEM, n)):
                    sh = _shaped_demand_padded(
                        mean[off:off + n], reqs[:, r], var[off:off + n],
                        cfg.safeguard)
                    demand[rc[0][sel], rc[1][sel], r] = sh
            else:
                # conformal safeguard: per-series calibrated quantile
                # replaces K2 (rows follow the batch layout: CPU then MEM)
                M = mon.count.shape[0]
                rows = np.concatenate([mslots[sel], M + mslots[sel]])
                groups, q_rows = None, None
                if ctl is not None:
                    # per-tenant pools + credit-modulated target level:
                    # rows map to the tenant owning the slot; q_groups
                    # reads the PREVIOUS tick's credit (the control
                    # update runs later, at admission time)
                    tg = wl.tenant[cl.slot_gid[run[rc[0][sel]]]]
                    groups = np.concatenate([tg, tg])
                    qg = ctl.q_groups(calib.q, cfg.calibration.q_min,
                                      cfg.calibration.q_max)
                    q_rows = qg[groups]
                scale = calib.scales(rows, groups=groups, q=q_rows)
                for r, off in ((CPU, 0), (MEM, n)):
                    sh = _shaped_demand_scaled_padded(
                        mean[off:off + n], reqs[:, r], var[off:off + n],
                        cfg.safeguard.k1, scale[off:off + n])
                    demand[rc[0][sel], rc[1][sel], r] = sh
                sigma = sigma_from_var_np(var).astype(np.float32)
                counts = np.concatenate([mon.count[mslots[sel]]] * 2)
                calib.begin(rows, mean.astype(np.float32), sigma,
                            scale.astype(np.float32), counts,
                            groups=groups)

    # build the fixed-size ShapeProblem over ALL slots
    dem_full = np.zeros((A, C, 2), np.float32)
    dem_full[run] = demand
    app_exists = cl.slot_gid >= 0
    order = np.full((A,), -1, np.int64)
    fifo = np.argsort(submit0[np.maximum(cl.slot_gid, 0)]
                      + np.where(app_exists, 0, 1e18))
    order[:run.size] = fifo[:run.size]
    prob = ShapeProblem(
        host_cpu=jnp.asarray(cl.host_cap[:, CPU]),
        host_mem=jnp.asarray(cl.host_cap[:, MEM]),
        app_exists=jnp.asarray(app_exists),
        app_order=jnp.asarray(order),
        comp_exists=jnp.asarray(cl.comp_running),
        comp_core=jnp.asarray(
            wl.is_core[np.maximum(cl.slot_gid, 0)]
            & app_exists[:, None]),
        comp_host=jnp.asarray(cl.comp_host),
        comp_cpu=jnp.asarray(dem_full[:, :, CPU]),
        comp_mem=jnp.asarray(dem_full[:, :, MEM]),
        comp_alive=jnp.asarray(t - cl.alive_since),
    )
    dec = policy_fn(prob)
    return (np.asarray(dec.kill_app), np.asarray(dec.kill_comp),
            np.asarray(dec.alloc_cpu), np.asarray(dec.alloc_mem))


def run_sim(cfg: SimConfig, wl: Workload | None = None, *,
            forecast_fn=None) -> SimResults:
    """Run one simulation to completion (vectorized engine).

    ``forecast_fn(windows, valid) -> (mean, var)`` overrides the default
    per-process forecast client — the sweep driver passes a cross-sim
    batching client here.

    ``cfg.workload`` may be ANY registered scenario config (google,
    diurnal, flashcrowd, heavytail, colocated, replay, ...): the default
    workload is built through the scenario registry, and the engine
    consumes the canonical ``Trace`` unchanged.
    """
    wl = wl if wl is not None else build_trace(cfg.workload)
    N, C = wl.n_apps, wl.max_components
    cl = Cluster(cfg.cluster, C)
    A = cl.A
    mon = Monitor(slots=A * C, window=cfg.window)
    fc = forecast_fn if forecast_fn is not None else _BatchedForecaster(cfg)
    # per-tick "no request" signal for the sweep's barrier batch mode:
    # a registered sim that ticks without forecasting (grace period,
    # empty cluster, baseline policy) tells the batcher so full-cohort
    # detection is exact and idle ticks stop paying the leader timeout
    idle_fn = getattr(fc, "idle", None)
    fc_calls = [0]
    if idle_fn is not None:
        inner_fc = fc

        def fc(windows, valid, _inner=inner_fc):
            fc_calls[0] += 1
            return _inner(windows, valid)
    policy_fn = POLICIES[cfg.policy]
    res = SimResults(n_apps=N)
    tick = cfg.cluster.tick
    all_comps = np.arange(C)[None, :]     # broadcast helper for mon resets
    # multi-tenant control plane (admission gate + credit accounting)
    hc = None
    if cfg.control.enabled:
        if wl.n_tenants > cfg.control.max_tenants:
            raise ValueError(
                f"trace has {wl.n_tenants} tenants > control.max_tenants="
                f"{cfg.control.max_tenants}")
        hc = HostControl(cfg.control)
    # online conformal calibration (oracle forecasts are exact — there
    # is no residual distribution to calibrate); with the control plane
    # on, scores additionally pool per tenant (the series -> group ->
    # fleet tier)
    calib = None
    if cfg.calibration.enabled and cfg.forecaster != "oracle":
        calib = OnlineCalibrator(n_series=2 * A * C, horizon=cfg.horizon,
                                 fallback=cfg.safeguard.k2,
                                 cfg=cfg.calibration,
                                 n_groups=(cfg.control.max_tenants
                                           if hc is not None else 0))

    queue: list[tuple[float, int]] = []   # (original submit, gid) sorted
    arrived = 0
    done = np.zeros((N,), bool)
    submit0 = wl.submit.copy()            # original submit (priority key)
    # preempt-to-checkpoint mode (work_lost_on_kill=False): a preempted
    # app resumes from its last "checkpoint" (saved progress) instead of
    # restarting — the TPU adaptation's beyond-paper ablation
    saved_work: dict[int, float] = {}

    def requeue(gid: int):
        bisect.insort(queue, (float(submit0[gid]), gid))

    t = 0.0
    for step in range(cfg.max_ticks):
        if done.all():
            break
        t += tick

        # 1. arrivals ---------------------------------------------------
        while arrived < N and wl.submit[arrived] <= t:
            requeue(arrived)
            arrived += 1

        # 2. progress + completions (array scan over the slot table) ------
        rate = cl.progress_rate(wl)
        cl.work_done += rate * tick
        run = cl.running_slots()
        fin = run[cl.work_done[run] >= wl.runtime[cl.slot_gid[run]]]
        if fin.size:
            mon.reset_slot((fin[:, None] * C + all_comps).ravel())
            fin_gids = cl.evict_apps(fin)
            done[fin_gids] = True
            for gid in fin_gids:
                res.record_completion(int(gid), submit0[gid], t)
            if hc is not None:
                hc.note_completed(wl.tenant[fin_gids])

        # 3. monitor sampling --------------------------------------------
        usage = cl.usage_now(wl)
        run = cl.running_slots()
        if run.size:
            rc = np.nonzero(cl.comp_running[run])  # (slot_i, c)
            mslots = run[rc[0]] * C + rc[1]
            mon.record(mslots, usage[run][rc][:, CPU], usage[run][rc][:, MEM])
        if calib is not None:
            if hc is not None:
                gr0 = calib.group_resolved.copy()
                ge0 = calib.group_errors.copy()
            calib.observe(np.concatenate([usage[:, :, CPU].ravel(),
                                          usage[:, :, MEM].ravel()]),
                          mon.count)
            if hc is not None:
                # covered / miscovered conformal resolutions feed the
                # tenant credit score alongside completions / failures
                derr = calib.group_errors - ge0
                hc.note_calib(calib.group_resolved - gr0 - derr, derr)

        # 4. shaping ------------------------------------------------------
        # two distinct kill channels (paper §4.2): controlled preemptions
        # (Algorithm 1, work lost but clean) vs uncontrolled OS OOM kills
        # (the "application failures" metric of Figs. 3-4)
        preempted_this_tick: list[int] = []
        oom_failed_this_tick: list[int] = []
        calls_before = fc_calls[0]
        if cfg.policy != "baseline" and run.size:
            kill_app, kill_comp, alloc_cpu, alloc_mem = _shape_decisions(
                cfg, cl, wl, mon, fc, policy_fn, submit0, run, t, tick,
                calib=calib, ctl=hc)

            kills = np.nonzero(kill_app & (cl.slot_gid >= 0))[0]
            if kills.size:
                if not cfg.work_lost_on_kill:
                    for gid0, wd in zip(cl.slot_gid[kills],
                                        cl.work_done[kills]):
                        saved_work[int(gid0)] = float(wd)
                kgids = cl.evict_apps(kills)
                usage[kills] = 0.0
                mon.reset_slot((kills[:, None] * C + all_comps).ravel())
                if cfg.policy == "optimistic":
                    # optimistic-concurrency conflict: an UNCONTROLLED
                    # failure (paper: "the system will let one of the
                    # two fail")
                    oom_failed_this_tick.extend(int(g) for g in kgids)
                else:
                    preempted_this_tick.extend(int(g) for g in kgids)
                    res.full_preemptions += kills.size
            ks, kc = np.nonzero(kill_comp & (cl.slot_gid >= 0)[:, None]
                                & cl.comp_running)
            if ks.size:
                cl.kill_components(ks, kc)
                usage[ks, kc] = 0.0
                mon.reset_slot(ks * C + kc)
                res.partial_preemptions += ks.size
            live = cl.comp_running
            cl.alloc[:, :, CPU] = np.where(live, alloc_cpu, 0.0)
            cl.alloc[:, :, MEM] = np.where(live, alloc_mem, 0.0)
        if idle_fn is not None and fc_calls[0] == calls_before:
            idle_fn()

        # 5. OOM (uncontrolled failures) -----------------------------------
        oom_gids, oom_partial = cl.resolve_oom(wl, usage)
        for gid in oom_gids:
            oom_failed_this_tick.append(gid)
            res.oom_kills += 1
        res.partial_preemptions += len(oom_partial)
        if oom_partial:
            parr = np.asarray(oom_partial, np.int64)
            mon.reset_slot(parr[:, 0] * C + parr[:, 1])

        for gid in oom_failed_this_tick:
            res.record_failure(gid)
        if hc is not None and oom_failed_this_tick:
            hc.note_failed(wl.tenant[np.asarray(oom_failed_this_tick)])
        for gid in oom_failed_this_tick + preempted_this_tick:
            requeue(gid)

        # 6. scheduler: FIFO admission + elastic re-placement --------------
        # with the control plane on, the tick's events first fold into
        # the tenant credit, then the wDRF gate decides which tenants
        # may admit this tick (ineligible tenants' apps stay queued)
        elig = None
        if hc is not None:
            T = cfg.control.max_tenants
            alloc_t = np.zeros((T, 2), np.float32)
            run6 = cl.running_slots()
            if run6.size:
                np.add.at(alloc_t, wl.tenant[cl.slot_gid[run6]],
                          cl.alloc[run6].sum(1))
            queued_t = np.bincount(wl.tenant[[g for _, g in queue]],
                                   minlength=T)
            elig = hc.gate(alloc_t, cl.host_cap.sum(0), queued_t)
        while queue:
            if elig is None:
                i0 = 0
            else:
                # FIFO head among ELIGIBLE tenants (queue is sorted by
                # (submit0, gid), so the first eligible entry is the
                # same head the fused tick's masked argmin selects)
                i0 = next((i for i, (_, g) in enumerate(queue)
                           if elig[wl.tenant[g]]), -1)
                if i0 < 0:
                    break
            _, gid = queue[i0]
            slot = cl.admit(gid, wl, t)
            if slot < 0:
                break
            queue.pop(i0)
            if hc is not None:
                hc.note_admitted(int(wl.tenant[gid]))
            if not cfg.work_lost_on_kill and gid in saved_work:
                cl.work_done[slot] = saved_work.pop(gid)  # resume from ckpt
            mon.reset_slot(slot * C + np.arange(C))
        cl.place_missing_elastic(wl, t)

        # 7. metrics -------------------------------------------------------
        res.record_tick(t, cl, usage)

    if calib is not None:
        res.calibration = calib.report()
        gb = calib.group_report()
        if gb is not None:
            res.calibration["groups"] = gb
    if hc is not None:
        res.tenancy = tenancy_summary(cfg.control, wl, res.turnaround,
                                      res.failed_apps, hc.arrays())
    res.finalize(t)
    return res
