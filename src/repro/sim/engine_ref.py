"""Reference (seed) simulation engine — per-tick Python loops.

This preserves the seed implementation of ``run_sim`` as a golden
reference: the vectorized engine in ``repro.sim.engine`` must produce
bit-identical ``SimResults`` on any workload (``tests/test_sweep.py``
enforces it).  It is O(slots) Python iterations per tick and therefore
slow — use it only for equivalence checks and debugging
(``sweep.run_grid(..., engine="reference")``).

Everything the vectorized refactor touched is *inlined* here — the
loop-based OOM handler and elastic-placement scan that used to live on
``Cluster``, and the per-tick forecast -> safeguard -> Algorithm 1
shaping step — so the reference stays frozen and independent even as
``engine.py`` and ``cluster.py`` evolve.  Only the paper's math itself
(forecasters, ``shaped_demand``, the shaping policies) is shared, by
design: those are the exact modules the live framework runs.
"""
from __future__ import annotations

import bisect

import jax.numpy as jnp
import numpy as np

from repro.core.monitor import Monitor
from repro.core.shaper import POLICIES, SafeguardConfig, ShapeProblem, shaped_demand
from repro.sim.cluster import CPU, MEM, Cluster
from repro.sim.engine import SimConfig, _BatchedForecaster, _oracle_peaks
from repro.sim.metrics import SimResults
from repro.sim.scenarios.registry import build_trace
from repro.sim.workload import Workload


def _bucket_ref(n: int) -> int:
    b = 64
    while b < n:
        b *= 2
    return b


def _shaped_demand_padded_ref(peak: np.ndarray, req: np.ndarray,
                              var: np.ndarray, sg: SafeguardConfig) -> np.ndarray:
    """Frozen copy of the engine's bucket-padded ``shaped_demand`` call."""
    n = peak.shape[0]
    b = _bucket_ref(n)
    if b == n:
        return np.asarray(shaped_demand(peak, req, var, sg))

    def pad(a):
        z = np.zeros((b,) + a.shape[1:], a.dtype)
        z[:n] = a
        return z

    return np.asarray(shaped_demand(pad(peak), pad(req), pad(var), sg))[:n]


def _shape_decisions_reference(cfg: SimConfig, cl: Cluster, wl: Workload,
                               mon: Monitor, fc, policy_fn,
                               submit0: np.ndarray, run: np.ndarray,
                               t: float, tick: float):
    """Frozen copy of the per-tick shaping step (forecast -> safeguard ->
    Algorithm 1).  Kept separate from ``engine._shape_decisions`` so a
    future regression there cannot shift both engines identically and
    slip past the equivalence tests."""
    A, C = cl.A, cl.C
    gids = cl.slot_gid[run]
    req = np.stack([wl.cpu_req[gids], wl.mem_req[gids]], -1)  # (n,C,2)
    running = cl.comp_running[run]
    demand = np.where(running[:, :, None], req, 0.0).astype(np.float32)

    if cfg.forecaster == "oracle":
        peaks = _oracle_peaks(cl, wl, cfg.horizon, tick)[run]
        var = np.zeros_like(peaks)
        ready = running
        shaped = _shaped_demand_padded_ref(peaks, req, var, cfg.safeguard)
        demand = np.where(ready[:, :, None], shaped, demand)
    else:
        rc = np.nonzero(running)
        mslots = run[rc[0]] * C + rc[1]
        ready = mon.ready(mslots, cfg.grace)
        if ready.any():
            sel = np.nonzero(ready)[0]
            wins, vmask = mon.windows(mslots[sel])
            n = sel.size
            wflat = np.concatenate([wins[:, :, CPU], wins[:, :, MEM]])
            vflat = np.concatenate([vmask, vmask])
            mean, var = fc(wflat, vflat)
            reqs = req[rc[0][sel], rc[1][sel]]     # (n, 2)
            for r, off in ((CPU, 0), (MEM, n)):
                sh = _shaped_demand_padded_ref(
                    mean[off:off + n], reqs[:, r], var[off:off + n],
                    cfg.safeguard)
                demand[rc[0][sel], rc[1][sel], r] = sh

    dem_full = np.zeros((A, C, 2), np.float32)
    dem_full[run] = demand
    app_exists = cl.slot_gid >= 0
    order = np.full((A,), -1, np.int64)
    fifo = np.argsort(submit0[np.maximum(cl.slot_gid, 0)]
                      + np.where(app_exists, 0, 1e18))
    order[:run.size] = fifo[:run.size]
    prob = ShapeProblem(
        host_cpu=jnp.asarray(cl.host_cap[:, CPU]),
        host_mem=jnp.asarray(cl.host_cap[:, MEM]),
        app_exists=jnp.asarray(app_exists),
        app_order=jnp.asarray(order),
        comp_exists=jnp.asarray(cl.comp_running),
        comp_core=jnp.asarray(
            wl.is_core[np.maximum(cl.slot_gid, 0)]
            & app_exists[:, None]),
        comp_host=jnp.asarray(cl.comp_host),
        comp_cpu=jnp.asarray(dem_full[:, :, CPU]),
        comp_mem=jnp.asarray(dem_full[:, :, MEM]),
        comp_alive=jnp.asarray(t - cl.alive_since),
    )
    dec = policy_fn(prob)
    return (np.asarray(dec.kill_app), np.asarray(dec.kill_comp),
            np.asarray(dec.alloc_cpu), np.asarray(dec.alloc_mem))


def _resolve_oom_reference(cl: Cluster, wl: Workload, usage: np.ndarray):
    """Seed OOM handler: nested Python scans over slots x components."""
    full, partial = [], []
    host_tot = cl.host_usage(usage)
    over_hosts = np.nonzero(host_tot[:, MEM] > cl.host_cap[:, MEM] + 1e-6)[0]
    for h in over_hosts:
        while True:
            tot = 0.0
            cands = []
            for slot in cl.running_slots():
                on_h = cl.comp_running[slot] & (cl.comp_host[slot] == h)
                for c in np.nonzero(on_h)[0]:
                    tot += usage[slot, c, MEM]
                    cands.append((usage[slot, c, MEM]
                                  - cl.alloc[slot, c, MEM], slot, int(c)))
            if tot <= cl.host_cap[h, MEM] + 1e-6 or not cands:
                break
            cands.sort(reverse=True)
            _, slot, c = cands[0]
            gid = int(cl.slot_gid[slot])
            if wl.is_core[gid, c]:
                usage[slot] = 0.0
                cl.evict_app(slot)
                full.append(gid)
            else:
                usage[slot, c] = 0.0
                cl.kill_component(slot, c)
                partial.append((slot, c))
    return full, partial


def _place_missing_elastic_reference(cl: Cluster, wl: Workload,
                                     t: float) -> int:
    """Seed elastic re-placement: Python loop over slots x components."""
    placed = 0
    free = cl.free_resources().copy()
    for slot in cl.running_slots():
        gid = cl.slot_gid[slot]
        for c in range(cl.C):
            if (wl.cpu_req[gid, c] == 0 or wl.is_core[gid, c]
                    or cl.comp_running[slot, c]):
                continue
            h = cl._fit_component(free, wl.cpu_req[gid, c],
                                  wl.mem_req[gid, c])
            if h < 0:
                continue
            cl.comp_running[slot, c] = True
            cl.comp_host[slot, c] = h
            cl.alloc[slot, c, CPU] = wl.cpu_req[gid, c]
            cl.alloc[slot, c, MEM] = wl.mem_req[gid, c]
            cl.alive_since[slot, c] = t
            free[h, CPU] -= wl.cpu_req[gid, c]
            free[h, MEM] -= wl.mem_req[gid, c]
            placed += 1
    return placed


def run_sim_reference(cfg: SimConfig, wl: Workload | None = None, *,
                      forecast_fn=None) -> SimResults:
    """Seed ``run_sim`` — one Python iteration per slot per tick."""
    if cfg.calibration.enabled:
        # the reference engine is the FROZEN seed loop; it predates (and
        # must not grow) the conformal-safeguard path.  Refusing beats
        # silently simulating a different policy than requested.
        raise NotImplementedError(
            "engine_ref has no conformal-calibration path; run the "
            "vectorized engine or disable cfg.calibration")
    if cfg.control.enabled:
        # same frozen-seed rule for the multi-tenant control plane
        raise NotImplementedError(
            "engine_ref has no control-plane path; run the vectorized "
            "engine or disable cfg.control")
    wl = wl if wl is not None else build_trace(cfg.workload)
    N, C = wl.n_apps, wl.max_components
    cl = Cluster(cfg.cluster, C)
    A = cl.A
    mon = Monitor(slots=A * C, window=cfg.window)
    fc = forecast_fn if forecast_fn is not None else _BatchedForecaster(cfg)
    policy_fn = POLICIES[cfg.policy]
    res = SimResults(n_apps=N)
    tick = cfg.cluster.tick

    queue: list[tuple[float, int]] = []   # (original submit, gid) sorted
    arrived = 0
    done = np.zeros((N,), bool)
    submit0 = wl.submit.copy()            # original submit (priority key)
    saved_work: dict[int, float] = {}

    def requeue(gid: int):
        bisect.insort(queue, (float(submit0[gid]), gid))

    t = 0.0
    for step in range(cfg.max_ticks):
        if done.all():
            break
        t += tick

        # 1. arrivals ---------------------------------------------------
        while arrived < N and wl.submit[arrived] <= t:
            requeue(arrived)
            arrived += 1

        # 2. progress + completions --------------------------------------
        rate = cl.progress_rate(wl)
        cl.work_done += rate * tick
        for slot in cl.running_slots():
            gid = int(cl.slot_gid[slot])
            if cl.work_done[slot] >= wl.runtime[gid]:
                for c in range(C):
                    if cl.comp_running[slot, c]:
                        mon.reset_slot(slot * C + c)
                cl.evict_app(slot)
                done[gid] = True
                res.record_completion(gid, submit0[gid], t)

        # 3. monitor sampling --------------------------------------------
        usage = cl.usage_now(wl)
        run = cl.running_slots()
        if run.size:
            rc = np.nonzero(cl.comp_running[run])  # (slot_i, c)
            mslots = run[rc[0]] * C + rc[1]
            mon.record(mslots, usage[run][rc][:, CPU], usage[run][rc][:, MEM])

        # 4. shaping ------------------------------------------------------
        preempted_this_tick: list[int] = []
        oom_failed_this_tick: list[int] = []
        if cfg.policy != "baseline" and run.size:
            kill_app, kill_comp, alloc_cpu, alloc_mem = \
                _shape_decisions_reference(
                    cfg, cl, wl, mon, fc, policy_fn, submit0, run, t, tick)
            app_exists = cl.slot_gid >= 0

            for slot in np.nonzero(kill_app & app_exists)[0]:
                if not cfg.work_lost_on_kill:
                    gid0 = int(cl.slot_gid[slot])
                    saved_work[gid0] = float(cl.work_done[slot])
                gid = cl.evict_app(int(slot))
                usage[slot] = 0.0
                for c in range(C):
                    mon.reset_slot(int(slot) * C + c)
                if cfg.policy == "optimistic":
                    oom_failed_this_tick.append(gid)
                else:
                    preempted_this_tick.append(gid)
                    res.full_preemptions += 1
            for slot, c in zip(*np.nonzero(kill_comp)):
                if cl.slot_gid[slot] >= 0 and cl.comp_running[slot, c]:
                    cl.kill_component(int(slot), int(c))
                    usage[slot, c] = 0.0
                    mon.reset_slot(int(slot) * C + int(c))
                    res.partial_preemptions += 1
            live = cl.comp_running
            cl.alloc[:, :, CPU] = np.where(live, alloc_cpu, 0.0)
            cl.alloc[:, :, MEM] = np.where(live, alloc_mem, 0.0)

        # 5. OOM (uncontrolled failures) -----------------------------------
        oom_gids, oom_partial = _resolve_oom_reference(cl, wl, usage)
        for gid in oom_gids:
            oom_failed_this_tick.append(gid)
            res.oom_kills += 1
        res.partial_preemptions += len(oom_partial)
        for slot, c in oom_partial:
            mon.reset_slot(slot * C + c)

        for gid in oom_failed_this_tick:
            res.record_failure(gid)
        for gid in oom_failed_this_tick + preempted_this_tick:
            requeue(gid)

        # 6. scheduler: FIFO admission + elastic re-placement --------------
        while queue:
            _, gid = queue[0]
            slot = cl.admit(gid, wl, t)
            if slot < 0:
                break
            queue.pop(0)
            if not cfg.work_lost_on_kill and gid in saved_work:
                cl.work_done[slot] = saved_work.pop(gid)  # resume from ckpt
            for c in range(C):
                mon.reset_slot(slot * C + c)
        _place_missing_elastic_reference(cl, wl, t)

        # 7. metrics -------------------------------------------------------
        res.record_tick(t, cl, usage)

    res.finalize(t)
    return res
