"""Simulation metrics (paper §4.1): turnaround, resource slack, failures."""
from __future__ import annotations

import dataclasses

import numpy as np

CPU, MEM = 0, 1

# summary keys the sweep aggregates across seeds (paper's Fig. 3-4 axes:
# turnaround, failures, slack / utilization)
AGGREGATE_KEYS = (
    "turnaround_mean", "turnaround_median", "turnaround_p95",
    "slack_cpu_mean", "slack_mem_mean", "util_cpu_mean", "util_mem_mean",
    "failed_frac", "failure_events", "oom_kills",
    "full_preemptions", "partial_preemptions", "completed", "sim_hours",
)


def aggregate_summaries(summaries: list[dict],
                        keys: tuple = AGGREGATE_KEYS) -> dict:
    """Mean + median of each metric across per-seed ``summary()`` dicts."""
    out: dict = {"n_seeds": len(summaries)}
    for k in keys:
        vals = np.asarray([s[k] for s in summaries], np.float64)
        out[k] = float(np.mean(vals))
        out[k + "_median"] = float(np.median(vals))
    return out


def trace_stats(trace) -> dict:
    """Workload-shape statistics of a Trace — the sweep attaches these
    per scenario so BENCH artifacts are self-describing (a reader can
    see WHAT regime produced each metric block)."""
    exists = trace.cpu_req > 0
    return {
        "n_apps": int(trace.n_apps),
        "max_components": int(trace.max_components),
        "elastic_frac": float(trace.is_elastic.mean()),
        "jumpy_frac": float(trace.is_jumpy.mean()),
        "mean_components": float(exists.sum(1).mean()),
        "elastic_comp_frac": float((exists & ~trace.is_core).sum()
                                   / max(exists.sum(), 1)),
        "runtime_mean_s": float(trace.runtime.mean()),
        "runtime_p95_s": float(np.percentile(trace.runtime, 95)),
        "arrival_makespan_h": float(trace.submit[-1] / 3600.0),
        "mem_req_mean_gb": float(trace.mem_req[exists].mean()),
        "mem_req_p95_gb": float(np.percentile(trace.mem_req[exists], 95)),
        "mean_level": float(trace.levels[exists].mean()),
    }


@dataclasses.dataclass
class SimResults:
    n_apps: int
    turnaround: dict = dataclasses.field(default_factory=dict)   # gid -> s
    failed_apps: set = dataclasses.field(default_factory=set)
    failure_events: int = 0          # uncontrolled (OS OOM) kills
    oom_kills: int = 0
    full_preemptions: int = 0        # controlled (Algorithm 1) app preemptions
    partial_preemptions: int = 0     # elastic-component preemptions
    # per-tick series
    slack_cpu: list = dataclasses.field(default_factory=list)
    slack_mem: list = dataclasses.field(default_factory=list)
    util_cpu: list = dataclasses.field(default_factory=list)
    util_mem: list = dataclasses.field(default_factory=list)
    n_running: list = dataclasses.field(default_factory=list)
    sim_time: float = 0.0
    # online conformal-calibration telemetry (engine fills this only
    # when SimConfig.calibration is enabled, so legacy summaries — and
    # the engine/engine_ref equivalence contract — are unchanged)
    calibration: dict | None = None
    # scan-engine forecast-load telemetry (rows_ready / rows_batch /
    # ticks_forecasting): the masked-rows overhead of forecasting the
    # full padded batch each tick.  NOT part of summary() — the host
    # engines gather ready rows dynamically and never fill it, and the
    # engine-agreement contracts compare summaries.
    forecast_rows: dict | None = None
    # multi-tenant control-plane telemetry (repro.control): per-tenant
    # fairness / SLO / turnaround block, filled only when
    # SimConfig.control is enabled — tenancy-off summaries (and the
    # engine-equivalence contracts) are unchanged.
    tenancy: dict | None = None
    # drained per-tick telemetry rings (repro.obs.rings): field -> (T,)
    # arrays, filled by the scan/shard engines only when SimConfig.obs
    # is enabled.  Like forecast_rows, NOT part of summary() — telemetry
    # must never perturb the engine-equivalence contracts.
    obs: dict | None = None

    def record_completion(self, gid: int, submit: float, t: float) -> None:
        self.turnaround[int(gid)] = float(t - submit)

    def record_failure(self, gid: int) -> None:
        self.failed_apps.add(int(gid))
        self.failure_events += 1

    def record_tick(self, t: float, cluster, usage: np.ndarray) -> None:
        run = cluster.running_slots()
        self.n_running.append(len(run))
        cap = cluster.host_cap.sum(0)
        used = usage.sum((0, 1))
        alloc = cluster.alloc.sum((0, 1))
        self.util_cpu.append(used[CPU] / cap[CPU])
        self.util_mem.append(used[MEM] / cap[MEM])
        # slack: (allocated - used) / allocated, cluster-aggregate (paper
        # §4.1: % allocated vs % actually used)
        self.slack_cpu.append(
            float((alloc[CPU] - used[CPU]) / alloc[CPU]) if alloc[CPU] > 0 else 0.0)
        self.slack_mem.append(
            float((alloc[MEM] - used[MEM]) / alloc[MEM]) if alloc[MEM] > 0 else 0.0)

    def finalize(self, t: float) -> None:
        self.sim_time = float(t)

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        ta = np.asarray(list(self.turnaround.values()), np.float64)
        out = {
            "completed": len(self.turnaround),
            "n_apps": self.n_apps,
            "sim_hours": self.sim_time / 3600.0,
            "turnaround_mean": float(ta.mean()) if ta.size else float("nan"),
            "turnaround_median": float(np.median(ta)) if ta.size else float("nan"),
            "turnaround_p95": float(np.percentile(ta, 95)) if ta.size else float("nan"),
            "slack_cpu_mean": float(np.mean(self.slack_cpu)) if self.slack_cpu else float("nan"),
            "slack_mem_mean": float(np.mean(self.slack_mem)) if self.slack_mem else float("nan"),
            "util_cpu_mean": float(np.mean(self.util_cpu)) if self.util_cpu else float("nan"),
            "util_mem_mean": float(np.mean(self.util_mem)) if self.util_mem else float("nan"),
            "failed_frac": len(self.failed_apps) / max(self.n_apps, 1),
            "failure_events": self.failure_events,
            "oom_kills": self.oom_kills,
            "full_preemptions": self.full_preemptions,
            "partial_preemptions": self.partial_preemptions,
        }
        if self.calibration is not None:
            out["calibration"] = self.calibration
        if self.tenancy is not None:
            out["tenancy"] = self.tenancy
        return out
