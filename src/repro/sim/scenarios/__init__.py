"""Scenario & trace subsystem: pluggable workload sources for the sim.

One canonical :class:`Trace` schema (``schema``), a named registry of
generator families (``registry``), four parametric families beyond the
paper's Google-shaped workload (``families``: diurnal, flashcrowd,
heavytail, colocated), a CSV/Parquet trace-replay adapter (``replay``)
and per-scenario forecast-error diagnostics (``diagnostics``).

The legacy generator in :mod:`repro.sim.workload` registers itself as
the ``"google"`` family — the registry imports it lazily, so either
import order works.

    from repro.sim.scenarios import build_trace, make_config
    tr = build_trace(make_config("flashcrowd", n_apps=200, seed=1))
"""
from repro.sim.scenarios import families as _families              # noqa: F401
from repro.sim.scenarios import replay as _replay                  # noqa: F401
from repro.sim.scenarios.diagnostics import (coverage_report,
                                             forecast_error_report,
                                             forecast_reports,
                                             sample_usage_series)
from repro.sim.scenarios.families import (ColocatedConfig, DiurnalConfig,
                                          FlashcrowdConfig, HeavytailConfig)
from repro.sim.scenarios.fitting import FittedConfig, fit_trace
from repro.sim.scenarios.registry import (ScenarioSpec, build_trace, get,
                                          make_config, register,
                                          scenario_names, scenario_of)
from repro.sim.scenarios.replay import ReplayConfig, load_trace, save_trace
from repro.sim.scenarios.schema import (SEGMENTS, Trace,
                                        TraceValidationError, sort_by_submit)
from repro.sim.scenarios.stream import StreamConfig, run_sim_stream

__all__ = [
    "SEGMENTS", "Trace", "TraceValidationError", "sort_by_submit",
    "ScenarioSpec", "register", "get", "scenario_names", "scenario_of",
    "make_config", "build_trace",
    "DiurnalConfig", "FlashcrowdConfig", "HeavytailConfig",
    "ColocatedConfig", "ReplayConfig", "load_trace", "save_trace",
    "FittedConfig", "fit_trace", "StreamConfig", "run_sim_stream",
    "coverage_report", "forecast_error_report", "forecast_reports",
    "sample_usage_series",
]
