"""Per-scenario diagnostics: sampled utilization series and rolling
forecast-error reports.

The paper's Fig. 2 evaluates forecast error on ~6000 memory series from
one cluster; with pluggable scenarios the same question becomes
per-regime: *how learnable is this workload family for each
forecaster?*  ``sample_usage_series`` draws component utilization
series straight from a :class:`Trace`'s ground-truth profiles (the
exact curves the simulator will realize), and
``forecast_error_report`` runs batched one-step-ahead rolling
forecasts over them, returning the error quartiles + |z| calibration
the sweep attaches to ``BENCH_sweep.json`` next to each scenario's
paper metrics.

Only :mod:`repro.core.forecast` is imported — no engine dependency, so
the diagnostics are bit-neutral to simulation results by construction.
"""
from __future__ import annotations

import numpy as np

from repro.sim.scenarios.schema import MEM, Trace

__all__ = ["sample_usage_series", "rolling_errors", "forecast_error_report",
           "rolling_forecasts", "coverage_report", "forecast_reports"]

# jitted one-step forecast per model config: jax.jit caches by function
# identity, so a fresh lambda per call would recompile the whole GP/ARIMA
# path for every diagnostic record.  Model configs are frozen dataclasses
# (same keying as the engine's process-wide jit cache).
_JIT: dict = {}


def sample_usage_series(trace: Trace, n_series: int, length: int,
                        seed: int, resource: int = MEM,
                        noise: float = 0.01) -> np.ndarray:
    """(n_series, length) utilization series sampled from the trace's
    component profiles, full-lifetime, at uniform progress spacing."""
    rng = np.random.RandomState(seed)
    req = trace.cpu_req if resource == 0 else trace.mem_req
    gids, comps = np.nonzero(req > 0)
    if gids.size == 0:
        raise ValueError("trace has no components to sample")
    pick = rng.randint(0, gids.size, n_series)
    prog = np.linspace(0.0, 1.0, length, dtype=np.float32)
    out = np.empty((n_series, length), np.float32)
    for i, k in enumerate(pick):
        gid, c = gids[k], comps[k]
        u = trace.usage(np.full(length, gid), prog)[np.arange(length), c,
                                                    resource]
        out[i] = u + rng.normal(0.0, noise * req[gid, c], length)
    return out


def _make_model(forecaster: str, gp=None, arima=None):
    from repro.core.forecast import (ARIMAConfig, ARIMAForecaster, GPConfig,
                                     GPForecaster)
    if forecaster == "gp":
        return GPForecaster(gp or GPConfig())
    if forecaster == "arima":
        return ARIMAForecaster(arima or ARIMAConfig())
    raise ValueError(f"no diagnostic model for forecaster {forecaster!r}")


def rolling_forecasts(forecaster: str, series: np.ndarray, window: int,
                      n_eval: int, gp=None, arima=None):
    """Batched one-step-ahead rolling forecasts over sampled series.

    Returns ``(mean, sd, tgts)``, each of shape ``(n_eval * n_series,)``,
    grouped by evaluation start (block ``i`` holds every series at
    start ``i`` — the split exploited by :func:`coverage_report`).
    """
    T = series.shape[1]
    starts = np.linspace(0, T - window - 1, n_eval).astype(int)
    wins = np.concatenate([series[:, s:s + window] for s in starts])
    tgts = np.concatenate([series[:, s + window] for s in starts])

    if forecaster == "persist":
        mean = wins[:, -1]
        sd = np.sqrt(wins.var(axis=1) + 1e-6)
    else:
        import jax
        import jax.numpy as jnp
        model = _make_model(forecaster, gp=gp, arima=arima)
        fn = _JIT.get(model)
        if fn is None:
            fn = _JIT[model] = jax.jit(
                lambda w, m=model: m.forecast_batch(w, 1))
        fc = fn(jnp.asarray(wins))
        mean = np.asarray(fc.mean)[:, 0]
        sd = np.sqrt(np.maximum(np.asarray(fc.var)[:, 0], 1e-12))
    return mean, sd, tgts


def rolling_errors(forecaster: str, series: np.ndarray, window: int,
                   n_eval: int, gp=None, arima=None):
    """Batched one-step-ahead rolling forecasts -> (rel_errors, |z|)."""
    mean, sd, tgts = rolling_forecasts(forecaster, series, window, n_eval,
                                       gp=gp, arima=arima)
    scale = np.maximum(np.abs(tgts), 1e-3)
    rel = (mean - tgts) / scale
    z = np.abs(mean - tgts) / np.maximum(sd, 1e-9)
    return rel, z


def _error_block(forecaster: str, mean, sd, tgts, *, window: int,
                 n_series: int, n_eval: int) -> dict:
    """Error-quartile record from an existing rolling-forecast pass."""
    scale = np.maximum(np.abs(tgts), 1e-3)
    rel = (mean - tgts) / scale
    z = np.abs(mean - tgts) / np.maximum(sd, 1e-9)
    q25, q50, q75 = np.percentile(np.abs(rel), [25, 50, 75])
    return {
        "forecaster": forecaster,
        "n_series": int(n_series),
        "n_eval": int(n_eval),
        "window": int(window),
        "abs_rel_err_q25": float(q25),
        "abs_rel_err_median": float(q50),
        "abs_rel_err_q75": float(q75),
        "abs_rel_err_mean": float(np.abs(rel).mean()),
        "median_abs_z": float(np.median(z)),
    }


def forecast_error_report(trace: Trace, forecaster: str, *,
                          window: int = 24, n_series: int = 16,
                          n_eval: int = 4, seed: int = 0,
                          gp=None, arima=None) -> dict | None:
    """One forecast-error record for (trace, forecaster); None for
    forecasters with nothing to diagnose (oracle is error-free)."""
    if forecaster == "oracle":
        return None
    length = window + max(n_eval, 2) + 8
    series = sample_usage_series(trace, n_series, length, seed)
    mean, sd, tgts = rolling_forecasts(forecaster, series, window, n_eval,
                                       gp=gp, arima=arima)
    return _error_block(forecaster, mean, sd, tgts, window=window,
                        n_series=n_series, n_eval=n_eval)


def coverage_report(trace: Trace, forecaster: str, *,
                    window: int = 24, n_series: int = 16,
                    n_eval: int = 8, seed: int = 0,
                    q_levels: tuple = (0.8, 0.9, 0.95),
                    gp=None, arima=None) -> dict | None:
    """Calibration diagnostics: Gaussian vs conformal bands per regime.

    Split-conformal evaluation on the trace's ground-truth profiles:
    rolling one-step forecasts are split by SERIES into a *calibration*
    half (whose sigma-normalized residual scores feed the conformal
    quantile — pooled across series, the engine's group tier) and an
    *evaluation* half, on which both band constructions are scored at
    each nominal level:

      * empirical coverage vs nominal (the trustworthiness gap);
      * pinball loss (proper: penalizes mis-placed bands at equal q);
      * Gaussian CRPS of the raw predictive distribution;
      * coverage of the paper's K2 = 3 sigma-band vs ITS Gaussian
        nominal (the Eq. 9 trustworthiness check).

    The split is across series, not time: series are drawn iid from the
    trace's components, so exchangeability — and with it the conformal
    coverage guarantee — holds between the halves (a temporal split
    would not be exchangeable on ramping profiles).

    Pure diagnostics — like :func:`forecast_error_report` it never
    touches the engines, so simulation results stay bit-identical.
    """
    if forecaster == "oracle":
        return None
    n_eval = max(n_eval, 4)
    n_series = max(n_series, 4)
    length = window + n_eval + 8
    series = sample_usage_series(trace, n_series, length, seed)
    mean, sd, tgts = rolling_forecasts(forecaster, series, window, n_eval,
                                       gp=gp, arima=arima)
    return _coverage_block(forecaster, mean, sd, tgts, window=window,
                           n_series=n_series, n_eval=n_eval,
                           q_levels=q_levels)


def _coverage_block(forecaster: str, mean, sd, tgts, *, window: int,
                    n_series: int, n_eval: int, q_levels: tuple) -> dict:
    """Gaussian-vs-conformal band scoring from an existing pass."""
    import jax.numpy as jnp

    from repro.core.uncertainty import (ScoreBuffer, crps_gaussian,
                                        empirical_coverage,
                                        gaussian_quantile_scale,
                                        pinball_loss)

    # rows are grouped by start, series-major within each block: row
    # (start_i, series_j) sits at  start_i * n_series + series_j
    cal_mask = np.tile(np.arange(n_series) < n_series // 2, n_eval)
    scores = ((tgts[cal_mask] - mean[cal_mask])
              / np.maximum(sd[cal_mask], 1e-9)).astype(np.float32)
    n_cal = scores.shape[0]
    ring = ScoreBuffer(1, n_cal)
    ring.push_many(0, scores)
    ev = ~cal_mask
    y = jnp.asarray(tgts[ev])
    m = jnp.asarray(mean[ev])
    s = jnp.asarray(sd[ev])

    levels = []
    for q in q_levels:
        zg = float(gaussian_quantile_scale(q))
        zc = float(ring.scales(np.asarray([0]), q, zg)[0])
        up_g, up_c = m + zg * s, m + zc * s
        levels.append({
            "q": float(q),
            "gaussian_scale": round(zg, 4),
            "conformal_scale": round(zc, 4),
            "gaussian_coverage": round(float(empirical_coverage(y, up_g)), 4),
            "conformal_coverage": round(float(empirical_coverage(y, up_c)), 4),
            "gaussian_pinball": float(pinball_loss(y, up_g, q)),
            "conformal_pinball": float(pinball_loss(y, up_c, q)),
        })
    # the paper's K2 = 3 band, scored against its own Gaussian nominal
    # (3-sigma ~ 0.99865): the gap is the Eq. 9 trustworthiness deficit
    from jax.scipy.stats import norm
    k2_nominal = float(norm.cdf(3.0))
    k2_cov = float(empirical_coverage(y, m + 3.0 * s))
    return {
        "forecaster": forecaster,
        "window": int(window),
        "n_series": int(n_series),
        "n_eval": int(n_eval),
        "n_calib_scores": int(n_cal),
        "crps_gaussian": float(crps_gaussian(y, m, s ** 2)),
        "k2_nominal": round(k2_nominal, 5),
        "k2_coverage": round(k2_cov, 5),
        "levels": levels,
    }


def forecast_reports(trace: Trace, forecaster: str, *,
                     window: int = 24, n_series: int = 16,
                     n_eval: int | None = None, seed: int = 0,
                     coverage: bool = True,
                     q_levels: tuple = (0.8, 0.9, 0.95),
                     gp=None, arima=None) -> tuple[dict | None, dict | None]:
    """(forecast-error report, coverage report) from ONE shared pass.

    The sweep needs both diagnostics per (scenario, forecaster) pair;
    run separately they each sample series and roll forecasts — the
    expensive part — over the same trace.  This runs a single
    ``rolling_forecasts`` pass at the coverage report's (larger)
    evaluation length and derives both records from it.  ``coverage=
    False`` skips the conformal block AND drops back to the error
    report's shorter evaluation length, so grids that sweep no
    calibration pay nothing for it.  Returns ``(None, None)`` for the
    oracle.
    """
    if forecaster == "oracle":
        return None, None
    if n_eval is None:
        n_eval = 8 if coverage else 4    # each report's standalone default
    n_eval = max(n_eval, 4) if coverage else n_eval
    n_series = max(n_series, 4) if coverage else n_series
    length = window + (n_eval if coverage else max(n_eval, 2)) + 8
    series = sample_usage_series(trace, n_series, length, seed)
    mean, sd, tgts = rolling_forecasts(forecaster, series, window, n_eval,
                                       gp=gp, arima=arima)
    err = _error_block(forecaster, mean, sd, tgts, window=window,
                       n_series=n_series, n_eval=n_eval)
    cov = None
    if coverage:
        cov = _coverage_block(forecaster, mean, sd, tgts, window=window,
                              n_series=n_series, n_eval=n_eval,
                              q_levels=q_levels)
    return err, cov
