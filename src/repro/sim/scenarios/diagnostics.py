"""Per-scenario diagnostics: sampled utilization series and rolling
forecast-error reports.

The paper's Fig. 2 evaluates forecast error on ~6000 memory series from
one cluster; with pluggable scenarios the same question becomes
per-regime: *how learnable is this workload family for each
forecaster?*  ``sample_usage_series`` draws component utilization
series straight from a :class:`Trace`'s ground-truth profiles (the
exact curves the simulator will realize), and
``forecast_error_report`` runs batched one-step-ahead rolling
forecasts over them, returning the error quartiles + |z| calibration
the sweep attaches to ``BENCH_sweep.json`` next to each scenario's
paper metrics.

Only :mod:`repro.core.forecast` is imported — no engine dependency, so
the diagnostics are bit-neutral to simulation results by construction.
"""
from __future__ import annotations

import numpy as np

from repro.sim.scenarios.schema import MEM, Trace

__all__ = ["sample_usage_series", "rolling_errors", "forecast_error_report"]

# jitted one-step forecast per model config: jax.jit caches by function
# identity, so a fresh lambda per call would recompile the whole GP/ARIMA
# path for every diagnostic record.  Model configs are frozen dataclasses
# (same keying as the engine's process-wide jit cache).
_JIT: dict = {}


def sample_usage_series(trace: Trace, n_series: int, length: int,
                        seed: int, resource: int = MEM,
                        noise: float = 0.01) -> np.ndarray:
    """(n_series, length) utilization series sampled from the trace's
    component profiles, full-lifetime, at uniform progress spacing."""
    rng = np.random.RandomState(seed)
    req = trace.cpu_req if resource == 0 else trace.mem_req
    gids, comps = np.nonzero(req > 0)
    if gids.size == 0:
        raise ValueError("trace has no components to sample")
    pick = rng.randint(0, gids.size, n_series)
    prog = np.linspace(0.0, 1.0, length, dtype=np.float32)
    out = np.empty((n_series, length), np.float32)
    for i, k in enumerate(pick):
        gid, c = gids[k], comps[k]
        u = trace.usage(np.full(length, gid), prog)[np.arange(length), c,
                                                    resource]
        out[i] = u + rng.normal(0.0, noise * req[gid, c], length)
    return out


def _make_model(forecaster: str, gp=None, arima=None):
    from repro.core.forecast import (ARIMAConfig, ARIMAForecaster, GPConfig,
                                     GPForecaster)
    if forecaster == "gp":
        return GPForecaster(gp or GPConfig())
    if forecaster == "arima":
        return ARIMAForecaster(arima or ARIMAConfig())
    raise ValueError(f"no diagnostic model for forecaster {forecaster!r}")


def rolling_errors(forecaster: str, series: np.ndarray, window: int,
                   n_eval: int, gp=None, arima=None):
    """Batched one-step-ahead rolling forecasts -> (rel_errors, |z|)."""
    T = series.shape[1]
    starts = np.linspace(0, T - window - 1, n_eval).astype(int)
    wins = np.concatenate([series[:, s:s + window] for s in starts])
    tgts = np.concatenate([series[:, s + window] for s in starts])

    if forecaster == "persist":
        mean = wins[:, -1]
        sd = np.sqrt(wins.var(axis=1) + 1e-6)
    else:
        import jax
        import jax.numpy as jnp
        model = _make_model(forecaster, gp=gp, arima=arima)
        fn = _JIT.get(model)
        if fn is None:
            fn = _JIT[model] = jax.jit(
                lambda w, m=model: m.forecast_batch(w, 1))
        fc = fn(jnp.asarray(wins))
        mean = np.asarray(fc.mean)[:, 0]
        sd = np.sqrt(np.maximum(np.asarray(fc.var)[:, 0], 1e-12))

    scale = np.maximum(np.abs(tgts), 1e-3)
    rel = (mean - tgts) / scale
    z = np.abs(mean - tgts) / np.maximum(sd, 1e-9)
    return rel, z


def forecast_error_report(trace: Trace, forecaster: str, *,
                          window: int = 24, n_series: int = 16,
                          n_eval: int = 4, seed: int = 0,
                          gp=None, arima=None) -> dict | None:
    """One forecast-error record for (trace, forecaster); None for
    forecasters with nothing to diagnose (oracle is error-free)."""
    if forecaster == "oracle":
        return None
    length = window + max(n_eval, 2) + 8
    series = sample_usage_series(trace, n_series, length, seed)
    rel, z = rolling_errors(forecaster, series, window, n_eval,
                            gp=gp, arima=arima)
    q25, q50, q75 = np.percentile(np.abs(rel), [25, 50, 75])
    return {
        "forecaster": forecaster,
        "n_series": int(n_series),
        "n_eval": int(n_eval),
        "window": int(window),
        "abs_rel_err_q25": float(q25),
        "abs_rel_err_median": float(q50),
        "abs_rel_err_q75": float(q75),
        "abs_rel_err_mean": float(np.abs(rel).mean()),
        "median_abs_z": float(np.median(z)),
    }
