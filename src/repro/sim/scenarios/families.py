"""Parametric scenario families beyond the paper's Google-shaped trace.

Four regimes the paper's single workload (§4.1) does not cover, chosen
to stress different parts of the mechanism (Flex / ADARES evaluate
usage-vs-allocation gap closing across exactly such mixes):

  * ``diurnal``    — tidal service load: arrival rate AND utilization
                     follow a shared day/night cycle, so demand peaks
                     are cluster-wide and phase-correlated (the regime
                     where persistence forecasting looks good and the
                     GP's uncertainty adds little);
  * ``flashcrowd`` — correlated burst arrivals whose utilization spikes
                     together mid-life: the adversarial case for the
                     safeguard's failure control (many under-predicted
                     components ramp at once);
  * ``heavytail``  — Pareto runtimes and memory demands,
                     ML-training-like: most jobs are small, a few are
                     enormous and long, utilization ramps to a high
                     plateau (allocation-shaping upside concentrates in
                     the tail);
  * ``colocated``  — Alibaba-style colocation: long-running
                     latency-critical services (day-peaking) packed
                     with elastic batch jobs (night-peaking), i.e.
                     anti-correlated utilization across the two classes
                     — the canonical over-commit opportunity.

Every family emits the canonical :class:`Trace` and registers in
:mod:`repro.sim.scenarios.registry`; all share the ``n_apps`` /
``max_components`` / ``seed`` scale knobs so the sweep's ``scenario``
axis can swap families while keeping the grid's scale.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.scenarios.registry import register
from repro.sim.scenarios.schema import SEGMENTS, Trace, sort_by_submit

DAY_S = 86_400.0


# ----------------------------------------------------------------------
# shared construction helpers
# ----------------------------------------------------------------------

def _structure(rng, N: int, C: int, is_elastic: np.ndarray,
               max_elastic: int | None = None):
    """Component structure shared by all families: elastic apps get 3
    core components (controller/master/worker) plus k elastic workers;
    rigid apps get 1-2 core components and no elastic."""
    if C < 3:
        raise ValueError(
            f"max_components={C} too small for this scenario family: "
            "elastic apps need 3 core components (controller/master/"
            "worker); use max_components >= 3")
    n_core = np.where(is_elastic, 3, rng.randint(1, 3, N))
    room = np.minimum(C - n_core, max_elastic or C)
    n_elastic = np.where(is_elastic,
                         rng.randint(2, np.maximum(room + 1, 3)), 0)
    n_elastic = np.minimum(n_elastic, room)
    idx = np.arange(C)[None, :]
    exists = idx < (n_core + n_elastic)[:, None]
    is_core = (idx < n_core[:, None]) & exists
    return n_core.astype(np.int64), n_elastic.astype(np.int64), exists, is_core


def _demands(rng, N: int, C: int, exists, is_elastic,
             min_cpu: float, max_cpu: float,
             min_mem: float, max_mem: float):
    """Log-uniform per-component reservations; the coordinator cores of
    elastic apps stay lightweight (same convention as the google family)."""
    idx = np.arange(C)[None, :]
    cpu = np.round(np.exp(rng.uniform(np.log(min_cpu), np.log(max_cpu),
                                      (N, C))) * 4) / 4
    mem = np.exp(rng.uniform(np.log(min_mem), np.log(max_mem), (N, C)))
    light = is_elastic[:, None] & (idx < 2)
    cpu = np.where(light, np.minimum(cpu, 0.5), cpu)
    mem = np.where(light, np.minimum(mem, 2.0), mem)
    cpu_req = np.where(exists, np.maximum(cpu, min_cpu), 0.0)
    mem_req = np.where(exists, np.maximum(mem, min_mem), 0.0)
    return cpu_req.astype(np.float32), mem_req.astype(np.float32)


def _assemble(*, submit, is_elastic, is_jumpy, n_core, n_elastic, runtime,
              cpu_req, mem_req, is_core, levels, cfg,
              tenant=None, slo=None) -> Trace:
    """Sort by submit, cast, mask absent components, validate."""
    N = len(np.asarray(submit))
    cols = sort_by_submit(
        np.asarray(submit, np.float32),
        is_elastic=is_elastic, is_jumpy=is_jumpy, n_core=n_core,
        n_elastic=n_elastic, runtime=np.asarray(runtime, np.float32),
        cpu_req=cpu_req, mem_req=mem_req, is_core=is_core, levels=levels,
        tenant=(np.zeros(N, np.int64) if tenant is None
                else np.asarray(tenant, np.int64)),
        slo=(np.zeros(N, np.int64) if slo is None
             else np.asarray(slo, np.int64)))
    exists = cols["cpu_req"] > 0
    cols["levels"] = np.clip(
        cols["levels"] * exists[:, :, None, None], 0.0, 1.0
    ).astype(np.float32)
    return Trace(cfg=cfg, **cols).validate()


def _tenants(rng, N: int, n_tenants: int, skew: float) -> np.ndarray:
    """Zipf-skewed tenant assignment (tenant 0 is the heaviest).

    Drawn at the very END of each builder's rng stream, and consuming
    NOTHING when ``n_tenants <= 1`` — so every pre-control-plane trace
    (the default single-tenant configs) is bit-identical to the seed
    generators."""
    if n_tenants <= 1:
        return np.zeros(N, np.int64)
    w = (1.0 + np.arange(n_tenants)) ** -float(skew)
    return rng.choice(n_tenants, size=N, p=w / w.sum()).astype(np.int64)


def _phase_profile(submit, runtime, *, day_s: float, peak_shift: float,
                   base: float, amp: float):
    """(N, SEGMENTS) wall-clock-locked day/night utilization curve.

    Segment k of an app maps to absolute time ``submit + runtime*k/(S-1)``
    (full-rate approximation), so co-running apps rise and fall
    *together* — the defining property of tidal load.  ``peak_shift``
    moves the peak within the day (π phase = services vs batch)."""
    frac = np.linspace(0.0, 1.0, SEGMENTS, dtype=np.float64)[None, :]
    t = submit[:, None] + runtime[:, None] * frac
    daylight = 0.5 * (1.0 + np.sin(2 * np.pi * t / day_s - np.pi / 2
                                   + peak_shift))
    return base + amp * daylight


# ----------------------------------------------------------------------
# diurnal — tidal day/night service load
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DiurnalConfig:
    n_apps: int = 500
    max_components: int = 12
    seed: int = 0
    day_s: float = DAY_S
    arrival_amp: float = 0.85      # day/night arrival-rate modulation
    mean_gap: float = 180.0        # base inter-arrival (s)
    min_runtime: float = 2 * 3600.0
    max_runtime: float = 36 * 3600.0
    elastic_frac: float = 0.5
    night_level: float = 0.18      # utilization trough (fraction of resv)
    day_level: float = 0.95        # utilization crest
    noise: float = 0.04
    jumpy_frac: float = 0.10
    min_cpu: float = 0.25
    max_cpu: float = 2.0
    min_mem: float = 1.0
    max_mem: float = 24.0
    # control plane: Zipf-skewed tenant assignment (1 = single tenant,
    # bit-identical to the pre-tenancy generator)
    n_tenants: int = 1
    tenant_skew: float = 1.0


@register("diurnal", DiurnalConfig,
          doc="tidal service load: arrivals + utilization on a shared "
              "day/night cycle")
def build_diurnal(cfg: DiurnalConfig) -> Trace:
    rng = np.random.RandomState(cfg.seed)
    N, C = cfg.n_apps, cfg.max_components

    # nonhomogeneous arrivals: exponential gaps stretched by the inverse
    # instantaneous rate, so submissions bunch in "daytime"
    submit = np.empty(N)
    t = 0.0
    for i in range(N):
        rate = 1.0 + cfg.arrival_amp * np.sin(2 * np.pi * t / cfg.day_s
                                              - np.pi / 2)
        t += rng.exponential(cfg.mean_gap) / max(rate, 1.0 - cfg.arrival_amp)
        submit[i] = t

    is_elastic = rng.rand(N) < cfg.elastic_frac
    n_core, n_elastic, exists, is_core = _structure(rng, N, C, is_elastic)
    cpu_req, mem_req = _demands(rng, N, C, exists, is_elastic,
                                cfg.min_cpu, cfg.max_cpu,
                                cfg.min_mem, cfg.max_mem)
    runtime = np.exp(rng.uniform(np.log(cfg.min_runtime),
                                 np.log(cfg.max_runtime), N))

    tide = _phase_profile(submit, runtime, day_s=cfg.day_s, peak_shift=0.0,
                          base=cfg.night_level,
                          amp=cfg.day_level - cfg.night_level)
    # per-component amplitude jitter + noise; memory drains slower than
    # CPU at night (heaps do not shrink to the service's idle floor)
    scale = rng.uniform(0.8, 1.0, (N, C, 1, 2))
    lv = tide[:, None, :, None] * scale
    lv[..., 1] = np.maximum(lv[..., 1], 0.5 * tide[:, None, :])
    lv = lv + rng.normal(0.0, cfg.noise, lv.shape)
    levels = np.clip(lv, 0.02, 1.0)

    is_jumpy = rng.rand(N) < cfg.jumpy_frac
    tenant = _tenants(rng, N, cfg.n_tenants, cfg.tenant_skew)
    return _assemble(submit=submit, is_elastic=is_elastic,
                     is_jumpy=is_jumpy,
                     n_core=n_core, n_elastic=n_elastic, runtime=runtime,
                     cpu_req=cpu_req, mem_req=mem_req, is_core=is_core,
                     levels=levels, cfg=cfg, tenant=tenant,
                     slo=np.ones(N, np.int64))   # services: "standard"


# ----------------------------------------------------------------------
# flashcrowd — correlated burst arrivals with synchronized spikes
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlashcrowdConfig:
    n_apps: int = 500
    max_components: int = 12
    seed: int = 0
    burst_frac: float = 0.6        # fraction of apps arriving in bursts
    n_events: int = 4              # flash events across the horizon
    event_gap_s: float = 1.5       # inter-arrival inside a burst
    mean_gap: float = 120.0        # background inter-arrival
    min_runtime: float = 180.0
    max_runtime: float = 3600.0    # crowd jobs are short
    bg_max_runtime: float = 4 * 3600.0
    calm_level: float = 0.15       # burst apps idle low ...
    spike_level: float = 0.97      # ... then spike together
    spike_width: int = 8           # segments the spike spans
    elastic_frac: float = 0.4
    jumpy_frac: float = 0.25
    min_cpu: float = 0.25
    max_cpu: float = 2.0
    min_mem: float = 1.0
    max_mem: float = 20.0
    n_tenants: int = 1
    tenant_skew: float = 1.0


@register("flashcrowd", FlashcrowdConfig,
          doc="correlated burst arrivals whose utilization spikes "
              "together (safeguard stress test)")
def build_flashcrowd(cfg: FlashcrowdConfig) -> Trace:
    rng = np.random.RandomState(cfg.seed)
    N, C = cfg.n_apps, cfg.max_components
    n_burst = int(round(N * cfg.burst_frac))
    n_bg = N - n_burst

    # background population: plain Poisson arrivals, google-ish walks
    bg_submit = np.cumsum(rng.exponential(cfg.mean_gap, n_bg))
    horizon = bg_submit[-1] if n_bg else cfg.mean_gap * N

    # flash events: each spawns an equal share of the burst population
    # within seconds, all sharing one spike window in progress-space
    event_t = np.sort(rng.uniform(0.15, 0.85, cfg.n_events)) * horizon
    per_event = np.full(cfg.n_events, n_burst // cfg.n_events)
    per_event[:n_burst % cfg.n_events] += 1
    burst_submit = np.concatenate([
        et + np.cumsum(rng.exponential(cfg.event_gap_s, k))
        for et, k in zip(event_t, per_event)]) if n_burst else np.empty(0)
    event_id = np.repeat(np.arange(cfg.n_events), per_event)

    submit = np.concatenate([bg_submit, burst_submit])
    is_burst = np.zeros(N, bool)
    is_burst[n_bg:] = True

    is_elastic = rng.rand(N) < cfg.elastic_frac
    n_core, n_elastic, exists, is_core = _structure(rng, N, C, is_elastic)
    cpu_req, mem_req = _demands(rng, N, C, exists, is_elastic,
                                cfg.min_cpu, cfg.max_cpu,
                                cfg.min_mem, cfg.max_mem)
    runtime = np.where(
        is_burst,
        np.exp(rng.uniform(np.log(cfg.min_runtime),
                           np.log(cfg.max_runtime), N)),
        np.exp(rng.uniform(np.log(cfg.min_runtime),
                           np.log(cfg.bg_max_runtime), N)))

    # background: bounded random walk (the learnable regime)
    steps = rng.normal(0.0, 0.15, (N, C, SEGMENTS, 2))
    start = rng.uniform(0.15, 0.6, (N, C, 1, 2))
    walk = np.clip(start + np.cumsum(steps, axis=2), 0.08, 1.0)

    # burst apps: calm floor, then every app of an event spikes over the
    # SAME progress window (correlated, unforecastable from history)
    seg = np.arange(SEGMENTS)[None, None, :, None]
    spike_start = rng.randint(SEGMENTS // 4, SEGMENTS // 2, cfg.n_events)
    s0 = np.zeros(N, np.int64)
    s0[n_bg:] = spike_start[event_id]
    in_spike = (seg >= s0[:, None, None, None]) & \
               (seg < s0[:, None, None, None] + cfg.spike_width)
    calm = cfg.calm_level + rng.normal(0.0, 0.03, walk.shape)
    spike = cfg.spike_level + rng.normal(0.0, 0.02, walk.shape)
    burst_lv = np.where(in_spike, spike, calm)
    levels = np.where(is_burst[:, None, None, None], burst_lv, walk)
    levels = np.clip(levels, 0.02, 1.0)

    is_jumpy = rng.rand(N) < cfg.jumpy_frac
    tenant = _tenants(rng, N, cfg.n_tenants, cfg.tenant_skew)
    return _assemble(submit=submit, is_elastic=is_elastic,
                     is_jumpy=is_jumpy,
                     n_core=n_core, n_elastic=n_elastic, runtime=runtime,
                     cpu_req=cpu_req, mem_req=mem_req, is_core=is_core,
                     levels=levels, cfg=cfg, tenant=tenant)


# ----------------------------------------------------------------------
# heavytail — Pareto runtimes/demands, ML-training-like
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HeavytailConfig:
    n_apps: int = 500
    max_components: int = 12
    seed: int = 0
    mean_gap: float = 90.0
    min_runtime: float = 120.0
    max_runtime: float = 7 * 24 * 3600.0
    runtime_alpha: float = 1.1     # Pareto shape (≈ trace-fit tails)
    min_mem: float = 0.5
    max_mem: float = 96.0
    mem_alpha: float = 1.3
    min_cpu: float = 0.25
    max_cpu: float = 4.0
    elastic_frac: float = 0.2      # gang-scheduled training: mostly rigid
    warmup_segs: int = 4           # ramp-in before the plateau
    plateau: float = 0.92          # steady-state utilization level
    dip_prob: float = 0.06         # checkpoint/GC dips off the plateau
    jumpy_frac: float = 0.15
    n_tenants: int = 1
    tenant_skew: float = 1.0


@register("heavytail", HeavytailConfig,
          doc="Pareto runtimes + memory demands (ML-training-like tail)")
def build_heavytail(cfg: HeavytailConfig) -> Trace:
    rng = np.random.RandomState(cfg.seed)
    N, C = cfg.n_apps, cfg.max_components

    submit = np.cumsum(rng.exponential(cfg.mean_gap, N))
    runtime = np.minimum(cfg.min_runtime * (1.0 + rng.pareto(
        cfg.runtime_alpha, N)), cfg.max_runtime)

    is_elastic = rng.rand(N) < cfg.elastic_frac
    n_core, n_elastic, exists, is_core = _structure(rng, N, C, is_elastic)

    idx = np.arange(C)[None, :]
    cpu = np.round(np.exp(rng.uniform(np.log(cfg.min_cpu),
                                      np.log(cfg.max_cpu), (N, C))) * 4) / 4
    # per-APP Pareto memory scale shared by its components: a big
    # training job is big in every worker
    app_mem = np.minimum(cfg.min_mem * (1.0 + rng.pareto(cfg.mem_alpha, N)),
                         cfg.max_mem)
    mem = app_mem[:, None] * rng.uniform(0.6, 1.0, (N, C))
    light = is_elastic[:, None] & (idx < 2)
    cpu = np.where(light, np.minimum(cpu, 0.5), cpu)
    mem = np.where(light, np.minimum(mem, 2.0), mem)
    cpu_req = np.where(exists, np.maximum(cpu, cfg.min_cpu),
                       0.0).astype(np.float32)
    mem_req = np.where(exists, np.maximum(mem, cfg.min_mem),
                       0.0).astype(np.float32)

    # warm-up ramp to a high plateau, with sporadic dips (checkpoints)
    seg = np.arange(SEGMENTS)[None, None, :, None]
    ramp = np.minimum(seg / max(cfg.warmup_segs, 1), 1.0)
    plateau = cfg.plateau * rng.uniform(0.9, 1.0, (N, C, 1, 2))
    lv = 0.1 + (plateau - 0.1) * ramp
    dips = rng.rand(N, C, SEGMENTS, 2) < cfg.dip_prob
    lv = np.where(dips, rng.uniform(0.3, 0.6, lv.shape), lv)
    levels = np.clip(lv + rng.normal(0.0, 0.03, lv.shape), 0.02, 1.0)

    is_jumpy = rng.rand(N) < cfg.jumpy_frac
    tenant = _tenants(rng, N, cfg.n_tenants, cfg.tenant_skew)
    return _assemble(submit=submit, is_elastic=is_elastic,
                     is_jumpy=is_jumpy,
                     n_core=n_core, n_elastic=n_elastic, runtime=runtime,
                     cpu_req=cpu_req, mem_req=mem_req, is_core=is_core,
                     levels=levels, cfg=cfg, tenant=tenant)


# ----------------------------------------------------------------------
# colocated — batch + latency-critical services, anti-correlated
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColocatedConfig:
    n_apps: int = 500
    max_components: int = 12
    seed: int = 0
    service_frac: float = 0.45     # latency-critical long-runners
    day_s: float = DAY_S
    mean_gap: float = 150.0
    svc_min_runtime: float = 12 * 3600.0
    svc_max_runtime: float = 3 * 24 * 3600.0
    batch_min_runtime: float = 600.0
    batch_max_runtime: float = 4 * 3600.0
    svc_night: float = 0.15        # service trough (night)
    svc_day: float = 0.95          # service crest (day)
    batch_night: float = 0.9       # batch crest (night) — anti-correlated
    batch_day: float = 0.35        # batch trough (day)
    noise: float = 0.04
    jumpy_frac: float = 0.10
    min_cpu: float = 0.25
    max_cpu: float = 2.0
    svc_min_mem: float = 4.0
    svc_max_mem: float = 48.0
    batch_min_mem: float = 1.0
    batch_max_mem: float = 16.0
    n_tenants: int = 1
    tenant_skew: float = 1.0


@register("colocated", ColocatedConfig,
          doc="Alibaba-style service + batch mix with anti-correlated "
              "utilization")
def build_colocated(cfg: ColocatedConfig) -> Trace:
    rng = np.random.RandomState(cfg.seed)
    N, C = cfg.n_apps, cfg.max_components

    submit = np.cumsum(rng.exponential(cfg.mean_gap, N))
    is_service = rng.rand(N) < cfg.service_frac
    # services are rigid (fixed replica sets); batch is elastic
    is_elastic = ~is_service
    n_core, n_elastic, exists, is_core = _structure(rng, N, C, is_elastic)

    cpu_req, mem_req = _demands(rng, N, C, exists, is_elastic,
                                cfg.min_cpu, cfg.max_cpu,
                                cfg.batch_min_mem, cfg.batch_max_mem)
    # services reserve the big, day-sized footprints
    svc_mem = np.exp(rng.uniform(np.log(cfg.svc_min_mem),
                                 np.log(cfg.svc_max_mem), (N, C)))
    mem_req = np.where(is_service[:, None] & (cpu_req > 0), svc_mem,
                       mem_req).astype(np.float32)

    runtime = np.where(
        is_service,
        np.exp(rng.uniform(np.log(cfg.svc_min_runtime),
                           np.log(cfg.svc_max_runtime), N)),
        np.exp(rng.uniform(np.log(cfg.batch_min_runtime),
                           np.log(cfg.batch_max_runtime), N)))

    svc = _phase_profile(submit, runtime, day_s=cfg.day_s, peak_shift=0.0,
                         base=cfg.svc_night, amp=cfg.svc_day - cfg.svc_night)
    bat = _phase_profile(submit, runtime, day_s=cfg.day_s,
                         peak_shift=np.pi,    # half a day out of phase
                         base=cfg.batch_day,
                         amp=cfg.batch_night - cfg.batch_day)
    tide = np.where(is_service[:, None], svc, bat)
    scale = rng.uniform(0.85, 1.0, (N, C, 1, 2))
    lv = tide[:, None, :, None] * scale
    lv[..., 1] = np.maximum(lv[..., 1], 0.5 * tide[:, None, :])
    levels = np.clip(lv + rng.normal(0.0, cfg.noise, lv.shape), 0.02, 1.0)

    is_jumpy = rng.rand(N) < cfg.jumpy_frac
    tenant = _tenants(rng, N, cfg.n_tenants, cfg.tenant_skew)
    # latency-critical services buy "premium", batch rides "best-effort"
    slo = np.where(is_service, 2, 0).astype(np.int64)
    return _assemble(submit=submit, is_elastic=is_elastic,
                     is_jumpy=is_jumpy,
                     n_core=n_core, n_elastic=n_elastic, runtime=runtime,
                     cpu_req=cpu_req, mem_req=mem_req, is_core=is_core,
                     levels=levels, cfg=cfg, tenant=tenant, slo=slo)
