"""Arrival-process fitting: distill a replayed trace into a parametric,
sweepable scenario config.

``fit_trace`` estimates the generative knobs of ANY schema-valid
:class:`Trace` — typically one ingested by the replay adapter from an
Azure/Alibaba-style file — and returns a frozen :class:`FittedConfig`:

  * **arrival process** — exponential inter-arrival at the trace's
    empirical rate (apps/sec over the observed submission span);
  * **lifetime** — lognormal runtime (moments of ``log runtime``);
  * **size** — lognormal per-component CPU/MEM reservations, fitted
    over *existing* components only;
  * **structure** — empirical component-count distribution plus the
    elastic/jumpy population fractions;
  * **utilization profile** — Beta-matched mean/std of the piecewise
    knot levels (per resource), smoothed so the synthetic series stay
    learnable (ramps, not white noise);
  * **tenancy** — tenant count and a Zipf skew fitted by least squares
    on the log-rank/log-share curve.

Because the result is a plain frozen scenario config registered as
``"fitted"``, it drops straight into the sweep grid: fit once, then
sweep ``n_apps`` / ``seed`` / ``rate`` around the measured operating
point — the scale-out story the replay file itself cannot provide.

    cfg = fit_trace(load_trace("azure.csv", preset="azure"))
    big = dataclasses.replace(cfg, n_apps=100_000, seed=7)
    tr  = build_trace(big)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.scenarios.families import _assemble, _structure, _tenants
from repro.sim.scenarios.registry import register
from repro.sim.scenarios.schema import CPU, MEM, SEGMENTS, Trace

__all__ = ["FittedConfig", "fit_trace"]


@dataclasses.dataclass(frozen=True)
class FittedConfig:
    """Parametric scenario config estimated from a replayed trace.

    Every field is a plain float/int/tuple so the config hashes (sweep
    axes, ``_cfg_key`` compilation caching) and sweeps: ``rate`` scales
    load intensity, ``n_apps`` scales trace length, ``seed`` draws a
    fresh population from the same fitted distributions.
    """
    n_apps: int = 256
    max_components: int = 1
    seed: int = 0
    # arrival process: exponential inter-arrival, apps per second
    rate: float = 1.0 / 300.0
    # lifetime: lognormal over seconds
    runtime_mu: float = 7.0
    runtime_sigma: float = 1.0
    # per-component reservations: lognormal (cores / GB)
    cpu_mu: float = 0.0
    cpu_sigma: float = 0.7
    mem_mu: float = 1.5
    mem_sigma: float = 0.8
    # structure: P(app has k+1 components), k = 0..len-1; population mix
    comp_weights: tuple = (1.0,)
    elastic_frac: float = 0.0
    jumpy_frac: float = 0.0
    # utilization knots: Beta-matched mean/std per resource
    cpu_level_mu: float = 0.5
    cpu_level_sigma: float = 0.2
    mem_level_mu: float = 0.5
    mem_level_sigma: float = 0.15
    # tenancy (carried by the sweep's scenario axis)
    n_tenants: int = 1
    tenant_skew: float = 1.1


def _log_moments(x: np.ndarray, floor: float) -> tuple[float, float]:
    lx = np.log(np.maximum(np.asarray(x, np.float64), floor))
    return float(lx.mean()), float(max(lx.std(), 1e-3))


def _fit_skew(tenant: np.ndarray, n_tenants: int) -> float:
    """Least-squares Zipf exponent of the tenant share-vs-rank curve."""
    counts = np.sort(np.bincount(tenant, minlength=n_tenants))[::-1]
    counts = counts[counts > 0].astype(np.float64)
    if counts.size < 2:
        return 1.1
    lr = np.log(1.0 + np.arange(counts.size))
    lc = np.log(counts)
    slope = np.polyfit(lr, lc, 1)[0]
    return float(np.clip(-slope, 0.0, 4.0))


def fit_trace(trace: Trace, *, n_apps: int = 0, seed: int = 0) -> FittedConfig:
    """Estimate a :class:`FittedConfig` from any schema-valid trace.

    ``n_apps`` defaults to the source trace's length; pass a larger
    value (or ``dataclasses.replace`` later) to scale the synthetic
    population beyond the recording.
    """
    sub = np.asarray(trace.submit, np.float64)
    span = float(sub[-1] - sub[0])
    n = trace.n_apps
    rate = (n - 1) / span if (n > 1 and span > 0) else 1.0 / 300.0

    run_mu, run_sigma = _log_moments(trace.runtime, 1.0)
    exists = np.asarray(trace.cpu_req) > 0
    cpu_mu, cpu_sigma = _log_moments(trace.cpu_req[exists], 0.25)
    mem_mu, mem_sigma = _log_moments(trace.mem_req[exists], 0.05)

    n_comp = exists.sum(1)
    weights = np.bincount(np.maximum(n_comp - 1, 0),
                          minlength=trace.max_components).astype(np.float64)
    weights /= weights.sum()

    lv = np.asarray(trace.levels, np.float64)[exists]   # (k, SEGMENTS, 2)
    cpu_lv, mem_lv = lv[..., CPU].ravel(), lv[..., MEM].ravel()

    n_tenants = trace.n_tenants
    return FittedConfig(
        n_apps=n_apps or n,
        max_components=trace.max_components,
        seed=seed,
        rate=float(rate),
        runtime_mu=run_mu, runtime_sigma=run_sigma,
        cpu_mu=cpu_mu, cpu_sigma=cpu_sigma,
        mem_mu=mem_mu, mem_sigma=mem_sigma,
        comp_weights=tuple(float(round(w, 6)) for w in weights),
        elastic_frac=float(np.mean(trace.is_elastic)),
        jumpy_frac=float(np.mean(trace.is_jumpy)),
        cpu_level_mu=float(cpu_lv.mean()),
        cpu_level_sigma=float(max(cpu_lv.std(), 1e-3)),
        mem_level_mu=float(mem_lv.mean()),
        mem_level_sigma=float(max(mem_lv.std(), 1e-3)),
        n_tenants=n_tenants,
        tenant_skew=(_fit_skew(np.asarray(trace.tenant), n_tenants)
                     if n_tenants > 1 else 1.1),
    )


def _beta_knots(rng, shape, mu: float, sigma: float) -> np.ndarray:
    """Beta-distributed knots matched to (mu, sigma), smoothed along the
    segment axis so profiles ramp rather than jitter (the forecaster
    presupposes learnable series — see ``Trace.usage``)."""
    mu = float(np.clip(mu, 0.02, 0.98))
    var = float(min(sigma, 0.45) ** 2)
    var = min(var, 0.9 * mu * (1.0 - mu))
    k = mu * (1.0 - mu) / max(var, 1e-6) - 1.0
    raw = rng.beta(max(mu * k, 0.05), max((1.0 - mu) * k, 0.05), shape)
    # 5-knot moving average along the last axis (reflect-padded)
    pad = np.concatenate([raw[..., 2:0:-1], raw, raw[..., -2:-4:-1]], -1)
    win = np.lib.stride_tricks.sliding_window_view(pad, 5, axis=-1)
    return np.clip(win.mean(-1), 0.0, 1.0)


@register("fitted", FittedConfig,
          doc="synthetic trace drawn from distributions fitted to a "
              "replayed trace (fit_trace)")
def _build(cfg: FittedConfig) -> Trace:
    rng = np.random.RandomState(cfg.seed)
    N, C = cfg.n_apps, cfg.max_components

    gaps = rng.exponential(1.0 / max(cfg.rate, 1e-9), N)
    submit = np.cumsum(gaps) - gaps[0]
    runtime = np.maximum(
        rng.lognormal(cfg.runtime_mu, cfg.runtime_sigma, N), 1.0)

    is_elastic = (rng.rand(N) < cfg.elastic_frac) & (C >= 3)
    is_jumpy = rng.rand(N) < cfg.jumpy_frac

    if is_elastic.any():
        n_core, n_elastic, exists, is_core = _structure(rng, N, C, is_elastic)
    else:
        w = np.asarray(cfg.comp_weights[:C], np.float64)
        w = w / w.sum() if w.sum() > 0 else np.ones(C) / C
        n_core = 1 + rng.choice(len(w), size=N, p=w)
        n_elastic = np.zeros(N, np.int64)
        idx = np.arange(C)[None, :]
        exists = idx < n_core[:, None]
        is_core = exists
    # rigid rows of a mixed population keep the empirical count mix
    if is_elastic.any() and (~is_elastic).any():
        w = np.asarray(cfg.comp_weights[:C], np.float64)
        w = w / w.sum() if w.sum() > 0 else np.ones(C) / C
        k = 1 + rng.choice(len(w), size=N, p=w)
        n_core = np.where(is_elastic, n_core, np.minimum(k, C))
        idx = np.arange(C)[None, :]
        rigid_exists = idx < n_core[:, None]
        exists = np.where(is_elastic[:, None], exists, rigid_exists)
        is_core = np.where(is_elastic[:, None], is_core, rigid_exists)

    cpu = np.round(rng.lognormal(cfg.cpu_mu, cfg.cpu_sigma, (N, C)) * 4) / 4
    cpu_req = np.where(exists, np.maximum(cpu, 0.25), 0.0).astype(np.float32)
    mem = rng.lognormal(cfg.mem_mu, cfg.mem_sigma, (N, C))
    mem_req = np.where(exists, np.maximum(mem, 0.05), 0.0).astype(np.float32)

    levels = np.zeros((N, C, SEGMENTS, 2), np.float32)
    levels[..., CPU] = _beta_knots(rng, (N, C, SEGMENTS),
                                   cfg.cpu_level_mu, cfg.cpu_level_sigma)
    levels[..., MEM] = _beta_knots(rng, (N, C, SEGMENTS),
                                   cfg.mem_level_mu, cfg.mem_level_sigma)

    tenant = _tenants(rng, N, cfg.n_tenants, cfg.tenant_skew)
    return _assemble(submit=submit, is_elastic=is_elastic, is_jumpy=is_jumpy,
                     n_core=n_core, n_elastic=n_elastic, runtime=runtime,
                     cpu_req=cpu_req, mem_req=mem_req, is_core=is_core,
                     levels=levels, cfg=cfg, tenant=tenant)
