"""Named scenario registry: config class + builder per workload family.

A *scenario* is a frozen config dataclass plus a builder that turns it
into a schema-valid :class:`~repro.sim.scenarios.schema.Trace`.  Sources
register under a short name::

    @register("diurnal", DiurnalConfig, doc="tidal day/night service load")
    def build(cfg: DiurnalConfig) -> Trace: ...

and the sweep's ``scenario`` grid axis, ``make_config`` and
``build_trace`` dispatch through the registry.  Config classes double as
the dispatch key, so ``SimConfig.workload`` can hold ANY registered
scenario config and ``run_sim`` still finds the right builder.

Built-in families load lazily: looking up a name (or a config type)
that is not registered yet first imports the module known to provide
it, so ``make_config("google")`` works without the caller importing
``repro.sim.workload`` explicitly.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

from repro.sim.scenarios.schema import Trace

__all__ = ["ScenarioSpec", "register", "get", "scenario_names",
           "scenario_of", "make_config", "build_trace"]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    config_cls: type
    build: Callable[[Any], Trace]
    doc: str = ""


_SCENARIOS: dict[str, ScenarioSpec] = {}
_BY_CONFIG: dict[type, ScenarioSpec] = {}

# name -> module that registers it on import (lazy, avoids import cycles:
# repro.sim.workload itself imports this module)
_BUILTIN = {
    "google": "repro.sim.workload",
    "diurnal": "repro.sim.scenarios.families",
    "flashcrowd": "repro.sim.scenarios.families",
    "heavytail": "repro.sim.scenarios.families",
    "colocated": "repro.sim.scenarios.families",
    "replay": "repro.sim.scenarios.replay",
    "stream": "repro.sim.scenarios.stream",
    "fitted": "repro.sim.scenarios.fitting",
}


def register(name: str, config_cls: type, doc: str = ""):
    """Decorator for a ``build(cfg) -> Trace`` function."""
    def deco(build_fn):
        spec = ScenarioSpec(name=name, config_cls=config_cls,
                            build=build_fn, doc=doc)
        _SCENARIOS[name] = spec
        _BY_CONFIG[config_cls] = spec
        return build_fn
    return deco


def _load_builtins() -> None:
    for mod in set(_BUILTIN.values()):
        importlib.import_module(mod)


def get(name: str) -> ScenarioSpec:
    if name not in _SCENARIOS and name in _BUILTIN:
        importlib.import_module(_BUILTIN[name])
    try:
        return _SCENARIOS[name]
    except KeyError:
        _load_builtins()
        if name in _SCENARIOS:
            return _SCENARIOS[name]
        raise KeyError(f"unknown scenario {name!r} "
                       f"(registered: {scenario_names()})") from None


def scenario_names() -> tuple[str, ...]:
    _load_builtins()
    return tuple(sorted(_SCENARIOS))


def scenario_of(cfg: Any) -> str:
    """Registry name of a scenario config instance."""
    return _spec_for(cfg).name


def _spec_for(cfg: Any) -> ScenarioSpec:
    spec = _BY_CONFIG.get(type(cfg))
    if spec is None:
        _load_builtins()
        spec = _BY_CONFIG.get(type(cfg))
    if spec is None:
        raise TypeError(f"{type(cfg).__name__} is not a registered "
                        f"scenario config (registered: {scenario_names()})")
    return spec


# the only fields that carry across FAMILIES when the sweep's scenario
# axis swaps workloads: grid scale, seed and the tenant layout.  Shape
# parameters (runtime ranges, demand ranges, mix fractions) stay
# family-authentic — carrying a CI-scale google max_runtime into
# `diurnal` would erase its day-cycle character.  Tenancy carries
# because it is population structure, not load shape: a sweep pairing a
# `tenancy` axis with a `scenario` axis keeps the same tenant mix.
_CARRY = ("n_apps", "max_components", "seed", "n_tenants", "tenant_skew")


def make_config(name: str, base: Any = None, **overrides: Any):
    """Build the named scenario's config.

    ``base`` may be any other scenario config.  Same family: ``base`` is
    kept verbatim (plus ``overrides``).  Different family: only the
    shared scale knobs (``n_apps``, ``max_components``, ``seed``) carry
    over — this is how the sweep's ``scenario`` axis preserves the grid's
    scale while switching regimes.  ``overrides`` always win.
    """
    spec = get(name)
    kw: dict[str, Any] = {}
    if base is not None and type(base) is spec.config_cls:
        kw = {f.name: getattr(base, f.name)
              for f in dataclasses.fields(base)}
    elif base is not None:
        ours = {f.name for f in dataclasses.fields(spec.config_cls)}
        base_fields = {f.name for f in dataclasses.fields(base)}
        for fname in _CARRY:
            if fname in ours and fname in base_fields:
                kw[fname] = getattr(base, fname)
    kw.update(overrides)
    return spec.config_cls(**kw)


def build_trace(cfg: Any) -> Trace:
    """Dispatch a scenario config to its registered builder."""
    return _spec_for(cfg).build(cfg)
