"""Trace replay: ingest real cluster traces (CSV / Parquet) as a Trace.

File format — one row per *component*, grouped by application:

    app_id, submit, runtime, is_elastic, is_jumpy, component, is_core,
    cpu_req, mem_req, cpu_levels, mem_levels [, tenant_id, slo_class]

``tenant_id`` / ``slo_class`` are optional (files written before the
control plane load as a single tenant 0, SLO "best-effort"); string
tenant ids are densely re-encoded, ``slo_class`` accepts a class name
or its integer code.
``cpu_levels`` / ``mem_levels`` are ``;``-joined utilization fractions
(of the reservation) sampled anywhere along the component's lifetime —
any length; they are linearly resampled to the engine's ``SEGMENTS``
knots on load.  This keeps the files rectangular (plain CSV, Parquet,
or anything pandas reads) while allowing per-trace sampling rates.

CSV round-trips through the stdlib ``csv`` module — no extra
dependencies.  Parquet requires pandas+pyarrow and degrades to a clear
error when they are absent (they are NOT a hard dependency of the
package).

``save_trace`` writes any :class:`Trace` back out in the same format,
so synthetic scenarios can be exported, edited, and replayed — and the
round-trip is exact for float32 values.
"""
from __future__ import annotations

import csv
import dataclasses
import os
import warnings

import numpy as np

from repro.control.config import SLO_CLASSES
from repro.sim.scenarios.registry import register
from repro.sim.scenarios.schema import CPU, MEM, SEGMENTS, Trace, sort_by_submit

try:
    import pandas as _pd
except ImportError:                        # pragma: no cover - env-dependent
    _pd = None

# tenant_id / slo_class are OPTIONAL on load (pre-control-plane files
# back-compat to tenant 0, "best-effort"); save_trace always writes them
COLUMNS = ("app_id", "submit", "runtime", "is_elastic", "is_jumpy",
           "component", "is_core", "cpu_req", "mem_req",
           "cpu_levels", "mem_levels", "tenant_id", "slo_class")

# default 5-minute reading cadence of the Azure public VM traces, used
# when a VM has a single reading (no inferable interval)
_AZURE_DT_S = 300.0


def _azure_rows(rows: list[dict]) -> list[dict]:
    """Column-mapping preset for Azure-public-dataset-style VM traces.

    Input: long format, one row per *reading* —

        vmid, timestamp, corecount, memory, avgcpu [, avgmem]

    (``timestamp`` in seconds, ``avgcpu``/``avgmem`` in percent of the
    provisioned ``corecount`` cores / ``memory`` GB, the convention of
    the AzurePublicDataset usage files).  Each VM becomes one rigid
    single-component app: first reading = submission, reading span =
    runtime, utilization series = the readings scaled to fractions
    (resampled to the engine's knots by the normal replay path).  The
    Azure traces carry no memory utilization; absent ``avgmem``, memory
    levels default to a flat 50% of the reservation.
    """
    by_vm: dict = {}
    for r in rows:
        by_vm.setdefault(str(r["vmid"]), []).append(r)
    out = []
    for vmid, rs in by_vm.items():
        rs = sorted(rs, key=lambda r: float(r["timestamp"]))
        ts = np.asarray([float(r["timestamp"]) for r in rs])
        dt = float(np.median(np.diff(ts))) if ts.size > 1 else _AZURE_DT_S
        cpu = [min(max(float(r["avgcpu"]) / 100.0, 0.0), 1.0) for r in rs]

        def mem_level(r):
            # per-reading: blank / missing / NaN cells (the Azure traces
            # carry no memory readings at all) -> flat 50% default
            v = r.get("avgmem")
            if v in ("", None):
                return 0.5
            v = float(v)
            return 0.5 if v != v else min(max(v / 100.0, 0.0), 1.0)

        mem = [mem_level(r) for r in rs]
        out.append({
            "tenant_id": rs[0].get("tenant", 0) or 0,
            "app_id": vmid,
            "submit": ts[0],
            "runtime": max(ts[-1] - ts[0] + dt, dt),
            "is_elastic": 0,
            "is_jumpy": 0,
            "component": 0,
            "is_core": 1,
            "cpu_req": float(rs[0]["corecount"]),
            "mem_req": float(rs[0]["memory"]),
            "cpu_levels": ";".join(str(v) for v in cpu),
            "mem_levels": ";".join(str(v) for v in mem),
        })
    return out


# default sampling cadence of the Alibaba cluster-trace (v2018)
# container_usage readings, used when a container has a single reading
_ALIBABA_DT_S = 10.0


def _alibaba_rows(rows: list[dict]) -> list[dict]:
    """Column-mapping preset for Alibaba-cluster-trace-style containers.

    Input: long format, one row per *reading*, the v2018
    ``container_usage`` columns joined with the container's requested
    resources from ``container_meta`` —

        container_id, time_stamp, cpu_request, mem_size,
        cpu_util_percent [, mem_util_percent]

    (``time_stamp`` in seconds; ``cpu_request`` in the trace's 1/100-
    core units, so 400 = 4 cores; ``mem_size`` in GB;
    ``cpu_util_percent`` / ``mem_util_percent`` in percent of the
    request, the convention of the published trace).  Each container
    becomes one rigid single-component app, mirroring the Azure preset:
    first reading = submission, reading span = runtime, utilization
    series = the percent readings scaled to fractions.  Missing memory
    readings default to a flat 50% of the reservation.
    """
    by_c: dict = {}
    for r in rows:
        by_c.setdefault(str(r["container_id"]), []).append(r)
    out = []
    for cid, rs in by_c.items():
        rs = sorted(rs, key=lambda r: float(r["time_stamp"]))
        ts = np.asarray([float(r["time_stamp"]) for r in rs])
        dt = float(np.median(np.diff(ts))) if ts.size > 1 else _ALIBABA_DT_S

        def frac(r, col):
            v = r.get(col)
            if v in ("", None):
                return 0.5
            v = float(v)
            return 0.5 if v != v else min(max(v / 100.0, 0.0), 1.0)

        out.append({
            "tenant_id": rs[0].get("tenant", 0) or 0,
            "app_id": cid,
            "submit": ts[0],
            "runtime": max(ts[-1] - ts[0] + dt, dt),
            "is_elastic": 0,
            "is_jumpy": 0,
            "component": 0,
            "is_core": 1,
            "cpu_req": float(rs[0]["cpu_request"]) / 100.0,
            "mem_req": float(rs[0]["mem_size"]),
            "cpu_levels": ";".join(str(frac(r, "cpu_util_percent"))
                                   for r in rs),
            "mem_levels": ";".join(str(frac(r, "mem_util_percent"))
                                   for r in rs),
        })
    return out


# preset name -> raw-row transform into the canonical replay columns
PRESETS = {"azure": _azure_rows, "alibaba": _alibaba_rows}


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    """Scenario config for trace replay.

    ``seed`` exists only so the sweep's seed axis applies uniformly to
    every scenario config; a replayed trace is identical across seeds.
    ``n_apps`` > 0 truncates to the first N applications (by submission
    time); ``max_components`` > 0 overrides the inferred component
    padding (it must cover the widest app).  ``preset`` selects a
    column-mapping for foreign trace formats (currently ``"azure"`` for
    Azure-public-dataset-style VM readings).
    """
    path: str = ""
    n_apps: int = 0
    max_components: int = 0
    seed: int = 0
    preset: str = ""


def _fmt_levels(row: np.ndarray) -> str:
    # no precision cap: format_float_positional defaults to the unique
    # shortest repr, which is what makes the round-trip float32-exact
    return ";".join(np.format_float_positional(v, trim="-") for v in row)


def _parse_levels(s: str) -> np.ndarray:
    vals = np.asarray([float(x) for x in str(s).split(";")], np.float32)
    if vals.size == SEGMENTS:
        return vals
    # linear resample onto the engine's knot grid
    src = np.linspace(0.0, 1.0, vals.size)
    dst = np.linspace(0.0, 1.0, SEGMENTS)
    return np.interp(dst, src, vals).astype(np.float32)


def save_trace(trace: Trace, path: str) -> None:
    """Write a Trace in the replay format (.csv or .parquet)."""
    rows = []
    for gid in range(trace.n_apps):
        for c in range(trace.max_components):
            if trace.cpu_req[gid, c] == 0:
                continue
            rows.append({
                "app_id": gid,
                "submit": float(trace.submit[gid]),
                "runtime": float(trace.runtime[gid]),
                "is_elastic": int(trace.is_elastic[gid]),
                "is_jumpy": int(trace.is_jumpy[gid]),
                "component": c,
                "is_core": int(trace.is_core[gid, c]),
                "cpu_req": float(trace.cpu_req[gid, c]),
                "mem_req": float(trace.mem_req[gid, c]),
                "cpu_levels": _fmt_levels(trace.levels[gid, c, :, CPU]),
                "mem_levels": _fmt_levels(trace.levels[gid, c, :, MEM]),
                "tenant_id": int(trace.tenant[gid]),
                "slo_class": SLO_CLASSES[int(trace.slo[gid])],
            })
    if path.endswith(".parquet"):
        if _pd is None:
            raise RuntimeError("parquet export needs pandas+pyarrow; "
                               "write .csv instead")
        _pd.DataFrame(rows, columns=COLUMNS).to_parquet(path, index=False)
        return
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=COLUMNS)
        w.writeheader()
        w.writerows(rows)


def _slo_code(v) -> int:
    """``slo_class`` cell -> integer code: a class name, a numeric
    code, or blank/absent (-> 0, "best-effort")."""
    if v in ("", None) or v != v:           # blank cell or NaN
        return 0
    s = str(v)
    if s in SLO_CLASSES:
        return SLO_CLASSES.index(s)
    return int(float(s))


def _tenant_codes(raw: list) -> np.ndarray:
    """``tenant_id`` cells -> dense integer codes.

    Integer-valued cells pass through; any non-numeric id (string
    tenant names) densely re-encodes ALL ids by sorted unique value,
    so foreign traces can tag tenants symbolically."""
    vals = ["0" if v in ("", None) or v != v else str(v) for v in raw]
    try:
        return np.asarray([int(float(v)) for v in vals], np.int64)
    except ValueError:
        uniq = {v: i for i, v in enumerate(sorted(set(vals)))}
        return np.asarray([uniq[v] for v in vals], np.int64)


# per-app scalar columns that every component row of one app must agree
# on — a conflict means two different applications share an app_id (the
# old loader silently kept the first row's values)
_APP_SCALARS = ("submit", "runtime", "is_elastic", "is_jumpy")


def _check_app(aid: str, rs: list[dict]) -> list[dict]:
    """Validate and canonicalize one app's component rows.

    Rows sort by their declared ``component`` id (the old loader packed
    them in file order, silently re-keying shuffled components);
    duplicate component ids and conflicting per-app scalars raise.
    """
    for col in _APP_SCALARS:
        vals = {float(r[col]) for r in rs}
        if len(vals) > 1:
            raise ValueError(
                f"replay app {aid!r}: component rows disagree on "
                f"{col!r} ({sorted(vals)}) — duplicate app_id reused "
                "for different applications?")
    comps = [int(float(r["component"])) for r in rs]
    if len(set(comps)) != len(comps):
        raise ValueError(f"replay app {aid!r}: duplicate component ids "
                         f"{sorted(comps)}")
    if comps != sorted(comps):
        rs = [r for _, r in sorted(zip(comps, rs), key=lambda p: p[0])]
    return rs


def _read_rows(path: str) -> list[dict]:
    if path.endswith(".parquet"):
        if _pd is None:
            raise RuntimeError(f"cannot read {path}: parquet support needs "
                               "pandas+pyarrow (convert to .csv)")
        return _pd.read_parquet(path).to_dict("records")
    with open(path, newline="") as f:
        return list(csv.DictReader(f))


def load_trace(path: str, n_apps: int = 0, max_components: int = 0,
               cfg: ReplayConfig | None = None,
               preset: str | None = None) -> Trace:
    """Parse a replay file into a schema-valid Trace.

    ``preset`` maps a foreign column layout onto the canonical replay
    columns before parsing — e.g. ``preset="azure"`` ingests Azure-VM-
    trace-style long-format readings (see :data:`PRESETS`).  When not
    given explicitly it defaults to ``cfg.preset``.

    Malformed files are detected rather than silently mangled:
    applications out of submission order stable-sort with a warning
    (duplicate arrival times keep file order); component rows sort by
    their declared ``component`` id; duplicate component ids or
    component rows that disagree on per-app scalars (``submit``,
    ``runtime``, ...) raise ``ValueError``.
    """
    if preset is None and cfg is not None and cfg.preset:
        preset = cfg.preset
    if preset:
        transform = PRESETS.get(preset)
        if transform is None:
            raise ValueError(f"unknown replay preset {preset!r} "
                             f"(available: {sorted(PRESETS)})")
    if not os.path.exists(path):
        raise FileNotFoundError(f"replay trace not found: {path}")
    rows = _read_rows(path)
    if preset:
        rows = transform(rows)
    if not rows:
        raise ValueError(f"replay trace {path} is empty")

    by_app: dict = {}
    for r in rows:
        by_app.setdefault(str(r["app_id"]), []).append(r)
    apps = [_check_app(aid, rs) for aid, rs in by_app.items()]
    subs = [float(rs[0]["submit"]) for rs in apps]
    if any(a > b for a, b in zip(subs, subs[1:])):
        # stable sort: ties (duplicate arrival times) keep file order,
        # so re-saving the sorted trace is a fixed point
        warnings.warn(
            f"replay trace {path}: application rows are not in submission "
            "order; stable-sorting by submit (ties keep file order)",
            stacklevel=2)
    apps.sort(key=lambda rs: float(rs[0]["submit"]))
    if n_apps > 0:
        apps = apps[:n_apps]

    N = len(apps)
    width = max(len(rs) for rs in apps)
    if max_components > 0 and width > max_components:
        raise ValueError(f"app with {width} components exceeds "
                         f"max_components={max_components}")
    C = max_components if max_components > 0 else width

    submit = np.zeros(N, np.float32)
    runtime = np.zeros(N, np.float32)
    is_elastic = np.zeros(N, bool)
    is_jumpy = np.zeros(N, bool)
    cpu_req = np.zeros((N, C), np.float32)
    mem_req = np.zeros((N, C), np.float32)
    is_core = np.zeros((N, C), bool)
    levels = np.zeros((N, C, SEGMENTS, 2), np.float32)
    slo = np.zeros(N, np.int64)
    raw_tenant = []

    for gid, rs in enumerate(apps):
        submit[gid] = float(rs[0]["submit"])
        runtime[gid] = float(rs[0]["runtime"])
        is_elastic[gid] = bool(int(rs[0]["is_elastic"]))
        is_jumpy[gid] = bool(int(rs[0]["is_jumpy"]))
        # tenancy columns are optional: tenant-less files back-compat
        # to a single tenant 0 on the "best-effort" SLO class
        raw_tenant.append(rs[0].get("tenant_id"))
        slo[gid] = _slo_code(rs[0].get("slo_class"))
        # components pack into slots 0..k in file order (slot ids in the
        # padded table are positional, not semantic)
        for c, r in enumerate(rs):
            cpu_req[gid, c] = float(r["cpu_req"])
            mem_req[gid, c] = float(r["mem_req"])
            is_core[gid, c] = bool(int(r["is_core"]))
            levels[gid, c, :, CPU] = _parse_levels(r["cpu_levels"])
            levels[gid, c, :, MEM] = _parse_levels(r["mem_levels"])

    exists = cpu_req > 0
    levels = np.clip(levels * exists[:, :, None, None], 0.0, 1.0)
    cols = sort_by_submit(submit, runtime=runtime, is_elastic=is_elastic,
                          is_jumpy=is_jumpy, cpu_req=cpu_req,
                          mem_req=mem_req, is_core=is_core, levels=levels,
                          tenant=_tenant_codes(raw_tenant), slo=slo)
    exists = cols["cpu_req"] > 0
    return Trace(n_core=cols["is_core"].sum(1).astype(np.int64),
                 n_elastic=(exists & ~cols["is_core"]).sum(1).astype(np.int64),
                 cfg=cfg, **cols).validate()


@register("replay", ReplayConfig,
          doc="replay a recorded CSV/Parquet cluster trace")
def build_replay(cfg: ReplayConfig) -> Trace:
    if not cfg.path:
        raise ValueError("ReplayConfig.path is required "
                         "(e.g. make_config('replay', path='trace.csv'))")
    return load_trace(cfg.path, n_apps=cfg.n_apps,
                      max_components=cfg.max_components, cfg=cfg)
