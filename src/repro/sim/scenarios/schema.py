"""Canonical trace schema consumed by the simulation engines.

A :class:`Trace` is a column-oriented application table: arrival times,
per-component reservations, rigid/elastic tags and piecewise-linear
utilization profiles.  Every workload source — the parametric families
in :mod:`repro.sim.scenarios.families`, the legacy Google-shaped
generator in :mod:`repro.sim.workload`, and the CSV/Parquet replay
adapter in :mod:`repro.sim.scenarios.replay` — emits this one schema,
so ``repro.sim.engine`` / ``engine_ref`` run any of them unchanged.

Invariants (checked by :meth:`Trace.validate`):

  * ``submit`` is nondecreasing — the engine's arrival scan pops apps
    in submission order;
  * reservations are nonnegative and CPU/MEM agree on which components
    exist (``cpu_req > 0`` iff ``mem_req > 0``);
  * every app has at least one core component and core components are a
    prefix-consistent subset of existing ones; rigid apps (``is_elastic
    == False``) carry no elastic components;
  * utilization levels live in ``[0, 1]`` (fraction of the reservation
    — usage can never exceed what was reserved) and are zero for absent
    components;
  * tenant ids are nonnegative and SLO classes index
    ``repro.control.config.SLO_CLASSES``.  Both columns are OPTIONAL:
    tenant-less sources back-compat to a single default tenant 0 with
    the ``best-effort`` SLO class (``__post_init__`` normalizes
    ``None`` to zeros), so every pre-control-plane trace still
    validates and runs bit-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.control.config import SLO_CLASSES

#: number of piecewise-linear utilization knots per component profile
SEGMENTS = 32
CPU, MEM = 0, 1


class TraceValidationError(ValueError):
    """Raised by :meth:`Trace.validate` with every violated invariant."""


@dataclasses.dataclass
class Trace:
    """Column-oriented application table (index = global app id)."""

    submit: np.ndarray        # (N,) seconds, nondecreasing
    is_elastic: np.ndarray    # (N,) bool
    is_jumpy: np.ndarray      # (N,) bool — "unpredictable" class
    n_core: np.ndarray        # (N,) int
    n_elastic: np.ndarray     # (N,) int
    runtime: np.ndarray       # (N,) base runtime (all components running)
    cpu_req: np.ndarray       # (N, C) per-component reservation (0 = absent)
    mem_req: np.ndarray       # (N, C) GB
    is_core: np.ndarray       # (N, C) bool
    levels: np.ndarray        # (N, C, SEGMENTS, 2) utilization fraction
    cfg: Any = None           # the scenario config that built this trace
    tenant: np.ndarray = None  # (N,) int tenant id (None -> all tenant 0)
    slo: np.ndarray = None     # (N,) int index into SLO_CLASSES

    def __post_init__(self):
        # tenant-less back-compat: a trace built without the control
        # plane is a single default tenant on the weakest SLO class
        n = self.submit.shape[0] if isinstance(self.submit, np.ndarray) else 0
        if self.tenant is None:
            self.tenant = np.zeros(n, np.int64)
        if self.slo is None:
            self.slo = np.zeros(n, np.int64)

    @property
    def n_apps(self) -> int:
        return self.submit.shape[0]

    @property
    def max_components(self) -> int:
        return self.cpu_req.shape[1]

    @property
    def n_tenants(self) -> int:
        return int(self.tenant.max()) + 1 if self.tenant.size else 1

    def usage(self, gid: np.ndarray, progress: np.ndarray) -> np.ndarray:
        """(len(gid), C, 2) instantaneous usage at given progress in [0,1].

        Levels are linearly interpolated between segment knots: real
        utilization ramps (allocators grow/shrink heaps over minutes)
        rather than stepping discontinuously — this is what makes the
        series *learnable*, which the paper's Fig. 2 error distributions
        presuppose."""
        x = np.clip(progress, 0.0, 1.0) * (SEGMENTS - 1)
        s0 = np.minimum(x.astype(np.int64), SEGMENTS - 2)
        frac = (x - s0).astype(np.float32)
        ar = np.arange(len(gid))[:, None]
        ac = np.arange(self.max_components)[None, :]
        lv0 = self.levels[gid][ar, ac, s0[:, None], :]
        lv1 = self.levels[gid][ar, ac, s0[:, None] + 1, :]
        lv = lv0 + (lv1 - lv0) * frac[:, None, None]
        # "unpredictable" apps step discontinuously (no ramp to learn from)
        jumpy = self.is_jumpy[gid][:, None, None]
        lv = np.where(jumpy, lv0, lv)
        req = np.stack([self.cpu_req[gid], self.mem_req[gid]], axis=-1)
        return lv * req

    # ------------------------------------------------------------------
    def validate(self) -> "Trace":
        """Check every schema invariant; raise with the full list of
        violations (returns self so builders can ``return tr.validate()``)."""
        p: list[str] = []
        N, C = self.n_apps, self.max_components
        if N < 1:
            raise TraceValidationError("trace has no applications")

        shapes = {"submit": (N,), "is_elastic": (N,), "is_jumpy": (N,),
                  "n_core": (N,), "n_elastic": (N,), "runtime": (N,),
                  "cpu_req": (N, C), "mem_req": (N, C), "is_core": (N, C),
                  "levels": (N, C, SEGMENTS, 2),
                  "tenant": (N,), "slo": (N,)}
        for name, want in shapes.items():
            a = getattr(self, name)
            if not isinstance(a, np.ndarray):
                p.append(f"{name}: not an ndarray")
            elif a.shape != want:
                p.append(f"{name}: shape {a.shape}, want {want}")
        if p:
            raise TraceValidationError("; ".join(p))

        for name in ("submit", "runtime", "cpu_req", "mem_req", "levels"):
            if not np.isfinite(getattr(self, name)).all():
                p.append(f"{name}: non-finite values")
        if (np.diff(self.submit) < 0).any():
            p.append("submit: not nondecreasing (engine pops arrivals "
                     "in submission order)")
        if (self.submit < 0).any():
            p.append("submit: negative times")
        if (self.runtime <= 0).any():
            p.append("runtime: must be positive")

        exists = self.cpu_req > 0
        if ((self.mem_req > 0) != exists).any():
            p.append("cpu_req/mem_req disagree on which components exist")
        if (self.cpu_req < 0).any() or (self.mem_req < 0).any():
            p.append("negative reservations")
        if (self.is_core & ~exists).any():
            p.append("is_core set on absent components")
        if (self.is_core.sum(1) < 1).any():
            p.append("every app needs >= 1 core component (progress "
                     "requires a full core set)")
        if (self.n_core != self.is_core.sum(1)).any():
            p.append("n_core inconsistent with is_core")
        if (self.n_elastic != (exists & ~self.is_core).sum(1)).any():
            p.append("n_elastic inconsistent with existing non-core "
                     "components")
        if (self.n_elastic[~self.is_elastic] != 0).any():
            p.append("rigid apps must carry no elastic components")

        if (self.levels < 0).any() or (self.levels > 1).any():
            p.append("levels: outside [0, 1] (fraction of reservation)")
        if (self.levels[~exists] != 0).any():
            p.append("levels: nonzero for absent components")

        if (self.tenant < 0).any():
            p.append("tenant: negative tenant ids")
        if (self.slo < 0).any() or (self.slo >= len(SLO_CLASSES)).any():
            p.append(f"slo: outside [0, {len(SLO_CLASSES) - 1}] "
                     f"(indexes SLO_CLASSES)")

        if p:
            raise TraceValidationError("; ".join(p))
        return self


def sort_by_submit(submit: np.ndarray, **columns: np.ndarray) -> dict:
    """Stable-sort per-app columns by submission time.

    Generator families that interleave several arrival processes (e.g.
    flashcrowd's background + burst populations) build their columns in
    population order and call this to restore the engine's required
    arrival order.  Returns ``{"submit": sorted, **columns sorted}``.
    """
    order = np.argsort(submit, kind="stable")
    out = {"submit": submit[order]}
    for name, col in columns.items():
        out[name] = col[order]
    return out
