"""Streaming trace ingestion: production-scale replay in a bounded window.

The materialized engines upload the WHOLE trace and size every per-app
array — the :class:`~repro.sim.state.DeviceTrace` columns and the
``(N,)`` lifecycle mirrors in :class:`~repro.sim.state.SimState` — by
total task count.  At 10^5-10^6 tasks (ROADMAP item 2: full
Alibaba/Azure traces) that padding dwarfs the real working set: only the
*concurrent* apps matter to any tick.  This module inverts the
host-side replay-drain pattern for ingestion: the host keeps the full
trace, the device sees a fixed ``W``-row *window*, and at every chunk
boundary (where the scan driver already syncs ``st.done``) completed
rows are harvested to host accumulators, reclaimed, and re-keyed for
the next arrivals.

Correctness contract — streamed ≡ materialized, bit-identical:

* Every per-tick reduction over the app axis is integer/boolean/min
  arithmetic (one-hot masked sums, ``argmin``, ``all``), so the window
  size cannot perturb float accumulation; the ONLY order-sensitive op
  was the FIFO head ``argmin`` on ties, which now breaks ties on the
  global app id (``DeviceTrace.gid``) instead of the row index.
* Free rows carry an inert sentinel (``submit = +inf``, zero demand,
  ``arrived = done = True``) that every tick phase provably ignores.
* Arrivals stay exact: the host replays the f32 clock recurrence
  (`t += tick`, same IEEE-754 rounding as the device) to decide which
  apps fall due inside the next chunk, and *over*-loading is always
  safe — the device still gates arrival on ``submit <= t`` — so only a
  late load could diverge, and the replayed bound makes that
  impossible.
* While the stream has apps left, at least one loaded row stays
  un-arrived past the chunk horizon (the *prefetch invariant*), so
  ``active`` gating and the leap engine's ``next_sub`` see the true
  next arrival.
* In leap mode the per-chunk tick budget is additionally capped by the
  exact f32 tick count to the first UNLOADED arrival, so an idle skip
  can never jump past an app the device has not seen; the budget
  truncation machinery (PR 9) re-splits long skips across boundaries
  with bit-identical expanded histories.

Turnaround, tenancy, calibration and telemetry accounting survive
re-keying because none of it is keyed by window row: the slot monitor
buffers and conformal rings are slot-indexed, tenancy counters are
tenant-indexed, telemetry rings are drained every boundary, and the
final drain swaps the harvested global ``(N,)`` lifecycle back in
before :func:`~repro.sim.state.drain_results` runs.

``StreamConfig`` is itself a registered scenario ("stream") wrapping
any inner scenario config, so replay presets and synthetic families
alike can be streamed through ``run_grid(engine="scan"/"shard")``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.sim.scenarios.registry import build_trace, register

__all__ = ["StreamConfig", "StreamWindow", "auto_window",
           "run_sim_stream"]

# longest idle run (ticks) the host scouts past the loaded horizon per
# chunk in leap mode; longer gaps split across boundaries (bit-identical
# — see module docstring) at one chunk dispatch per _LEAP_SCOUT ticks
_LEAP_SCOUT = 16_384

# SimState lifecycle mirrors that are (N,)-per-app and therefore
# windowed; everything else in the state is slot-, tenant- or
# ring-indexed and survives re-keying untouched
_LIFE = ("arrived", "queued", "done", "failed", "finish_t",
         "saved_work", "has_saved")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Streaming wrapper around any registered scenario config.

    ``inner`` is the workload being streamed (a replay preset, a
    synthetic family, a fitted config — anything registered).  The
    builder materializes the inner trace on the HOST; the streaming is
    in the device/compiled footprint, which scales with ``window``
    (concurrency) instead of total tasks.  ``window = 0`` sizes the
    window automatically from the slot table; ``seed`` overrides the
    inner config's seed so the sweep's seed axis works unchanged.
    """

    inner: Any
    window: int = 0
    seed: int | None = None


@register("stream", StreamConfig,
          doc="streaming ingestion wrapper: any scenario in a bounded "
              "device window")
def _build(cfg: StreamConfig):
    inner = cfg.inner
    if cfg.seed is not None and hasattr(inner, "seed"):
        inner = dataclasses.replace(inner, seed=cfg.seed)
    return dataclasses.replace(build_trace(inner), cfg=cfg)


def auto_window(cfg, n_apps: int) -> int:
    """Power-of-two device window: 2x the slot table (queue + prefetch
    headroom over peak concurrency), floor 64, capped at the trace."""
    w = 64
    while w < 2 * cfg.cluster.max_running_apps:
        w *= 2
    return min(max(int(n_apps), 1), w)


def _f32_ticks(t0: float, tick: float, n: int) -> np.float32:
    """Clock value after ``n`` device ticks: the exact f32 recurrence
    (numpy and XLA both round IEEE-754 binary32 to nearest)."""
    t = np.float32(t0)
    tk = np.float32(tick)
    for _ in range(n):
        t = np.float32(t + tk)
    return t


def _ticks_below(t0: float, tick: float, h: float, limit: int) -> int:
    """Max ticks executable from ``t0`` with every tick's clock < ``h``
    under the exact f32 recurrence — the leap budget cap that keeps a
    skip from crossing an unloaded arrival."""
    t = np.float32(t0)
    tk = np.float32(tick)
    h32 = np.float32(h)
    k = 0
    while k < limit:
        nt = np.float32(t + tk)
        if not nt < h32:
            break
        t = nt
        k += 1
    return k


class StreamWindow:
    """Host-side manager of the bounded device window.

    Owns the full host trace, the ``row -> global app`` mapping, the
    free-row pool, and the harvested global lifecycle accumulators.
    ``refill`` runs at every chunk boundary; ``finalize`` swaps the
    global lifecycle back into the final state for the drain.
    """

    def __init__(self, wl, window: int):
        self.wl = wl
        self.N = int(wl.n_apps)
        self.C = int(wl.max_components)
        self.W = min(max(int(window), 1), max(self.N, 1))
        # full trace columns, final dtypes, host-resident
        self._sub = np.ascontiguousarray(wl.submit, np.float32)
        self._cols = dict(
            runtime=np.ascontiguousarray(wl.runtime, np.float32),
            cpu_req=np.ascontiguousarray(wl.cpu_req, np.float32),
            mem_req=np.ascontiguousarray(wl.mem_req, np.float32),
            is_core=np.ascontiguousarray(wl.is_core, bool),
            is_jumpy=np.ascontiguousarray(wl.is_jumpy, bool),
            levels=np.ascontiguousarray(wl.levels, np.float32),
            tenant=np.ascontiguousarray(wl.tenant, np.int32))
        self.next_load = 0
        self.row_app = np.full(self.W, -1, np.int64)
        self.done_g = np.zeros(self.N, bool)
        self.failed_g = np.zeros(self.N, bool)
        self.finish_g = np.zeros(self.N, np.float32)
        self.peak_rows = 0
        self.grows = 0
        self._alloc_window(self.W)

    # -- window column storage -----------------------------------------

    def _alloc_window(self, W: int) -> None:
        S2 = self._cols["levels"].shape[2:]          # (SEGMENTS, 2)
        self.w_submit = np.full(W, np.inf, np.float32)
        self.w_runtime = np.ones(W, np.float32)
        self.w_cpu = np.zeros((W, self.C), np.float32)
        self.w_mem = np.zeros((W, self.C), np.float32)
        self.w_core = np.zeros((W, self.C), bool)
        self.w_jumpy = np.zeros(W, bool)
        self.w_levels = np.zeros((W, self.C) + S2, np.float32)
        self.w_tenant = np.zeros(W, np.int32)
        self.w_gid = np.zeros(W, np.int32)

    def _grow(self, need_free: int) -> None:
        """Double the window until ``need_free`` rows are free (recorded
        as a grow event — the next chunk recompiles at the new W)."""
        old_w, occ = self.W, int((self.row_app >= 0).sum())
        target = occ + need_free        # <= N: occupied + unloaded apps
        W = self.W
        while W < target:
            W *= 2
        W = max(min(W, max(self.N, 1)), target)
        olds = (self.w_submit, self.w_runtime, self.w_cpu, self.w_mem,
                self.w_core, self.w_jumpy, self.w_levels, self.w_tenant,
                self.w_gid)
        old_map = self.row_app
        self._alloc_window(W)
        for old, new in zip(olds, (self.w_submit, self.w_runtime,
                                   self.w_cpu, self.w_mem, self.w_core,
                                   self.w_jumpy, self.w_levels,
                                   self.w_tenant, self.w_gid)):
            new[:old_w] = old
        self.row_app = np.full(W, -1, np.int64)
        self.row_app[:old_w] = old_map
        self.W = W
        self.grows += 1
        try:  # observability only; never load-bearing
            from repro.obs.metrics import REGISTRY
            REGISTRY.counter("stream.window_grow").inc()
            REGISTRY.gauge("stream.window_rows").set(W)
        except Exception:
            pass

    def _clear_rows(self, rows: np.ndarray) -> None:
        self.w_submit[rows] = np.inf
        self.w_runtime[rows] = 1.0
        self.w_cpu[rows] = 0.0
        self.w_mem[rows] = 0.0
        self.w_core[rows] = False
        self.w_jumpy[rows] = False
        self.w_levels[rows] = 0.0
        self.w_tenant[rows] = 0
        self.w_gid[rows] = 0

    def _set_rows(self, rows: np.ndarray, apps: np.ndarray) -> None:
        c = self._cols
        self.w_submit[rows] = self._sub[apps]
        self.w_runtime[rows] = c["runtime"][apps]
        self.w_cpu[rows] = c["cpu_req"][apps]
        self.w_mem[rows] = c["mem_req"][apps]
        self.w_core[rows] = c["is_core"][apps]
        self.w_jumpy[rows] = c["is_jumpy"][apps]
        self.w_levels[rows] = c["levels"][apps]
        self.w_tenant[rows] = c["tenant"][apps]
        self.w_gid[rows] = apps.astype(np.int32)

    # -- device views ---------------------------------------------------

    @property
    def exhausted(self) -> bool:
        return self.next_load >= self.N

    def device_trace(self):
        """Fresh window DeviceTrace (bypasses the upload cache — window
        contents change across boundaries)."""
        import jax.numpy as jnp

        from repro.sim.state import DeviceTrace
        return DeviceTrace(
            submit=jnp.asarray(self.w_submit),
            runtime=jnp.asarray(self.w_runtime),
            cpu_req=jnp.asarray(self.w_cpu),
            mem_req=jnp.asarray(self.w_mem),
            is_core=jnp.asarray(self.w_core),
            is_jumpy=jnp.asarray(self.w_jumpy),
            levels=jnp.asarray(self.w_levels),
            exists=jnp.asarray(self.w_cpu > 0),
            tenant=jnp.asarray(self.w_tenant),
            gid=jnp.asarray(self.w_gid))

    def seal_free(self, st):
        """Mark every unoccupied row with the inert sentinel lifecycle
        (``arrived = done = True``) on a fresh ``init_state``."""
        import jax.numpy as jnp
        free = self.row_app < 0
        return dataclasses.replace(
            st,
            arrived=jnp.asarray(np.asarray(st.arrived) | free),
            done=jnp.asarray(np.asarray(st.done) | free))

    # -- the chunk-boundary protocol ------------------------------------

    def refill(self, st, *, t0: float, tick: float, size: int,
               leap: bool, chunk: int):
        """Harvest, load, re-key.  Returns ``(st, changed, leap_cap)``:
        ``changed`` means the window columns moved (rebuild the device
        trace), ``leap_cap`` is the per-chunk tick-budget cap (``None``
        = uncapped: stream exhausted)."""
        done = np.asarray(st.done)

        # 1. harvest completed rows into the global accumulators
        harv = (self.row_app >= 0) & done[:self.W]
        freed = np.nonzero(harv)[0]
        if freed.size:
            g = self.row_app[freed]
            self.done_g[g] = True
            self.failed_g[g] = np.asarray(st.failed)[freed]
            self.finish_g[g] = np.asarray(st.finish_t)[freed]
            self.row_app[freed] = -1
            self._clear_rows(freed)

        # 2. apps due inside the chunk: exact f32 clock bound (uniform
        # chunks execute exactly `size` ticks; leap uses the nominal
        # horizon — the cap below owns correctness past it)
        t_end = float(_f32_ticks(t0, tick, size))
        beyond = int(np.searchsorted(self._sub, np.float32(t_end),
                                     side="right"))
        hi = max(beyond, self.next_load)

        # 3. prefetch invariant: keep one loaded row un-arrived PAST the
        # chunk horizon so `active` stays true and next_sub is the true
        # next arrival.  Loads are prefix-ordered, so apps in
        # [beyond, next_load) are loaded-beyond-horizon rows; only when
        # that range is empty does one extra app need loading.
        if hi < self.N and beyond >= self.next_load:
            hi += 1

        # 4. leap budget cap: exact tick count to the first UNLOADED
        # arrival; force-load apps that would cap the chunk below its
        # step count so progress is always >= min(budget, chunk) ticks
        cap = None
        if leap:
            while hi < self.N:
                cap = _ticks_below(t0, tick, float(self._sub[hi]),
                                   _LEAP_SCOUT)
                if cap >= chunk:
                    break
                hi += 1
                cap = None

        # 5. assign due apps to free rows (grow on overflow)
        to_load = np.arange(self.next_load, hi)
        if to_load.size:
            free_rows = np.nonzero(self.row_app < 0)[0]
            if to_load.size > free_rows.size:
                self._grow(to_load.size)
                free_rows = np.nonzero(self.row_app < 0)[0]
            rows = free_rows[:to_load.size]
            self._set_rows(rows, to_load)
            self.row_app[rows] = to_load
            self.next_load = hi

        self.peak_rows = max(self.peak_rows,
                             int((self.row_app >= 0).sum()))
        changed = bool(freed.size) or bool(to_load.size)
        if changed:
            st = self._push_lifecycle(st, freed, to_load)
        return st, changed, cap

    def _push_lifecycle(self, st, freed: np.ndarray,
                        loaded_apps: np.ndarray):
        """Re-key the (W,) lifecycle mirrors: freed rows get the inert
        sentinel, freshly loaded rows a virgin lifecycle; grown rows
        appear as sentinel free rows."""
        import jax.numpy as jnp
        life = {f: np.array(getattr(st, f)) for f in _LIFE}  # mutable copies
        W0 = life["done"].shape[0]
        if self.W > W0:                       # window grew this refill
            for f, v in life.items():
                pad = np.zeros(self.W - W0, v.dtype)
                if f in ("arrived", "done"):
                    pad[:] = True
                life[f] = np.concatenate([v, pad])
        sentinel = dict(arrived=True, queued=False, done=True,
                        failed=False, finish_t=0.0, saved_work=0.0,
                        has_saved=False)
        virgin = {**sentinel, "arrived": False, "done": False}
        if freed.size:
            for f, v in sentinel.items():
                life[f][freed] = v
        if loaded_apps.size:
            rows = np.nonzero(np.isin(self.row_app, loaded_apps))[0]
            for f, v in virgin.items():
                life[f][rows] = v
        return dataclasses.replace(
            st, **{f: jnp.asarray(v) for f, v in life.items()})

    # -- final drain ----------------------------------------------------

    def finalize(self, st):
        """Swap the harvested global ``(N,)`` lifecycle into the final
        state so :func:`~repro.sim.state.drain_results` (turnaround,
        failed set, tenancy summary) sees every app of the full trace."""
        import jax.numpy as jnp
        done = np.asarray(st.done)
        occ = self.row_app >= 0
        rows = np.nonzero(occ)[0]
        if rows.size:
            g = self.row_app[rows]
            self.done_g[g] = done[rows]
            self.failed_g[g] = np.asarray(st.failed)[rows]
            self.finish_g[g] = np.asarray(st.finish_t)[rows]
        return dataclasses.replace(
            st, done=jnp.asarray(self.done_g),
            failed=jnp.asarray(self.failed_g),
            finish_t=jnp.asarray(self.finish_g))

    def stats(self) -> dict:
        return {"window_rows": int(self.W),
                "peak_rows": int(self.peak_rows),
                "grows": int(self.grows),
                "n_apps": int(self.N),
                "loaded": int(self.next_load)}


def run_sim_stream(cfg, wl=None, *, chunk: int = 32, window: int = 0,
                   stats: dict | None = None):
    """Run one simulation with streamed ingestion on the scan engine.

    Bit-identical to ``run_sim_scan`` on the materialized trace (the
    correctness anchor of tests/test_replay_scale.py); the device and
    compiled-program footprint scales with the window (peak concurrency)
    instead of total tasks.  ``stats`` (optional dict) receives window
    telemetry: peak occupied rows, grow events, final window size.
    """
    import jax.numpy as jnp

    from repro.sim.state import drain_results, init_state
    from repro.sim.step import (_bucketed, _chunk_fn, _concat_metrics,
                                _pick_bucket, _ring_drain)

    if wl is None:
        wl = build_trace(cfg.workload)
    if not window and isinstance(cfg.workload, StreamConfig):
        window = cfg.workload.window
    win = StreamWindow(wl, window or auto_window(cfg, wl.n_apps))
    tick = float(cfg.cluster.tick)
    st = win.seal_free(init_state(cfg, win.W, wl.max_components))
    drain = _ring_drain(cfg, chunk, st)
    bucketing = _bucketed(cfg)
    parts: list = []
    tr = None

    def fn_for(size, bucket):
        # same shapes key a materialized W-app trace would produce, so
        # streamed and materialized runs of equal geometry share one
        # compiled program
        shapes = (win.W, win.C, cfg.cluster.max_running_apps, cfg.window)
        return _chunk_fn(cfg, size, shapes, False, bucket)

    if not cfg.leap:
        remaining = cfg.max_ticks
        while remaining > 0:
            size = min(chunk, remaining)
            t0 = float(np.asarray(st.t))
            st, changed, _ = win.refill(st, t0=t0, tick=tick, size=size,
                                        leap=False, chunk=chunk)
            if changed or tr is None:
                tr = win.device_trace()
            fn = fn_for(size, _pick_bucket(cfg, st) if bucketing else None)
            st, ms = fn(tr, st)
            parts.append(ms)
            remaining -= size
            if drain is not None:
                drain.drain(st.obs)
            if win.exhausted and bool(np.asarray(st.done).all()):
                break
    else:
        left_budget = cfg.max_ticks
        while left_budget > 0:
            t0 = float(np.asarray(st.t))
            st, changed, cap = win.refill(st, t0=t0, tick=tick,
                                          size=chunk, leap=True,
                                          chunk=chunk)
            if changed or tr is None:
                tr = win.device_trace()
            left = left_budget if cap is None else min(left_budget, cap)
            fn = fn_for(chunk, _pick_bucket(cfg, st) if bucketing else None)
            st, left_out, ms = fn(tr, st, jnp.asarray(np.int32(left)))
            parts.append(ms)
            left_budget -= left - int(np.asarray(left_out))
            if drain is not None:
                drain.drain(st.obs)
            if win.exhausted and bool(np.asarray(st.done).all()):
                break
    st = win.finalize(st)
    if stats is not None:
        stats.update(win.stats())
    return drain_results(cfg, wl, st, _concat_metrics(parts),
                         obs=drain.history(0) if drain is not None
                         else None)
