"""Sharded sweep fleets: grid cells x seed cohorts on a device mesh.

``run_grid(engine="scan")`` retired the per-SEED host loop — each
combo's seed cohort runs as one vmapped device program — but kept a
per-COMBO Python loop on the host, and the whole sweep still executes
on a single device.  This module retires that last host-side
orchestration for homogeneous grids: sweep cells that share every
config knob except their WORKLOAD (seed and/or scenario — trace data,
not compiled structure) are grouped into *fleets*, each fleet's stacked
cohort axis is padded up to the mesh size and laid across the devices
with ``shard_map`` (:func:`repro.sim.step.run_fleet_shard`), and the
whole fleet advances as ONE SPMD program with host sync only at chunk
boundaries.

There are no collectives — sims never communicate — so the mesh is pure
capacity: per-cell results are bit-identical to the scan engine
(``shard(mesh=1) == scan``, and any mesh re-slices the fleet axis
without changing a member's numerics; enforced by
``tests/test_shard.py``).  On CPU the mesh is built from forced host
devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.sim.sweep --engine shard --mesh 8

Cells whose static config is unique in the grid (singleton fleets)
fall back to solo scan runs — a one-member SPMD program would only pay
mesh-placement overhead for nothing.

The leap engine (``SimConfig.leap``) and ragged forecast bucketing
(``SimConfig.forecast_bucket``) compose with sharding for free: both
are plain config fields, so they participate in fleet grouping like
any other static knob (cells may only share a program when they agree
on them), and the per-chunk bucket choice is made once per fleet from
the gathered host snapshot — every mesh slice runs the same bucket
program.  ``shard(mesh=k) == scan`` holds under both flags.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np

from repro.obs import span
from repro.sim.step import FLEET_AXIS, run_fleet_shard, run_sim_scan

__all__ = ["fleet_mesh", "device_count", "group_fleets",
           "run_shard_records", "FLEET_AXIS"]


def device_count() -> int:
    """Visible device count (CPU: 1 unless forced host devices)."""
    return jax.device_count()


def fleet_mesh(n: int | None = None):
    """1-D mesh over the first ``n`` (default: all) visible devices."""
    from jax.sharding import Mesh
    devs = jax.devices()
    n = len(devs) if n is None else int(n)
    if not 1 <= n <= len(devs):
        raise ValueError(f"fleet_mesh({n}): {len(devs)} devices visible "
                         "(on CPU, set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    return Mesh(np.array(devs[:n]), (FLEET_AXIS,))


def _strip_workload(cfg, ref):
    """``cfg`` with its workload replaced by ``ref``'s — equality of the
    stripped configs is exactly 'may share one SPMD program'."""
    import dataclasses
    return dataclasses.replace(cfg, workload=ref.workload)


def group_fleets(cells: Sequence, workloads: dict) -> list[list]:
    """Group sweep cells into fleets: members agree on every config
    field except ``workload`` AND on the padded trace shape (a fleet is
    one compiled program; shapes are static).  Order-stable: fleets
    appear in first-member grid order, members in grid order."""
    ref = cells[0].cfg
    groups: dict = {}
    for cell in cells:
        wl = workloads[cell.cfg.workload]
        key = (_strip_workload(cell.cfg, ref),
               int(wl.n_apps), int(wl.max_components))
        groups.setdefault(key, []).append(cell)
    return list(groups.values())


def run_shard_records(grid: Sequence, workloads: dict, record, *,
                      chunk: int = 32, mesh: int | None = None,
                      log=None) -> list[dict]:
    """Shard-engine sweep driver (called by ``run_grid``).

    ``record(cell, results, wall_s)`` builds the per-cell record dict;
    per-cell wall time is the fleet wall divided by its member count.
    ``log`` (optional callable) receives one line per fleet.
    """
    import time
    recs: dict[int, dict] = {}
    fleets = group_fleets(grid, workloads)
    for fleet in fleets:
        base_cfg = fleet[0].cfg
        t0 = time.time()
        with span(f"fleet:{fleet[0].name}", cat="fleet",
                  args={"members": len(fleet)}):
            if len(fleet) == 1:
                # singleton static config: solo scan run (see module doc)
                results = [run_sim_scan(base_cfg,
                                        workloads[base_cfg.workload],
                                        chunk=chunk)]
            else:
                results = run_fleet_shard(
                    base_cfg, cfgs=[c.cfg for c in fleet],
                    wls=[workloads[c.cfg.workload] for c in fleet],
                    chunk=chunk, mesh=mesh)
        wall = (time.time() - t0) / len(fleet)
        if log is not None:
            log(f"fleet[{len(fleet)} cells] {fleet[0].name} "
                f"(+{len(fleet) - 1} more): {wall * len(fleet):.2f}s")
        for cell, res in zip(fleet, results):
            recs[id(cell)] = record(cell, res, wall)
    return [recs[id(cell)] for cell in grid]
