"""Device-resident simulation state (the scan engine's slot table).

The host-loop engines (``repro.sim.engine`` / ``engine_ref``) keep
cluster state in NumPy and pay a device round-trip per tick — every
``ShapeProblem`` field is a fresh ``device_put``, every forecast window
a host->device copy.  This module holds the SAME padded slot table as a
pytree of ``jnp`` arrays so the fused per-tick step (``repro.sim.step``)
can run whole tick *chunks* on device with host sync only at chunk
boundaries:

  * :class:`DeviceTrace` — the immutable workload columns (arrival
    times, reservations, utilization profiles), uploaded once per run;
  * :class:`SimState`    — everything that evolves per tick: the cluster
    slot table, monitor rings, FIFO-queue membership, per-app telemetry
    and (optionally) the conformal-calibration rings
    (:class:`~repro.core.uncertainty.online.CalibState`);
  * :class:`TickMetrics` — the per-tick scan outputs (``lax.scan`` ys)
    drained to the host at chunk boundaries;
  * :func:`drain_results` — folds final state + stacked metrics back
    into the engines' :class:`~repro.sim.metrics.SimResults`.

Both dataclasses are registered pytrees, so a whole seed cohort is just
``vmap`` over a stacked state (every array gains a leading seed axis and
one batched device program executes the cohort).  The sharded fleet
executor (``repro.sim.step.run_fleet_shard`` / ``repro.sim.shard``)
reuses the same stacked layout: the leading cohort axis becomes the
``shard_map`` mesh axis, padded up to a multiple of the mesh size
(:func:`round_up` / ``from_traces(..., pad_to=...)``) so every device
holds an equal slice.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import TenantState, control_init, tenancy_summary
from repro.core.uncertainty.online import (CalibState, calib_group_report,
                                           calib_init, calib_report)
from repro.obs.rings import ObsState, obs_init
from repro.sim.metrics import SimResults

Array = jax.Array

CPU, MEM = 0, 1


def round_up(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``n`` (mesh padding:
    a sharded fleet axis must divide evenly across the mesh devices)."""
    return -(-n // multiple) * multiple


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceTrace:
    """Immutable workload columns on device (one upload per run).

    Mirrors :class:`~repro.sim.scenarios.schema.Trace`; ``exists`` is
    precomputed (``cpu_req > 0``) because every tick needs it.
    """

    submit: Array     # (N,) f32 nondecreasing arrival times
    runtime: Array    # (N,) f32 base runtime
    cpu_req: Array    # (N, C) f32 per-component reservation
    mem_req: Array    # (N, C) f32
    is_core: Array    # (N, C) bool
    is_jumpy: Array   # (N,) bool — step-change (unlearnable) profiles
    levels: Array     # (N, C, SEGMENTS, 2) f32 utilization knots
    exists: Array     # (N, C) bool == cpu_req > 0
    tenant: Array     # (N,) i32 owning tenant (all zero when untagged)
    gid: Array        # (N,) i32 global app id — row index for a fully
    #                   materialized trace; the streamed engine re-keys
    #                   window rows so gid keeps the submission-order
    #                   identity a row had in the full trace

    @classmethod
    def from_trace(cls, wl) -> "DeviceTrace":
        n = len(np.asarray(wl.submit))
        return cls(
            submit=jnp.asarray(wl.submit, jnp.float32),
            runtime=jnp.asarray(wl.runtime, jnp.float32),
            cpu_req=jnp.asarray(wl.cpu_req, jnp.float32),
            mem_req=jnp.asarray(wl.mem_req, jnp.float32),
            is_core=jnp.asarray(wl.is_core, bool),
            is_jumpy=jnp.asarray(wl.is_jumpy, bool),
            levels=jnp.asarray(wl.levels, jnp.float32),
            exists=jnp.asarray(wl.cpu_req > 0, bool),
            tenant=jnp.asarray(wl.tenant, jnp.int32),
            gid=jnp.arange(n, dtype=jnp.int32))

    @classmethod
    def from_traces(cls, wls, pad_to: int | None = None) -> "DeviceTrace":
        """Stacked cohort trace, (S, ...) per field — stacked on the
        host in one pass (one upload per field, not one per seed).

        ``pad_to`` rounds the cohort axis up by repeating the LAST trace
        (sharded fleets need the axis divisible by the mesh size; the
        padding rows simulate a real workload whose results the driver
        simply discards, so no phase needs a validity mask)."""
        wls = list(wls)
        if pad_to is not None:
            if pad_to < len(wls):
                raise ValueError(f"pad_to={pad_to} < cohort size {len(wls)}")
            wls = wls + [wls[-1]] * (pad_to - len(wls))
        col = lambda f, dt: jnp.asarray(  # noqa: E731
            np.stack([np.asarray(f(w), dt) for w in wls]))
        return cls(
            submit=col(lambda w: w.submit, np.float32),
            runtime=col(lambda w: w.runtime, np.float32),
            cpu_req=col(lambda w: w.cpu_req, np.float32),
            mem_req=col(lambda w: w.mem_req, np.float32),
            is_core=col(lambda w: w.is_core, bool),
            is_jumpy=col(lambda w: w.is_jumpy, bool),
            levels=col(lambda w: w.levels, np.float32),
            exists=col(lambda w: w.cpu_req > 0, bool),
            tenant=col(lambda w: w.tenant, np.int32),
            gid=col(lambda w: np.arange(len(np.asarray(w.submit))),
                    np.int32))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimState:
    """Everything that evolves per tick, as one pytree of device arrays.

    A = slot-table apps, C = components, N = trace apps, W = monitor
    window.  Monitor rows are flat ``slot * C + comp`` exactly like the
    host :class:`~repro.core.monitor.Monitor`, so forecast-batch row ids
    (CPU rows then MEM rows) are identical across engines.
    """

    # cluster slot table
    slot_gid: Array       # (A,) i32, -1 = empty
    work_done: Array      # (A,) f32
    comp_running: Array   # (A, C) bool
    comp_host: Array      # (A, C) i32
    alloc: Array          # (A, C, 2) f32
    alive_since: Array    # (A, C) f32
    # monitor rings
    mon_buf: Array        # (A*C, W, 2) f32, oldest first
    mon_count: Array      # (A*C,) i32 samples seen per row
    # application lifecycle (FIFO queue is the `queued` mask: order is
    # derived, (submit0, gid) ascending — exactly bisect.insort's key)
    arrived: Array        # (N,) bool
    queued: Array         # (N,) bool
    done: Array           # (N,) bool
    failed: Array         # (N,) bool — ever OOM/conflict-failed
    finish_t: Array       # (N,) f32 completion time (0 until done)
    saved_work: Array     # (N,) f32 checkpointed progress
    has_saved: Array      # (N,) bool
    # counters / clock
    t: Array              # () f32 sim time (exact multiple of tick)
    failure_events: Array      # () i32
    oom_kills: Array           # () i32
    full_preemptions: Array    # () i32
    partial_preemptions: Array # () i32
    # conformal calibration rings (None when calibration is off — the
    # step function is specialized per config, so presence is static)
    calib: CalibState | None
    # tenant accounting (None when the control plane is off — same
    # static-presence convention, so tenancy-off programs are
    # structurally identical to pre-control-plane ones)
    tenancy: TenantState | None
    # per-tick telemetry rings (None when observability is off — same
    # static-presence convention again: obs-off programs are
    # bit-identical to pre-observability engines)
    obs: ObsState | None


def init_state(cfg, n_apps: int, max_components: int,
               batch: int | None = None) -> SimState:
    """Fresh device state for one simulation of ``cfg``.

    ``batch`` prepends a seed-cohort axis to every field (a fresh state
    is identical across seeds, so the stacked cohort state is built
    directly — no per-seed init + stack round trips)."""
    A = cfg.cluster.max_running_apps
    C = max_components
    N = n_apps
    W = cfg.window
    B = () if batch is None else (batch,)
    zi = lambda *s: jnp.zeros(B + s, jnp.int32)    # noqa: E731
    zf = lambda *s: jnp.zeros(B + s, jnp.float32)  # noqa: E731
    zb = lambda *s: jnp.zeros(B + s, bool)         # noqa: E731
    tenancy = None
    if cfg.control.enabled:
        tenancy = control_init(cfg.control, batch=batch)
    calib = None
    if cfg.calibration.enabled and cfg.forecaster != "oracle":
        calib = calib_init(2 * A * C, cfg.calibration, batch=batch,
                           n_groups=(cfg.control.max_tenants
                                     if cfg.control.enabled else 0))
    obs = (obs_init(cfg.obs, batch=batch, leap=cfg.leap)
           if cfg.obs.enabled else None)
    return SimState(
        slot_gid=jnp.full(B + (A,), -1, jnp.int32),
        work_done=zf(A), comp_running=zb(A, C), comp_host=zi(A, C),
        alloc=zf(A, C, 2), alive_since=zf(A, C),
        mon_buf=zf(A * C, W, 2), mon_count=zi(A * C),
        arrived=zb(N), queued=zb(N), done=zb(N), failed=zb(N),
        finish_t=zf(N), saved_work=zf(N), has_saved=zb(N),
        t=zf(),
        failure_events=zi(), oom_kills=zi(), full_preemptions=zi(),
        partial_preemptions=zi(), calib=calib, tenancy=tenancy, obs=obs)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TickMetrics:
    """Per-tick scan outputs; ``valid`` masks post-completion padding
    ticks (the step body is a no-op once every app is done, so chunk
    size cannot change results — only when telemetry is drained).

    Raw usage/allocation SUMS, not ratios: utilization and slack divide
    on the host at drain time.  XLA is free to rewrite a division by a
    loop-invariant constant (e.g. into a reciprocal multiply) depending
    on how the scan unrolls, which would make the last ulp of a ratio
    depend on the chunk size — the sums themselves are chunk-stable."""

    valid: Array       # () bool — this tick actually executed
    n_running: Array   # () i32
    used_cpu: Array    # () f32 cluster-total instantaneous usage
    used_mem: Array    # () f32
    alloc_cpu: Array   # () f32 cluster-total committed allocation
    alloc_mem: Array   # () f32
    # forecast-load telemetry: rows past the grace period this tick (the
    # rows a compacting forecaster would NEED; the full-batch scan path
    # computes the whole padded batch, so ready/batch is the masked-rows
    # overhead the bucketed path exists to close)
    forecast_rows: Array  # () i32
    # rows the forecast MODEL actually computed this tick: the full
    # padded batch when it ran un-bucketed, passes x bucket batch under
    # ragged bucketing, 0 for persist/oracle (no model call)
    forecast_rows_done: Array  # () i32
    # event-leap telemetry: provably-idle ticks the leap engine skipped
    # immediately BEFORE this step's tick (always 0 under the uniform
    # engine).  drain_results re-expands each step into `lead` all-zero
    # ticks followed by the executed tick, so leap histories are
    # bit-identical to uniform ones.
    lead: Array        # () i32


def drain_results(cfg, wl, state: SimState, metrics: TickMetrics,
                  obs: dict | None = None) -> SimResults:
    """Fold final device state + stacked per-tick metrics (leading axis
    = ticks, already concatenated across chunks) into ``SimResults``.

    ``obs`` is one member's drained ring history (``field -> (T,)``)
    from :class:`repro.obs.rings.RingDrain` — attached verbatim to
    ``SimResults.obs`` (and, like ``forecast_rows``, excluded from
    ``summary()`` so telemetry can never perturb equivalence checks)."""
    res = SimResults(n_apps=int(wl.n_apps))
    valid = np.asarray(metrics.valid)
    # Re-expand leap steps into per-tick histories: each step stands for
    # `lead` skipped idle ticks (all-zero telemetry by the leap guard —
    # empty cluster, empty queue, quiescent calibration) followed by one
    # executed tick when `valid`.  Under the uniform engine lead == 0
    # everywhere and this reduces to plain valid-masking, so the two
    # modes produce bit-identical results.
    lead = np.asarray(metrics.lead, np.int64)
    reps = lead + valid.astype(np.int64)
    pos = np.cumsum(reps) - 1
    T = int(reps.sum())

    def expand(x):
        x = np.asarray(x)
        out = np.zeros(T, x.dtype)
        out[pos[valid]] = x[valid]
        return out

    res.n_running = [int(v) for v in expand(metrics.n_running)]
    H = cfg.cluster.n_hosts
    cap_cpu = np.float32(H) * np.float32(cfg.cluster.host_cpu)
    cap_mem = np.float32(H) * np.float32(cfg.cluster.host_mem)
    used_c = expand(metrics.used_cpu)
    used_m = expand(metrics.used_mem)
    alloc_c = expand(metrics.alloc_cpu)
    alloc_m = expand(metrics.alloc_mem)
    res.util_cpu = list(used_c / cap_cpu)
    res.util_mem = list(used_m / cap_mem)
    res.slack_cpu = [float((a - u) / a) if a > 0 else 0.0
                     for a, u in zip(alloc_c, used_c)]
    res.slack_mem = [float((a - u) / a) if a > 0 else 0.0
                     for a, u in zip(alloc_m, used_m)]

    done = np.asarray(state.done)
    # float32 subtraction: the host engines compute `t - submit` in
    # float32 (NEP 50 scalar promotion), and turnaround should not
    # depend on which engine produced it
    finish = np.asarray(state.finish_t, np.float32)
    submit0 = np.asarray(wl.submit, np.float32)
    for gid in np.nonzero(done)[0]:
        res.turnaround[int(gid)] = float(finish[gid] - submit0[gid])
    res.failed_apps = {int(g) for g in np.nonzero(np.asarray(state.failed))[0]}
    # forecast-load telemetry (scan-engine only; see TickMetrics): how
    # many rows were ready vs the full padded batch the program computes
    if cfg.policy != "baseline" and cfg.forecaster != "oracle":
        rows = expand(metrics.forecast_rows)
        AC = state.mon_count.shape[-1]
        res.forecast_rows = {
            "rows_ready": int(rows.sum()),
            "rows_batch": 2 * AC,
            "rows_bucketed": int(expand(metrics.forecast_rows_done).sum()),
            "ticks_forecasting": int((rows > 0).sum()),
            "ticks": T,
        }
    if obs is not None:
        res.obs = obs
    res.failure_events = int(state.failure_events)
    res.oom_kills = int(state.oom_kills)
    res.full_preemptions = int(state.full_preemptions)
    res.partial_preemptions = int(state.partial_preemptions)
    if state.calib is not None:
        res.calibration = calib_report(state.calib, cfg.calibration)
        gb = calib_group_report(state.calib, cfg.calibration)
        if gb is not None:
            res.calibration["groups"] = gb
    if state.tenancy is not None:
        ten = state.tenancy
        res.tenancy = tenancy_summary(
            cfg.control, wl, res.turnaround, res.failed_apps,
            dict(credit=np.asarray(ten.credit),
                 admitted=np.asarray(ten.admitted),
                 throttled=np.asarray(ten.throttled),
                 completed=np.asarray(ten.completed),
                 failed=np.asarray(ten.failed),
                 share_sum=np.asarray(ten.share_sum),
                 active_ticks=np.asarray(ten.active_ticks)))
    res.finalize(float(state.t))
    return res
