"""Fused device-resident tick: the scan engine (paper §4 at fleet scale).

One tick of the simulation — progress -> monitor sample -> forecast ->
safeguard / conformal scale -> shaping policy (Algorithm 1) -> OS OOM ->
FIFO admission — as ONE traced function over the device state pytree
(:mod:`repro.sim.state`), driven by ``lax.scan`` over tick *chunks*.
The host-loop engines pay per tick: ~10 jitted dispatches, a dozen
``device_put`` s for the ``ShapeProblem``, and NumPy re-marshalling of
the slot table.  Here a whole chunk of ticks is one XLA call; the host
syncs only at chunk boundaries (metrics drain + termination check).

Semantics follow ``repro.sim.engine`` phase for phase.  Two deliberate
deviations mean the scan engine is not bit-identical to the host
engines: floating-point *accumulation order* (NumPy pairwise /
sequential sums vs XLA reductions), and the Algorithm-1 FIFO order on
EXACTLY tied submit times (the host engines' ``np.argsort`` is
unstable; here ``jnp.argsort`` is stable, breaking ties by slot index
— relevant only to replay traces with identical timestamps, since
generated arrival processes are tie-free).  The correctness anchors
are instead:

  * CHUNK INVARIANCE — results are independent of ``chunk`` by
    construction: everything that affects dynamics lives inside the
    step; ticks after global completion are no-ops (``active`` gating),
    so chunk=1 and chunk=32 are bit-identical;
  * COHORT EQUIVALENCE — a ``vmap`` over the seed axis executes a whole
    seed cohort as one batched program, bit-identical per seed to its
    solo run (XLA CPU reductions are batch-invariant; enforced by
    ``tests/test_scan_engine.py``);
  * the host ``engine`` <-> frozen ``engine_ref`` bit-equivalence
    remains separately enforced (``tests/test_sweep.py``).

Event-driven inner loops (admission, elastic re-placement, OOM victim
selection) are ``lax.while_loop`` s whose trip counts equal the number
of actual events — not O(slots x components) per tick.

Fleet scale: :func:`run_fleet_shard` lays the stacked cohort axis
across a JAX device mesh with ``shard_map`` (one SPMD program, no
collectives — sims are independent), adding a third anchor on top of
the two above: ``shard(mesh=1)`` is bit-identical to the cohort scan,
and any larger mesh is bit-identical per member to ``mesh=1``.  The
sweep-level executor that groups grid cells into fleets lives in
:mod:`repro.sim.shard`.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.control.credit import credit_quantile, credit_step
from repro.control.device import credit_mean, device_weights
from repro.control.fairness import dominant_shares, gate_mask
from repro.core.forecast.base import peak_over_horizon, persistence_peak
from repro.core.shaper import RAW_POLICIES, ShapeProblem
from repro.core.shaper.safeguard import (shaped_demand_raw,
                                         shaped_demand_scaled_raw)
from repro.core.uncertainty.online import (calib_begin, calib_observe,
                                           calib_scales)
from repro.obs import REGISTRY, span
from repro.obs.rings import RING_FIELDS, RingDrain, obs_record
from repro.sim.metrics import SimResults
from repro.sim.state import (CPU, MEM, DeviceTrace, SimState, TickMetrics,
                             drain_results, init_state, round_up)

Array = jax.Array

__all__ = ["fused_tick", "fused_leap", "run_sim_scan", "run_cohort_scan",
           "run_fleet_shard", "FLEET_AXIS"]

# mesh axis name for sharded fleets (repro.sim.shard lays grid cells x
# seed cohorts along this axis)
FLEET_AXIS = "fleet"

SEGMENTS_AXIS = 2  # levels layout (N, C, SEGMENTS, 2)


# ----------------------------------------------------------------------
# small pure helpers over the slot table
# ----------------------------------------------------------------------

def _progress_rate(tr: DeviceTrace, st: SimState) -> Array:
    """(A,) work/second: (1 + running elastic) / (1 + n_elastic) when the
    full core set runs, 0 otherwise (mirrors ``Cluster.progress_rate``)."""
    run = st.slot_gid >= 0
    gid = jnp.maximum(st.slot_gid, 0)
    is_core = tr.is_core[gid]
    exists = tr.exists[gid]
    core_ok = ((is_core & st.comp_running).sum(1) == is_core.sum(1))
    n_el = (exists & ~is_core).sum(1)
    n_run_el = (st.comp_running & ~is_core).sum(1)
    rate = core_ok * (1.0 + n_run_el) / (1.0 + n_el)
    return jnp.where(run, rate, 0.0).astype(jnp.float32)


def _usage_at(tr: DeviceTrace, st: SimState, prog: Array) -> Array:
    """(A, C, 2) usage of running components at per-slot progress
    ``prog`` (mirrors ``Trace.usage`` + ``Cluster.usage_now``)."""
    S = tr.levels.shape[SEGMENTS_AXIS]
    C = tr.levels.shape[1]
    gid = jnp.maximum(st.slot_gid, 0)
    x = jnp.clip(prog, 0.0, 1.0) * (S - 1)
    s0 = jnp.minimum(x.astype(jnp.int32), S - 2)
    frac = (x - s0).astype(jnp.float32)
    # single fused gather of the two knots actually needed — NOT
    # levels[gid] (which would materialize the full (A, C, S, 2) table
    # every tick, ~10x the bytes of the result)
    comps = jnp.arange(C)[None, :]
    lv0 = tr.levels[gid[:, None], comps, s0[:, None]]      # (A, C, 2)
    lv1 = tr.levels[gid[:, None], comps, s0[:, None] + 1]
    out = lv0 + (lv1 - lv0) * frac[:, None, None]
    out = jnp.where(tr.is_jumpy[gid][:, None, None], lv0, out)
    req = jnp.stack([tr.cpu_req[gid], tr.mem_req[gid]], axis=-1)
    run = (st.slot_gid >= 0)[:, None] & st.comp_running
    return out * req * run[:, :, None]


def _free_resources(st: SimState, host_cap: Array) -> Array:
    """(H, 2) capacity minus committed allocations.

    Broadcast masked sum, not a scatter-add — this runs inside the
    admission while_loop and XLA CPU scatters stay serial under vmap."""
    H = host_cap.shape[0]
    live = st.comp_running.reshape(-1)
    host = st.comp_host.reshape(-1)
    mask = live[:, None] & (host[:, None] == jnp.arange(H)[None, :])
    used = jnp.where(mask[:, :, None],
                     st.alloc.reshape(-1, 2)[:, None, :], 0.0).sum(0)
    return host_cap - used


def _mon_reset(st: SimState, rows_mask: Array) -> SimState:
    """Zero monitor rings for flat rows where ``rows_mask``.

    Called ONCE per tick with the union of every phase's resets
    (completion, preemption, OOM, admission): within a tick the rings
    are only read in the shaping phase, and every resetting event makes
    the affected rows non-running there — so deferring the writes to the
    end of the tick is observation-equivalent and saves three full
    ring-buffer passes per tick."""
    buf = jnp.where(rows_mask[:, None, None], 0.0, st.mon_buf)
    cnt = jnp.where(rows_mask, 0, st.mon_count)
    return dataclasses.replace(st, mon_buf=buf, mon_count=cnt)


def _evict_slots(st: SimState, slots_mask: Array) -> SimState:
    """Batched ``Cluster.evict_apps`` over a boolean slot mask."""
    m = slots_mask
    return dataclasses.replace(
        st,
        slot_gid=jnp.where(m, -1, st.slot_gid),
        comp_running=st.comp_running & ~m[:, None],
        alloc=jnp.where(m[:, None, None], 0.0, st.alloc),
        work_done=jnp.where(m, 0.0, st.work_done))


def _tenant_counts(tenant: Array, mask: Array, T: int) -> Array:
    """(T,) i32 count of masked apps per tenant (one-hot reduction —
    the control plane's scatter-free ``np.add.at``)."""
    return ((tenant[:, None] == jnp.arange(T)[None, :])
            & mask[:, None]).sum(0).astype(jnp.int32)


def _worst_fit(free: Array, cpu: Array, mem: Array) -> tuple[Array, Array]:
    """Most-free-memory host among those fitting (cpu, mem); returns
    (host, fits) — host is garbage when nothing fits."""
    ok = (free[:, CPU] >= cpu) & (free[:, MEM] >= mem)
    h = jnp.argmax(jnp.where(ok, free[:, MEM], -jnp.inf))
    return h, ok.any()


# ----------------------------------------------------------------------
# tick phases
# ----------------------------------------------------------------------

def _completions(tr: DeviceTrace, st: SimState, t: Array,
                 tick: float) -> tuple[SimState, Array]:
    """Progress all slots one tick; evict finished apps.  Returns the
    monitor rows to reset (applied once at end of tick)."""
    C = st.comp_running.shape[1]
    N = tr.submit.shape[0]
    rate = _progress_rate(tr, st)
    work = st.work_done + rate * tick
    st = dataclasses.replace(st, work_done=work)
    run = st.slot_gid >= 0
    gid = jnp.maximum(st.slot_gid, 0)
    fin = run & (work >= tr.runtime[gid])
    # slot -> app scatter as a one-hot mask (vmap-friendly; each app
    # occupies at most one slot, so the reduction has one nonzero)
    fin_app = ((jnp.arange(N)[None, :] == gid[:, None])
               & fin[:, None]).any(0)
    done = st.done | fin_app
    finish_t = jnp.where(fin_app, jnp.maximum(st.finish_t, t), st.finish_t)
    st = _evict_slots(st, fin)
    return (dataclasses.replace(st, done=done, finish_t=finish_t),
            jnp.repeat(fin, C))


def _record_monitor(st: SimState, usage: Array) -> SimState:
    """Append one sample per running component (flat-row ring update)."""
    AC = st.mon_buf.shape[0]
    run = (st.slot_gid >= 0)[:, None] & st.comp_running
    m = run.reshape(AC)
    new = usage.reshape(AC, 2)
    shifted = jnp.concatenate([st.mon_buf[:, 1:], new[:, None, :]], axis=1)
    buf = jnp.where(m[:, None, None], shifted, st.mon_buf)
    cnt = st.mon_count + m
    return dataclasses.replace(st, mon_buf=buf, mon_count=cnt)


def _oracle_peaks(tr: DeviceTrace, st: SimState, horizon: int,
                  tick: float) -> Array:
    """(A, C, 2) true future peak usage over the horizon (variance 0)."""
    rate = _progress_rate(tr, st)
    gid = jnp.maximum(st.slot_gid, 0)
    peaks = jnp.zeros_like(st.alloc)
    for k in range(1, horizon + 1):
        prog = jnp.clip((st.work_done + rate * tick * k) / tr.runtime[gid],
                        0.0, 1.0)
        peaks = jnp.maximum(peaks, _usage_at(tr, st, prog))
    return peaks


def _bucketed_forecast(cfg, model, wins: Array, valid: Array,
                       ready: Array, bucket: int):
    """gp/arima forecast over the READY monitor rows only, in
    power-of-two buckets of ``bucket`` rows per resource (the model
    batch is ``2 * bucket``: CPU rows stacked over MEM rows, exactly
    like the full-batch path).

    Static shapes under jit forbid true compaction, so the ready rows
    are compacted by a stable argsort and consumed in
    ``ceil(n_ready / bucket)`` gather -> model -> scatter-back passes of
    one ``lax.while_loop`` — zero passes on idle ticks, and within-chunk
    ready growth past the driver's chunk-boundary bucket choice is
    absorbed by extra passes, never wrong results.  Per-row model
    independence (the property ``engine.forecast_peaks`` documents as
    "bit-identical across bucket sizes") makes every ready row's
    (mean, var) bit-identical to the full-batch path; non-ready rows
    come back 0, which downstream masking (``ready2`` in
    ``_shaped_demands``, ``deploy`` in ``calib_begin``) never reads.
    The scatter-back is a one-hot matmul, not ``.at[].set`` — XLA CPU
    scatters serialize under the cohort vmap.

    Returns (mean, var, n_pass), each over the full ``(2 * AC,)`` row
    space; ``n_pass * 2 * bucket`` is the rows the model actually
    computed (the ``rows_bucketed`` telemetry).
    """
    AC = ready.shape[0]
    B = bucket
    # ready rows first (stable argsort), padded up to a multiple of B
    # with out-of-range sentinels so dynamic_slice never clamps a pass
    # start back over rows an earlier pass already wrote
    order = jnp.argsort(~ready).astype(jnp.int32)
    pad = round_up(AC, B) - AC
    if pad:
        order = jnp.concatenate([order, jnp.zeros((pad,), jnp.int32)])
    n_ready = ready.sum().astype(jnp.int32)
    cols = jnp.arange(AC)

    def cond(carry):
        return carry[0] * B < n_ready

    def body(carry):
        p, mean, var = carry
        idx = jax.lax.dynamic_slice(order, (p * B,), (B,))
        in_pass = (jnp.arange(B) + p * B) < n_ready
        w2 = jnp.concatenate([wins[idx], wins[idx + AC]])
        v2 = jnp.concatenate([valid[idx], valid[idx + AC]])
        fc = model.forecast_batch(w2, cfg.horizon, valid=v2)
        peak, pvar = peak_over_horizon(fc)
        peak = peak.astype(jnp.float32)
        pvar = pvar.astype(jnp.float32)
        # one-hot scatter-back: each ready row appears in exactly one
        # pass (argsort is a permutation; the sentinel tail is masked),
        # so each output element is one value plus exact zeros
        oh = ((idx[:, None] == cols[None, :])
              & in_pass[:, None]).astype(jnp.float32)      # (B, AC)
        mean = mean + jnp.concatenate([peak[:B] @ oh, peak[B:] @ oh])
        var = var + jnp.concatenate([pvar[:B] @ oh, pvar[B:] @ oh])
        return p + 1, mean, var

    z = jnp.zeros((2 * AC,), jnp.float32)
    n_pass, mean, var = jax.lax.while_loop(
        cond, body, (jnp.int32(0), z, z))
    return mean, var, n_pass


def _shaped_demands(cfg, model, tr: DeviceTrace, st: SimState,
                    tick: float, bucket: int | None = None
                    ) -> tuple[Array, SimState, Array, Array]:
    """(A, C, 2) shaped demand table, updated calib state, the number of
    forecast rows actually past the grace period this tick, and the rows
    the forecast model computed for them (0 for persist/oracle).

    Mirrors ``engine._shape_decisions``'s demand construction: running
    components default to their reservation; components past the grace
    period get ``clip(peak + beta, 0, request)`` — with the conformal
    per-series scale replacing K2 when calibration is on."""
    A, C = st.comp_running.shape
    AC = A * C
    gid = jnp.maximum(st.slot_gid, 0)
    run = (st.slot_gid >= 0)[:, None] & st.comp_running       # (A, C)
    req = jnp.stack([tr.cpu_req[gid], tr.mem_req[gid]], axis=-1)
    demand = jnp.where(run[:, :, None], req, 0.0)

    if cfg.forecaster == "oracle":
        peaks = _oracle_peaks(tr, st, cfg.horizon, tick)
        shaped = shaped_demand_raw(peaks, req, jnp.zeros_like(peaks),
                                   cfg.safeguard)
        return (jnp.where(run[:, :, None], shaped, demand), st,
                jnp.int32(0), jnp.int32(0))

    # forecast over the monitor rows (CPU rows then MEM rows); rows not
    # past the grace period are masked out of the demand afterwards.
    # Shapes are static under jit, so per-row compaction needs the
    # bucketed path: with ``bucket`` set (the driver's per-chunk choice)
    # the gp/arima model runs only over the ready rows, in
    # ceil(ready / bucket) passes of a fixed-shape batch.  Un-bucketed,
    # the MODEL call is gated on any row being ready at all, which skips
    # the model during warm-up/grace ticks and after global completion —
    # but the gate only helps solo (non-vmapped) programs: under a
    # cohort vmap the cond lowers to a select and both branches execute.
    # The ready/computed gap either way is what the ``forecast_rows``
    # telemetry measures (the gp block of BENCH_engine.json).
    W = st.mon_buf.shape[1]
    ready = run.reshape(AC) & (st.mon_count >= cfg.grace)
    wins = jnp.concatenate([st.mon_buf[:, :, CPU], st.mon_buf[:, :, MEM]])
    age = jnp.arange(W)[None, :]
    vrow = age >= (W - jnp.minimum(st.mon_count, W))[:, None]
    valid = jnp.concatenate([vrow, vrow])
    fc_done = jnp.int32(0)
    if cfg.forecaster == "persist":
        mean, var = persistence_peak(wins, valid)
    elif bucket is not None:
        mean, var, n_pass = _bucketed_forecast(
            cfg, model, wins, valid, ready, bucket)
        fc_done = n_pass * jnp.int32(2 * bucket)
    else:
        def _model(args):
            w, v = args
            fc = model.forecast_batch(w, cfg.horizon, valid=v)
            peak, pvar = peak_over_horizon(fc)
            return peak.astype(jnp.float32), pvar.astype(jnp.float32)

        def _skip(args):
            z = jnp.zeros((2 * AC,), jnp.float32)
            return z, z

        mean, var = jax.lax.cond(ready.any(), _model, _skip, (wins, valid))
        fc_done = jnp.where(ready.any(), jnp.int32(2 * AC), jnp.int32(0))

    req_rows = jnp.concatenate([req[:, :, CPU].reshape(AC),
                                req[:, :, MEM].reshape(AC)])
    if st.calib is None:
        shaped = shaped_demand_raw(mean, req_rows, var, cfg.safeguard)
        calib = st.calib
    else:
        # per-tenant tier (control plane on): rows map to the tenant
        # owning the slot (-1 for empty slots); with credit enabled the
        # target quantile is the tenant's credit-modulated level —
        # computed from the CURRENT (previous tick's) credit, exactly
        # like the host engine reads q_groups before its gate update
        groups = q_rows = q_groups = None
        if st.tenancy is not None and st.calib.group is not None:
            tslot = jnp.where(st.slot_gid >= 0, tr.tenant[gid], -1)
            g1 = jnp.repeat(tslot, C).astype(jnp.int32)
            groups = jnp.concatenate([g1, g1])
            if cfg.control.credit:
                qt = credit_quantile(st.tenancy.credit, st.calib.q,
                                     cfg.control.q_spread,
                                     cfg.calibration.q_min,
                                     cfg.calibration.q_max)
                q_rows = jnp.where(groups >= 0,
                                   qt[jnp.maximum(groups, 0)], st.calib.q)
                q_groups = qt
        scale = calib_scales(st.calib, cfg.calibration, cfg.safeguard.k2,
                             groups=groups, q_rows=q_rows,
                             q_groups=q_groups)
        shaped = shaped_demand_scaled_raw(
            mean, req_rows, var, jnp.float32(cfg.safeguard.k1), scale)
        sigma = jnp.sqrt(jnp.maximum(var, 0.0)).astype(jnp.float32)
        ready2 = jnp.concatenate([ready, ready])
        calib = calib_begin(st.calib, ready2, mean.astype(jnp.float32),
                            sigma, scale.astype(jnp.float32),
                            jnp.tile(st.mon_count, 2), cfg.horizon,
                            groups=groups)
    st = dataclasses.replace(st, calib=calib)

    ready2 = jnp.concatenate([ready, ready])
    rows = jnp.where(ready2, shaped, 0.0)
    shaped_tbl = jnp.stack([rows[:AC].reshape(A, C),
                            rows[AC:].reshape(A, C)], axis=-1)
    ready_tbl = ready.reshape(A, C)
    fc_rows = 2 * ready.sum().astype(jnp.int32)
    return (jnp.where(ready_tbl[:, :, None], shaped_tbl, demand), st,
            fc_rows, fc_done)


def _shape_problem(cfg, tr: DeviceTrace, st: SimState, demand: Array,
                   t: Array, host_cap: Array) -> ShapeProblem:
    A = st.slot_gid.shape[0]
    gid = jnp.maximum(st.slot_gid, 0)
    app_exists = st.slot_gid >= 0
    n_run = app_exists.sum()
    key = tr.submit[gid] + jnp.where(app_exists, 0.0, 1e18)
    fifo = jnp.argsort(key)
    order = jnp.where(jnp.arange(A) < n_run, fifo, -1)
    return ShapeProblem(
        host_cpu=host_cap[:, CPU], host_mem=host_cap[:, MEM],
        app_exists=app_exists, app_order=order,
        comp_exists=st.comp_running,
        comp_core=tr.is_core[gid] & app_exists[:, None],
        comp_host=st.comp_host,
        comp_cpu=demand[:, :, CPU], comp_mem=demand[:, :, MEM],
        comp_alive=t - st.alive_since)


def _apply_decision(cfg, tr: DeviceTrace, st: SimState, dec,
                    usage: Array) -> tuple[SimState, Array, Array, Array]:
    """Kills + resizes from a ShapeDecision.  Returns (state, usage,
    conflict_failed, monitor_resets) — ``conflict_failed`` the
    optimistic policy's uncontrolled failures (per-app gid mask)."""
    A, C = st.comp_running.shape
    exists = st.slot_gid >= 0
    gid = jnp.maximum(st.slot_gid, 0)

    N = tr.submit.shape[0]
    kills = dec.kill_app & exists                              # (A,)
    n_kills = kills.sum()
    slot_of = (jnp.arange(N)[None, :] == gid[:, None]) & kills[:, None]
    kgids_mask = slot_of.any(0)                                # (N,)
    if not cfg.work_lost_on_kill:
        saved = jnp.where(
            kgids_mask,
            jnp.where(slot_of, st.work_done[:, None], 0.0).sum(0),
            st.saved_work)
        has = st.has_saved | kgids_mask
        st = dataclasses.replace(st, saved_work=saved, has_saved=has)
    usage = jnp.where(kills[:, None, None], 0.0, usage)
    if cfg.policy == "optimistic":
        # optimistic-concurrency conflict: an UNCONTROLLED failure
        conflict = kgids_mask
        st = dataclasses.replace(
            st, failure_events=st.failure_events + n_kills.astype(jnp.int32))
    else:
        conflict = jnp.zeros_like(kgids_mask)
        st = dataclasses.replace(
            st, queued=st.queued | kgids_mask,
            full_preemptions=st.full_preemptions + n_kills.astype(jnp.int32))
    st = _evict_slots(st, kills)

    kc = dec.kill_comp & exists[:, None] & st.comp_running     # (A, C)
    usage = jnp.where(kc[:, :, None], 0.0, usage)
    st = dataclasses.replace(
        st,
        comp_running=st.comp_running & ~kc,
        partial_preemptions=(st.partial_preemptions
                             + kc.sum().astype(jnp.int32)))

    live = st.comp_running
    alloc = jnp.stack([jnp.where(live, dec.alloc_cpu, 0.0),
                       jnp.where(live, dec.alloc_mem, 0.0)], axis=-1)
    st = dataclasses.replace(st, alloc=alloc)
    resets = jnp.repeat(kills, C) | kc.reshape(-1)
    return st, usage, conflict, resets


def _resolve_oom(tr: DeviceTrace, st: SimState, usage: Array,
                 host_cap: Array):
    """OS OOM handler (mirrors ``Cluster.resolve_oom``): for every host
    over memory capacity at entry, kill components by descending
    (usage - allocation) overage until the host fits.  One
    ``lax.while_loop`` whose trip count is H + number of kills."""
    A, C = st.comp_running.shape
    H = host_cap.shape[0]
    N = tr.submit.shape[0]
    on_host = (st.comp_running.reshape(-1)[:, None]
               & (st.comp_host.reshape(-1)[:, None]
                  == jnp.arange(H)[None, :]))             # (A*C, H)
    over0 = (jnp.where(on_host, usage[:, :, MEM].reshape(-1)[:, None],
                       0.0).sum(0)
             > host_cap[:, MEM] + 1e-6)
    # victims are running at selection time, so their gid (and coreness)
    # cannot have changed since loop entry — gather the tables once
    gid0 = jnp.maximum(st.slot_gid, 0)
    core_tbl = tr.is_core[gid0].reshape(-1)                 # (A*C,)
    gid_tbl = gid0.repeat(C)                                # (A*C,)
    cap_mem = host_cap[:, MEM]

    def cond(carry):
        return carry[0] < H

    def body(carry):
        (h, usage, slot_gid, comp_running, alloc, work_done,
         failed, queued, monreset, oom_kills, fevents, partials) = carry
        on_h = comp_running & (st.comp_host == h)
        mem = usage[:, :, MEM]
        tot = jnp.where(on_h, mem, 0.0).sum()
        oh = jnp.arange(H) == h
        need = (jnp.where(oh, over0, False).any() & on_h.any()
                & (tot > jnp.where(oh, cap_mem, 0.0).sum() + 1e-6))

        over = jnp.where(on_h, mem - alloc[:, :, MEM], -jnp.inf)
        flat = over.reshape(-1)
        # seed tie-break: largest overage, then largest (slot, comp)
        vic = (A * C - 1) - jnp.argmax(flat[::-1] == flat.max())
        ovic = jnp.arange(A * C) == vic                     # one-hot
        core = (ovic & core_tbl).any()
        vgid_oh = ((jnp.arange(N)[None, :] == gid_tbl[:, None])
                   & ovic[:, None]).any(0)                  # (N,) one-hot
        full = need & core
        part = need & ~core

        rowm = full & (ovic.reshape(A, C).any(1))           # (A,)
        killm = rowm[:, None] | (part & ovic.reshape(A, C))
        usage = jnp.where(killm[:, :, None], 0.0, usage)
        comp_running = comp_running & ~killm
        alloc = jnp.where(killm[:, :, None], 0.0, alloc)
        slot_gid = jnp.where(rowm, -1, slot_gid)
        work_done = jnp.where(rowm, 0.0, work_done)
        failed = failed | (full & vgid_oh)
        queued = queued | (full & vgid_oh)
        monreset = monreset | (part & ovic)
        oom_kills = oom_kills + full
        fevents = fevents + full
        partials = partials + part
        h = h + jnp.where(need, 0, 1)
        return (h, usage, slot_gid, comp_running, alloc, work_done,
                failed, queued, monreset, oom_kills, fevents, partials)

    # start past the last host when none is over capacity: the common
    # (healthy) tick pays only the over0 reduction, not H loop bodies
    h0 = jnp.where(over0.any(), jnp.int32(0), jnp.int32(H))
    init = (h0, usage, st.slot_gid, st.comp_running, st.alloc,
            st.work_done, st.failed, st.queued,
            jnp.zeros((A * C,), bool), jnp.int32(0), jnp.int32(0),
            jnp.int32(0))
    (_, usage, slot_gid, comp_running, alloc, work_done, failed, queued,
     monreset, oom_kills, fevents, partials) = jax.lax.while_loop(
        cond, body, init)
    st = dataclasses.replace(
        st, slot_gid=slot_gid, comp_running=comp_running, alloc=alloc,
        work_done=work_done, failed=failed, queued=queued,
        oom_kills=st.oom_kills + oom_kills,
        failure_events=st.failure_events + fevents,
        partial_preemptions=st.partial_preemptions + partials)
    return st, usage, monreset


def _admit_queued(cfg, tr: DeviceTrace, st: SimState, t: Array,
                  host_cap: Array,
                  elig_app: Array | None = None) -> tuple[SimState, Array]:
    """FIFO admission: pop (submit0, gid)-ascending heads while they
    admit (all core components must fit, worst-fit placement) — the
    engine's scheduler loop as an event-bounded ``while_loop``.
    ``elig_app`` (control plane, (N,) bool) restricts head selection to
    apps of gate-eligible tenants; ineligible entries stay queued.
    Returns (state, monitor rows to reset)."""
    A, C = st.comp_running.shape
    N = tr.submit.shape[0]

    H = host_cap.shape[0]

    def try_place(cur, gid):
        """Sequential worst-fit of app ``gid``'s components (core pass
        then elastic pass, mirroring ``Cluster.admit``).  Scans run over
        the component COLUMNS (no per-step gathers) and free updates are
        one-hot masked (no scatters) — both vmap cleanly."""
        cpu, mem = tr.cpu_req[gid], tr.mem_req[gid]      # (C,)
        needed = tr.exists[gid]
        core = needed & tr.is_core[gid]
        free0 = _free_resources(cur, host_cap)

        def core_step(carry, x):
            free, ok = carry
            cpu_c, mem_c, core_c = x
            h, fits = _worst_fit(free, cpu_c, mem_c)
            commit = core_c & fits & ok
            ok = ok & (~core_c | fits)
            oh = (jnp.arange(H) == h) & commit
            free = free - jnp.where(oh[:, None],
                                    jnp.stack([cpu_c, mem_c]), 0.0)
            return (free, ok), (h, commit)

        (free, ok), (h_core, c_core) = jax.lax.scan(
            core_step, (free0, jnp.bool_(True)), (cpu, mem, core),
            unroll=True)

        def el_step(carry, x):
            free = carry
            cpu_c, mem_c, el_c = x
            h, fits = _worst_fit(free, cpu_c, mem_c)
            commit = el_c & fits & ok
            oh = (jnp.arange(H) == h) & commit
            free = free - jnp.where(oh[:, None],
                                    jnp.stack([cpu_c, mem_c]), 0.0)
            return free, (h, commit)

        free, (h_el, c_el) = jax.lax.scan(
            el_step, free, (cpu, mem, needed & ~core), unroll=True)
        placement = jnp.where(
            c_core, h_core,
            jnp.where(c_el, h_el, -1)).astype(jnp.int32)
        return ok, placement

    def cond(carry):
        return carry[2]

    def _q(queued):
        return queued if elig_app is None else queued & elig_app

    def body(carry):
        cur, resets, _ = carry
        qm = _q(cur.queued)
        has_q = qm.any()
        # FIFO head: earliest submit, ties broken by global app id so
        # admission order is independent of a row's position in the
        # table (materialized traces have gid == row index, so this is
        # bit-identical to the plain argmin; the streamed engine re-keys
        # window rows and relies on the gid tie-break)
        smin = jnp.min(jnp.where(qm, tr.submit, jnp.inf))
        tied = qm & (tr.submit == smin)
        head = jnp.argmin(jnp.where(tied, tr.gid, jnp.iinfo(jnp.int32).max))
        empty = cur.slot_gid < 0
        slot = jnp.argmax(empty)
        fits, placement = try_place(cur, head)
        ok = has_q & empty.any() & fits

        placed = placement >= 0
        ogid = (jnp.arange(N) == head) & ok          # one-hot app
        if cfg.work_lost_on_kill:
            resume = jnp.float32(0.0)
        else:   # preempt-to-checkpoint: resume from the saved progress
            resume = jnp.where((ogid & cur.has_saved).any(),
                               jnp.where(ogid, cur.saved_work, 0.0).sum(),
                               0.0)
        osl = (jnp.arange(A) == slot) & ok           # one-hot slot
        row = lambda x, new: jnp.where(  # noqa: E731
            osl.reshape((A,) + (1,) * (x.ndim - 1)), new, x)
        nxt = dataclasses.replace(
            cur,
            slot_gid=row(cur.slot_gid, head.astype(jnp.int32)),
            work_done=row(cur.work_done, resume),
            comp_running=row(cur.comp_running, placed[None, :]),
            comp_host=row(cur.comp_host, jnp.maximum(placement, 0)[None, :]),
            alloc=row(cur.alloc,
                      jnp.where(placed[:, None],
                                jnp.stack([tr.cpu_req[head],
                                           tr.mem_req[head]], -1),
                                0.0)[None]),
            alive_since=row(cur.alive_since, t),
            queued=cur.queued & ~ogid,
            has_saved=cur.has_saved & ~ogid)
        resets = resets | jnp.repeat(osl, C)
        cont = ok & _q(nxt.queued).any() & (nxt.slot_gid < 0).any()
        return nxt, resets, cont

    # no empty slot (saturated cluster) => the head cannot admit: skip
    # the whole loop instead of paying one doomed placement attempt
    cont0 = _q(st.queued).any() & (st.slot_gid < 0).any()
    st, resets, _ = jax.lax.while_loop(
        cond, body, (st, jnp.zeros((A * C,), bool), cont0))
    return st, resets


def _place_missing_elastic(tr: DeviceTrace, st: SimState, t: Array,
                           host_cap: Array) -> SimState:
    """Best-effort re-placement of missing elastic components, walked in
    row-major (slot, component) order over the entry snapshot — an
    event-bounded ``while_loop`` over the actually-missing set."""
    A, C = st.comp_running.shape
    gid = jnp.maximum(st.slot_gid, 0)
    missing = ((st.slot_gid >= 0)[:, None] & tr.exists[gid]
               & ~tr.is_core[gid] & ~st.comp_running).reshape(-1)
    n_miss = missing.sum()

    H = host_cap.shape[0]
    req_cpu, req_mem = tr.cpu_req[gid], tr.mem_req[gid]    # (A, C)

    def place(st):
        # ascending flat indices, missing entries first (stable argsort)
        order = jnp.argsort(~missing)
        free0 = _free_resources(st, host_cap)

        def cond(carry):
            return carry[0] < n_miss

        def body(carry):
            i, free, comp_running, comp_host, alloc, alive = carry
            oe = jnp.arange(A * C) == order[i]
            m2 = oe.reshape(A, C)                          # one-hot (A, C)
            cpu = jnp.where(m2, req_cpu, 0.0).sum()
            mem = jnp.where(m2, req_mem, 0.0).sum()
            h, fits = _worst_fit(free, cpu, mem)
            oh = (jnp.arange(H) == h) & fits
            free = free - jnp.where(oh[:, None],
                                    jnp.stack([cpu, mem]), 0.0)
            m2f = m2 & fits
            comp_running = comp_running | m2f
            comp_host = jnp.where(m2f, h.astype(jnp.int32), comp_host)
            alloc = jnp.where(m2f[:, :, None],
                              jnp.stack([cpu, mem]), alloc)
            alive = jnp.where(m2f, t, alive)
            return i + 1, free, comp_running, comp_host, alloc, alive

        (_, _, comp_running, comp_host, alloc, alive) = jax.lax.while_loop(
            cond, body, (jnp.int32(0), free0, st.comp_running,
                         st.comp_host, st.alloc, st.alive_since))
        return dataclasses.replace(st, comp_running=comp_running,
                                   comp_host=comp_host, alloc=alloc,
                                   alive_since=alive)

    # most ticks have nothing missing: skip the sort + free computation
    return jax.lax.cond(n_miss > 0, place, lambda s: s, st)


# ----------------------------------------------------------------------
# the fused tick
# ----------------------------------------------------------------------

def fused_tick(cfg, model, tr: DeviceTrace, st: SimState,
               bucket: int | None = None,
               lead: Array | None = None) -> tuple[SimState, TickMetrics]:
    """One simulation tick as a pure function (cfg and model static).

    Phase order is exactly ``engine.run_sim``'s loop body; the whole
    body is gated on ``active`` (some app unfinished AND the tick budget
    not exhausted) so post-completion scan padding is a no-op.

    ``bucket`` (static, from the driver's per-chunk choice) routes the
    gp/arima forecast through :func:`_bucketed_forecast`; ``lead`` is
    the leap engine's skipped-idle-tick count for this step, threaded
    into the tick's telemetry (metrics + obs ring) so histories can be
    re-expanded on the host.
    """
    A, C = st.comp_running.shape
    H = cfg.cluster.n_hosts
    tick = cfg.cluster.tick
    host_cap = jnp.stack(
        [jnp.full((H,), cfg.cluster.host_cpu, jnp.float32),
         jnp.full((H,), cfg.cluster.host_mem, jnp.float32)], axis=-1)

    # Post-completion scan padding is a NATURAL no-op: with every app
    # done there are no running slots, no queue, no arrivals and no
    # outstanding calibration predictions, so every phase below mutates
    # nothing — only the clock needs explicit gating.  (The max_ticks
    # budget is enforced by the driver slicing the last chunk exactly,
    # so a truncated sim never executes ticks past its budget either.)
    active = ~st.done.all()
    t_prev = st.t
    t = st.t + jnp.float32(tick)

    # telemetry rings (repro.obs): static pytree-structure branch like
    # `ctl` below.  Entry-of-tick counter snapshots turn the cumulative
    # counters into per-tick DELTAS — raw sums only, never ratios, so
    # the rings stay chunk-invariant (see ObsState docstring).
    rec = st.obs is not None
    if rec:
        oom0, fail0 = st.oom_kills, st.failure_events
        pre0 = st.full_preemptions + st.partial_preemptions
        cres0 = st.calib.resolved if st.calib is not None else None
        cerr0 = st.calib.errors if st.calib is not None else None
        obs_dem = None                       # shaped-demand sums, (2,)
        obs_throttled = jnp.int32(0)
        obs_credit = jnp.float32(0.0)

    # 1. arrivals
    new = ~st.arrived & (tr.submit <= t)
    st = dataclasses.replace(st, arrived=st.arrived | new,
                             queued=st.queued | new)

    # 2. progress + completions (monitor resets accumulate across phases
    # and apply once at end of tick — see _mon_reset)
    ctl = st.tenancy is not None          # static pytree-structure branch
    done_before = st.done
    st, resets = _completions(tr, st, t, tick)

    # control-plane event accounting (mirrors HostControl.note_*): good
    # events are completions + covered conformal resolutions, bad events
    # are failures + miscoverage; `fail_t` tracks failures alone for the
    # per-tenant failed counter.
    if ctl:
        Tn = cfg.control.max_tenants
        comp_t = _tenant_counts(tr.tenant, st.done & ~done_before, Tn)
        good_t = comp_t
        bad_t = jnp.zeros((Tn,), jnp.int32)
        fail_t = jnp.zeros((Tn,), jnp.int32)

    # 3. monitor sampling
    gid = jnp.maximum(st.slot_gid, 0)
    prog = jnp.clip(st.work_done / tr.runtime[gid], 0.0, 1.0)
    usage = _usage_at(tr, st, prog)
    st = _record_monitor(st, usage)
    if st.calib is not None:
        rows = jnp.concatenate([usage[:, :, CPU].reshape(-1),
                                usage[:, :, MEM].reshape(-1)])
        grp = ctl and st.calib.group_resolved is not None
        if grp:
            gr0, ge0 = st.calib.group_resolved, st.calib.group_errors
        st = dataclasses.replace(
            st, calib=calib_observe(st.calib, rows,
                                    jnp.tile(st.mon_count, 2),
                                    cfg.calibration, active=active))
        if grp:
            derr = st.calib.group_errors - ge0
            good_t = good_t + (st.calib.group_resolved - gr0) - derr
            bad_t = bad_t + derr

    # 4. shaping (static branch: the baseline policy never shapes).
    # The engine skips this phase when no slot is occupied; here an
    # empty slot table makes every sub-step a no-op (empty kill masks,
    # all-zero allocations over an all-zero table), so no gate is needed.
    fc_rows = fc_done = jnp.int32(0)
    if cfg.policy != "baseline":
        demand, st, fc_rows, fc_done = _shaped_demands(
            cfg, model, tr, st, tick, bucket)
        if rec:
            obs_dem = demand.sum((0, 1))     # (2,) shaped-demand totals
        prob = _shape_problem(cfg, tr, st, demand, t, host_cap)
        dec = RAW_POLICIES[cfg.policy](prob)
        st, usage, conflict, resets4 = _apply_decision(
            cfg, tr, st, dec, usage)
        st = dataclasses.replace(
            st, failed=st.failed | conflict, queued=st.queued | conflict)
        resets = resets | resets4
        if ctl:
            c4 = _tenant_counts(tr.tenant, conflict, Tn)
            fail_t = fail_t + c4
            bad_t = bad_t + c4

    # 5. OS OOM (uncontrolled failures) — fails recorded + requeued
    q5 = st.queued
    st, usage, resets5 = _resolve_oom(tr, st, usage, host_cap)
    if ctl:
        oomed = _tenant_counts(tr.tenant, st.queued & ~q5, Tn)
        fail_t = fail_t + oomed
        bad_t = bad_t + oomed

    # 6. scheduler: FIFO admission + elastic re-placement.  With the
    # control plane on, a wDRF gate runs first: per-tenant dominant
    # shares from the live allocation table decide which tenants may
    # admit this tick (HostControl.gate, vectorized).
    elig_app = None
    if ctl:
        ten = st.tenancy
        credit = (credit_step(ten.credit, good_t, bad_t,
                              cfg.control.credit_gamma,
                              cfg.control.credit_floor)
                  if cfg.control.credit else ten.credit)
        occ = st.slot_gid >= 0
        tslot = jnp.where(occ, tr.tenant[jnp.maximum(st.slot_gid, 0)], -1)
        oh_slot = tslot[:, None] == jnp.arange(Tn)[None, :]       # (A, T)
        alloc_t = jnp.where(oh_slot[:, :, None],
                            st.alloc.sum(1)[:, None, :], 0.0).sum(0)
        share = dominant_shares(alloc_t, host_cap.sum(0),
                                device_weights(cfg.control))
        queued_t = _tenant_counts(tr.tenant, st.queued, Tn)
        active_t = (share > 0) | (queued_t > 0)
        if cfg.control.gate:
            slack = (jnp.float32(cfg.control.slack) * credit
                     if cfg.control.credit
                     else jnp.float32(cfg.control.slack))
            elig_t = gate_mask(share, active_t, slack)
        else:
            elig_t = jnp.ones((Tn,), bool)
        st = dataclasses.replace(st, tenancy=dataclasses.replace(
            ten, credit=credit,
            throttled=ten.throttled + jnp.where(elig_t, 0, queued_t),
            completed=ten.completed + comp_t,
            failed=ten.failed + fail_t,
            share_sum=ten.share_sum + (share * active_t).astype(jnp.float32),
            active_ticks=ten.active_ticks + active_t.astype(jnp.int32)))
        elig_app = elig_t[jnp.clip(tr.tenant, 0, Tn - 1)]
        q6 = st.queued
        if rec:
            obs_throttled = jnp.where(elig_t, 0, queued_t).sum()
            obs_credit = credit_mean(credit, active_t)
    q_admit = st.queued
    st, resets6 = _admit_queued(cfg, tr, st, t, host_cap, elig_app)
    if ctl:
        st = dataclasses.replace(st, tenancy=dataclasses.replace(
            st.tenancy, admitted=st.tenancy.admitted
            + _tenant_counts(tr.tenant, q6 & ~st.queued, Tn)))
    st = _place_missing_elastic(tr, st, t, host_cap)
    st = _mon_reset(st, resets | resets5 | resets6)

    # 7. metrics (raw sums; the ratios divide on the host at drain)
    used = usage.sum((0, 1))
    alloc = jnp.where(st.comp_running[:, :, None], st.alloc, 0.0).sum((0, 1))
    metrics = TickMetrics(
        valid=active,
        n_running=(st.slot_gid >= 0).sum().astype(jnp.int32),
        used_cpu=used[CPU], used_mem=used[MEM],
        alloc_cpu=alloc[CPU], alloc_mem=alloc[MEM],
        forecast_rows=fc_rows, forecast_rows_done=fc_done,
        lead=jnp.int32(0) if lead is None else lead)

    if rec:
        zero = jnp.int32(0)
        st = dataclasses.replace(st, obs=obs_record(st.obs, active, {
            "used_cpu": used[CPU], "used_mem": used[MEM],
            "queue": st.queued.sum().astype(jnp.int32),
            "gap_cpu": (obs_dem[CPU] - used[CPU]
                        if obs_dem is not None else jnp.float32(0.0)),
            "gap_mem": (obs_dem[MEM] - used[MEM]
                        if obs_dem is not None else jnp.float32(0.0)),
            "oom": st.oom_kills - oom0,
            "fail": st.failure_events - fail0,
            "preempt": (st.full_preemptions + st.partial_preemptions
                        - pre0),
            "admitted": (q_admit & ~st.queued).sum().astype(jnp.int32),
            "throttled": obs_throttled,
            "credit": obs_credit,
            "cov_resolved": (st.calib.resolved - cres0
                             if cres0 is not None else zero),
            "cov_errors": (st.calib.errors - cerr0
                           if cerr0 is not None else zero),
        }, lead=lead))

    st = dataclasses.replace(st, t=jnp.where(active, t, t_prev))
    return st, metrics


def fused_leap(cfg, model, tr: DeviceTrace, st: SimState, left: Array,
               bucket: int | None = None
               ) -> tuple[SimState, Array, TickMetrics]:
    """One EVENT-DRIVEN leap step: skip a run of provably-idle ticks,
    then execute one real :func:`fused_tick`.

    A tick is provably idle — every phase of the uniform step a no-op —
    when the cluster is empty, the FIFO queue is empty, calibration has
    no pending predictions (``CalibState.left`` ages per executed tick,
    so outstanding scores must run, not leap) and the next arrival is
    still in the future.  Tenancy needs no guard: with zero events
    ``credit_step`` is an identity and every counter increments by zero.
    The skip itself is a scalar ``while_loop`` that replays the uniform
    engine's EXACT ``t + tick`` float32 accumulation (~3 scalar ops per
    skipped tick instead of a full fused tick), so arrival tick indices
    — and therefore all downstream results — are bit-identical for any
    tick value.  Under a cohort vmap each member skips its own idle
    spans: a chunk costs ~max(per-member non-idle ticks) steps.

    ``left`` is the member's remaining tick budget (the driver seeds it
    with ``max_ticks``); it caps the skip and gates the executed tick,
    replacing the uniform driver's last-chunk slicing.  Budget-truncated
    idle tails still record their skipped ticks (metrics ``lead`` /
    a zero obs column) so truncated histories match uniform ones.

    Returns (state, left', metrics): ``left' = left - lead - executed``.
    """
    tick_f = jnp.float32(cfg.cluster.tick)
    active = ~st.done.all() & (left > 0)
    idle = active & (st.slot_gid < 0).all() & ~st.queued.any()
    if st.calib is not None:
        idle = idle & (st.calib.left == 0).all()
    next_sub = jnp.min(jnp.where(st.arrived, jnp.inf, tr.submit))

    def wcond(carry):
        t_c, n = carry
        return idle & (n < left) & (next_sub > t_c + tick_f)

    def wbody(carry):
        t_c, n = carry
        return t_c + tick_f, n + 1

    t2, lead = jax.lax.while_loop(wcond, wbody, (st.t, jnp.int32(0)))
    st = dataclasses.replace(st, t=t2)
    run = active & (left - lead > 0)
    # the tick always executes (a vmapped cond would lower to a select
    # and run both branches anyway) and is discarded when the budget
    # ran out; `run` implies the tick's own `active` gate
    st2, m = fused_tick(cfg, model, tr, st, bucket=bucket, lead=lead)
    st = jax.tree.map(lambda a, b: jnp.where(run, a, b), st2, st)
    m = dataclasses.replace(m, valid=m.valid & run, lead=lead)
    if st.obs is not None:
        # budget exhausted mid-skip: the skipped ticks still happened —
        # record them as one zero column standing for `lead` idle ticks
        tail = ~run & (lead > 0)
        st = dataclasses.replace(st, obs=obs_record(
            st.obs, tail, {name: 0 for name, _ in RING_FIELDS},
            lead=lead - 1))
    return st, left - lead - run.astype(jnp.int32), m


# ----------------------------------------------------------------------
# chunked scan drivers
# ----------------------------------------------------------------------

def _make_model(cfg):
    from repro.sim.engine import _make_model as mk
    return mk(cfg)


def _cfg_key(cfg):
    """Hashable compile key: everything the traced program depends on
    (NOT the workload config — shapes are keyed separately, so sweep
    cells across scenarios share compilations)."""
    return (cfg.cluster, cfg.policy, cfg.forecaster, cfg.safeguard,
            cfg.calibration, cfg.control, cfg.obs, cfg.window, cfg.grace,
            cfg.horizon, cfg.gp, cfg.arima, cfg.work_lost_on_kill,
            cfg.leap, cfg.forecast_bucket)


_CHUNK_CACHE: dict = {}

# device-trace upload cache: workload configs are frozen (hashable)
# dataclasses and the engines never mutate a Trace, so repeated runs of
# the same cell (e.g. benchmark reps, sweep baselines) reuse the upload.
# Bounded LRU — a long-lived process sweeping many scenarios must not
# pin every uploaded trace in device memory forever.
_TRACE_CACHE: "dict" = {}
_TRACE_CACHE_MAX = 16


def _device_trace(wls, batched: bool, *, pad_to: int | None = None,
                  place=None, place_key=None) -> DeviceTrace:
    build = (
        (lambda ws: DeviceTrace.from_traces(ws, pad_to=pad_to)) if batched
        else lambda ws: DeviceTrace.from_trace(ws[0]))
    if place is not None:
        inner = build
        build = lambda ws: place(inner(ws))  # noqa: E731
    cfgs = tuple(getattr(w, "cfg", None) for w in wls)
    if any(c is None for c in cfgs):
        return build(wls)
    # the key carries the layout too: a batched single-seed cohort has a
    # leading seed axis that a solo upload of the same config lacks, and
    # a sharded fleet (place_key = mesh devices) a different placement
    key = (batched, pad_to, place_key, cfgs)
    tr = _TRACE_CACHE.pop(key, None)
    if tr is None:
        tr = build(wls)
        while len(_TRACE_CACHE) >= _TRACE_CACHE_MAX:
            _TRACE_CACHE.pop(next(iter(_TRACE_CACHE)))
    _TRACE_CACHE[key] = tr          # (re)insert as most recently used
    return tr


def _timed_first_call(fn, metric: str):
    """Wrap a fresh jitted chunk fn so its FIRST call — which traces and
    compiles synchronously before dispatching — is measured: the wall
    feeds a ``repro.obs`` histogram (manifests snapshot it) and a
    ``jit_compile`` trace span.  Later calls pass straight through."""
    holder = {"first": True}

    def wrapped(*args):
        if holder["first"]:
            holder["first"] = False
            with span("jit_compile", cat="compile",
                      args={"metric": metric}):
                t0 = time.perf_counter()
                out = fn(*args)
            REGISTRY.histogram(metric).observe(time.perf_counter() - t0)
            return out
        return fn(*args)

    return wrapped


def _chunk_body(cfg, model, chunk: int, bucket):
    """The (un-vmapped) chunk step body: a ``lax.scan`` over
    :func:`fused_tick` (uniform) or :func:`fused_leap` (the tick budget
    then rides in the carry)."""
    if cfg.leap:
        def run_chunk(tr, st, left):
            def body(carry, _):
                s, l, m = fused_leap(cfg, model, tr, *carry, bucket=bucket)
                return (s, l), m
            (st, left), ms = jax.lax.scan(body, (st, left), None,
                                          length=chunk)
            return st, left, ms
        return run_chunk, (1, 2)

    def run_chunk(tr, st):
        def body(s, _):
            return fused_tick(cfg, model, tr, s, bucket=bucket)
        return jax.lax.scan(body, st, None, length=chunk)
    return run_chunk, (1,)


# distinct (cfg-key, bucket) jit-cache entries created by the bucketed
# forecast path — surfaced as a registry gauge so bucket proliferation
# (compile cost) is observable in manifests and the engine bench
_BUCKET_JIT_KEYS: set = set()


def _note_bucket_entry(key) -> None:
    _BUCKET_JIT_KEYS.add(key)
    REGISTRY.gauge("scan.bucket_cache_entries").set(len(_BUCKET_JIT_KEYS))


def _chunk_fn(cfg, chunk: int, shapes, cohort: bool,
              bucket: int | None = None):
    key = (_cfg_key(cfg), chunk, shapes, cohort, bucket)
    fn = _CHUNK_CACHE.get(key)
    if fn is None:
        model = _make_model(cfg)
        run_chunk, donate = _chunk_body(cfg, model, chunk, bucket)
        if cohort:
            run_chunk = jax.vmap(run_chunk)
        fn = _CHUNK_CACHE[key] = _timed_first_call(
            jax.jit(run_chunk, donate_argnums=donate), "scan.compile_s")
        if bucket is not None:
            _note_bucket_entry(key)
    return fn


def _shapes_key(wl, cfg):
    return (int(wl.n_apps), int(wl.max_components),
            cfg.cluster.max_running_apps, cfg.window)


def _concat_metrics(parts: list, axis: int = 0) -> TickMetrics:
    """Per-chunk device outputs concatenated along the tick axis (which
    is axis 1 for cohort runs: vmap puts the seed axis first)."""
    host = [jax.device_get(p) for p in parts]
    return jax.tree.map(lambda *xs: np.concatenate(xs, axis=axis), *host)


# smallest forecast bucket, in monitor rows per resource (the model
# batch is 2x this: CPU + MEM rows): small enough that mostly-idle
# tables pay little, large enough to bound the distinct compilations
# per config at log2(AC / _BUCKET_MIN)
_BUCKET_MIN = 8


def _bucketed(cfg) -> bool:
    """Does this config route forecasts through the bucketed path?"""
    return (cfg.forecast_bucket and cfg.policy != "baseline"
            and cfg.forecaster in ("gp", "arima"))


def _pick_bucket(cfg, st) -> int | None:
    """Per-chunk bucket choice: the smallest power-of-two (>= the floor)
    covering the CURRENT max ready-row count across members, read on the
    host at the chunk boundary (where the driver syncs anyway).  Ready
    growth within the chunk is absorbed by extra ``_bucketed_forecast``
    passes, not a bigger bucket, so the choice affects performance only
    — never results.  ``None`` (the full-batch path) when the bucket
    would cover the whole table anyway."""
    mc = np.asarray(st.mon_count)
    AC = mc.shape[-1]
    run = ((np.asarray(st.slot_gid) >= 0)[..., None]
           & np.asarray(st.comp_running)).reshape(mc.shape)
    n = int((run & (mc >= cfg.grace)).sum(-1).max())
    b = _BUCKET_MIN
    while b < n:
        b *= 2
    if b >= AC:
        return None
    REGISTRY.counter("forecast.bucket_chunks", bucket=str(2 * b)).inc()
    REGISTRY.histogram("forecast.bucket_occupancy",
                       bucket=str(2 * b)).observe(n / b)
    return b


def _ring_drain(cfg, chunk: int, st):
    if st.obs is None:
        return None
    if chunk > cfg.obs.ring:
        raise ValueError(
            f"chunk={chunk} exceeds the telemetry ring capacity "
            f"{cfg.obs.ring}: rings are drained once per chunk, so "
            "undrained ticks would be overwritten (raise "
            "SimConfig.obs.ring or shrink the chunk)")
    return RingDrain()


def _drive_chunks(cfg, chunk: int, fn_for_size, tr, st):
    """Run chunks until every sim is done or the tick budget is spent.

    ``fn_for_size(size, bucket)`` returns the compiled chunk step (the
    scan and shard engines differ only in this factory); ``bucket`` is
    re-chosen at every chunk boundary from the live ready-row count.
    The budget is enforced by slicing the LAST chunk to exactly the
    remaining ticks (one extra compile at most): the step itself gates
    only on completion, so a truncated sim must never execute a tick
    past ``max_ticks``.

    When telemetry rings are present the host drains them at every
    chunk boundary (returned ``RingDrain``; ``None`` when obs is off),
    which is why ring capacity must cover a whole chunk.
    """
    drain = _ring_drain(cfg, chunk, st)
    bucketing = _bucketed(cfg)
    parts = []
    remaining = cfg.max_ticks
    while remaining > 0:
        size = min(chunk, remaining)
        fn = fn_for_size(size, _pick_bucket(cfg, st) if bucketing else None)
        with span("chunk", cat="execute", args={"ticks": size}):
            st, ms = fn(tr, st)
        parts.append(ms)
        remaining -= size
        if drain is not None:
            with span("ring_drain", cat="drain"):
                drain.drain(st.obs)
        # np.asarray, not st.done.all(): the fleet state is sharded
        # across devices and the host-side gather is the cheap form
        if bool(np.asarray(st.done).all()):
            break
    return st, parts, drain


def _drive_chunks_leap(cfg, chunk: int, fn_for_size, tr, st):
    """Leap-mode chunk driver.  A leap step consumes a VARIABLE number
    of ticks, so the host cannot enforce ``max_ticks`` by slicing the
    last chunk; instead the per-member budget rides in the scan carry
    (seeded here, decremented by skipped + executed ticks inside
    :func:`fused_leap`) and every chunk runs the full ``chunk`` steps —
    one compiled size, no last-chunk recompile.  Termination is per
    member: done, or budget spent."""
    drain = _ring_drain(cfg, chunk, st)
    bucketing = _bucketed(cfg)
    left = jnp.full(st.t.shape, cfg.max_ticks, jnp.int32)
    parts = []
    while True:
        fn = fn_for_size(chunk, _pick_bucket(cfg, st) if bucketing else None)
        with span("chunk", cat="execute", args={"ticks": chunk}):
            st, left, ms = fn(tr, st, left)
        parts.append(ms)
        if drain is not None:
            with span("ring_drain", cat="drain"):
                drain.drain(st.obs)
        done = np.asarray(st.done)
        if bool(np.all(done.all(axis=-1) | (np.asarray(left) <= 0))):
            break
    return st, parts, drain


def run_sim_scan(cfg, wl=None, *, chunk: int = 32) -> SimResults:
    """Run one simulation on the device-resident scan engine.

    Semantically equivalent to ``engine.run_sim`` (same phase order,
    same event rules) but executes ``chunk`` ticks per XLA call with no
    host round-trips in between.  Results are independent of ``chunk``
    (bit-identical; see module docstring for the correctness anchors).
    """
    from repro.sim.scenarios.registry import build_trace
    from repro.sim.scenarios.stream import StreamConfig, run_sim_stream
    if isinstance(cfg.workload, StreamConfig):
        # streamed ingestion: bounded device window, rows re-keyed at
        # chunk boundaries (bit-identical to the materialized run)
        return run_sim_stream(cfg, wl, chunk=chunk)
    wl = wl if wl is not None else build_trace(cfg.workload)
    tr = _device_trace([wl], batched=False)
    st = init_state(cfg, wl.n_apps, wl.max_components)
    shapes = _shapes_key(wl, cfg)
    driver = _drive_chunks_leap if cfg.leap else _drive_chunks
    st, parts, drain = driver(
        cfg, chunk,
        lambda size, bucket: _chunk_fn(cfg, size, shapes, False, bucket),
        tr, st)
    return drain_results(
        cfg, wl, st, _concat_metrics(parts),
        obs=drain.history(0) if drain is not None else None)


def run_cohort_scan(cfg, seeds, *, chunk: int = 32,
                    wls=None) -> list[SimResults]:
    """Run a whole seed cohort as ONE batched device program.

    The per-seed states (and traces) are stacked and the chunk step is
    ``vmap`` ped over the seed axis: a sweep cell's cohort costs one
    compilation and one program launch per chunk instead of
    ``len(seeds)`` interleaved host loops.  Each seed's results are
    bit-identical to its ``run_sim_scan`` solo run.
    """
    from repro.sim.scenarios.registry import build_trace
    from repro.sim.scenarios.stream import StreamConfig
    seeds = list(seeds)
    if not seeds:
        return []
    cfgs = [dataclasses.replace(
        cfg, workload=dataclasses.replace(cfg.workload, seed=int(s)))
        for s in seeds]
    if wls is None:
        wls = [build_trace(c.workload) for c in cfgs]
    if isinstance(cfg.workload, StreamConfig):
        # streamed members keep their own windows (the vmapped path
        # assumes one static trace layout per member) — solo streamed
        # runs per seed, each still bit-identical to its scan run
        return [run_sim_scan(c, w, chunk=chunk)
                for c, w in zip(cfgs, wls)]
    if len(seeds) == 1:
        # a cohort of one is just a solo run (and must not go through
        # the vmapped path, whose trace/state layouts carry a seed axis)
        return [run_sim_scan(cfgs[0], wls[0], chunk=chunk)]
    shapes = {(int(w.n_apps), int(w.max_components)) for w in wls}
    if len(shapes) != 1:
        raise ValueError(f"cohort traces disagree on shape: {shapes}")
    tr = _device_trace(wls, batched=True)
    st = init_state(cfg, wls[0].n_apps, wls[0].max_components,
                    batch=len(seeds))
    shapes = _shapes_key(wls[0], cfg)
    driver = _drive_chunks_leap if cfg.leap else _drive_chunks
    st, parts, drain = driver(
        cfg, chunk,
        lambda size, bucket: _chunk_fn(cfg, size, shapes, True, bucket),
        tr, st)
    metrics = _concat_metrics(parts, axis=1)   # leaves: (S, ticks_total)
    if drain is not None:
        # the rings are already drained; slicing them per member would
        # dispatch eager device ops for data drain_results never reads
        st = dataclasses.replace(st, obs=None)
    out = []
    for i, (c, w) in enumerate(zip(cfgs, wls)):
        # lazy device slices: drain_results touches only the telemetry
        # fields, so the big buffers (monitor rings, score rings) are
        # never copied back to the host
        st_i = jax.tree.map(lambda x, i=i: x[i], st)
        ms_i = jax.tree.map(lambda x, i=i: x[i], metrics)
        out.append(drain_results(
            c, w, st_i, ms_i,
            obs=drain.history(i) if drain is not None else None))
    return out


# ----------------------------------------------------------------------
# sharded fleet driver (shard_map over a device mesh)
# ----------------------------------------------------------------------

def _resolve_mesh(mesh, fleet_size: int):
    """Normalize ``mesh`` (None = all local devices, int = first N
    devices, or a ready-made ``Mesh``) to a 1-D fleet mesh.

    The mesh is capped so every device holds at least TWO fleet rows:
    a device with zero rows would idle, and jaxlib 0.4.x's CPU
    partitioner SIGFPEs compiling a ``shard_map`` whose per-device
    slice of this program is exactly 1 (padding past the crash would
    cost the same wasted compute the cap avoids)."""
    from jax.sharding import Mesh
    if isinstance(mesh, Mesh):
        return mesh
    devs = jax.devices()
    n = len(devs) if mesh is None else int(mesh)
    if not 1 <= n <= len(devs):
        raise ValueError(f"mesh={mesh!r}: need 1..{len(devs)} devices "
                         f"({len(devs)} visible; set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N for "
                         "forced host devices on CPU)")
    cap = max(1, round_up(fleet_size, 2) // 2)
    return Mesh(np.array(devs[:min(n, cap)]), (FLEET_AXIS,))


def _shard_chunk_fn(cfg, chunk: int, shapes, mesh,
                    bucket: int | None = None):
    """Compiled chunk step for a sharded fleet: the SAME vmapped chunk
    body as the cohort path, laid across the mesh with ``shard_map`` —
    each device advances its slice of the fleet independently (no
    collectives: sims never communicate), so one SPMD program executes
    the whole fleet with host sync only at chunk boundaries."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.shmap import no_check_kwargs, shard_map
    key = (_cfg_key(cfg), chunk, shapes, "shard", bucket,
           tuple(d.id for d in mesh.devices.flat))
    fn = _CHUNK_CACHE.get(key)
    if fn is None:
        model = _make_model(cfg)
        run_chunk, donate = _chunk_body(cfg, model, chunk, bucket)
        spec = P(FLEET_AXIS)
        n_args = 1 + len(donate)        # (tr, st[, left])
        sharded = shard_map(jax.vmap(run_chunk), mesh=mesh,
                            in_specs=(spec,) * n_args,
                            out_specs=(spec,) * n_args,
                            **no_check_kwargs())
        fn = _CHUNK_CACHE[key] = _timed_first_call(
            jax.jit(sharded, donate_argnums=donate), "shard.compile_s")
        if bucket is not None:
            _note_bucket_entry(key)
    return fn


def run_fleet_shard(cfg, seeds=None, *, chunk: int = 32, wls=None,
                    cfgs=None, mesh=None) -> list[SimResults]:
    """Run a fleet of sims as ONE SPMD program across a device mesh.

    The fleet axis is ``run_cohort_scan``'s stacked cohort axis, padded
    up to a multiple of the mesh size and laid across the devices with
    ``shard_map``: each device ``vmap``s its slice of the fleet through
    the fused tick chunks, and the host syncs only at chunk boundaries
    (metrics drain + global termination check).  Members may differ in
    their WORKLOAD only (seed or scenario — both are trace data, not
    compiled structure); every other config knob is static in the traced
    program, which is exactly what ``repro.sim.shard`` groups sweep
    cells by.

    Fleet members are specified either as ``seeds`` (expanded against
    ``cfg`` exactly like ``run_cohort_scan``) or as explicit ``cfgs``
    (fully-resolved configs agreeing with ``cfg`` on everything but
    ``workload``).  ``mesh`` is ``None`` (all visible devices), a device
    count, or a ready-made 1-D ``Mesh`` over the ``"fleet"`` axis.

    Correctness anchors (``tests/test_shard.py``): ``mesh=1`` is
    bit-identical to ``run_cohort_scan``, and any larger mesh is
    bit-identical per member to ``mesh=1`` (XLA CPU reductions are
    batch-size invariant, so re-slicing the fleet axis cannot change a
    member's numerics).  Padding members are real sims whose results
    are simply never drained.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sim.scenarios.registry import build_trace
    if cfgs is None:
        if seeds is None:
            raise ValueError("pass seeds or cfgs")
        cfgs = [dataclasses.replace(
            cfg, workload=dataclasses.replace(cfg.workload, seed=int(s)))
            for s in seeds]
    cfgs = list(cfgs)
    if not cfgs:
        return []
    for i, c in enumerate(cfgs):
        if dataclasses.replace(c, workload=cfg.workload) != cfg:
            raise ValueError(
                f"fleet member {i} differs from the base config beyond "
                "its workload (policy/forecaster/safeguard/... are "
                "static in the SPMD program)")
    if wls is None:
        wls = [build_trace(c.workload) for c in cfgs]
    from repro.sim.scenarios.stream import StreamConfig
    if any(isinstance(c.workload, StreamConfig) for c in cfgs):
        # streamed members re-key their device windows at chunk
        # boundaries, which the static SPMD fleet layout cannot express
        # — fall back to solo streamed runs per member (bit-identical
        # to what the fleet would produce)
        return [run_sim_scan(c, w, chunk=chunk)
                for c, w in zip(cfgs, wls)]
    shapes = {(int(w.n_apps), int(w.max_components)) for w in wls}
    if len(shapes) != 1:
        raise ValueError(f"fleet traces disagree on shape: {shapes}")

    B = len(cfgs)
    mesh = _resolve_mesh(mesh, B)
    m = int(mesh.devices.size)
    # >= 2 rows per device (see _resolve_mesh); an explicitly passed
    # Mesh wider than B/2 is honored by padding up to 2 rows per device
    padded = round_up(B, m) if m == 1 else round_up(max(B, 2 * m), m)
    sharding = NamedSharding(mesh, P(FLEET_AXIS))
    tr = _device_trace(wls, batched=True, pad_to=padded,
                       place=lambda t: jax.device_put(t, sharding),
                       place_key=tuple(d.id for d in mesh.devices.flat))
    n_apps, max_comp = wls[0].n_apps, wls[0].max_components
    # jit the fresh state straight into the sharded layout: a fresh
    # state is all zeros, so materializing it on the default device and
    # re-placing it would pay ~25 eager dispatches + transfers per run
    init_key = ("fleet_init", _cfg_key(cfg), n_apps, max_comp, padded,
                tuple(d.id for d in mesh.devices.flat))
    init_fn = _CHUNK_CACHE.get(init_key)
    if init_fn is None:
        init_fn = _CHUNK_CACHE[init_key] = jax.jit(
            lambda: init_state(cfg, n_apps, max_comp, batch=padded),
            out_shardings=sharding)
    st = init_fn()
    shapes_k = _shapes_key(wls[0], cfg)
    driver = _drive_chunks_leap if cfg.leap else _drive_chunks
    st, parts, drain = driver(
        cfg, chunk,
        lambda size, bucket: _shard_chunk_fn(cfg, size, shapes_k, mesh,
                                             bucket),
        tr, st)
    metrics = _concat_metrics(parts, axis=1)   # leaves: (padded, ticks)
    # ONE bulk device->host gather, then cheap NumPy slices per member:
    # slicing the sharded axis on device would pay a cross-device
    # gather per field per member
    st = jax.device_get(st)
    out = []
    for i, (c, w) in enumerate(zip(cfgs, wls)):
        st_i = jax.tree.map(lambda x, i=i: x[i], st)
        ms_i = jax.tree.map(lambda x, i=i: x[i], metrics)
        # padding members past the real fleet are never drained here
        out.append(drain_results(
            c, w, st_i, ms_i,
            obs=drain.history(i) if drain is not None else None))
    return out
