"""Batched experiment sweeps over the simulator (paper Figs. 3-4 grids).

The paper's headline results are *grids* — policy x forecaster x
safeguard (K1, K2) x **scenario** x seed.  This module makes that
space enumerable in one process:

  * ``expand_grid``      — cross-product a base ``SimConfig`` with axes
                           (dotted override paths, zipped tuple axes,
                           explicit cells) and seeds.  The special axis
                           key ``"scenario"`` swaps the base workload
                           for another registered family (diurnal,
                           flashcrowd, heavytail, colocated, replay,
                           ...), carrying over the shared scale knobs
                           (``n_apps``, ``max_components``, ``seed``);
  * ``ForecastBatcher``  — stacks the forecast windows of all
                           concurrently running sims into one padded JAX
                           batch, so the jitted GP/ARIMA path (and its
                           compilation, via the process-wide cache in
                           ``repro.sim.engine``) is amortized across the
                           whole grid.  Rows are independent, so results
                           are bit-identical to solo runs;
  * ``run_grid``         — thread-pooled, deterministic-per-seed driver
                           that runs every cell, aggregates
                           ``SimResults`` into the paper's metrics
                           (median turnaround speedup vs the SAME
                           scenario's baseline, failure rate,
                           utilization), attaches per-scenario trace
                           statistics and forecast-error diagnostics,
                           and writes a machine-readable
                           ``BENCH_sweep.json``.

CLI::

    python -m repro.sim.sweep --policy baseline,pessimistic \
        --forecaster persist,oracle \
        --scenario google,diurnal,flashcrowd,heavytail,colocated \
        --seeds 2 --out BENCH_sweep.json
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import itertools
import json
import os
import threading
import time
from typing import Any, Mapping, Sequence

import numpy as np

from repro.obs import (DEFAULT_RULES, REGISTRY, bucketed_row_overhead,
                       build_manifest, compact_history, evaluate_rules,
                       masked_row_overhead, render_dashboard,
                       write_alert_log, obs_summary, span, tracing,
                       write_manifest)
from repro.sim.cluster import ClusterConfig
from repro.sim.engine import (SimConfig, _BatchedForecaster, _make_model,
                              forecast_peaks, run_sim)
from repro.sim.metrics import aggregate_summaries, trace_stats
from repro.sim.scenarios import build_trace, make_config, scenario_of
from repro.sim.scenarios.diagnostics import forecast_reports
from repro.sim.workload import WorkloadConfig

__all__ = ["SweepCell", "SweepResult", "ForecastBatcher", "expand_grid",
           "run_grid", "quick_base_config", "main"]


# ----------------------------------------------------------------------
# grid expansion
# ----------------------------------------------------------------------

def _set_path(cfg: Any, path: str, value: Any) -> Any:
    """Functional update of a dotted field path on nested frozen
    dataclasses, e.g. ``_set_path(cfg, "safeguard.k1", 0.25)``."""
    head, _, rest = path.partition(".")
    if rest:
        return dataclasses.replace(
            cfg, **{head: _set_path(getattr(cfg, head), rest, value)})
    return dataclasses.replace(cfg, **{head: value})


# the "calibration" axis sweeps safeguard *modes* by name: the paper's
# fixed K2-sigma band, the conformal calibrated band, and the adaptive
# (budget-tracking) controller.  Field-level knobs remain reachable via
# dotted paths ("calibration.q", "calibration.budget", ...).
CALIBRATION_MODES: dict[str, dict] = {
    "sigma": dict(enabled=False, adaptive=False),
    "conformal": dict(enabled=True, adaptive=False),
    "adaptive": dict(enabled=True, adaptive=True),
}


# the "tenancy" axis sweeps control-plane *modes* by name: fully off
# (bit-identical to the pre-control-plane engines), accounting-only
# (shares/credit observed, nobody throttled), the wDRF admission gate,
# and the gate with credit-aware shaping on top.  Field-level knobs
# remain reachable via dotted paths ("control.slack", ...).
TENANCY_MODES: dict[str, dict] = {
    "off": dict(enabled=False),
    "ungated": dict(enabled=True, gate=False, credit=False),
    "wdrf": dict(enabled=True, gate=True, credit=False),
    "credit": dict(enabled=True, gate=True, credit=True),
}


def _apply_overrides(cfg: SimConfig, overrides: Mapping[str, Any]) -> SimConfig:
    # "scenario" swaps the whole workload config and must resolve before
    # any "workload.*" field override can land on the new family
    if "scenario" in overrides:
        cfg = dataclasses.replace(
            cfg, workload=make_config(overrides["scenario"],
                                      base=cfg.workload))
    for path, value in overrides.items():
        if path == "scenario":
            continue
        if path == "calibration" and isinstance(value, str):
            if value not in CALIBRATION_MODES:
                raise ValueError(
                    f"unknown calibration mode {value!r} "
                    f"(expected {sorted(CALIBRATION_MODES)})")
            cfg = dataclasses.replace(
                cfg, calibration=dataclasses.replace(
                    cfg.calibration, **CALIBRATION_MODES[value]))
            continue
        if path == "tenancy" and isinstance(value, str):
            if value not in TENANCY_MODES:
                raise ValueError(
                    f"unknown tenancy mode {value!r} "
                    f"(expected {sorted(TENANCY_MODES)})")
            cfg = dataclasses.replace(
                cfg, control=dataclasses.replace(
                    cfg.control, **TENANCY_MODES[value]))
            continue
        cfg = _set_path(cfg, path, value)
    return cfg


def _cell_name(overrides: Mapping[str, Any]) -> str:
    return ",".join(f"{k}={v}" for k, v in overrides.items()) or "base"


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One (configuration, seed) point of the grid."""

    name: str                  # combo label, shared across seeds
    overrides: dict            # dotted-path -> value, applied to the base
    seed: int
    cfg: SimConfig             # fully resolved (overrides + seed applied)
    scenario: str = "google"   # registry name of cfg.workload's family


def expand_grid(base: SimConfig,
                axes: Mapping[Any, Sequence[Any]] | None = None,
                seeds: Sequence[int] | None = None,
                cells: Sequence[Mapping[str, Any]] | None = None
                ) -> list[SweepCell]:
    """Cross product of ``axes`` (plus explicit ``cells``) x ``seeds``.

    ``axes`` maps an override path to its values.  A key may also be a
    tuple of paths whose values are tuples, zipped together — e.g.
    ``{("policy", "forecaster"): [("baseline", "persist"),
    ("pessimistic", "oracle")]}`` for the paper's paired Fig. 3 axis.
    ``seeds`` replace ``workload.seed``; ``None`` keeps the base seed.
    """
    combos: list[dict] = []
    axis_items = list((axes or {}).items())
    keys = [k if isinstance(k, tuple) else (k,) for k, _ in axis_items]
    # no axes + explicit cells = a cells-only grid (the zero-axis product
    # would otherwise smuggle in a spurious bare-base combo)
    if axis_items or not cells:
        for values in itertools.product(*(v for _, v in axis_items)):
            combo: dict = {}
            for ks, v in zip(keys, values):
                vs = v if isinstance(v, tuple) else (v,)
                if len(ks) != len(vs):
                    raise ValueError(f"axis {ks} expects {len(ks)}-tuples, "
                                     f"got {v!r}")
                combo.update(zip(ks, vs))
            combos.append(combo)
    combos.extend(dict(c) for c in cells or ())

    out = []
    for combo in combos:
        cfg = _apply_overrides(base, combo)
        scen = scenario_of(cfg.workload)
        for seed in (seeds if seeds is not None else (None,)):
            scfg = cfg if seed is None else _set_path(
                cfg, "workload.seed", int(seed))
            out.append(SweepCell(name=_cell_name(combo), overrides=combo,
                                 seed=scfg.workload.seed, cfg=scfg,
                                 scenario=scen))
    return out


# ----------------------------------------------------------------------
# cross-sim forecast batching
# ----------------------------------------------------------------------

class _Request:
    __slots__ = ("windows", "valid", "event", "result")

    def __init__(self, windows: np.ndarray, valid: np.ndarray):
        self.windows = windows
        self.valid = valid
        self.event = threading.Event()
        self.result = None


class ForecastBatcher:
    """Stacks concurrent forecast requests from many sims into one padded
    jitted call.

    Sims sharing a forecaster model (same frozen config, horizon, window
    width) land in the same batch key.  The first requester of a round
    becomes the leader: it waits until every *registered* sim of that key
    has a request pending (or a timeout elapses — a sim in its grace
    period requests nothing), concatenates the windows, runs ONE padded
    forecast through the shared jit cache, and distributes the row
    slices.  Rows are computed independently by the vmapped models, so
    every sim receives bit-identical values to a solo run.

    Two batching modes (results are identical either way — the mode only
    trades wall-clock against batch occupancy):

    * ``leader`` (default): the leader waits at most ``wait_s`` (2 ms) —
      low latency, but heterogeneous grids often fire partial cohorts;
    * ``barrier``: tick-synchronous — the leader waits up to
      ``barrier_timeout_s`` for the FULL registered cohort, so
      homogeneous grids (same forecaster/shape across cells, sims
      ticking in lockstep) batch whole rounds instead of whatever
      arrived within 2 ms.  The generous timeout is a liveness
      safety-net for cells still inside their grace period.

    Sims that tick WITHOUT requesting a forecast (grace period, empty
    cluster, baseline policy) signal it via :meth:`_tick_idle` (the
    engine calls ``client.idle()`` once per such tick): the leader
    counts DISTINCT idle sims toward the cohort, so full-cohort
    detection is exact and idle ticks stop costing the barrier timeout.
    Distinct-per-round counting matters: a non-requesting sim (e.g. a
    baseline-policy cell sharing a gp cohort key) ticks much faster
    than the forecasting sims, and counting its every tick would let
    idle credit accumulate until leaders fire solo batches.  The signal
    is advisory — an over-count merely fires a smaller batch early, and
    results are row-independent either way.
    """

    def __init__(self, wait_s: float = 0.002, mode: str = "leader",
                 barrier_timeout_s: float = 0.25):
        if mode not in ("leader", "barrier"):
            raise ValueError(f"unknown batch mode {mode!r} "
                             "(expected 'leader' or 'barrier')")
        self._wait_s = wait_s if mode == "leader" else barrier_timeout_s
        self.mode = mode
        self._cond = threading.Condition()
        self._pending: dict = {}    # key -> list[_Request] (current round)
        self._clients: dict = {}    # key -> registered sim count
        self._idle: dict = {}       # key -> ids of sims idle this round
        self.batches = 0            # rounds fired (introspection)
        self.requests = 0           # requests served

    def client(self, cfg: SimConfig):
        """forecast_fn for ``run_sim`` (None when the cell needs none)."""
        if cfg.forecaster in ("oracle",):
            return None
        if cfg.forecaster == "persist":
            return _BatchedForecaster(cfg)   # pure NumPy, nothing to batch
        model = _make_model(cfg)
        key = (model, cfg.horizon, cfg.window)
        return _BatcherClient(self, key, model, cfg.horizon)

    # -- internal ------------------------------------------------------
    def _register(self, key):
        with self._cond:
            self._clients[key] = self._clients.get(key, 0) + 1

    def _unregister(self, key):
        with self._cond:
            self._clients[key] -= 1
            self._cond.notify_all()   # a waiting leader may now be complete

    def _tick_idle(self, key, client_id):
        """One registered sim ticked without a forecast request."""
        with self._cond:
            self._idle.setdefault(key, set()).add(client_id)
            self._cond.notify_all()   # the leader's cohort may be complete

    def _forecast(self, key, model, horizon, windows, valid):
        req = _Request(windows, valid)
        with self._cond:
            batch = self._pending.setdefault(key, [])
            batch.append(req)
            leader = len(batch) == 1
            if leader:
                deadline = time.monotonic() + self._wait_s
                while (len(batch) + len(self._idle.get(key, ()))
                       < self._clients.get(key, 1)):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(remaining)
                self._pending[key] = []     # next arrival starts a new round
                self._idle[key] = set()
            else:
                self._cond.notify_all()
        if not leader:
            req.event.wait()
            if isinstance(req.result, BaseException):
                raise req.result
            return req.result

        try:
            rows = np.cumsum([0] + [r.windows.shape[0] for r in batch])
            mean, var = forecast_peaks(
                model, horizon,
                np.concatenate([r.windows for r in batch]),
                np.concatenate([r.valid for r in batch]))
        except BaseException as e:
            # wake every follower with the failure — a silent leader death
            # would deadlock their event.wait() and hang the whole sweep
            for r in batch:
                if r is not req:
                    r.result = e
                    r.event.set()
            raise
        with self._cond:
            self.batches += 1
            self.requests += len(batch)
        for r, lo, hi in zip(batch, rows[:-1], rows[1:]):
            r.result = (mean[lo:hi], var[lo:hi])
            if r is not req:
                r.event.set()
        return req.result


class _BatcherClient:
    """Per-sim handle: forwards forecast calls into the shared batcher."""

    def __init__(self, batcher: ForecastBatcher, key, model, horizon: int):
        self._batcher = batcher
        self._key = key
        self._model = model
        self._horizon = horizon
        batcher._register(key)

    def __call__(self, windows: np.ndarray, valid: np.ndarray):
        return self._batcher._forecast(self._key, self._model,
                                       self._horizon, windows, valid)

    def idle(self):
        """Engine signal: this sim's current tick needs no forecast."""
        self._batcher._tick_idle(self._key, id(self))

    def close(self):
        self._batcher._unregister(self._key)


# ----------------------------------------------------------------------
# sweep driver
# ----------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    cells: list[dict]          # one record per (combo, seed) run
    aggregates: list[dict]     # one record per combo (across seeds)
    base: dict                 # base SimConfig snapshot
    wall_s: float
    forecast_batches: int = 0
    forecast_requests: int = 0
    # per-scenario workload statistics (registry name -> trace_stats)
    scenarios: dict = dataclasses.field(default_factory=dict)
    # per-(scenario, forecaster) rolling forecast-error diagnostics
    forecast_error: list = dataclasses.field(default_factory=list)
    # per-(scenario, forecaster) Gaussian-vs-conformal coverage
    # diagnostics (schema 3; attached when the grid sweeps calibration)
    calibration: list = dataclasses.field(default_factory=list)
    # which engine actually ran the grid (additive schema-3 keys).
    # mesh_devices is the mesh width OFFERED to fleets — the shard
    # request clamped to the visible devices (0 = not sharded); each
    # fleet may still use fewer devices, since the per-fleet mesh is
    # capped at half its padded member count (see step._resolve_mesh)
    engine: str = "vectorized"
    mesh_devices: int = 0

    def to_json(self) -> dict:
        return {
            "schema": 3,
            "engine": self.engine,
            "mesh_devices": self.mesh_devices,
            "base": self.base,
            "cells": self.cells,
            "aggregates": self.aggregates,
            "scenarios": self.scenarios,
            "forecast_error": self.forecast_error,
            "calibration": self.calibration,
            "wall_s": self.wall_s,
            "forecast_batches": self.forecast_batches,
            "forecast_requests": self.forecast_requests,
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)


def _aggregate(cells: list[dict]) -> list[dict]:
    """Group per-seed cell records by combo; add the paper's metrics."""
    by_name: dict[str, list[dict]] = {}
    for c in cells:
        by_name.setdefault(c["name"], []).append(c)
    aggs = []
    for name, group in by_name.items():
        agg = aggregate_summaries([c["summary"] for c in group])
        aggs.append(dict(name=name, overrides=group[0]["overrides"],
                         scenario=group[0]["scenario"],
                         seeds=[c["seed"] for c in group],
                         wall_s=round(sum(c["wall_s"] for c in group), 2),
                         **agg))
    # the speedup denominator is the SAME scenario's baseline: turnaround
    # scales are not comparable across workload regimes.  Baseline ignores
    # the forecaster, so multiple baseline combos are interchangeable —
    # use the first per scenario.
    base_by_scen: dict[str, dict] = {}
    for a in aggs:
        if a["overrides"].get("policy") == "baseline":
            base_by_scen.setdefault(a["scenario"], a)
    for a in aggs:
        b = base_by_scen.get(a["scenario"])
        if b is not None:
            a["turnaround_speedup"] = (b["turnaround_mean"]
                                       / a["turnaround_mean"])
            a["turnaround_speedup_median"] = (
                b["turnaround_mean_median"] / a["turnaround_mean_median"])
    return aggs


def _run_grid(base: SimConfig,
              axes: Mapping[Any, Sequence[Any]] | None = None,
              seeds: Sequence[int] | None = None,
              cells: Sequence[Mapping[str, Any]] | None = None,
              *,
              workers: int | None = None,
              engine: str = "vectorized",
              batch_forecasts: bool = True,
              batch_mode: str = "leader",
              barrier_timeout_s: float = 0.25,
              chunk: int = 32,
              mesh: int | None = None,
              out_path: str | None = None,
              expect_completed: bool = False,
              forecast_diag: bool = True,
              alert_rules: Sequence = DEFAULT_RULES) -> SweepResult:
    """Grid execution body (see :func:`run_grid`, the public wrapper
    that adds telemetry, tracing and manifest writing around this).

    Cells run on a thread pool (NumPy/JAX release the GIL in kernels and
    the forecast batcher needs concurrency to stack windows); each cell
    is deterministic per seed regardless of scheduling, because forecast
    rows are computed independently.

    ``engine="scan"`` selects the device-resident scan engine
    (``repro.sim.step``): no thread pool and no forecast batcher —
    every cell runs as fused tick chunks on device, and each combo's
    whole SEED COHORT executes as one vmapped device program (the
    thread-pooled cross-sim batcher exists to amortize exactly the
    per-tick dispatch that the scan engine eliminates, so
    cohort-homogeneous grids retire it wholesale).  Per-seed results
    are bit-identical to solo ``run_sim_scan`` runs; ``chunk`` sets the
    ticks executed per device call.

    ``engine="shard"`` lays the scan engine's fleets across a device
    mesh with ``shard_map`` (``repro.sim.shard``): cells agreeing on
    every config knob except their workload (seeds AND scenarios) run
    as ONE SPMD program, ``mesh`` devices wide (None = all visible).
    Per-cell results stay bit-identical to ``engine="scan"``.  With a
    single visible device (CPU without forced host devices) the call
    gracefully falls back to ``scan``.

    ``forecast_diag`` attaches one rolling forecast-error record per
    (scenario, forecaster) pair in the grid — computed on series sampled
    from the scenario's ground-truth profiles, entirely outside the
    engines, so simulation results stay bit-identical either way.
    Grids that sweep calibration (a ``calibration`` axis or any
    calibration-enabled cell) additionally get one Gaussian-vs-conformal
    coverage record per pair (``result.calibration``) — like the
    forecast-error records, these are skipped when ``forecast_diag`` is
    off.

    ``batch_mode`` selects the forecast batcher's cohort policy
    (``"leader"`` = 2 ms leader timeout, ``"barrier"`` =
    tick-synchronous full-cohort rounds for homogeneous grids).
    """
    from concurrent.futures import ThreadPoolExecutor

    grid = expand_grid(base, axes, seeds, cells)
    if not grid:
        raise ValueError("empty sweep grid")
    mesh_devices = 0
    if engine == "shard":
        # graceful single-device fallback: a 1-wide mesh buys nothing
        # over the vmapped cohort path, so don't pay its placement.
        # An over-asking --mesh is clamped to the visible devices, NOT
        # an error — the fallback promise covers it
        from repro.sim.shard import device_count
        want = device_count() if mesh is None else int(mesh)
        want = max(1, min(want, device_count()))
        if want < 2:
            print("# engine=shard: single device visible — falling back "
                  "to engine=scan (on CPU set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=N)")
            engine = "scan"
        else:
            mesh = mesh_devices = want
    if engine == "vectorized":
        run_fn = run_sim
    elif engine == "reference":
        from repro.sim.engine_ref import run_sim_reference
        run_fn = run_sim_reference
    elif engine in ("scan", "shard"):
        run_fn = None                      # cohort/fleet paths below
    else:
        raise ValueError(f"unknown engine {engine!r}")
    batcher = (ForecastBatcher(mode=batch_mode,
                               barrier_timeout_s=barrier_timeout_s)
               if batch_forecasts and engine not in ("scan", "shard")
               else None)

    # one trace per unique scenario config: many cells share a
    # (config, seed) point and the engines never mutate a Trace, so
    # generation happens once, serially, and the arrays are shared
    # read-only across threads
    with span("build_traces", cat="build",
              args={"n": len({c.cfg.workload for c in grid})}):
        workloads = {cfg: build_trace(cfg)
                     for cfg in {cell.cfg.workload for cell in grid}}

    def _record(cell: SweepCell, res, wall_s: float) -> dict:
        s = res.summary()
        if expect_completed and s["completed"] != s["n_apps"]:
            raise RuntimeError(
                f"cell {cell.name} seed {cell.seed}: only {s['completed']}"
                f"/{s['n_apps']} apps completed (raise max_ticks?)")
        rec = dict(name=cell.name, overrides=cell.overrides,
                   scenario=cell.scenario, seed=cell.seed, summary=s,
                   wall_s=round(wall_s, 2))
        # telemetry blocks ride OUTSIDE summary (additive schema-3
        # keys): forecast-load counters with the derived masked-rows
        # overhead, and the obs-ring scalars when rings were on
        if res.forecast_rows is not None:
            rec["forecast_rows"] = dict(
                res.forecast_rows,
                masked_row_overhead=round(
                    masked_row_overhead(res.forecast_rows), 2))
            if res.forecast_rows.get("rows_bucketed"):
                # rows the model ACTUALLY computed (scan/shard engines;
                # compacted when SimConfig.forecast_bucket routed gp/
                # arima through the bucketed path) vs rows ready
                rec["forecast_rows"]["bucketed_row_overhead"] = round(
                    bucketed_row_overhead(res.forecast_rows), 2)
        if res.obs is not None:
            rec["obs"] = obs_summary(res.obs)
            # downsampled per-channel series for the dashboard
            # sparklines (event channels bucket-SUM so totals survive)
            rec["obs"]["history"] = compact_history(res.obs)
            if alert_rules:
                fired = evaluate_rules(
                    res.obs, alert_rules,
                    nominal_q=cell.cfg.calibration.q,
                    tenancy=res.tenancy)
                for a in fired:
                    a["cell"] = cell.name
                    a["seed"] = cell.seed
                rec["obs"]["alerts"] = fired
        return rec

    def one(cell: SweepCell) -> dict:
        t0 = time.time()
        client = batcher.client(cell.cfg) if batcher else None
        try:
            with span(f"cell:{cell.name}", cat="cell",
                      args={"seed": cell.seed}):
                res = run_fn(cell.cfg, workloads[cell.cfg.workload],
                             forecast_fn=client)
        finally:
            if client is not None and hasattr(client, "close"):
                client.close()
        return _record(cell, res, time.time() - t0)

    def scan_records() -> list[dict]:
        """Scan-engine driver: one vmapped device program per combo's
        seed cohort (serial over combos — the device is the parallel
        axis, not a thread pool)."""
        from repro.sim.step import run_cohort_scan, run_sim_scan
        by_combo: dict[str, list[SweepCell]] = {}
        for cell in grid:
            by_combo.setdefault(cell.name, []).append(cell)
        recs: dict[int, dict] = {}
        for cells_g in by_combo.values():
            base_cfg = cells_g[0].cfg
            seeds_g = [c.seed for c in cells_g]
            # a cohort needs identical configs modulo the workload seed
            strip = lambda c: _set_path(c, "workload.seed", 0)  # noqa: E731
            homogeneous = (len(cells_g) > 1
                           and len(set(seeds_g)) == len(seeds_g)
                           and all(strip(c.cfg) == strip(base_cfg)
                                   for c in cells_g))
            t0 = time.time()
            with span(f"cohort:{cells_g[0].name}", cat="cohort",
                      args={"seeds": len(cells_g),
                            "vmapped": homogeneous}):
                if homogeneous:
                    results = run_cohort_scan(
                        base_cfg, seeds_g, chunk=chunk,
                        wls=[workloads[c.cfg.workload] for c in cells_g])
                else:
                    results = [run_sim_scan(c.cfg,
                                            workloads[c.cfg.workload],
                                            chunk=chunk)
                               for c in cells_g]
            wall = (time.time() - t0) / len(cells_g)
            for cell, res in zip(cells_g, results):
                recs[id(cell)] = _record(cell, res, wall)
        return [recs[id(cell)] for cell in grid]

    t0 = time.time()
    if engine == "shard":
        from repro.sim.shard import run_shard_records
        records = run_shard_records(grid, workloads, _record,
                                    chunk=chunk, mesh=mesh)
    elif engine == "scan":
        records = scan_records()
    else:
        n_workers = workers or min(len(grid), os.cpu_count() or 4)
        if n_workers > 1:
            with ThreadPoolExecutor(max_workers=n_workers) as pool:
                records = list(pool.map(one, grid))
        else:
            records = [one(c) for c in grid]

    # per-scenario trace statistics + forecast-error diagnostics (one
    # record per (scenario, forecaster-model) pair seen in the grid);
    # grids with any calibration-ENABLED cell also get coverage
    # diagnostics per pair (a sigma-only axis exercises no conformal
    # code, so it pays for none)
    sweeps_cal = any(c.cfg.calibration.enabled for c in grid)
    scen_stats: dict[str, dict] = {}
    diag: list[dict] = []
    cal_diag: list[dict] = []
    seen_diag: set = set()
    with span("diagnostics", cat="diag"):
        for cell in grid:
            tr = workloads[cell.cfg.workload]
            scen_stats.setdefault(cell.scenario, trace_stats(tr))
            if not forecast_diag or cell.cfg.forecaster == "oracle":
                continue
            c = cell.cfg
            model_key = {"gp": c.gp, "arima": c.arima}.get(c.forecaster)
            key = (cell.scenario, c.forecaster, model_key, c.window)
            if key in seen_diag:
                continue
            seen_diag.add(key)
            # ONE shared rolling-forecast pass feeds both reports (the
            # sampling + forecasting dominates; previously each report
            # ran its own pass per (scenario, forecaster) pair)
            rep, cov = forecast_reports(tr, c.forecaster, window=c.window,
                                        coverage=sweeps_cal,
                                        gp=c.gp, arima=c.arima)
            if rep is not None:
                diag.append({"scenario": cell.scenario, **rep})
            if cov is not None:
                cal_diag.append({"scenario": cell.scenario, **cov})

    result = SweepResult(
        cells=records, aggregates=_aggregate(records),
        base=dataclasses.asdict(base), wall_s=round(time.time() - t0, 2),
        forecast_batches=batcher.batches if batcher else 0,
        forecast_requests=batcher.requests if batcher else 0,
        scenarios=scen_stats, forecast_error=diag, calibration=cal_diag,
        engine=engine, mesh_devices=mesh_devices)
    if out_path:
        result.write(out_path)
    return result


def run_grid(base: SimConfig,
             axes: Mapping[Any, Sequence[Any]] | None = None,
             seeds: Sequence[int] | None = None,
             cells: Sequence[Mapping[str, Any]] | None = None,
             *,
             workers: int | None = None,
             engine: str = "vectorized",
             batch_forecasts: bool = True,
             batch_mode: str = "leader",
             barrier_timeout_s: float = 0.25,
             chunk: int = 32,
             mesh: int | None = None,
             leap: bool = False,
             forecast_bucket: bool = True,
             out_path: str | None = None,
             expect_completed: bool = False,
             forecast_diag: bool = True,
             obs: bool = False,
             trace_path: str | None = None,
             manifest_path: str | None = None,
             alert_rules: Sequence = DEFAULT_RULES,
             alert_log_path: str | None = None,
             dashboard_path: str | None = None) -> SweepResult:
    """Expand and run a sweep grid; aggregate and optionally write JSON.

    See :func:`_run_grid` for the execution model (thread-pooled host
    engines, vmapped scan cohorts, shard_map fleets).  This wrapper
    adds the observability plane (``repro.obs``) around it:

    ``obs=True`` enables the device-side telemetry rings on every cell
    (``SimConfig.obs``; scan/shard engines only — the host engines
    ignore the flag): each cell record then carries an ``obs`` block of
    ring-derived scalars, and ``SimResults.obs`` the full per-tick
    histories.  Cells whose engine collects forecast-load telemetry
    additionally get a ``forecast_rows`` block with the derived
    ``masked_row_overhead`` (the padded-batch cost the BENCH_engine
    ``gp`` block tracks) and, on the scan/shard engines,
    ``bucketed_row_overhead`` (rows the model actually computed under
    ragged bucketing — see ``SimConfig.forecast_bucket``).

    ``leap=True`` sets ``SimConfig.leap`` on every cell: the scan/shard
    engines then skip provably-idle tick runs event-driven (bursty
    traces with long gaps cost ~the number of non-idle ticks).  Results
    are bit-identical to ``leap=False``; the host engines ignore it.
    ``forecast_bucket=False`` disables the ragged bucketed gp/arima
    batching on every cell (A/B lever for the overhead telemetry
    above; results are bit-identical either way).

    ``trace_path`` writes a Chrome trace-event / Perfetto JSON covering
    the driver phases (trace build, jit compile, chunk execute, ring
    drain, per-combo cohorts, diagnostics) — load it in
    ``chrome://tracing`` or https://ui.perfetto.dev.

    A run manifest (config hashes, jax/jaxlib versions, device
    topology, compile-time metrics, artifact paths) is written to
    ``manifest_path``, defaulting to ``<out_path minus .json>
    .manifest.json`` whenever ``out_path`` is set — so every
    BENCH_*.json is reproducible from its sidecar.  The manifest's
    cell hashes are recomputable from its own contents
    (:func:`repro.obs.load_manifest` verifies the round trip).

    Obs-enabled cells are additionally run through the alert watchdog
    (``alert_rules``, default :data:`repro.obs.DEFAULT_RULES`; pass an
    empty tuple to skip): fired alerts land in the per-cell ``obs``
    block, the manifest's un-hashed ``alerts`` extra, the labeled
    ``alerts.fired{rule,severity}`` REGISTRY counters, and — when
    ``out_path`` or ``alert_log_path`` is set — a JSONL alert log next
    to the results (``<out minus .json>.alerts.jsonl``).

    ``dashboard_path`` renders the self-contained HTML report
    (:func:`repro.obs.render_dashboard`) from the freshly written
    artifacts: per-cell ring sparklines with alert highlights, the
    span waterfall, the metrics snapshot, and the fired-alert table.
    """
    if obs:
        base = _set_path(base, "obs.enabled", True)
    if leap:
        base = _set_path(base, "leap", True)
    if not forecast_bucket:
        base = _set_path(base, "forecast_bucket", False)
    ctx = (tracing(trace_path) if trace_path is not None
           else contextlib.nullcontext())
    t0 = time.time()
    with ctx:
        result = _run_grid(
            base, axes, seeds, cells, workers=workers, engine=engine,
            batch_forecasts=batch_forecasts, batch_mode=batch_mode,
            barrier_timeout_s=barrier_timeout_s, chunk=chunk, mesh=mesh,
            out_path=out_path, expect_completed=expect_completed,
            forecast_diag=forecast_diag, alert_rules=alert_rules)
    alerts = [a for c in result.cells
              for a in (c.get("obs") or {}).get("alerts", [])]
    if alert_log_path is None and out_path and alerts:
        alert_log_path = (out_path[:-5] if out_path.endswith(".json")
                          else out_path) + ".alerts.jsonl"
    if alert_log_path:
        write_alert_log(alert_log_path, alerts)
    if manifest_path is None and out_path:
        manifest_path = (out_path[:-5] if out_path.endswith(".json")
                         else out_path) + ".manifest.json"
    man = None
    if manifest_path or dashboard_path:
        artifacts = {"results": out_path, "trace": trace_path,
                     "alerts": alert_log_path}
        man = build_manifest(
            base_config=result.base,
            cells=[{"name": c["name"], "scenario": c["scenario"],
                    "seed": c["seed"], "overrides": c["overrides"]}
                   for c in result.cells],
            engine=result.engine,
            artifacts={k: v for k, v in artifacts.items() if v},
            wall_s=time.time() - t0,
            metrics=REGISTRY.snapshot(),
            extra={"mesh_devices": result.mesh_devices, "chunk": chunk,
                   "obs": obs, "alerts": alerts})
    if manifest_path:
        write_manifest(manifest_path, man)
    if dashboard_path:
        # prefer the on-disk manifest so artifact-path resolution gets
        # exercised exactly as it would on a CI artifact download
        render_dashboard(manifest_path or man, dashboard_path,
                         results=None if (manifest_path and out_path)
                         else {"cells": result.cells})
    return result


# ----------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------

def quick_base_config(n_apps: int = 64, n_hosts: int = 4,
                      max_components: int = 8, seed: int = 0) -> SimConfig:
    """CI-scale base config: saturated little cluster, minutes of load."""
    return SimConfig(
        cluster=ClusterConfig(n_hosts=n_hosts, max_running_apps=48),
        workload=WorkloadConfig(n_apps=n_apps, max_components=max_components,
                                max_runtime=1800.0, mean_burst_gap=2.0,
                                mean_long_gap=40.0, seed=seed),
        max_ticks=20_000)


def _csv(kind):
    return lambda s: [kind(x) for x in s.split(",") if x]


def main(argv: Sequence[str] | None = None) -> SweepResult:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim.sweep",
        description="Run a policy x forecaster x safeguard sweep grid.")
    ap.add_argument("--policy", type=_csv(str),
                    default=["baseline", "optimistic", "pessimistic"])
    ap.add_argument("--forecaster", type=_csv(str),
                    default=["persist", "oracle"],
                    help="any of: persist,oracle,gp,arima")
    ap.add_argument("--scenario", type=_csv(str), default=None,
                    help="scenario axis, any registered family (e.g. "
                         "google,diurnal,flashcrowd,heavytail,colocated); "
                         "omitted = base workload only")
    ap.add_argument("--k1", type=_csv(float), default=None,
                    help="safeguard K1 axis (e.g. 0.0,0.05,0.25)")
    ap.add_argument("--k2", type=_csv(float), default=None,
                    help="safeguard K2 axis (e.g. 0.0,1.0,3.0)")
    ap.add_argument("--calibration", type=_csv(str), default=None,
                    help="safeguard-mode axis, any of: sigma (Eq. 9 "
                         "K2-band), conformal, adaptive")
    ap.add_argument("--tenancy", type=_csv(str), default=None,
                    help="control-plane mode axis, any of: off, ungated "
                         "(accounting only), wdrf (admission gate), "
                         "credit (gate + credit-aware shaping)")
    ap.add_argument("--tenants", type=int, default=None,
                    help="workload tenant count (workload.n_tenants); "
                         "tenants are Zipf-skewed over apps")
    ap.add_argument("--target-q", type=float, default=None,
                    help="conformal target quantile (calibration.q)")
    ap.add_argument("--budget", type=float, default=None,
                    help="adaptive failure-rate budget "
                         "(calibration.budget, target miscoverage)")
    ap.add_argument("--seeds", type=int, default=2,
                    help="number of workload seeds (0..N-1)")
    ap.add_argument("--apps", type=int, default=64)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--components", type=int, default=8)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--engine",
                    choices=("vectorized", "reference", "scan", "shard"),
                    default="vectorized",
                    help="vectorized = host loop; reference = frozen "
                         "seed loop; scan = device-resident fused tick "
                         "chunks with vmapped seed cohorts; shard = "
                         "scan fleets laid across a device mesh with "
                         "shard_map (falls back to scan on one device)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="scan/shard engines: ticks per device call")
    ap.add_argument("--mesh", type=int, default=None,
                    help="shard engine: mesh width in devices (default "
                         "all visible; on CPU force several with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--leap", action="store_true",
                    help="scan/shard engines: event-driven leap ticks "
                         "(skip provably-idle tick runs; bit-identical "
                         "to uniform ticks)")
    ap.add_argument("--no-bucket", action="store_true",
                    help="scan/shard engines: disable ragged bucketed "
                         "forecast batching (run gp/arima over the "
                         "full padded row batch every stride)")
    ap.add_argument("--no-batch", action="store_true",
                    help="disable cross-sim forecast batching")
    ap.add_argument("--batch-mode", choices=("leader", "barrier"),
                    default="leader",
                    help="forecast-batcher cohort policy: leader (2 ms "
                         "timeout) or barrier (tick-synchronous full "
                         "cohorts for homogeneous grids)")
    ap.add_argument("--no-diag", action="store_true",
                    help="skip per-scenario forecast-error and coverage "
                         "diagnostics")
    ap.add_argument("--obs", action="store_true",
                    help="enable device-side telemetry rings on every "
                         "cell (scan/shard engines; cell records gain "
                         "an obs block)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the sweep "
                         "driver phases (open in chrome://tracing or "
                         "ui.perfetto.dev)")
    ap.add_argument("--manifest", default=None, metavar="PATH",
                    help="run-manifest path (default: <out minus "
                         ".json>.manifest.json)")
    ap.add_argument("--dashboard", default=None, metavar="PATH",
                    help="render the self-contained HTML report "
                         "(sparklines, waterfall, fired alerts) to "
                         "PATH after the run")
    ap.add_argument("--alert-log", default=None, metavar="PATH",
                    help="JSONL fired-alert log (default: <out minus "
                         ".json>.alerts.jsonl when any alert fires)")
    ap.add_argument("--no-alerts", action="store_true",
                    help="skip the alert watchdog on obs-enabled cells")
    ap.add_argument("--out", default="BENCH_sweep.json")
    args = ap.parse_args(argv)
    if args.seeds < 1:
        ap.error("--seeds must be >= 1")

    base = quick_base_config(args.apps, args.hosts, args.components)
    if args.target_q is not None:
        base = _set_path(base, "calibration.q", args.target_q)
    if args.budget is not None:
        base = _set_path(base, "calibration.budget", args.budget)
    axes: dict = {}
    if args.scenario:
        axes["scenario"] = args.scenario
    axes.update({"policy": args.policy, "forecaster": args.forecaster})
    if args.k1:
        axes["safeguard.k1"] = args.k1
    if args.k2:
        axes["safeguard.k2"] = args.k2
    if args.calibration:
        axes["calibration"] = args.calibration
    if args.tenants is not None:
        base = _set_path(base, "workload.n_tenants", args.tenants)
    if args.tenancy:
        axes["tenancy"] = args.tenancy
    result = run_grid(base, axes, seeds=range(args.seeds),
                      workers=args.workers, engine=args.engine,
                      batch_forecasts=not args.no_batch,
                      batch_mode=args.batch_mode, chunk=args.chunk,
                      mesh=args.mesh, leap=args.leap,
                      forecast_bucket=not args.no_bucket,
                      forecast_diag=not args.no_diag, out_path=args.out,
                      obs=args.obs, trace_path=args.trace,
                      manifest_path=args.manifest,
                      alert_rules=() if args.no_alerts else DEFAULT_RULES,
                      alert_log_path=args.alert_log,
                      dashboard_path=args.dashboard)

    print(f"# {len(result.cells)} cells in {result.wall_s:.1f}s "
          f"({result.forecast_requests} forecast requests in "
          f"{result.forecast_batches} stacked batches) -> {args.out}")
    print("combo,seeds,turnaround_mean_s,speedup,failed_frac,util_mem")
    for a in result.aggregates:
        speed = a.get("turnaround_speedup", float("nan"))
        print(f"{a['name']},{a['n_seeds']},{a['turnaround_mean']:.0f},"
              f"{speed:.2f},{a['failed_frac']:.3f},"
              f"{a['util_mem_mean']:.3f}")
    for d in result.forecast_error:
        print(f"# forecast_error {d['scenario']}/{d['forecaster']}: "
              f"median_abs_rel={d['abs_rel_err_median']:.3f} "
              f"median_|z|={d['median_abs_z']:.2f}")
    for d in result.calibration:
        lv = next((r for r in d["levels"] if abs(r["q"] - 0.9) < 1e-9),
                  d["levels"][0])
        print(f"# coverage {d['scenario']}/{d['forecaster']} "
              f"q={lv['q']}: gaussian={lv['gaussian_coverage']:.3f} "
              f"conformal={lv['conformal_coverage']:.3f}")
    return result


if __name__ == "__main__":
    main()
