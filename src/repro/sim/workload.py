"""Google-shaped workload generator (the paper's §4.1 trace statistics).

The paper samples 150k batch applications from empirical distributions of
the public Google cluster traces [Reiss'11, Wilkes'11].  Those traces are
not downloadable in this offline environment, so we sample from parametric
families fitted to the *published* characteristics the paper quotes:

  * mix: rigid (TensorFlow-like) and elastic (Spark-like) applications —
    60% / 40% as in the paper's §5.1 workload;
  * components per application: "from a few to tens of thousands" —
    log-uniform, truncated at ``max_components`` for tractability (the
    simulator's tables are O(apps x components));
  * per-component demand: up to 6 CPU cores, few MB to dozens of GB RAM
    (log-uniform 256 MB .. 32 GB);
  * runtime: "a few dozens of seconds to several weeks" — log-uniform
    60 s .. ``max_runtime`` (heavy right tail);
  * inter-arrival: bi-modal — bursts (exponential, fast) mixed with long
    gaps, per the paper's description of the trace empiricals.

Utilization patterns: each component gets a piecewise-constant utilization
profile over SEGMENTS progress segments — a bounded random walk in
[min_level, 1.0] x reservation with occasional spikes toward the
reservation — mimicking the "fluctuating, peak-reserved" behavior the
paper describes (reservations are engineered for peak demand, so the peak
of every profile touches ~the reservation at least once).

This module is ONE workload source among several: it emits the canonical
:class:`~repro.sim.scenarios.schema.Trace` and registers in the scenario
registry as the ``"google"`` family (``Workload`` remains as a
backward-compatible alias of ``Trace``).  See ``repro.sim.scenarios``
for the other families (diurnal, flashcrowd, heavytail, colocated) and
the CSV/Parquet replay adapter.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.scenarios.families import _tenants
from repro.sim.scenarios.registry import register
from repro.sim.scenarios.schema import CPU, MEM, SEGMENTS, Trace  # noqa: F401

#: backward-compatible alias — the canonical schema lives in
#: repro.sim.scenarios.schema
Workload = Trace


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_apps: int = 500
    elastic_frac: float = 0.6
    max_components: int = 12       # core + elastic cap per app
    min_runtime: float = 120.0     # seconds
    max_runtime: float = 4 * 3600.0
    mean_burst_gap: float = 12.0   # bimodal inter-arrival: burst mode
    mean_long_gap: float = 600.0   # and long-gap mode
    burst_prob: float = 0.7
    # memory is the binding (finite) resource, as in the paper: the
    # mem:cpu demand ratio sits well above the hosts' 4 GB/core
    min_cpu: float = 0.25
    max_cpu: float = 2.0
    min_mem: float = 1.0           # GB
    max_mem: float = 32.0
    min_level: float = 0.10        # utilization floor (fraction of resv)
    spike_prob: float = 0.08       # per-segment probability of a peak
    jumpy_frac: float = 0.25       # "unpredictable" apps (cf. [66]): step
                                   # changes instead of smooth ramps
    seed: int = 0
    # control plane: Zipf-skewed tenant assignment (1 = single tenant,
    # bit-identical to the pre-tenancy generator)
    n_tenants: int = 1
    tenant_skew: float = 1.0


@register("google", WorkloadConfig,
          doc="the paper's Google-trace-shaped batch workload (§4.1)")
def generate(cfg: WorkloadConfig) -> Trace:
    rng = np.random.RandomState(cfg.seed)
    N, C = cfg.n_apps, cfg.max_components

    # --- arrival process: bimodal bursts + long gaps -------------------
    burst = rng.rand(N) < cfg.burst_prob
    gaps = np.where(burst,
                    rng.exponential(cfg.mean_burst_gap, N),
                    rng.exponential(cfg.mean_long_gap, N))
    submit = np.cumsum(gaps)

    # --- structure ------------------------------------------------------
    is_elastic = rng.rand(N) < cfg.elastic_frac
    # elastic apps (Spark-like): 3 core (controller/master/worker) + k
    # elastic workers carrying the bulk of the demand; rigid apps
    # (TF-like): 1-2 core components, no elastic.  The paper's traces are
    # overwhelmingly elastic-component-heavy (up to tens of thousands of
    # workers per app) — it is this elastic mass that Algorithm 1 evicts
    # first to absorb demand spikes without full preemptions.
    n_core = np.where(is_elastic, 3, rng.randint(1, 3, N))
    room = C - n_core
    n_elastic = np.where(is_elastic, rng.randint(2, np.maximum(room + 1, 3)), 0)
    n_elastic = np.minimum(n_elastic, room)

    idx = np.arange(C)[None, :]
    exists = idx < (n_core + n_elastic)[:, None]
    is_core = idx < n_core[:, None]

    # --- demands ---------------------------------------------------------
    cpu = np.round(np.exp(rng.uniform(np.log(cfg.min_cpu), np.log(cfg.max_cpu),
                                      (N, C))) * 4) / 4
    mem = np.exp(rng.uniform(np.log(cfg.min_mem), np.log(cfg.max_mem), (N, C)))
    # controller/master cores of elastic apps are lightweight coordinators
    light = is_elastic[:, None] & (idx < 2)
    cpu = np.where(light, np.minimum(cpu, 0.5), cpu)
    mem = np.where(light, np.minimum(mem, 2.0), mem)
    cpu_req = np.where(exists, np.maximum(cpu, cfg.min_cpu), 0.0).astype(np.float32)
    mem_req = np.where(exists, np.maximum(mem, cfg.min_mem), 0.0).astype(np.float32)

    # --- runtime (heavy right tail) ---------------------------------------
    runtime = np.exp(rng.uniform(np.log(cfg.min_runtime),
                                 np.log(cfg.max_runtime), N)).astype(np.float32)

    # --- utilization profiles: bounded random walk + spikes ---------------
    steps = rng.normal(0.0, 0.18, (N, C, SEGMENTS, 2))
    start = rng.uniform(cfg.min_level, 0.7, (N, C, 1, 2))
    walk = np.clip(start + np.cumsum(steps, axis=2), cfg.min_level, 1.0)
    spikes = rng.rand(N, C, SEGMENTS, 2) < cfg.spike_prob
    walk = np.where(spikes, rng.uniform(0.9, 1.0, walk.shape), walk)
    # guarantee every profile touches its reservation at least once
    # (reservations are engineered for peak demand — paper §1)
    peak_seg = rng.randint(0, SEGMENTS, (N, C, 1, 2))
    onehot = (np.arange(SEGMENTS)[None, None, :, None] == peak_seg)
    walk = np.where(onehot, np.maximum(walk, rng.uniform(0.92, 1.0, walk.shape)),
                    walk)
    levels = (walk * exists[:, :, None, None]).astype(np.float32)

    is_jumpy = rng.rand(N) < cfg.jumpy_frac
    # tenant draw LAST so n_tenants=1 (no draw) keeps the rng stream —
    # and therefore the whole trace — bit-identical to the seed generator
    tenant = _tenants(rng, N, cfg.n_tenants, cfg.tenant_skew)
    return Trace(submit=submit.astype(np.float32), is_elastic=is_elastic,
                 is_jumpy=is_jumpy,
                 n_core=n_core.astype(np.int64),
                 n_elastic=n_elastic.astype(np.int64),
                 runtime=runtime, cpu_req=cpu_req, mem_req=mem_req,
                 is_core=is_core & exists, levels=levels, cfg=cfg,
                 tenant=tenant).validate()
