"""Training substrate: losses + jit-able train steps per family."""
from repro.train.step import (TrainConfig, cross_entropy, make_train_step,
                              train_step_fn, whisper_step_fn)

__all__ = ["TrainConfig", "cross_entropy", "make_train_step",
           "train_step_fn", "whisper_step_fn"]
