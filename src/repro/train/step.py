"""Train steps: causal-LM and encoder-decoder, microbatch-accumulating.

``train_step_fn(params, opt_state, batch)`` is the function the dry-run
lowers: forward (scan-over-layers, remat policy from the ModelConfig),
vocab-parallel cross-entropy, backward, AdamW.  Gradient accumulation
over ``microbatches`` uses a ``lax.scan`` so the HLO stays compact and
XLA overlaps the per-microbatch grad reduce with the next microbatch's
backward (latency hiding at the pjit level).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optim: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    aux_weight: float = 0.01      # MoE load-balance loss weight
    z_weight: float = 1e-4        # z-loss (logit drift control)


def cross_entropy(logits: Array, labels: Array,
                  z_weight: float = 0.0) -> Array:
    """Mean token CE; computed in fp32 on (possibly vocab-sharded) logits."""
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    ce = (lse - gold).mean()
    if z_weight:
        ce = ce + z_weight * (lse ** 2).mean()
    return ce


def _lm_loss(params, cfg: ModelConfig, tc: TrainConfig, batch):
    logits, _, aux = T.forward(
        params, cfg, tokens=batch["tokens"],
        img_embeds=batch.get("img_embeds"))
    loss = cross_entropy(logits, batch["labels"], tc.z_weight)
    return loss + tc.aux_weight * aux, loss


def _whisper_loss(params, cfg: ModelConfig, tc: TrainConfig, batch):
    enc = W.encode(params, batch["frames"], cfg)
    logits, _ = W.decode(params, batch["dec_tokens"], enc, cfg)
    loss = cross_entropy(logits, batch["dec_labels"], tc.z_weight)
    return loss, loss


def _split_micro(batch, n: int):
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def _make_step(loss_fn):
    def step(params, opt_state, batch, *, cfg: ModelConfig,
             tc: TrainConfig):
        grad_fn = jax.grad(lambda p, b: loss_fn(p, cfg, tc, b),
                           has_aux=True)
        if tc.microbatches > 1:
            micro = _split_micro(batch, tc.microbatches)

            def acc(carry, mb):
                g_acc, l_acc = carry
                g, l = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
            loss = loss / tc.microbatches
        else:
            grads, loss = grad_fn(params, batch)
        new_params, new_opt, stats = adamw_update(
            grads, opt_state, params, tc.optim)
        stats = dict(stats, loss=loss)
        return new_params, new_opt, stats

    return step


train_step_fn = _make_step(_lm_loss)
whisper_step_fn = _make_step(_whisper_loss)


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    fn = whisper_step_fn if cfg.encdec else train_step_fn
    return functools.partial(fn, cfg=cfg, tc=tc)
