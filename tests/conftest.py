import os

# tests see the single real CPU device — the 512-device override belongs
# EXCLUSIVELY to the dry-run (src/repro/launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def optional_hypothesis():
    """(given, settings, st) — real hypothesis when installed, else shims
    that turn each property test into a runtime skip while the rest of the
    module still collects and runs (hypothesis is a dev extra)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
        return given, settings, st
    except ModuleNotFoundError:
        import pytest

        def given(*_a, **_k):
            def deco(fn):
                def skipped():
                    pytest.skip("hypothesis not installed")
                skipped.__name__ = fn.__name__
                return skipped
            return deco

        def settings(*_a, **_k):
            return lambda fn: fn

        class _AnyStrategy:
            """Absorbs any attribute / call chain (st.composite, st.integers,
            strategy objects, ...) — the shimmed ``given`` skips the test
            body, so the values never execute."""

            def __call__(self, *_a, **_k):
                return self

            def __getattr__(self, _name):
                return self

        return given, settings, _AnyStrategy()
