import os

# tests see the single real CPU device — the 512-device override belongs
# EXCLUSIVELY to the dry-run (src/repro/launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
