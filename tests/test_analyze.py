"""Telemetry analysis plane: detectors, alert rules, dashboard.

Contracts under test (docs/OBSERVABILITY.md "Alerting" / "Dashboard"):

  * detectors are vectorized post-drain NumPy — exact closed forms
    (EWMA blocked recursion, CUSUM cumsum-minus-running-min), quiet on
    stationary noise, firing on injected anomalies with bounded
    detection latency;
  * ``AlertRule`` sets evaluate per cell; fired alerts are typed
    records that reach the per-cell obs block, the manifest's un-hashed
    ``alerts`` extra, labeled REGISTRY counters, and the JSONL log;
  * the dashboard renders every ring channel and the fired-alert table
    into one self-contained HTML file;
  * spans record an error flag when the body raises (state intact),
    and concurrent spans from a thread pool produce a valid trace.
"""
import dataclasses
import json
import threading

import numpy as np
import pytest

from repro.obs import (DEFAULT_RULES, AlertRule, MetricsRegistry, Tracer,
                       compact_history, evaluate_rules, load_manifest,
                       obs_summary, render_dashboard, validate_trace,
                       write_alert_log)
from repro.obs.analyze import (burn_rate_detect, burst_detect,
                               coverage_drift_detect, cusum_detect, ewma,
                               ewma_detect, rolling_sum)
from repro.obs.rings import RING_FIELDS

CHANNELS = [f[0] if isinstance(f, tuple) else f for f in RING_FIELDS]
RNG = np.random.default_rng(7)


def _quiet_history(t=400):
    """Synthetic stationary history over all 13 ring channels."""
    h = {}
    for ch in CHANNELS:
        if ch in ("oom", "fail", "preempt", "throttled"):
            h[ch] = np.zeros(t)
        elif ch == "admitted":
            h[ch] = RNG.integers(0, 2, t).astype(np.float64)
        elif ch == "cov_resolved":
            h[ch] = np.full(t, 8.0)
        elif ch == "cov_errors":
            h[ch] = RNG.binomial(8, 0.1, t).astype(np.float64)
        elif ch == "queue":
            h[ch] = RNG.integers(3, 7, t).astype(np.float64)
        else:
            h[ch] = 20.0 + RNG.normal(0.0, 1.0, t)
    return h


# ----------------------------------------------------------------------
# detector primitives
# ----------------------------------------------------------------------

def test_ewma_matches_loop_reference():
    x = RNG.normal(0, 1, 700)
    alpha = 0.2
    ref = np.empty_like(x)
    ref[0] = x[0]
    for i in range(1, x.size):
        ref[i] = (1 - alpha) * ref[i - 1] + alpha * x[i]
    np.testing.assert_allclose(ewma(x, alpha), ref, rtol=0, atol=1e-12)
    np.testing.assert_array_equal(ewma(x, 1.0), x)   # alpha=1 is identity
    with pytest.raises(ValueError):
        ewma(x, 0.0)


def test_rolling_sum_trailing_windows():
    x = np.arange(6, dtype=float)
    np.testing.assert_array_equal(rolling_sum(x, 3),
                                  [3.0, 6.0, 9.0, 12.0])
    with pytest.raises(ValueError):
        rolling_sum(x, 0)


def test_ewma_detect_step_fires_noise_does_not():
    x = RNG.normal(10, 1, 600)
    quiet = ewma_detect(x, threshold=12.0, warmup=64)
    assert not quiet.fired
    x2 = x.copy()
    x2[300:] += 30.0                        # abrupt level jump
    det = ewma_detect(x2, threshold=12.0, warmup=64, channel="used_cpu")
    assert det.fired and det.channel == "used_cpu"
    assert det.first_tick == 300            # caught on the jump tick
    assert det.to_dict()["detector"] == "ewma"


def test_ewma_detect_short_series_skips():
    det = ewma_detect(np.ones(20), warmup=64)
    assert not det.fired and det.n_ticks == 20 and det.n_alarms == 0


def test_cusum_detect_drift_fires_stationary_does_not():
    x = RNG.normal(10, 1, 800)
    assert not cusum_detect(x, threshold=15.0, warmup=64).fired
    x2 = x.copy()
    x2[400:] += np.linspace(0, 4, 400)       # slow drift, no jump
    det = cusum_detect(x2, threshold=15.0, warmup=64)
    assert det.fired and det.first_tick > 400
    # the drift is slow enough that per-tick residuals stay small: the
    # EWMA chart must NOT see it (that's what CUSUM is for)
    assert not ewma_detect(x2, threshold=12.0, warmup=64).fired


def test_burst_detect_window_latency():
    x = np.zeros(300)
    x[100:110] = 2.0                        # 20 events in 10 ticks
    det = burst_detect(x, threshold=8.0, window=16)
    assert det.fired
    assert 100 <= det.first_tick <= 100 + 16
    assert not burst_detect(np.zeros(300), threshold=8.0, window=16).fired


def test_coverage_drift_under_not_over():
    t = 400
    resolved = np.full(t, 8.0)
    good = np.full(t, 0.8)                  # 10% errors at nominal 0.9
    assert not coverage_drift_detect(resolved, good, nominal=0.9,
                                     window=128).fired
    bad = good.copy()
    bad[200:] = 4.0                         # 50% errors from t=200
    det = coverage_drift_detect(resolved, bad, nominal=0.9, window=128)
    assert det.fired and det.first_tick >= 200
    # over-coverage (zero errors) is conservative, never an alarm
    assert not coverage_drift_detect(resolved, np.zeros(t),
                                     nominal=0.9, window=128).fired


def test_coverage_drift_clamps_and_skips_sparse():
    # a run shorter than the window still evaluates (window clamps)
    det = coverage_drift_detect(np.full(60, 8.0), np.full(60, 4.0),
                                nominal=0.9, window=256, min_resolved=32)
    assert det.fired
    # windows with too few resolutions are skipped entirely
    det = coverage_drift_detect(np.full(60, 0.1), np.full(60, 0.1),
                                nominal=0.9, window=16, min_resolved=32)
    assert det.n_alarms == 0


def test_burn_rate_needs_both_windows():
    t = 600
    exposure = np.full(t, 4.0)
    spike = np.zeros(t)
    spike[300:308] = 4.0                    # short spike only
    det = burn_rate_detect(spike, exposure, budget=0.05, threshold=4.0,
                           window=32, long_window=256)
    assert not det.fired                     # long window never burns
    sustained = np.zeros(t)
    sustained[300:] = 2.0                   # sustained 50% bad
    det = burn_rate_detect(sustained, exposure, budget=0.05,
                           threshold=4.0, window=32, long_window=256)
    assert det.fired and det.first_tick >= 300
    with pytest.raises(ValueError):
        burn_rate_detect(spike, exposure, budget=0.0)


# ----------------------------------------------------------------------
# alert rules
# ----------------------------------------------------------------------

def test_alert_rule_validation():
    with pytest.raises(ValueError, match="detector"):
        AlertRule("x", "oom", "nope", threshold=1.0)
    with pytest.raises(ValueError, match="severity"):
        AlertRule("x", "oom", "burst", threshold=1.0, severity="meh")
    # frozen + hashable like every config object
    assert hash(AlertRule("x", "oom", "burst", threshold=1.0))


def test_default_rules_quiet_on_stationary_history():
    fired = evaluate_rules(_quiet_history(), registry=None)
    assert fired == []


def test_evaluate_rules_fires_and_counts():
    h = _quiet_history()
    h["oom"] = np.zeros(400)
    h["oom"][200:210] = 2.0
    reg = MetricsRegistry()
    fired = evaluate_rules(h, registry=reg)
    # an OOM storm both trips the burst watchdog and burns SLO budget
    # (burn's bad series is fail + oom) — two pages, by design
    assert [a["rule"] for a in fired] == ["oom-burst", "slo-burn"]
    a = fired[0]
    assert a["severity"] == "page" and a["channel"] == "oom"
    assert 200 <= a["first_tick"] <= 216
    snap = reg.snapshot()
    key = 'alerts.fired{rule="oom-burst",severity="page"}'
    assert snap[key]["value"] == 1.0
    assert snap['alerts.fired{rule="slo-burn",severity="page"}']["value"] == 1.0
    assert snap["alerts.evaluated"]["value"] == len(DEFAULT_RULES) - 1


def test_evaluate_rules_skips_missing_channels():
    fired = evaluate_rules({"queue": np.zeros(10)}, registry=None)
    assert fired == []


def test_tenant_burn_uses_class_budgets():
    # tenant 0: best-effort (budget .25) at 50% misses -> burn 2.0
    # tenant 1: premium (budget .02) at 50% misses -> burn 25 -> fires
    tenancy = {"slo_met_frac": [0.5, 0.5, float("nan")],
               "slo_class": [0, 2, 0]}
    rule = AlertRule("tb", "slo_burn", "tenant_burn", threshold=4.0)
    fired = evaluate_rules({}, (rule,), tenancy=tenancy, registry=None)
    assert len(fired) == 1
    assert fired[0]["tenant"] == 1 and fired[0]["slo_class"] == "premium"
    assert fired[0]["peak_stat"] == pytest.approx(25.0)


def test_write_alert_log_appends_jsonl(tmp_path):
    path = tmp_path / "alerts.jsonl"
    write_alert_log(str(path), [{"rule": "r1", "cell": "c1"}])
    write_alert_log(str(path), [{"rule": "r2"}], cell="c2", run_id="x")
    write_alert_log(str(path), [])               # no-op, creates nothing
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["cell"] == "c1"              # record beats default
    assert lines[1]["cell"] == "c2" and lines[1]["run_id"] == "x"


# ----------------------------------------------------------------------
# report helpers
# ----------------------------------------------------------------------

def test_obs_summary_zero_resolved_emits_no_nan():
    h = {ch: np.zeros(8) for ch in CHANNELS}
    s = obs_summary(h)
    assert "coverage" not in s                   # no divide-by-zero NaN
    assert not any(isinstance(v, float) and np.isnan(v)
                   for v in s.values())


def test_compact_history_preserves_event_totals():
    h = {"oom": RNG.integers(0, 3, 1000).astype(np.float64),
         "used_cpu": RNG.normal(20, 2, 1000)}
    c = compact_history(h, max_points=100)
    assert c["ticks"] == 1000 and c["stride"] == 10
    assert len(c["channels"]["oom"]) == 100
    # event channels bucket-SUM: run totals survive downsampling
    assert sum(c["channels"]["oom"]) == pytest.approx(h["oom"].sum())
    # level channels bucket-MEAN: stays in the data's range
    assert 15 < min(c["channels"]["used_cpu"]) < 25
    short = compact_history({"oom": np.ones(50)}, max_points=100)
    assert short["stride"] == 1 and len(short["channels"]["oom"]) == 50
    assert compact_history({}) == {"ticks": 0, "stride": 1,
                                   "channels": {}}


# ----------------------------------------------------------------------
# metrics labels + prometheus exposition
# ----------------------------------------------------------------------

def test_labeled_metrics_are_distinct_series():
    reg = MetricsRegistry()
    reg.counter("alerts.fired", rule="a", severity="warn").inc()
    reg.counter("alerts.fired", severity="warn", rule="a").inc()  # same
    reg.counter("alerts.fired", rule="b", severity="page").inc(2)
    snap = reg.snapshot()
    assert snap['alerts.fired{rule="a",severity="warn"}']["value"] == 2.0
    assert snap['alerts.fired{rule="b",severity="page"}']["value"] == 2.0
    assert snap['alerts.fired{rule="a",severity="warn"}']["labels"] == \
        {"rule": "a", "severity": "warn"}


def test_textfile_help_type_once_per_family_and_escaping(tmp_path):
    reg = MetricsRegistry()
    reg.counter("alerts.fired", rule="r1", severity="warn").inc()
    reg.counter("alerts.fired", rule='q"\\\n', severity="page").inc()
    reg.set_help("alerts.fired", "fired alerts")
    reg.histogram("compile.s", phase="jit").observe(1.0)
    reg.histogram("compile.s", phase="run").observe(2.0)
    path = tmp_path / "m.prom"
    reg.write_textfile(str(path))
    text = path.read_text()
    # one HELP + one TYPE per family, not per series
    assert text.count("# TYPE alerts_fired counter") == 1
    assert text.count("# HELP alerts_fired fired alerts") == 1
    assert text.count("# TYPE compile_s summary") == 1
    # label values escaped per the exposition format
    assert 'rule="q\\"\\\\\\n"' in text
    assert 'compile_s_count{phase="jit"} 1' in text


# ----------------------------------------------------------------------
# span error flags + concurrency
# ----------------------------------------------------------------------

def test_span_records_error_flag_and_survives():
    tr = Tracer()
    with pytest.raises(KeyError):
        with tr.span("boom", args={"k": 1}):
            raise KeyError("x")
    with tr.span("after"):                      # tracer state intact
        pass
    evs = {e["name"]: e for e in tr.events}
    assert evs["boom"]["args"]["error"] == "KeyError"
    assert evs["boom"]["args"]["k"] == 1        # caller args preserved
    assert evs["boom"]["dur"] >= 0
    assert "args" not in evs["after"]
    assert validate_trace(tr.to_json()) == []


def test_concurrent_spans_from_thread_pool_are_valid():
    from concurrent.futures import ThreadPoolExecutor
    tr = Tracer()
    barrier = threading.Barrier(4)

    def cell(i):
        barrier.wait()                          # force real overlap
        with tr.span(f"cell:{i}", cat="cell"):
            if i == 2:
                raise RuntimeError("boom")

    with ThreadPoolExecutor(max_workers=4) as pool:
        futs = [pool.submit(cell, i) for i in range(4)]
        errs = [f.exception() for f in futs]
    assert sum(e is not None for e in errs) == 1
    assert len(tr.events) == 4
    assert len({e["tid"] for e in tr.events}) > 1
    flagged = [e for e in tr.events
               if e.get("args", {}).get("error")]
    assert len(flagged) == 1 and flagged[0]["name"] == "cell:2"
    assert validate_trace(tr.to_json()) == []   # ts stays monotone


# ----------------------------------------------------------------------
# dashboard
# ----------------------------------------------------------------------

def _fake_manifest(alerts):
    h = compact_history(_quiet_history(64))
    return {
        "run_id": "t", "engine": "scan", "wall_s": 1.0,
        "metrics": {"ticks": {"type": "counter", "value": 3.0},
                    "compile.s": {"type": "histogram", "count": 1,
                                  "sum": 1.0, "min": 1.0, "max": 1.0}},
        "alerts": alerts,
        "cells": [],
    }


def test_render_dashboard_embeds_channels_and_alerts(tmp_path):
    alerts = [{"rule": "oom-burst", "cell": "c0", "channel": "oom",
               "detector": "burst", "severity": "page",
               "peak_stat": 12.0, "threshold": 8.0,
               "first_tick": 10, "last_tick": 20}]
    man = _fake_manifest(alerts)
    man["cells"] = [{"name": "c0",
                     "obs": {"history":
                             compact_history(_quiet_history(64))}}]
    out = tmp_path / "report.html"
    render_dashboard(man, str(out), results={"cells": man["cells"]},
                     trace={"traceEvents": [
                         {"name": "s", "cat": "x", "ph": "X", "ts": 0,
                          "dur": 5.0, "pid": 1, "tid": 1}]},
                     bench_docs={"BENCH_x.json":
                                 {"criteria": {"ok": True, "bad": False}}})
    html = out.read_text()
    for ch in CHANNELS:
        assert f">{ch}<" in html, f"channel {ch} missing"
    assert "oom-burst" in html and "fired alerts" in html
    assert "● page" in html                     # severity icon + label
    assert "✓ pass" in html and "✗ FAIL" in html
    assert "nan" not in html.lower().replace("tenan", "")


def test_render_dashboard_from_files(tmp_path):
    man = _fake_manifest([])
    man["artifacts"] = {"results": "r.json"}
    (tmp_path / "r.json").write_text(json.dumps(
        {"cells": [{"name": "c0", "obs":
                    {"history": compact_history(_quiet_history(32))}}]}))
    mpath = tmp_path / "m.manifest.json"
    mpath.write_text(json.dumps(man))
    out = render_dashboard(str(mpath), str(tmp_path / "r.html"))
    html = (tmp_path / "r.html").read_text()
    assert out.endswith("r.html")
    assert "no alerts fired" in html
    assert html.count("<svg") >= len(CHANNELS)


# ----------------------------------------------------------------------
# sweep wiring (one tiny end-to-end grid)
# ----------------------------------------------------------------------

def test_run_grid_alerts_manifest_dashboard(tmp_path):
    from repro.sim.sweep import quick_base_config, run_grid

    out = tmp_path / "grid.json"
    report = tmp_path / "report.html"
    base = quick_base_config(n_apps=12, n_hosts=2, max_components=4)
    smoke = AlertRule("smoke-admitted", "admitted", "burst",
                      threshold=1.0, severity="info", window=8)
    res = run_grid(base, {"policy": ["pessimistic"],
                          "forecaster": ["persist"]},
                   seeds=[0], engine="scan", obs=True,
                   out_path=str(out), forecast_diag=False,
                   alert_rules=(smoke,), dashboard_path=str(report))
    rec = res.cells[0]
    assert rec["obs"]["history"]["ticks"] == rec["obs"]["ticks"]
    assert [a["rule"] for a in rec["obs"]["alerts"]] == ["smoke-admitted"]
    man = load_manifest(str(tmp_path / "grid.manifest.json"), verify=True)
    assert [a["rule"] for a in man["alerts"]] == ["smoke-admitted"]
    assert man["artifacts"]["alerts"] == str(out)[:-5] + ".alerts.jsonl"
    logged = [json.loads(ln) for ln in
              open(man["artifacts"]["alerts"]).read().splitlines()]
    assert logged and logged[0]["rule"] == "smoke-admitted"
    html = report.read_text()
    assert "smoke-admitted" in html
    for ch in CHANNELS:
        assert f">{ch}<" in html
