"""Multi-tenant control-plane tests: wDRF share/fairness math, the
credit score, gate determinism, engine wiring (host + scan), tenant-less
back-compat, and the replay schema's optional tenancy columns."""
import dataclasses

import numpy as np
import pytest

from repro.control import (SLO_CLASSES, TenancyConfig, credit_quantile,
                           credit_step, dominant_shares, gate_mask,
                           jain_index, resolve_weights)
from repro.core.uncertainty import CalibrationConfig
from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, generate, run_sim
from repro.sim.step import run_sim_scan

WL = WorkloadConfig(n_apps=24, max_components=6, max_runtime=1200.0,
                    mean_burst_gap=4.0, mean_long_gap=60.0, seed=7,
                    n_tenants=4)
CL = ClusterConfig(n_hosts=3, max_running_apps=16)
BASE = SimConfig(cluster=CL, workload=WL, max_ticks=3000,
                 policy="pessimistic", forecaster="persist",
                 calibration=CalibrationConfig(enabled=True, adaptive=True),
                 control=TenancyConfig(enabled=True))


# ----------------------------------------------------------------------
# formula layer (np path; the jnp path is exercised via the scan engine)
# ----------------------------------------------------------------------

def test_jain_index_bounds():
    # equal shares -> 1; one tenant hogging everything -> 1/n
    assert jain_index(np.full(4, 0.25, np.float32)) == pytest.approx(1.0)
    one_hot = np.asarray([1.0, 0.0, 0.0, 0.0], np.float32)
    assert jain_index(one_hot) == pytest.approx(0.25)
    # the active mask drops idle tenants from the denominator
    assert jain_index(one_hot, active=np.asarray([True] + [False] * 3)) \
        == pytest.approx(1.0)
    # no active tenant: vacuously fair (guarded division)
    assert jain_index(np.zeros(3, np.float32)) == pytest.approx(1.0)


def test_dominant_shares_wdrf():
    alloc = np.asarray([[8.0, 4.0],     # cpu-dominant: 8/16 = 0.5
                        [2.0, 16.0]], np.float32)   # mem-dominant: 16/32
    cap = np.asarray([16.0, 32.0], np.float32)
    shares = dominant_shares(alloc, cap, np.ones(2, np.float32))
    np.testing.assert_allclose(shares, [0.5, 0.5])
    # a weight-2 tenant is entitled to twice the share: wDRF halves it
    w = dominant_shares(alloc, cap, np.asarray([2.0, 1.0], np.float32))
    np.testing.assert_allclose(w, [0.25, 0.5])


def test_gate_mask_throttles_above_mean_plus_slack():
    shares = np.asarray([0.6, 0.1, 0.1, 0.0], np.float32)
    active = np.asarray([True, True, True, False])
    elig = gate_mask(shares, active, 0.1)
    # mean over active = 0.2667; only tenant 0 exceeds +slack
    assert elig.tolist() == [False, True, True, True]
    # inactive tenants are always eligible (they hold nothing)
    assert elig[3]


def test_credit_step_ema_and_floor():
    c0 = np.full(3, 0.5, np.float32)
    good = np.asarray([4, 0, 0])
    bad = np.asarray([0, 4, 0])
    c1 = credit_step(c0, good, bad, gamma=0.5, floor=0.05)
    assert c1[0] == pytest.approx(0.75)       # toward 1.0
    assert c1[1] == pytest.approx(0.25)       # toward 0.0
    assert c1[2] == pytest.approx(0.5)        # no events: unchanged
    # repeated failures bottom out at the floor, never 0
    c = np.full(1, 0.5, np.float32)
    for _ in range(50):
        c = credit_step(c, np.zeros(1, int), np.full(1, 9), 0.5, 0.05)
    assert c[0] == pytest.approx(0.05)


def test_credit_quantile_spread_and_clip():
    credit = np.asarray([0.5, 0.0, 1.0], np.float32)
    q = credit_quantile(credit, 0.9, spread=0.05, q_min=0.5, q_max=0.92)
    assert q[0] == pytest.approx(0.9)         # neutral keeps the target
    assert q[1] == pytest.approx(0.92)        # low credit widens (clipped)
    assert q[2] == pytest.approx(0.85)        # high credit sharpens


def test_resolve_weights_validation():
    cfg = TenancyConfig(max_tenants=4, weights=(2.0, 1.0))
    np.testing.assert_allclose(resolve_weights(cfg), [2.0, 1.0, 1.0, 1.0])
    with pytest.raises(ValueError):
        resolve_weights(TenancyConfig(max_tenants=2, weights=(1.0,) * 3))
    with pytest.raises(ValueError):
        resolve_weights(TenancyConfig(weights=(0.0,)))


# ----------------------------------------------------------------------
# engine wiring
# ----------------------------------------------------------------------

def test_gated_admission_deterministic():
    wl = generate(WL)
    a = run_sim(BASE, wl)
    b = run_sim(BASE, wl)
    assert a.tenancy == b.tenancy
    assert a.summary() == b.summary()


def test_host_and_scan_agree_with_control_on():
    wl = generate(WL)
    h = run_sim(BASE, wl)
    s = run_sim_scan(BASE, wl, chunk=16)
    for k in ("n_tenants", "admitted", "throttled", "completed",
              "failed_apps", "active_ticks"):
        assert h.tenancy[k] == s.tenancy[k], k
    np.testing.assert_allclose(h.tenancy["credit"], s.tenancy["credit"],
                               rtol=1e-4)
    np.testing.assert_allclose(h.tenancy["mean_share"],
                               s.tenancy["mean_share"], rtol=1e-4)
    # per-tenant conformal pools resolve identically on both engines
    assert h.calibration["groups"] == s.calibration["groups"]


def test_scan_chunk_invariance_with_control():
    wl = generate(WL)
    r1 = run_sim_scan(BASE, wl, chunk=1)
    r32 = run_sim_scan(BASE, wl, chunk=32)
    assert r1.summary() == r32.summary()
    assert r1.tenancy == r32.tenancy


def test_wdrf_gate_improves_jain_on_skewed_tenants():
    """The acceptance criterion's shape at CI scale: a Zipf-skewed
    4-tenant population on a saturated cluster is measurably fairer
    (Jain index of the mean dominant shares) with the wDRF gate on."""
    wl = generate(WL)
    gated = run_sim(BASE, wl)
    ungated = run_sim(dataclasses.replace(
        BASE, control=TenancyConfig(enabled=True, gate=False,
                                    credit=False)), wl)
    assert gated.tenancy["jain_mean_share"] \
        > ungated.tenancy["jain_mean_share"]
    assert sum(gated.tenancy["throttled"]) > 0
    # the gate defers work, it must not lose any
    assert sum(gated.tenancy["completed"]) == wl.n_apps


def test_tenancy_summary_shape():
    res = run_sim(BASE, generate(WL))
    ten = res.summary()["tenancy"]
    T = ten["n_tenants"]
    assert T == 4
    for k in ("mean_share", "credit", "admitted", "throttled", "completed",
              "failed_apps", "turnaround_mean", "slo_met_frac"):
        assert len(ten[k]) == T, k
    assert 0.0 < ten["jain_mean_share"] <= 1.0
    # admissions cover every completed app (each admission-requeue pair
    # re-admits, so admitted >= completed)
    assert all(a >= c for a, c in zip(ten["admitted"], ten["completed"]))


def test_control_off_emits_no_tenancy():
    cfg = dataclasses.replace(BASE, control=TenancyConfig(enabled=False))
    res = run_sim(cfg, generate(WL))
    assert res.tenancy is None
    assert "tenancy" not in res.summary()
    assert "groups" not in res.calibration


def test_too_many_tenants_rejected():
    cfg = dataclasses.replace(
        BASE, control=TenancyConfig(enabled=True, max_tenants=2))
    with pytest.raises(ValueError, match="tenant"):
        run_sim(cfg, generate(WL))


def test_engine_ref_rejects_control():
    from repro.sim.engine_ref import run_sim_reference
    with pytest.raises(NotImplementedError):
        run_sim_reference(BASE, generate(WL))


# ----------------------------------------------------------------------
# tenant-less back-compat + replay schema
# ----------------------------------------------------------------------

def test_single_tenant_trace_identical_to_pre_tenancy_generator():
    """n_tenants=1 draws nothing from the rng, so the whole trace — and
    therefore every engine result — is bit-identical to the seed
    generator's output."""
    wl0 = generate(dataclasses.replace(WL, n_tenants=1))
    wl1 = generate(dataclasses.replace(WL, n_tenants=1, tenant_skew=2.0))
    for f in ("submit", "runtime", "cpu_req", "mem_req", "levels"):
        np.testing.assert_array_equal(getattr(wl0, f), getattr(wl1, f))
    assert (wl0.tenant == 0).all() and wl0.n_tenants == 1


def test_replay_tenantless_csv_backcompat(tmp_path):
    """Pre-control-plane replay files (no tenant_id / slo_class columns)
    load as a single tenant 0 on the weakest SLO class."""
    from repro.sim.scenarios.replay import load_trace
    p = tmp_path / "old.csv"
    p.write_text(
        "app_id,submit,runtime,is_elastic,is_jumpy,component,is_core,"
        "cpu_req,mem_req,cpu_levels,mem_levels\n"
        "a,0.0,100.0,0,0,0,1,2.0,4.0,0.5;0.6,0.4;0.4\n"
        "b,5.0,80.0,0,0,0,1,1.0,2.0,0.3;0.3,0.2;0.2\n")
    tr = load_trace(str(p))
    assert tr.n_apps == 2
    assert (tr.tenant == 0).all() and (tr.slo == 0).all()
    assert tr.n_tenants == 1


def test_replay_roundtrip_preserves_tenancy(tmp_path):
    from repro.sim.scenarios.replay import load_trace, save_trace
    wl = generate(WL)
    p = tmp_path / "t.csv"
    save_trace(wl, str(p))
    back = load_trace(str(p))
    np.testing.assert_array_equal(back.tenant, wl.tenant)
    np.testing.assert_array_equal(back.slo, wl.slo)


def test_fixture_traces_carry_tenants():
    """The azure/alibaba tiny fixtures tag their rows with tenants (and
    symbolic ids re-encode densely)."""
    from repro.sim.scenarios.replay import load_trace
    az = load_trace("tests/data/azure_tiny.csv", preset="azure")
    al = load_trace("tests/data/alibaba_tiny.csv", preset="alibaba")
    assert az.n_tenants > 1
    assert al.n_tenants > 1
    assert set(SLO_CLASSES) == {"best-effort", "standard", "premium"}


# ----------------------------------------------------------------------
# sweep axis
# ----------------------------------------------------------------------

def test_tenancy_sweep_axis():
    from repro.sim.sweep import TENANCY_MODES, expand_grid
    grid = expand_grid(BASE, {"tenancy": list(TENANCY_MODES)})
    by = {c.overrides["tenancy"]: c.cfg.control for c in grid}
    assert not by["off"].enabled
    assert by["ungated"].enabled and not by["ungated"].gate
    assert by["wdrf"].gate and not by["wdrf"].credit
    assert by["credit"].gate and by["credit"].credit


def test_tenancy_mode_unknown_rejected():
    from repro.sim.sweep import expand_grid
    with pytest.raises(ValueError, match="tenancy"):
        expand_grid(BASE, {"tenancy": ["bogus"]})
