"""Docs cannot rot: executable snippets + markdown link check.

Every fenced ``python`` block in the README and docs/ is
syntax-checked, and — unless annotated with an HTML comment
``<!-- docs-smoke: compile-only -->`` just above the fence, or
containing a literal ``...`` placeholder — EXECUTED, so import paths
and kwargs in the docs track the code.  ``sh`` blocks are not run, but
every ``python -m <module>`` they mention must resolve to an importable
module.  All relative markdown links (including anchors-free file
targets in tables) must point at files that exist.
"""
import importlib.util
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
SNIPPET_FILES = ("README.md", "docs/ARCHITECTURE.md",
                 "docs/BENCHMARKS.md", "docs/CONTROL_PLANE.md",
                 "docs/OBSERVABILITY.md")
COMPILE_ONLY = "docs-smoke: compile-only"


def _blocks(relpath: str):
    """[(first_code_line, lang, code, runnable)] for one markdown file."""
    lines = (ROOT / relpath).read_text().splitlines()
    out = []
    i = 0
    while i < len(lines):
        m = re.match(r"^```(\w+)\s*$", lines[i])
        if m:
            start = i + 1
            j = start
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            code = "\n".join(lines[start:j])
            prev = [ln for ln in lines[max(0, i - 3):i] if ln.strip()]
            marked = any(COMPILE_ONLY in ln for ln in prev)
            runnable = not marked and "..." not in code
            out.append((start + 1, m.group(1), code, runnable))
            i = j + 1
        else:
            i += 1
    return out


@pytest.mark.parametrize("relpath", SNIPPET_FILES)
def test_python_blocks_compile(relpath):
    blocks = [b for b in _blocks(relpath) if b[1] == "python"]
    if relpath != "docs/BENCHMARKS.md":   # reference doc: sh-only is fine
        assert blocks, f"{relpath}: no python blocks found"
    for lineno, _, code, _ in blocks:
        compile(code, f"{relpath}:{lineno}", "exec")


@pytest.mark.parametrize("relpath", SNIPPET_FILES)
def test_python_blocks_execute(relpath):
    """Runnable blocks execute top-to-bottom in one shared namespace
    per file (later snippets may build on earlier imports)."""
    ns: dict = {"__name__": f"docs_smoke_{Path(relpath).stem}"}
    ran = 0
    for lineno, lang, code, runnable in _blocks(relpath):
        if lang != "python" or not runnable:
            continue
        try:
            exec(compile(code, f"{relpath}:{lineno}", "exec"), ns)
        except Exception as e:          # pragma: no cover - diagnostic
            pytest.fail(f"{relpath}:{lineno}: snippet raised {e!r}")
        ran += 1
    if relpath != "docs/BENCHMARKS.md":   # reference doc: sh-only is fine
        assert ran, f"{relpath}: every python block is marked " \
                    "compile-only — docs would rot silently"


def test_sh_blocks_reference_importable_modules():
    seen = set()
    for relpath in SNIPPET_FILES:
        for _, lang, code, _ in _blocks(relpath):
            if lang != "sh":
                continue
            seen |= set(re.findall(r"python3? -m ([\w.]+)", code))
    assert seen, "no `python -m` references found in sh blocks"
    for mod in sorted(seen):
        assert importlib.util.find_spec(mod) is not None, \
            f"docs reference `python -m {mod}` but it does not resolve"


def test_run_grid_kwargs_match_docs():
    """The engine/mesh kwargs the docs advertise must stay real."""
    import inspect

    from repro.sim.step import run_fleet_shard
    from repro.sim.sweep import run_grid
    grid_params = inspect.signature(run_grid).parameters
    for kw in ("engine", "mesh", "chunk", "workers", "out_path"):
        assert kw in grid_params, kw
    fleet_params = inspect.signature(run_fleet_shard).parameters
    for kw in ("chunk", "wls", "cfgs", "mesh"):
        assert kw in fleet_params, kw


def _md_files():
    return sorted(set(ROOT.glob("*.md")) | set((ROOT / "docs").glob("*.md")))


def test_markdown_relative_links_resolve():
    bad = []
    for md in _md_files():
        text = md.read_text()
        # strip fenced code (snippet pseudo-links are not navigation)
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for label, target in re.findall(r"\[([^\]]*)\]\(([^)\s]+)\)", text):
            if re.match(r"^(https?|mailto):", target) or target.startswith("#"):
                continue
            path = (md.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                bad.append(f"{md.relative_to(ROOT)}: [{label}]({target})")
    assert not bad, "dangling relative links:\n" + "\n".join(bad)
