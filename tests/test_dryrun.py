"""Dry-run launch path: production meshes + a real (reduced-size) cell
compiled in a subprocess with 512 placeholder devices."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=f"{ROOT}/src")


def test_production_meshes_build():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.shape == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert m2.shape == {"pod": 2, "data": 16, "model": 16}, m2.shape
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], env=ENV,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_dryrun_cell_compiles(tmp_path):
    """One smoke-size cell through the real dryrun CLI on both meshes."""
    out_json = str(tmp_path / "res.json")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "internlm2-1.8b", "--shape", "train_4k",
         "--out", out_json],
        env=ENV, capture_output=True, text=True, timeout=900, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.load(open(out_json))
    for mesh in ("single", "multi"):
        rec = res[f"internlm2-1.8b|train_4k|{mesh}"]
        assert rec["ok"], rec
        assert rec["cost"]["flops"] > 0
        assert rec["hlo"]["flops"] >= rec["cost"]["flops"]  # loop-corrected
        assert rec["collectives"]["count"] > 0              # TP collectives


def test_full_dryrun_results_if_present():
    """Validate the committed full-size dry-run artifact (all 40 cells x
    2 meshes: every cell either ok or an eligibility skip)."""
    path = os.path.join(ROOT, "dryrun_results.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("full dry-run artifact not generated yet")
    res = json.load(open(path))
    from repro.launch.specs import SHAPES
    from repro.models import ARCHS
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                rec = res.get(f"{arch}|{shape}|{mesh}")
                assert rec is not None, f"missing {arch}|{shape}|{mesh}"
                assert rec.get("ok") or rec.get("skipped"), rec
