"""Extra coverage: sharding rules, chunkwise mLSTM, MoE dispatch
properties, serving engine, checkpoint-mode ablation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.models import get_config
from repro.models import transformer as T
from repro.models import xlstm as X

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# sharding rules
# ----------------------------------------------------------------------

def test_param_specs_shapes_and_rules():
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as Sh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("glm4-9b", smoke=True)
    params = jax.eval_shape(lambda: T.init_lm(KEY, cfg))
    specs = Sh.param_specs(params, mesh)
    # column-parallel q projection: stacked (L, d, q_dim) -> model on last
    assert tuple(specs["blocks"]["attn"]["wq"])[-1] == "model"
    # row-parallel output: model on the second-to-last dim
    wo = tuple(specs["blocks"]["attn"]["wo"])
    assert wo[-2] == "model" and wo[-1] is None
    # norms replicate
    assert tuple(specs["blocks"]["ln1"]) == ()
    # every leaf got a spec
    assert (len(jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)))
            == len(jax.tree.leaves(params)))


def test_moe_expert_specs():
    from repro.distributed import sharding as Sh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = jax.eval_shape(lambda: T.init_lm(KEY, cfg))
    specs = Sh.param_specs(params, mesh)
    # expert tensors shard the EXPERT dim over model: (L, E, d, ff)
    assert tuple(specs["blocks"]["moe"]["gate"])[-3] == "model"


def test_zero_shard_moments():
    from jax.sharding import AbstractMesh
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as Sh
    try:
        mesh = AbstractMesh((4, 1), ("data", "model"))
    except TypeError:   # jax<=0.4.x: AbstractMesh(((name, size), ...))
        mesh = AbstractMesh((("data", 4), ("model", 1)))
    leaf = jnp.zeros((8, 64))
    out = Sh.zero_shard(P(), leaf, mesh)
    assert tuple(out)[0] in ("data", ("data",))  # first divisible dim sharded
    # indivisible everywhere -> unchanged
    odd = jnp.zeros((3, 5))
    assert tuple(Sh.zero_shard(P(), odd, mesh)) == (None, None)


# ----------------------------------------------------------------------
# chunkwise mLSTM == sequential (property over shapes/chunks)
# ----------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([16, 32]), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 100))
def test_mlstm_chunkwise_matches_sequential(s, chunk, seed):
    cfg = get_config("xlstm-1.3b", smoke=True)
    cfg_c = dataclasses.replace(cfg, mlstm_chunk=chunk)
    key = jax.random.PRNGKey(seed)
    p = X.init_mlstm(key, cfg)
    x = jax.random.normal(key, (2, s, cfg.d_model), jnp.float32)
    y_seq, _ = X.mlstm_layer(p, x, cfg, None)
    y_chn, _ = X.mlstm_layer(p, x, cfg_c, None)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chn),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_chunkwise_state_carry_matches():
    cfg = get_config("xlstm-1.3b", smoke=True)
    cfg_c = dataclasses.replace(cfg, mlstm_chunk=8)
    p = X.init_mlstm(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.float32)
    st0 = X.init_mlstm_state(cfg, 2)
    _, s1 = X.mlstm_layer(p, x, cfg, st0)
    _, s2 = X.mlstm_layer(p, x, cfg_c, st0)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(s1[k]), np.asarray(s2[k]),
                                   rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# MoE dispatch properties
# ----------------------------------------------------------------------

def test_moe_identity_experts_preserve_token_mixture():
    """With identity-like expert FFNs disabled, output must be a convex
    combination: zero experts -> zero output; gates sum to 1."""
    from repro.models import moe as M
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    p = M.init_moe(KEY, cfg)
    zeroed = dict(p, gate=jnp.zeros_like(p["gate"]),
                  up=jnp.zeros_like(p["up"]),
                  down=jnp.zeros_like(p["down"]))
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), cfg.dtype)
    out, aux = M.moe_block(zeroed, x, cfg)
    assert float(jnp.abs(out).max()) == 0.0
    assert np.isfinite(float(aux))


def test_moe_permutation_equivariance():
    """Permuting tokens permutes outputs (dispatch is content-based)."""
    from repro.models import moe as M
    cfg = get_config("olmoe-1b-7b", smoke=True)
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model), jnp.float32)
    out1, _ = M.moe_block(p, x, cfg)
    perm = jnp.asarray(np.random.RandomState(0).permutation(16))
    out2, _ = M.moe_block(p, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(out1[:, perm]),
                               np.asarray(out2), rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------------------
# serving engine
# ----------------------------------------------------------------------

def test_greedy_generate_deterministic_and_extends_prompt():
    from repro.serve.engine import greedy_generate
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = T.init_lm(KEY, cfg)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    out1 = greedy_generate(params, cfg, prompt, steps=4, max_len=32)
    out2 = greedy_generate(params, cfg, prompt, steps=4, max_len=32)
    assert out1.shape == (2, 8 + 1 + 4)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :8]),
                                  np.asarray(prompt))


def test_unrolled_serving_caches_list_roundtrip():
    cfg = dataclasses.replace(get_config("granite-3-8b", smoke=True),
                              scan_layers=False)
    caches = T.init_caches(cfg, 2, 16)
    assert isinstance(caches, list) and len(caches) == cfg.n_layers
    params = T.init_lm(KEY, cfg)
    tok = jnp.zeros((2, 1), jnp.int32)
    lg, caches, _ = T.forward(params, cfg, tokens=tok, caches=caches)
    assert int(caches[0].attn.length) == 1


# ----------------------------------------------------------------------
# checkpoint-mode ablation (preempt-to-checkpoint vs kill)
# ----------------------------------------------------------------------

def test_checkpoint_mode_never_slower():
    from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, run_sim
    wl = WorkloadConfig(n_apps=80, max_components=10, max_runtime=2400.0,
                        mean_burst_gap=1.0, mean_long_gap=30.0, seed=13)
    cl = ClusterConfig(n_hosts=4, max_running_apps=64)

    def run(lost):
        return run_sim(SimConfig(
            cluster=cl, workload=wl, policy="pessimistic",
            forecaster="oracle", work_lost_on_kill=lost,
            max_ticks=8000)).summary()

    kill = run(True)
    ckpt = run(False)
    assert ckpt["completed"] == wl.n_apps
    # preserving work on preemption cannot hurt turnaround (allow sim
    # scheduling noise)
    assert (ckpt["turnaround_mean"]
            <= kill["turnaround_mean"] * 1.02)
