"""Forecasting module tests (paper §3.1): accuracy, uncertainty,
degenerate inputs, batching."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.forecast import (ARIMAForecaster, GPConfig, GPForecaster,
                                 OracleForecaster)


def _series(kind: str, n: int = 60, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    t = np.arange(n, dtype=np.float32)
    if kind == "const":
        return 5.0 + rng.normal(0, 0.05, n).astype(np.float32)
    if kind == "trend":
        return (0.5 * t + rng.normal(0, 0.3, n)).astype(np.float32)
    if kind == "sine":
        return (10 + 3 * np.sin(t / 4) + rng.normal(0, 0.2, n)).astype(
            np.float32)
    if kind == "ar1":
        x = np.zeros(n, np.float32)
        for i in range(1, n):
            x[i] = 0.8 * x[i - 1] + rng.normal(0, 0.5)
        return x + 10
    raise ValueError(kind)


GP = GPForecaster(GPConfig(history=10, max_patterns=15, opt_steps=15))
AR = ARIMAForecaster()


@pytest.mark.parametrize("model", [GP, AR], ids=["gp", "arima"])
@pytest.mark.parametrize("kind", ["const", "trend", "sine", "ar1"])
def test_forecast_tracks_signal(model, kind):
    y = _series(kind)
    fc = model.forecast(jnp.asarray(y[:-3]), 3)
    assert np.isfinite(np.asarray(fc.mean)).all()
    assert (np.asarray(fc.var) >= 0).all()
    # 1-step prediction should beat a mean-of-window predictor
    err = abs(float(fc.mean[0]) - y[-3])
    base = abs(y[:-3].mean() - y[-3])
    scale = y.std() + 1e-6
    assert err <= base + 1.0 * scale


def test_gp_variance_reflects_noise():
    """Noisier series -> larger predictive variance (uncertainty
    quantification, the paper's core requirement)."""
    quiet = _series("const", seed=1)
    rng = np.random.RandomState(2)
    noisy = quiet + rng.normal(0, 2.0, quiet.shape).astype(np.float32)
    vq = float(GP.forecast(jnp.asarray(quiet), 1).var[0])
    vn = float(GP.forecast(jnp.asarray(noisy), 1).var[0])
    assert vn > vq


def test_arima_narrower_than_gp_on_structured_series():
    """The paper's Fig. 2/4 observation: ARIMA's intervals are narrower
    (over-confident) than the GP's.  The effect is workload-dependent;
    it is strongest on series a low-order linear model fits well
    in-sample (small residual sigma^2) while the GP still reports
    honest history-kernel uncertainty — e.g. smooth periodic series."""
    vs_gp, vs_ar = [], []
    for seed in range(4):
        y = jnp.asarray(_series("sine", seed=seed))
        vs_gp.append(float(GP.forecast(y, 1).var[0]))
        vs_ar.append(float(AR.forecast(y, 1).var[0]))
    assert np.median(vs_ar) < np.median(vs_gp)


def test_arima_variance_grows_with_horizon():
    y = jnp.asarray(_series("ar1"))
    fc = AR.forecast(y, 5)
    v = np.asarray(fc.var)
    assert (np.diff(v) >= -1e-6).all()


def test_short_history_fallback():
    y = jnp.asarray([3.0] * 30)
    valid = jnp.zeros((30,), bool).at[-3:].set(True)  # only 3 samples
    for model in (GP, AR):
        fc = model.forecast(y, 2, valid=valid)
        assert np.isfinite(np.asarray(fc.mean)).all()
        assert float(fc.mean[0]) == pytest.approx(3.0, abs=1e-3)
        assert (np.asarray(fc.var) > 0).all()   # inflated, not confident


def test_oracle_zero_variance():
    fc = OracleForecaster().forecast_from_future(jnp.asarray([1.0, 2.0]))
    assert float(fc.var.sum()) == 0.0


def test_batched_matches_single():
    ys = np.stack([_series("sine", seed=s) for s in range(3)])
    fb = GP.forecast_batch(jnp.asarray(ys), 2)
    for i in range(3):
        fs = GP.forecast(jnp.asarray(ys[i]), 2)
        np.testing.assert_allclose(fb.mean[i], fs.mean, rtol=1e-4,
                                   atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(data=st.lists(st.floats(-100, 100), min_size=25, max_size=40))
def test_forecasters_never_nan(data):
    y = jnp.asarray(np.asarray(data, np.float32))
    for model in (GP, AR):
        fc = model.forecast(y, 3)
        assert np.isfinite(np.asarray(fc.mean)).all()
        assert np.isfinite(np.asarray(fc.var)).all()
        assert (np.asarray(fc.var) >= 0).all()
