"""Loop-aware HLO cost analyzer: validated against hand-counted programs."""
import jax
import jax.numpy as jnp

from benchmarks.hlo_analysis import analyze


def _compiled(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    r = analyze(_compiled(lambda x, y: x @ y, a, b).as_text())
    assert r["flops"] == 2 * 128 * 256 * 512


def test_scan_multiplies_by_trip_count():
    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)

    def f(ws, x):
        return jax.lax.scan(lambda c, wl: (jnp.tanh(c @ wl), None),
                            x, ws)[0]

    r = analyze(_compiled(f, w, x).as_text())
    assert r["flops"] == 8 * 2 * 64 * 256 * 256


def test_nested_scan():
    w = jax.ShapeDtypeStruct((4, 8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)

    def g(ws, x):
        def outer(c, wg):
            return jax.lax.scan(
                lambda ci, wl: (jnp.tanh(ci @ wl), None), c, wg)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    r = analyze(_compiled(g, w, x).as_text())
    assert r["flops"] == 32 * 2 * 32 * 128 * 128


def test_bytes_scale_with_loop():
    """weight re-streaming counted per iteration."""
    def mk(n):
        w = jax.ShapeDtypeStruct((n, 256, 256), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 256), jnp.float32)

        def f(ws, x):
            return jax.lax.scan(lambda c, wl: (c @ wl, None), x, ws)[0]

        return analyze(_compiled(f, w, x).as_text())["bytes"]

    b2, b8 = mk(2), mk(8)
    assert b8 > 3 * b2          # roughly linear in trip count


def test_collectives_counted_with_loop_multiplier():
    import os
    import subprocess
    import sys
    # needs >1 device: run in a subprocess with 8 fake devices
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
import sys
sys.path.insert(0, %r)
from benchmarks.hlo_analysis import analyze
mesh = jax.make_mesh((8,), ("model",))
w = jax.ShapeDtypeStruct((4, 256, 256), jnp.float32)
x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
def f(ws, x):
    def body(c, wl):
        y = c @ wl
        return y, None
    return jax.lax.scan(body, x, ws)[0]
ws_sh = NamedSharding(mesh, P(None, None, "model"))
x_sh = NamedSharding(mesh, P(None, None))
with mesh:
    c = jax.jit(f, in_shardings=(ws_sh, x_sh),
                out_shardings=NamedSharding(mesh, P(None, None))).lower(w, x).compile()
r = analyze(c.as_text())
assert r["collective_bytes"] > 0, r
print("OK", r["collective_bytes"])
"""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", code % root],
                         capture_output=True, text=True,
                         env=dict(os.environ, PYTHONPATH=f"{root}/src"))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
