"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes, dtypes, and kernel options."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# gp_gram
# ----------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["exp", "rbf"])
@pytest.mark.parametrize("m,n,d", [
    (1, 1, 1), (7, 5, 3), (10, 10, 11), (40, 40, 41),
    (128, 128, 128), (130, 60, 17),
])
def test_gram_matches_ref(kind, m, n, d):
    k1, k2 = jax.random.split(KEY)
    xa = jax.random.normal(k1, (m, d), jnp.float32)
    xb = jax.random.normal(k2, (n, d), jnp.float32)
    got = ops.gram(xa, xb, 0.7, 1.3, kind=kind, impl="pallas")
    want = ref.gram(xa, xb, 0.7, 1.3, kind=kind)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("kind", ["exp", "rbf"])
def test_gram_properties(kind):
    x = jax.random.normal(KEY, (12, 5), jnp.float32)
    K = np.asarray(ops.gram(x, x, 1.0, 2.0, kind=kind, impl="pallas"))
    np.testing.assert_allclose(K, K.T, atol=1e-5)          # symmetry
    # diag = sf^2 up to fp32 cancellation in the matmul distance identity
    np.testing.assert_allclose(np.diag(K), 4.0, rtol=3e-3)
    assert (K > 0).all() and (K <= 4.0 + 1e-4).all()


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 33), n=st.integers(1, 33), d=st.integers(1, 20),
       ell=st.floats(0.1, 5.0), sf=st.floats(0.1, 3.0))
def test_gram_hypothesis(m, n, d, ell, sf):
    k1, k2 = jax.random.split(KEY)
    xa = jax.random.normal(k1, (m, d), jnp.float32)
    xb = jax.random.normal(k2, (n, d), jnp.float32)
    got = ops.gram(xa, xb, ell, sf, kind="exp", impl="pallas")
    want = ref.gram(xa, xb, ell, sf, kind="exp")
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

def _qkv(b, hq, hkv, s, t, d, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, hq, s, d), dtype)
    k = jax.random.normal(k2, (b, hkv, t, d), dtype)
    v = jax.random.normal(k3, (b, hkv, t, d), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 1, 1, 32, 16), (2, 4, 2, 64, 32), (1, 8, 1, 128, 64),
])
def test_flash_causal(dtype, tol, b, hq, hkv, s, d):
    q, k, v = _qkv(b, hq, hkv, s, s, d, dtype)
    got = ops.attention(q, k, v, causal=True, impl="pallas", bq=32, bk=32)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.float32(got), np.float32(want),
                               rtol=tol, atol=tol)


def test_flash_padded_shapes():
    """Non-multiple S/T and odd head dims exercise the padding path."""
    q, k, v = _qkv(2, 4, 4, 48, 48, 24, jnp.float32)
    got = ops.attention(q, k, v, causal=True, impl="pallas", bq=32, bk=32)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_flash_decode_prefix():
    """q shorter than kv (decode-style suffix alignment)."""
    q, k, v = _qkv(1, 4, 2, 32, 128, 32, jnp.float32)
    got = ops.attention(q, k, v, causal=True, impl="pallas", bq=32, bk=32)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_flash_noncausal():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32, jnp.float32)
    got = ops.attention(q, k, v, causal=False, impl="pallas", bq=32, bk=32)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 32, 64]), group=st.sampled_from([1, 2, 4]),
       d=st.sampled_from([16, 32]))
def test_flash_hypothesis(s, group, d):
    q, k, v = _qkv(1, 4, 4 // group, s, s, d, jnp.float32)
    got = ops.attention(q, k, v, causal=True, impl="pallas", bq=16, bk=16)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_ref_attention_softmax_rows_sum_to_one():
    q, k, v = _qkv(1, 2, 2, 16, 16, 8, jnp.float32)
    ones = jnp.ones_like(v)
    out = ref.attention(q, k, ones, causal=True)
    np.testing.assert_allclose(out, 1.0, rtol=1e-5)
