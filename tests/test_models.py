"""Per-architecture smoke tests (reduced configs, full code paths) +
decode/teacher-forced consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCHS, get_config
from repro.models import transformer as T
from repro.models import whisper as W

KEY = jax.random.PRNGKey(0)


def _lm_inputs(cfg, b=2, s=32):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    img = (jax.random.normal(KEY, (b, cfg.n_img_tokens, cfg.d_model))
           if cfg.family == "vlm" else None)
    return toks, img


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.family == "audio":
        params = W.init_whisper(KEY, cfg)
        frames = jax.random.normal(KEY, (2, 32, cfg.d_model))
        enc = W.encode(params, frames, cfg)
        logits, _ = W.decode(params, jnp.zeros((2, cfg.dec_len), jnp.int32),
                             enc, cfg)
        assert logits.shape == (2, cfg.dec_len, cfg.vocab)
    else:
        params = T.init_lm(KEY, cfg)
        toks, img = _lm_inputs(cfg)
        logits, _, aux = T.forward(params, cfg, tokens=toks, img_embeds=img)
        assert logits.shape == (2, 32, cfg.vocab)
        assert np.isfinite(float(aux))
    assert np.isfinite(np.float32(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.optim import adamw_init
    from repro.train import TrainConfig, make_train_step
    cfg = get_config(arch, smoke=True)
    key = KEY
    if cfg.family == "audio":
        params = W.init_whisper(key, cfg)
        batch = {
            "frames": jax.random.normal(key, (2, 16, cfg.d_model)),
            "dec_tokens": jnp.zeros((2, cfg.dec_len), jnp.int32),
            "dec_labels": jnp.ones((2, cfg.dec_len), jnp.int32),
        }
    else:
        params = T.init_lm(key, cfg)
        batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab)}
        if cfg.family == "vlm":
            batch["img_embeds"] = jax.random.normal(
                key, (2, cfg.n_img_tokens, cfg.d_model))
    step = jax.jit(make_train_step(cfg, TrainConfig()))
    p, o, stats = step(params, adamw_init(params), batch)
    assert np.isfinite(float(stats["loss"]))
    assert np.isfinite(float(stats["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         p, params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["glm4-9b", "olmoe-1b-7b", "hymba-1.5b",
                                  "xlstm-1.3b"])
def test_incremental_decode_matches_full(arch):
    """KV caches / SSM states / mLSTM states reproduce the teacher-forced
    forward exactly (the serving-correctness contract)."""
    cfg = get_config(arch, smoke=True)
    params = T.init_lm(KEY, cfg)
    toks, _ = _lm_inputs(cfg, b=2, s=16)
    full, _, _ = T.forward(params, cfg, tokens=toks)
    caches = T.init_caches(cfg, 2, 32)
    outs = []
    for i in range(8):
        lg, caches, _ = T.forward(params, cfg, tokens=toks[:, i:i + 1],
                                  caches=caches)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.float32(inc), np.float32(full[:, :8]),
                               rtol=2e-4, atol=2e-4)


def test_chunked_prefill_matches_full():
    """Incremental prefill in chunks == one-shot (cache consistency)."""
    cfg = get_config("granite-3-8b", smoke=True)
    params = T.init_lm(KEY, cfg)
    toks, _ = _lm_inputs(cfg, b=2, s=32)
    full, _, _ = T.forward(params, cfg, tokens=toks)
    caches = T.init_caches(cfg, 2, 64)
    parts = []
    for i in range(0, 32, 8):
        lg, caches, _ = T.forward(params, cfg, tokens=toks[:, i:i + 8],
                                  caches=caches)
        parts.append(lg)
    np.testing.assert_allclose(np.float32(jnp.concatenate(parts, 1)),
                               np.float32(full), rtol=2e-4, atol=2e-4)


def test_hymba_windowed_vs_global_layers_differ():
    cfg = get_config("hymba-1.5b", smoke=True)
    from repro.models.transformer import layer_meta
    meta = layer_meta(cfg)
    w = np.asarray(meta["window"])
    assert w[0] == 0                      # global layer
    assert (w[1:] > 0).any()              # windowed layers exist


def test_moe_aux_loss_balanced_router_is_one():
    """Switch LB loss == 1.0 for a perfectly uniform router."""
    from repro.models import moe as M
    cfg = get_config("olmoe-1b-7b", smoke=True)
    p = M.init_moe(KEY, cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))   # uniform gates
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), cfg.dtype)
    _, aux = M.moe_block(p, x, cfg)
    assert float(aux) == pytest.approx(1.0, rel=0.05)


def test_vlm_image_tokens_change_output():
    cfg = get_config("phi-3-vision-4.2b", smoke=True)
    params = T.init_lm(KEY, cfg)
    toks, img = _lm_inputs(cfg)
    l1, _, _ = T.forward(params, cfg, tokens=toks, img_embeds=img)
    l2, _, _ = T.forward(params, cfg, tokens=toks,
                         img_embeds=img + 1.0)
    assert float(jnp.abs(l1 - l2).max()) > 0


def test_param_count_approximation():
    """config.n_params() within 15% of actual init for dense archs."""
    for arch in ("internlm2-1.8b", "glm4-9b"):
        cfg = get_config(arch)
        est = cfg.n_params()
        # count real params at smoke scale is meaningless; compare FULL
        # analytic vs eval_shape (no allocation)
        shapes = jax.eval_shape(
            lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
        real = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert abs(est - real) / real < 0.15
