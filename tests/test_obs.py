"""Observability-plane tests (``repro.obs``): telemetry-ring identity +
chunk invariance, span tracing, the metrics registry, run manifests,
the shared benchmark timer, and the sweep-level wiring.

The two contracts that must NEVER regress (docs/OBSERVABILITY.md):

  * obs DISABLED is structurally absent — ``SimState.obs is None`` and
    results carry no rings, so compiled programs are bit-identical to
    the pre-observability engines;
  * obs ENABLED never perturbs dynamics — summaries equal the obs-off
    run's, and drained histories are chunk-invariant and identical
    across solo / cohort / shard execution.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.obs import (ObsConfig, MetricsRegistry, Tracer, best_of,
                       build_manifest, cell_hash, config_hash,
                       load_manifest, masked_row_overhead, obs_summary,
                       span, time_us, tracing, validate_trace,
                       write_manifest)
from repro.obs.rings import RING_FIELDS, RingDrain, obs_init, obs_record
from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, generate
from repro.sim.state import init_state
from repro.sim.step import run_cohort_scan, run_fleet_shard, run_sim_scan

WL = WorkloadConfig(n_apps=16, max_components=4, max_runtime=900.0,
                    mean_burst_gap=4.0, mean_long_gap=60.0, seed=3)
CL = ClusterConfig(n_hosts=2, max_running_apps=8)
OFF = SimConfig(cluster=CL, workload=WL, max_ticks=2000,
                policy="pessimistic", forecaster="persist")
ON = dataclasses.replace(OFF, obs=ObsConfig(enabled=True))


@pytest.fixture(scope="module")
def wl():
    return generate(WL)


# ----------------------------------------------------------------------
# rings: structural absence, identity, invariance
# ----------------------------------------------------------------------

def test_obs_off_structurally_absent(wl):
    st = init_state(OFF, wl.n_apps, wl.max_components)
    assert st.obs is None
    res = run_sim_scan(OFF, wl, chunk=32)
    assert res.obs is None
    assert "obs" not in res.summary()


def test_obs_on_does_not_perturb_dynamics(wl):
    off = run_sim_scan(OFF, wl, chunk=32)
    on = run_sim_scan(ON, wl, chunk=32)
    assert on.obs is not None
    assert off.summary() == on.summary()
    assert off.turnaround == on.turnaround


def test_ring_histories_chunk_invariant(wl):
    h32 = run_sim_scan(ON, wl, chunk=32).obs
    h1 = run_sim_scan(ON, wl, chunk=1).obs
    assert set(h32) == {name for name, _ in RING_FIELDS}
    for k in h32:
        np.testing.assert_array_equal(h32[k], h1[k], err_msg=k)


def test_ring_history_semantics(wl):
    res = run_sim_scan(ON, wl, chunk=32)
    h = res.obs
    T = h["queue"].shape[0]
    assert T > 0 and all(v.shape == (T,) for v in h.values())
    cap_cpu = CL.n_hosts * CL.host_cpu
    assert float(h["used_cpu"].max()) <= cap_cpu + 1e-3
    assert h["queue"].min() >= 0
    # event deltas reconcile with the end-of-run counters
    assert int(h["oom"].sum()) == res.summary()["oom_kills"]
    # admissions happen (apps must start running to ever complete)
    assert int(h["admitted"].sum()) > 0
    # calibration off -> the coverage rings stay zero
    assert int(h["cov_resolved"].sum()) == 0
    # tenancy off -> no gate throttling, flat credit channel
    assert int(h["throttled"].sum()) == 0


def test_cohort_and_shard_histories_match_solo(wl):
    seeds = [0, 1, 2]
    wls = [generate(dataclasses.replace(WL, seed=s)) for s in seeds]
    cohort = run_cohort_scan(ON, seeds, chunk=32, wls=wls)
    shard = run_fleet_shard(ON, seeds, chunk=32, wls=wls, mesh=1)
    for s, co, sh in zip(seeds, cohort, shard):
        solo = run_sim_scan(
            dataclasses.replace(
                ON, workload=dataclasses.replace(WL, seed=s)),
            wls[s], chunk=32)
        for k in solo.obs:
            np.testing.assert_array_equal(co.obs[k], solo.obs[k],
                                          err_msg=f"cohort seed {s}: {k}")
            np.testing.assert_array_equal(sh.obs[k], solo.obs[k],
                                          err_msg=f"shard seed {s}: {k}")


def test_chunk_must_fit_ring_capacity(wl):
    small = dataclasses.replace(ON, obs=ObsConfig(enabled=True, ring=8))
    with pytest.raises(ValueError, match="ring capacity"):
        run_sim_scan(small, wl, chunk=32)


def test_ring_overflow_detected_on_drain():
    obs = obs_init(ObsConfig(enabled=True, ring=4))
    active = np.asarray(True)
    for _ in range(5):      # 5 writes into a 4-slot ring, no drain
        obs = obs_record(obs, active,
                         {name: 1 for name, _ in RING_FIELDS})
    drain = RingDrain()
    with pytest.raises(RuntimeError, match="ring overflow"):
        drain.drain(obs)


def test_inactive_ticks_record_nothing():
    obs = obs_init(ObsConfig(enabled=True, ring=8))
    vals = {name: 7 for name, _ in RING_FIELDS}
    obs = obs_record(obs, np.asarray(True), vals)
    obs = obs_record(obs, np.asarray(False), vals)   # padding tick
    drain = RingDrain()
    drain.drain(obs)
    h = drain.history(0)
    assert h["queue"].shape == (1,)      # only the active tick landed
    assert int(h["queue"][0]) == 7


# ----------------------------------------------------------------------
# span tracing
# ----------------------------------------------------------------------

def test_tracing_writes_valid_chrome_trace(tmp_path):
    path = tmp_path / "trace.json"
    with tracing(str(path)):
        with span("outer", cat="test", args={"k": 1}):
            with span("inner", cat="test"):
                pass
    doc = json.loads(path.read_text())
    assert validate_trace(doc) == []
    names = [e["name"] for e in doc["traceEvents"]]
    assert "outer" in names and "inner" in names
    # events are sorted by timestamp and carry complete-event durations
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts)
    assert all(e["ph"] == "X" and e["dur"] >= 0
               for e in doc["traceEvents"])


def test_span_without_tracer_is_a_noop():
    with span("untraced"):      # no tracer installed: shared nullcontext
        pass


def test_tracing_refuses_nesting(tmp_path):
    with tracing(str(tmp_path / "a.json")):
        with pytest.raises(RuntimeError, match="already installed"):
            with tracing(str(tmp_path / "b.json")):
                pass


def test_validate_trace_catches_tampering():
    t = Tracer()
    with t.span("ok"):
        pass
    good = t.to_json()
    assert validate_trace(good) == []
    assert validate_trace({"traceEvents": "nope"})
    no_dur = {"traceEvents": [dict(good["traceEvents"][0])]}
    del no_dur["traceEvents"][0]["dur"]
    assert any("dur" in p for p in validate_trace(no_dur))
    unmatched = {"traceEvents": [
        {"name": "b", "ph": "B", "ts": 0, "pid": 1, "tid": 1}]}
    assert any("unclosed" in p.lower() for p in validate_trace(unmatched))


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------

def test_metrics_registry_kinds_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("runs").inc()
    reg.counter("runs").inc(2)
    reg.gauge("devices").set(8)
    h = reg.histogram("wall_s")
    h.observe(0.5)
    h.observe(1.5)
    snap = reg.snapshot()
    assert snap["runs"]["value"] == 3
    assert snap["devices"]["value"] == 8
    assert snap["wall_s"]["count"] == 2
    assert snap["wall_s"]["sum"] == pytest.approx(2.0)
    assert snap["wall_s"]["min"] == pytest.approx(0.5)
    with pytest.raises(TypeError):
        reg.gauge("runs")       # name already registered as a counter


def test_metrics_exports(tmp_path):
    reg = MetricsRegistry()
    reg.counter("ticks").inc(42)
    reg.histogram("compile.s").observe(1.25)
    jl = tmp_path / "metrics.jsonl"
    reg.write_jsonl(str(jl), run="r1")
    reg.write_jsonl(str(jl), run="r2")       # appends
    lines = [json.loads(ln) for ln in jl.read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["metrics"]["ticks"]["value"] == 42
    assert lines[1]["run"] == "r2"
    prom = tmp_path / "metrics.prom"
    reg.write_textfile(str(prom))
    text = prom.read_text()
    assert "ticks 42" in text
    # histograms expand; dots sanitize to legal prometheus names
    assert "compile_s_count 1" in text
    assert "compile_s_sum 1.25" in text


# ----------------------------------------------------------------------
# run manifests
# ----------------------------------------------------------------------

def test_manifest_roundtrip_and_tamper_detection(tmp_path):
    man = build_manifest(
        base_config=dataclasses.asdict(OFF), engine="scan",
        cells=[{"name": "a", "seed": 0, "overrides": {"policy": "x"}},
               {"name": "b", "seed": 1, "overrides": {}}],
        artifacts={"results": "out.json"}, wall_s=1.0)
    path = tmp_path / "run.manifest.json"
    write_manifest(str(path), man)
    loaded = load_manifest(str(path), verify=True)     # hashes recompute
    assert loaded["base_config_hash"] == man["base_config_hash"]
    assert len(loaded["cells"]) == 2
    assert loaded["environment"]["jax"]

    tampered = json.loads(path.read_text())
    tampered["base_config"]["policy"] = "optimistic"
    path.write_text(json.dumps(tampered))
    with pytest.raises(ValueError, match="hash"):
        load_manifest(str(path), verify=True)


def test_config_and_cell_hashes_are_stable():
    h1, h2 = config_hash(OFF), config_hash(OFF)
    assert h1 == h2
    assert h1 != config_hash(ON)
    assert cell_hash(h1, {"policy": "baseline"}, 0) \
        != cell_hash(h1, {"policy": "baseline"}, 1)


# ----------------------------------------------------------------------
# shared timer + report helpers
# ----------------------------------------------------------------------

def test_best_of_returns_min_wall():
    calls = []
    s = best_of(lambda: calls.append(1), 3)
    assert len(calls) == 3 and s >= 0.0


def test_time_us_returns_average_microseconds():
    us = time_us(lambda x: x + 1, 41, iters=2)
    assert us > 0.0


def test_masked_row_overhead_formula():
    rows = {"rows_batch": 128, "ticks_forecasting": 10, "rows_ready": 64}
    assert masked_row_overhead(rows) == pytest.approx(20.0)
    assert masked_row_overhead({"rows_batch": 1, "ticks_forecasting": 1,
                                "rows_ready": 0}) == pytest.approx(1.0)


def test_obs_summary_shapes(wl):
    h = run_sim_scan(ON, wl, chunk=32).obs
    s = obs_summary(h)
    assert s["ticks"] == h["queue"].shape[0]
    assert s["oom_total"] >= 0 and s["queue_peak"] >= 0
    assert 0.0 < s["used_cpu_mean"] <= CL.n_hosts * CL.host_cpu
    assert "coverage" not in s      # calibration off: nothing resolved
    assert obs_summary({}) == {"ticks": 0}


# ----------------------------------------------------------------------
# sweep wiring: obs blocks in records, trace + manifest artifacts
# ----------------------------------------------------------------------

def test_run_grid_obs_trace_manifest(tmp_path):
    from repro.sim.sweep import quick_base_config, run_grid

    out = tmp_path / "grid.json"
    trace = tmp_path / "grid.trace.json"
    base = quick_base_config(n_apps=12, n_hosts=2, max_components=4)
    res = run_grid(base, {"policy": ["pessimistic"],
                          "forecaster": ["persist"]},
                   seeds=[0, 1], engine="scan", obs=True,
                   out_path=str(out), trace_path=str(trace),
                   forecast_diag=False)
    assert all("obs" in c and c["obs"]["ticks"] > 0 for c in res.cells)
    doc = json.loads(trace.read_text())
    assert validate_trace(doc) == []
    cats = {e["cat"] for e in doc["traceEvents"]}
    assert {"build", "execute", "drain"} <= cats
    # manifest path defaulted from out_path; hashes round-trip
    man = load_manifest(str(tmp_path / "grid.manifest.json"), verify=True)
    assert man["engine"] == "scan"
    assert len(man["cells"]) == len(res.cells)
    assert man["artifacts"]["results"] == str(out)
