"""Pipeline parallelism: GPipe schedule == sequential stage application."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_pipeline_matches_sequential_4stages():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed.pipeline import pipeline_apply

S, M, mb, d = 4, 3, 8, 16
mesh = jax.make_mesh((S,), ("stage",))
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (S, d, d)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
got = pipeline_apply(stage_fn, ws, x, mesh=mesh)

# sequential reference
ref = x
for s in range(S):
    ref = jax.vmap(lambda xm: stage_fn(ws[s], xm))(ref)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code],
                         env=dict(os.environ, PYTHONPATH=f"{ROOT}/src"),
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
