"""Replay-at-scale anchors: streamed ingestion, slot compaction, trace
loading hygiene, arrival-process fitting, and property-based Trace laws.

The streaming engine's contract (``repro.sim.scenarios.stream``):

  * STREAM IDENTITY — feeding a trace through the bounded device window
    (rows harvested and re-keyed at chunk boundaries) is bit-identical
    to materializing the whole trace up front, on every engine;
  * COMPACTION INVARIANCE — window size (tiny + growth, exact-fit,
    auto) and tick chunking never change results;
  * BOUNDED RESIDENCY — peak loaded rows track *concurrency*, not
    trace length.

Property tests use real hypothesis when installed (CI) and skip via the
``tests/conftest.py`` shim otherwise — both paths are exercised below.
"""
import csv
import dataclasses
import os
import warnings

import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.control import TenancyConfig
from repro.control.config import SLO_CLASSES
from repro.obs import ObsConfig
from repro.sim import ClusterConfig, SimConfig, run_sim
from repro.sim.scenarios import (FittedConfig, SEGMENTS, StreamConfig, Trace,
                                 build_trace, fit_trace, load_trace,
                                 make_config, save_trace)
from repro.sim.scenarios.replay import ReplayConfig, _pd, _tenant_codes
from repro.sim.scenarios.stream import run_sim_stream
from repro.sim.step import run_fleet_shard, run_sim_scan

DATA = os.path.join(os.path.dirname(__file__), "data")

WL = make_config("colocated", n_apps=24, max_components=4, seed=5)
BASE = SimConfig(cluster=ClusterConfig(n_hosts=3, max_running_apps=16),
                 workload=WL, policy="pessimistic", forecaster="persist",
                 max_ticks=4000)


def _results_equal(a, b) -> bool:
    return (a.summary() == b.summary()
            and a.turnaround == b.turnaround
            and a.failed_apps == b.failed_apps
            and a.util_cpu == b.util_cpu and a.util_mem == b.util_mem
            and a.n_running == b.n_running)


# ----------------------------------------------------------------------
# stream identity: streamed == materialized, per engine
# ----------------------------------------------------------------------

@pytest.mark.parametrize("leap", [False, True])
def test_streamed_matches_materialized_scan(leap):
    cfg = dataclasses.replace(BASE, leap=leap)
    wl = build_trace(WL)
    mat = run_sim_scan(cfg, wl, chunk=16)
    stats = {}
    stream = run_sim_stream(cfg, wl, chunk=16, window=8, stats=stats)
    assert _results_equal(mat, stream)
    assert stats["loaded"] == wl.n_apps


def test_stream_config_dispatches_through_scan():
    scfg = StreamConfig(inner=WL, window=8)
    cfg = dataclasses.replace(BASE, workload=scfg)
    res = run_sim_scan(cfg, chunk=16)
    mat = run_sim_scan(BASE, build_trace(WL), chunk=16)
    assert _results_equal(mat, res)


def test_streamed_matches_materialized_host():
    # the host engine materializes StreamConfig through the registry
    # builder — same trace, same result
    cfg = dataclasses.replace(BASE, workload=StreamConfig(inner=WL))
    host = run_sim(cfg, build_trace(StreamConfig(inner=WL)))
    mat = run_sim(BASE, build_trace(WL))
    assert host.turnaround == mat.turnaround
    assert host.summary() == mat.summary()


def test_streamed_matches_materialized_shard():
    seeds = [0, 1]
    mat = run_fleet_shard(BASE, seeds, chunk=16, mesh=1)
    scfg = dataclasses.replace(
        BASE, workload=StreamConfig(inner=WL, window=8))
    stream = run_fleet_shard(scfg, seeds, chunk=16, mesh=1)
    assert len(mat) == len(stream) == len(seeds)
    for m, s in zip(mat, stream):
        assert _results_equal(m, s)


def test_run_grid_scan_engine_streams():
    """Sweep wiring: a StreamConfig base workload routes every scan
    cell through streamed ingestion, matching the materialized sweep."""
    from repro.sim.sweep import run_grid
    scfg = dataclasses.replace(BASE, workload=StreamConfig(inner=WL,
                                                           window=8))
    stream = run_grid(scfg, axes={"policy": ["baseline", "pessimistic"]},
                      seeds=[0], engine="scan", chunk=16,
                      forecast_diag=False)
    mat = run_grid(BASE, axes={"policy": ["baseline", "pessimistic"]},
                   seeds=[0], engine="scan", chunk=16,
                   forecast_diag=False)
    assert len(stream.cells) == len(mat.cells) == 2
    for s, m in zip(stream.cells, mat.cells):
        assert s["summary"] == m["summary"], s["name"]


# ----------------------------------------------------------------------
# compaction invariance
# ----------------------------------------------------------------------

def test_compaction_on_off_equality():
    """Tiny window (rows harvested + re-keyed every boundary) == window
    covering the whole trace (no re-keying ever needed)."""
    wl = build_trace(WL)
    stats_on, stats_off = {}, {}
    on = run_sim_stream(BASE, wl, chunk=16, window=8, stats=stats_on)
    off = run_sim_stream(BASE, wl, chunk=16, window=wl.n_apps,
                         stats=stats_off)
    assert _results_equal(on, off)
    assert on.summary() == off.summary()
    # the tiny window really did compact (grew lazily, stayed < n_apps
    # only if concurrency allowed; at minimum it started at 8)
    assert stats_off["grows"] == 0


def test_chunk_invariance_with_compaction():
    wl = build_trace(WL)
    r1 = run_sim_stream(BASE, wl, chunk=1, window=8)
    r32 = run_sim_stream(BASE, wl, chunk=32, window=8)
    assert _results_equal(r1, r32)


def test_leap_obs_tenancy_composition_on_replayed_trace():
    """Full composition on a replayed trace: leap ticks + telemetry
    rings + the tenant control plane, streamed vs materialized."""
    wl = load_trace(os.path.join(DATA, "alibaba_tiny.csv"),
                    preset="alibaba")
    cfg = dataclasses.replace(
        BASE, workload=ReplayConfig(path="unused"), leap=True,
        obs=ObsConfig(enabled=True),
        control=TenancyConfig(enabled=True, max_tenants=4),
        max_ticks=2000)
    mat = run_sim_scan(cfg, wl, chunk=16)
    stream = run_sim_stream(cfg, wl, chunk=16, window=2)
    assert _results_equal(mat, stream)
    assert mat.tenancy == stream.tenancy
    assert mat.obs.keys() == stream.obs.keys()
    for k in mat.obs:
        assert np.array_equal(mat.obs[k], stream.obs[k]), k


def test_window_bounded_by_concurrency():
    """A long sparse trace streams through a window that tracks peak
    concurrency, far below the trace length."""
    fit = FittedConfig(n_apps=96, max_components=1, seed=2,
                       rate=1.0 / 600.0, runtime_mu=np.log(600.0),
                       runtime_sigma=0.3)
    wl = build_trace(fit)
    cfg = dataclasses.replace(BASE, workload=fit, leap=True,
                              max_ticks=2_000_000)
    stats = {}
    stream = run_sim_stream(cfg, wl, chunk=16, window=16, stats=stats)
    assert stats["loaded"] == 96
    assert stats["peak_rows"] <= 16 and stats["grows"] == 0
    mat = run_sim_scan(cfg, wl, chunk=16)
    assert _results_equal(mat, stream)


# ----------------------------------------------------------------------
# load_trace hygiene (regression: silently mangled malformed files)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("fixture,preset", [
    ("alibaba_tiny.csv", "alibaba"), ("azure_tiny.csv", "azure")])
def test_fixture_csvs_load_without_warnings(fixture, preset):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        tr = load_trace(os.path.join(DATA, fixture), preset=preset)
    assert tr.n_apps == 3
    assert np.all(np.diff(tr.submit) >= 0)


def _rewrite(path, rows):
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)


@pytest.fixture()
def saved_trace(tmp_path):
    tr = build_trace(make_config("colocated", n_apps=6, max_components=4,
                                 seed=0))
    p = str(tmp_path / "t.csv")
    save_trace(tr, p)
    return tr, p


def test_unsorted_rows_warn_and_stable_sort(saved_trace):
    tr, p = saved_trace
    rows = list(csv.DictReader(open(p)))
    _rewrite(p, rows[::-1])   # reversed: apps AND component rows shuffled
    with pytest.warns(UserWarning, match="submission order"):
        back = load_trace(p)
    assert np.array_equal(back.submit, tr.submit)
    assert np.array_equal(back.cpu_req, tr.cpu_req)
    assert np.array_equal(back.is_core, tr.is_core)
    assert np.array_equal(back.levels, tr.levels)


def test_conflicting_app_scalars_raise(saved_trace):
    _, p = saved_trace
    rows = list(csv.DictReader(open(p)))
    multi = [r["app_id"] for r in rows
             if sum(q["app_id"] == r["app_id"] for q in rows) > 1][0]
    for r in rows:
        if r["app_id"] == multi:
            r["submit"] = str(float(r["submit"]) + 7.0)
            break
    _rewrite(p, rows)
    with pytest.raises(ValueError, match="disagree"):
        load_trace(p)


def test_duplicate_component_ids_raise(saved_trace):
    _, p = saved_trace
    rows = list(csv.DictReader(open(p)))
    aid = [r["app_id"] for r in rows
           if sum(q["app_id"] == r["app_id"] for q in rows) > 1][0]
    multi = [r for r in rows if r["app_id"] == aid]
    multi[1]["component"] = multi[0]["component"]
    _rewrite(p, rows)
    with pytest.raises(ValueError, match="duplicate component"):
        load_trace(p)


# ----------------------------------------------------------------------
# arrival-process fitting
# ----------------------------------------------------------------------

def test_fit_trace_recovers_operating_point():
    src = FittedConfig(n_apps=400, max_components=1, seed=9,
                       rate=1.0 / 120.0, runtime_mu=6.0, runtime_sigma=0.5)
    fit = fit_trace(build_trace(src))
    assert fit.n_apps == 400 and fit.max_components == 1
    assert abs(fit.rate - src.rate) / src.rate < 0.25
    assert abs(fit.runtime_mu - src.runtime_mu) < 0.25
    assert fit.comp_weights == (1.0,)


def test_fit_replay_fixture_and_scale_out():
    tr = load_trace(os.path.join(DATA, "alibaba_tiny.csv"),
                    preset="alibaba")
    fit = fit_trace(tr)
    assert fit.n_tenants == tr.n_tenants
    big = build_trace(dataclasses.replace(fit, n_apps=300, seed=1))
    big.validate()
    assert big.n_apps == 300
    assert np.all(np.diff(big.submit) >= 0)
    # deterministic per seed
    again = build_trace(dataclasses.replace(fit, n_apps=300, seed=1))
    assert np.array_equal(big.submit, again.submit)
    assert np.array_equal(big.levels, again.levels)


def test_fitted_mixed_population_round_trip():
    col = build_trace(make_config("colocated", n_apps=48, max_components=4,
                                  seed=0))
    fit = fit_trace(col)
    assert 0.0 < fit.elastic_frac < 1.0
    syn = build_trace(dataclasses.replace(fit, n_apps=64, seed=2))
    syn.validate()
    assert syn.is_elastic.any() and (~syn.is_elastic).any()


# ----------------------------------------------------------------------
# hypothesis shim: both the real and the fallback path work
# ----------------------------------------------------------------------

def test_optional_hypothesis_shim_skips_cleanly(monkeypatch):
    import builtins

    real_import = builtins.__import__

    def no_hypothesis(name, *a, **k):
        if name.split(".")[0] == "hypothesis":
            raise ModuleNotFoundError(name)
        return real_import(name, *a, **k)

    monkeypatch.setattr(builtins, "__import__", no_hypothesis)
    g, s, stg = optional_hypothesis()
    assert stg.integers(0, 5) is stg.composite(lambda d: d)  # absorber

    @s(max_examples=3)
    @g(stg.integers())
    def prop():
        raise AssertionError("shimmed property body must never run")

    with pytest.raises(pytest.skip.Exception):
        prop()


def test_optional_hypothesis_real_path():
    hyp = pytest.importorskip("hypothesis")
    g, s, stg = optional_hypothesis()
    assert g is hyp.given


# ----------------------------------------------------------------------
# property-based Trace laws (real strategies under CI's hypothesis)
# ----------------------------------------------------------------------

_f32 = st.floats(min_value=0.0, max_value=1.0, allow_nan=False, width=32)


@st.composite
def _trace_specs(draw):
    n = draw(st.integers(min_value=1, max_value=5))
    c = draw(st.integers(min_value=1, max_value=3))
    submit = draw(st.lists(
        st.floats(min_value=0.0, max_value=1e4, allow_nan=False, width=32),
        min_size=n, max_size=n))
    runtime = draw(st.lists(
        st.floats(min_value=1.0, max_value=1e4, allow_nan=False, width=32),
        min_size=n, max_size=n))
    ncomp = draw(st.lists(st.integers(1, c), min_size=n, max_size=n))
    knots = draw(st.lists(_f32, min_size=8, max_size=8))
    tenant = draw(st.lists(st.integers(0, 3), min_size=n, max_size=n))
    slo = draw(st.lists(st.integers(0, len(SLO_CLASSES) - 1),
                        min_size=n, max_size=n))
    return n, c, submit, runtime, ncomp, knots, tenant, slo


def _make_trace(spec) -> Trace:
    n, c, submit, runtime, ncomp, knots, tenant, slo = spec
    idx = np.arange(c)[None, :]
    exists = idx < np.asarray(ncomp)[:, None]
    cpu = np.where(exists, 1.0 + idx.astype(np.float32), 0.0)
    lv = np.resize(np.asarray(knots, np.float32),
                   (n, c, SEGMENTS, 2)) * exists[:, :, None, None]
    return Trace(
        submit=np.sort(np.asarray(submit, np.float32)),
        is_elastic=np.zeros(n, bool), is_jumpy=np.zeros(n, bool),
        n_core=np.asarray(ncomp, np.int64),
        n_elastic=np.zeros(n, np.int64),
        runtime=np.asarray(runtime, np.float32),
        cpu_req=cpu.astype(np.float32),
        mem_req=(cpu * 2).astype(np.float32),
        is_core=exists, levels=np.clip(lv, 0, 1).astype(np.float32),
        tenant=np.asarray(tenant, np.int64),
        slo=np.asarray(slo, np.int64)).validate()


@settings(max_examples=25, deadline=None)
@given(_trace_specs())
def test_property_arrival_monotone_after_load(spec):
    """Any row permutation of a saved trace loads back sorted — arrival
    monotonicity is a postcondition of load_trace, not of the file."""
    import tempfile
    tr = _make_trace(spec)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.csv")
        save_trace(tr, p)
        rows = list(csv.DictReader(open(p)))
        _rewrite(p, rows[::-1])
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            back = load_trace(p)
    assert np.all(np.diff(back.submit) >= 0)
    assert np.array_equal(np.sort(back.submit), np.sort(tr.submit))


@settings(max_examples=25, deadline=None)
@given(_trace_specs())
def test_property_float32_roundtrip(spec):
    """save_trace -> load_trace is float32-exact for every column."""
    import tempfile
    tr = _make_trace(spec)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.csv")
        save_trace(tr, p)
        back = load_trace(p)
    assert np.array_equal(back.submit, tr.submit)
    assert np.array_equal(back.runtime, tr.runtime)
    assert np.array_equal(back.cpu_req, tr.cpu_req)
    assert np.array_equal(back.mem_req, tr.mem_req)
    assert np.array_equal(back.levels, tr.levels)
    assert np.array_equal(back.slo, tr.slo)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["t-a", "t-b", "7", "", "tenant-x"]),
                min_size=1, max_size=12))
def test_property_tenant_codes_dense(names):
    """String tenant ids re-encode densely: codes are exactly 0..k-1
    and preserve the equality classes of the raw ids."""
    codes = _tenant_codes(list(names))
    uniq = sorted(set(codes.tolist()))
    assert uniq == list(range(len(uniq)))
    norm = ["0" if v == "" else v for v in names]
    for i in range(len(names)):
        for j in range(len(names)):
            assert (codes[i] == codes[j]) == (norm[i] == norm[j])


@settings(max_examples=25, deadline=None)
@given(_trace_specs())
def test_property_parquet_roundtrip(spec):
    if _pd is None:
        pytest.skip("pandas/pyarrow not installed")
    import tempfile
    tr = _make_trace(spec)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.parquet")
        try:
            save_trace(tr, p)
        except (ImportError, ValueError):
            pytest.skip("no parquet engine available")
        back = load_trace(p)
    assert np.array_equal(back.levels, tr.levels)
    assert np.array_equal(back.cpu_req, tr.cpu_req)
