"""Scan-engine correctness anchors (repro.sim.state / repro.sim.step).

The device-resident engine's contracts, in order of strength:

  * CHUNK INVARIANCE — results are bit-identical for any tick chunking
    (everything that affects dynamics lives inside the fused step);
  * COHORT EQUIVALENCE — a vmapped seed cohort reproduces each seed's
    solo run bit for bit;
  * HOST AGREEMENT — on the quick-grid configs the scan engine's
    turnaround table and headline counters equal the host engine's
    (the engines share every decision rule; only float accumulation
    order and the FIFO tie-break on exactly equal submit times differ,
    neither of which these workloads excite);
  * the frozen ``engine_ref`` anchor for the HOST engine lives in
    ``tests/test_sweep.py`` and is unaffected by any of this.
"""
import dataclasses

import numpy as np
import pytest

from repro.control import TenancyConfig
from repro.core.uncertainty import CalibrationConfig
from repro.obs import ObsConfig
from repro.sim import (ClusterConfig, SimConfig, WorkloadConfig, generate,
                       run_sim)
from repro.sim.scenarios import make_config
from repro.sim.step import run_cohort_scan, run_sim_scan

WL = WorkloadConfig(n_apps=24, max_components=6, max_runtime=1200.0,
                    mean_burst_gap=4.0, mean_long_gap=60.0, seed=7)
CL = ClusterConfig(n_hosts=3, max_running_apps=16)
BASE = SimConfig(cluster=CL, workload=WL, max_ticks=3000)


def _results_equal(a, b) -> bool:
    return (a.summary() == b.summary()
            and a.turnaround == b.turnaround
            and a.failed_apps == b.failed_apps
            and a.slack_cpu == b.slack_cpu and a.slack_mem == b.slack_mem
            and a.util_cpu == b.util_cpu and a.util_mem == b.util_mem
            and a.n_running == b.n_running)


# ----------------------------------------------------------------------
# chunk invariance: chunk=1 == chunk=32, bit for bit
# ----------------------------------------------------------------------

@pytest.mark.parametrize("policy,forecaster", [
    ("baseline", "persist"),
    ("pessimistic", "persist"),
    ("pessimistic", "oracle"),
    ("optimistic", "oracle"),
])
def test_chunk_invariance(policy, forecaster):
    cfg = dataclasses.replace(BASE, policy=policy, forecaster=forecaster)
    wl = generate(cfg.workload)
    r1 = run_sim_scan(cfg, wl, chunk=1)
    r32 = run_sim_scan(cfg, wl, chunk=32)
    assert _results_equal(r1, r32)


def test_chunk_invariance_with_calibration():
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster="persist",
        calibration=CalibrationConfig(enabled=True, adaptive=True))
    wl = generate(cfg.workload)
    r1 = run_sim_scan(cfg, wl, chunk=1)
    r32 = run_sim_scan(cfg, wl, chunk=32)
    assert _results_equal(r1, r32)
    assert r1.calibration == r32.calibration


def test_chunk_invariance_checkpoint_mode():
    cfg = dataclasses.replace(BASE, policy="pessimistic",
                              forecaster="oracle", work_lost_on_kill=False)
    wl = generate(cfg.workload)
    assert _results_equal(run_sim_scan(cfg, wl, chunk=1),
                          run_sim_scan(cfg, wl, chunk=32))


# ----------------------------------------------------------------------
# vmapped cohort == solo runs, bit for bit, per seed
# ----------------------------------------------------------------------

def test_cohort_matches_solo_runs():
    cfg = dataclasses.replace(BASE, policy="pessimistic",
                              forecaster="persist")
    seeds = [0, 1, 2, 3]
    cohort = run_cohort_scan(cfg, seeds, chunk=16)
    assert len(cohort) == len(seeds)
    for seed, res in zip(seeds, cohort):
        solo_cfg = dataclasses.replace(
            cfg, workload=dataclasses.replace(cfg.workload, seed=seed))
        solo = run_sim_scan(solo_cfg, chunk=16)
        assert _results_equal(solo, res), f"seed {seed} diverged"


def test_cohort_rejects_mismatched_shapes():
    cfg = dataclasses.replace(BASE, policy="baseline", forecaster="persist")
    wls = [generate(dataclasses.replace(cfg.workload, seed=0)),
           generate(dataclasses.replace(cfg.workload, seed=1,
                                        n_apps=WL.n_apps + 1))]
    with pytest.raises(ValueError, match="shape"):
        run_cohort_scan(cfg, [0, 1], wls=wls)


# ----------------------------------------------------------------------
# scan engine vs host engine
# ----------------------------------------------------------------------

@pytest.mark.parametrize("policy,forecaster", [
    ("baseline", "persist"),
    ("pessimistic", "persist"),
    ("pessimistic", "oracle"),
    ("optimistic", "oracle"),
])
def test_scan_agrees_with_host_engine(policy, forecaster):
    cfg = dataclasses.replace(BASE, policy=policy, forecaster=forecaster)
    wl = generate(cfg.workload)
    scan = run_sim_scan(cfg, wl, chunk=32)
    host = run_sim(cfg, wl)
    assert scan.turnaround == host.turnaround
    s, h = scan.summary(), host.summary()
    for k in ("completed", "failed_frac", "failure_events", "oom_kills",
              "full_preemptions", "partial_preemptions", "sim_hours"):
        assert s[k] == h[k], (k, s[k], h[k])
    # telemetry ratios differ only in reduction order
    np.testing.assert_allclose(scan.util_mem, host.util_mem, rtol=1e-5)
    np.testing.assert_allclose(scan.slack_mem, host.slack_mem, rtol=1e-5)


def test_scan_agrees_with_host_engine_gp_gated():
    """The gp/arima model call is gated on any(ready) inside the fused
    tick (PR-5 forecast gating): the gate must not change results vs
    the host engine, and the forecast_rows telemetry must report the
    masked-batch load without leaking into summary()."""
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster="gp",
        workload=dataclasses.replace(WL, n_apps=12))
    wl = generate(cfg.workload)
    scan = run_sim_scan(cfg, wl, chunk=16)
    host = run_sim(cfg, wl)
    assert scan.turnaround == host.turnaround
    s, h = scan.summary(), host.summary()
    for k in ("completed", "failed_frac", "failure_events", "oom_kills",
              "full_preemptions", "partial_preemptions", "sim_hours"):
        assert s[k] == h[k], (k, s[k], h[k])
    # telemetry ratios differ only in reduction order (module doc)
    np.testing.assert_allclose(scan.util_mem, host.util_mem, rtol=1e-5)
    fr = scan.forecast_rows
    assert fr is not None and host.forecast_rows is None
    assert fr["rows_batch"] == 2 * CL.max_running_apps * WL.max_components
    assert 0 < fr["ticks_forecasting"] <= fr["ticks"]
    assert "forecast_rows" not in scan.summary()


def test_scan_agrees_with_host_engine_calibrated():
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster="persist",
        calibration=CalibrationConfig(enabled=True))
    wl = generate(cfg.workload)
    scan = run_sim_scan(cfg, wl, chunk=8)
    host = run_sim(cfg, wl)
    assert scan.turnaround == host.turnaround
    for k in ("resolved", "miscovered", "dropped", "coverage",
              "scores_recorded"):
        assert scan.calibration[k] == host.calibration[k], k


def test_scan_max_ticks_truncation_matches_host():
    """The tick budget must cut the scan at EXACTLY max_ticks even when
    the chunk size does not divide it."""
    cfg = dataclasses.replace(BASE, policy="pessimistic",
                              forecaster="persist", max_ticks=10)
    wl = generate(cfg.workload)
    scan = run_sim_scan(cfg, wl, chunk=32)
    host = run_sim(cfg, wl)
    assert scan.sim_time == host.sim_time
    assert len(scan.util_cpu) == len(host.util_cpu) == 10
    assert scan.turnaround == host.turnaround


# ----------------------------------------------------------------------
# leap engine: event-driven ticks == uniform ticks, bit for bit
# ----------------------------------------------------------------------

@pytest.mark.parametrize(
    "family", ["google", "diurnal", "flashcrowd", "heavytail", "colocated"])
def test_leap_matches_uniform_every_family(family):
    """``SimConfig.leap=True`` skips provably-idle tick runs with a
    scalar while_loop that accumulates time EXACTLY like the uniform
    engine (``t + float32(tick)`` per skipped tick) — so summaries,
    turnaround tables, per-tick telemetry, tenancy counters AND the
    drained obs ring histories must all be bit-identical, on every
    scenario family, with the control plane and rings both live."""
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster="persist",
        workload=make_config(family, base=WL, n_apps=16, n_tenants=3),
        control=TenancyConfig(enabled=True),
        obs=ObsConfig(enabled=True))
    uni = run_sim_scan(cfg, chunk=16)
    leap = run_sim_scan(dataclasses.replace(cfg, leap=True), chunk=16)
    assert _results_equal(uni, leap)
    assert uni.tenancy == leap.tenancy
    assert uni.obs is not None and uni.obs.keys() == leap.obs.keys()
    for name in uni.obs:
        assert np.array_equal(uni.obs[name], leap.obs[name]), name


def test_leap_chunk_invariance_with_calibration():
    """Leap budgets ride in the scan carry (``left``), not in last-chunk
    slicing — chunking must still not matter, including for the
    conformal calibration counters."""
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster="persist", leap=True,
        calibration=CalibrationConfig(enabled=True, adaptive=True))
    wl = generate(cfg.workload)
    r1 = run_sim_scan(cfg, wl, chunk=1)
    r32 = run_sim_scan(cfg, wl, chunk=32)
    assert _results_equal(r1, r32)
    assert r1.calibration == r32.calibration


def test_leap_cohort_matches_solo_runs():
    cfg = dataclasses.replace(BASE, policy="pessimistic",
                              forecaster="persist", leap=True)
    seeds = [0, 1]
    cohort = run_cohort_scan(cfg, seeds, chunk=16)
    for seed, res in zip(seeds, cohort):
        solo_cfg = dataclasses.replace(
            cfg, workload=dataclasses.replace(cfg.workload, seed=seed))
        assert _results_equal(run_sim_scan(solo_cfg, chunk=16), res), seed


def test_leap_max_ticks_truncation_matches_uniform():
    """A budget that runs out mid-idle-gap must still yield EXACTLY
    max_ticks of history (the truncated tail of a leap is re-expanded
    into zero ticks, same as the uniform engine's idle ticks)."""
    cfg = dataclasses.replace(BASE, policy="pessimistic",
                              forecaster="persist", max_ticks=10)
    wl = generate(cfg.workload)
    uni = run_sim_scan(cfg, wl, chunk=32)
    leap = run_sim_scan(dataclasses.replace(cfg, leap=True), wl, chunk=32)
    assert uni.sim_time == leap.sim_time
    assert len(leap.util_cpu) == 10
    assert _results_equal(uni, leap)


# ----------------------------------------------------------------------
# ragged bucketed forecast batching (gp / arima)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("forecaster", ["gp", "arima"])
def test_bucketed_forecast_agrees_with_host_engine(forecaster):
    """The bucketed path compacts forecast-ready monitor rows into
    power-of-2 passes; per-row model independence (the documented
    ``forecast_peaks`` contract) makes it bit-identical to the full
    padded batch — and hence to the host engine."""
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster=forecaster,
        workload=dataclasses.replace(WL, n_apps=12))
    wl = generate(cfg.workload)
    scan = run_sim_scan(cfg, wl, chunk=16)
    host = run_sim(cfg, wl)
    assert scan.turnaround == host.turnaround
    s, h = scan.summary(), host.summary()
    for k in ("completed", "failed_frac", "failure_events", "oom_kills",
              "full_preemptions", "partial_preemptions", "sim_hours"):
        assert s[k] == h[k], (k, s[k], h[k])
    # the telemetry proves the bucket engaged: the model computed fewer
    # rows than ticks_forecasting * the full padded batch
    fr = scan.forecast_rows
    assert fr["rows_bucketed"] > 0
    assert fr["rows_bucketed"] < fr["ticks_forecasting"] * fr["rows_batch"]


def test_bucketed_forecast_off_is_bit_identical():
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster="gp",
        workload=dataclasses.replace(WL, n_apps=12))
    wl = generate(cfg.workload)
    on = run_sim_scan(cfg, wl, chunk=16)
    off = run_sim_scan(dataclasses.replace(cfg, forecast_bucket=False),
                       wl, chunk=16)
    assert _results_equal(on, off)
    # off-path telemetry reports the full padded batch per stride
    assert off.forecast_rows["rows_bucketed"] == (
        off.forecast_rows["ticks_forecasting"]
        * off.forecast_rows["rows_batch"])


def test_bucketed_forecast_chunk_invariance():
    """The bucket is re-chosen per chunk from the host snapshot — an odd
    chunk size exercises different bucket sequences, yet results must
    stay bit-identical."""
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster="gp",
        workload=dataclasses.replace(WL, n_apps=12))
    wl = generate(cfg.workload)
    assert _results_equal(run_sim_scan(cfg, wl, chunk=7),
                          run_sim_scan(cfg, wl, chunk=32))


def test_leap_with_bucketed_gp_matches_uniform_unbucketed():
    """Both tentpole paths composed vs neither: still bit-identical."""
    cfg = dataclasses.replace(
        BASE, policy="pessimistic", forecaster="gp",
        workload=dataclasses.replace(WL, n_apps=12),
        calibration=CalibrationConfig(enabled=True))
    wl = generate(cfg.workload)
    plain = run_sim_scan(
        dataclasses.replace(cfg, forecast_bucket=False), wl, chunk=16)
    fast = run_sim_scan(
        dataclasses.replace(cfg, leap=True), wl, chunk=16)
    assert _results_equal(plain, fast)
    assert plain.calibration == fast.calibration


# ----------------------------------------------------------------------
# sweep integration: engine="scan" cohort fast path
# ----------------------------------------------------------------------

def test_sweep_scan_engine_matches_solo_scan_runs():
    from repro.sim.sweep import quick_base_config, run_grid
    base = quick_base_config(n_apps=24, n_hosts=3, seed=0)
    res = run_grid(base, axes={"policy": ["baseline", "pessimistic"],
                               "forecaster": ["persist"]},
                   seeds=[0, 1], engine="scan")
    assert len(res.cells) == 4
    assert res.forecast_batches == 0        # batcher retired
    for cell in res.cells:
        cfg = base
        for k, v in cell["overrides"].items():
            cfg = dataclasses.replace(cfg, **{k: v})
        cfg = dataclasses.replace(
            cfg, workload=dataclasses.replace(cfg.workload,
                                              seed=cell["seed"]))
        assert run_sim_scan(cfg).summary() == cell["summary"]


def test_sweep_scan_engine_heterogeneous_cells():
    """Cells that share a combo name but are not seed-homogeneous fall
    back to solo scan runs (still correct, just unbatched)."""
    from repro.sim.sweep import quick_base_config, run_grid
    base = quick_base_config(n_apps=16, n_hosts=2, seed=0)
    res = run_grid(base, axes={"policy": ["pessimistic"],
                               "forecaster": ["persist"]},
                   seeds=[3], engine="scan")
    assert len(res.cells) == 1
    cfg = dataclasses.replace(
        base, policy="pessimistic", forecaster="persist",
        workload=dataclasses.replace(base.workload, seed=3))
    assert run_sim_scan(cfg).summary() == res.cells[0]["summary"]


# ----------------------------------------------------------------------
# barrier batch mode: idle ticks no longer pay the leader timeout
# ----------------------------------------------------------------------

def test_barrier_idle_signal_completes_cohort(monkeypatch):
    """A leader whose cohort peers tick WITHOUT requesting must return
    as soon as their idle signals arrive — not after the barrier
    timeout."""
    import threading
    import time

    from repro.sim import sweep as SW

    # stub the forecast: this test times the BARRIER, not the model
    monkeypatch.setattr(
        SW, "forecast_peaks",
        lambda model, horizon, w, v: (w[:, -1], w.var(axis=1) + 1e-6))
    batcher = SW.ForecastBatcher(mode="barrier", barrier_timeout_s=30.0)
    cfg = dataclasses.replace(SW.quick_base_config(), forecaster="gp")
    requester = batcher.client(cfg)
    idler = batcher.client(cfg)
    wins = np.zeros((2, cfg.window), np.float32)
    val = np.ones((2, cfg.window), bool)
    out = {}

    def lead():
        out["result"] = requester(wins, val)

    t = threading.Thread(target=lead)
    t0 = time.monotonic()
    t.start()
    time.sleep(0.05)
    idler.idle()                      # the second sim's tick needs nothing
    t.join(timeout=10.0)
    elapsed = time.monotonic() - t0
    assert not t.is_alive(), "leader never returned"
    assert elapsed < 5.0, f"leader waited the barrier timeout ({elapsed})"
    assert out["result"][0].shape == (2,)
    requester.close()
    idler.close()