"""Scenario subsystem tests: schema validity, per-seed determinism,
workload statistics, replay round-trips, engine end-to-end runs, and
the sweep's scenario axis."""
import dataclasses
import os

import numpy as np
import pytest

from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, run_sim
from repro.sim.scenarios import (SEGMENTS, Trace, TraceValidationError,
                                 build_trace, load_trace, make_config,
                                 save_trace, scenario_names, scenario_of)
from repro.sim.scenarios.diagnostics import (forecast_error_report,
                                             sample_usage_series)
from repro.sim.scenarios.replay import ReplayConfig, _pd
from repro.sim.sweep import run_grid

GENERATORS = ("google", "diurnal", "flashcrowd", "heavytail", "colocated")


def _small(name, seed=3, n_apps=30):
    return make_config(name, n_apps=n_apps, seed=seed)


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------

def test_registry_lists_all_builtin_families():
    names = scenario_names()
    for want in GENERATORS + ("replay",):
        assert want in names


def test_registry_unknown_name_and_config():
    with pytest.raises(KeyError, match="unknown scenario"):
        make_config("nope")
    with pytest.raises(TypeError, match="not a registered"):
        build_trace(object())


def test_make_config_same_family_keeps_base_verbatim():
    base = WorkloadConfig(n_apps=11, max_runtime=999.0, seed=4)
    cfg = make_config("google", base=base)
    assert cfg == base
    assert make_config("google", base=base, seed=8).seed == 8


def test_make_config_cross_family_carries_only_scale_knobs():
    base = WorkloadConfig(n_apps=11, max_components=9, seed=4,
                          max_runtime=999.0)
    cfg = make_config("diurnal", base=base)
    assert (cfg.n_apps, cfg.max_components, cfg.seed) == (11, 9, 4)
    # family shape parameters must NOT be polluted by the base family
    assert cfg.max_runtime != 999.0


# ----------------------------------------------------------------------
# every registered generator: schema-valid, deterministic, runnable
# ----------------------------------------------------------------------

@pytest.mark.parametrize("name", GENERATORS)
def test_generator_emits_schema_valid_trace(name):
    tr = build_trace(_small(name))
    assert isinstance(tr, Trace)
    tr.validate()                               # raises on any violation
    assert scenario_of(tr.cfg) == name
    assert (np.diff(tr.submit) >= 0).all()
    assert tr.levels.shape == (tr.n_apps, tr.max_components, SEGMENTS, 2)


@pytest.mark.parametrize("name", GENERATORS)
def test_generator_per_seed_determinism(name):
    a = build_trace(_small(name, seed=5))
    b = build_trace(_small(name, seed=5))
    c = build_trace(_small(name, seed=6))
    for f in ("submit", "runtime", "cpu_req", "mem_req", "levels"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert not np.array_equal(a.submit, c.submit)


@pytest.mark.parametrize("name", GENERATORS)
def test_generator_usage_within_reservation(name):
    tr = build_trace(_small(name))
    for prog in (0.0, 0.4, 1.0):
        u = tr.usage(np.arange(tr.n_apps),
                     np.full(tr.n_apps, prog, np.float32))
        assert (u[:, :, 0] <= tr.cpu_req + 1e-4).all()
        assert (u[:, :, 1] <= tr.mem_req + 1e-4).all()


@pytest.mark.parametrize("name", GENERATORS)
def test_engine_runs_every_scenario_end_to_end(name):
    cfg = SimConfig(cluster=ClusterConfig(n_hosts=4, max_running_apps=32),
                    workload=_small(name, n_apps=16),
                    policy="pessimistic", forecaster="persist",
                    max_ticks=20_000)
    s = run_sim(cfg).summary()
    assert s["completed"] == 16, s
    assert np.isfinite(s["turnaround_mean"])
    assert 0.0 <= s["util_mem_mean"] <= 1.0


# ----------------------------------------------------------------------
# family statistics
# ----------------------------------------------------------------------

def test_google_elastic_fraction_tracks_config():
    tr = build_trace(make_config("google", n_apps=400, elastic_frac=0.6,
                                 seed=0))
    assert abs(tr.is_elastic.mean() - 0.6) < 0.1
    assert (tr.n_elastic[~tr.is_elastic] == 0).all()


def test_colocated_mix_proportions_and_anticorrelation():
    cfg = make_config("colocated", n_apps=400, seed=0)
    tr = build_trace(cfg)
    # batch apps are the elastic class; service_frac sets the split
    assert abs((~tr.is_elastic).mean() - cfg.service_frac) < 0.1
    # anti-correlated utilization: average the wall-clock-locked profiles
    # of each class on a common day-phase grid — peaks half a day apart
    exists = tr.cpu_req > 0
    mean_lv = np.array([tr.levels[i][exists[i]][:, :, 1].mean()
                        for i in range(tr.n_apps)])
    phase = (tr.submit + 0.5 * tr.runtime) % cfg.day_s
    day = (phase > cfg.day_s * 0.25) & (phase < cfg.day_s * 0.75)
    svc, bat = ~tr.is_elastic, tr.is_elastic
    if (svc & day).any() and (bat & day).any():
        assert mean_lv[svc & day].mean() > mean_lv[bat & day].mean()


def test_heavytail_runtimes_and_demands_have_heavy_tail():
    tr = build_trace(make_config("heavytail", n_apps=500, seed=0))
    assert np.percentile(tr.runtime, 99) / np.median(tr.runtime) > 10
    mem = tr.mem_req[tr.mem_req > 0]
    assert np.percentile(mem, 99) / np.median(mem) > 4
    assert tr.is_elastic.mean() < 0.4           # rigid-dominant


def test_flashcrowd_burst_arrivals_are_correlated():
    cfg = make_config("flashcrowd", n_apps=300, seed=0)
    tr = build_trace(cfg)
    # some 60 s window must contain a large synchronized burst
    binned = np.bincount((tr.submit // 60).astype(int))
    assert binned.max() >= 10
    # and the bursts dominate a background that never bunches like that
    assert binned.max() > 5 * np.median(binned[binned > 0])


def test_diurnal_arrivals_modulated_by_day_cycle():
    cfg = make_config("diurnal", n_apps=600, seed=0)
    tr = build_trace(cfg)
    phase = (tr.submit % cfg.day_s) / cfg.day_s
    day = ((phase > 0.25) & (phase < 0.75)).sum()
    night = len(phase) - day
    assert day > 1.5 * night


# ----------------------------------------------------------------------
# schema validation catches broken traces
# ----------------------------------------------------------------------

def test_validate_rejects_unsorted_submit_and_bad_levels():
    tr = build_trace(_small("google"))
    bad = dataclasses.replace(tr, submit=tr.submit[::-1].copy())
    with pytest.raises(TraceValidationError, match="nondecreasing"):
        bad.validate()
    lv = tr.levels.copy()
    lv[0, 0, 0, 0] = 1.5
    with pytest.raises(TraceValidationError, match="outside"):
        dataclasses.replace(tr, levels=lv).validate()


# ----------------------------------------------------------------------
# replay adapter
# ----------------------------------------------------------------------

def test_replay_csv_roundtrip_is_exact(tmp_path):
    tr = build_trace(_small("flashcrowd", n_apps=20))
    path = str(tmp_path / "trace.csv")
    save_trace(tr, path)
    back = build_trace(make_config("replay", path=path,
                                   max_components=tr.max_components))
    for f in ("submit", "runtime", "cpu_req", "mem_req", "levels"):
        assert np.array_equal(getattr(tr, f), getattr(back, f)), f
    assert np.array_equal(tr.is_core, back.is_core)
    assert np.array_equal(tr.n_elastic, back.n_elastic)


@pytest.mark.skipif(_pd is None, reason="pandas/pyarrow not installed")
def test_replay_parquet_roundtrip_is_exact(tmp_path):
    tr = build_trace(_small("diurnal", n_apps=12))
    path = str(tmp_path / "trace.parquet")
    save_trace(tr, path)
    back = load_trace(path, max_components=tr.max_components)
    assert np.array_equal(tr.levels, back.levels)
    assert np.array_equal(tr.submit, back.submit)


def test_replayed_trace_runs_in_engine_and_matches_source(tmp_path):
    src = _small("google", n_apps=16)
    tr = build_trace(src)
    path = str(tmp_path / "trace.csv")
    save_trace(tr, path)
    cl = ClusterConfig(n_hosts=4, max_running_apps=32)
    a = run_sim(SimConfig(cluster=cl, workload=src, policy="baseline",
                          forecaster="persist", max_ticks=20_000))
    b = run_sim(SimConfig(
        cluster=cl,
        workload=ReplayConfig(path=path, max_components=tr.max_components),
        policy="baseline", forecaster="persist", max_ticks=20_000))
    # the replayed file IS the source workload: identical results
    assert a.summary() == b.summary()


def test_replay_roundtrip_exact_for_tiny_levels(tmp_path):
    """Levels below the families' 0.02 floor (real traces can go lower)
    must still round-trip float32-exactly through the text format."""
    tr = build_trace(_small("google", n_apps=8))
    rng = np.random.RandomState(0)
    lv = (tr.levels * rng.uniform(1e-4, 1.0, tr.levels.shape)
          ).astype(np.float32)
    tr = dataclasses.replace(tr, levels=lv)
    path = str(tmp_path / "tiny.csv")
    save_trace(tr, path)
    back = load_trace(path, max_components=tr.max_components)
    assert np.array_equal(tr.levels, back.levels)


def test_replay_max_components_must_cover_widest_app(tmp_path):
    tr = build_trace(_small("google", n_apps=10))
    width = int((tr.cpu_req > 0).sum(1).max())
    path = str(tmp_path / "trace.csv")
    save_trace(tr, path)
    with pytest.raises(ValueError, match="exceeds"):
        load_trace(path, max_components=width - 1)


@pytest.mark.parametrize("name", ("diurnal", "flashcrowd", "heavytail",
                                  "colocated"))
def test_family_rejects_too_small_max_components(name):
    with pytest.raises(ValueError, match="max_components"):
        build_trace(make_config(name, n_apps=10, max_components=2))


def test_replay_truncation_and_missing_file(tmp_path):
    tr = build_trace(_small("google", n_apps=10))
    path = str(tmp_path / "trace.csv")
    save_trace(tr, path)
    cut = load_trace(path, n_apps=4)
    assert cut.n_apps == 4
    with pytest.raises(FileNotFoundError):
        load_trace(str(tmp_path / "absent.csv"))


AZURE_FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                             "azure_tiny.csv")


def test_replay_azure_preset_maps_vm_readings():
    """The azure preset turns long-format VM readings into one rigid
    single-component app per VM, with utilization fractions from the
    percent readings and a flat 50% memory default where absent."""
    tr = load_trace(AZURE_FIXTURE, preset="azure")
    assert tr.n_apps == 3 and tr.max_components == 1
    # sorted by first reading: vm-a (t=0), vm-b (t=300), vm-c (t=600)
    np.testing.assert_allclose(tr.submit, [0.0, 300.0, 600.0])
    # runtime spans the readings plus one inferred interval; vm-c has a
    # single reading and falls back to the 5-minute Azure cadence
    np.testing.assert_allclose(tr.runtime, [1500.0, 1800.0, 300.0])
    np.testing.assert_allclose(tr.cpu_req.ravel(), [2.0, 4.0, 1.0])
    np.testing.assert_allclose(tr.mem_req.ravel(), [8.0, 16.0, 4.0])
    assert tr.is_core.all() and not tr.is_elastic.any()
    # percent readings -> fractions, endpoints preserved by resampling
    np.testing.assert_allclose(tr.levels[0, 0, 0, 0], 0.35, atol=1e-6)
    np.testing.assert_allclose(tr.levels[0, 0, -1, 0], 0.20, atol=1e-6)
    # vm-c has no avgmem readings -> flat 50% default
    np.testing.assert_allclose(tr.levels[2, 0, :, 1], 0.5, atol=1e-6)


def test_replay_azure_preset_via_scenario_config():
    cfg = make_config("replay", path=AZURE_FIXTURE, preset="azure")
    tr = build_trace(cfg)
    res = run_sim(SimConfig(workload=cfg, policy="pessimistic",
                            forecaster="persist", max_ticks=2000))
    assert res.summary()["completed"] == tr.n_apps


def test_replay_unknown_preset_rejected():
    with pytest.raises(ValueError, match="preset"):
        load_trace(AZURE_FIXTURE, preset="borg")


ALIBABA_FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                               "alibaba_tiny.csv")


def test_replay_alibaba_preset_maps_container_readings():
    """The alibaba preset turns container_usage-style readings into one
    rigid single-component app per container: cpu_request is in the
    trace's 1/100-core units, utilization percents are of the request,
    and missing memory readings default to a flat 50%."""
    tr = load_trace(ALIBABA_FIXTURE, preset="alibaba")
    assert tr.n_apps == 3 and tr.max_components == 1
    # sorted by first reading: c_1 (t=0), c_2 (t=10), c_3 (t=40)
    np.testing.assert_allclose(tr.submit, [0.0, 10.0, 40.0])
    # spans + one inferred interval; c_3 has a single reading and falls
    # back to the 10 s Alibaba cadence
    np.testing.assert_allclose(tr.runtime, [40.0, 30.0, 10.0])
    # 400/100 = 4 cores, 100/100 = 1, 200/100 = 2
    np.testing.assert_allclose(tr.cpu_req.ravel(), [4.0, 1.0, 2.0])
    np.testing.assert_allclose(tr.mem_req.ravel(), [8.0, 2.0, 4.0])
    assert tr.is_core.all() and not tr.is_elastic.any()
    # percent readings -> fractions, endpoints preserved by resampling
    np.testing.assert_allclose(tr.levels[0, 0, 0, 0], 0.30, atol=1e-6)
    np.testing.assert_allclose(tr.levels[0, 0, -1, 0], 0.52, atol=1e-6)
    # c_2 has blank mem_util_percent cells -> flat 50% default
    np.testing.assert_allclose(tr.levels[1, 0, :, 1], 0.5, atol=1e-6)


def test_replay_alibaba_preset_via_scenario_config():
    cfg = make_config("replay", path=ALIBABA_FIXTURE, preset="alibaba")
    tr = build_trace(cfg)
    res = run_sim(SimConfig(workload=cfg, policy="pessimistic",
                            forecaster="persist", max_ticks=2000))
    assert res.summary()["completed"] == tr.n_apps


# ----------------------------------------------------------------------
# diagnostics
# ----------------------------------------------------------------------

def test_sample_usage_series_shapes_and_determinism():
    tr = build_trace(_small("heavytail"))
    s1 = sample_usage_series(tr, n_series=6, length=40, seed=1)
    s2 = sample_usage_series(tr, n_series=6, length=40, seed=1)
    assert s1.shape == (6, 40)
    assert np.array_equal(s1, s2)


def test_forecast_error_report_persist_and_oracle():
    tr = build_trace(_small("google"))
    rep = forecast_error_report(tr, "persist", n_series=6, n_eval=3)
    assert rep["forecaster"] == "persist"
    assert np.isfinite(rep["abs_rel_err_median"])
    assert forecast_error_report(tr, "oracle") is None


# ----------------------------------------------------------------------
# sweep scenario axis
# ----------------------------------------------------------------------

def test_sweep_scenario_axis_per_scenario_metrics(tmp_path):
    base = SimConfig(cluster=ClusterConfig(n_hosts=3, max_running_apps=32),
                     workload=WorkloadConfig(n_apps=16, max_components=8,
                                             max_runtime=1200.0,
                                             mean_burst_gap=2.0,
                                             mean_long_gap=40.0),
                     forecaster="persist", max_ticks=20_000)
    out = tmp_path / "BENCH_sweep.json"
    res = run_grid(base,
                   axes={"scenario": ["google", "flashcrowd"],
                         "policy": ["baseline", "pessimistic"]},
                   seeds=[0], out_path=str(out))
    assert len(res.cells) == 4
    assert {c["scenario"] for c in res.cells} == {"google", "flashcrowd"}
    # per-scenario speedup: each scenario's baseline is its own denominator
    for a in res.aggregates:
        if a["overrides"]["policy"] == "baseline":
            assert a["turnaround_speedup"] == 1.0
        assert np.isfinite(a["turnaround_speedup"])
    # per-scenario trace stats + forecast-error diagnostics in the artifact
    assert set(res.scenarios) == {"google", "flashcrowd"}
    assert res.scenarios["google"]["n_apps"] == 16
    diag_keys = {(d["scenario"], d["forecaster"])
                 for d in res.forecast_error}
    assert diag_keys == {("google", "persist"), ("flashcrowd", "persist")}
    import json
    data = json.loads(out.read_text())
    assert data["schema"] == 3
    assert set(data["scenarios"]) == {"google", "flashcrowd"}
    assert len(data["forecast_error"]) == 2


def test_sweep_scenario_axis_workload_override_applies_after_swap():
    base = SimConfig(workload=WorkloadConfig(n_apps=8))
    from repro.sim.sweep import expand_grid
    cells = expand_grid(base, axes={"scenario": ["heavytail"],
                                    "workload.mean_gap": [33.0]},
                        seeds=[2])
    cfg = cells[0].cfg.workload
    assert scenario_of(cfg) == "heavytail"
    assert cfg.mean_gap == 33.0 and cfg.n_apps == 8 and cfg.seed == 2
