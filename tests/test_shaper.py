"""Resource-shaper tests: Algorithm 1 semantics + safety invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.shaper import (SafeguardConfig, ShapeProblem, baseline_shape,
                               beta, optimistic_shape, pessimistic_shape,
                               shaped_demand)


def _problem(host_cpu, host_mem, apps):
    """apps: list of dicts with comps: (host, cpu, mem, core, alive)."""
    A = len(apps)
    C = max(len(a) for a in apps)
    def z(dt):
        return np.zeros((A, C), dt)
    ex, co = z(bool), z(bool)
    ho = z(np.int32)
    cp, me, al = z(np.float32), z(np.float32), z(np.float32)
    for i, comps in enumerate(apps):
        for j, (h, c, m, is_core, alive) in enumerate(comps):
            ex[i, j] = True
            co[i, j] = is_core
            ho[i, j] = h
            cp[i, j], me[i, j], al[i, j] = c, m, alive
    return ShapeProblem(
        host_cpu=jnp.asarray(host_cpu, jnp.float32),
        host_mem=jnp.asarray(host_mem, jnp.float32),
        app_exists=jnp.ones((A,), bool),
        app_order=jnp.arange(A),
        comp_exists=jnp.asarray(ex), comp_core=jnp.asarray(co),
        comp_host=jnp.asarray(ho), comp_cpu=jnp.asarray(cp),
        comp_mem=jnp.asarray(me), comp_alive=jnp.asarray(al),
    )


def test_all_fit_nothing_killed():
    p = _problem([10.0], [100.0],
                 [[(0, 2, 20, True, 5)], [(0, 2, 20, True, 3)]])
    d = pessimistic_shape(p)
    assert not bool(d.kill_app.any()) and not bool(d.kill_comp.any())
    np.testing.assert_allclose(d.cpu_free, [6.0])
    np.testing.assert_allclose(d.mem_free, [60.0])


def test_core_overflow_evicts_whole_app_fifo_order():
    # app0 (older) takes 8 cpu; app1 core needs 4 -> evicted
    p = _problem([10.0], [100.0],
                 [[(0, 8, 10, True, 5)], [(0, 4, 10, True, 3)]])
    d = pessimistic_shape(p)
    assert list(np.asarray(d.kill_app)) == [False, True]
    # evicted app's allocation is zeroed
    assert float(d.alloc_cpu[1].sum()) == 0.0


def test_elastic_evicted_newest_first():
    # one app: core 2 + three elastic of 3 cpu each on a 9-cpu host:
    # core (2) + oldest (3) + middle (3) fit with 1 cpu spare; the
    # NEWEST (alive=1) hits the exhausted host and is preempted
    p = _problem([9.0], [100.0],
                 [[(0, 2, 5, True, 10), (0, 3, 5, False, 9),
                   (0, 3, 5, False, 8), (0, 3, 5, False, 1)]])
    d = pessimistic_shape(p)
    assert not bool(d.kill_app.any())
    kc = np.asarray(d.kill_comp[0])
    assert list(kc) == [False, False, False, True]


def test_elastic_checked_le_zero_core_lt_zero():
    """Paper listing: core uses < 0, elastic uses <= 0 (exact fit kills
    elastic but keeps core)."""
    p = _problem([4.0], [100.0], [[(0, 4, 10, True, 5)]])
    d = pessimistic_shape(p)
    assert not bool(d.kill_app.any())            # core exact fit survives
    p2 = _problem([4.0], [100.0],
                  [[(0, 2, 10, True, 5), (0, 2, 10, False, 1)]])
    d2 = pessimistic_shape(p2)
    assert bool(d2.kill_comp[0, 1])              # elastic exact fit dies


def test_optimistic_kills_on_contention():
    p = _problem([10.0], [30.0],
                 [[(0, 2, 20, True, 5)], [(0, 2, 20, True, 3)]])
    d = optimistic_shape(p)
    assert int(np.asarray(d.kill_app).sum()) == 1   # one of the two fails


def test_baseline_allocates_everything():
    p = _problem([10.0], [30.0],
                 [[(0, 2, 20, True, 5)], [(0, 2, 20, True, 3)]])
    d = baseline_shape(p)
    assert not bool(d.kill_app.any())
    assert float(jnp.sum(d.alloc_mem)) == 40.0      # overcommit visible


# ----------------------------------------------------------------------
# safety invariants (hypothesis)
# ----------------------------------------------------------------------

@st.composite
def problems(draw):
    H = draw(st.integers(1, 3))
    A = draw(st.integers(1, 5))
    C = draw(st.integers(1, 4))
    rng = np.random.RandomState(draw(st.integers(0, 10_000)))
    apps = []
    for _ in range(A):
        comps = []
        n = rng.randint(1, C + 1)
        for j in range(n):
            comps.append((rng.randint(0, H),
                          float(rng.uniform(0.1, 6)),
                          float(rng.uniform(0.1, 40)),
                          bool(j == 0 or rng.rand() < 0.4),
                          float(rng.uniform(0, 100))))
        apps.append(comps)
    return _problem([16.0] * H, [64.0] * H, apps)


@settings(max_examples=40, deadline=None)
@given(p=problems())
def test_pessimistic_never_overcommits(p):
    d = pessimistic_shape(p)
    H = p.host_cpu.shape[0]
    for r, (alloc, cap) in enumerate([(d.alloc_cpu, p.host_cpu),
                                      (d.alloc_mem, p.host_mem)]):
        used = np.zeros(H)
        a = np.asarray(alloc)
        h = np.asarray(p.comp_host)
        for i in range(a.shape[0]):
            for j in range(a.shape[1]):
                used[h[i, j]] += a[i, j]
        assert (used <= np.asarray(cap) + 1e-3).all()


@settings(max_examples=40, deadline=None)
@given(p=problems())
def test_pessimistic_kill_comp_only_elastic(p):
    d = pessimistic_shape(p)
    kc = np.asarray(d.kill_comp)
    core = np.asarray(p.comp_core)
    assert not (kc & core).any()


@settings(max_examples=40, deadline=None)
@given(p=problems())
def test_optimistic_post_kill_demand_fits(p):
    d = optimistic_shape(p)
    assert (np.asarray(d.cpu_free) >= -1e-3).all()
    assert (np.asarray(d.mem_free) >= -1e-3).all()


# ----------------------------------------------------------------------
# safeguard buffer (Eq. 9)
# ----------------------------------------------------------------------

def test_beta_monotonic_in_k1_k2():
    r, v = jnp.asarray(10.0), jnp.asarray(4.0)
    b00 = float(beta(r, v, SafeguardConfig(0.0, 0.0)))
    b10 = float(beta(r, v, SafeguardConfig(0.1, 0.0)))
    b13 = float(beta(r, v, SafeguardConfig(0.1, 3.0)))
    assert b00 == 0.0 and b10 == pytest.approx(1.0)
    assert b13 == pytest.approx(1.0 + 3 * 2.0)


def test_shaped_demand_clamped_to_request():
    d = shaped_demand(jnp.asarray(100.0), jnp.asarray(10.0),
                      jnp.asarray(25.0), SafeguardConfig(0.05, 3.0))
    assert float(d) == 10.0      # never exceeds reservation
    d2 = shaped_demand(jnp.asarray(2.0), jnp.asarray(10.0),
                       jnp.asarray(0.0), SafeguardConfig(0.05, 0.0))
    assert float(d2) == pytest.approx(2.5)


def test_k1_100pct_degenerates_to_baseline():
    """Paper: K1 = 100% -> allocation = reservation."""
    d = shaped_demand(jnp.asarray(1.0), jnp.asarray(10.0),
                      jnp.asarray(0.0), SafeguardConfig(1.0, 0.0))
    assert float(d) == 10.0
