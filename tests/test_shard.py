"""Sharded fleet correctness anchors (repro.sim.step.run_fleet_shard /
repro.sim.shard).

Contracts, in order of strength:

  * MESH-1 IDENTITY — ``run_fleet_shard(mesh=1)`` is bit-identical per
    member to ``run_cohort_scan`` (the shard engine is the cohort scan
    laid across a mesh; a 1-wide mesh must be a no-op);
  * MESH INVARIANCE — any wider mesh is bit-identical per member to
    ``mesh=1`` (re-slicing the fleet axis cannot change a member's
    numerics; XLA CPU reductions are batch-size invariant).  Wide
    meshes need forced host devices, so those tests skip on a single
    device and run in CI under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; one
    subprocess test keeps the multi-device path exercised in every
    tier-1 run;
  * the sweep's ``engine="shard"`` groups cells into fleets (cells x
    seeds, across scenarios) and falls back to ``scan`` on one device.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, generate
from repro.sim.step import run_cohort_scan, run_fleet_shard, run_sim_scan

WL = WorkloadConfig(n_apps=20, max_components=5, max_runtime=1200.0,
                    mean_burst_gap=4.0, mean_long_gap=60.0, seed=3)
CL = ClusterConfig(n_hosts=3, max_running_apps=12)
BASE = SimConfig(cluster=CL, workload=WL, max_ticks=2500,
                 policy="pessimistic", forecaster="persist")

multi_device = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _results_equal(a, b) -> bool:
    return (a.summary() == b.summary()
            and a.turnaround == b.turnaround
            and a.failed_apps == b.failed_apps
            and a.slack_cpu == b.slack_cpu and a.slack_mem == b.slack_mem
            and a.util_cpu == b.util_cpu and a.util_mem == b.util_mem
            and a.n_running == b.n_running)


# ----------------------------------------------------------------------
# mesh=1 identity: shard is the cohort scan laid across a 1-wide mesh
# ----------------------------------------------------------------------

def test_mesh1_matches_cohort_scan():
    seeds = [0, 1, 2]
    cohort = run_cohort_scan(BASE, seeds, chunk=16)
    fleet = run_fleet_shard(BASE, seeds, chunk=16, mesh=1)
    for s, a, b in zip(seeds, cohort, fleet):
        assert _results_equal(a, b), f"seed {s} diverged"


def test_explicit_cfgs_cross_scenario_fleet():
    """A fleet may mix WORKLOADS (scenario families), not just seeds."""
    from repro.sim.scenarios import make_config
    other = dataclasses.replace(
        BASE, workload=make_config("flashcrowd", base=BASE.workload))
    fleet = run_fleet_shard(BASE, cfgs=[BASE, other], chunk=16, mesh=1)
    assert _results_equal(fleet[0], run_sim_scan(BASE, chunk=16))
    assert _results_equal(fleet[1], run_sim_scan(other, chunk=16))


def test_fleet_rejects_non_workload_heterogeneity():
    other = dataclasses.replace(BASE, policy="baseline")
    with pytest.raises(ValueError, match="beyond its workload"):
        run_fleet_shard(BASE, cfgs=[BASE, other])


def test_fleet_rejects_mismatched_shapes():
    other = dataclasses.replace(
        BASE, workload=dataclasses.replace(WL, seed=1,
                                           n_apps=WL.n_apps + 1))
    with pytest.raises(ValueError, match="shape"):
        run_fleet_shard(BASE, cfgs=[BASE, other])


def test_forecast_rows_telemetry():
    """The scan/shard engines report the masked-forecast load the
    ROADMAP asks to measure (rows past grace vs the full padded batch)."""
    res = run_fleet_shard(BASE, [0, 1], chunk=16, mesh=1)[0]
    fr = res.forecast_rows
    assert fr is not None
    A, C = CL.max_running_apps, WL.max_components
    assert fr["rows_batch"] == 2 * A * C
    assert 0 < fr["rows_ready"] <= fr["rows_batch"] * fr["ticks"]
    assert 0 < fr["ticks_forecasting"] <= fr["ticks"]
    # telemetry must not leak into the engine-agreement summary
    assert "forecast_rows" not in res.summary()


# ----------------------------------------------------------------------
# wide meshes (forced host devices)
# ----------------------------------------------------------------------

@multi_device
def test_wide_mesh_matches_mesh1():
    seeds = list(range(6))
    narrow = run_fleet_shard(BASE, seeds, chunk=16, mesh=1)
    wide = run_fleet_shard(BASE, seeds, chunk=16, mesh=4)
    for s, a, b in zip(seeds, narrow, wide):
        assert _results_equal(a, b), f"seed {s} diverged"


@multi_device
def test_padding_roundup_discarded():
    """A fleet that does not divide the mesh gets padded with repeats
    of the last member; padding must never leak into results."""
    seeds = [0, 1, 2, 3, 4]                  # 5 members, mesh 2 -> pad 6
    fleet = run_fleet_shard(BASE, seeds, chunk=16, mesh=2)
    assert len(fleet) == len(seeds)
    for s, res in zip(seeds, fleet):
        solo_cfg = dataclasses.replace(
            BASE, workload=dataclasses.replace(BASE.workload, seed=s))
        assert _results_equal(res, run_sim_scan(solo_cfg, chunk=16)), s


@multi_device
def test_sweep_shard_engine_matches_solo_scans():
    from repro.sim.sweep import (_apply_overrides, _set_path,
                                 quick_base_config, run_grid)
    base = quick_base_config(n_apps=20, n_hosts=3, seed=0)
    res = run_grid(base, axes={"scenario": ["google", "flashcrowd"],
                               "policy": ["baseline", "pessimistic"],
                               "forecaster": ["persist"]},
                   seeds=[0, 1], engine="shard", mesh=4)
    assert res.engine == "shard"
    assert res.mesh_devices == 4
    assert res.forecast_batches == 0          # batcher retired
    assert len(res.cells) == 8
    for cell in res.cells:
        cfg = _apply_overrides(base, cell["overrides"])
        cfg = _set_path(cfg, "workload.seed", cell["seed"])
        assert run_sim_scan(cfg).summary() == cell["summary"], cell["name"]


@multi_device
def test_group_fleets_cells_by_static_config():
    from repro.sim.scenarios import build_trace
    from repro.sim.shard import group_fleets
    from repro.sim.sweep import expand_grid, quick_base_config
    base = quick_base_config(n_apps=20, n_hosts=3, seed=0)
    grid = expand_grid(base,
                       axes={"scenario": ["google", "flashcrowd"],
                             "policy": ["baseline", "pessimistic"]},
                       seeds=[0, 1])
    workloads = {c.cfg.workload: build_trace(c.cfg.workload) for c in grid}
    fleets = group_fleets(grid, workloads)
    # scenario x seed fold into ONE fleet per static config (= policy)
    assert sorted(len(f) for f in fleets) == [4, 4]
    for fleet in fleets:
        assert len({c.cfg.policy for c in fleet}) == 1


# ----------------------------------------------------------------------
# single-device behaviour
# ----------------------------------------------------------------------

def test_sweep_shard_falls_back_to_scan_on_one_device(capsys):
    from repro.sim.sweep import quick_base_config, run_grid
    if jax.device_count() > 1:
        pytest.skip("fallback only triggers on a single device")
    base = quick_base_config(n_apps=20, n_hosts=3, seed=0)
    # mesh=4 over-asks the single visible device: still a graceful
    # fallback (clamped to the devices), never a ValueError
    res = run_grid(base, axes={"policy": ["pessimistic"],
                               "forecaster": ["persist"]},
                   seeds=[0, 1], engine="shard", mesh=4)
    assert res.engine == "scan"
    assert res.mesh_devices == 0
    assert "falling back" in capsys.readouterr().out


# ----------------------------------------------------------------------
# forced-host-device subprocess: the multi-device path stays exercised
# even when the parent run has a single device
# ----------------------------------------------------------------------

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import dataclasses, json
from repro.sim import ClusterConfig, SimConfig, WorkloadConfig
from repro.sim.step import run_fleet_shard

WL = WorkloadConfig(n_apps=12, max_components=4, max_runtime=900.0,
                    mean_burst_gap=4.0, mean_long_gap=60.0, seed=3)
cfg = SimConfig(cluster=ClusterConfig(n_hosts=2, max_running_apps=8),
                workload=WL, max_ticks=1500,
                policy="pessimistic", forecaster="persist")
fleet = run_fleet_shard(cfg, [0, 1, 2, 3], chunk=16, mesh=4)
print(json.dumps([{"turnaround": r.turnaround, "summary": r.summary()}
                  for r in fleet]))
"""


def test_wide_mesh_bit_identity_subprocess():
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        filter(None, [os.path.join(os.path.dirname(__file__), "..", "src"),
                      os.environ.get("PYTHONPATH", "")])))
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    child = json.loads(out.stdout.splitlines()[-1])
    assert len(child) == 4

    WLc = dataclasses.replace(WL, n_apps=12, max_components=4,
                              max_runtime=900.0)
    cfg = dataclasses.replace(
        BASE, cluster=ClusterConfig(n_hosts=2, max_running_apps=8),
        workload=WLc, max_ticks=1500)
    for seed, got in zip([0, 1, 2, 3], child):
        solo_cfg = dataclasses.replace(
            cfg, workload=dataclasses.replace(cfg.workload, seed=seed))
        want = run_sim_scan(solo_cfg, chunk=16)
        # JSON round-trip stringifies dict keys — normalize ours the
        # same way before comparing
        assert got["turnaround"] == json.loads(
            json.dumps(want.turnaround)), f"seed {seed}"
        assert got["summary"] == json.loads(
            json.dumps(want.summary())), f"seed {seed}"
