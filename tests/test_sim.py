"""Simulator integration tests + conservation invariants."""
import numpy as np

from repro.core.shaper import SafeguardConfig
from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, generate, run_sim

WL = WorkloadConfig(n_apps=40, max_components=8, max_runtime=1200.0,
                    mean_burst_gap=4.0, mean_long_gap=60.0, seed=7)
CL = ClusterConfig(n_hosts=4, max_running_apps=32)


def _run(policy, forecaster, **kw):
    cfg = SimConfig(cluster=CL, workload=WL, policy=policy,
                    forecaster=forecaster, max_ticks=4000, **kw)
    return run_sim(cfg)


def test_baseline_completes_everything():
    r = _run("baseline", "persist")
    s = r.summary()
    assert s["completed"] == WL.n_apps
    assert s["failed_frac"] == 0.0
    assert s["full_preemptions"] == 0
    assert np.isfinite(s["turnaround_mean"])


def test_turnaround_at_least_runtime():
    wl = generate(WL)
    r = _run("baseline", "persist")
    for gid, ta in r.turnaround.items():
        assert ta >= wl.runtime[gid] - CL.tick - 1e-3


def test_pessimistic_oracle_no_failures():
    """Paper Fig. 3: oracle + pessimistic -> zero (uncontrolled)
    application failures."""
    r = _run("pessimistic", "oracle")
    s = r.summary()
    assert s["completed"] == WL.n_apps
    assert s["failed_frac"] == 0.0
    assert s["oom_kills"] == 0


def test_shaping_reduces_slack():
    b = _run("baseline", "persist").summary()
    p = _run("pessimistic", "oracle").summary()
    assert p["slack_mem_mean"] < b["slack_mem_mean"]


def test_workload_reservations_cover_usage():
    wl = generate(WL)
    for prog in (0.0, 0.3, 0.7, 1.0):
        u = wl.usage(np.arange(wl.n_apps),
                     np.full(wl.n_apps, prog, np.float32))
        assert (u[:, :, 0] <= wl.cpu_req + 1e-4).all()
        assert (u[:, :, 1] <= wl.mem_req + 1e-4).all()


def test_workload_peak_touches_reservation():
    wl = generate(WL)
    peaks = wl.levels.max(axis=2)                    # (N, C, 2)
    exists = wl.cpu_req > 0
    assert (peaks[exists][:, 0] > 0.9).all()
    assert (peaks[exists][:, 1] > 0.9).all()


def test_elastic_apps_slow_down_when_preempted():
    wl = generate(WL)
    from repro.sim.cluster import Cluster
    cl = Cluster(CL, wl.max_components)
    gid = int(np.nonzero(wl.is_elastic)[0][0])
    slot = cl.admit(gid, wl, 0.0)
    assert slot >= 0
    full_rate = cl.progress_rate(wl)[slot]
    el = [c for c in range(wl.max_components)
          if wl.cpu_req[gid, c] > 0 and not wl.is_core[gid, c]
          and cl.comp_running[slot, c]]
    if el:
        cl.kill_component(slot, el[0])
        assert cl.progress_rate(wl)[slot] < full_rate


def test_rigid_apps_have_no_elastic():
    wl = generate(WL)
    rigid = ~wl.is_elastic
    assert (wl.n_elastic[rigid] == 0).all()


def test_gp_pessimistic_runs_and_completes():
    r = _run("pessimistic", "gp",
             safeguard=SafeguardConfig(k1=0.05, k2=1.0))
    s = r.summary()
    assert s["completed"] == WL.n_apps
    assert np.isfinite(s["turnaround_mean"])
