"""Optimizer, data pipeline, checkpoint, fault-tolerance, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import DataConfig, SyntheticStream
from repro.distributed.compression import (compressed_psum, dequantize_int8,
                                           ef_compress, quantize_int8)
from repro.distributed.fault import (HeartbeatTracker, RestartLedger,
                                     StragglerDetector)
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, min_lr_frac=1.0)
    for _ in range(100):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clipping_caps_update():
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                      warmup_steps=0, min_lr_frac=1.0)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, stats = adamw_update(grads, state, params, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0            # warmup rises
    assert lrs[99] == pytest.approx(0.1, abs=0.02)


def test_microbatch_equivalence():
    """grad accumulation over 2 microbatches == single big batch."""
    from repro.models import get_config
    from repro.models import transformer as T
    from repro.train import TrainConfig, make_train_step
    cfg = get_config("internlm2-1.8b", smoke=True)
    params = T.init_lm(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab)}
    opt = adamw_init(params)
    p1, _, s1 = make_train_step(cfg, TrainConfig(microbatches=1))(
        params, opt, batch)
    p2, _, s2 = make_train_step(cfg, TrainConfig(microbatches=2))(
        params, opt, batch)
    assert float(s1["loss"]) == pytest.approx(float(s2["loss"]), rel=1e-4)
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        p1, p2)))
    assert diff < 5e-3


# ----------------------------------------------------------------------
# data
# ----------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=3)
    s = SyntheticStream(cfg)
    b1, b2 = s.batch(5), s.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s.batch(6)["tokens"], b1["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert b1["tokens"].max() < 1000 and b1["tokens"].min() >= 0


def test_data_prefetch_order():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    s = SyntheticStream(cfg)
    got = list(s.prefetch(4))
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], s.batch(i)["tokens"])


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    r = load_pytree(t, str(tmp_path / "ck"))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_pytree(_tree(), str(tmp_path / "ck"))
    assert not os.path.exists(str(tmp_path / "ck.tmp"))


def test_manager_keep_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        m.save(s, _tree())
    assert m.steps() == [20, 30]
    assert m.latest() == 30
    restored, step = m.restore(_tree())
    assert step == 30


def test_manager_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    m.save_async(5, _tree())
    m.wait()
    assert m.latest() == 5


def test_elastic_reshard_roundtrip(tmp_path):
    """checkpoint -> host -> new mesh placement preserves values."""
    from repro.distributed.elastic import reshard, to_host
    from repro.launch.mesh import make_host_mesh
    t = {"wq": jnp.ones((8, 16)), "wo": jnp.ones((16, 8))}
    host = to_host(t)
    mesh = make_host_mesh()
    r = reshard(host, mesh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# fault tolerance
# ----------------------------------------------------------------------

def test_heartbeat_deadline():
    hb = HeartbeatTracker(deadline_s=10.0)
    hb.beat(0, now=100.0)
    hb.beat(1, now=105.0)
    assert hb.dead_hosts(now=112.0) == [0]
    assert hb.alive(now=112.0) == [1]


def test_straggler_detection():
    sd = StragglerDetector(alpha=1.0, threshold=1.5)
    for h in range(4):
        sd.record(h, 1.0)
    sd.record(3, 10.0)
    assert sd.stragglers() == [3]


def test_restart_ledger_replay(tmp_path):
    led = RestartLedger(str(tmp_path / "ledger.jsonl"))
    led.record("checkpoint_committed", step=100)
    led.record("host_failed", host=3)
    led.record("checkpoint_committed", step=200)
    assert led.last_committed_step() == 200
    assert len(led.replay()) == 3


# ----------------------------------------------------------------------
# gradient compression
# ----------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(KEY, (256,)) * 3
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x).max()
    assert float(err) <= float(scale) / 2 + 1e-6


def test_error_feedback_identity():
    """q*scale + residual exactly reconstructs the EF target."""
    x = jax.random.normal(KEY, (64,))
    res0 = jnp.zeros_like(x)
    q, scale, res1 = ef_compress(x, res0)
    np.testing.assert_allclose(dequantize_int8(q, scale) + res1, x,
                               rtol=1e-5, atol=1e-6)


def test_compressed_psum_single_device():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("pod",))
    x = jax.random.normal(KEY, (32,))
    res = jnp.zeros_like(x)

    def f(x, r):
        return compressed_psum(x, r, "pod")

    from repro.distributed.shmap import shard_map
    out, new_res = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()))(x, res)
    np.testing.assert_allclose(out + new_res, x, rtol=1e-5, atol=1e-5)
