"""Sweep subsystem tests: grid expansion, vectorized-engine equivalence,
solo-vs-sweep bit-identity, artifact schema."""
import dataclasses
import itertools
import json

import numpy as np
import pytest

from repro.sim import (ClusterConfig, SimConfig, WorkloadConfig, generate,
                       run_sim, run_sim_reference)
from repro.sim.sweep import expand_grid, quick_base_config, run_grid

WL = WorkloadConfig(n_apps=40, max_components=8, max_runtime=1200.0,
                    mean_burst_gap=4.0, mean_long_gap=60.0, seed=7)
CL = ClusterConfig(n_hosts=4, max_running_apps=32)
BASE = SimConfig(cluster=CL, workload=WL, max_ticks=4000)


def _results_equal(a, b) -> bool:
    return (a.summary() == b.summary()
            and a.turnaround == b.turnaround
            and a.failed_apps == b.failed_apps
            and a.slack_cpu == b.slack_cpu and a.slack_mem == b.slack_mem
            and a.util_cpu == b.util_cpu and a.util_mem == b.util_mem
            and a.n_running == b.n_running)


# ----------------------------------------------------------------------
# grid expansion
# ----------------------------------------------------------------------

def test_grid_covers_cross_product_exactly_once():
    axes = {"policy": ["baseline", "pessimistic"],
            "forecaster": ["persist", "oracle"],
            "safeguard.k1": [0.0, 0.05, 0.25]}
    seeds = [0, 1]
    cells = expand_grid(BASE, axes, seeds)
    assert len(cells) == 2 * 2 * 3 * 2
    seen = {(c.cfg.policy, c.cfg.forecaster, c.cfg.safeguard.k1, c.seed)
            for c in cells}
    want = set(itertools.product(["baseline", "pessimistic"],
                                 ["persist", "oracle"],
                                 [0.0, 0.05, 0.25], seeds))
    assert seen == want                      # every combo exactly once


def test_grid_zipped_axis_and_explicit_cells():
    cells = expand_grid(
        BASE,
        axes={("policy", "forecaster"): [("baseline", "persist"),
                                         ("pessimistic", "oracle")]},
        seeds=[3],
        cells=[{"policy": "optimistic", "forecaster": "oracle"}])
    combos = [(c.cfg.policy, c.cfg.forecaster) for c in cells]
    assert combos == [("baseline", "persist"), ("pessimistic", "oracle"),
                      ("optimistic", "oracle")]
    assert all(c.cfg.workload.seed == 3 for c in cells)


def test_grid_base_seed_kept_when_seeds_none():
    cells = expand_grid(BASE, {"policy": ["baseline"]}, seeds=None)
    assert len(cells) == 1 and cells[0].cfg.workload.seed == WL.seed


def test_grid_nested_override_leaves_base_untouched():
    cells = expand_grid(BASE, {"safeguard.k2": [9.0]}, seeds=[0])
    assert cells[0].cfg.safeguard.k2 == 9.0
    assert BASE.safeguard.k2 != 9.0


# ----------------------------------------------------------------------
# vectorized engine == seed (reference) engine, bit for bit
# ----------------------------------------------------------------------

@pytest.mark.parametrize("policy,forecaster", [
    ("baseline", "persist"),
    ("pessimistic", "oracle"),
    ("optimistic", "oracle"),
    ("pessimistic", "persist"),     # exercises monitor windows + grace
])
def test_vectorized_engine_matches_reference(policy, forecaster):
    cfg = dataclasses.replace(BASE, policy=policy, forecaster=forecaster)
    wl = generate(cfg.workload)
    vec = run_sim(cfg, wl)
    ref = run_sim_reference(cfg, wl)
    s, r = vec.summary(), ref.summary()
    # the headline counters the paper plots ...
    for k in ("completed", "failed_frac", "failure_events", "oom_kills",
              "full_preemptions", "partial_preemptions"):
        assert s[k] == r[k], (k, s[k], r[k])
    # ... and in fact the entire result, bit for bit
    assert _results_equal(vec, ref)


def test_vectorized_engine_matches_reference_checkpoint_mode():
    cfg = dataclasses.replace(BASE, policy="pessimistic",
                              forecaster="oracle", work_lost_on_kill=False)
    wl = generate(cfg.workload)
    assert _results_equal(run_sim(cfg, wl), run_sim_reference(cfg, wl))


# ----------------------------------------------------------------------
# sweep driver
# ----------------------------------------------------------------------

def test_sweep_cell_bit_identical_to_solo_run():
    """Same seed => same SimResults whether a cell runs alone or inside a
    thread-pooled sweep with cross-sim forecast batching."""
    base = quick_base_config(n_apps=30, n_hosts=3, seed=0)
    res = run_grid(base,
                   axes={"policy": ["baseline", "pessimistic"],
                         "forecaster": ["persist", "gp"]},
                   seeds=[0, 1], workers=4)
    assert len(res.cells) == 8
    for overrides, seed in (({"policy": "pessimistic", "forecaster": "gp"}, 1),
                            ({"policy": "baseline", "forecaster": "persist"}, 0)):
        cell = next(c for c in res.cells
                    if c["overrides"] == overrides and c["seed"] == seed)
        cfg = base
        for k, v in overrides.items():
            cfg = dataclasses.replace(cfg, **{k: v})
        cfg = dataclasses.replace(
            cfg, workload=dataclasses.replace(cfg.workload, seed=seed))
        assert run_sim(cfg).summary() == cell["summary"]


def test_sweep_aggregates_and_artifact(tmp_path):
    out = tmp_path / "BENCH_sweep.json"
    base = quick_base_config(n_apps=24, n_hosts=3, seed=0)
    res = run_grid(base,
                   axes={"policy": ["baseline", "pessimistic"],
                         "forecaster": ["oracle"]},
                   seeds=[0, 1], out_path=str(out))
    data = json.loads(out.read_text())
    assert data["schema"] == 3
    assert "google" in data["scenarios"]        # per-scenario trace stats
    assert len(data["cells"]) == 4 and len(data["aggregates"]) == 2
    for c in data["cells"]:
        for key in ("turnaround_mean", "failed_frac", "util_mem_mean"):
            assert key in c["summary"]
    by_policy = {a["overrides"]["policy"]: a for a in data["aggregates"]}
    assert by_policy["baseline"]["turnaround_speedup"] == 1.0
    assert np.isfinite(by_policy["pessimistic"]["turnaround_speedup"])
    assert by_policy["pessimistic"]["n_seeds"] == 2
    # deterministic per seed: rerun reproduces the same summaries
    res2 = run_grid(base, axes={"policy": ["baseline", "pessimistic"],
                                "forecaster": ["oracle"]}, seeds=[0, 1])
    assert [c["summary"] for c in res2.cells] == \
        [c["summary"] for c in res.cells]


def test_batcher_propagates_leader_failure(monkeypatch):
    """A failing forecast must raise in EVERY participating sim instead of
    deadlocking followers on their never-set events."""
    import threading

    from repro.sim import sweep as SW

    monkeypatch.setattr(
        SW, "forecast_peaks",
        lambda *a, **k: (_ for _ in ()).throw(RuntimeError("boom")))
    batcher = SW.ForecastBatcher(wait_s=0.05)
    cfg = dataclasses.replace(quick_base_config(), forecaster="gp")
    clients = [batcher.client(cfg) for _ in range(2)]
    wins = np.zeros((2, cfg.window), np.float32)
    val = np.ones((2, cfg.window), bool)
    errs = []

    def call(c):
        try:
            c(wins, val)
        except RuntimeError as e:
            errs.append(str(e))

    threads = [threading.Thread(target=call, args=(c,)) for c in clients]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert not any(t.is_alive() for t in threads), "batcher deadlocked"
    assert errs == ["boom", "boom"]


def test_sweep_reference_engine_option():
    base = quick_base_config(n_apps=16, n_hosts=2, seed=0)
    kw = dict(axes={"policy": ["pessimistic"], "forecaster": ["oracle"]},
              seeds=[0])
    vec = run_grid(base, **kw)
    ref = run_grid(base, engine="reference", **kw)
    assert vec.cells[0]["summary"] == ref.cells[0]["summary"]
