"""End-to-end behaviour tests for the paper's system.

The headline claims, asserted at CI scale:
  1. dynamic shaping beats the reservation baseline on turnaround+slack
     under saturation (paper Figs. 3/5);
  2. the pessimistic policy never produces uncontrolled failures with an
     oracle, while the optimistic policy does (paper §4.2);
  3. the live training driver trains (loss drops), checkpoints, resumes;
  4. the serving driver completes all requests under a shaper-governed
     batch cap.
"""
import numpy as np
import pytest

from repro.sim import ClusterConfig, SimConfig, WorkloadConfig, run_sim

# saturated mini-cluster: queueing pressure makes shaping matter
WL = WorkloadConfig(n_apps=120, max_components=10, max_runtime=3600.0,
                    mean_burst_gap=1.0, mean_long_gap=30.0, seed=11)
CL = ClusterConfig(n_hosts=5, max_running_apps=96)


def _run(policy, forecaster):
    return run_sim(SimConfig(cluster=CL, workload=WL, policy=policy,
                             forecaster=forecaster, max_ticks=8000)).summary()


@pytest.fixture(scope="module")
def results():
    return {
        "baseline": _run("baseline", "persist"),
        "pessimistic": _run("pessimistic", "oracle"),
        "optimistic": _run("optimistic", "oracle"),
    }


def test_everything_completes(results):
    for name, s in results.items():
        assert s["completed"] == WL.n_apps, name


def test_shaping_beats_baseline_turnaround(results):
    assert (results["pessimistic"]["turnaround_mean"]
            < results["baseline"]["turnaround_mean"])


def test_shaping_beats_baseline_slack(results):
    assert (results["pessimistic"]["slack_mem_mean"]
            < results["baseline"]["slack_mem_mean"])


def test_pessimistic_zero_failures_optimistic_fails(results):
    assert results["pessimistic"]["failed_frac"] == 0.0
    assert results["optimistic"]["failed_frac"] > 0.0


def test_pessimistic_beats_optimistic(results):
    """Paper: 'the pessimistic policy ... is consistently superior'."""
    assert (results["pessimistic"]["turnaround_mean"]
            <= results["optimistic"]["turnaround_mean"] * 1.05)


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main
    out = main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "40",
                "--batch", "4", "--seq", "64", "--ckpt-every", "20",
                "--ckpt-dir", str(tmp_path)])
    assert out["final_loss"] < out["first_loss"]


def test_train_driver_resume(tmp_path):
    from repro.launch.train import main
    main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "20",
          "--batch", "4", "--seq", "64", "--ckpt-every", "10",
          "--ckpt-dir", str(tmp_path)])
    out = main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "30",
                "--batch", "4", "--seq", "64", "--ckpt-every", "10",
                "--ckpt-dir", str(tmp_path), "--resume"])
    assert np.isfinite(out["final_loss"])


def test_serve_driver_end_to_end():
    from repro.launch.serve import main
    stats = main(["--arch", "internlm2-1.8b", "--smoke",
                  "--requests", "12", "--max-batch", "4",
                  "--prompt-len", "16", "--gen-len", "4"])
    assert stats["tokens"] == 12 * 4
