"""Uncertainty-calibration subsystem tests: conformal coverage
convergence (Gaussian + Pareto residual streams), proper-scoring
metrics, safeguard monotonicity in the target quantile, adaptive
control, engine integration, and the sweep's calibration axis."""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forecast import Forecast
from repro.core.shaper import SafeguardConfig, shaped_demand, shaped_demand_scaled
from repro.core.uncertainty import (CalibrationConfig, ConformalForecaster,
                                    OnlineCalibrator, QuantileController,
                                    ScoreBuffer, conformal_scale,
                                    crps_gaussian, empirical_coverage,
                                    gaussian_quantile_scale, pinball_loss,
                                    sigma_from_var)

Q = 0.9


def _coverage_of_scale(scale: float, eval_scores: np.ndarray) -> float:
    return float(np.mean(eval_scores <= scale))


# ----------------------------------------------------------------------
# split-conformal core: distribution-free coverage
# ----------------------------------------------------------------------

def _spiky(rng, n):
    """Flashcrowd-like residuals: mostly small noise, 15% large spikes
    (standardized).  The regime where a Gaussian z-band under-covers."""
    spike = rng.rand(n) < 0.15
    raw = np.where(spike, rng.normal(3.0, 0.5, n), rng.normal(0, 0.3, n))
    return ((raw - raw.mean()) / raw.std()).astype(np.float32)


@pytest.mark.parametrize("dist", ["gaussian", "pareto", "spiky"])
def test_conformal_coverage_converges_to_nominal(dist):
    """Calibrate on one half of an iid score stream, evaluate on the
    other: conformal coverage lands within +-3 points of nominal on
    EVERY distribution; the Gaussian z-band only manages that where its
    assumption holds (standardized Pareto over-covers at q = 0.9, the
    spike mixture under-covers — both are miscalibrated)."""
    rng = np.random.RandomState(0)
    n = 2000
    if dist == "gaussian":
        scores = rng.normal(0, 1, 2 * n).astype(np.float32)
    elif dist == "pareto":
        raw = rng.pareto(2.5, 2 * n)           # heavy-tailed residuals
        scores = ((raw - raw.mean()) / raw.std()).astype(np.float32)
    else:
        scores = _spiky(rng, 2 * n)
    cal, ev = scores[:n], scores[n:]

    ring = ScoreBuffer(1, n)
    ring.push_many(0, cal)
    zc = float(ring.scales(np.asarray([0]), Q, 99.0)[0])
    zg = float(gaussian_quantile_scale(Q))
    cov_c = _coverage_of_scale(zc, ev)
    cov_g = _coverage_of_scale(zg, ev)
    assert abs(cov_c - Q) <= 0.03, (dist, cov_c)
    if dist == "gaussian":
        assert abs(cov_g - Q) <= 0.03
    else:
        # conformal is strictly better calibrated than the z-band
        assert abs(cov_g - Q) > abs(cov_c - Q), (cov_g, cov_c)
    if dist == "spiky":
        assert cov_g < Q - 0.03      # the deficit conformal repairs


def test_conformal_scale_monotone_in_q():
    rng = np.random.RandomState(1)
    ring = ScoreBuffer(1, 512)
    ring.push_many(0, rng.normal(0, 1, 512).astype(np.float32))
    rows = np.asarray([0])
    scales = [float(ring.scales(rows, q, 0.0)[0])
              for q in (0.5, 0.7, 0.9, 0.95, 0.99)]
    assert all(b >= a for a, b in zip(scales, scales[1:]))


def test_conformal_scale_finite_sample_correction():
    """With n scores, level q > n/(n+1) must saturate at the max score
    (the bounded surrogate of conformal's +inf), never extrapolate."""
    ring = ScoreBuffer(1, 8)
    ring.push_many(0, np.arange(8, dtype=np.float32))
    assert float(ring.scales(np.asarray([0]), 0.999, 0.0)[0]) == 7.0


def test_conformal_scale_fallback_and_ring_eviction():
    ring = ScoreBuffer(2, 4)
    # empty series -> fallback
    assert float(ring.scales(np.asarray([1]), Q, 3.0)[0]) == 3.0
    # ring keeps only the newest `capacity` scores
    ring.push_many(0, np.asarray([100.0, 100.0, 1.0, 2.0, 3.0, 4.0],
                                 np.float32))
    assert float(ring.scales(np.asarray([0]), 0.999, 0.0)[0]) == 4.0
    assert int(ring.n(np.asarray([0]))[0]) == 4


def test_conformal_scale_is_batched_and_row_independent():
    rng = np.random.RandomState(2)
    buf = rng.normal(0, 1, (5, 64)).astype(np.float32)
    counts = np.asarray([64, 64, 10, 0, 64])
    q = np.full((5,), Q, np.float32)
    fb = np.full((5,), 3.0, np.float32)
    batch = np.asarray(conformal_scale(jnp.asarray(buf),
                                       jnp.asarray(counts),
                                       jnp.asarray(q), jnp.asarray(fb)))
    for i in range(5):
        solo = np.asarray(conformal_scale(jnp.asarray(buf[i:i + 1]),
                                          jnp.asarray(counts[i:i + 1]),
                                          jnp.asarray(q[:1]),
                                          jnp.asarray(fb[:1])))
        assert batch[i] == solo[0]
    assert batch[3] == 3.0           # empty row -> fallback


# ----------------------------------------------------------------------
# proper-scoring metrics
# ----------------------------------------------------------------------

def test_pinball_minimized_near_true_quantile():
    rng = np.random.RandomState(3)
    y = jnp.asarray(rng.normal(0, 1, 4000).astype(np.float32))
    true_q = float(gaussian_quantile_scale(Q))
    cands = np.linspace(-1.0, 3.0, 41)
    losses = [float(pinball_loss(y, jnp.full_like(y, c), Q)) for c in cands]
    assert abs(cands[int(np.argmin(losses))] - true_q) <= 0.2


def test_crps_rewards_sharp_calibrated_forecasts():
    rng = np.random.RandomState(4)
    y = jnp.asarray(rng.normal(0, 1, 2000).astype(np.float32))
    zero = jnp.zeros_like(y)
    honest = float(crps_gaussian(y, zero, jnp.ones_like(y)))
    too_wide = float(crps_gaussian(y, zero, 25.0 * jnp.ones_like(y)))
    biased = float(crps_gaussian(y, zero + 3.0, jnp.ones_like(y)))
    assert honest < too_wide and honest < biased


def test_empirical_coverage_masking():
    y = jnp.asarray([0.0, 1.0, 2.0, 3.0])
    up = jnp.asarray([1.0, 1.0, 1.0, 10.0])
    assert float(empirical_coverage(y, up)) == 0.75
    w = jnp.asarray([True, True, False, False])
    assert float(empirical_coverage(y, up, where=w)) == 1.0


def test_sigma_from_var_clamps_negatives():
    v = jnp.asarray([-1e-6, 0.0, 4.0])
    np.testing.assert_allclose(np.asarray(sigma_from_var(v)), [0.0, 0.0, 2.0])


# ----------------------------------------------------------------------
# Forecast quantile API + safeguard monotonicity
# ----------------------------------------------------------------------

def test_forecast_quantile_api():
    fc = Forecast(mean=jnp.asarray([1.0, 2.0]), var=jnp.asarray([4.0, 9.0]))
    np.testing.assert_allclose(np.asarray(fc.sigma), [2.0, 3.0])
    np.testing.assert_allclose(np.asarray(fc.quantile(0.5)), [1.0, 2.0],
                               atol=1e-6)
    z = float(gaussian_quantile_scale(Q))
    np.testing.assert_allclose(np.asarray(fc.quantile(Q)),
                               [1.0 + 2 * z, 2.0 + 3 * z], rtol=1e-6)
    # distribution-free override: a calibrated scale replaces z
    np.testing.assert_allclose(np.asarray(fc.quantile(Q, scale=2.0)),
                               [5.0, 8.0], rtol=1e-6)
    lo, hi = fc.interval(0.1, Q)
    assert (np.asarray(lo) <= np.asarray(hi)).all()


def test_shaped_demand_scaled_monotone_in_scale():
    peak = jnp.asarray([2.0, 5.0, 0.5])
    req = jnp.asarray([10.0, 10.0, 10.0])
    var = jnp.asarray([1.0, 0.25, 4.0])
    prev = None
    for s in (0.0, 0.5, 1.0, 2.0, 4.0):
        d = np.asarray(shaped_demand_scaled(peak, req, var, 0.05,
                                            jnp.full((3,), s)))
        assert (d <= np.asarray(req) + 1e-6).all()
        if prev is not None:
            assert (d >= prev - 1e-6).all()
        prev = d


def test_shaped_demand_scaled_matches_legacy_at_k2():
    """scale == K2 everywhere reproduces the Eq. 9 sigma path exactly."""
    rng = np.random.RandomState(5)
    peak = jnp.asarray(rng.uniform(0, 8, 64).astype(np.float32))
    req = jnp.asarray(rng.uniform(1, 10, 64).astype(np.float32))
    var = jnp.asarray(rng.uniform(0, 4, 64).astype(np.float32))
    cfg = SafeguardConfig(k1=0.05, k2=3.0)
    legacy = np.asarray(shaped_demand(peak, req, var, cfg))
    scaled = np.asarray(shaped_demand_scaled(peak, req, var, cfg.k1,
                                             jnp.full((64,), cfg.k2)))
    np.testing.assert_array_equal(legacy, scaled)


# ----------------------------------------------------------------------
# adaptive controller
# ----------------------------------------------------------------------

def test_quantile_controller_tracks_failure_budget():
    """Closed loop on an iid N(0,1) score stream: the realized
    miscoverage converges to the budget and q to the matching quantile."""
    budget = 0.2
    cfg = CalibrationConfig(enabled=True, adaptive=True, budget=budget,
                            gamma=0.02, q=0.5)
    ctl = QuantileController(cfg)
    rng = np.random.RandomState(6)
    ring = ScoreBuffer(1, 1024)
    ring.push_many(0, rng.normal(0, 1, 1024).astype(np.float32))
    errs = []
    for _ in range(800):
        zc = float(ring.scales(np.asarray([0]), ctl.q, 0.0)[0])
        batch = rng.normal(0, 1, 8)
        err = batch > zc
        errs.extend(err.tolist())
        ctl.update(err)
    tail = np.mean(errs[-2000:])
    assert abs(tail - budget) <= 0.05, tail
    assert abs(ctl.q - (1 - budget)) <= 0.08, ctl.q


def test_quantile_controller_clamps_and_ignores_empty():
    cfg = CalibrationConfig(adaptive=True, budget=0.5, gamma=10.0,
                            q=0.9, q_min=0.6, q_max=0.95)
    ctl = QuantileController(cfg)
    q0 = ctl.q
    ctl.update(np.asarray([], bool))
    assert ctl.q == q0                       # no observation, no action
    ctl.update(np.ones(10, bool))            # huge error burst
    assert ctl.q == 0.95                     # clamped at q_max
    for _ in range(10):
        ctl.update(np.zeros(10, bool))
    assert ctl.q == 0.6                      # clamped at q_min


# ----------------------------------------------------------------------
# ConformalForecaster wrapper
# ----------------------------------------------------------------------

class _PersistBase:
    """Cheap Forecaster: persistence mean, unit variance."""

    def forecast(self, window, horizon, *, valid=None):
        last = jnp.asarray(window)[-1]
        return Forecast(mean=jnp.full((horizon,), last, jnp.float32),
                        var=jnp.ones((horizon,), jnp.float32))


def test_conformal_forecaster_wrapper_calibrates_upper():
    """Streaming loop on a biased heavy-tailed residual process: the
    wrapper's calibrated upper bound covers ~q where the Gaussian band
    of the base forecaster does not."""
    cfg = CalibrationConfig(enabled=True, q=Q, capacity=512, min_scores=32)
    wrapper = ConformalForecaster(_PersistBase(), cfg)
    rng = np.random.RandomState(7)
    resid = _spiky(rng, 1500)                # spike-mixture residuals
    y = 1.0
    hits_cal, hits_gauss, n_eval = 0, 0, 0
    for t in range(1500):
        window = jnp.full((8,), y, jnp.float32)
        fc = wrapper.forecast(window, 1)
        up_c = float(wrapper.upper(fc)[0])
        up_g = float(fc.quantile(Q)[0])
        y_next = y + float(resid[t])
        if t >= 500:
            n_eval += 1
            hits_cal += y_next <= up_c
            hits_gauss += y_next <= up_g
        wrapper.observe(y_next)
        y = y_next
    assert abs(hits_cal / n_eval - Q) <= 0.04, hits_cal / n_eval
    assert hits_gauss / n_eval < Q - 0.04    # Gaussian band under-covers


# ----------------------------------------------------------------------
# online calibrator (engine-facing)
# ----------------------------------------------------------------------

def _mk_calib(n_series=4, horizon=2, fallback=3.0, **kw):
    cfg = CalibrationConfig(enabled=True, **kw)
    return OnlineCalibrator(n_series, horizon, fallback, cfg)


def test_online_calibrator_scores_peak_over_horizon():
    calib = _mk_calib(min_scores=1, pool=False)
    rows = np.asarray([0, 2])
    counts = np.asarray([10, 10])        # per-row monitor counts
    calib.begin(rows, np.asarray([1.0, 2.0], np.float32),
                np.asarray([1.0, 2.0], np.float32),
                np.asarray([2.0, 2.0], np.float32), counts)
    mon = np.asarray([10, 10])           # (M,) counts, M = n_series/2
    usage = np.asarray([1.5, 0.0, 5.0, 0.0], np.float32)
    calib.observe(usage, mon + 1)
    usage2 = np.asarray([2.5, 0.0, 4.0, 0.0], np.float32)
    calib.observe(usage2, mon + 2)
    assert calib.resolved == 2
    # row 0: peak 2.5, mean 1, sigma 1 -> score 1.5; bound 1+2*1=3 -> hit
    # row 2: peak 5, mean 2, sigma 2 -> score 1.5; bound 2+2*2=6 -> hit
    assert calib.errors == 0
    np.testing.assert_allclose(calib.scores.buf[0, -1], 1.5)
    np.testing.assert_allclose(calib.scores.buf[2, -1], 1.5)


def test_online_calibrator_reset_invalidates_pending():
    calib = _mk_calib(min_scores=1, pool=False)
    rows = np.asarray([1])
    calib.begin(rows, np.asarray([1.0], np.float32),
                np.asarray([1.0], np.float32),
                np.asarray([2.0], np.float32), np.asarray([12]))
    mon = np.asarray([12, 0])
    calib.observe(np.zeros(4, np.float32), mon + 1)
    # slot reset: counts restart instead of reaching count0 + horizon
    calib.observe(np.zeros(4, np.float32), np.asarray([1, 0]))
    assert calib.resolved == 0 and calib.dropped == 1


def test_online_calibrator_hierarchical_fallback():
    calib = _mk_calib(n_series=6, min_scores=4)
    rows = np.asarray([0, 1])
    # cold everything -> K2 fallback
    np.testing.assert_allclose(calib.scales(rows), 3.0)
    # warm the POOL only (scores land on series 5)
    for k in range(8):
        calib.begin(np.asarray([5]), np.asarray([0.0], np.float32),
                    np.asarray([1.0], np.float32),
                    np.asarray([3.0], np.float32), np.asarray([10 + 2 * k]))
        calib.observe(np.full(6, 0.5, np.float32),
                      np.asarray([0, 0, 10 + 2 * k + 1]))
        calib.observe(np.full(6, 0.5, np.float32),
                      np.asarray([0, 0, 10 + 2 * k + 2]))
    assert calib.resolved == 8
    got = calib.scales(rows)
    assert (got != 3.0).all()            # pooled quantile, not K2
    assert (np.abs(got - 0.5) < 0.2).all()


def _feed_group(calib, rows, group, scores, c0=10):
    """Resolve one prediction per (row, score) pair, all owned by
    ``group`` — each row stays below ``min_scores`` while the group's
    ring warms up."""
    n = calib._group.shape[0]
    for k, (r, s) in enumerate(zip(rows, scores)):
        base = c0 + 3 * k
        counts = np.full(n // 2, base)
        calib.begin(np.asarray([r]), np.asarray([0.0], np.float32),
                    np.asarray([1.0], np.float32),
                    np.asarray([1.0], np.float32),   # bound for coverage
                    np.asarray([base]),
                    groups=np.asarray([group]))
        usage = np.zeros(n, np.float32)
        usage[r] = s
        calib.observe(usage, counts + 1)
        calib.observe(usage, counts + 2)


def test_online_calibrator_group_tier():
    """Per-tenant (group) conformal pools: a young series borrows its
    GROUP's quantile before falling back to the shared pool — two
    tenants with very different residual scales get different bands."""
    calib = OnlineCalibrator(8, 2, 3.0,
                             CalibrationConfig(enabled=True, min_scores=4),
                             n_groups=2)
    rng = np.random.RandomState(0)
    lo = 0.4 + 0.02 * rng.rand(8).astype(np.float32)   # tenant 0: tight
    hi = 4.0 + 0.20 * rng.rand(8).astype(np.float32)   # tenant 1: wild
    _feed_group(calib, [0, 1, 2, 3] * 2, 0, lo, c0=10)
    _feed_group(calib, [4, 5, 6, 7] * 2, 1, hi, c0=100)
    assert calib.resolved == 16

    r = np.asarray([0])
    s0 = float(calib.scales(r, groups=np.asarray([0]))[0])
    s1 = float(calib.scales(r, groups=np.asarray([1]))[0])
    pooled = float(calib.scales(r)[0])
    # group 0's band is far below the (spike-dominated) pool band; the
    # pool's 0.9-quantile may tie group 1's exactly (same order stat)
    assert s0 < pooled <= s1
    assert s1 - s0 > 3.0
    assert abs(s0 - lo.max()) < 0.1
    assert abs(s1 - hi.max()) < 0.5
    # an unknown group (-1) falls back to the pool tier
    assert float(calib.scales(r, groups=np.asarray([-1]))[0]) == pooled

    # per-group coverage accounting: bound 1.0 covers every lo score
    # and none of the hi ones
    rep = calib.group_report()
    assert rep["resolved"] == [8, 8]
    assert rep["miscovered"] == [0, 8]
    assert rep["coverage"] == [1.0, 0.0]


def test_online_calibrator_group_q_override():
    """Per-row quantile overrides (the control plane's credit-widened
    targets) move the group band monotonically."""
    calib = OnlineCalibrator(4, 2, 3.0,
                             CalibrationConfig(enabled=True, min_scores=4),
                             n_groups=1)
    scores = np.linspace(1.0, 2.0, 8).astype(np.float32)
    _feed_group(calib, [0, 1] * 4, 0, scores)
    r, g = np.asarray([2]), np.asarray([0])
    mid = float(calib.scales(r, groups=g, q=np.asarray([0.5]))[0])
    top = float(calib.scales(r, groups=g, q=np.asarray([1.0]))[0])
    assert mid < top
    assert top == pytest.approx(2.0, abs=1e-5)


def test_device_group_tier_matches_host():
    """jnp functional mirror (`calib_*`): same deploy/observe stream ->
    identical group rings, counters and scale outputs."""
    from repro.core.uncertainty.online import (calib_begin,
                                               calib_group_report,
                                               calib_init, calib_observe,
                                               calib_scales)
    cfg = CalibrationConfig(enabled=True, min_scores=4)
    host = OnlineCalibrator(8, 2, 3.0, cfg, n_groups=2)
    st = calib_init(8, cfg, n_groups=2)
    rng = np.random.RandomState(1)
    plan = [(r, 0, 0.5 + 0.1 * rng.rand()) for r in [0, 1, 2, 3] * 2] \
        + [(r, 1, 3.0 + 0.5 * rng.rand()) for r in [4, 5, 6, 7] * 2]
    for k, (r, g, s) in enumerate(plan):
        base = 10 + 3 * k
        counts = np.full(4, base)
        host.begin(np.asarray([r]), np.asarray([0.0], np.float32),
                   np.asarray([1.0], np.float32),
                   np.asarray([2.0], np.float32), np.asarray([base]),
                   groups=np.asarray([g]))
        deploy = jnp.arange(8) == r
        st = calib_begin(st, deploy, jnp.zeros(8), jnp.ones(8),
                         jnp.full(8, 2.0), jnp.full(8, base), 2,
                         groups=jnp.full(8, g, jnp.int32))
        usage = np.zeros(8, np.float32)
        usage[r] = s
        for d in (1, 2):
            host.observe(usage, counts + d)
            st = calib_observe(st, jnp.asarray(usage),
                               jnp.tile(jnp.full(4, base + d), 2), cfg)
    assert host.group_resolved.tolist() == \
        np.asarray(st.group_resolved).tolist()
    assert calib_group_report(st, cfg) == host.group_report()
    rows = np.asarray([0, 4])
    all_groups = np.repeat([0, 1], 4)        # device path: per-row map
    np.testing.assert_allclose(
        np.asarray(calib_scales(st, cfg, 3.0,
                                groups=jnp.asarray(all_groups)))[rows],
        host.scales(rows, groups=all_groups[rows]), rtol=1e-5)


# ----------------------------------------------------------------------
# engine + sweep integration
# ----------------------------------------------------------------------

def _small_cfg(**kw):
    from repro.sim import ClusterConfig, SimConfig, WorkloadConfig
    return SimConfig(
        cluster=ClusterConfig(n_hosts=3, max_running_apps=24),
        workload=WorkloadConfig(n_apps=24, max_components=6,
                                max_runtime=1800.0, mean_burst_gap=2.0,
                                mean_long_gap=40.0, seed=3),
        policy="pessimistic", forecaster="persist", max_ticks=6000, **kw)


def test_engine_conformal_safeguard_end_to_end():
    from repro.sim import run_sim
    cfg = _small_cfg(calibration=CalibrationConfig(enabled=True, q=Q,
                                                   min_scores=8))
    s = run_sim(cfg).summary()
    cal = s["calibration"]
    assert s["completed"] == s["n_apps"]
    assert cal["resolved"] > 0 and cal["pool_warm"]
    assert 0.0 <= cal["coverage"] <= 1.0
    # the calibrated multiplier departed from the K2 fallback (in either
    # direction — conformal may widen a band K2 under-covered) and the
    # realized coverage tracks the q = 0.9 set-point, not K2's ~0.999
    assert cal["mean_scale"] != 3.0
    assert abs(cal["coverage"] - Q) <= 0.12
    off = run_sim(_small_cfg()).summary()
    assert "calibration" not in off


def test_engine_equivalence_preserved_with_calibration_off():
    """The default (disabled) path must stay bit-identical to the frozen
    seed reference engine."""
    from repro.sim import run_sim, run_sim_reference
    from repro.sim.scenarios import build_trace
    cfg = _small_cfg()
    wl = build_trace(cfg.workload)
    vec = run_sim(cfg, wl)
    ref = run_sim_reference(cfg, wl)
    assert vec.summary() == ref.summary()
    assert vec.turnaround == ref.turnaround
    assert vec.slack_mem == ref.slack_mem


def test_engine_ref_refuses_calibration():
    from repro.sim import run_sim_reference
    cfg = _small_cfg(calibration=CalibrationConfig(enabled=True))
    with pytest.raises(NotImplementedError):
        run_sim_reference(cfg)


def test_sweep_calibration_axis_end_to_end(tmp_path):
    from repro.sim.sweep import CALIBRATION_MODES, run_grid
    out = tmp_path / "BENCH_sweep.json"
    base = _small_cfg()
    res = run_grid(base,
                   axes={"calibration": ["sigma", "conformal", "adaptive"]},
                   seeds=[0], out_path=str(out))
    assert sorted(CALIBRATION_MODES) == ["adaptive", "conformal", "sigma"]
    data = json.loads(out.read_text())
    assert data["schema"] == 3
    assert data["calibration"], "coverage diagnostics missing"
    rec = data["calibration"][0]
    assert {"k2_coverage", "k2_nominal", "levels"} <= set(rec)
    by_mode = {c["overrides"]["calibration"]: c for c in data["cells"]}
    assert set(by_mode) == {"sigma", "conformal", "adaptive"}
    assert "calibration" not in by_mode["sigma"]["summary"]
    for mode in ("conformal", "adaptive"):
        cal = by_mode[mode]["summary"]["calibration"]
        assert cal["adaptive"] == (mode == "adaptive")
        assert cal["resolved"] > 0


def test_sweep_calibration_dotted_overrides():
    from repro.sim.sweep import expand_grid
    base = _small_cfg()
    cells = expand_grid(base, {"calibration": ["conformal"],
                               "calibration.q": [0.8, 0.95]}, seeds=[0])
    assert len(cells) == 2
    assert all(c.cfg.calibration.enabled for c in cells)
    assert sorted(c.cfg.calibration.q for c in cells) == [0.8, 0.95]


def test_sweep_cells_only_grid_has_no_spurious_base_cell():
    from repro.sim.sweep import expand_grid
    cells = expand_grid(_small_cfg(), axes=None, seeds=[0],
                        cells=[{"policy": "baseline"}])
    assert [c.overrides for c in cells] == [{"policy": "baseline"}]


def test_sweep_unknown_calibration_mode_rejected():
    from repro.sim.sweep import expand_grid
    with pytest.raises(ValueError):
        expand_grid(_small_cfg(), {"calibration": ["bogus"]}, seeds=[0])


def test_batcher_barrier_mode_bit_identical():
    """Tick-synchronous barrier batching must not change any result."""
    from repro.sim.sweep import ForecastBatcher, run_grid
    base = dataclasses.replace(
        _small_cfg(), forecaster="gp",
        workload=dataclasses.replace(_small_cfg().workload, n_apps=12))
    kw = dict(axes={"policy": ["pessimistic"]}, seeds=[0, 1])
    lead = run_grid(base, workers=2, **kw)
    barr = run_grid(base, workers=2, batch_mode="barrier",
                    barrier_timeout_s=0.01, **kw)
    assert [c["summary"] for c in lead.cells] == \
        [c["summary"] for c in barr.cells]
    assert barr.forecast_requests == lead.forecast_requests
    with pytest.raises(ValueError):
        ForecastBatcher(mode="bogus")
